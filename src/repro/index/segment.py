"""LSM-style segments over the paper's index structures.

A ``MemSegment`` is the mutable memtable: it absorbs incoming documents at
O(doc length) cost per add and, when sealed, builds all four paper index
structures (ordinary+NSW, (w,v), (f,s,t)) for its slice of the corpus via
``core.index_builder.build_segment_index`` — the *same* code path as the
single-shot ``build_index`` (which is now literally "one sealed segment").

A sealed ``Segment`` is immutable: a ``ProximityIndex`` whose doc ids are
segment-local, plus ``doc_map`` translating them to global doc ids. Every
global document lives in exactly one segment (updates are delete+re-add
under a fresh global id), which is the invariant the k-way merge reads in
``repro.index.merge`` rely on.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path

import numpy as np

from repro.core.index_builder import ProximityIndex, build_segment_index
from repro.core.lexicon import Lexicon
from repro.data.corpus import TokenTable


@dataclass(frozen=True, eq=False)  # identity equality: fields hold arrays
class Segment:
    """Immutable sealed segment: index over a corpus slice + id mapping.

    ``derived_from`` records the *immediate* lineage of a compaction
    output: the segment_ids of the victims a merge rewrote. Global doc
    ids are stable across merges, so a segment whose lineage lies
    entirely inside a snapshot's segment set carries bitwise the same
    merged reads as its victims did — the invariant the serving pack
    cache's merge-aware retention rests on (DESIGN.md §18).

    ``is_live`` marks a frozen memtable overlay (``MemSegment.freeze``):
    an ephemeral pseudo-segment serving unsealed documents inside a
    ``SegmentedView``; never persisted, never compacted."""

    segment_id: int
    index: ProximityIndex
    doc_map: np.ndarray  # (n_local_docs,) int64, strictly increasing global ids
    derived_from: tuple = ()  # segment_ids of the merge victims, () for seals
    is_live: bool = False  # frozen memtable overlay, not a durable segment

    @property
    def n_docs(self) -> int:
        return int(self.doc_map.size)

    @property
    def n_postings(self) -> int:
        """Ordinary-index posting count — the size proxy used for tiering."""
        return int(sum(self.index.ordinary.counts.values()))

    def min_doc(self) -> int:
        return int(self.doc_map[0]) if self.doc_map.size else -1

    def max_doc(self) -> int:
        return int(self.doc_map[-1]) if self.doc_map.size else -1

    # -- persistence -------------------------------------------------------
    def save(self, path: str | Path) -> None:
        from repro.index.persist import index_to_arrays

        path = Path(path)
        path.mkdir(parents=True, exist_ok=True)
        arrays = index_to_arrays(self.index)
        arrays["doc_map"] = self.doc_map.astype(np.int64)
        meta = {
            "segment_id": self.segment_id,
            "n_docs": self.n_docs,
            "max_distance": self.index.max_distance,
            "has_wv": self.index.wv is not None,
            "has_fst": self.index.fst is not None,
            "has_nsw": self.index.nsw is not None,
            "derived_from": list(self.derived_from),
        }
        # npz before meta: a dir with meta but no npz is recognizably
        # partial (crash mid-write) and ignored by the manifest loader
        np.savez(path / "segment.npz", **arrays)
        (path / "meta.json").write_text(json.dumps(meta))

    @classmethod
    def load(cls, path: str | Path, lexicon: Lexicon) -> "Segment":
        from repro.index.persist import index_from_arrays

        path = Path(path)
        meta = json.loads((path / "meta.json").read_text())
        with np.load(path / "segment.npz") as z:
            arrays = {k: z[k] for k in z.files}
        index = index_from_arrays(arrays, lexicon, meta)
        return cls(
            segment_id=int(meta["segment_id"]),
            index=index,
            doc_map=arrays["doc_map"].astype(np.int64),
            derived_from=tuple(meta.get("derived_from", ())),
        )


class MemSegment:
    """Mutable memtable absorbing documents for the next sealed segment.

    ``add_document`` only appends rows (cheap, no index work); the paper
    structures are built for the whole slice at ``seal()`` — the classic
    LSM amortization: per-doc cost stays O(doc), the d^2-heavy (f,s,t)
    construction runs once per segment over vectorized numpy.
    """

    def __init__(
        self,
        lexicon: Lexicon,
        max_distance: int = 5,
        build_wv: bool = True,
        build_fst: bool = True,
        build_nsw: bool = True,
    ):
        self.lexicon = lexicon
        self.max_distance = max_distance
        self.build_wv = build_wv
        self.build_fst = build_fst
        self.build_nsw = build_nsw
        self._doc_rows: list[np.ndarray] = []  # per doc: (n_rows,) local doc col
        self._pos_rows: list[np.ndarray] = []
        self._lem_rows: list[np.ndarray] = []
        self._lengths: list[int] = []
        self._global_ids: list[int] = []
        self._n_tokens = 0

    # -- stats -------------------------------------------------------------
    @property
    def n_docs(self) -> int:
        return len(self._lengths)

    @property
    def n_tokens(self) -> int:
        return self._n_tokens

    # -- absorption --------------------------------------------------------
    def add_document(self, global_id: int, tokens) -> None:
        """Absorb one document. ``tokens`` is a list of lemma ids, or a list
        of per-position lemma-alternative lists (multi-lemma words)."""
        if self._global_ids and global_id <= self._global_ids[-1]:
            raise ValueError("global doc ids must be strictly increasing")
        local = self.n_docs
        if len(tokens) and isinstance(tokens[0], (list, tuple)):
            pos = np.array(
                [pi for pi, alts in enumerate(tokens) for _ in alts], np.int32
            )
            lem = np.array([l for alts in tokens for l in alts], np.int32)
            length = len(tokens)
        else:
            lem = np.asarray(tokens, np.int32)
            pos = np.arange(lem.size, dtype=np.int32)
            length = int(lem.size)
        self._doc_rows.append(np.full(pos.size, local, np.int32))
        self._pos_rows.append(pos)
        self._lem_rows.append(lem)
        self._lengths.append(length)
        self._global_ids.append(int(global_id))
        self._n_tokens += length

    def add_table(self, table: TokenTable, global_ids: np.ndarray | None = None) -> None:
        """Absorb a whole TokenTable (bulk load / the single-shot path).
        Local doc ids continue from the docs already absorbed."""
        if global_ids is None:
            base = self._global_ids[-1] + 1 if self._global_ids else 0
            global_ids = np.arange(base, base + table.n_docs, dtype=np.int64)
        offset = self.n_docs
        self._doc_rows.append(table.doc_ids.astype(np.int32) + offset)
        self._pos_rows.append(table.positions.astype(np.int32))
        self._lem_rows.append(table.lemma_ids.astype(np.int32))
        self._lengths.extend(int(x) for x in table.doc_lengths)
        self._global_ids.extend(int(g) for g in global_ids)
        self._n_tokens += int(table.doc_lengths.sum())
        if len(self._global_ids) > 1:
            gids = np.asarray(self._global_ids)
            if not np.all(np.diff(gids) > 0):
                raise ValueError("global doc ids must be strictly increasing")

    # -- live search -------------------------------------------------------
    @property
    def version(self) -> tuple:
        """Cheap mutation stamp: changes on every absorbed document.
        ``SegmentedIndex.live_view`` memoizes its frozen overlay on it."""
        return (len(self._lengths), self._n_tokens)

    def freeze(self) -> Segment | None:
        """An ephemeral live overlay over the *current* buffer: the same
        build as :meth:`seal` (bit-identical structures, so merged reads
        over it match a fresh rebuild), but marked ``is_live`` and keyed
        by a sentinel segment id — it is never persisted, tiered or
        compacted, and the memtable keeps absorbing afterwards. Cost is
        O(buffered tokens); callers memoize per :attr:`version`."""
        seg = self.seal(segment_id=-1)
        if seg is None:
            return None
        return Segment(
            segment_id=-1, index=seg.index, doc_map=seg.doc_map, is_live=True
        )

    # -- sealing -----------------------------------------------------------
    def seal(self, segment_id: int) -> Segment | None:
        """Build the four index structures for this slice and freeze it.
        Returns None for an empty memtable."""
        if not self._lengths:
            return None
        table = TokenTable(
            np.concatenate(self._doc_rows) if self._doc_rows else np.zeros(0, np.int32),
            np.concatenate(self._pos_rows) if self._pos_rows else np.zeros(0, np.int32),
            np.concatenate(self._lem_rows) if self._lem_rows else np.zeros(0, np.int32),
            np.array(self._lengths, np.int32),
        )
        index = build_segment_index(
            table,
            self.lexicon,
            max_distance=self.max_distance,
            build_wv=self.build_wv,
            build_fst=self.build_fst,
            build_nsw=self.build_nsw,
        )
        return Segment(
            segment_id=segment_id,
            index=index,
            doc_map=np.array(self._global_ids, np.int64),
        )
