"""Background compaction executor (DESIGN.md §18).

``CompactionExecutor`` takes physical merges off the ``refresh()`` hot
path: a ``SegmentedIndex`` in background mode *schedules* merge jobs
here instead of running them inline, and a bounded worker pool merges
off-thread while readers keep serving immutable snapshots.

Protocol (the correctness rules the tests in
``tests/test_background_compaction.py`` pin down):

* **Capture at schedule time.** A job snapshots its victim ``Segment``
  objects and the tombstone set *as of scheduling*. The merge runs over
  exactly that capture; mutations racing the merge never feed it.
* **Atomic swap-in.** The merged output replaces its victims under the
  owner's lock in one step, and a fresh ``SegmentedView`` is published
  in the same critical section — a reader sees either the pre-merge or
  the post-merge segment set, never a torn mix.
* **Validate or supersede.** Swap-in first checks every victim is still
  live in the owner *by identity*. If any victim was already rewritten
  (an overlapping merge won, or a fully-dead segment was dropped by
  ``refresh``), the output is discarded and the job counts as
  ``superseded`` — never a second copy of a document.
* **Late tombstones survive.** Only tombstones that were in the capture
  *and* covered by the victims are purged at swap-in. A delete arriving
  mid-merge stays in the live set and keeps masking the merged segment
  at read time — a background merge can never resurrect a document.
* **Overlap cancellation.** Scheduling skips plans whose victims overlap
  a queued/running job, and a queued job whose victim set is strictly
  contained in a newly scheduled plan is cancelled in favour of the
  wider merge. Cancellation is cooperative: a running merge finishes
  (or fails) and then loses at validation.
* **Rate limit.** Merge *starts* are spaced ``min_interval_s`` apart so
  a churn burst cannot saturate the host with back-to-back merges.

``fault_hook(stage, job)`` is a test seam invoked at ``"before_merge"``
and ``"before_swap"``; raising from it fails the job (counted, surfaced
via ``result()``, never wedging the pool), sleeping in it simulates a
slow merge. ``result()`` waits on an event that is set in a ``finally``
— it cannot hang on a failed or cancelled job.
"""

from __future__ import annotations

import threading
import time

import numpy as np

from repro.index.compaction import merge_segments
from repro.index.segment import Segment

# terminal job states
MERGED = "merged"
SUPERSEDED = "superseded"
CANCELLED = "cancelled"
FAILED = "failed"
NOOP = "noop"  # merge produced no survivors and victims were dropped


class CompactionJob:
    """One scheduled merge: captured victims + tombstones, a terminal
    state, and a never-hanging ``result()``."""

    def __init__(self, victims: list[Segment], tombstones: np.ndarray, segment_id: int):
        self.victims = list(victims)
        self.victim_ids = frozenset(s.segment_id for s in victims)
        self.tombstones = np.sort(np.asarray(tombstones, np.int64))
        self.segment_id = segment_id
        self.state: str | None = None  # terminal state once _done is set
        self.error: BaseException | None = None
        self._done = threading.Event()
        self._cancel = threading.Event()

    def cancel(self) -> None:
        """Cooperative: honoured if the job has not started merging."""
        self._cancel.set()

    @property
    def cancelled(self) -> bool:
        return self._cancel.is_set()

    def _finish(self, state: str, error: BaseException | None = None) -> None:
        self.state = state
        self.error = error
        self._done.set()

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: float | None = None) -> str:
        """Block until terminal and return the state. Raises TimeoutError
        on timeout and re-raises the merge error for ``FAILED`` jobs."""
        if not self._done.wait(timeout):
            raise TimeoutError("compaction job still running")
        if self.state == FAILED and self.error is not None:
            raise self.error
        return self.state


class CompactionExecutor:
    """Bounded off-thread merge runner with rate limiting and overlap
    cancellation. One executor may serve one ``SegmentedIndex`` owner
    (the owner passes itself at ``schedule`` time)."""

    def __init__(
        self,
        workers: int = 1,
        min_interval_s: float = 0.0,
        metrics=None,
        tracer=None,
        fault_hook=None,
    ):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        self.min_interval_s = float(min_interval_s)
        self.metrics = metrics
        self.tracer = tracer
        self.fault_hook = fault_hook
        self.stats = {
            "scheduled": 0,
            "started": 0,
            "merged": 0,
            "superseded": 0,
            "cancelled": 0,
            "failed": 0,
            "noop": 0,
        }
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._queue: list[tuple[CompactionJob, object]] = []
        self._inflight: set[CompactionJob] = set()
        self._last_start = -float("inf")
        self._closed = False
        self._threads = [
            threading.Thread(target=self._worker, name=f"compaction-{i}", daemon=True)
            for i in range(workers)
        ]
        for t in self._threads:
            t.start()

    # -- scheduling --------------------------------------------------------
    def _busy_ids(self) -> set[int]:
        ids: set[int] = set()
        for job, _ in self._queue:
            ids.update(job.victim_ids)
        for job in self._inflight:
            ids.update(job.victim_ids)
        return ids

    def schedule(self, owner) -> list[CompactionJob]:
        """Plan merges over the owner's current segments and enqueue the
        non-overlapping groups. Returns the jobs enqueued (possibly [])."""
        specs = owner._compaction_specs()  # [(victims, tomb, segment_id)]
        jobs: list[CompactionJob] = []
        with self._lock:
            if self._closed:
                return []
            for victims, tomb, segment_id in specs:
                job = CompactionJob(victims, tomb, segment_id)
                # a queued job strictly inside this plan is superseded by it
                for queued, _ in list(self._queue):
                    if queued.victim_ids < job.victim_ids and not queued.done():
                        queued.cancel()
                        self._queue.remove((queued, owner))
                        queued._finish(CANCELLED)
                        self._count(CANCELLED)
                        self._idle.notify_all()
                if job.victim_ids & self._busy_ids():
                    continue  # overlap with queued/running work: skip this round
                self._queue.append((job, owner))
                jobs.append(job)
                self.stats["scheduled"] += 1
                if self.metrics is not None:
                    self.metrics.inc("compaction.scheduled")
            if jobs:
                self._idle.notify_all()
        return jobs

    # -- worker loop -------------------------------------------------------
    def _next_job(self):
        with self._lock:
            while True:
                if self._closed:
                    return None
                if self._queue:
                    wait = self._last_start + self.min_interval_s - time.monotonic()
                    if wait <= 0:
                        job, owner = self._queue.pop(0)
                        self._last_start = time.monotonic()
                        self._inflight.add(job)
                        return job, owner
                    self._idle.wait(timeout=wait)
                else:
                    self._idle.wait(timeout=0.1)

    def _worker(self) -> None:
        while True:
            item = self._next_job()
            if item is None:
                return
            job, owner = item
            try:
                self._run_job(job, owner)
            finally:
                with self._lock:
                    self._inflight.discard(job)
                    self._idle.notify_all()

    def _run_job(self, job: CompactionJob, owner) -> None:
        if job.cancelled:
            job._finish(CANCELLED)
            self._count(CANCELLED)
            return
        self.stats["started"] += 1
        if self.metrics is not None:
            self.metrics.inc("compaction.started")
        t0 = time.perf_counter()
        span = (
            self.tracer.span(
                "compaction.merge",
                cat="compaction",
                victims=sorted(job.victim_ids),
                out_segment=job.segment_id,
            )
            if self.tracer is not None
            else None
        )
        try:
            if span is not None:
                span.__enter__()
            try:
                if self.fault_hook is not None:
                    self.fault_hook("before_merge", job)
                merged = merge_segments(
                    job.victims,
                    job.tombstones,
                    owner.lexicon,
                    owner.max_distance,
                    segment_id=job.segment_id,
                )
                if self.fault_hook is not None:
                    self.fault_hook("before_swap", job)
                state = owner._apply_merge(job.victims, merged, job.tombstones)
            finally:
                if span is not None:
                    span.__exit__(None, None, None)
        except BaseException as exc:  # fault injection included
            job._finish(FAILED, exc)
            self._count(FAILED)
            return
        job._finish(state)
        self._count(state)
        if self.metrics is not None:
            self.metrics.observe("compaction.merge_ms", (time.perf_counter() - t0) * 1e3)

    def _count(self, state: str) -> None:
        self.stats[state] = self.stats.get(state, 0) + 1
        if self.metrics is not None:
            self.metrics.inc(f"compaction.{state}")

    # -- lifecycle ---------------------------------------------------------
    def pending(self) -> int:
        with self._lock:
            return len(self._queue) + len(self._inflight)

    def wait_idle(self, timeout: float | None = None) -> bool:
        """Block until no job is queued or running. Returns False on
        timeout (never raises: callers poll in loops)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            while self._queue or self._inflight:
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    return False
                self._idle.wait(timeout=remaining if remaining is not None else 0.1)
            return True

    def close(self, timeout: float | None = 10.0) -> None:
        """Cancel queued jobs, let running ones finish, stop the workers."""
        with self._lock:
            if self._closed:
                return
            for job, _ in self._queue:
                job._finish(CANCELLED)
                self._count(CANCELLED)
            self._queue.clear()
            self._closed = True
            self._idle.notify_all()
        for t in self._threads:
            t.join(timeout=timeout)
