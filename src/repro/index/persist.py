"""On-disk (de)serialization for sealed index structures.

Extends the persistence that previously existed only for ``Lexicon`` to
the full ``ProximityIndex``: every ``PostingStore`` is written as its
*encoded* blobs (one concatenated byte stream + offsets + keys + counts),
so save/load round-trips the exact on-disk representation the ByteMeter
accounts for, and loading does no re-encoding work.

Layout: a flat dict of numpy arrays (npz-friendly) with a ``kind_``
prefix per structure, plus a small JSON meta carried by the caller
(``Segment.save`` / ``save_index``).
"""

from __future__ import annotations

import json
import os
from pathlib import Path

import numpy as np

from repro.core.index_builder import NSWStreams, ProximityIndex
from repro.core.lexicon import Lexicon
from repro.core.postings import PostingStore

_KDIM = {"ordinary": 1, "wv": 2, "fst": 3}
_NCOL = {"ordinary": 2, "wv": 3, "fst": 4}


def write_json_atomic(path: str | Path, obj) -> None:
    """Crash-safe JSON swap: write a sibling tmp file, then ``os.replace``
    it over the target. Readers observe either the old or the new file,
    never a truncated one — the manifest-swap primitive the crash-recovery
    contract of ``SegmentedIndex.save`` rests on (DESIGN.md §18)."""
    path = Path(path)
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_text(json.dumps(obj))
    os.replace(tmp, path)


def store_to_arrays(store: PostingStore, kind: str) -> dict[str, np.ndarray]:
    """Force-encode a PostingStore and flatten it into arrays."""
    keys = sorted(store.counts)
    kdim = _KDIM[kind]
    if kdim == 1:
        keys_arr = np.array([[k] for k in keys], np.int64).reshape(len(keys), 1)
    else:
        keys_arr = np.array([list(k) for k in keys], np.int64).reshape(len(keys), kdim)
    blobs = [store._blob(k) for k in keys]
    lens = np.array([len(b) for b in blobs], np.int64)
    offsets = np.zeros(len(keys) + 1, np.int64)
    np.cumsum(lens, out=offsets[1:])
    blob = np.frombuffer(b"".join(blobs), np.uint8)
    counts = np.array([store.counts[k] for k in keys], np.int64)
    return {
        f"{kind}_keys": keys_arr,
        f"{kind}_counts": counts,
        f"{kind}_offsets": offsets,
        f"{kind}_blob": blob,
    }


def store_from_arrays(arrays: dict, kind: str) -> PostingStore:
    keys_arr = arrays[f"{kind}_keys"]
    counts_arr = arrays[f"{kind}_counts"]
    offsets = arrays[f"{kind}_offsets"]
    blob = arrays[f"{kind}_blob"].tobytes()
    kdim = _KDIM[kind]
    store = PostingStore(n_columns=_NCOL[kind])
    for i in range(keys_arr.shape[0]):
        key = int(keys_arr[i, 0]) if kdim == 1 else tuple(int(x) for x in keys_arr[i])
        store.blobs[key] = blob[int(offsets[i]) : int(offsets[i + 1])]
        store.counts[key] = int(counts_arr[i])
    return store


def nsw_to_arrays(nsw: NSWStreams) -> dict[str, np.ndarray]:
    lemmas = sorted(nsw.lemma_row_start)
    spans = np.array(
        [[l, *nsw.lemma_row_start[l]] for l in lemmas], np.int64
    ).reshape(len(lemmas), 3)
    return {
        "nsw_rows": nsw.neighbor_rows.astype(np.int64),
        "nsw_fls": nsw.neighbor_fls.astype(np.int64),
        "nsw_offs": nsw.neighbor_offs.astype(np.int64),
        "nsw_spans": spans,
    }


def nsw_from_arrays(arrays: dict) -> NSWStreams:
    spans = arrays["nsw_spans"]
    lemma_row_start = {
        int(spans[i, 0]): (int(spans[i, 1]), int(spans[i, 2]))
        for i in range(spans.shape[0])
    }
    return NSWStreams(
        arrays["nsw_rows"].astype(np.int64),
        arrays["nsw_fls"].astype(np.int64),
        arrays["nsw_offs"].astype(np.int64),
        lemma_row_start,
    )


def index_to_arrays(index: ProximityIndex) -> dict[str, np.ndarray]:
    arrays = store_to_arrays(index.ordinary, "ordinary")
    if index.wv is not None:
        arrays.update(store_to_arrays(index.wv, "wv"))
    if index.fst is not None:
        arrays.update(store_to_arrays(index.fst, "fst"))
    if index.nsw is not None:
        arrays.update(nsw_to_arrays(index.nsw))
    if index.doc_lengths is not None:
        arrays["doc_lengths"] = np.asarray(index.doc_lengths, np.int64)
    return arrays


def index_from_arrays(arrays: dict, lexicon: Lexicon, meta: dict) -> ProximityIndex:
    return ProximityIndex(
        lexicon=lexicon,
        max_distance=int(meta["max_distance"]),
        ordinary=store_from_arrays(arrays, "ordinary"),
        nsw=nsw_from_arrays(arrays) if meta.get("has_nsw") else None,
        wv=store_from_arrays(arrays, "wv") if meta.get("has_wv") else None,
        fst=store_from_arrays(arrays, "fst") if meta.get("has_fst") else None,
        doc_lengths=arrays.get("doc_lengths"),
    )


def save_index(index: ProximityIndex, path: str | Path) -> None:
    """Persist a plain (single-shot) ProximityIndex, lexicon included."""
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    index.lexicon.save(path / "lexicon.json")
    meta = {
        "max_distance": index.max_distance,
        "has_wv": index.wv is not None,
        "has_fst": index.fst is not None,
        "has_nsw": index.nsw is not None,
    }
    (path / "meta.json").write_text(json.dumps(meta))
    np.savez(path / "index.npz", **index_to_arrays(index))


def load_index(path: str | Path) -> ProximityIndex:
    path = Path(path)
    lexicon = Lexicon.load(path / "lexicon.json")
    meta = json.loads((path / "meta.json").read_text())
    with np.load(path / "index.npz") as z:
        arrays = {k: z[k] for k in z.files}
    return index_from_arrays(arrays, lexicon, meta)
