"""Size-tiered compaction: physically merge sealed segments.

Policy (classic LSM size-tiering): segments are bucketed into tiers by
``floor(log_fanout(n_postings))``; whenever a tier accumulates ``fanout``
segments they are merged into one segment of the next tier. Merging is a
key-wise k-way merge of all four index structures with tombstoned docs
dropped and local doc ids remapped; tombstones fully absorbed by a merge
are purged (every global doc lives in exactly one segment, so once the
only segment that could contain a deleted doc is rewritten, its tombstone
is dead weight).

Physical merging is vectorized the same way ``build_segment_index`` is:
all (key, posting) rows of a store are concatenated across segments
(raw columns, no codec round-trip), doc ids are mapped/filtered/remapped
once per segment, and a single stable lexsort + boundary-slice regroups
them per key — no per-key Python loop over posting data.
"""

from __future__ import annotations

import numpy as np

from repro.core.index_builder import NSWStreams, ProximityIndex
from repro.core.lexicon import Lexicon
from repro.core.postings import PostingStore
from repro.index.merge import isin_sorted, merged_nsw_read
from repro.index.segment import Segment


def size_tiered_plan(segments: list[Segment], fanout: int = 4) -> list[list[int]]:
    """Group segment *indices* into merge batches: any tier holding >=
    fanout segments is merged (oldest first, whole tier at once)."""
    if fanout < 2:
        raise ValueError("fanout must be >= 2")
    tiers: dict[int, list[int]] = {}
    for i, seg in enumerate(segments):
        size = max(seg.n_postings, 1)
        tier = int(np.log(size) / np.log(fanout))
        tiers.setdefault(tier, []).append(i)
    return [idxs for _, idxs in sorted(tiers.items()) if len(idxs) >= fanout]


def leveled_plan(segments: list[Segment], fanout: int = 4) -> list[list[int]]:
    """Leveled policy: a tier may hold at most one run. Any tier holding
    >= 2 segments merges them all (the output lands in a higher tier), so
    steady state is <= 1 segment per tier — minimal read amplification at
    higher write amplification than size-tiering (DESIGN.md §18). Tier
    assignment reuses the size-tiered bucketing so the two policies are
    directly comparable."""
    if fanout < 2:
        raise ValueError("fanout must be >= 2")
    tiers: dict[int, list[int]] = {}
    for i, seg in enumerate(segments):
        size = max(seg.n_postings, 1)
        tier = int(np.log(size) / np.log(fanout))
        tiers.setdefault(tier, []).append(i)
    return [idxs for _, idxs in sorted(tiers.items()) if len(idxs) >= 2]


def _merge_store(
    segments, kind: str, n_columns: int, tomb: np.ndarray, remap, with_prov: bool
):
    """Vectorized k-way merge of one PostingStore kind across segments.

    Returns (store, prov) where prov maps key -> (seg_ids, old_rows): each
    merged row's source segment ordinal and its pre-merge row ordinal
    within that segment's posting list for the key (pre-tombstone-filter
    numbering — what the NSW record renumbering aligns against).
    """
    key_parts, col_parts = [], [[] for _ in range(n_columns)]
    seg_parts, row_parts = [], []
    kdim = 0
    for si, seg in enumerate(segments):
        store = getattr(seg.index, kind)
        if store is None or store.n_keys() == 0:
            continue
        bulk = store.bulk_rows()
        if bulk is not None:
            # arena-backed store (seal/merge output): all rows are already
            # one contiguous column set — expand keys by count, no per-key
            # loop (the merge-throughput hot path, DESIGN.md §18)
            karr, bstarts, bends, bcols = bulk
            kdim = karr.shape[1]
            cnts = bends - bstarts
            keys = np.repeat(karr, cnts, axis=0)
            cols = [np.asarray(c).astype(np.int64) for c in bcols]
            if with_prov:
                rp_all = np.arange(keys.shape[0], dtype=np.int64) - np.repeat(
                    bstarts, cnts
                )
        else:
            kp, cp, rp = [], [[] for _ in range(n_columns)], []
            for k in store.counts:
                cols = store.columns(k)
                n = cols[0].size
                if n == 0:
                    continue
                krow = np.asarray(k if isinstance(k, tuple) else (k,), np.int64)
                kdim = krow.size
                kp.append(np.broadcast_to(krow, (n, kdim)))
                for ci in range(n_columns):
                    cp[ci].append(cols[ci])
                if with_prov:
                    rp.append(np.arange(n, dtype=np.int64))
            if not kp:
                continue
            keys = np.concatenate(kp)
            cols = [np.concatenate(x) for x in cp]
            if with_prov:
                rp_all = np.concatenate(rp)
        gdoc = seg.doc_map[cols[0]]
        keep = ~isin_sorted(tomb, gdoc)
        if not keep.any():
            continue
        keys = keys[keep]
        cols[0] = remap(gdoc[keep])
        cols[1:] = [c[keep] for c in cols[1:]]
        key_parts.append(keys)
        for ci in range(n_columns):
            col_parts[ci].append(cols[ci])
        if with_prov:
            seg_parts.append(np.full(keys.shape[0], si, np.int32))
            row_parts.append(rp_all[keep])

    out = PostingStore(n_columns=n_columns)
    prov: dict = {}
    if not key_parts:
        return out, prov
    keys = np.concatenate(key_parts)
    cols = [np.concatenate(x) for x in col_parts]
    # stable sort by (key, doc): same-(key,doc) rows come from one segment
    # (docs are disjoint), so fresh-build intra-doc order is preserved
    order = np.lexsort((cols[0], *[keys[:, d] for d in range(kdim - 1, -1, -1)]))
    keys = keys[order]
    cols = [c[order] for c in cols]
    if with_prov:
        seg_ids = np.concatenate(seg_parts)[order]
        old_rows = np.concatenate(row_parts)[order]
    change = np.nonzero(np.any(np.diff(keys, axis=0) != 0, axis=1))[0] + 1
    starts = np.concatenate([[0], change])
    ends = np.concatenate([change, [keys.shape[0]]])
    out_keys = keys[starts, 0] if kdim == 1 else keys[starts]
    out.put_bulk(out_keys, starts, ends, cols)
    if with_prov:  # ordinary only: a few hundred lemma keys
        for key, s, e in zip(out_keys.tolist() if kdim == 1
                             else map(tuple, keys[starts].tolist()),
                             starts.tolist(), ends.tolist()):
            prov[key] = (seg_ids[s:e], old_rows[s:e])
    return out, prov


def merge_segments(
    segments: list[Segment],
    tombstones: np.ndarray,
    lexicon: Lexicon,
    max_distance: int,
    segment_id: int,
) -> Segment | None:
    """Merge sealed segments into one, dropping tombstoned docs and
    compacting local doc ids. Returns None if nothing survives."""
    tomb = np.sort(np.asarray(tombstones, np.int64))
    # ---- surviving docs & the id remap ----------------------------------
    gid_parts, len_parts = [], []
    for seg in segments:
        keep = ~isin_sorted(tomb, seg.doc_map)
        gid_parts.append(seg.doc_map[keep])
        len_parts.append(np.asarray(seg.index.doc_lengths)[keep])
    gids = np.concatenate(gid_parts) if gid_parts else np.zeros(0, np.int64)
    if gids.size == 0:
        return None
    order = np.argsort(gids)
    doc_map_new = gids[order]
    doc_lengths_new = np.concatenate(len_parts)[order].astype(np.int32)
    remap = lambda g: np.searchsorted(doc_map_new, g)  # noqa: E731

    has_wv = all(seg.index.wv is not None for seg in segments)
    has_fst = all(seg.index.fst is not None for seg in segments)
    has_nsw = all(seg.index.nsw is not None for seg in segments)

    ordinary, prov = _merge_store(segments, "ordinary", 2, tomb, remap, with_prov=has_nsw)
    wv = _merge_store(segments, "wv", 3, tomb, remap, with_prov=False)[0] if has_wv else None
    fst = _merge_store(segments, "fst", 4, tomb, remap, with_prov=False)[0] if has_fst else None

    # ---- NSW streams: renumber rows into the merged ordinary order ------
    nsw = None
    if has_nsw:
        sw = lexicon.sw_count
        rows_l, fls_l, offs_l = [], [], []
        lemma_row_start: dict[int, tuple[int, int]] = {}
        off = 0
        for k in sorted(ordinary.counts):  # ascending lemma -> ascending spans
            cnt = ordinary.n_postings(k)
            if cnt and k >= sw:
                lemma_row_start[k] = (off, off + cnt)
                seg_ids, old_rows = prov[k]
                rows, fls, offs, _ = merged_nsw_read(
                    segments, k, seg_ids, old_rows, count_bytes=False
                )
                if rows.size:
                    rows_l.append(rows + off)
                    fls_l.append(fls)
                    offs_l.append(offs)
            off += cnt
        nsw = NSWStreams(
            np.concatenate(rows_l) if rows_l else np.zeros(0, np.int64),
            np.concatenate(fls_l) if fls_l else np.zeros(0, np.int64),
            np.concatenate(offs_l) if offs_l else np.zeros(0, np.int64),
            lemma_row_start,
        )

    index = ProximityIndex(
        lexicon=lexicon,
        max_distance=max_distance,
        ordinary=ordinary,
        nsw=nsw,
        wv=wv,
        fst=fst,
        doc_lengths=doc_lengths_new,
    )
    return Segment(
        segment_id=segment_id,
        index=index,
        doc_map=doc_map_new,
        derived_from=tuple(s.segment_id for s in segments),
    )
