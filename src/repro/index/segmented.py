"""Segmented, incrementally-updatable composite index.

``SegmentedIndex`` is the mutable manager: documents stream into a
``MemSegment`` memtable, ``refresh()`` seals it into an immutable
``Segment`` and publishes a new ``SegmentedView`` snapshot; deletes are
tombstones applied at read/merge time; size-tiered compaction keeps the
segment count bounded.

``SegmentedView`` implements the exact read API of
``core.index_builder.ProximityIndex`` (``read_ordinary`` / ``read_wv`` /
``read_fst`` / ``nsw.read`` / ``size_report`` plus the ``ordinary`` /
``wv`` / ``fst`` store attributes, ``lexicon``, ``max_distance``,
``doc_lengths``), so ``InvertedIndexEngine``, ``ProximitySearchEngine``
and the bucketed JAX serving path (``pack_qt1_batch`` /
``make_qt1_serve_step``) all run unchanged over a mutating corpus.

Visibility contract (Lucene-NRT style): reads go through the snapshot
current at engine construction; adds/deletes become visible only after
``refresh()``. Snapshots are immutable, so in-flight batches on an old
snapshot stay consistent while merges run. Doc ids seen by engines are
*global* ids (stable across compactions; deleted ids leave holes).

The FL-list (``Lexicon``) is fixed for the lifetime of the index, as in
the paper: lemma ids are frequency ranks of the reference corpus, and
re-ranking would invalidate every sealed segment.
"""

from __future__ import annotations

import itertools
import json
import threading
from collections import OrderedDict
from pathlib import Path

import numpy as np

from repro.core.codecs import zigzag_decode
from repro.core.lexicon import Lexicon
from repro.core.postings import ByteMeter
from repro.data.corpus import TokenTable
from repro.index.compaction import merge_segments, size_tiered_plan
from repro.index.merge import isin_sorted, merged_key_read, merged_nsw_read
from repro.index.segment import MemSegment, Segment

_CACHE_CAP = 4096  # merged-read entries per snapshot

_SNAPSHOT_IDS = itertools.count(1)


def snapshot_token(index) -> int:
    """Stable identity of an immutable searcher view, for external caches
    (e.g. the serving layer's packed-posting cache, DESIGN.md §11).

    ``SegmentedView`` carries a process-unique ``snapshot_id`` minted at
    construction — two distinct snapshots never share a token, even if one
    is garbage-collected and the other reuses its memory. Static
    ``ProximityIndex`` objects are immutable for their lifetime, so their
    ``id()`` is a valid token as long as the caller keeps a reference
    (serving engines do). A mutable ``SegmentedIndex`` delegates to its
    current published snapshot."""
    tok = getattr(index, "snapshot_id", None)
    if tok is not None:
        return tok
    if hasattr(index, "snapshot"):
        return index.snapshot().snapshot_id
    return id(index)


class _MergedStore:
    """PostingStore-shaped facade over the per-segment stores: metered
    ``read``, ``__contains__``, ``n_postings``, ``keys``, backed by the
    snapshot's merged-read cache."""

    def __init__(self, view: "SegmentedView", kind: str):
        self._view = view
        self._kind = kind

    def __contains__(self, key) -> bool:
        return any(
            key in getattr(seg.index, self._kind) for seg in self._view.segments
        )

    def keys(self):
        out = set()
        for seg in self._view.segments:
            out.update(getattr(seg.index, self._kind).keys())
        return out

    def n_postings(self, key) -> int:
        """Exact *live* posting count (tombstones applied) — anchor choice
        and bucket sizing match a fresh rebuild."""
        if key not in self:
            return 0
        cols, _, _, _ = self._view._merged(self._kind, key)
        return int(cols[0].size)

    def read(self, key, meter: ByteMeter | None = None) -> list[np.ndarray]:
        cols, _, _, nbytes = self._view._merged(self._kind, key)
        if meter is not None:
            meter.add(nbytes, cols[0].size)
        return cols

    def total_bytes(self) -> int:
        return sum(
            getattr(seg.index, self._kind).total_bytes() for seg in self._view.segments
        )


class _MergedNSW:
    """NSWStreams-shaped facade: per-lemma record streams renumbered to
    align with the merged ordinary posting list of that lemma."""

    def __init__(self, view: "SegmentedView"):
        self._view = view

    def read(self, lemma: int, meter: ByteMeter | None = None):
        rows, fls, offs, nbytes = self._view._merged_nsw(lemma)
        if meter is not None:
            meter.add(nbytes, 0)
        return rows, fls, offs

    def total_bytes(self) -> int:
        return sum(
            seg.index.nsw.blob(l).__len__()
            for seg in self._view.segments
            if seg.index.nsw is not None
            for l in seg.index.nsw.lemma_row_start
        )


class SegmentedView:
    """Immutable searcher snapshot over a set of sealed segments."""

    def __init__(
        self,
        segments: tuple[Segment, ...],
        tombstones: np.ndarray,
        lexicon: Lexicon,
        max_distance: int,
        n_total_docs: int,
        epoch: int = 0,
    ):
        # identity for external caches: `epoch` is the publisher's refresh
        # counter (human-meaningful), `snapshot_id` is process-unique and
        # never reused — cache keys must use snapshot_id (DESIGN.md §11)
        self.epoch = int(epoch)
        self.snapshot_id = next(_SNAPSHOT_IDS)
        self.segments = tuple(segments)
        self.tombstones = np.sort(np.asarray(tombstones, np.int64))
        self.lexicon = lexicon
        self.max_distance = max_distance
        self.n_total_docs = int(n_total_docs)
        # global doc-length table (holes for deleted/compacted-away docs
        # keep their slot: engines only use it to size the doc stride)
        dl = np.zeros(max(self.n_total_docs, 1), np.int64)
        for seg in self.segments:
            dl[seg.doc_map] = np.asarray(seg.index.doc_lengths, np.int64)
        self.doc_lengths = dl
        has = lambda kind: any(  # noqa: E731
            getattr(s.index, kind) is not None for s in self.segments
        )
        self.ordinary = _MergedStore(self, "ordinary")
        self.wv = _MergedStore(self, "wv") if has("wv") else None
        self.fst = _MergedStore(self, "fst") if has("fst") else None
        self.nsw = _MergedNSW(self) if has("nsw") else None
        self._cache: OrderedDict = OrderedDict()
        self._cache_lock = threading.Lock()  # snapshots are shared across
        # serving threads; merged entries are immutable so only the
        # OrderedDict bookkeeping needs guarding

    # -- merged reads (cached per snapshot) --------------------------------
    def _cache_put(self, ck, value):
        with self._cache_lock:
            if len(self._cache) >= _CACHE_CAP:
                self._cache.popitem(last=False)
            self._cache[ck] = value

    def _merged(self, kind: str, key):
        with self._cache_lock:
            hit = self._cache.get((kind, key))
        if hit is None:
            hit = merged_key_read(self.segments, kind, key, self.tombstones)
            self._cache_put((kind, key), hit)
        return hit

    def _merged_nsw(self, lemma: int):
        with self._cache_lock:
            hit = self._cache.get(("nsw", lemma))
        if hit is None:
            _, seg_ids, old_rows, _ = self._merged("ordinary", lemma)
            hit = merged_nsw_read(self.segments, lemma, seg_ids, old_rows)
            self._cache_put(("nsw", lemma), hit)
        return hit

    # -- ProximityIndex read API -------------------------------------------
    @property
    def has_additional(self) -> bool:
        return self.fst is not None

    def read_ordinary(self, lemma: int, meter: ByteMeter | None = None):
        cols = self.ordinary.read(lemma, meter)
        return cols[0], cols[1]

    def read_wv(self, key, meter: ByteMeter | None = None):
        cols = self.wv.read(key, meter)
        return cols[0], cols[1], zigzag_decode(cols[2].astype(np.uint64))

    def read_fst(self, key, meter: ByteMeter | None = None):
        cols = self.fst.read(key, meter)
        return (
            cols[0],
            cols[1],
            zigzag_decode(cols[2].astype(np.uint64)),
            zigzag_decode(cols[3].astype(np.uint64)),
        )

    def live_doc_ids(self) -> np.ndarray:
        """Sorted global ids of all non-deleted documents."""
        if not self.segments:
            return np.zeros(0, np.int64)
        parts = [
            seg.doc_map[~isin_sorted(self.tombstones, seg.doc_map)]
            for seg in self.segments
        ]
        return np.sort(np.concatenate(parts))

    def size_report(self) -> dict:
        rep = {
            "n_segments": len(self.segments),
            "live_docs": int(self.live_doc_ids().size),
            "tombstones": int(self.tombstones.size),
            "ordinary_bytes": sum(s.index.ordinary.total_bytes() for s in self.segments),
        }
        if self.wv is not None:
            rep["wv_bytes"] = sum(
                s.index.wv.total_bytes() for s in self.segments if s.index.wv is not None
            )
            rep["wv_keys"] = len(self.wv.keys())
        if self.fst is not None:
            rep["fst_bytes"] = sum(
                s.index.fst.total_bytes() for s in self.segments if s.index.fst is not None
            )
            rep["fst_keys"] = len(self.fst.keys())
        return rep


class SegmentedIndex:
    """Mutable LSM-style index manager with an immutable-snapshot read path.

    Typical serving loop::

        idx = SegmentedIndex(lexicon)
        idx.add_document([...]); idx.delete_document(gid)
        idx.refresh()                    # seal + maybe compact + publish
        engine = ProximitySearchEngine(idx.snapshot())
    """

    def __init__(
        self,
        lexicon: Lexicon,
        max_distance: int = 5,
        build_wv: bool = True,
        build_fst: bool = True,
        build_nsw: bool = True,
        memtable_docs: int = 512,
        tier_fanout: int = 4,
    ):
        if tier_fanout < 2:
            raise ValueError("tier_fanout must be >= 2")
        if memtable_docs < 1:
            raise ValueError("memtable_docs must be >= 1")
        self.lexicon = lexicon
        self.max_distance = max_distance
        self._flags = dict(build_wv=build_wv, build_fst=build_fst, build_nsw=build_nsw)
        self.memtable_docs = memtable_docs
        self.tier_fanout = tier_fanout
        self._segments: list[Segment] = []
        self._tombstones: set[int] = set()
        self._next_doc = 0
        self._next_seg = 0
        self._mem = self._new_mem()
        self._snapshot: SegmentedView | None = None
        self._epoch = 0
        self.stats = {"seals": 0, "merges": 0, "docs_added": 0, "docs_deleted": 0}

    def _new_mem(self) -> MemSegment:
        return MemSegment(self.lexicon, max_distance=self.max_distance, **self._flags)

    # -- mutation ----------------------------------------------------------
    def add_document(self, tokens) -> int:
        """Absorb one document; returns its global doc id. The doc becomes
        searchable after the next refresh()."""
        gid = self._next_doc
        self._next_doc += 1
        self._mem.add_document(gid, tokens)
        self.stats["docs_added"] += 1
        if self._mem.n_docs >= self.memtable_docs:
            self._seal()
        return gid

    def add_table(self, table: TokenTable) -> np.ndarray:
        """Bulk-load a TokenTable; returns the assigned global doc ids."""
        gids = np.arange(self._next_doc, self._next_doc + table.n_docs, dtype=np.int64)
        self._mem.add_table(table, gids)
        self._next_doc += table.n_docs
        self.stats["docs_added"] += table.n_docs
        if self._mem.n_docs >= self.memtable_docs:
            self._seal()
        return gids

    def delete_document(self, global_id: int) -> None:
        """Tombstone a document (visible after the next refresh()). The id
        is never reused; an update is delete + re-add under a fresh id.
        Idempotent: re-deleting an already-deleted doc (even one whose
        tombstone was purged by compaction) is a no-op — a tombstone no
        segment covers could never be purged again."""
        global_id = int(global_id)
        if not 0 <= global_id < self._next_doc:
            raise KeyError(f"unknown doc id {global_id}")
        if global_id in self._tombstones:
            return
        covered = global_id in self._mem._global_ids or any(
            bool(isin_sorted(seg.doc_map, np.array([global_id])))
            for seg in self._segments
        )
        if not covered:  # already deleted and physically compacted away
            return
        self._tombstones.add(global_id)
        self.stats["docs_deleted"] += 1

    # -- seal / compact ----------------------------------------------------
    def _seal(self) -> None:
        seg = self._mem.seal(segment_id=self._next_seg)
        if seg is not None:
            self._next_seg += 1
            self._segments.append(seg)
            self.stats["seals"] += 1
            self._mem = self._new_mem()
            self.maybe_compact()

    def maybe_compact(self) -> int:
        """Run the size-tiered policy until stable; returns merge count."""
        merges = 0
        while True:
            plan = size_tiered_plan(self._segments, self.tier_fanout)
            if not plan:
                return merges
            # merge one group per pass: indices into self._segments go
            # stale the moment _merge_group mutates the list, so replan
            self._merge_group(plan[0])
            merges += 1

    def compact(self, force: bool = False) -> int:
        """force=True merges *all* segments into one (major compaction);
        otherwise runs the size-tiered policy."""
        if not force:
            return self.maybe_compact()
        if len(self._segments) <= 1 and not (
            self._segments and self._covered_tombstones(self._segments)
        ):
            return 0
        self._merge_group(list(range(len(self._segments))))
        return 1

    def _covered_tombstones(self, segs: list[Segment]) -> set[int]:
        covered = set()
        for seg in segs:
            covered.update(int(g) for g in seg.doc_map)
        return covered & self._tombstones

    def _merge_group(self, group: list[int]) -> None:
        group_set = set(group)
        victims = [self._segments[i] for i in group]
        tomb = np.array(sorted(self._tombstones), np.int64)
        merged = merge_segments(
            victims, tomb, self.lexicon, self.max_distance, segment_id=self._next_seg
        )
        self._next_seg += 1
        survivors = [s for i, s in enumerate(self._segments) if i not in group_set]
        if merged is not None:
            survivors.append(merged)
        self._segments = survivors
        # tombstones absorbed by this merge are purged: each global doc
        # lives in exactly one segment, so no other segment can hold them
        self._tombstones -= self._covered_tombstones(victims)
        self.stats["merges"] += 1

    # -- snapshot / refresh -------------------------------------------------
    def refresh(self) -> SegmentedView:
        """Seal the memtable, drop fully-dead segments, run compaction, and
        publish a new immutable snapshot."""
        if self._mem.n_docs:
            self._seal()
        tomb = np.array(sorted(self._tombstones), np.int64)
        live = [
            seg
            for seg in self._segments
            if not bool(np.all(isin_sorted(tomb, seg.doc_map)))
        ]
        if len(live) != len(self._segments):
            dropped = [s for s in self._segments if s not in live]
            self._segments = live
            for seg in dropped:
                self._tombstones -= {int(g) for g in seg.doc_map}
        self.maybe_compact()
        self._epoch += 1
        self._snapshot = SegmentedView(
            tuple(self._segments),
            np.array(sorted(self._tombstones), np.int64),
            self.lexicon,
            self.max_distance,
            self._next_doc,
            epoch=self._epoch,
        )
        return self._snapshot

    def snapshot(self) -> SegmentedView:
        """The last published immutable view (publishing one if none yet)."""
        if self._snapshot is None:
            return self.refresh()
        return self._snapshot

    @property
    def n_segments(self) -> int:
        return len(self._segments)

    # -- ProximityIndex read API (delegates to the current snapshot) -------
    @property
    def doc_lengths(self):
        return self.snapshot().doc_lengths

    @property
    def ordinary(self):
        return self.snapshot().ordinary

    @property
    def wv(self):
        return self.snapshot().wv

    @property
    def fst(self):
        return self.snapshot().fst

    @property
    def nsw(self):
        return self.snapshot().nsw

    @property
    def has_additional(self) -> bool:
        return self.snapshot().has_additional

    def read_ordinary(self, lemma, meter=None):
        return self.snapshot().read_ordinary(lemma, meter)

    def read_wv(self, key, meter=None):
        return self.snapshot().read_wv(key, meter)

    def read_fst(self, key, meter=None):
        return self.snapshot().read_fst(key, meter)

    def live_doc_ids(self) -> np.ndarray:
        return self.snapshot().live_doc_ids()

    def size_report(self) -> dict:
        return self.snapshot().size_report()

    # -- persistence --------------------------------------------------------
    def save(self, path: str | Path) -> None:
        path = Path(path)
        path.mkdir(parents=True, exist_ok=True)
        if self._mem.n_docs:  # durability: everything buffered gets sealed
            self._seal()
        self.lexicon.save(path / "lexicon.json")
        manifest = {
            "format_version": 1,
            "max_distance": self.max_distance,
            "flags": self._flags,
            "memtable_docs": self.memtable_docs,
            "tier_fanout": self.tier_fanout,
            "next_doc": self._next_doc,
            "next_seg": self._next_seg,
            "tombstones": sorted(self._tombstones),
            "segments": [f"seg_{seg.segment_id:06d}" for seg in self._segments],
        }
        for seg in self._segments:
            seg.save(path / f"seg_{seg.segment_id:06d}")
        (path / "manifest.json").write_text(json.dumps(manifest))

    @classmethod
    def load(cls, path: str | Path) -> "SegmentedIndex":
        path = Path(path)
        manifest = json.loads((path / "manifest.json").read_text())
        lexicon = Lexicon.load(path / "lexicon.json")
        out = cls(
            lexicon,
            max_distance=manifest["max_distance"],
            memtable_docs=manifest["memtable_docs"],
            tier_fanout=manifest["tier_fanout"],
            **manifest["flags"],
        )
        out._segments = [Segment.load(path / name, lexicon) for name in manifest["segments"]]
        out._tombstones = set(manifest["tombstones"])
        out._next_doc = manifest["next_doc"]
        out._next_seg = manifest["next_seg"]
        return out
