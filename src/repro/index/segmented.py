"""Segmented, incrementally-updatable composite index.

``SegmentedIndex`` is the mutable manager: documents stream into a
``MemSegment`` memtable, ``refresh()`` seals it into an immutable
``Segment`` and publishes a new ``SegmentedView`` snapshot; deletes are
tombstones applied at read/merge time; size-tiered compaction keeps the
segment count bounded.

``SegmentedView`` implements the exact read API of
``core.index_builder.ProximityIndex`` (``read_ordinary`` / ``read_wv`` /
``read_fst`` / ``nsw.read`` / ``size_report`` plus the ``ordinary`` /
``wv`` / ``fst`` store attributes, ``lexicon``, ``max_distance``,
``doc_lengths``), so ``InvertedIndexEngine``, ``ProximitySearchEngine``
and the bucketed JAX serving path (``pack_qt1_batch`` /
``make_qt1_serve_step``) all run unchanged over a mutating corpus.

Visibility contract (Lucene-NRT style): reads go through the snapshot
current at engine construction; adds/deletes become visible only after
``refresh()``. Snapshots are immutable, so in-flight batches on an old
snapshot stay consistent while merges run. Doc ids seen by engines are
*global* ids (stable across compactions; deleted ids leave holes).

The FL-list (``Lexicon``) is fixed for the lifetime of the index, as in
the paper: lemma ids are frequency ranks of the reference corpus, and
re-ranking would invalidate every sealed segment.
"""

from __future__ import annotations

import itertools
import json
import threading
from collections import OrderedDict
from pathlib import Path

import numpy as np

from repro.core.codecs import zigzag_decode
from repro.core.lexicon import Lexicon
from repro.core.postings import ByteMeter
from repro.data.corpus import TokenTable
from repro.index.background import MERGED, NOOP, SUPERSEDED, CompactionExecutor
from repro.index.compaction import leveled_plan, merge_segments, size_tiered_plan
from repro.index.merge import isin_sorted, merged_key_read, merged_nsw_read
from repro.index.segment import MemSegment, Segment

_CACHE_CAP = 4096  # merged-read entries per snapshot

_SNAPSHOT_IDS = itertools.count(1)


def snapshot_token(index) -> int:
    """Stable identity of an immutable searcher view, for external caches
    (e.g. the serving layer's packed-posting cache, DESIGN.md §11).

    ``SegmentedView`` carries a process-unique ``snapshot_id`` minted at
    construction — two distinct snapshots never share a token, even if one
    is garbage-collected and the other reuses its memory. Static
    ``ProximityIndex`` objects are immutable for their lifetime, so their
    ``id()`` is a valid token as long as the caller keeps a reference
    (serving engines do). A mutable ``SegmentedIndex`` delegates to its
    current published snapshot."""
    tok = getattr(index, "snapshot_id", None)
    if tok is not None:
        return tok
    if hasattr(index, "snapshot"):
        return index.snapshot().snapshot_id
    return id(index)


class _MergedStore:
    """PostingStore-shaped facade over the per-segment stores: metered
    ``read``, ``__contains__``, ``n_postings``, ``keys``, backed by the
    snapshot's merged-read cache."""

    def __init__(self, view: "SegmentedView", kind: str):
        self._view = view
        self._kind = kind

    def __contains__(self, key) -> bool:
        return any(
            key in getattr(seg.index, self._kind) for seg in self._view.segments
        )

    def keys(self):
        out = set()
        for seg in self._view.segments:
            out.update(getattr(seg.index, self._kind).keys())
        return out

    def n_postings(self, key) -> int:
        """Exact *live* posting count (tombstones applied) — anchor choice
        and bucket sizing match a fresh rebuild."""
        if key not in self:
            return 0
        cols, _, _, _ = self._view._merged(self._kind, key)
        return int(cols[0].size)

    def read(self, key, meter: ByteMeter | None = None) -> list[np.ndarray]:
        cols, _, _, nbytes = self._view._merged(self._kind, key)
        if meter is not None:
            meter.add(nbytes, cols[0].size)
        return cols

    def total_bytes(self) -> int:
        return sum(
            getattr(seg.index, self._kind).total_bytes() for seg in self._view.segments
        )


class _MergedNSW:
    """NSWStreams-shaped facade: per-lemma record streams renumbered to
    align with the merged ordinary posting list of that lemma."""

    def __init__(self, view: "SegmentedView"):
        self._view = view

    def read(self, lemma: int, meter: ByteMeter | None = None):
        rows, fls, offs, nbytes = self._view._merged_nsw(lemma)
        if meter is not None:
            meter.add(nbytes, 0)
        return rows, fls, offs

    def total_bytes(self) -> int:
        return sum(
            seg.index.nsw.blob(l).__len__()
            for seg in self._view.segments
            if seg.index.nsw is not None
            for l in seg.index.nsw.lemma_row_start
        )


class SegmentedView:
    """Immutable searcher snapshot over a set of sealed segments."""

    def __init__(
        self,
        segments: tuple[Segment, ...],
        tombstones: np.ndarray,
        lexicon: Lexicon,
        max_distance: int,
        n_total_docs: int,
        epoch: int = 0,
        mem_overlay: Segment | None = None,
    ):
        # identity for external caches: `epoch` is the publisher's refresh
        # counter (human-meaningful), `snapshot_id` is process-unique and
        # never reused — cache keys must use snapshot_id (DESIGN.md §11)
        self.epoch = int(epoch)
        self.snapshot_id = next(_SNAPSHOT_IDS)
        # `mem_overlay` (DESIGN.md §18) is a frozen memtable pseudo-segment
        # appended to the read set: live views built by
        # ``SegmentedIndex.live_view`` make unsealed adds searchable before
        # any refresh. It participates in every merged read like a sealed
        # segment but is ephemeral — caches treat overlay views as
        # uncacheable churn and the planner routes overlay-touching queries
        # to the scalar executor.
        self.mem_overlay = mem_overlay
        self.segments = tuple(segments) + (
            (mem_overlay,) if mem_overlay is not None else ()
        )
        self.tombstones = np.sort(np.asarray(tombstones, np.int64))
        self.lexicon = lexicon
        self.max_distance = max_distance
        self.n_total_docs = int(n_total_docs)
        # global doc-length table (holes for deleted/compacted-away docs
        # keep their slot: engines only use it to size the doc stride)
        dl = np.zeros(max(self.n_total_docs, 1), np.int64)
        for seg in self.segments:
            dl[seg.doc_map] = np.asarray(seg.index.doc_lengths, np.int64)
        self.doc_lengths = dl
        has = lambda kind: any(  # noqa: E731
            getattr(s.index, kind) is not None for s in self.segments
        )
        self.ordinary = _MergedStore(self, "ordinary")
        self.wv = _MergedStore(self, "wv") if has("wv") else None
        self.fst = _MergedStore(self, "fst") if has("fst") else None
        self.nsw = _MergedNSW(self) if has("nsw") else None
        self._cache: OrderedDict = OrderedDict()
        self._cache_lock = threading.Lock()  # snapshots are shared across
        # serving threads; merged entries are immutable so only the
        # OrderedDict bookkeeping needs guarding

    # -- merged reads (cached per snapshot) --------------------------------
    def _cache_put(self, ck, value):
        with self._cache_lock:
            if len(self._cache) >= _CACHE_CAP:
                self._cache.popitem(last=False)
            self._cache[ck] = value

    def _merged(self, kind: str, key):
        with self._cache_lock:
            hit = self._cache.get((kind, key))
        if hit is None:
            hit = merged_key_read(self.segments, kind, key, self.tombstones)
            self._cache_put((kind, key), hit)
        return hit

    def _merged_nsw(self, lemma: int):
        with self._cache_lock:
            hit = self._cache.get(("nsw", lemma))
        if hit is None:
            _, seg_ids, old_rows, _ = self._merged("ordinary", lemma)
            hit = merged_nsw_read(self.segments, lemma, seg_ids, old_rows)
            self._cache_put(("nsw", lemma), hit)
        return hit

    # -- ProximityIndex read API -------------------------------------------
    @property
    def has_additional(self) -> bool:
        return self.fst is not None

    def read_ordinary(self, lemma: int, meter: ByteMeter | None = None):
        cols = self.ordinary.read(lemma, meter)
        return cols[0], cols[1]

    def read_wv(self, key, meter: ByteMeter | None = None):
        cols = self.wv.read(key, meter)
        return cols[0], cols[1], zigzag_decode(cols[2].astype(np.uint64))

    def read_fst(self, key, meter: ByteMeter | None = None):
        cols = self.fst.read(key, meter)
        return (
            cols[0],
            cols[1],
            zigzag_decode(cols[2].astype(np.uint64)),
            zigzag_decode(cols[3].astype(np.uint64)),
        )

    def live_doc_ids(self) -> np.ndarray:
        """Sorted global ids of all non-deleted documents."""
        if not self.segments:
            return np.zeros(0, np.int64)
        parts = [
            seg.doc_map[~isin_sorted(self.tombstones, seg.doc_map)]
            for seg in self.segments
        ]
        return np.sort(np.concatenate(parts))

    def size_report(self) -> dict:
        rep = {
            "n_segments": len(self.segments),
            "live_docs": int(self.live_doc_ids().size),
            "tombstones": int(self.tombstones.size),
            "ordinary_bytes": sum(s.index.ordinary.total_bytes() for s in self.segments),
        }
        if self.wv is not None:
            rep["wv_bytes"] = sum(
                s.index.wv.total_bytes() for s in self.segments if s.index.wv is not None
            )
            rep["wv_keys"] = len(self.wv.keys())
        if self.fst is not None:
            rep["fst_bytes"] = sum(
                s.index.fst.total_bytes() for s in self.segments if s.index.fst is not None
            )
            rep["fst_keys"] = len(self.fst.keys())
        return rep


class SegmentedIndex:
    """Mutable LSM-style index manager with an immutable-snapshot read path.

    Typical serving loop::

        idx = SegmentedIndex(lexicon)
        idx.add_document([...]); idx.delete_document(gid)
        idx.refresh()                    # seal + maybe compact + publish
        engine = ProximitySearchEngine(idx.snapshot())
    """

    def __init__(
        self,
        lexicon: Lexicon,
        max_distance: int = 5,
        build_wv: bool = True,
        build_fst: bool = True,
        build_nsw: bool = True,
        memtable_docs: int = 512,
        tier_fanout: int = 4,
        background: bool = False,
        policy: str = "size_tiered",
        executor: CompactionExecutor | None = None,
        min_compact_interval_s: float = 0.0,
    ):
        if tier_fanout < 2:
            raise ValueError("tier_fanout must be >= 2")
        if memtable_docs < 1:
            raise ValueError("memtable_docs must be >= 1")
        if policy not in ("size_tiered", "leveled"):
            raise ValueError(f"unknown compaction policy {policy!r}")
        self.lexicon = lexicon
        self.max_distance = max_distance
        self._flags = dict(build_wv=build_wv, build_fst=build_fst, build_nsw=build_nsw)
        self.memtable_docs = memtable_docs
        self.tier_fanout = tier_fanout
        self.policy = policy
        self._plan_fn = size_tiered_plan if policy == "size_tiered" else leveled_plan
        self._segments: list[Segment] = []
        self._tombstones: set[int] = set()
        self._next_doc = 0
        self._next_seg = 0
        self._mem = self._new_mem()
        self._snapshot: SegmentedView | None = None
        self._epoch = 0
        # one reentrant lock guards all mutable state; immutable snapshots
        # are read lock-free. Background swap-ins take the same lock, so a
        # published view is always a consistent (segments, tombstones) pair
        self._lock = threading.RLock()
        self._live_memo: tuple | None = None
        self.background = bool(background)
        self._owns_executor = background and executor is None
        self._executor = (
            executor
            if executor is not None
            else (
                CompactionExecutor(min_interval_s=min_compact_interval_s)
                if background
                else None
            )
        )
        self.stats = {"seals": 0, "merges": 0, "docs_added": 0, "docs_deleted": 0}

    @property
    def executor(self) -> CompactionExecutor | None:
        return self._executor

    def _new_mem(self) -> MemSegment:
        return MemSegment(self.lexicon, max_distance=self.max_distance, **self._flags)

    # -- mutation ----------------------------------------------------------
    def add_document(self, tokens) -> int:
        """Absorb one document; returns its global doc id. The doc becomes
        searchable after the next refresh() (immediately via live_view())."""
        with self._lock:
            gid = self._next_doc
            self._next_doc += 1
            self._mem.add_document(gid, tokens)
            self.stats["docs_added"] += 1
            if self._mem.n_docs >= self.memtable_docs:
                self._seal()
        return gid

    def add_table(self, table: TokenTable) -> np.ndarray:
        """Bulk-load a TokenTable; returns the assigned global doc ids."""
        with self._lock:
            gids = np.arange(self._next_doc, self._next_doc + table.n_docs, dtype=np.int64)
            self._mem.add_table(table, gids)
            self._next_doc += table.n_docs
            self.stats["docs_added"] += table.n_docs
            if self._mem.n_docs >= self.memtable_docs:
                self._seal()
        return gids

    def delete_document(self, global_id: int) -> None:
        """Tombstone a document (visible after the next refresh()). The id
        is never reused; an update is delete + re-add under a fresh id.
        Idempotent: re-deleting an already-deleted doc (even one whose
        tombstone was purged by compaction) is a no-op — a tombstone no
        segment covers could never be purged again."""
        global_id = int(global_id)
        with self._lock:
            if not 0 <= global_id < self._next_doc:
                raise KeyError(f"unknown doc id {global_id}")
            if global_id in self._tombstones:
                return
            covered = global_id in self._mem._global_ids or any(
                bool(isin_sorted(seg.doc_map, np.array([global_id])))
                for seg in self._segments
            )
            if not covered:  # already deleted and physically compacted away
                return
            self._tombstones.add(global_id)
            self.stats["docs_deleted"] += 1

    # -- seal / compact ----------------------------------------------------
    def _seal_only(self) -> bool:
        """Seal the memtable into a new segment (no compaction). O(memtable)."""
        seg = self._mem.seal(segment_id=self._next_seg)
        if seg is None:
            return False
        self._next_seg += 1
        self._segments.append(seg)
        self.stats["seals"] += 1
        self._mem = self._new_mem()
        return True

    def _seal(self) -> None:
        """Seal + trigger compaction: inline to fixpoint in foreground
        mode, a non-blocking schedule in background mode."""
        if self._seal_only():
            if self.background:
                self._executor.schedule(self)
            else:
                self.maybe_compact()

    def maybe_compact(self) -> int:
        """Run the compaction policy inline until stable; returns merge
        count. (Background mode schedules via the executor instead; this
        entry point stays inline so forced/major compactions and the
        foreground path behave exactly as before.)"""
        merges = 0
        with self._lock:
            while True:
                plan = self._plan_fn(self._segments, self.tier_fanout)
                if not plan:
                    return merges
                # merge one group per pass: indices into self._segments go
                # stale the moment _merge_group mutates the list, so replan
                self._merge_group(plan[0])
                merges += 1

    def compact(self, force: bool = False) -> int:
        """force=True merges *all* segments into one (major compaction);
        otherwise runs the compaction policy inline."""
        with self._lock:
            if not force:
                return self.maybe_compact()
            if len(self._segments) <= 1 and not (
                self._segments and self._covered_tombstones(self._segments)
            ):
                return 0
            self._merge_group(list(range(len(self._segments))))
            return 1

    # -- background protocol (called by CompactionExecutor, DESIGN.md §18) --
    def _compaction_specs(self) -> list[tuple[list[Segment], np.ndarray, int]]:
        """Capture merge jobs for the executor: victim Segment objects and
        the tombstone set *as of now*, plus a pre-allocated output id."""
        with self._lock:
            plan = self._plan_fn(self._segments, self.tier_fanout)
            tomb = np.array(sorted(self._tombstones), np.int64)
            specs = []
            for group in plan:
                victims = [self._segments[i] for i in group]
                specs.append((victims, tomb, self._next_seg))
                self._next_seg += 1
            return specs

    def _apply_merge(self, victims: list[Segment], merged: Segment | None, captured_tomb) -> str:
        """Atomic swap-in of a background merge. Validates every victim is
        still live *by identity* (else the job was superseded by an
        overlapping merge or a dead-segment drop), replaces victims with
        the output, purges only tombstones that were captured at merge
        start AND covered by the victims (later deletes keep masking the
        merged segment at read time — no resurrection), and publishes a
        fresh snapshot in the same critical section."""
        with self._lock:
            live_ids = {id(s) for s in self._segments}
            if any(id(v) not in live_ids for v in victims):
                return SUPERSEDED
            victim_ids = {id(v) for v in victims}
            survivors = [s for s in self._segments if id(s) not in victim_ids]
            if merged is not None:
                survivors.append(merged)
            self._segments = survivors
            captured = {int(t) for t in np.asarray(captured_tomb).ravel()}
            covered = {int(g) for v in victims for g in v.doc_map}
            self._tombstones -= captured & covered
            self.stats["merges"] += 1
            self._publish_locked()
            return MERGED if merged is not None else NOOP

    def _covered_tombstones(self, segs: list[Segment]) -> set[int]:
        covered = set()
        for seg in segs:
            covered.update(int(g) for g in seg.doc_map)
        return covered & self._tombstones

    def _merge_group(self, group: list[int]) -> None:
        group_set = set(group)
        victims = [self._segments[i] for i in group]
        tomb = np.array(sorted(self._tombstones), np.int64)
        merged = merge_segments(
            victims, tomb, self.lexicon, self.max_distance, segment_id=self._next_seg
        )
        self._next_seg += 1
        survivors = [s for i, s in enumerate(self._segments) if i not in group_set]
        if merged is not None:
            survivors.append(merged)
        self._segments = survivors
        # tombstones absorbed by this merge are purged: each global doc
        # lives in exactly one segment, so no other segment can hold them
        self._tombstones -= self._covered_tombstones(victims)
        self.stats["merges"] += 1

    # -- snapshot / refresh -------------------------------------------------
    def _publish_locked(self) -> SegmentedView:
        self._epoch += 1
        self._snapshot = SegmentedView(
            tuple(self._segments),
            np.array(sorted(self._tombstones), np.int64),
            self.lexicon,
            self.max_distance,
            self._next_doc,
            epoch=self._epoch,
        )
        return self._snapshot

    def refresh(self, wait: bool | None = None) -> SegmentedView:
        """Seal the memtable, drop fully-dead segments, and publish a new
        immutable snapshot.

        ``wait`` controls compaction (default: ``not background``):

        * foreground + ``wait=True`` — the original inline behaviour:
          compaction runs to fixpoint before the snapshot is published.
        * ``wait=False`` — seal-only: O(memtable) work, merges are merely
          *scheduled* in background mode (and skipped in foreground mode);
          the snapshot publishes immediately and later background swap-ins
          republish on their own.
        * background + ``wait=True`` — quiesce: schedule and wait for the
          executor to drain (re-scheduling until the plan is stable), then
          return the latest published snapshot.
        """
        if wait is None:
            wait = not self.background
        with self._lock:
            if self._mem.n_docs:
                self._seal_only()
            tomb = np.array(sorted(self._tombstones), np.int64)
            live = [
                seg
                for seg in self._segments
                if not bool(np.all(isin_sorted(tomb, seg.doc_map)))
            ]
            if len(live) != len(self._segments):
                dropped = [s for s in self._segments if s not in live]
                self._segments = live
                for seg in dropped:
                    self._tombstones -= {int(g) for g in seg.doc_map}
            if not self.background and wait:
                self.maybe_compact()
            snap = self._publish_locked()
        if self.background:
            if wait:
                # drain-and-replan until stable: a finished merge can push
                # its output tier over the policy threshold. Guarded by a
                # progress check so a persistently failing merge (fault
                # injection, OOM) degrades to "compaction behind" instead
                # of spinning this loop forever
                while True:
                    self._executor.wait_idle()
                    done0 = self._executor.stats["merged"] + self._executor.stats["noop"]
                    if not self._executor.schedule(self):
                        break
                    self._executor.wait_idle()
                    if self._executor.stats["merged"] + self._executor.stats["noop"] == done0:
                        break
                with self._lock:
                    snap = self._snapshot  # swap-ins republished under lock
            else:
                self._executor.schedule(self)
        return snap

    def snapshot(self) -> SegmentedView:
        """The last published immutable view (publishing one if none yet)."""
        snap = self._snapshot
        if snap is None:
            return self.refresh()
        return snap

    def live_view(self) -> SegmentedView:
        """A searcher view over sealed segments *plus* the unsealed
        memtable (frozen into an ephemeral overlay segment): adds and
        deletes are visible immediately, before any refresh. Memoized on
        (segments identity, memtable version, tombstones), so repeated
        calls between mutations are O(1); the freeze itself is
        O(memtable) — same build path as sealing, hence bit-identical
        reads (DESIGN.md §18)."""
        with self._lock:
            key = (
                tuple(id(s) for s in self._segments),
                self._mem.version,
                len(self._tombstones),
            )
            if self._live_memo is not None and self._live_memo[0] == key:
                return self._live_memo[1]
            overlay = self._mem.freeze()
            view = SegmentedView(
                tuple(self._segments),
                np.array(sorted(self._tombstones), np.int64),
                self.lexicon,
                self.max_distance,
                self._next_doc,
                epoch=self._epoch,
                mem_overlay=overlay,
            )
            self._live_memo = (key, view)
            return view

    def close(self) -> None:
        """Stop the owned background executor (injected executors are the
        caller's to close). Idempotent."""
        if self._owns_executor and self._executor is not None:
            self._executor.close()

    @property
    def n_segments(self) -> int:
        return len(self._segments)

    # -- ProximityIndex read API (delegates to the current snapshot) -------
    @property
    def doc_lengths(self):
        return self.snapshot().doc_lengths

    @property
    def ordinary(self):
        return self.snapshot().ordinary

    @property
    def wv(self):
        return self.snapshot().wv

    @property
    def fst(self):
        return self.snapshot().fst

    @property
    def nsw(self):
        return self.snapshot().nsw

    @property
    def has_additional(self) -> bool:
        return self.snapshot().has_additional

    def read_ordinary(self, lemma, meter=None):
        return self.snapshot().read_ordinary(lemma, meter)

    def read_wv(self, key, meter=None):
        return self.snapshot().read_wv(key, meter)

    def read_fst(self, key, meter=None):
        return self.snapshot().read_fst(key, meter)

    def live_doc_ids(self) -> np.ndarray:
        return self.snapshot().live_doc_ids()

    def size_report(self) -> dict:
        return self.snapshot().size_report()

    # -- persistence --------------------------------------------------------
    def save(self, path: str | Path) -> None:
        """Crash-safe layout (DESIGN.md §18): every segment directory is
        fully written *before* the manifest is swapped in atomically
        (tmp + ``os.replace``). A crash mid-save leaves either the old
        manifest (new segment dirs are unreferenced orphans, ignored by
        ``load``) or the new one (whose segments are all complete) —
        never a manifest pointing at a partial segment. Holding the lock
        for the whole save keeps background swap-ins from changing the
        segment set under the writer."""
        from repro.index.persist import write_json_atomic

        path = Path(path)
        path.mkdir(parents=True, exist_ok=True)
        with self._lock:
            if self._mem.n_docs:  # durability: everything buffered gets sealed
                self._seal_only()
            self.lexicon.save(path / "lexicon.json")
            manifest = {
                "format_version": 1,
                "max_distance": self.max_distance,
                "flags": self._flags,
                "memtable_docs": self.memtable_docs,
                "tier_fanout": self.tier_fanout,
                "next_doc": self._next_doc,
                "next_seg": self._next_seg,
                "tombstones": sorted(self._tombstones),
                "segments": [f"seg_{seg.segment_id:06d}" for seg in self._segments],
            }
            for seg in self._segments:
                seg.save(path / f"seg_{seg.segment_id:06d}")
            write_json_atomic(path / "manifest.json", manifest)

    @classmethod
    def load(cls, path: str | Path) -> "SegmentedIndex":
        path = Path(path)
        manifest = json.loads((path / "manifest.json").read_text())
        lexicon = Lexicon.load(path / "lexicon.json")
        out = cls(
            lexicon,
            max_distance=manifest["max_distance"],
            memtable_docs=manifest["memtable_docs"],
            tier_fanout=manifest["tier_fanout"],
            **manifest["flags"],
        )
        out._segments = [Segment.load(path / name, lexicon) for name in manifest["segments"]]
        out._tombstones = set(manifest["tombstones"])
        out._next_doc = manifest["next_doc"]
        out._next_seg = manifest["next_seg"]
        return out
