"""Segmented incremental indexing (LSM-style) for the paper's composite
index: memtable absorption, immutable sealed segments with on-disk
persistence, tombstone deletes, size-tiered background compaction, and a
``ProximityIndex``-compatible merged read facade for live-refresh serving.
"""

from repro.index.background import CompactionExecutor, CompactionJob
from repro.index.compaction import leveled_plan, merge_segments, size_tiered_plan
from repro.index.persist import load_index, save_index, write_json_atomic
from repro.index.segment import MemSegment, Segment
from repro.index.segmented import SegmentedIndex, SegmentedView, snapshot_token

__all__ = [
    "CompactionExecutor",
    "CompactionJob",
    "MemSegment",
    "Segment",
    "SegmentedIndex",
    "SegmentedView",
    "leveled_plan",
    "merge_segments",
    "size_tiered_plan",
    "save_index",
    "load_index",
    "snapshot_token",
    "write_json_atomic",
]
