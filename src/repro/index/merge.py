"""K-way merge primitives over sealed segments.

Shared by the live read path (``segmented.SegmentedView`` merges postings
on the fly) and physical compaction (``compaction.merge_segments`` writes
the merged postings into a new sealed segment).

Correctness hinges on two invariants:

* every global document lives in exactly one segment, so after mapping
  local -> global doc ids the per-segment posting lists cover disjoint doc
  sets and a stable sort by doc id is a true k-way merge that preserves
  each document's intra-doc posting order (the fresh-build order);
* ``doc_map`` is strictly increasing, so local doc order == global doc
  order within a segment and NSW row provenance stays monotone.
"""

from __future__ import annotations

import numpy as np

_N_COLUMNS = {"ordinary": 2, "wv": 3, "fst": 4}


def isin_sorted(sorted_arr: np.ndarray, values: np.ndarray) -> np.ndarray:
    """Vectorized membership of `values` in sorted `sorted_arr`."""
    if sorted_arr.size == 0:
        return np.zeros(values.shape, bool)
    i = np.searchsorted(sorted_arr, values)
    ic = np.clip(i, 0, sorted_arr.size - 1)
    return (i < sorted_arr.size) & (sorted_arr[ic] == values)


def merged_key_read(
    segments,
    kind: str,
    key,
    tomb_sorted: np.ndarray,
    remap=None,
    count_bytes: bool = True,
):
    """Merge one key's posting list across segments.

    Maps local doc ids to global via each segment's ``doc_map``, drops
    postings of tombstoned docs, optionally remaps global ids through
    ``remap`` (a monotone vectorized callable, used by compaction), and
    k-way merges by doc id.

    Returns ``(cols, seg_ids, old_rows, nbytes)`` where ``cols`` are the
    merged posting columns (doc col first), ``seg_ids``/``old_rows`` give
    each merged row's provenance (segment ordinal, pre-merge row ordinal
    within that segment's list — the alignment NSW merging needs), and
    ``nbytes`` is the total encoded bytes a disk read would have fetched.
    """
    n_columns = _N_COLUMNS[kind]
    cols_parts, seg_parts, row_parts = [], [], []
    nbytes = 0
    for si, seg in enumerate(segments):
        store = getattr(seg.index, kind)
        if store is None or key not in store:
            continue
        if count_bytes:  # the ByteMeter metric; forces lazy encoding, so
            nbytes += len(store._blob(key))  # physical merges skip it
        cols = store.columns(key)
        if cols[0].size == 0:
            continue
        gdoc = seg.doc_map[cols[0]]
        keep = ~isin_sorted(tomb_sorted, gdoc)
        if not keep.any():
            continue
        kept_rows = np.nonzero(keep)[0].astype(np.int64)
        doc_out = gdoc[keep]
        if remap is not None:
            doc_out = remap(doc_out)
        cols_parts.append([doc_out.astype(np.int64)] + [c[keep] for c in cols[1:]])
        seg_parts.append(np.full(kept_rows.size, si, np.int32))
        row_parts.append(kept_rows)
    if not cols_parts:
        empty = np.zeros(0, np.int64)
        return (
            [np.zeros(0, np.int64) for _ in range(n_columns)],
            np.zeros(0, np.int32),
            empty,
            nbytes,
        )
    cols = [np.concatenate([p[ci] for p in cols_parts]) for ci in range(n_columns)]
    seg_ids = np.concatenate(seg_parts)
    old_rows = np.concatenate(row_parts)
    if len(cols_parts) > 1:
        # stable: intra-doc order (== fresh-build order) is preserved, and
        # docs are disjoint across segments, so doc-only keys suffice.
        order = np.argsort(cols[0], kind="stable")
        cols = [c[order] for c in cols]
        seg_ids = seg_ids[order]
        old_rows = old_rows[order]
    return cols, seg_ids, old_rows, nbytes


def merged_nsw_read(
    segments,
    lemma: int,
    seg_ids: np.ndarray,
    old_rows: np.ndarray,
    count_bytes: bool = True,
):
    """Merge one lemma's NSW record stream across segments, renumbering
    record rows to align with a prior ``merged_key_read(..., "ordinary",
    lemma, ...)`` whose provenance is ``(seg_ids, old_rows)``.

    Records attached to tombstone-dropped postings are dropped with them.
    Returns ``(rows, fls, offs, nbytes)`` sorted by merged row.
    """
    rows_l, fls_l, offs_l = [], [], []
    nbytes = 0
    for si, seg in enumerate(segments):
        nsw = seg.index.nsw
        if nsw is None or lemma not in nsw.lemma_row_start:
            continue
        if count_bytes:
            nbytes += len(nsw.blob(lemma))
        r, f, o = nsw.read(lemma) if count_bytes else nsw.records(lemma)
        if r.size == 0:
            continue
        sel = np.nonzero(seg_ids == si)[0]  # merged rows owned by this segment
        if sel.size == 0:
            continue
        old = old_rows[sel]  # ascending (stable doc merge keeps local order)
        pos = np.searchsorted(old, r)
        posc = np.clip(pos, 0, old.size - 1)
        ok = (pos < old.size) & (old[posc] == r)
        if not ok.any():
            continue
        rows_l.append(sel[posc[ok]])
        fls_l.append(f[ok])
        offs_l.append(o[ok])
    if not rows_l:
        return (np.zeros(0, np.int64),) * 3 + (nbytes,)
    rows = np.concatenate(rows_l)
    fls = np.concatenate(fls_l)
    offs = np.concatenate(offs_l)
    order = np.argsort(rows, kind="stable")  # a row maps to one segment, so
    return rows[order], fls[order], offs[order], nbytes  # in-row order survives
