"""The paper's own engine as a dry-runnable architecture (family 'search').

Shapes mirror the serving regimes of the recsys set: a latency-bound
online batch, a bulk offline batch, and a heavy cell with long posting
lists (frequent stop-lemma triples) — the paper's worst case."""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchSpec, ShapeSpec


@dataclass(frozen=True)
class SearchConfig:
    name: str = "proximity-search"
    max_distance: int = 5
    top_k: int = 16
    n_keys: int = 2  # (f,s,t) keys per query (queries of 3-5 words)


SEARCH_SHAPES = {
    "qt1_serve": ShapeSpec("qt1_serve", "search", {"batch": 4096, "postings": 65_536}),
    "qt1_p99": ShapeSpec("qt1_p99", "search", {"batch": 512, "postings": 65_536}),
    "qt1_bulk": ShapeSpec("qt1_bulk", "search", {"batch": 32_768, "postings": 65_536}),
    "qt1_heavy": ShapeSpec("qt1_heavy", "search", {"batch": 256, "postings": 1_048_576}),
}

_SMOKE = {
    "qt1_serve": ShapeSpec("qt1_serve", "search", {"batch": 8, "postings": 256}),
    "qt1_heavy": ShapeSpec("qt1_heavy", "search", {"batch": 2, "postings": 1024}),
}


def _reduce(spec: ArchSpec) -> ArchSpec:
    return ArchSpec(spec.arch_id + "-smoke", "search", spec.model_cfg, dict(_SMOKE), {}, None, spec.source)


SEARCH_ARCH = ArchSpec(
    "proximity-search", "search", SearchConfig(), dict(SEARCH_SHAPES),
    reduce_fn=_reduce, source="this paper (Veretennikov 2020)",
)
