"""The five assigned LM-family architectures (exact published configs)."""

from __future__ import annotations

from dataclasses import replace

from repro.configs.base import ArchSpec, LM_FULL_ATTENTION_SKIP, LM_SHAPES, ShapeSpec
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig


def _reduce_lm(spec: ArchSpec) -> ArchSpec:
    cfg = spec.model_cfg
    moe = cfg.moe
    if moe is not None:
        moe = MoEConfig(n_experts=4, top_k=min(moe.top_k, 2), d_ff_expert=64,
                        capacity_factor=moe.capacity_factor)
    small = replace(
        cfg,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=max(1, min(cfg.n_kv, 2)) if cfg.n_kv < cfg.n_heads else 4,
        d_head=16,
        d_ff=128,
        vocab=512,
        moe=moe,
        remat=False,
    )
    shapes = {
        "train_4k": ShapeSpec("train_4k", "train", {"seq_len": 64, "global_batch": 4}),
        "prefill_32k": ShapeSpec("prefill_32k", "prefill", {"seq_len": 96, "global_batch": 2}),
        "decode_32k": ShapeSpec("decode_32k", "decode", {"seq_len": 96, "global_batch": 4}),
    }
    return ArchSpec(spec.arch_id + "-smoke", "lm", small, shapes, dict(spec.skips), None, spec.source)


def _lm(arch_id: str, cfg: TransformerConfig, source: str) -> ArchSpec:
    shapes = {k: v for k, v in LM_SHAPES.items() if k != "long_500k"}
    return ArchSpec(
        arch_id=arch_id,
        family="lm",
        model_cfg=cfg,
        shapes=shapes,
        skips={"long_500k": LM_FULL_ATTENTION_SKIP},
        reduce_fn=_reduce_lm,
        source=source,
    )


STABLELM_1_6B = _lm(
    "stablelm-1.6b",
    TransformerConfig(
        name="stablelm-1.6b",
        n_layers=24, d_model=2048, n_heads=32, n_kv=32, d_ff=5632, vocab=100352,
        qkv_bias=False, norm="layernorm", rotary_pct=0.25, tie_embeddings=False,
    ),
    "hf:stabilityai/stablelm-2-1_6b",
)

CODEQWEN_7B = _lm(
    "codeqwen1.5-7b",
    TransformerConfig(
        name="codeqwen1.5-7b",
        n_layers=32, d_model=4096, n_heads=32, n_kv=32, d_ff=13440, vocab=92416,
        qkv_bias=True, norm="rmsnorm", rotary_pct=1.0,
    ),
    "hf:Qwen/CodeQwen1.5-7B",
)

QWEN_32B = _lm(
    "qwen1.5-32b",
    TransformerConfig(
        name="qwen1.5-32b",
        n_layers=64, d_model=5120, n_heads=40, n_kv=40, d_ff=27392, vocab=152064,
        qkv_bias=True, norm="rmsnorm", rotary_pct=1.0,
    ),
    "hf:Qwen/Qwen1.5-32B (QKV bias per Qwen1.5 family)",
)

PHI35_MOE = _lm(
    "phi3.5-moe-42b-a6.6b",
    TransformerConfig(
        name="phi3.5-moe-42b-a6.6b",
        n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=6400, vocab=32064,
        qkv_bias=False, norm="layernorm", rotary_pct=1.0,
        moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=6400),
    ),
    "hf:microsoft/Phi-3.5-MoE-instruct",
)

GRANITE_MOE = _lm(
    "granite-moe-1b-a400m",
    TransformerConfig(
        name="granite-moe-1b-a400m",
        n_layers=24, d_model=1024, n_heads=16, n_kv=8, d_ff=512, vocab=49155,
        qkv_bias=False, norm="rmsnorm", rotary_pct=1.0, tie_embeddings=True,
        moe=MoEConfig(n_experts=32, top_k=8, d_ff_expert=512),
    ),
    "hf:ibm-granite/granite-3.0-1b-a400m-base",
)

LM_ARCHS = [STABLELM_1_6B, CODEQWEN_7B, QWEN_32B, PHI35_MOE, GRANITE_MOE]
