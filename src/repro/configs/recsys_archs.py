"""The four assigned recsys architectures + their shared shape set."""

from __future__ import annotations

from dataclasses import replace

from repro.configs.base import ArchSpec, ShapeSpec
from repro.models.recsys import DINConfig, SeqRecConfig, TwoTowerConfig

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", {"batch": 65_536}),
    "serve_p99": ShapeSpec("serve_p99", "serve", {"batch": 512}),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", {"batch": 262_144}),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1_048_576}),
}

_SMOKE_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", {"batch": 32}),
    "serve_p99": ShapeSpec("serve_p99", "serve", {"batch": 8}),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", {"batch": 64}),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 256}),
}

N_NEG = 255  # sampled-softmax negatives for sequential recommenders


def _reduce_seqrec(spec: ArchSpec) -> ArchSpec:
    cfg = replace(spec.model_cfg, n_items=1024, embed_dim=16, n_blocks=1, n_heads=2, seq_len=12)
    return ArchSpec(spec.arch_id + "-smoke", "recsys", cfg, dict(_SMOKE_SHAPES), {}, None, spec.source)


def _reduce_din(spec: ArchSpec) -> ArchSpec:
    cfg = replace(spec.model_cfg, n_items=1024, n_cates=64, embed_dim=8, seq_len=10)
    return ArchSpec(spec.arch_id + "-smoke", "recsys", cfg, dict(_SMOKE_SHAPES), {}, None, spec.source)


def _reduce_tt(spec: ArchSpec) -> ArchSpec:
    cfg = replace(spec.model_cfg, n_items=1024, n_cates=64, embed_dim=16, tower=(32, 24, 16), hist_len=8)
    return ArchSpec(spec.arch_id + "-smoke", "recsys", cfg, dict(_SMOKE_SHAPES), {}, None, spec.source)


BERT4REC = ArchSpec(
    "bert4rec", "recsys",
    SeqRecConfig(name="bert4rec", n_items=1_048_576, embed_dim=64, n_blocks=2,
                 n_heads=2, seq_len=200, causal=False),
    dict(RECSYS_SHAPES), reduce_fn=_reduce_seqrec,
    source="arXiv:1904.06690 (BERT4Rec: d=64, 2 blocks, 2 heads, seq 200)",
)

SASREC = ArchSpec(
    "sasrec", "recsys",
    SeqRecConfig(name="sasrec", n_items=1_048_576, embed_dim=50, n_blocks=2,
                 n_heads=1, seq_len=50, causal=True),
    dict(RECSYS_SHAPES), reduce_fn=_reduce_seqrec,
    source="arXiv:1808.09781 (SASRec: d=50, 2 blocks, 1 head, seq 50)",
)

DIN = ArchSpec(
    "din", "recsys",
    DINConfig(name="din", n_items=10_000_000, n_cates=100_000, embed_dim=18,
              seq_len=100, attn_mlp=(80, 40), mlp=(200, 80)),
    dict(RECSYS_SHAPES), reduce_fn=_reduce_din,
    source="arXiv:1706.06978 (DIN: d=18, attn MLP 80-40, MLP 200-80, seq 100)",
)

TWO_TOWER = ArchSpec(
    "two-tower-retrieval", "recsys",
    TwoTowerConfig(name="two-tower-retrieval", n_items=10_000_000, n_cates=100_000,
                   embed_dim=256, tower=(1024, 512, 256), hist_len=50),
    dict(RECSYS_SHAPES), reduce_fn=_reduce_tt,
    source="RecSys'19 (YouTube two-tower, sampled softmax + logQ)",
)

RECSYS_ARCHS = [BERT4REC, DIN, TWO_TOWER, SASREC]
