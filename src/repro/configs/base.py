"""Architecture/shape registry for the dry-run and smoke tests.

Every assigned architecture gets an ArchSpec with:
  * model_cfg — the exact published configuration;
  * shapes — its assigned input-shape cells (kind: train/prefill/decode/
    serve/retrieval), each lowered by launch/steps.py;
  * skips — cells that are inapplicable (with the reason recorded, e.g.
    long_500k on pure full-attention LMs, per the brief);
  * reduced() — a structurally identical small config for CPU smoke tests.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode | serve | retrieval
    dims: dict


@dataclass
class ArchSpec:
    arch_id: str
    family: str  # lm | gnn | recsys | search
    model_cfg: Any
    shapes: dict
    skips: dict = field(default_factory=dict)  # shape name -> reason
    reduce_fn: Callable | None = None
    source: str = ""

    def reduced(self):
        assert self.reduce_fn is not None
        return self.reduce_fn(self)


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    "decode_32k": ShapeSpec("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    "long_500k": ShapeSpec("long_500k", "decode", {"seq_len": 524288, "global_batch": 1}),
}

LM_FULL_ATTENTION_SKIP = (
    "long_500k requires sub-quadratic attention; this arch is pure full "
    "(GQA) attention — skipped per brief, see DESIGN.md §6"
)
