"""EGNN architecture + its four assigned graph shapes."""

from __future__ import annotations

from repro.configs.base import ArchSpec, ShapeSpec
from repro.models.gnn import EGNNConfig

# sampled-subgraph sizes for minibatch_lg (Reddit: 232,965 nodes,
# 114,615,892 edges, d=602; seeds=1024, fanout 15-10):
#   nodes <= 1024 * (1 + 15 + 150) = 170,  -> pad to 172032
#   edges <= 1024 * (15 + 150)      = 168,960 -> pad to 172032
_MINIBATCH_NODES = 172_032
_MINIBATCH_EDGES = 172_032

EGNN_SHAPES = {
    "full_graph_sm": ShapeSpec(
        "full_graph_sm", "train",
        {"n_nodes": 2_708, "n_edges": 10_556, "d_feat": 1_433, "batched": False},
    ),
    "minibatch_lg": ShapeSpec(
        "minibatch_lg", "train",
        {"n_nodes": _MINIBATCH_NODES, "n_edges": _MINIBATCH_EDGES, "d_feat": 602,
         "batched": False, "sampled": True, "seeds": 1024, "fanout": (15, 10)},
    ),
    "ogb_products": ShapeSpec(
        "ogb_products", "train",
        {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100, "batched": False},
    ),
    "molecule": ShapeSpec(
        "molecule", "train",
        {"n_nodes": 30, "n_edges": 64, "d_feat": 16, "batch": 128, "batched": True},
    ),
}


def _reduce_egnn(spec: ArchSpec) -> ArchSpec:
    shapes = {
        "full_graph_sm": ShapeSpec("full_graph_sm", "train",
                                   {"n_nodes": 40, "n_edges": 120, "d_feat": 24, "batched": False}),
        "minibatch_lg": ShapeSpec("minibatch_lg", "train",
                                  {"n_nodes": 64, "n_edges": 128, "d_feat": 24, "batched": False,
                                   "sampled": True, "seeds": 4, "fanout": (3, 2)}),
        "molecule": ShapeSpec("molecule", "train",
                              {"n_nodes": 8, "n_edges": 16, "d_feat": 8, "batch": 4, "batched": True}),
    }
    cfg = EGNNConfig(name="egnn-smoke", n_layers=2, d_hidden=16, d_feat=24)
    return ArchSpec(spec.arch_id + "-smoke", "gnn", cfg, shapes, {}, None, spec.source)


EGNN_ARCH = ArchSpec(
    arch_id="egnn",
    family="gnn",
    model_cfg=EGNNConfig(name="egnn", n_layers=4, d_hidden=64, d_feat=1433),
    shapes=EGNN_SHAPES,
    reduce_fn=_reduce_egnn,
    source="arXiv:2102.09844 (EGNN, E(n)-equivariant)",
)

GNN_ARCHS = [EGNN_ARCH]
