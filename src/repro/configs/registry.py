"""Central --arch registry."""

from __future__ import annotations

from repro.configs.base import ArchSpec
from repro.configs.gnn_archs import GNN_ARCHS
from repro.configs.lm_archs import LM_ARCHS
from repro.configs.proximity_search import SEARCH_ARCH
from repro.configs.recsys_archs import RECSYS_ARCHS

ALL_ARCHS: list[ArchSpec] = LM_ARCHS + GNN_ARCHS + RECSYS_ARCHS + [SEARCH_ARCH]

ARCHS: dict[str, ArchSpec] = {a.arch_id: a for a in ALL_ARCHS}

ASSIGNED_ARCH_IDS = [a.arch_id for a in LM_ARCHS + GNN_ARCHS + RECSYS_ARCHS]


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown --arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]
