"""Serving tier: deadline-aware, batched, bucketed proximity-search
serving with a response-time guarantee (the paper's product), plus a
continuous-batching LM decode loop.

The tier is three explicit layers (DESIGN.md §14):

* :mod:`repro.serving.planner` — pure per-query routing:
  ``plan(request, snapshot, config) -> QueryPlan`` captures query type,
  route, L-bucket, payload, estimated step cost and a machine-readable
  ``fallback_reason`` for every scalar-route shape of the DESIGN.md §13
  dispatch matrix;
* :mod:`repro.serving.executors` — ``CompiledExecutor`` (serve-step
  factories + the per-(kind, B, L) executable table, shared across
  paths by dispatch-aware batching) and ``ScalarExecutor`` behind one
  ``Executor`` protocol;
* :mod:`repro.serving.service` — the :class:`SearchService` facade:
  one :class:`ServeConfig`, ``submit(lemma_ids, deadline_s=...) ->
  SearchTicket``, ``drain()`` resolving tickets with per-response
  ``plan``/``deadline_met``/``queue_wait_s``, and ``explain()``.

Public API
----------

* :class:`SearchService` / :class:`ServeConfig` / :class:`SearchTicket`
  / :class:`SearchResponse` — the serving facade.
* :class:`QueryPlan` — the inspectable routing decision.
* :class:`AdmissionController` / :class:`AdmissionVerdict` — the §17
  deadline control loop consulted by ``submit()`` on an
  ``admission=True`` engine (fast-reject, degrade, shed).
* :class:`LoadReport` / :func:`run_open_loop` / :func:`run_closed_loop`
  / :func:`poisson_arrivals` / :func:`bursty_arrivals` — the open-loop
  load harness that exercises the control loop at a fixed offered rate.
* :class:`SearchServingEngine` — **deprecated** monolithic API, kept as
  a thin shim over ``SearchService``.
* :class:`PackedPostingCache` — LRU memo of the padded per-key device
  rows (and their block-delta16 compressed twins), invalidated by
  snapshot identity (DESIGN.md §11).
* :class:`LMContinuousBatcher` — slot-based continuous batching for LM
  decode (vLLM-style admission).

``python -m pydoc repro.serving.service`` / ``repro.serving.planner`` /
``repro.serving.executors`` render the full reference.
"""

from repro.serving.admission import (  # noqa: F401
    AdmissionController,
    AdmissionVerdict,
)
from repro.serving.engine import SearchServingEngine  # noqa: F401 (deprecated)
from repro.serving.lm_batcher import LMContinuousBatcher  # noqa: F401
from repro.serving.load import (  # noqa: F401
    LoadReport,
    bursty_arrivals,
    poisson_arrivals,
    run_closed_loop,
    run_open_loop,
    warm_service,
)
from repro.serving.pack_cache import PackedPostingCache  # noqa: F401
from repro.serving.planner import QueryPlan  # noqa: F401
from repro.serving.service import (  # noqa: F401
    SearchRequest,
    SearchResponse,
    SearchService,
    SearchTicket,
    ServeConfig,
)

__all__ = [
    "AdmissionController",
    "AdmissionVerdict",
    "LMContinuousBatcher",
    "LoadReport",
    "PackedPostingCache",
    "QueryPlan",
    "SearchRequest",
    "SearchResponse",
    "SearchService",
    "SearchServingEngine",
    "SearchTicket",
    "ServeConfig",
    "bursty_arrivals",
    "poisson_arrivals",
    "run_closed_loop",
    "run_open_loop",
    "warm_service",
]
