"""Serving tier: batched, bucketed proximity-search serving with a
response-time guarantee (the paper's product), plus a continuous-batching
LM decode loop.

Public API
----------

* :class:`SearchServingEngine` — submit/drain/refresh serving over a
  static ``ProximityIndex`` or a live ``repro.index.SegmentedIndex``.
  One drain dispatches every query type of the paper (QT1-QT5) to a
  compiled, mesh-sharded serve step (DESIGN.md §12-§13); shapes the
  static steps cannot express fall back to the scalar reference engine,
  so results are always exact.
* :class:`PackedPostingCache` — LRU memo of the padded per-key device
  rows (and their block-delta16 compressed twins) that packing a batch
  assembles from, invalidated by snapshot identity (DESIGN.md §11).
* :class:`LMContinuousBatcher` — slot-based continuous batching for LM
  decode (vLLM-style admission).

``python -m pydoc repro.serving.engine`` / ``repro.serving.pack_cache``
render the full reference.
"""

from repro.serving.engine import (  # noqa: F401
    LMContinuousBatcher,
    SearchRequest,
    SearchResponse,
    SearchServingEngine,
)
from repro.serving.pack_cache import PackedPostingCache  # noqa: F401

__all__ = [
    "LMContinuousBatcher",
    "PackedPostingCache",
    "SearchRequest",
    "SearchResponse",
    "SearchServingEngine",
]
