"""Query planner: the pure routing layer of the serving tier
(DESIGN.md §14).

``plan(request, snapshot, config)`` turns one request (a lemma-id list)
into a :class:`QueryPlan` — the machine-readable answer to "which
executable will this query hit, and why": query type, route, L-bucket,
payload format, estimated compiled-step cost, and a ``fallback_reason``
for every scalar-route shape of the DESIGN.md §13 dispatch matrix. The
function is pure (no engine state, no device work, no caches), so
``SearchService.explain()`` can answer routing questions without
executing, and the executed path can be asserted against the
pre-computed plan (tests/test_planner.py does exactly that, row by
row).

The paper's companion work (arXiv:1811.07361, arXiv:2101.03327) frames
index/parameter selection as an explicit per-query planning decision;
this module is that decision as a first-class object.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from repro.core.lexicon import UNKNOWN_FL
from repro.core.query import (
    QueryType,
    classify,
    qt1_plan,
    qt2_plan,
    qt34_plan,
    qt5_plan,
    select_wv_keys,
)

# -- routes ----------------------------------------------------------------
ROUTE_QT1 = "qt1"
ROUTE_QT2 = "qt2"
ROUTE_QT34 = "qt34"
ROUTE_QT5 = "qt5"
ROUTE_SCALAR = "scalar"  # the ProximitySearchEngine correctness backstop
ROUTE_EMPTY = "empty"    # answered inline with zero results

COMPILED_ROUTES = (ROUTE_QT1, ROUTE_QT2, ROUTE_QT34, ROUTE_QT5)

# -- payloads --------------------------------------------------------------
PAYLOAD_RAW = "raw"
PAYLOAD_DELTA16 = "delta16"
PAYLOAD_OFFSETS = "offsets"

# -- machine-readable fallback reasons, one per scalar-route shape of the
# DESIGN.md §13 dispatch matrix (column "CPU-fallback conditions")
FB_UNKNOWN_LEMMA = "unknown_lemma"            # any type: contains UNKNOWN_FL
FB_NO_FST_INDEX = "no_fst_index"              # QT1: no (f,s,t) store
FB_QUERY_TOO_SHORT = "query_too_short"        # QT1: len < 3 (CPU degenerate)
FB_QUERY_TOO_LONG = "query_too_long"          # QT1: len > MaxDistance (split)
FB_TOO_MANY_FST_KEYS = "too_many_fst_keys"    # QT1: > k_fst keys
FB_NO_WV_INDEX = "no_wv_index"                # QT2: no (w,v) store
FB_SHARDED_QT2 = "sharded_qt2_window"         # QT2: doc_shards > 1
FB_TOO_MANY_WV_KEYS = "too_many_wv_keys"      # QT2: > k_wv keys
FB_NO_ORDINARY_INDEX = "no_ordinary_index"    # QT3/QT4/QT5: no ordinary store
FB_TOO_MANY_ORD_CONSTRAINTS = "too_many_ord_constraints"  # QT3/QT4: > k_ord
FB_MULTIPLICITY_OVER_R_MAX = "multiplicity_exceeds_r_max"  # QT3/4/5: r > r_max
FB_NO_NSW_INDEX = "no_nsw_index"              # QT5: no NSW store
FB_DEGENERATE_QT5 = "degenerate_qt5_plan"     # QT5: no stop or no non-stop
FB_TOO_MANY_NS_CONSTRAINTS = "too_many_nonstop_constraints"  # QT5: > k_ns
FB_TOO_MANY_STOP_CONSTRAINTS = "too_many_stop_constraints"   # QT5: > k_st
FB_STOP_MULTIPLICITY_OVERFLOW = "stop_multiplicity_overflow"  # QT5: r > 254
FB_ROW_EXCEEDS_LADDER = "row_exceeds_ladder"  # any type: row > largest bucket
FB_LIVE_MEMTABLE = "live_memtable_key"        # any type: a query lemma lives in
# the snapshot's unsealed memtable overlay (DESIGN.md §18) — served scalar so
# the compiled ladder never packs against an ephemeral pre-refresh view


@dataclass(frozen=True)
class QueryPlan:
    """The per-query routing decision, inspectable before execution.

    * ``qtype`` — QT1-QT5 (None for empty / unknown-lemma requests);
    * ``route`` — ``qt1``/``qt2``/``qt34``/``qt5``/``scalar``/``empty``:
      the dispatch-matrix row (DESIGN.md §13) the request falls on;
    * ``step_family`` — the compiled-step family that will execute it;
      differs from ``route`` exactly when dispatch-aware batching rides
      a ``qt34`` request on the ``qt5`` executable (DESIGN.md §14); None
      off-device;
    * ``bucket`` — the L-bucket the padded posting rows hit (None
      off-device);
    * ``payload`` — ``raw``/``delta16``/``offsets``; the *predicted*
      device format (a delta16 prediction can still downgrade to
      offsets at pack time when a key's in-block span overflows uint16
      — ``SearchResponse.plan`` carries the executed format);
    * ``est_step_cost`` — padded posting slots the compiled step scans
      (streams x bucket x doc_shards): the shape-bound work behind the
      response-time guarantee. None for scalar/empty routes — the
      scalar engine has no compiled-shape bound, which is the point;
    * ``fallback_reason`` — machine-readable, set iff route is
      ``scalar``;
    * ``selection`` — the memoized key selection the packers consume
      ((f,s,t) keys / ordered (w,v) keys / the qt34/qt5 plan tuple);
    * ``measured`` — never set by ``plan()`` (the function stays pure);
      ``SearchService.explain(q, costs=True)`` attaches the §15
      measured-cost record here (per-B run-time percentiles, compile
      time, XLA cost summary, est-vs-measured ratio) on a *copy* of the
      memoized plan;
    * ``degraded`` — set by :func:`degrade` when admission control
      reroutes an over-budget plan to a smaller bucket (DESIGN.md §17):
      the packers truncate each key's posting rows to the smaller
      padded length, so the response searches a bounded posting prefix
      (results ⊆ the full route's candidate set) within the budget."""

    qtype: QueryType | None
    route: str
    step_family: str | None = None
    bucket: int | None = None
    payload: str | None = None
    est_step_cost: int | None = None
    fallback_reason: str | None = None
    selection: object = None
    measured: dict | None = None
    degraded: bool = False

    @property
    def is_compiled(self) -> bool:
        return self.route in COMPILED_ROUTES


def ladder_bucket(longest: int, config) -> int | None:
    """Smallest L-bucket holding a posting row of ``longest`` entries —
    sized for worst-case doc skew under ``doc_shards`` range
    partitioning (each shard segment holds only L / doc_shards slots,
    and a doc-skewed key can land all its postings in one segment).
    None when even the largest bucket cannot hold the row: the packers
    would silently truncate it, so the planner must route to the scalar
    engine instead."""
    longest *= config.doc_shards
    for cand in config.buckets:
        if longest <= cand:
            return cand
    return None


def delta16_aligned(bucket: int, config) -> bool:
    """Whether an L-bucket can take the block-delta16 format at all:
    every 64-posting block must align with the bucket/shard layout.
    The single source of the alignment rule — the planner's payload
    prediction and the executor's cache-less compress path both call
    it, so they cannot drift."""
    return bucket % (64 * config.doc_shards) == 0


def _payload(bucket: int, config, step_family: str | None = None,
             costs=None) -> str:
    """Predicted device payload for one compiled group: raw when the
    engine is uncompressed; delta16 when the bucket is block-aligned
    (the headline 4 B/posting format); offsets otherwise. Per-key
    uint16 span overflow can still downgrade a delta16 prediction at
    pack time.

    With a :class:`repro.serving.costs.PayloadCostModel` (and the step
    family it is keyed by), the static rule only names the compressed
    *candidate* — the model arbitrates it against raw per
    (step_family, bucket) from measured warm batch time (DESIGN.md
    §16), so a route where compression loses (QT3's measured
    regression) serves raw while QT4 keeps its compressed win."""
    if not config.compressed:
        return PAYLOAD_RAW
    static = (PAYLOAD_DELTA16 if delta16_aligned(bucket, config)
              else PAYLOAD_OFFSETS)
    if costs is not None and step_family is not None:
        return costs.choose(step_family, bucket, static)
    return static


def _streams(step_family: str, config) -> int:
    """Static posting streams the compiled step scans per query."""
    if step_family == ROUTE_QT1:
        return config.k_fst
    if step_family == ROUTE_QT2:
        return config.k_wv
    if step_family == ROUTE_QT34:
        return 1 + config.k_ord
    return 1 + config.k_ns + config.k_st  # qt5: anchor + non-stop + NSW


def _compiled(qtype, route, bucket, config, selection, step_family=None,
              costs=None) -> QueryPlan:
    step_family = step_family or route
    return QueryPlan(
        qtype=qtype, route=route, step_family=step_family, bucket=bucket,
        payload=_payload(bucket, config, step_family, costs),
        est_step_cost=_streams(step_family, config) * bucket * config.doc_shards,
        selection=selection,
    )


def _scalar(qtype, reason: str) -> QueryPlan:
    return QueryPlan(qtype=qtype, route=ROUTE_SCALAR, fallback_reason=reason)


def degrade(plan: QueryPlan, bucket: int, config, costs=None) -> QueryPlan:
    """An over-budget compiled plan rerouted to a cheaper bucket — the
    admission controller's degraded-mode path (DESIGN.md §17).

    The key selection is unchanged; only the L-bucket shrinks. The row
    packers truncate each key's postings to the smaller padded length
    (``_fill_partitioned`` keeps the first ``L/doc_shards`` per shard
    segment, i.e. the lowest doc ranges), so the degraded step scans a
    *bounded posting prefix*: its candidate matches are a subset of the
    full route's, at ``bucket / plan.bucket`` of the step cost. The
    response is marked ``status="degraded"`` so clients know the
    guarantee was bought with completeness."""
    if not plan.is_compiled or bucket >= plan.bucket:
        raise ValueError(f"cannot degrade {plan.route}@{plan.bucket} "
                         f"to bucket {bucket}")
    return dataclasses.replace(
        plan,
        bucket=bucket,
        payload=_payload(bucket, config, plan.step_family, costs),
        est_step_cost=(_streams(plan.step_family, config) * bucket
                       * config.doc_shards),
        degraded=True,
    )


def plan(request, snapshot, config, costs=None) -> QueryPlan:
    """Pure routing: one request -> :class:`QueryPlan`, reproducing the
    DESIGN.md §13 dispatch matrix row by row (conditions checked in
    matrix order, so ``fallback_reason`` names the *first* failing
    one). ``request`` is a lemma-id list (or anything with a
    ``lemma_ids`` attribute); ``snapshot`` an immutable index view;
    ``config`` a :class:`repro.serving.service.ServeConfig`; ``costs``
    an optional :class:`repro.serving.costs.PayloadCostModel` — the
    one measured input: given the same (request, snapshot, config) and
    the same cost-model state (its ``generation`` is the service's
    memo key), the decision is still deterministic."""
    ids = list(getattr(request, "lemma_ids", request))
    if not ids:
        return QueryPlan(qtype=None, route=ROUTE_EMPTY)
    if any(l == UNKNOWN_FL for l in ids):
        return _scalar(None, FB_UNKNOWN_LEMMA)
    qtype = classify(ids, snapshot.lexicon)

    # live-memtable route (DESIGN.md §18): when the snapshot carries an
    # unsealed-memtable overlay and any query lemma has postings in it,
    # results depend on pre-refresh documents — the scalar engine reads
    # the overlay through the same merged-view API bit-identically, while
    # the compiled ladder would burn pack/compile work on a view that
    # dies at the next add. A query whose lemmas the overlay cannot
    # contribute postings to reads the same merged rows with or without
    # the overlay, so it keeps its compiled route (the same touch
    # predicate the pack cache uses for staleness).
    overlay = getattr(snapshot, "mem_overlay", None)
    if overlay is not None and getattr(config, "scalar_memtable", True):
        if any(l in overlay.index.ordinary for l in ids):
            return _scalar(qtype, FB_LIVE_MEMTABLE)

    if qtype == QueryType.QT1:
        if snapshot.fst is None:
            return _scalar(qtype, FB_NO_FST_INDEX)
        if len(ids) < 3:
            return _scalar(qtype, FB_QUERY_TOO_SHORT)
        if len(ids) > snapshot.max_distance:
            return _scalar(qtype, FB_QUERY_TOO_LONG)
        keys, longest = qt1_plan(snapshot, ids)
        if len(keys) > config.k_fst:
            return _scalar(qtype, FB_TOO_MANY_FST_KEYS)
        bucket = ladder_bucket(longest, config)
        if bucket is None:
            return _scalar(qtype, FB_ROW_EXCEEDS_LADDER)
        return _compiled(qtype, ROUTE_QT1, bucket, config, keys, costs=costs)

    if qtype == QueryType.QT2:
        if snapshot.wv is None:
            return _scalar(qtype, FB_NO_WV_INDEX)
        if config.doc_shards > 1:
            # the interval join's 2*MaxDistance window can reach across
            # a doc (and therefore shard-segment) boundary, which the
            # per-shard device join cannot see (pack_qt2_batch's caveat)
            return _scalar(qtype, FB_SHARDED_QT2)
        # cheap key-count early-out before qt2_plan's posting-count
        # scans + sort (the cover size never changes with ordering)
        if len(select_wv_keys(ids)) > config.k_wv:
            return _scalar(qtype, FB_TOO_MANY_WV_KEYS)
        ordered, longest = qt2_plan(snapshot, ids)
        bucket = ladder_bucket(longest, config)
        if bucket is None:
            return _scalar(qtype, FB_ROW_EXCEEDS_LADDER)
        return _compiled(qtype, ROUTE_QT2, bucket, config, ordered,
                         costs=costs)

    if qtype == QueryType.QT5:
        if snapshot.ordinary is None:
            return _scalar(qtype, FB_NO_ORDINARY_INDEX)
        if snapshot.nsw is None:
            return _scalar(qtype, FB_NO_NSW_INDEX)
        p5 = qt5_plan(snapshot, ids)
        if p5 is None:
            return _scalar(qtype, FB_DEGENERATE_QT5)
        anchor, others, stops, counts = p5
        if len(others) > config.k_ns:
            return _scalar(qtype, FB_TOO_MANY_NS_CONSTRAINTS)
        if len(stops) > config.k_st:
            return _scalar(qtype, FB_TOO_MANY_STOP_CONSTRAINTS)
        if any(r > config.r_max for _, r in others):
            return _scalar(qtype, FB_MULTIPLICITY_OVER_R_MAX)
        if any(r > 254 for _, r in stops):
            return _scalar(qtype, FB_STOP_MULTIPLICITY_OVERFLOW)
        longest = max(counts[anchor],
                      max((counts[l] for l, _ in others), default=0))
        bucket = ladder_bucket(longest, config)
        if bucket is None:
            return _scalar(qtype, FB_ROW_EXCEEDS_LADDER)
        return _compiled(qtype, ROUTE_QT5, bucket, config, p5, costs=costs)

    # QT3/QT4: ordinary-index window scans through the shared qt34_join
    # — computationally identical, so one route serves both
    if snapshot.ordinary is None:
        return _scalar(qtype, FB_NO_ORDINARY_INDEX)
    p34 = qt34_plan(snapshot, ids)
    _, others, counts = p34
    if len(others) > config.k_ord:
        return _scalar(qtype, FB_TOO_MANY_ORD_CONSTRAINTS)
    if any(r > config.r_max for _, r in others):
        return _scalar(qtype, FB_MULTIPLICITY_OVER_R_MAX)
    bucket = ladder_bucket(max(counts.values()), config)
    if bucket is None:
        return _scalar(qtype, FB_ROW_EXCEEDS_LADDER)
    # dispatch-aware batching (the ROADMAP item, DESIGN.md §14): a QT34
    # group whose constraint count fits the QT5 step's non-stop slots
    # rides the qt5 executable at the same (B, L) — qt5_join with zero
    # stop constraints *is* qt34_join — so mixed traffic compiles one
    # executable ladder for both paths
    family = (ROUTE_QT5 if config.share_buckets and len(others) <= config.k_ns
              else ROUTE_QT34)
    return _compiled(qtype, ROUTE_QT34, bucket, config, p34,
                     step_family=family, costs=costs)
