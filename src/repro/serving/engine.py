"""Deprecated serving entry point.

``SearchServingEngine`` was the monolithic serving engine; the serving
tier is now three explicit layers (DESIGN.md §14) behind the
:class:`repro.serving.service.SearchService` facade:

* ``serving/planner.py`` — pure per-query routing (``QueryPlan``);
* ``serving/executors.py`` — compiled/scalar execution behind one
  protocol, with the shared per-(kind, B, L) executable table;
* ``serving/service.py`` — ``SearchService`` + ``ServeConfig`` +
  deadline-aware tickets.

This module keeps the old constructor signature working as a thin shim
over ``SearchService`` so existing callers, tests and benchmarks run
unmodified; new code should construct ``SearchService`` directly:

    from repro.serving import SearchService, ServeConfig
    svc = SearchService(index, mesh, ServeConfig(compressed=True))
    ticket = svc.submit(lemma_ids, deadline_s=0.05)
    responses = svc.drain()          # ticket.response is resolved too
    svc.explain(lemma_ids)           # the QueryPlan, without executing
"""

from __future__ import annotations

import warnings

from repro.serving.lm_batcher import LMContinuousBatcher  # noqa: F401 (compat)
from repro.serving.service import (  # noqa: F401 (compat re-exports)
    SearchRequest,
    SearchResponse,
    SearchService,
    SearchTicket,
    ServeConfig,
)


class SearchServingEngine:
    """Deprecated: thin delegation shim over :class:`SearchService`.

    Accepts the pre-§14 knob soup, folds it into a single
    :class:`ServeConfig`, and forwards ``submit``/``drain``/``refresh``
    plus the attribute surface old callers read (``stats``,
    ``pack_cache``, ``compressed_cache``, ``index``, ...). Responses
    additionally carry the new ``plan``/``deadline_met``/
    ``queue_wait_s`` fields — old callers simply never read them."""

    def __init__(
        self,
        index,
        mesh,
        buckets: tuple = (1024, 4096, 16384, 65536),
        max_batch: int = 64,
        top_k: int = 16,
        doc_shards: int = 1,
        compressed: bool = False,
        use_pack_cache: bool = True,
        use_compressed_cache: bool = True,
        cache_entries: int = 4096,
        cache_bytes: int = 256 << 20,
        k_fst: int = 2,
        k_wv: int = 3,
        k_ns: int = 3,
        k_st: int = 3,
        k_ord: int = 4,
        r_max: int = 4,
    ):
        warnings.warn(
            "SearchServingEngine is deprecated; use "
            "repro.serving.SearchService with a ServeConfig (DESIGN.md §14)",
            DeprecationWarning,
            stacklevel=2,
        )
        self.service = SearchService(index, mesh, ServeConfig(
            buckets=tuple(buckets), max_batch=max_batch, top_k=top_k,
            doc_shards=doc_shards, compressed=compressed,
            use_pack_cache=use_pack_cache,
            use_compressed_cache=use_compressed_cache,
            cache_entries=cache_entries, cache_bytes=cache_bytes,
            k_fst=k_fst, k_wv=k_wv, k_ns=k_ns, k_st=k_st, k_ord=k_ord,
            r_max=r_max,
        ))

    # -- the old serving protocol, delegated -------------------------------
    def submit(self, lemma_ids) -> None:
        self.service.submit(lemma_ids)

    def drain(self):
        return self.service.drain()

    def refresh(self) -> None:
        self.service.refresh()

    def explain(self, lemma_ids):
        return self.service.explain(lemma_ids)

    def stats_snapshot(self) -> dict:
        return self.service.stats_snapshot()

    # -- the old attribute surface -----------------------------------------
    @property
    def index(self):
        return self.service.index

    @property
    def stats(self) -> dict:
        return self.service.stats

    @property
    def pack_cache(self):
        return self.service.pack_cache

    @property
    def compressed_cache(self):
        return self.service.compressed_cache

    @property
    def mesh(self):
        return self.service.mesh

    @property
    def buckets(self) -> tuple:
        return self.service.config.buckets

    @property
    def max_batch(self) -> int:
        return self.service.config.max_batch

    @property
    def top_k(self) -> int:
        return self.service.config.top_k

    @property
    def doc_shards(self) -> int:
        return self.service.config.doc_shards

    @property
    def compressed(self) -> bool:
        return self.service.config.compressed

    @property
    def _queue(self) -> list:
        # a pre-§14 test asserts the queue is empty after drain()
        return self.service._queue
