"""Serving runtime: batched proximity-search serving (the paper's
product) and a continuous-batching LM decode loop.

Search serving (the end-to-end driver of examples/serve_search.py):
  * requests (query strings or lemma-id lists) accumulate in a queue;
  * the batcher cuts a batch on max_batch or max_wait, packs posting
    lists into the bucketed device format (core/jax_search.py), runs the
    compiled serve step and decodes results;
  * posting lengths are bucketed to a fixed ladder so each bucket hits a
    pre-compiled executable — the response-time guarantee is the compiled
    step time of the bucket (paper §1: "a simple inquiry should produce a
    response within two seconds").
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.jax_search import (
    assemble_qt1_compressed,
    assemble_qt2_compressed,
    assemble_qt34_compressed,
    assemble_qt5_compressed,
    batch_size_bucket,
    compress_qt1_batch,
    compress_qt2_batch,
    compress_qt34_batch,
    compress_qt5_batch,
    decode_results,
    make_qt1_serve_step,
    make_qt1_serve_step_compressed,
    make_wv_serve_step,
    ordered_wv_keys,
    pack_qt1_batch,
    pack_qt2_batch,
    pack_qt34_batch,
    pack_qt5_batch,
    qt34_plan,
    qt5_plan,
)
from repro.core.lexicon import UNKNOWN_FL
from repro.core.query import QueryType, classify, select_fst_keys, select_wv_keys
from repro.serving.pack_cache import PackedPostingCache

_EMPTY_RESULT = {
    "doc": np.zeros(0, np.int64),
    "start": np.zeros(0, np.int64),
    "end": np.zeros(0, np.int64),
    "score": np.zeros(0, np.float32),
}


@dataclass
class SearchRequest:
    lemma_ids: list
    arrival: float = field(default_factory=time.perf_counter)


@dataclass
class SearchResponse:
    results: dict
    latency_s: float
    bucket: int
    batch_size: int
    path: str = "qt1"


class SearchServingEngine:
    """Bucketed, batched proximity-search serving over a ProximityIndex
    or a snapshot-able incremental index (``repro.index.SegmentedIndex``).

    Serving always runs against an *immutable* searcher snapshot: a drain
    pins the snapshot once, so in-flight batches see a consistent view
    even while the indexer seals memtables and runs background merges.
    Call ``refresh()`` to pick up the indexer's latest published snapshot
    (documents added/deleted since the previous refresh become visible;
    the compiled serve steps are reused — only the host-side packing sees
    the new postings).

    Query-type dispatch (DESIGN.md §12-§13): a single drain routes each
    request by its lemma classes — QT1 to the (f,s,t) serve step, QT2 to
    the (w,v) interval-join step, QT3/QT4 to the ordinary-window step,
    QT5 to the NSW step — grouped per (path, L-bucket) and padded to the
    power-of-two batch ladder, so the response-time guarantee is uniform
    across every query type of the paper. Only shapes the static-shape
    steps cannot express (short/overlong queries, key counts beyond the
    static K, multiplicities beyond r_max, posting lists beyond the
    largest L-bucket) take the scalar CPU engine; the full route ×
    payload × fallback matrix is the dispatch-matrix table in
    DESIGN.md §13. Responses come back in submission order.

    Hot-path machinery (DESIGN.md §11-§12):

    * a ``PackedPostingCache`` memoizes the padded device rows of each
      (f,s,t) / (w,v) / ordinary / NSW key per (L, doc_shards) bucket,
      invalidated by snapshot identity (add-only refreshes retain
      untouched keys) — warm drains copy rows instead of re-deriving
      them from posting reads;
    * batch sizes are padded to a power-of-two ladder
      (``batch_size_bucket``), so each (path, B-bucket, L-bucket) triple
      hits one compiled executable instead of silently recompiling at
      every new queue length;
    * ``compressed=True`` ships block-delta16 device args (4 B/posting
      class instead of 12), falling back per batch to the offsets-only
      format when a 64-posting block's key span overflows uint16 — and
      memoizes the per-key (base, delta16, offsets) triples in a second
      ``PackedPostingCache`` so warm drains skip the O(B·K·L) host
      re-encode entirely."""

    def __init__(
        self,
        index,
        mesh,
        buckets: tuple = (1024, 4096, 16384, 65536),
        max_batch: int = 64,
        top_k: int = 16,
        doc_shards: int = 1,
        compressed: bool = False,
        use_pack_cache: bool = True,
        use_compressed_cache: bool = True,
        cache_entries: int = 4096,
        cache_bytes: int = 256 << 20,
        k_fst: int = 2,
        k_wv: int = 3,
        k_ns: int = 3,
        k_st: int = 3,
        k_ord: int = 4,
        r_max: int = 4,
    ):
        self._source = index if hasattr(index, "snapshot") else None
        self.index = index.snapshot() if self._source is not None else index
        if compressed and getattr(self.index, "max_distance", 0) > 254:
            # all compressed formats carry fragment bounds / NSW offsets
            # as uint8 distances; beyond 254 they would silently clip
            raise ValueError(
                "compressed serving requires max_distance <= 254 "
                f"(got {self.index.max_distance})"
            )
        self.mesh = mesh
        self.buckets = tuple(sorted(buckets))
        self.max_batch = max_batch
        self.top_k = top_k
        self.doc_shards = doc_shards
        self.compressed = compressed
        self.k_fst = k_fst
        self.k_wv = k_wv
        self.k_ns = k_ns
        self.k_st = k_st
        self.k_ord = k_ord
        self.r_max = r_max
        self.pack_cache = (
            PackedPostingCache(max_entries=cache_entries, max_bytes=cache_bytes)
            if use_pack_cache
            else None
        )
        # per-key compressed rows derive from (and sit beside) the raw
        # row cache; without it every warm compressed drain re-runs the
        # O(B·K·L) host delta encoding
        self.compressed_cache = (
            PackedPostingCache(max_entries=cache_entries, max_bytes=cache_bytes,
                               source=self.pack_cache)
            if compressed and use_compressed_cache
            else None
        )
        # compiled steps, one per (path, payload format); jit caches per
        # (B, L) shape under each, and batch_size_bucket bounds how many
        # shapes each one ever sees
        self._steps: dict[str, object] = {}
        self._queue: list[SearchRequest] = []
        self._queue_lock = threading.Lock()
        # per-snapshot lemma ids -> (path, bucket); validity is tied to
        # the *pinned view's identity* (not to refresh() clearing it: a
        # drain racing a refresh could otherwise re-insert a stale entry
        # after the clear). Bounded: a high-cardinality query stream over
        # a static index never refreshes, so the memo is cleared
        # wholesale at the cap (rebuilding an entry is one n_postings
        # scan per key)
        self._route_memo: dict[tuple, tuple] = {}
        self._route_memo_view = None
        self._route_memo_cap = 65536
        # scalar fallback engine, rebuilt per snapshot on first use
        self._cpu_engine = None
        # delta-format eligibility is static per bucket (block/shard
        # alignment); on the cache-less compressed path it also goes
        # sticky-False after a uint16 span overflow so persistent-
        # overflow corpora don't pay a failed delta encoding per batch
        # (with the compressed cache the verdict is per-key instead).
        # Keyed per (path, bucket): one path's overflow must not demote
        # the other paths' payloads at the same bucket
        self._delta_ok: dict[tuple, bool] = {}
        self.stats = {"batches": 0, "requests": 0, "refreshes": 0,
                      "compressed_batches": 0, "offset_fallbacks": 0,
                      "bucket_hist": {b: 0 for b in self.buckets},
                      "paths": {"qt1": 0, "qt2": 0, "qt34": 0, "qt5": 0,
                                "cpu": 0},
                      "pack_cache": {}, "compressed_cache": {}}

    def _step(self, kind: str):
        step = self._steps.get(kind)
        if step is None:
            d = self.index.max_distance
            if kind == "base":
                step = make_qt1_serve_step(self.mesh, top_k=self.top_k)
            elif kind in ("delta", "offsets"):
                step = make_qt1_serve_step_compressed(
                    self.mesh, top_k=self.top_k, delta_g=(kind == "delta")
                )
            else:  # "qt2_raw" ... "qt5_offsets"
                qtype, payload = kind.split("_", 1)
                step = make_wv_serve_step(
                    self.mesh, qtype, top_k=self.top_k, payload=payload,
                    max_distance=d, r_max=self.r_max,
                )
            self._steps[kind] = step
        return step

    def refresh(self) -> None:
        """Pick up the indexer's latest published snapshot.

        A no-op when the engine serves a static ``ProximityIndex``; for a
        ``repro.index.SegmentedIndex`` source this swaps in the newest
        immutable ``SegmentedView``, making documents added or deleted
        since the previous refresh visible to subsequent drains. Already
        in-flight drains keep the snapshot they pinned. The compiled
        per-bucket serve steps are reused across refreshes (only the
        host-side packing sees the new postings); route memoization is
        dropped lazily, and the row caches invalidate themselves on the
        first lookup against the new snapshot — entries are keyed by
        snapshot identity, and add-only refreshes retain untouched keys
        (DESIGN.md §12)."""
        if self._source is not None:
            self.index = self._source.snapshot()
            self.stats["refreshes"] += 1

    # -- routing -----------------------------------------------------------
    def _ladder(self, longest: int) -> int | None:
        # with doc_shards > 1 each range-partitioned shard segment holds
        # only L / doc_shards slots, and a doc-skewed key can land all its
        # postings in one segment: size conservatively for the worst-case
        # skew so the packers never silently truncate below the ladder cap.
        # None when even the largest bucket cannot hold the row — the
        # packers would silently truncate it, so the caller must route to
        # the scalar engine instead
        longest *= self.doc_shards
        for cand in self.buckets:
            if longest <= cand:
                return cand
        return None

    def _route(self, index, lemma_ids) -> tuple:
        """(path, bucket, plan) for one request: path is the compiled
        step family ("qt1" / "qt2" / "qt5") or "cpu" for shapes the
        compiled steps cannot express (the scalar engine is the
        correctness backstop, so routing is conservative). plan carries
        the memoized key selection — fst keys / size-ordered (w,v) keys /
        the qt5_plan tuple — so warm drains skip re-deriving it in the
        packers."""
        if index is not self._route_memo_view:
            self._route_memo = {}
            self._route_memo_view = index
            self._cpu_engine = None
        memo_key = tuple(lemma_ids)
        r = self._route_memo.get(memo_key)
        if r is not None:
            return r
        r = self._classify_route(index, list(lemma_ids))
        if len(self._route_memo) >= self._route_memo_cap:
            self._route_memo.clear()
        self._route_memo[memo_key] = r
        return r

    def _classify_route(self, index, ids) -> tuple:
        if not ids or any(l == UNKNOWN_FL for l in ids):
            return ("cpu", None, None) if ids else ("empty", None, None)
        qtype = classify(ids, index.lexicon)
        if qtype == QueryType.QT1:
            if index.fst is None or len(ids) < 3 or len(ids) > index.max_distance:
                return ("cpu", None, None)  # CPU degenerate/split paths
            _, keys = select_fst_keys(ids)
            if len(keys) > self.k_fst:
                return ("cpu", None, None)
            longest = 0
            for key in keys:
                if key in index.fst:
                    longest = max(longest, index.fst.n_postings(key))
            bucket = self._ladder(longest)
            return ("qt1", bucket, keys) if bucket else ("cpu", None, None)
        if qtype == QueryType.QT2:
            # sharded QT2 stays on the CPU: the interval join's
            # 2*MaxDistance window can reach across a doc (and therefore
            # shard-segment) boundary, which the per-shard device join
            # cannot see (pack_qt2_batch's doc_shards caveat) — exact
            # equivalence beats the compiled step there
            if index.wv is None or self.doc_shards > 1:
                return ("cpu", None, None)
            if len(select_wv_keys(ids)) > self.k_wv:
                return ("cpu", None, None)
            ordered, longest = ordered_wv_keys(index, ids)
            bucket = self._ladder(longest)
            return ("qt2", bucket, ordered) if bucket else ("cpu", None, None)
        if qtype == QueryType.QT5:
            if index.nsw is None:
                return ("cpu", None, None)
            plan = qt5_plan(index, ids)
            if plan is None:
                return ("cpu", None, None)
            anchor, others, stops, counts = plan
            if (
                len(others) > self.k_ns
                or len(stops) > self.k_st
                or any(r > self.r_max for _, r in others)
                or any(r > 254 for _, r in stops)
            ):
                return ("cpu", None, None)
            longest = max(counts[anchor],
                          max((counts[l] for l, _ in others), default=0))
            bucket = self._ladder(longest)
            return ("qt5", bucket, plan) if bucket else ("cpu", None, None)
        # QT3/QT4: ordinary-index window scans through the shared
        # qt34_join — computationally identical, so one route serves both
        if index.ordinary is None:
            return ("cpu", None, None)
        plan = qt34_plan(index, ids)
        _, others, counts = plan
        if len(others) > self.k_ord or any(r > self.r_max for _, r in others):
            return ("cpu", None, None)
        bucket = self._ladder(max(counts.values()))
        return ("qt34", bucket, plan) if bucket else ("cpu", None, None)

    def submit(self, lemma_ids) -> None:
        """Queue one search request (a list of lemma ids, i.e. one
        sub-query of ``core.query.build_subqueries``) for the next
        :meth:`drain`.

        Thread-safe and non-blocking: requests only accumulate here —
        no packing, classification or device work happens until the
        batcher cuts a batch. An empty list is answered with an empty
        result set; unknown lemmas (``UNKNOWN_FL``) route to the scalar
        engine, which resolves them to no matches."""
        req = SearchRequest(list(lemma_ids))
        with self._queue_lock:
            self._queue.append(req)

    def drain(self) -> list[SearchResponse]:
        """Serve everything queued, returning one :class:`SearchResponse`
        per request **in submission order**.

        The snapshot is pinned once for the whole drain, so every batch
        sees one consistent view even while the indexer refreshes
        concurrently. Each request is classified QT1-QT5 and routed per
        the dispatch matrix (DESIGN.md §13): QT1 to the (f,s,t) step,
        QT2 to the (w,v) interval-join step, QT3/QT4 to the
        ordinary-window step, QT5 to the NSW step — grouped per
        (path, L-bucket), padded to the power-of-two batch ladder and
        served largest group first in ``max_batch``-sized chunks;
        inexpressible shapes take the scalar CPU engine. Routing is
        memoized per lemma-id tuple per snapshot; ``stats["paths"]``
        counts the split. Each response carries its serve path, bucket,
        batch size and wall-clock batch latency."""
        if not self._queue:
            return []
        index = self.index
        # swap the queue out under the submit lock BEFORE grouping: a
        # submit() racing this drain either lands before the swap (and is
        # served now) or after it (and stays queued) — never silently
        # dropped into the already-grouped list
        with self._queue_lock:
            pending, self._queue = self._queue, []
        slots: list = [None] * len(pending)
        groups: dict[tuple, list[int]] = {}
        for i, r in enumerate(pending):
            path, bucket, _ = self._route(index, r.lemma_ids)
            groups.setdefault((path, bucket), []).append(i)
        for (path, bucket), idxs in sorted(groups.items(), key=lambda kv: -len(kv[1])):
            if path == "empty":
                for i in idxs:
                    slots[i] = SearchResponse(results=dict(_EMPTY_RESULT),
                                              latency_s=0.0, bucket=0,
                                              batch_size=1, path=path)
                self.stats["requests"] += len(idxs)
                self.stats["paths"]["empty"] = (
                    self.stats["paths"].get("empty", 0) + len(idxs)
                )
            elif path == "cpu":
                self._serve_cpu(index, pending, idxs, slots)
            else:
                for lo in range(0, len(idxs), self.max_batch):
                    chunk = idxs[lo : lo + self.max_batch]
                    self._serve_batch(index, path, bucket, pending, chunk, slots)
        return slots

    # -- the scalar correctness backstop ----------------------------------
    def _serve_cpu(self, index, pending, idxs, slots) -> None:
        from repro.core.search import ProximitySearchEngine

        if self._cpu_engine is None or self._cpu_engine.index is not index:
            self._cpu_engine = ProximitySearchEngine(
                index, top_k=self.top_k, equalize_mode="bulk"
            )
        for i in idxs:
            t0 = time.perf_counter()
            res, _ = self._cpu_engine.search_ids(pending[i].lemma_ids)
            slots[i] = SearchResponse(
                results={"doc": res.doc, "start": res.start, "end": res.end,
                         "score": res.score},
                latency_s=time.perf_counter() - t0, bucket=0, batch_size=1,
                path="cpu",
            )
        self.stats["requests"] += len(idxs)
        self.stats["paths"]["cpu"] += len(idxs)

    # -- compiled paths ----------------------------------------------------
    def _path_fns(self, path):
        """(assemble_fn, pack_fn, compress_fn, kind prefix, K kwargs) for
        one compiled path — the only place the three paths differ."""
        if path == "qt1":
            return (assemble_qt1_compressed, pack_qt1_batch,
                    compress_qt1_batch, "", {"K": self.k_fst})
        if path == "qt2":
            return (assemble_qt2_compressed, pack_qt2_batch,
                    compress_qt2_batch, "qt2_", {"K": self.k_wv})
        if path == "qt34":
            return (assemble_qt34_compressed, pack_qt34_batch,
                    compress_qt34_batch, "qt34_", {"Kn": self.k_ord})
        return (assemble_qt5_compressed, pack_qt5_batch,
                compress_qt5_batch, "qt5_", {"Kn": self.k_ns, "Ks": self.k_st})

    def _run_compiled(self, index, path, bucket, queries, plans):
        """Pack + execute one padded batch on the right compiled step;
        returns (batch_or_stub, device outs). ``plans`` carries the
        route-memoized key selections, aligned with ``queries``."""
        assemble_fn, pack_fn, compress_fn, prefix, kw = self._path_fns(path)
        ccache = self.compressed_cache
        if self.compressed and ccache is not None:
            kind, args, stub = assemble_fn(
                index, queries, L=bucket, doc_shards=self.doc_shards,
                ccache=ccache, cache=self.pack_cache, plans=plans, **kw,
            )
            self._count_compressed(kind)
            return stub, self._step(kind)(*args)
        batch = pack_fn(
            index, queries, L=bucket, doc_shards=self.doc_shards,
            cache=self.pack_cache, plans=plans, **kw,
        )
        if not self.compressed:
            raw_kind = "base" if path == "qt1" else f"{path}_raw"
            return batch, self._step(raw_kind)(*batch.device_args())
        kind, args = self._compress_batch(bucket, batch, compress_fn, prefix=prefix)
        return batch, self._step(kind)(*args)

    def _compress_batch(self, bucket, batch, compress_fn, prefix=""):
        """Cache-less compressed path: whole-batch re-encode with the
        per-(path, bucket) sticky delta verdict (PR 2 behavior, kept for
        benchmarking and as the use_compressed_cache=False fallback)."""
        ck = (prefix, bucket)
        ok = self._delta_ok.get(ck)
        if ok is None:
            ok = bucket % (64 * self.doc_shards) == 0
            self._delta_ok[ck] = ok
        kind = "offsets"
        if ok:
            try:
                args = compress_fn(batch, delta_g=True)
                kind = "delta"
            except ValueError:  # in-block key span overflows uint16
                self._delta_ok[ck] = False
        if kind == "offsets":
            args = compress_fn(batch, delta_g=False)
        self._count_compressed(kind)
        return prefix + kind, args

    def _count_compressed(self, kind: str) -> None:
        self.stats["compressed_batches"] += 1
        if kind.endswith("offsets"):
            self.stats["offset_fallbacks"] += 1

    def _serve_batch(self, index, path, bucket, pending, idxs, slots) -> None:
        t0 = time.perf_counter()
        B_pad = batch_size_bucket(len(idxs), self.max_batch)
        pad = B_pad - len(idxs)
        queries = [pending[i].lemma_ids for i in idxs] + [[]] * pad
        plans = [self._route(index, pending[i].lemma_ids)[2] for i in idxs]
        batch, outs = self._run_compiled(index, path, bucket, queries,
                                         plans + [None] * pad)
        decoded = decode_results(batch, *outs)
        dt = time.perf_counter() - t0
        self.stats["batches"] += 1
        self.stats["requests"] += len(idxs)
        self.stats["paths"][path] += len(idxs)
        if bucket in self.stats["bucket_hist"]:
            self.stats["bucket_hist"][bucket] += 1
        if self.pack_cache is not None:
            self.stats["pack_cache"] = self.pack_cache.stats
        if self.compressed_cache is not None:
            self.stats["compressed_cache"] = self.compressed_cache.stats
        for bi, i in enumerate(idxs):
            slots[i] = SearchResponse(results=decoded[bi], latency_s=dt,
                                      bucket=bucket, batch_size=len(idxs),
                                      path=path)


class LMContinuousBatcher:
    """Slot-based continuous batching for LM decode (vLLM-style admission,
    greedy sampling): a fixed pool of B cache slots; finished sequences
    free their slot and queued prompts are admitted at the next step."""

    def __init__(self, cfg, params, batch_slots: int, max_len: int, eos_id: int = 0):
        import jax.numpy as jnp

        from repro.models import transformer

        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.caches = transformer.init_cache(cfg, batch_slots, max_len)
        self.tokens = np.zeros((batch_slots, 1), np.int32)
        self.lengths = np.zeros(batch_slots, np.int32)
        self.active = np.zeros(batch_slots, bool)
        self.seq_outputs: dict[int, list] = {}
        self.next_id = 0
        self.slot_owner = [-1] * batch_slots
        self.queue: list[list[int]] = []
        import jax

        self._decode = jax.jit(
            lambda p, t, c, pos: transformer.decode_step(cfg, p, t, c, pos)
        )

    def submit(self, prompt_ids: list) -> int:
        rid = self.next_id
        self.next_id += 1
        self.queue.append((rid, list(prompt_ids)))
        return rid

    def _admit(self):
        import jax.numpy as jnp

        for slot in range(self.B):
            if not self.active[slot] and self.queue:
                rid, prompt = self.queue.pop(0)
                # prefill the slot by stepping through the prompt (simple
                # admission; production would use a chunked prefill kernel)
                self.active[slot] = True
                self.slot_owner[slot] = rid
                self.seq_outputs[rid] = []
                self.lengths[slot] = 0
                for tok in prompt:
                    self.tokens[slot, 0] = tok
                    # positions handled in step(); prompt tokens fed one by one

    def step(self) -> dict:
        """One decode step for all active slots. Returns finished seqs."""
        import jax.numpy as jnp

        self._admit()
        if not self.active.any():
            return {}
        pos = int(self.lengths.max())
        logits, self.caches = self._decode(
            self.params, jnp.asarray(self.tokens), self.caches, jnp.int32(pos)
        )
        nxt = np.asarray(logits.argmax(axis=-1)).astype(np.int32)
        finished = {}
        for slot in range(self.B):
            if not self.active[slot]:
                continue
            tok = int(nxt[slot])
            rid = self.slot_owner[slot]
            self.seq_outputs[rid].append(tok)
            self.tokens[slot, 0] = tok
            self.lengths[slot] += 1
            if tok == self.eos_id or self.lengths[slot] >= self.max_len - 1:
                finished[rid] = self.seq_outputs.pop(rid)
                self.active[slot] = False
                self.slot_owner[slot] = -1
        return finished
