"""Serving runtime: batched proximity-search serving (the paper's
product) and a continuous-batching LM decode loop.

Search serving (the end-to-end driver of examples/serve_search.py):
  * requests (query strings or lemma-id lists) accumulate in a queue;
  * the batcher cuts a batch on max_batch or max_wait, packs posting
    lists into the bucketed device format (core/jax_search.py), runs the
    compiled serve step and decodes results;
  * posting lengths are bucketed to a fixed ladder so each bucket hits a
    pre-compiled executable — the response-time guarantee is the compiled
    step time of the bucket (paper §1: "a simple inquiry should produce a
    response within two seconds").
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.jax_search import (
    batch_size_bucket,
    compress_qt1_batch,
    decode_results,
    make_qt1_serve_step,
    make_qt1_serve_step_compressed,
    pack_qt1_batch,
)
from repro.core.query import select_fst_keys
from repro.serving.pack_cache import PackedPostingCache


@dataclass
class SearchRequest:
    lemma_ids: list
    arrival: float = field(default_factory=time.perf_counter)


@dataclass
class SearchResponse:
    results: dict
    latency_s: float
    bucket: int
    batch_size: int


class SearchServingEngine:
    """Bucketed, batched QT1 serving over a ProximityIndex or a
    snapshot-able incremental index (``repro.index.SegmentedIndex``).

    Serving always runs against an *immutable* searcher snapshot: a drain
    pins the snapshot once, so in-flight batches see a consistent view
    even while the indexer seals memtables and runs background merges.
    Call ``refresh()`` to pick up the indexer's latest published snapshot
    (documents added/deleted since the previous refresh become visible;
    the compiled serve steps are reused — only the host-side packing sees
    the new postings).

    Hot-path machinery (DESIGN.md §11):

    * a ``PackedPostingCache`` memoizes the padded (g, lo, hi) rows of
      each (f,s,t) key per (L, doc_shards) bucket, invalidated by
      snapshot identity — warm drains copy rows instead of re-deriving
      them from posting reads;
    * batch sizes are padded to a power-of-two ladder
      (``batch_size_bucket``), so each (B-bucket, L-bucket) pair hits one
      compiled executable instead of silently recompiling at every new
      queue length;
    * ``compressed=True`` ships delta-coded device args
      (``compress_qt1_batch`` -> ``make_qt1_serve_step_compressed``):
      4 bytes/posting instead of 12, falling back per batch to the
      6-byte offsets-only format when a 64-posting block's key span
      overflows uint16."""

    def __init__(
        self,
        index,
        mesh,
        buckets: tuple = (1024, 4096, 16384, 65536),
        max_batch: int = 64,
        top_k: int = 16,
        doc_shards: int = 1,
        compressed: bool = False,
        use_pack_cache: bool = True,
        cache_entries: int = 4096,
        cache_bytes: int = 256 << 20,
    ):
        self._source = index if hasattr(index, "snapshot") else None
        self.index = index.snapshot() if self._source is not None else index
        if compressed and getattr(self.index, "max_distance", 0) > 254:
            # both compressed formats carry fragment bounds as uint8
            # offsets from the anchor; beyond 254 they would silently clip
            raise ValueError(
                "compressed serving requires max_distance <= 254 "
                f"(got {self.index.max_distance})"
            )
        self.mesh = mesh
        self.buckets = tuple(sorted(buckets))
        self.max_batch = max_batch
        self.top_k = top_k
        self.doc_shards = doc_shards
        self.compressed = compressed
        self.pack_cache = (
            PackedPostingCache(max_entries=cache_entries, max_bytes=cache_bytes)
            if use_pack_cache
            else None
        )
        # compiled steps, one per payload format; jit caches per (B, L)
        # shape under each, and batch_size_bucket bounds how many shapes
        # each one ever sees
        self._steps: dict[str, object] = {}
        self._queue: list[SearchRequest] = []
        self._queue_lock = threading.Lock()
        # per-snapshot lemma ids -> L; validity is tied to the *pinned
        # view's identity* (not to refresh() clearing it: a drain racing a
        # refresh could otherwise re-insert a stale entry after the
        # clear). Bounded: a high-cardinality query stream over a static
        # index never refreshes, so the memo is cleared wholesale at the
        # cap (rebuilding an entry is one n_postings scan)
        self._bucket_memo: dict[tuple, int] = {}
        self._bucket_memo_view = None
        self._bucket_memo_cap = 65536
        # delta-format eligibility is static per bucket (block/shard
        # alignment); it also goes sticky-False after a uint16 span
        # overflow so persistent-overflow corpora don't pay a failed
        # delta encoding on every batch
        self._delta_ok = {b: b % (64 * doc_shards) == 0 for b in self.buckets}
        self.stats = {"batches": 0, "requests": 0, "refreshes": 0,
                      "compressed_batches": 0, "offset_fallbacks": 0,
                      "bucket_hist": {b: 0 for b in self.buckets},
                      "pack_cache": {}}

    def _step(self, kind: str):
        step = self._steps.get(kind)
        if step is None:
            if kind == "base":
                step = make_qt1_serve_step(self.mesh, top_k=self.top_k)
            else:  # "delta" / "offsets"
                step = make_qt1_serve_step_compressed(
                    self.mesh, top_k=self.top_k, delta_g=(kind == "delta")
                )
            self._steps[kind] = step
        return step

    def refresh(self) -> None:
        """Swap in the indexer's latest published snapshot (no-op for a
        static ProximityIndex). Bucket memoization is dropped here; the
        pack cache invalidates itself on the first lookup against the new
        snapshot (its entries are keyed by snapshot identity)."""
        if self._source is not None:
            self.index = self._source.snapshot()
            self.stats["refreshes"] += 1

    def _bucket_for(self, index, lemma_ids) -> int:
        if index is not self._bucket_memo_view:
            self._bucket_memo = {}
            self._bucket_memo_view = index
        memo_key = tuple(lemma_ids)
        b = self._bucket_memo.get(memo_key)
        if b is not None:
            return b
        _, keys = select_fst_keys(list(lemma_ids))
        longest = 0
        for key in keys:
            if index.fst is not None and key in index.fst:
                longest = max(longest, index.fst.n_postings(key))
        b = self.buckets[-1]
        for cand in self.buckets:
            if longest <= cand:
                b = cand
                break
        if len(self._bucket_memo) >= self._bucket_memo_cap:
            self._bucket_memo.clear()
        self._bucket_memo[memo_key] = b
        return b

    def submit(self, lemma_ids) -> None:
        req = SearchRequest(list(lemma_ids))
        with self._queue_lock:
            self._queue.append(req)

    def drain(self) -> list[SearchResponse]:
        """Serve everything queued. The snapshot is pinned once for the
        whole drain; each request's bucket is computed once (memoized per
        lemma-id tuple per snapshot), the queue is consumed in one pass,
        and each bucket group is served in max_batch-sized chunks,
        largest group first."""
        out: list[SearchResponse] = []
        if not self._queue:
            return out
        index = self.index
        # swap the queue out under the submit lock BEFORE grouping: a
        # submit() racing this drain either lands before the swap (and is
        # served now) or after it (and stays queued) — never silently
        # dropped into the already-grouped list
        with self._queue_lock:
            pending, self._queue = self._queue, []
        by_bucket: dict[int, list[SearchRequest]] = {}
        for r in pending:
            by_bucket.setdefault(self._bucket_for(index, r.lemma_ids), []).append(r)
        for bucket, reqs in sorted(by_bucket.items(), key=lambda kv: -len(kv[1])):
            for lo in range(0, len(reqs), self.max_batch):
                self._serve_batch(index, bucket, reqs[lo : lo + self.max_batch], out)
        return out

    def _serve_batch(self, index, bucket, reqs, out) -> None:
        t0 = time.perf_counter()
        B_pad = batch_size_bucket(len(reqs), self.max_batch)
        queries = [r.lemma_ids for r in reqs] + [[]] * (B_pad - len(reqs))
        batch = pack_qt1_batch(
            index, queries, L=bucket, K=2,
            doc_shards=self.doc_shards, cache=self.pack_cache,
        )
        if self.compressed:
            # delta blocks are 64 postings wide and must not straddle the
            # L // doc_shards shard segments (the compressed step shards
            # the per-block base over the model axis): _delta_ok holds the
            # static verdict, and goes False on first uint16 span overflow
            kind = "offsets"
            if self._delta_ok.get(bucket, False):
                try:
                    args = compress_qt1_batch(batch, delta_g=True)
                    kind = "delta"
                except ValueError:  # in-block key span overflows uint16
                    self._delta_ok[bucket] = False
            if kind == "offsets":
                args = compress_qt1_batch(batch, delta_g=False)
                self.stats["offset_fallbacks"] += 1
            self.stats["compressed_batches"] += 1
            outs = self._step(kind)(*args)
        else:
            outs = self._step("base")(*batch.device_args())
        decoded = decode_results(batch, *outs)
        dt = time.perf_counter() - t0
        self.stats["batches"] += 1
        self.stats["requests"] += len(reqs)
        self.stats["bucket_hist"][bucket] += 1
        if self.pack_cache is not None:
            self.stats["pack_cache"] = self.pack_cache.stats
        for i in range(len(reqs)):
            out.append(
                SearchResponse(results=decoded[i], latency_s=dt, bucket=bucket,
                               batch_size=len(reqs))
            )


class LMContinuousBatcher:
    """Slot-based continuous batching for LM decode (vLLM-style admission,
    greedy sampling): a fixed pool of B cache slots; finished sequences
    free their slot and queued prompts are admitted at the next step."""

    def __init__(self, cfg, params, batch_slots: int, max_len: int, eos_id: int = 0):
        import jax.numpy as jnp

        from repro.models import transformer

        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.caches = transformer.init_cache(cfg, batch_slots, max_len)
        self.tokens = np.zeros((batch_slots, 1), np.int32)
        self.lengths = np.zeros(batch_slots, np.int32)
        self.active = np.zeros(batch_slots, bool)
        self.seq_outputs: dict[int, list] = {}
        self.next_id = 0
        self.slot_owner = [-1] * batch_slots
        self.queue: list[list[int]] = []
        import jax

        self._decode = jax.jit(
            lambda p, t, c, pos: transformer.decode_step(cfg, p, t, c, pos)
        )

    def submit(self, prompt_ids: list) -> int:
        rid = self.next_id
        self.next_id += 1
        self.queue.append((rid, list(prompt_ids)))
        return rid

    def _admit(self):
        import jax.numpy as jnp

        for slot in range(self.B):
            if not self.active[slot] and self.queue:
                rid, prompt = self.queue.pop(0)
                # prefill the slot by stepping through the prompt (simple
                # admission; production would use a chunked prefill kernel)
                self.active[slot] = True
                self.slot_owner[slot] = rid
                self.seq_outputs[rid] = []
                self.lengths[slot] = 0
                for tok in prompt:
                    self.tokens[slot, 0] = tok
                    # positions handled in step(); prompt tokens fed one by one

    def step(self) -> dict:
        """One decode step for all active slots. Returns finished seqs."""
        import jax.numpy as jnp

        self._admit()
        if not self.active.any():
            return {}
        pos = int(self.lengths.max())
        logits, self.caches = self._decode(
            self.params, jnp.asarray(self.tokens), self.caches, jnp.int32(pos)
        )
        nxt = np.asarray(logits.argmax(axis=-1)).astype(np.int32)
        finished = {}
        for slot in range(self.B):
            if not self.active[slot]:
                continue
            tok = int(nxt[slot])
            rid = self.slot_owner[slot]
            self.seq_outputs[rid].append(tok)
            self.tokens[slot, 0] = tok
            self.lengths[slot] += 1
            if tok == self.eos_id or self.lengths[slot] >= self.max_len - 1:
                finished[rid] = self.seq_outputs.pop(rid)
                self.active[slot] = False
                self.slot_owner[slot] = -1
        return finished
