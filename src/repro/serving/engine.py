"""Serving runtime: batched proximity-search serving (the paper's
product) and a continuous-batching LM decode loop.

Search serving (the end-to-end driver of examples/serve_search.py):
  * requests (query strings or lemma-id lists) accumulate in a queue;
  * the batcher cuts a batch on max_batch or max_wait, packs posting
    lists into the bucketed device format (core/jax_search.py), runs the
    compiled serve step and decodes results;
  * posting lengths are bucketed to a fixed ladder so each bucket hits a
    pre-compiled executable — the response-time guarantee is the compiled
    step time of the bucket (paper §1: "a simple inquiry should produce a
    response within two seconds").
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.jax_search import decode_results, make_qt1_serve_step, pack_qt1_batch
from repro.core.query import select_fst_keys


@dataclass
class SearchRequest:
    lemma_ids: list
    arrival: float = field(default_factory=time.perf_counter)


@dataclass
class SearchResponse:
    results: dict
    latency_s: float
    bucket: int
    batch_size: int


class SearchServingEngine:
    """Bucketed, batched QT1 serving over a ProximityIndex or a
    snapshot-able incremental index (``repro.index.SegmentedIndex``).

    Serving always runs against an *immutable* searcher snapshot: a drain
    pins the snapshot once, so in-flight batches see a consistent view
    even while the indexer seals memtables and runs background merges.
    Call ``refresh()`` to pick up the indexer's latest published snapshot
    (documents added/deleted since the previous refresh become visible;
    the compiled serve step is reused — only the host-side packing sees
    the new postings)."""

    def __init__(
        self,
        index,
        mesh,
        buckets: tuple = (1024, 4096, 16384, 65536),
        max_batch: int = 64,
        top_k: int = 16,
        doc_shards: int = 1,
    ):
        self._source = index if hasattr(index, "snapshot") else None
        self.index = index.snapshot() if self._source is not None else index
        self.mesh = mesh
        self.buckets = tuple(sorted(buckets))
        self.max_batch = max_batch
        self.doc_shards = doc_shards
        self.step = make_qt1_serve_step(mesh, top_k=top_k)
        self._queue: list[SearchRequest] = []
        self.stats = {"batches": 0, "requests": 0, "refreshes": 0,
                      "bucket_hist": {b: 0 for b in self.buckets}}

    def refresh(self) -> None:
        """Swap in the indexer's latest published snapshot (no-op for a
        static ProximityIndex)."""
        if self._source is not None:
            self.index = self._source.snapshot()
            self.stats["refreshes"] += 1

    def _bucket_for(self, index, lemma_ids) -> int:
        _, keys = select_fst_keys(list(lemma_ids))
        longest = 0
        for key in keys:
            if index.fst is not None and key in index.fst:
                longest = max(longest, index.fst.n_postings(key))
        for b in self.buckets:
            if longest <= b:
                return b
        return self.buckets[-1]

    def submit(self, lemma_ids) -> None:
        self._queue.append(SearchRequest(list(lemma_ids)))

    def drain(self) -> list[SearchResponse]:
        """Serve everything queued, one batch per bucket. The snapshot is
        pinned once for the whole drain."""
        out = []
        index = self.index
        while self._queue:
            # group by bucket; serve the largest group first
            by_bucket: dict[int, list[SearchRequest]] = {}
            for r in self._queue:
                by_bucket.setdefault(self._bucket_for(index, r.lemma_ids), []).append(r)
            bucket, reqs = max(by_bucket.items(), key=lambda kv: len(kv[1]))
            reqs = reqs[: self.max_batch]
            for r in reqs:
                self._queue.remove(r)
            t0 = time.perf_counter()
            batch = pack_qt1_batch(
                index, [r.lemma_ids for r in reqs], L=bucket, K=2,
                doc_shards=self.doc_shards,
            )
            outs = self.step(*batch.device_args())
            decoded = decode_results(batch, *outs)
            dt = time.perf_counter() - t0
            self.stats["batches"] += 1
            self.stats["requests"] += len(reqs)
            self.stats["bucket_hist"][bucket] += 1
            for i in range(len(reqs)):
                out.append(
                    SearchResponse(results=decoded[i], latency_s=dt, bucket=bucket,
                                   batch_size=len(reqs))
                )
        return out


class LMContinuousBatcher:
    """Slot-based continuous batching for LM decode (vLLM-style admission,
    greedy sampling): a fixed pool of B cache slots; finished sequences
    free their slot and queued prompts are admitted at the next step."""

    def __init__(self, cfg, params, batch_slots: int, max_len: int, eos_id: int = 0):
        import jax.numpy as jnp

        from repro.models import transformer

        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.caches = transformer.init_cache(cfg, batch_slots, max_len)
        self.tokens = np.zeros((batch_slots, 1), np.int32)
        self.lengths = np.zeros(batch_slots, np.int32)
        self.active = np.zeros(batch_slots, bool)
        self.seq_outputs: dict[int, list] = {}
        self.next_id = 0
        self.slot_owner = [-1] * batch_slots
        self.queue: list[list[int]] = []
        import jax

        self._decode = jax.jit(
            lambda p, t, c, pos: transformer.decode_step(cfg, p, t, c, pos)
        )

    def submit(self, prompt_ids: list) -> int:
        rid = self.next_id
        self.next_id += 1
        self.queue.append((rid, list(prompt_ids)))
        return rid

    def _admit(self):
        import jax.numpy as jnp

        for slot in range(self.B):
            if not self.active[slot] and self.queue:
                rid, prompt = self.queue.pop(0)
                # prefill the slot by stepping through the prompt (simple
                # admission; production would use a chunked prefill kernel)
                self.active[slot] = True
                self.slot_owner[slot] = rid
                self.seq_outputs[rid] = []
                self.lengths[slot] = 0
                for tok in prompt:
                    self.tokens[slot, 0] = tok
                    # positions handled in step(); prompt tokens fed one by one

    def step(self) -> dict:
        """One decode step for all active slots. Returns finished seqs."""
        import jax.numpy as jnp

        self._admit()
        if not self.active.any():
            return {}
        pos = int(self.lengths.max())
        logits, self.caches = self._decode(
            self.params, jnp.asarray(self.tokens), self.caches, jnp.int32(pos)
        )
        nxt = np.asarray(logits.argmax(axis=-1)).astype(np.int32)
        finished = {}
        for slot in range(self.B):
            if not self.active[slot]:
                continue
            tok = int(nxt[slot])
            rid = self.slot_owner[slot]
            self.seq_outputs[rid].append(tok)
            self.tokens[slot, 0] = tok
            self.lengths[slot] += 1
            if tok == self.eos_id or self.lengths[slot] >= self.max_len - 1:
                finished[rid] = self.seq_outputs.pop(rid)
                self.active[slot] = False
                self.slot_owner[slot] = -1
        return finished
