"""Continuous-batching LM decode loop (the serving tier's unrelated
second tenant — it shares the mesh/step infrastructure, not the search
planner/executor stack, so it lives in its own module)."""

from __future__ import annotations

import numpy as np


class LMContinuousBatcher:
    """Slot-based continuous batching for LM decode (vLLM-style admission,
    greedy sampling): a fixed pool of B cache slots; finished sequences
    free their slot and queued prompts are admitted at the next step."""

    def __init__(self, cfg, params, batch_slots: int, max_len: int, eos_id: int = 0):
        from repro.models import transformer

        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.caches = transformer.init_cache(cfg, batch_slots, max_len)
        self.tokens = np.zeros((batch_slots, 1), np.int32)
        self.lengths = np.zeros(batch_slots, np.int32)
        self.active = np.zeros(batch_slots, bool)
        self.seq_outputs: dict[int, list] = {}
        self.next_id = 0
        self.slot_owner = [-1] * batch_slots
        self.queue: list[list[int]] = []
        import jax

        self._decode = jax.jit(
            lambda p, t, c, pos: transformer.decode_step(cfg, p, t, c, pos)
        )

    def submit(self, prompt_ids: list) -> int:
        rid = self.next_id
        self.next_id += 1
        self.queue.append((rid, list(prompt_ids)))
        return rid

    def _admit(self):
        for slot in range(self.B):
            if not self.active[slot] and self.queue:
                rid, prompt = self.queue.pop(0)
                # prefill the slot by stepping through the prompt (simple
                # admission; production would use a chunked prefill kernel)
                self.active[slot] = True
                self.slot_owner[slot] = rid
                self.seq_outputs[rid] = []
                self.lengths[slot] = 0
                for tok in prompt:
                    self.tokens[slot, 0] = tok
                    # positions handled in step(); prompt tokens fed one by one

    def step(self) -> dict:
        """One decode step for all active slots. Returns finished seqs."""
        import jax.numpy as jnp

        self._admit()
        if not self.active.any():
            return {}
        pos = int(self.lengths.max())
        logits, self.caches = self._decode(
            self.params, jnp.asarray(self.tokens), self.caches, jnp.int32(pos)
        )
        nxt = np.asarray(logits.argmax(axis=-1)).astype(np.int32)
        finished = {}
        for slot in range(self.B):
            if not self.active[slot]:
                continue
            tok = int(nxt[slot])
            rid = self.slot_owner[slot]
            self.seq_outputs[rid].append(tok)
            self.tokens[slot, 0] = tok
            self.lengths[slot] += 1
            if tok == self.eos_id or self.lengths[slot] >= self.max_len - 1:
                finished[rid] = self.seq_outputs.pop(rid)
                self.active[slot] = False
                self.slot_owner[slot] = -1
        return finished
