"""SearchService: the deadline-aware serving facade (DESIGN.md §14).

The serving tier is three explicit layers — this module is the top one:

* :mod:`repro.serving.planner` — pure per-query routing
  (``plan(request, snapshot, config) -> QueryPlan``);
* :mod:`repro.serving.executors` — ``CompiledExecutor`` (serve-step
  factories + the shared per-(kind, B, L) executable table) and
  ``ScalarExecutor`` behind one protocol;
* :class:`SearchService` — submit/drain/refresh/explain over one
  :class:`ServeConfig`, replacing the fifteen positional knobs of the
  old monolithic engine.

``submit(lemma_ids, deadline_s=...)`` returns a :class:`SearchTicket`
resolved by the next :meth:`SearchService.drain`; every
:class:`SearchResponse` carries the :class:`QueryPlan` that routed it,
whether its deadline was met, and how long it waited in the queue —
the paper's response-time guarantee as an observable, per-request
contract instead of an implicit property of a compiled step.
"""

from __future__ import annotations

import copy
import dataclasses
import threading
import time
from dataclasses import dataclass, field

from repro.obs import MetricsRegistry, Tracer, chrome_trace, write_chrome_trace
from repro.serving import planner as _planner
from repro.serving.executors import (
    CompiledExecutor,
    ExecResult,
    ScalarExecutor,
    empty_results,
    zero_phases,
)
from repro.serving.costs import PayloadCostModel
from repro.serving.pack_cache import PackedPostingCache
from repro.serving.planner import QueryPlan


@dataclass(frozen=True)
class ServeConfig:
    """Every serving knob in one (frozen, reusable) place.

    * ``buckets`` — the L-bucket ladder posting rows are padded to; one
      compiled executable exists per (step kind, B-bucket, L-bucket);
    * ``max_batch`` / ``top_k`` / ``doc_shards`` — batch cap, results
      per query, model-axis doc shards;
    * ``compressed`` — serve the block-delta16 device payload
      (DESIGN.md §11-§12) with per-batch offsets fallback;
    * ``use_pack_cache`` / ``use_compressed_cache`` / ``cache_entries``
      / ``cache_bytes`` — the packed-posting row caches;
    * ``k_fst``/``k_wv``/``k_ns``/``k_st``/``k_ord``/``r_max`` — static
      key/constraint capacities of the compiled steps (the dispatch
      matrix's fallback thresholds, DESIGN.md §13);
    * ``share_buckets`` — dispatch-aware batching: qt34 groups whose
      plans fit the QT5 step's non-stop slots ride the qt5 executable
      of the same (B, L), and are batched together with qt5 traffic
      (DESIGN.md §14);
    * ``payload_cost_driven`` — arbitrate each compressed group's
      payload (raw vs the static delta16/offsets rule) per
      (step_family, L-bucket) from measured warm batch time
      (DESIGN.md §16); no effect on an uncompressed engine;
    * ``use_pallas`` — route the qt34/qt5 window join through the
      fused Pallas nearest-r kernel (TPU; interpret-mode on CPU is for
      validation only — the default lax counting join is the CPU fast
      path, DESIGN.md §16);
    * ``default_deadline_s`` — deadline attached to submits that don't
      pass one (None = no deadline);
    * ``trace_enabled`` / ``trace_capacity`` — the §15 span tracer (a
      bounded ring of completed spans; disabling reduces the obs
      overhead to the per-phase timestamps);
    * ``metrics_capacity`` — samples retained per latency histogram."""

    buckets: tuple = (1024, 4096, 16384, 65536)
    max_batch: int = 64
    top_k: int = 16
    doc_shards: int = 1
    compressed: bool = False
    use_pack_cache: bool = True
    use_compressed_cache: bool = True
    cache_entries: int = 4096
    cache_bytes: int = 256 << 20
    k_fst: int = 2
    k_wv: int = 3
    k_ns: int = 3
    k_st: int = 3
    k_ord: int = 4
    r_max: int = 4
    share_buckets: bool = True
    payload_cost_driven: bool = True
    use_pallas: bool = False
    default_deadline_s: float | None = None
    trace_enabled: bool = True
    trace_capacity: int = 8192
    metrics_capacity: int = 4096

    def __post_init__(self):
        object.__setattr__(self, "buckets", tuple(sorted(self.buckets)))


@dataclass
class SearchRequest:
    """Import-compatibility symbol only: no code path constructs it —
    the serving queue holds :class:`SearchTicket` records now. Deleted
    together with the :class:`SearchServingEngine` shim."""

    lemma_ids: list
    arrival: float = field(default_factory=time.perf_counter)


@dataclass
class SearchTicket:
    """Future-like handle returned by :meth:`SearchService.submit`,
    resolved in place by the next :meth:`SearchService.drain` (there is
    no background thread — resolution is the drain that serves it)."""

    lemma_ids: list
    deadline_s: float | None = None
    arrival: float = field(default_factory=time.perf_counter)
    response: "SearchResponse | None" = None

    @property
    def done(self) -> bool:
        return self.response is not None

    def result(self) -> "SearchResponse":
        if self.response is None:
            raise RuntimeError("ticket not resolved yet — call drain()")
        return self.response


@dataclass
class SearchResponse:
    """One served request: the results plus the serving contract —
    ``plan`` is the :class:`QueryPlan` that routed it (its ``payload``
    reflects the format actually executed), ``deadline_met`` whether
    resolution beat the ticket's budget (None when no deadline was
    set), ``queue_wait_s`` the time between submit and its batch
    starting execution.

    Observability surface (DESIGN.md §15): ``phases`` maps every phase
    of the request's life to its duration in seconds — ``queue`` (submit
    → its batch starting), ``plan``, then the batch phases ``pack`` /
    ``compress`` / ``compile`` / ``dispatch`` / ``execute`` / ``decode``
    — and sums to the end-to-end latency ``finished_at - arrival``
    (within the tiny planning overlap; tests pin 10%).
    ``started_at``/``finished_at`` are the perf_counter bounds of the
    batch that served it, on every route including scalar fallback and
    empty. ``deadline_blame`` names the largest non-queue phase when
    the deadline was missed — a missed budget names the phase that blew
    it — and the queue when waiting alone exceeded the budget."""

    results: dict
    latency_s: float
    bucket: int
    batch_size: int
    path: str = "qt1"
    plan: QueryPlan | None = None
    deadline_met: bool | None = None
    queue_wait_s: float = 0.0
    phases: dict = field(default_factory=dict)
    started_at: float = 0.0
    finished_at: float = 0.0
    deadline_blame: str | None = None

    @property
    def e2e_s(self) -> float:
        """End-to-end submit → resolution latency (queue wait included)."""
        return self.queue_wait_s + (self.finished_at - self.started_at)


def _route_to_path(route: str) -> str:
    """Plan routes -> the executed-path names of ``stats["paths"]``
    (the pre-planner vocabulary: the scalar route reports as "cpu")."""
    return "cpu" if route == _planner.ROUTE_SCALAR else route


class SearchService:
    """Deadline-aware, bucketed, batched proximity-search serving over
    a static ``ProximityIndex`` or a snapshot-able incremental index
    (``repro.index.SegmentedIndex``).

    Serving always runs against an *immutable* searcher snapshot: a
    drain pins the snapshot once, so in-flight batches see a consistent
    view even while the indexer seals memtables and runs background
    merges; :meth:`refresh` picks up the indexer's latest published
    snapshot. Each request is routed by the pure planner per the
    DESIGN.md §13 dispatch matrix, grouped per (step family, L-bucket)
    — with ``share_buckets``, qt34 and qt5 traffic batch together on
    the qt5 executables — padded to the power-of-two batch ladder, and
    served earliest-deadline-group first; shapes the static steps
    cannot express take the scalar engine, so results are always
    exact. :meth:`explain` returns the plan without executing.

    Hot-path machinery under the facade is unchanged from DESIGN.md
    §11-§13: the packed-posting row caches (snapshot-identity
    invalidation, add-only retention), the per-key compressed-row
    cache, and the compiled per-(kind, B, L) executable table now owned
    by :class:`CompiledExecutor`."""

    def __init__(self, index, mesh, config: ServeConfig | None = None):
        self.config = config if config is not None else ServeConfig()
        self._source = index if hasattr(index, "snapshot") else None
        self.index = index.snapshot() if self._source is not None else index
        if self.config.compressed and getattr(self.index, "max_distance", 0) > 254:
            # all compressed formats carry fragment bounds / NSW offsets
            # as uint8 distances; beyond 254 they would silently clip
            raise ValueError(
                "compressed serving requires max_distance <= 254 "
                f"(got {self.index.max_distance})"
            )
        self.mesh = mesh
        cfg = self.config
        # §15 observability tier: one registry + tracer per service,
        # shared by the executors and both row caches so every layer's
        # timings land in the same place
        self.metrics = MetricsRegistry(histogram_capacity=cfg.metrics_capacity)
        self.tracer = Tracer(capacity=cfg.trace_capacity,
                             enabled=cfg.trace_enabled)
        self.pack_cache = (
            PackedPostingCache(max_entries=cfg.cache_entries,
                               max_bytes=cfg.cache_bytes,
                               metrics=self.metrics, scope="cache.pack")
            if cfg.use_pack_cache
            else None
        )
        # per-key compressed rows derive from (and sit beside) the raw
        # row cache; without it every warm compressed drain re-runs the
        # O(B·K·L) host delta encoding
        self.compressed_cache = (
            PackedPostingCache(max_entries=cfg.cache_entries,
                               max_bytes=cfg.cache_bytes,
                               source=self.pack_cache,
                               metrics=self.metrics,
                               scope="cache.compressed")
            if cfg.compressed and cfg.use_compressed_cache
            else None
        )
        # measured payload arbitration (DESIGN.md §16): only meaningful
        # when two payload arms exist, i.e. on a compressed engine
        self.payload_costs = (
            PayloadCostModel()
            if cfg.compressed and cfg.payload_cost_driven else None
        )
        self.compiled = CompiledExecutor(
            mesh, cfg, pack_cache=self.pack_cache,
            compressed_cache=self.compressed_cache,
            metrics=self.metrics, tracer=self.tracer,
            costs=self.payload_costs,
        )
        self.scalar = ScalarExecutor(cfg, metrics=self.metrics,
                                     tracer=self.tracer)
        self._queue: list[SearchTicket] = []
        self._queue_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        # per-snapshot lemma ids -> QueryPlan; validity is tied to the
        # *pinned view's identity* (not to refresh() clearing it: a
        # drain racing a refresh could otherwise re-insert a stale
        # entry after the clear). Bounded: a high-cardinality query
        # stream over a static index never refreshes, so the memo is
        # cleared wholesale at the cap (rebuilding an entry is one
        # n_postings scan per key)
        self._plan_memo: dict[tuple, QueryPlan] = {}
        self._plan_memo_view = None
        self._plan_memo_gen = 0
        self._plan_memo_cap = 65536
        self.stats = {
            "batches": 0, "requests": 0, "refreshes": 0,
            "compressed_batches": 0, "offset_fallbacks": 0,
            "bucket_hist": {b: 0 for b in cfg.buckets},
            "paths": {"qt1": 0, "qt2": 0, "qt34": 0, "qt5": 0,
                      "cpu": 0, "empty": 0},
            "plans": {
                "routes": {r: 0 for r in (*_planner.COMPILED_ROUTES,
                                          _planner.ROUTE_SCALAR,
                                          _planner.ROUTE_EMPTY)},
                "fallbacks": {},
                "executables": 0,
                "shared_batches": 0,
                "est_vs_measured": {},
            },
            "deadlines": {"met": 0, "missed": 0, "unset": 0,
                          "miss_blame": {}},
            "pack_cache": {}, "compressed_cache": {},
        }

    # -- planning ----------------------------------------------------------
    def _plan(self, index, lemma_ids) -> QueryPlan:
        # validity is (snapshot identity, cost-model generation): a
        # payload-choice flip bumps the generation, so memoized plans
        # can never pin a stale payload
        gen = (self.payload_costs.generation
               if self.payload_costs is not None else 0)
        if index is not self._plan_memo_view or gen != self._plan_memo_gen:
            # the scalar executor tracks snapshot identity itself
            self._plan_memo = {}
            self._plan_memo_view = index
            self._plan_memo_gen = gen
        memo_key = tuple(lemma_ids)
        p = self._plan_memo.get(memo_key)
        if p is not None:
            return p
        p = _planner.plan(list(lemma_ids), index, self.config,
                          costs=self.payload_costs)
        if len(self._plan_memo) >= self._plan_memo_cap:
            self._plan_memo.clear()
        self._plan_memo[memo_key] = p
        return p

    def explain(self, lemma_ids, costs: bool = False) -> QueryPlan:
        """The :class:`QueryPlan` this request would execute under —
        route, executable family, L-bucket, payload, estimated step
        cost, fallback reason — without executing anything. Planned
        against the currently pinned snapshot with the same memo the
        next drain will use, so ``explain(q)`` and the executed
        ``response.plan`` agree (tests/test_planner.py pins this per
        dispatch-matrix row).

        With ``costs=True`` the returned plan additionally carries
        ``measured`` — the §15 calibration record for the same
        (step_family, L-bucket) executable family: per-B measured
        run-time percentiles from the live ``serve.step.*`` histograms,
        the first-call compile time, the XLA ``cost_analysis()``
        summary, and ``us_per_kslot`` (measured p50 per thousand
        ``est_step_cost`` slots — the est-vs-measured ratio). The
        cost-annotated plan is a fresh object (the memoized plan stays
        identity-stable); ``measured`` is None off-device or before any
        warm batch of the shape has run."""
        p = self._plan(self.index, lemma_ids)
        if not costs:
            return p
        measured = None
        if p.is_compiled:
            table = self.compiled.measured_cost(p.step_family, p.bucket)
            if table:
                est = p.est_step_cost
                for entry in table.values():
                    entry["us_per_kslot"] = (
                        entry["measured_p50_us"] / (est / 1000.0)
                    )
                measured = {"est_step_cost": est, "executables": table}
        return dataclasses.replace(p, measured=measured)

    # -- lifecycle ---------------------------------------------------------
    def refresh(self) -> None:
        """Pick up the indexer's latest published snapshot.

        A no-op when serving a static ``ProximityIndex``; for a
        ``repro.index.SegmentedIndex`` source this swaps in the newest
        immutable ``SegmentedView``, making documents added or deleted
        since the previous refresh visible to subsequent drains.
        Already in-flight drains keep the snapshot they pinned. The
        compiled executable table is reused across refreshes (only the
        host-side packing sees the new postings); plans are re-derived
        lazily, and the row caches invalidate themselves on the first
        lookup against the new snapshot — entries are keyed by snapshot
        identity, and add-only refreshes retain untouched keys
        (DESIGN.md §12)."""
        if self._source is not None:
            self.index = self._source.snapshot()
            self.stats["refreshes"] += 1

    # -- serving -----------------------------------------------------------
    def submit(self, lemma_ids, deadline_s: float | None = None) -> SearchTicket:
        """Queue one request (a lemma-id list, i.e. one sub-query of
        ``core.query.build_subqueries``) for the next :meth:`drain`;
        returns its :class:`SearchTicket`. ``deadline_s`` is a budget
        from *now* (submission): the resolving drain reports
        ``deadline_met`` per response and prioritizes
        tighter-deadline groups. Thread-safe and non-blocking — no
        planning, packing or device work happens until the batcher
        cuts a batch."""
        if deadline_s is None:
            deadline_s = self.config.default_deadline_s
        ticket = SearchTicket(list(lemma_ids), deadline_s=deadline_s)
        with self._queue_lock:
            self._queue.append(ticket)
        return ticket

    def drain(self) -> list[SearchResponse]:
        """Serve everything queued, resolving every pending ticket and
        returning one :class:`SearchResponse` per request **in
        submission order**.

        The snapshot is pinned once for the whole drain. Requests are
        planned (memoized per lemma-id tuple per snapshot), grouped per
        (step family, L-bucket) — so with ``share_buckets`` qt34 and
        qt5 requests batch together — padded to the power-of-two batch
        ladder, and groups are served earliest-deadline first
        (deadline-less groups follow, largest first). Each response
        carries its plan, executed path, bucket, batch size, wall-clock
        batch latency, queue wait and deadline verdict."""
        if not self._queue:
            return []
        index = self.index
        # swap the queue out under the submit lock BEFORE grouping: a
        # submit() racing this drain either lands before the swap (and
        # is served now) or after it (and stays queued) — never
        # silently dropped into the already-grouped list
        with self._queue_lock:
            pending, self._queue = self._queue, []
        t_drain0 = time.perf_counter()
        slots: list = [None] * len(pending)
        with self.tracer.span("drain", requests=len(pending)):
            # per-request planning time is part of the phase breakdown
            # (memoized hits are sub-µs; misses scan posting counts)
            plans, plan_s = [], []
            with self.tracer.span("plan", n=len(pending)):
                for t in pending:
                    tp0 = time.perf_counter()
                    plans.append(self._plan(index, t.lemma_ids))
                    plan_s.append(time.perf_counter() - tp0)
            with self.tracer.span("group"):
                groups: dict[tuple, list[int]] = {}
                for i, p in enumerate(plans):
                    if p.route == _planner.ROUTE_EMPTY:
                        key = ("empty", None)
                    elif p.route == _planner.ROUTE_SCALAR:
                        key = ("scalar", None)
                    else:
                        key = (p.step_family, p.bucket)
                    groups.setdefault(key, []).append(i)

                def urgency(item):
                    _, idxs = item
                    deadline = min(
                        (pending[i].arrival + pending[i].deadline_s
                         for i in idxs if pending[i].deadline_s is not None),
                        default=float("inf"),
                    )
                    return (deadline, -len(idxs))

                order = sorted(groups.items(), key=urgency)

            for (family, bucket), idxs in order:
                if family == "empty":
                    now = time.perf_counter()
                    for i in idxs:
                        self._resolve(
                            pending[i], plans[i], slots, i,
                            ExecResult(results=empty_results(), latency_s=0.0,
                                       bucket=0, batch_size=1, started_at=now,
                                       finished_at=now),
                            plan_s[i],
                        )
                    continue
                queries = [pending[i].lemma_ids for i in idxs]
                if family == "scalar":
                    execs = self.scalar.execute(index, queries,
                                                [None] * len(idxs),
                                                step_family=None, bucket=None)
                else:
                    sels = [self._selection_for(plans[i], family) for i in idxs]
                    shared = [plans[i].route != family for i in idxs]
                    # one payload per (family, bucket) group: all its
                    # plans were routed under the same cost-model state
                    execs = self.compiled.execute(index, queries, sels,
                                                  step_family=family,
                                                  bucket=bucket, shared=shared,
                                                  payload=plans[idxs[0]].payload)
                    if bucket in self.stats["bucket_hist"]:
                        mb = self.config.max_batch
                        with self._stats_lock:
                            self.stats["bucket_hist"][bucket] += (
                                -(-len(idxs) // mb)
                            )
                for i, ex in zip(idxs, execs):
                    self._resolve(pending[i], plans[i], slots, i, ex,
                                  plan_s[i])
        self.metrics.observe(
            "serve.drain.total",
            (time.perf_counter() - t_drain0) * 1e6,
        )
        self._finish_stats(plans)
        return slots

    @staticmethod
    def _selection_for(p: QueryPlan, family: str):
        """Packer-ready key selection: a qt34 plan riding the qt5 step
        becomes a zero-stop qt5 plan (anchor, others, (), counts)."""
        if p.route == _planner.ROUTE_QT34 and family == _planner.ROUTE_QT5:
            anchor, others, counts = p.selection
            return anchor, others, (), counts
        return p.selection

    def _resolve(self, ticket, p: QueryPlan, slots, i, ex: ExecResult,
                 plan_dt: float = 0.0) -> None:
        # deadline and queue wait are judged against *this request's
        # batch* (its ExecResult timestamps), not the whole group — in a
        # multi-chunk group, earlier chunks resolve earlier
        queue_wait = max(ex.started_at - ticket.arrival, 0.0)
        # the per-request phase breakdown (§15): queue + plan + the
        # batch phases. The batch phases tile [started_at, finished_at]
        # and queue tiles [arrival, started_at], so the values sum to
        # the end-to-end latency (plan overlaps the queue window but is
        # orders of magnitude smaller; tests pin agreement within 10%)
        phases = {"queue": queue_wait, "plan": plan_dt}
        phases.update(ex.phases if ex.phases else zero_phases())
        met = None
        blame = None
        e2e = ex.finished_at - ticket.arrival
        if ticket.deadline_s is not None:
            met = e2e <= ticket.deadline_s
            if not met:
                # name the phase that blew the budget: queue when
                # waiting alone exceeded it, else the slowest work phase
                if queue_wait > ticket.deadline_s:
                    blame = "queue"
                else:
                    blame = max(
                        (ph for ph in phases if ph != "queue"),
                        key=lambda ph: phases[ph],
                    )
            with self._stats_lock:
                dl = self.stats["deadlines"]
                dl["met" if met else "missed"] += 1
                if blame is not None:
                    dl["miss_blame"][blame] = (
                        dl["miss_blame"].get(blame, 0) + 1
                    )
        else:
            with self._stats_lock:
                self.stats["deadlines"]["unset"] += 1
        m = self.metrics
        for name, dur in phases.items():
            m.observe(f"serve.phase.{name}", dur * 1e6)
        m.observe("serve.request.e2e", e2e * 1e6)
        if blame is not None:
            m.inc(f"serve.deadline.miss_blame.{blame}")
        executed = p if ex.payload in (None, p.payload) \
            else dataclasses.replace(p, payload=ex.payload)
        resp = SearchResponse(
            results=ex.results, latency_s=ex.latency_s, bucket=ex.bucket,
            batch_size=ex.batch_size, path=_route_to_path(p.route),
            plan=executed, deadline_met=met, queue_wait_s=queue_wait,
            phases=phases, started_at=ex.started_at,
            finished_at=ex.finished_at, deadline_blame=blame,
        )
        ticket.response = resp
        slots[i] = resp

    def _finish_stats(self, plans: list[QueryPlan]) -> None:
        ex = self.compiled
        est_vs_measured = ex.est_vs_measured(_planner._streams)
        pack_stats = (self.pack_cache.stats
                      if self.pack_cache is not None else None)
        comp_stats = (self.compressed_cache.stats
                      if self.compressed_cache is not None else None)
        with self._stats_lock:
            st = self.stats
            st["requests"] += len(plans)
            routes = st["plans"]["routes"]
            for p in plans:
                routes[p.route] = routes.get(p.route, 0) + 1
                st["paths"][_route_to_path(p.route)] += 1
                if p.fallback_reason is not None:
                    fb = st["plans"]["fallbacks"]
                    fb[p.fallback_reason] = fb.get(p.fallback_reason, 0) + 1
            st["batches"] = ex.stats["batches"]
            st["compressed_batches"] = ex.stats["compressed_batches"]
            st["offset_fallbacks"] = ex.stats["offset_fallbacks"]
            st["plans"]["executables"] = ex.n_executables
            st["plans"]["shared_batches"] = ex.stats["shared_batches"]
            st["plans"]["est_vs_measured"] = est_vs_measured
            if self.payload_costs is not None:
                st["plans"]["payload_costs"] = self.payload_costs.table()
            if pack_stats is not None:
                st["pack_cache"] = pack_stats
            if comp_stats is not None:
                st["compressed_cache"] = comp_stats

    # -- observability (DESIGN.md §15) -------------------------------------
    def stats_snapshot(self) -> dict:
        """A deep, consistent copy of :attr:`stats`, with the cache
        stats re-read fresh. ``stats`` itself is mutated in place during
        :meth:`drain` — a concurrent reader iterating it can see
        half-updated counters (or hit a dict-size-changed error); this
        snapshot is taken under the same lock the mutators hold, so the
        counters in one snapshot are mutually consistent. Benchmarks and
        examples read this, never ``stats`` directly."""
        with self._stats_lock:
            snap = copy.deepcopy(self.stats)
        # cache stats properties already return fresh dicts under the
        # cache's own lock; re-read them so the snapshot is current even
        # between drains
        if self.pack_cache is not None:
            snap["pack_cache"] = self.pack_cache.stats
        if self.compressed_cache is not None:
            snap["compressed_cache"] = self.compressed_cache.stats
        return snap

    def metrics_snapshot(self, prefix: str = "") -> dict:
        """Plain-data snapshot of the metrics registry (counters,
        gauges, histogram percentiles) — ``prefix`` filters by dotted
        name (``"serve.phase."`` for the request phase breakdown)."""
        return self.metrics.snapshot(prefix)

    def trace_snapshot(self) -> dict:
        """The recorded span buffer as a Chrome JSON trace object —
        ``json.dump`` it and load the file in https://ui.perfetto.dev
        (or pass ``--trace-out`` to ``launch/serve.py`` /
        ``examples/serve_search.py``). One span tree per drain:
        ``drain`` → ``plan`` / ``group`` / per-batch ``batch`` →
        ``pack``/``compress``/``compile``/``dispatch``/``execute``/
        ``decode``."""
        return chrome_trace(self.tracer.snapshot())

    def write_trace(self, path: str) -> dict:
        """Write :meth:`trace_snapshot` to ``path``; returns the trace
        object (callers report event counts)."""
        return write_chrome_trace(path, self.tracer.snapshot())
