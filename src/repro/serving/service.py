"""SearchService: the deadline-aware serving facade (DESIGN.md §14).

The serving tier is three explicit layers — this module is the top one:

* :mod:`repro.serving.planner` — pure per-query routing
  (``plan(request, snapshot, config) -> QueryPlan``);
* :mod:`repro.serving.executors` — ``CompiledExecutor`` (serve-step
  factories + the shared per-(kind, B, L) executable table) and
  ``ScalarExecutor`` behind one protocol;
* :class:`SearchService` — submit/drain/refresh/explain over one
  :class:`ServeConfig`, replacing the fifteen positional knobs of the
  old monolithic engine.

``submit(lemma_ids, deadline_s=...)`` returns a :class:`SearchTicket`
resolved by the next :meth:`SearchService.drain`; every
:class:`SearchResponse` carries the :class:`QueryPlan` that routed it,
whether its deadline was met, and how long it waited in the queue —
the paper's response-time guarantee as an observable, per-request
contract instead of an implicit property of a compiled step.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from dataclasses import dataclass, field

from repro.serving import planner as _planner
from repro.serving.executors import (
    CompiledExecutor,
    ExecResult,
    ScalarExecutor,
    empty_results,
)
from repro.serving.pack_cache import PackedPostingCache
from repro.serving.planner import QueryPlan


@dataclass(frozen=True)
class ServeConfig:
    """Every serving knob in one (frozen, reusable) place.

    * ``buckets`` — the L-bucket ladder posting rows are padded to; one
      compiled executable exists per (step kind, B-bucket, L-bucket);
    * ``max_batch`` / ``top_k`` / ``doc_shards`` — batch cap, results
      per query, model-axis doc shards;
    * ``compressed`` — serve the block-delta16 device payload
      (DESIGN.md §11-§12) with per-batch offsets fallback;
    * ``use_pack_cache`` / ``use_compressed_cache`` / ``cache_entries``
      / ``cache_bytes`` — the packed-posting row caches;
    * ``k_fst``/``k_wv``/``k_ns``/``k_st``/``k_ord``/``r_max`` — static
      key/constraint capacities of the compiled steps (the dispatch
      matrix's fallback thresholds, DESIGN.md §13);
    * ``share_buckets`` — dispatch-aware batching: qt34 groups whose
      plans fit the QT5 step's non-stop slots ride the qt5 executable
      of the same (B, L), and are batched together with qt5 traffic
      (DESIGN.md §14);
    * ``default_deadline_s`` — deadline attached to submits that don't
      pass one (None = no deadline)."""

    buckets: tuple = (1024, 4096, 16384, 65536)
    max_batch: int = 64
    top_k: int = 16
    doc_shards: int = 1
    compressed: bool = False
    use_pack_cache: bool = True
    use_compressed_cache: bool = True
    cache_entries: int = 4096
    cache_bytes: int = 256 << 20
    k_fst: int = 2
    k_wv: int = 3
    k_ns: int = 3
    k_st: int = 3
    k_ord: int = 4
    r_max: int = 4
    share_buckets: bool = True
    default_deadline_s: float | None = None

    def __post_init__(self):
        object.__setattr__(self, "buckets", tuple(sorted(self.buckets)))


@dataclass
class SearchRequest:
    """Import-compatibility symbol only: no code path constructs it —
    the serving queue holds :class:`SearchTicket` records now. Deleted
    together with the :class:`SearchServingEngine` shim."""

    lemma_ids: list
    arrival: float = field(default_factory=time.perf_counter)


@dataclass
class SearchTicket:
    """Future-like handle returned by :meth:`SearchService.submit`,
    resolved in place by the next :meth:`SearchService.drain` (there is
    no background thread — resolution is the drain that serves it)."""

    lemma_ids: list
    deadline_s: float | None = None
    arrival: float = field(default_factory=time.perf_counter)
    response: "SearchResponse | None" = None

    @property
    def done(self) -> bool:
        return self.response is not None

    def result(self) -> "SearchResponse":
        if self.response is None:
            raise RuntimeError("ticket not resolved yet — call drain()")
        return self.response


@dataclass
class SearchResponse:
    """One served request: the results plus the serving contract —
    ``plan`` is the :class:`QueryPlan` that routed it (its ``payload``
    reflects the format actually executed), ``deadline_met`` whether
    resolution beat the ticket's budget (None when no deadline was
    set), ``queue_wait_s`` the time between submit and its batch
    starting execution."""

    results: dict
    latency_s: float
    bucket: int
    batch_size: int
    path: str = "qt1"
    plan: QueryPlan | None = None
    deadline_met: bool | None = None
    queue_wait_s: float = 0.0


def _route_to_path(route: str) -> str:
    """Plan routes -> the executed-path names of ``stats["paths"]``
    (the pre-planner vocabulary: the scalar route reports as "cpu")."""
    return "cpu" if route == _planner.ROUTE_SCALAR else route


class SearchService:
    """Deadline-aware, bucketed, batched proximity-search serving over
    a static ``ProximityIndex`` or a snapshot-able incremental index
    (``repro.index.SegmentedIndex``).

    Serving always runs against an *immutable* searcher snapshot: a
    drain pins the snapshot once, so in-flight batches see a consistent
    view even while the indexer seals memtables and runs background
    merges; :meth:`refresh` picks up the indexer's latest published
    snapshot. Each request is routed by the pure planner per the
    DESIGN.md §13 dispatch matrix, grouped per (step family, L-bucket)
    — with ``share_buckets``, qt34 and qt5 traffic batch together on
    the qt5 executables — padded to the power-of-two batch ladder, and
    served earliest-deadline-group first; shapes the static steps
    cannot express take the scalar engine, so results are always
    exact. :meth:`explain` returns the plan without executing.

    Hot-path machinery under the facade is unchanged from DESIGN.md
    §11-§13: the packed-posting row caches (snapshot-identity
    invalidation, add-only retention), the per-key compressed-row
    cache, and the compiled per-(kind, B, L) executable table now owned
    by :class:`CompiledExecutor`."""

    def __init__(self, index, mesh, config: ServeConfig | None = None):
        self.config = config if config is not None else ServeConfig()
        self._source = index if hasattr(index, "snapshot") else None
        self.index = index.snapshot() if self._source is not None else index
        if self.config.compressed and getattr(self.index, "max_distance", 0) > 254:
            # all compressed formats carry fragment bounds / NSW offsets
            # as uint8 distances; beyond 254 they would silently clip
            raise ValueError(
                "compressed serving requires max_distance <= 254 "
                f"(got {self.index.max_distance})"
            )
        self.mesh = mesh
        cfg = self.config
        self.pack_cache = (
            PackedPostingCache(max_entries=cfg.cache_entries,
                               max_bytes=cfg.cache_bytes)
            if cfg.use_pack_cache
            else None
        )
        # per-key compressed rows derive from (and sit beside) the raw
        # row cache; without it every warm compressed drain re-runs the
        # O(B·K·L) host delta encoding
        self.compressed_cache = (
            PackedPostingCache(max_entries=cfg.cache_entries,
                               max_bytes=cfg.cache_bytes,
                               source=self.pack_cache)
            if cfg.compressed and cfg.use_compressed_cache
            else None
        )
        self.compiled = CompiledExecutor(
            mesh, cfg, pack_cache=self.pack_cache,
            compressed_cache=self.compressed_cache,
        )
        self.scalar = ScalarExecutor(cfg)
        self._queue: list[SearchTicket] = []
        self._queue_lock = threading.Lock()
        # per-snapshot lemma ids -> QueryPlan; validity is tied to the
        # *pinned view's identity* (not to refresh() clearing it: a
        # drain racing a refresh could otherwise re-insert a stale
        # entry after the clear). Bounded: a high-cardinality query
        # stream over a static index never refreshes, so the memo is
        # cleared wholesale at the cap (rebuilding an entry is one
        # n_postings scan per key)
        self._plan_memo: dict[tuple, QueryPlan] = {}
        self._plan_memo_view = None
        self._plan_memo_cap = 65536
        self.stats = {
            "batches": 0, "requests": 0, "refreshes": 0,
            "compressed_batches": 0, "offset_fallbacks": 0,
            "bucket_hist": {b: 0 for b in cfg.buckets},
            "paths": {"qt1": 0, "qt2": 0, "qt34": 0, "qt5": 0,
                      "cpu": 0, "empty": 0},
            "plans": {
                "routes": {r: 0 for r in (*_planner.COMPILED_ROUTES,
                                          _planner.ROUTE_SCALAR,
                                          _planner.ROUTE_EMPTY)},
                "fallbacks": {},
                "executables": 0,
                "shared_batches": 0,
            },
            "deadlines": {"met": 0, "missed": 0, "unset": 0},
            "pack_cache": {}, "compressed_cache": {},
        }

    # -- planning ----------------------------------------------------------
    def _plan(self, index, lemma_ids) -> QueryPlan:
        if index is not self._plan_memo_view:
            # the scalar executor tracks snapshot identity itself
            self._plan_memo = {}
            self._plan_memo_view = index
        memo_key = tuple(lemma_ids)
        p = self._plan_memo.get(memo_key)
        if p is not None:
            return p
        p = _planner.plan(list(lemma_ids), index, self.config)
        if len(self._plan_memo) >= self._plan_memo_cap:
            self._plan_memo.clear()
        self._plan_memo[memo_key] = p
        return p

    def explain(self, lemma_ids) -> QueryPlan:
        """The :class:`QueryPlan` this request would execute under —
        route, executable family, L-bucket, payload, estimated step
        cost, fallback reason — without executing anything. Planned
        against the currently pinned snapshot with the same memo the
        next drain will use, so ``explain(q)`` and the executed
        ``response.plan`` agree (tests/test_planner.py pins this per
        dispatch-matrix row)."""
        return self._plan(self.index, lemma_ids)

    # -- lifecycle ---------------------------------------------------------
    def refresh(self) -> None:
        """Pick up the indexer's latest published snapshot.

        A no-op when serving a static ``ProximityIndex``; for a
        ``repro.index.SegmentedIndex`` source this swaps in the newest
        immutable ``SegmentedView``, making documents added or deleted
        since the previous refresh visible to subsequent drains.
        Already in-flight drains keep the snapshot they pinned. The
        compiled executable table is reused across refreshes (only the
        host-side packing sees the new postings); plans are re-derived
        lazily, and the row caches invalidate themselves on the first
        lookup against the new snapshot — entries are keyed by snapshot
        identity, and add-only refreshes retain untouched keys
        (DESIGN.md §12)."""
        if self._source is not None:
            self.index = self._source.snapshot()
            self.stats["refreshes"] += 1

    # -- serving -----------------------------------------------------------
    def submit(self, lemma_ids, deadline_s: float | None = None) -> SearchTicket:
        """Queue one request (a lemma-id list, i.e. one sub-query of
        ``core.query.build_subqueries``) for the next :meth:`drain`;
        returns its :class:`SearchTicket`. ``deadline_s`` is a budget
        from *now* (submission): the resolving drain reports
        ``deadline_met`` per response and prioritizes
        tighter-deadline groups. Thread-safe and non-blocking — no
        planning, packing or device work happens until the batcher
        cuts a batch."""
        if deadline_s is None:
            deadline_s = self.config.default_deadline_s
        ticket = SearchTicket(list(lemma_ids), deadline_s=deadline_s)
        with self._queue_lock:
            self._queue.append(ticket)
        return ticket

    def drain(self) -> list[SearchResponse]:
        """Serve everything queued, resolving every pending ticket and
        returning one :class:`SearchResponse` per request **in
        submission order**.

        The snapshot is pinned once for the whole drain. Requests are
        planned (memoized per lemma-id tuple per snapshot), grouped per
        (step family, L-bucket) — so with ``share_buckets`` qt34 and
        qt5 requests batch together — padded to the power-of-two batch
        ladder, and groups are served earliest-deadline first
        (deadline-less groups follow, largest first). Each response
        carries its plan, executed path, bucket, batch size, wall-clock
        batch latency, queue wait and deadline verdict."""
        if not self._queue:
            return []
        index = self.index
        # swap the queue out under the submit lock BEFORE grouping: a
        # submit() racing this drain either lands before the swap (and
        # is served now) or after it (and stays queued) — never
        # silently dropped into the already-grouped list
        with self._queue_lock:
            pending, self._queue = self._queue, []
        slots: list = [None] * len(pending)
        plans = [self._plan(index, t.lemma_ids) for t in pending]
        groups: dict[tuple, list[int]] = {}
        for i, p in enumerate(plans):
            if p.route == _planner.ROUTE_EMPTY:
                key = ("empty", None)
            elif p.route == _planner.ROUTE_SCALAR:
                key = ("scalar", None)
            else:
                key = (p.step_family, p.bucket)
            groups.setdefault(key, []).append(i)

        def urgency(item):
            _, idxs = item
            deadline = min(
                (pending[i].arrival + pending[i].deadline_s
                 for i in idxs if pending[i].deadline_s is not None),
                default=float("inf"),
            )
            return (deadline, -len(idxs))

        for (family, bucket), idxs in sorted(groups.items(), key=urgency):
            if family == "empty":
                now = time.perf_counter()
                for i in idxs:
                    self._resolve(pending[i], plans[i], slots, i, ExecResult(
                        results=empty_results(), latency_s=0.0, bucket=0,
                        batch_size=1, started_at=now, finished_at=now,
                    ))
                continue
            queries = [pending[i].lemma_ids for i in idxs]
            if family == "scalar":
                execs = self.scalar.execute(index, queries,
                                            [None] * len(idxs),
                                            step_family=None, bucket=None)
            else:
                sels = [self._selection_for(plans[i], family) for i in idxs]
                shared = [plans[i].route != family for i in idxs]
                execs = self.compiled.execute(index, queries, sels,
                                              step_family=family,
                                              bucket=bucket, shared=shared)
                if bucket in self.stats["bucket_hist"]:
                    mb = self.config.max_batch
                    self.stats["bucket_hist"][bucket] += -(-len(idxs) // mb)
            for i, ex in zip(idxs, execs):
                self._resolve(pending[i], plans[i], slots, i, ex)
        self._finish_stats(plans)
        return slots

    @staticmethod
    def _selection_for(p: QueryPlan, family: str):
        """Packer-ready key selection: a qt34 plan riding the qt5 step
        becomes a zero-stop qt5 plan (anchor, others, (), counts)."""
        if p.route == _planner.ROUTE_QT34 and family == _planner.ROUTE_QT5:
            anchor, others, counts = p.selection
            return anchor, others, (), counts
        return p.selection

    def _resolve(self, ticket, p: QueryPlan, slots, i, ex: ExecResult) -> None:
        # deadline and queue wait are judged against *this request's
        # batch* (its ExecResult timestamps), not the whole group — in a
        # multi-chunk group, earlier chunks resolve earlier
        met = None
        if ticket.deadline_s is not None:
            met = (ex.finished_at - ticket.arrival) <= ticket.deadline_s
            self.stats["deadlines"]["met" if met else "missed"] += 1
        else:
            self.stats["deadlines"]["unset"] += 1
        executed = p if ex.payload in (None, p.payload) \
            else dataclasses.replace(p, payload=ex.payload)
        resp = SearchResponse(
            results=ex.results, latency_s=ex.latency_s, bucket=ex.bucket,
            batch_size=ex.batch_size, path=_route_to_path(p.route),
            plan=executed, deadline_met=met,
            queue_wait_s=max(ex.started_at - ticket.arrival, 0.0),
        )
        ticket.response = resp
        slots[i] = resp

    def _finish_stats(self, plans: list[QueryPlan]) -> None:
        st = self.stats
        st["requests"] += len(plans)
        routes = st["plans"]["routes"]
        for p in plans:
            routes[p.route] = routes.get(p.route, 0) + 1
            st["paths"][_route_to_path(p.route)] += 1
            if p.fallback_reason is not None:
                fb = st["plans"]["fallbacks"]
                fb[p.fallback_reason] = fb.get(p.fallback_reason, 0) + 1
        ex = self.compiled
        st["batches"] = ex.stats["batches"]
        st["compressed_batches"] = ex.stats["compressed_batches"]
        st["offset_fallbacks"] = ex.stats["offset_fallbacks"]
        st["plans"]["executables"] = ex.n_executables
        st["plans"]["shared_batches"] = ex.stats["shared_batches"]
        if self.pack_cache is not None:
            st["pack_cache"] = self.pack_cache.stats
        if self.compressed_cache is not None:
            st["compressed_cache"] = self.compressed_cache.stats
