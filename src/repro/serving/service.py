"""SearchService: the deadline-aware serving facade (DESIGN.md §14).

The serving tier is three explicit layers — this module is the top one:

* :mod:`repro.serving.planner` — pure per-query routing
  (``plan(request, snapshot, config) -> QueryPlan``);
* :mod:`repro.serving.executors` — ``CompiledExecutor`` (serve-step
  factories + the shared per-(kind, B, L) executable table) and
  ``ScalarExecutor`` behind one protocol;
* :class:`SearchService` — submit/drain/refresh/explain over one
  :class:`ServeConfig`, replacing the fifteen positional knobs of the
  old monolithic engine.

``submit(lemma_ids, deadline_s=...)`` returns a :class:`SearchTicket`
resolved by the next :meth:`SearchService.drain`; every
:class:`SearchResponse` carries the :class:`QueryPlan` that routed it,
whether its deadline was met, and how long it waited in the queue —
the paper's response-time guarantee as an observable, per-request
contract instead of an implicit property of a compiled step.
"""

from __future__ import annotations

import copy
import dataclasses
import threading
import time
from dataclasses import dataclass, field

from repro.core.jax_search import batch_size_bucket
from repro.obs import MetricsRegistry, Tracer, chrome_trace, write_chrome_trace
from repro.serving import planner as _planner
from repro.serving.admission import (
    ADMIT,
    BLAME_INFEASIBLE,
    BLAME_SHED,
    DEGRADE,
    REASON_OPTIMISTIC,
    REJECT_INFEASIBLE,
    STATUS_DEGRADED,
    STATUS_OK,
    STATUS_REJECTED,
    STATUS_SHED,
    AdmissionController,
    AdmissionVerdict,
)
from repro.serving.executors import (
    CompiledExecutor,
    ExecResult,
    ScalarExecutor,
    empty_results,
    zero_phases,
)
from repro.serving.costs import (
    PayloadCostModel,
    RecallCostModel,
    StepCostPredictor,
)
from repro.serving.pack_cache import PackedPostingCache
from repro.serving.planner import QueryPlan


@dataclass(frozen=True)
class ServeConfig:
    """Every serving knob in one (frozen, reusable) place.

    * ``buckets`` — the L-bucket ladder posting rows are padded to; one
      compiled executable exists per (step kind, B-bucket, L-bucket);
    * ``max_batch`` / ``top_k`` / ``doc_shards`` — batch cap, results
      per query, model-axis doc shards;
    * ``compressed`` — serve the block-delta16 device payload
      (DESIGN.md §11-§12) with per-batch offsets fallback;
    * ``use_pack_cache`` / ``use_compressed_cache`` / ``cache_entries``
      / ``cache_bytes`` — the packed-posting row caches;
    * ``k_fst``/``k_wv``/``k_ns``/``k_st``/``k_ord``/``r_max`` — static
      key/constraint capacities of the compiled steps (the dispatch
      matrix's fallback thresholds, DESIGN.md §13);
    * ``share_buckets`` — dispatch-aware batching: qt34 groups whose
      plans fit the QT5 step's non-stop slots ride the qt5 executable
      of the same (B, L), and are batched together with qt5 traffic
      (DESIGN.md §14);
    * ``payload_cost_driven`` — arbitrate each compressed group's
      payload (raw vs the static delta16/offsets rule) per
      (step_family, L-bucket) from measured warm batch time
      (DESIGN.md §16); no effect on an uncompressed engine;
    * ``use_pallas`` — route the qt34/qt5 window join through the
      fused Pallas nearest-r kernel (TPU; interpret-mode on CPU is for
      validation only — the default lax counting join is the CPU fast
      path, DESIGN.md §16);
    * ``default_deadline_s`` — deadline attached to submits that don't
      pass one (None = no deadline);
    * ``admission`` — the §17 deadline control loop: ``submit()``
      consults an :class:`repro.serving.admission.AdmissionController`
      per deadline-carrying request, fast-rejecting infeasible budgets,
      degrading over-budget plans to a truncated-prefix route and
      shedding predicted-miss traffic while overloaded (default off:
      without it deadlines are measured, never enforced);
    * ``max_queue`` — bounded submit queue (admission engines only):
      past the bound the deadline-aware drop policy sheds the queued
      request that is already predicted infeasible, or the newcomer
      when every queued request is still feasible — never the FIFO
      head;
    * ``degrade`` — allow the admission controller to reroute an
      over-budget compiled plan to a smaller bucket
      (``planner.degrade``) instead of rejecting it outright;
    * ``split_budget`` / ``split_max_urgent`` — EDF group splitting
      (§17): max split dispatches per drain (0 disables) and max size
      of one urgent sub-batch;
    * ``shed_enter_s`` / ``shed_exit_s`` — overload hysteresis
      thresholds on the (EWMA-smoothed) predicted backlog (enter >
      exit, so transient bursts cannot flap the shed decision);
    * ``admit_margin`` / ``admit_optimism`` — the controller's reserve
      policy: admit when predicted completion fits ``margin ×`` the
      budget (the reserve absorbs work admitted later that lands
      ahead), optimistically up to ``optimism ×`` that bound while not
      latched overloaded;
    * ``adaptive_margin`` — derive the reserve from the controller's
      *realized* predicted-vs-actual completion error (recent-quantile
      tracking, DESIGN.md §19) instead of pinning it at
      ``admit_margin``; the static value stays the floor and the cold
      fallback, so a cold or badly-predicting engine is never less
      conservative than the hand-swept reserve;
    * ``admission_headroom`` — multiplier on every predicted cost
      (measured p50s under-predict the tail the deadline is judged on);
    * ``unit_us_per_kslot`` / ``unit_scalar_us`` — the cold-start cost
      fallbacks used before any measured ``serve.step.*`` samples
      exist;
    * ``serve_memtable`` — refresh() picks up the source's
      ``live_view()`` (sealed segments + the unsealed memtable as an
      overlay pseudo-segment, DESIGN.md §18) instead of the last
      *published* snapshot, making adds/deletes visible to drains
      without waiting for an index refresh;
    * ``scalar_memtable`` — route queries whose lemmas the live overlay
      could contribute postings to through the scalar engine
      (``FB_LIVE_MEMTABLE``) rather than packing the compiled ladder
      against an ephemeral view; overlay-untouched queries keep their
      compiled route either way;
    * ``trace_enabled`` / ``trace_capacity`` — the §15 span tracer (a
      bounded ring of completed spans; disabling reduces the obs
      overhead to the per-phase timestamps);
    * ``metrics_capacity`` — samples retained per latency histogram."""

    buckets: tuple = (1024, 4096, 16384, 65536)
    max_batch: int = 64
    top_k: int = 16
    doc_shards: int = 1
    compressed: bool = False
    use_pack_cache: bool = True
    use_compressed_cache: bool = True
    cache_entries: int = 4096
    cache_bytes: int = 256 << 20
    k_fst: int = 2
    k_wv: int = 3
    k_ns: int = 3
    k_st: int = 3
    k_ord: int = 4
    r_max: int = 4
    share_buckets: bool = True
    payload_cost_driven: bool = True
    use_pallas: bool = False
    serve_memtable: bool = False
    scalar_memtable: bool = True
    default_deadline_s: float | None = None
    admission: bool = False
    max_queue: int | None = None
    degrade: bool = True
    split_budget: int = 2
    split_max_urgent: int = 8
    shed_enter_s: float = 0.100
    shed_exit_s: float = 0.025
    admit_margin: float = 0.4
    adaptive_margin: bool = True
    admit_optimism: float = 1.2
    admission_headroom: float = 1.3
    unit_us_per_kslot: float = 1.0
    unit_scalar_us: float = 5000.0
    trace_enabled: bool = True
    trace_capacity: int = 8192
    metrics_capacity: int = 4096

    def __post_init__(self):
        object.__setattr__(self, "buckets", tuple(sorted(self.buckets)))

    # -- serialization (the §19 tuner's emit/load contract) ----------------
    def to_json_dict(self) -> dict:
        """Every knob as plain JSON data (tuples become lists).
        ``from_json_dict(to_json_dict())`` is the identity — the tuner
        emits its winning config through this and ``launch/serve.py
        --config`` loads it back."""
        d = dataclasses.asdict(self)
        d["buckets"] = list(self.buckets)
        return d

    @classmethod
    def from_json_dict(cls, data: dict) -> "ServeConfig":
        """Rebuild a config from :meth:`to_json_dict` output. Unknown
        fields fail loudly: a config artifact naming a knob this build
        does not have must not silently serve defaults."""
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(data) - known)
        if unknown:
            raise ValueError(f"unknown ServeConfig fields: {unknown}")
        kw = dict(data)
        if "buckets" in kw:
            kw["buckets"] = tuple(kw["buckets"])
        return cls(**kw)


@dataclass
class SearchRequest:
    """Import-compatibility symbol only: no code path constructs it —
    the serving queue holds :class:`SearchTicket` records now. Deleted
    together with the :class:`SearchServingEngine` shim."""

    lemma_ids: list
    arrival: float = field(default_factory=time.perf_counter)


@dataclass
class SearchTicket:
    """Future-like handle returned by :meth:`SearchService.submit`,
    resolved in place by the next :meth:`SearchService.drain` (there is
    no background thread — resolution is the drain that serves it).

    On an admission-controlled engine (DESIGN.md §17) a ticket can also
    resolve *at submit time*: rejected/shed requests carry a
    :class:`SearchResponse` with ``status="rejected"``/``"shed"`` and
    empty results — ``result()`` never hangs on a ticket no drain will
    serve. ``verdict`` records the admission decision;
    ``degraded_bucket`` the cheaper bucket a degraded admit was
    rerouted to (applied by the resolving drain against its own pinned
    snapshot)."""

    lemma_ids: list
    deadline_s: float | None = None
    arrival: float = field(default_factory=time.perf_counter)
    response: "SearchResponse | None" = None
    verdict: AdmissionVerdict | None = None
    degraded_bucket: int | None = None
    plan: QueryPlan | None = None
    group_key: tuple | None = None  # internal: pending-backlog accounting

    @property
    def done(self) -> bool:
        return self.response is not None

    def result(self) -> "SearchResponse":
        if self.response is None:
            raise RuntimeError("ticket not resolved yet — call drain()")
        return self.response


@dataclass
class SearchResponse:
    """One served request: the results plus the serving contract —
    ``plan`` is the :class:`QueryPlan` that routed it (its ``payload``
    reflects the format actually executed), ``deadline_met`` whether
    resolution beat the ticket's budget (None when no deadline was
    set), ``queue_wait_s`` the time between submit and its batch
    starting execution.

    Observability surface (DESIGN.md §15): ``phases`` maps every phase
    of the request's life to its duration in seconds — ``queue`` (submit
    → its batch starting), ``plan``, then the batch phases ``pack`` /
    ``compress`` / ``compile`` / ``dispatch`` / ``execute`` / ``decode``
    — and sums to the end-to-end latency ``finished_at - arrival``
    (within the tiny planning overlap; tests pin 10%).
    ``started_at``/``finished_at`` are the perf_counter bounds of the
    batch that served it, on every route including scalar fallback and
    empty. ``deadline_blame`` names the largest non-queue phase when
    the deadline was missed — a missed budget names the phase that blew
    it — and the queue when waiting alone exceeded the budget.

    ``status`` is the §17 serving outcome: ``ok`` (served as planned),
    ``degraded`` (served from a truncated-prefix route the admission
    controller rerouted it to), ``rejected`` (budget infeasible even on
    an idle system — resolved at submit, empty results) or ``shed``
    (dropped under overload — resolved at submit or by the bounded
    queue, empty results)."""

    results: dict
    latency_s: float
    bucket: int
    batch_size: int
    path: str = "qt1"
    plan: QueryPlan | None = None
    deadline_met: bool | None = None
    queue_wait_s: float = 0.0
    phases: dict = field(default_factory=dict)
    started_at: float = 0.0
    finished_at: float = 0.0
    deadline_blame: str | None = None
    status: str = STATUS_OK

    @property
    def e2e_s(self) -> float:
        """End-to-end submit → resolution latency (queue wait included)."""
        return self.queue_wait_s + (self.finished_at - self.started_at)


def _route_to_path(route: str) -> str:
    """Plan routes -> the executed-path names of ``stats["paths"]``
    (the pre-planner vocabulary: the scalar route reports as "cpu")."""
    return "cpu" if route == _planner.ROUTE_SCALAR else route


class SearchService:
    """Deadline-aware, bucketed, batched proximity-search serving over
    a static ``ProximityIndex`` or a snapshot-able incremental index
    (``repro.index.SegmentedIndex``).

    Serving always runs against an *immutable* searcher snapshot: a
    drain pins the snapshot once, so in-flight batches see a consistent
    view even while the indexer seals memtables and runs background
    merges; :meth:`refresh` picks up the indexer's latest published
    snapshot. Each request is routed by the pure planner per the
    DESIGN.md §13 dispatch matrix, grouped per (step family, L-bucket)
    — with ``share_buckets``, qt34 and qt5 traffic batch together on
    the qt5 executables — padded to the power-of-two batch ladder, and
    served earliest-deadline-group first; shapes the static steps
    cannot express take the scalar engine, so results are always
    exact. :meth:`explain` returns the plan without executing.

    Hot-path machinery under the facade is unchanged from DESIGN.md
    §11-§13: the packed-posting row caches (snapshot-identity
    invalidation, add-only retention), the per-key compressed-row
    cache, and the compiled per-(kind, B, L) executable table now owned
    by :class:`CompiledExecutor`."""

    def __init__(self, index, mesh, config: ServeConfig | None = None):
        self.config = config if config is not None else ServeConfig()
        self._source = index if hasattr(index, "snapshot") else None
        self.index = index.snapshot() if self._source is not None else index
        if self.config.compressed and getattr(self.index, "max_distance", 0) > 254:
            # all compressed formats carry fragment bounds / NSW offsets
            # as uint8 distances; beyond 254 they would silently clip
            raise ValueError(
                "compressed serving requires max_distance <= 254 "
                f"(got {self.index.max_distance})"
            )
        self.mesh = mesh
        cfg = self.config
        # §15 observability tier: one registry + tracer per service,
        # shared by the executors and both row caches so every layer's
        # timings land in the same place
        self.metrics = MetricsRegistry(histogram_capacity=cfg.metrics_capacity)
        self.tracer = Tracer(capacity=cfg.trace_capacity,
                             enabled=cfg.trace_enabled)
        self.pack_cache = (
            PackedPostingCache(max_entries=cfg.cache_entries,
                               max_bytes=cfg.cache_bytes,
                               metrics=self.metrics, scope="cache.pack")
            if cfg.use_pack_cache
            else None
        )
        # per-key compressed rows derive from (and sit beside) the raw
        # row cache; without it every warm compressed drain re-runs the
        # O(B·K·L) host delta encoding
        self.compressed_cache = (
            PackedPostingCache(max_entries=cfg.cache_entries,
                               max_bytes=cfg.cache_bytes,
                               source=self.pack_cache,
                               metrics=self.metrics,
                               scope="cache.compressed")
            if cfg.compressed and cfg.use_compressed_cache
            else None
        )
        # measured payload arbitration (DESIGN.md §16): only meaningful
        # when two payload arms exist, i.e. on a compressed engine
        self.payload_costs = (
            PayloadCostModel()
            if cfg.compressed and cfg.payload_cost_driven else None
        )
        self.compiled = CompiledExecutor(
            mesh, cfg, pack_cache=self.pack_cache,
            compressed_cache=self.compressed_cache,
            metrics=self.metrics, tracer=self.tracer,
            costs=self.payload_costs,
        )
        self.scalar = ScalarExecutor(cfg, metrics=self.metrics,
                                     tracer=self.tracer)
        # §17 deadline control loop: predictor + controller consulted at
        # submit; pending-group counts and the in-flight horizon feed
        # the backlog estimate the controller judges against
        self.predictor = StepCostPredictor(self.compiled, cfg,
                                           _planner._streams)
        self.admission = (
            AdmissionController(cfg.shed_enter_s, cfg.shed_exit_s,
                                margin=cfg.admit_margin,
                                optimism=cfg.admit_optimism,
                                adaptive_margin=cfg.adaptive_margin)
            if cfg.admission else None
        )
        # measured recall cost of degraded buckets (§19): orders the
        # degrade candidates the controller judges, best-retained-recall
        # first (prefix fraction as the cold prior)
        self.recall_costs = (
            RecallCostModel()
            if cfg.admission and cfg.degrade else None
        )
        self._pending: dict[tuple, int] = {}
        self._inflight_until = 0.0
        self._queue: list[SearchTicket] = []
        self._queue_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        # per-snapshot lemma ids -> QueryPlan; validity is tied to the
        # *pinned view's identity* (not to refresh() clearing it: a
        # drain racing a refresh could otherwise re-insert a stale
        # entry after the clear). Bounded: a high-cardinality query
        # stream over a static index never refreshes, so the memo is
        # cleared wholesale at the cap (rebuilding an entry is one
        # n_postings scan per key)
        self._plan_memo: dict[tuple, QueryPlan] = {}
        self._plan_memo_view = None
        self._plan_memo_gen = 0
        self._plan_memo_cap = 65536
        self.stats = {
            "batches": 0, "requests": 0, "refreshes": 0,
            "compressed_batches": 0, "offset_fallbacks": 0,
            "bucket_hist": {b: 0 for b in cfg.buckets},
            "paths": {"qt1": 0, "qt2": 0, "qt34": 0, "qt5": 0,
                      "cpu": 0, "empty": 0},
            "plans": {
                "routes": {r: 0 for r in (*_planner.COMPILED_ROUTES,
                                          _planner.ROUTE_SCALAR,
                                          _planner.ROUTE_EMPTY)},
                "fallbacks": {},
                "executables": 0,
                "shared_batches": 0,
                "est_vs_measured": {},
            },
            "deadlines": {"met": 0, "missed": 0, "unset": 0,
                          "miss_blame": {}},
            "pack_cache": {}, "compressed_cache": {},
        }
        if self.admission is not None:
            self.stats["admission"] = {
                "admitted": 0, "optimistic": 0, "degraded": 0,
                "rejected_infeasible": 0, "shed_overload": 0,
                "queue_shed": 0, "expired": 0, "splits": 0,
                "overload_transitions": 0,
                "margin": self.admission.margin_stats(),
                "recall": {},
            }

    # -- planning ----------------------------------------------------------
    def _plan(self, index, lemma_ids) -> QueryPlan:
        # validity is (snapshot identity, cost-model generation): a
        # payload-choice flip bumps the generation, so memoized plans
        # can never pin a stale payload
        gen = (self.payload_costs.generation
               if self.payload_costs is not None else 0)
        if index is not self._plan_memo_view or gen != self._plan_memo_gen:
            # the scalar executor tracks snapshot identity itself
            self._plan_memo = {}
            self._plan_memo_view = index
            self._plan_memo_gen = gen
        memo_key = tuple(lemma_ids)
        p = self._plan_memo.get(memo_key)
        if p is not None:
            return p
        p = _planner.plan(list(lemma_ids), index, self.config,
                          costs=self.payload_costs)
        if len(self._plan_memo) >= self._plan_memo_cap:
            self._plan_memo.clear()
        self._plan_memo[memo_key] = p
        return p

    def explain(self, lemma_ids, costs: bool = False) -> QueryPlan:
        """The :class:`QueryPlan` this request would execute under —
        route, executable family, L-bucket, payload, estimated step
        cost, fallback reason — without executing anything. Planned
        against the currently pinned snapshot with the same memo the
        next drain will use, so ``explain(q)`` and the executed
        ``response.plan`` agree (tests/test_planner.py pins this per
        dispatch-matrix row).

        With ``costs=True`` the returned plan additionally carries
        ``measured`` — the §15 calibration record for the same
        (step_family, L-bucket) executable family: per-B measured
        run-time percentiles from the live ``serve.step.*`` histograms,
        the first-call compile time, the XLA ``cost_analysis()``
        summary, and ``us_per_kslot`` (measured p50 per thousand
        ``est_step_cost`` slots — the est-vs-measured ratio). The
        cost-annotated plan is a fresh object (the memoized plan stays
        identity-stable); ``measured`` is None off-device or before any
        warm batch of the shape has run."""
        p = self._plan(self.index, lemma_ids)
        if not costs:
            return p
        measured = None
        if p.is_compiled:
            table = self.compiled.measured_cost(p.step_family, p.bucket)
            if table:
                est = p.est_step_cost
                for entry in table.values():
                    entry["us_per_kslot"] = (
                        entry["measured_p50_us"] / (est / 1000.0)
                    )
                measured = {"est_step_cost": est, "executables": table}
        return dataclasses.replace(p, measured=measured)

    # -- lifecycle ---------------------------------------------------------
    def refresh(self) -> None:
        """Pick up the indexer's latest published snapshot.

        A no-op when serving a static ``ProximityIndex``; for a
        ``repro.index.SegmentedIndex`` source this swaps in the newest
        immutable ``SegmentedView``, making documents added or deleted
        since the previous refresh visible to subsequent drains.
        Already in-flight drains keep the snapshot they pinned. The
        compiled executable table is reused across refreshes (only the
        host-side packing sees the new postings); plans are re-derived
        lazily, and the row caches invalidate themselves on the first
        lookup against the new snapshot — entries are keyed by snapshot
        identity, and benign transitions (add-only refreshes, pure
        background compactions) retain untouched keys (DESIGN.md §12,
        §18).

        With ``serve_memtable`` the service instead picks the source's
        ``live_view()`` — sealed segments plus the unsealed memtable as
        an overlay — so documents are searchable the moment they are
        added (DESIGN.md §18); the planner routes overlay-touching
        queries to the scalar engine when ``scalar_memtable`` is set."""
        if self._source is not None:
            if self.config.serve_memtable and hasattr(self._source, "live_view"):
                self.index = self._source.live_view()
            else:
                self.index = self._source.snapshot()
            self.stats["refreshes"] += 1

    # -- serving -----------------------------------------------------------
    def submit(self, lemma_ids, deadline_s: float | None = None,
               arrival: float | None = None) -> SearchTicket:
        """Queue one request (a lemma-id list, i.e. one sub-query of
        ``core.query.build_subqueries``) for the next :meth:`drain`;
        returns its :class:`SearchTicket`. ``deadline_s`` is a budget
        from *now* (submission): the resolving drain reports
        ``deadline_met`` per response and prioritizes
        tighter-deadline groups. ``arrival`` backdates the request to a
        scheduled perf_counter instant (trace replay / the open-loop
        load harness, DESIGN.md §17): queue wait, the deadline verdict
        *and* the admission budget are all judged from it. Thread-safe;
        on a non-admission engine no planning, packing or device work
        happens until the batcher cuts a batch — with
        ``config.admission`` the §17 controller plans the request
        (memoized) and judges its budget here, so a rejected or shed
        ticket resolves immediately and never hangs."""
        if deadline_s is None:
            deadline_s = self.config.default_deadline_s
        ticket = SearchTicket(list(lemma_ids), deadline_s=deadline_s)
        if arrival is not None:
            ticket.arrival = arrival
        if self.admission is None:
            with self._queue_lock:
                self._queue.append(ticket)
            return ticket
        self._admit(ticket)
        return ticket

    def _group_key(self, p: QueryPlan) -> tuple:
        if p.route == _planner.ROUTE_EMPTY:
            return ("empty", None)
        if p.route == _planner.ROUTE_SCALAR:
            return ("scalar", None)
        return (p.step_family, p.bucket)

    def _backlog_locked(self, now: float) -> float:
        """Predicted seconds of queued + in-flight work (queue lock
        held): the remaining horizon of the currently executing drain
        plus each pending group's batch-count × predicted batch cost —
        per-(family, bucket) counts, not per-request sums, because
        batching amortizes (16 queued qt5@4096 requests are one batch,
        not 16)."""
        backlog = max(0.0, self._inflight_until - now)
        mb = self.config.max_batch
        for (family, bucket), n in self._pending.items():
            if n <= 0 or family == "empty":
                continue
            if family == "scalar":
                backlog += n * self.predictor.scalar_s()
            else:
                B = batch_size_bucket(min(n, mb), mb)
                backlog += (-(-n // mb)) * self.predictor.batch_s(
                    family, B, bucket)
        return backlog

    def _admit(self, ticket: SearchTicket) -> None:
        """The §17 admission decision for one submit: predict the
        request's completion (backlog + its group's batch cost, per
        :class:`StepCostPredictor`), let the controller pick the
        least-degraded feasible route, and either enqueue the ticket or
        resolve it right here as rejected/shed."""
        cfg = self.config
        mb = cfg.max_batch
        with self.tracer.span("admission"):
            p = self._plan(self.index, ticket.lemma_ids)
            gkey = self._group_key(p)
            now = time.perf_counter()
            with self._queue_lock:
                backlog = self._backlog_locked(now)
                pend = self._pending.get(gkey, 0)
            if p.route == _planner.ROUTE_EMPTY:
                candidates = [(None, 0.0)]
                idle_s = 0.0
            elif p.route == _planner.ROUTE_SCALAR:
                candidates = [(None, self.predictor.scalar_s())]
                idle_s = candidates[0][1]
            else:
                B = batch_size_bucket(min(pend + 1, mb), mb)
                candidates = [(p.bucket,
                               self.predictor.batch_s(p.step_family, B,
                                                      p.bucket))]
                if cfg.degrade:
                    # degrade candidates ordered by estimated retained
                    # recall (measured result-count ratio vs the full
                    # route, §19), so "first fit" is "least measured
                    # degradation"; a cold recall model falls back to
                    # the prefix-fraction prior == largest-first
                    below = [b for b in cfg.buckets if b < p.bucket]
                    if self.recall_costs is not None:
                        below = self.recall_costs.order(
                            p.step_family, below, p.bucket)
                    else:
                        below = sorted(below, reverse=True)
                    candidates += [
                        (b, self.predictor.batch_s(p.step_family, B, b))
                        for b in below
                    ]
                # infeasibility is judged on a B=1 batch of the cheapest
                # candidate route — serving this request *alone*, not
                # with the crowd it happens to arrive into
                idle_s = min(self.predictor.batch_s(p.step_family, 1, b)
                             for b, _ in candidates)
            budget = (None if ticket.deadline_s is None
                      else ticket.arrival + ticket.deadline_s - now)
            verdict = self.admission.consider(candidates, backlog, budget,
                                              idle_cost_s=idle_s)
            ticket.verdict = verdict
            self.metrics.inc(f"serve.admission.{verdict.decision}")
            with self._stats_lock:
                adm = self.stats["admission"]
                if verdict.decision == ADMIT:
                    adm["admitted"] += 1
                    if verdict.reason == REASON_OPTIMISTIC:
                        adm["optimistic"] += 1
                elif verdict.decision == DEGRADE:
                    adm["admitted"] += 1
                    adm["degraded"] += 1
                elif verdict.decision == REJECT_INFEASIBLE:
                    adm["rejected_infeasible"] += 1
                else:
                    adm["shed_overload"] += 1
                adm["overload_transitions"] = self.admission.transitions
            if not verdict.admitted:
                status = (STATUS_REJECTED
                          if verdict.decision == REJECT_INFEASIBLE
                          else STATUS_SHED)
                with self.tracer.span(f"admission.{verdict.decision}",
                                      route=p.route):
                    self._resolve_unserved(ticket, p, status)
                return
            if verdict.decision == DEGRADE:
                ticket.degraded_bucket = verdict.bucket
                gkey = (p.step_family, verdict.bucket)
            ticket.plan = p
            ticket.group_key = gkey
            self._enqueue(ticket, gkey)

    def _enqueue(self, ticket: SearchTicket, gkey: tuple) -> None:
        """Append under the bounded-queue policy: past ``max_queue`` the
        deadline-aware drop sheds whichever request is already predicted
        infeasible (least remaining budget among those the backlog has
        outrun) — the newcomer only when every queued request is still
        feasible. Never a FIFO head-drop."""
        cfg = self.config
        victim = None
        with self._queue_lock:
            if cfg.max_queue is not None and len(self._queue) >= cfg.max_queue:
                now = time.perf_counter()
                backlog = self._backlog_locked(now)
                victim = self._infeasible_victim_locked(now, backlog)
                if victim is not None:
                    self._queue.remove(victim)
                    if victim.group_key is not None:
                        self._pending[victim.group_key] = max(
                            0, self._pending.get(victim.group_key, 1) - 1)
                    self._queue.append(ticket)
                    self._pending[gkey] = self._pending.get(gkey, 0) + 1
                else:
                    victim = ticket  # full of feasible work: shed newcomer
            else:
                self._queue.append(ticket)
                self._pending[gkey] = self._pending.get(gkey, 0) + 1
        if victim is not None:
            self.metrics.inc("serve.admission.queue_shed")
            with self._stats_lock:
                self.stats["admission"]["queue_shed"] += 1
            with self.tracer.span("admission.queue_shed"):
                self._resolve_unserved(victim, victim.plan, STATUS_SHED)

    def _infeasible_victim_locked(self, now: float,
                                  backlog_s: float) -> SearchTicket | None:
        """The queued ticket the backlog has most clearly outrun: least
        remaining budget among deadline-carrying tickets whose remaining
        budget is below the predicted backlog. None when every queued
        request is still feasible."""
        victim, victim_rem = None, None
        for t in self._queue:
            if t.deadline_s is None:
                continue
            rem = t.arrival + t.deadline_s - now
            if rem < backlog_s and (victim_rem is None or rem < victim_rem):
                victim, victim_rem = t, rem
        return victim

    def _resolve_unserved(self, ticket: SearchTicket, p: QueryPlan | None,
                          status: str) -> None:
        """Resolve a rejected/shed ticket in place with empty results —
        ``result()`` must never hang on a ticket no drain will serve.
        Rejected/shed requests with a deadline count as misses with the
        §17 blame vocabulary (``infeasible`` / ``shed``); deadline-less
        ones count as unset."""
        now = time.perf_counter()
        wait = max(now - ticket.arrival, 0.0)
        blame = None
        if ticket.deadline_s is not None:
            blame = (BLAME_INFEASIBLE if status == STATUS_REJECTED
                     else BLAME_SHED)
            with self._stats_lock:
                dl = self.stats["deadlines"]
                dl["missed"] += 1
                dl["miss_blame"][blame] = dl["miss_blame"].get(blame, 0) + 1
            self.metrics.inc(f"serve.deadline.miss_blame.{blame}")
        else:
            with self._stats_lock:
                self.stats["deadlines"]["unset"] += 1
        resp = SearchResponse(
            results=empty_results(), latency_s=0.0, bucket=0, batch_size=0,
            path=_route_to_path(p.route) if p is not None else "unserved",
            plan=p, deadline_met=False if ticket.deadline_s is not None
            else None,
            queue_wait_s=wait,
            phases={"queue": wait, "plan": 0.0, **zero_phases()},
            started_at=now, finished_at=now, deadline_blame=blame,
            status=status,
        )
        ticket.response = resp

    def drain(self) -> list[SearchResponse]:
        """Serve everything queued, resolving every pending ticket and
        returning one :class:`SearchResponse` per request **in
        submission order**.

        The snapshot is pinned once for the whole drain. Requests are
        planned (memoized per lemma-id tuple per snapshot), grouped per
        (step family, L-bucket) — so with ``share_buckets`` qt34 and
        qt5 requests batch together — padded to the power-of-two batch
        ladder, and groups are served earliest-deadline first
        (deadline-less groups follow, largest first). Each response
        carries its plan, executed path, bucket, batch size, wall-clock
        batch latency, queue wait and deadline verdict.

        On an admission engine, requests whose deadline already expired
        while queued are shed here instead of served (a guaranteed miss
        would still burn a batch slot, §17): they resolve through their
        ticket with ``status="shed"`` and are *not* in the returned
        list."""
        if not self._queue:
            return []
        index = self.index
        # swap the queue out under the submit lock BEFORE grouping: a
        # submit() racing this drain either lands before the swap (and
        # is served now) or after it (and stays queued) — never
        # silently dropped into the already-grouped list
        with self._queue_lock:
            pending, self._queue = self._queue, []
            self._pending = {}
        # this drain lands new step measurements; predictions made from
        # the previous batch of measurements expire now
        self.predictor.invalidate()
        if self.admission is not None:
            pending = self._drop_expired(pending)
            if not pending:
                return []
        t_drain0 = time.perf_counter()
        slots: list = [None] * len(pending)
        with self.tracer.span("drain", requests=len(pending)):
            # per-request planning time is part of the phase breakdown
            # (memoized hits are sub-µs; misses scan posting counts)
            plans, plan_s = [], []
            with self.tracer.span("plan", n=len(pending)):
                for t in pending:
                    tp0 = time.perf_counter()
                    p = self._plan(index, t.lemma_ids)
                    # a degraded admit reroutes to the cheaper bucket
                    # here, against *this* drain's pinned snapshot (the
                    # memoized plan stays untouched for other requests)
                    if (t.degraded_bucket is not None and p.is_compiled
                            and t.degraded_bucket < p.bucket):
                        p = _planner.degrade(p, t.degraded_bucket,
                                             self.config,
                                             costs=self.payload_costs)
                    plans.append(p)
                    plan_s.append(time.perf_counter() - tp0)
            with self.tracer.span("group"):
                groups: dict[tuple, list[int]] = {}
                for i, p in enumerate(plans):
                    if p.route == _planner.ROUTE_EMPTY:
                        key = ("empty", None)
                    elif p.route == _planner.ROUTE_SCALAR:
                        key = ("scalar", None)
                    else:
                        key = (p.step_family, p.bucket)
                    groups.setdefault(key, []).append(i)

                def urgency(item):
                    _, idxs = item
                    deadline = min(
                        (pending[i].arrival + pending[i].deadline_s
                         for i in idxs if pending[i].deadline_s is not None),
                        default=float("inf"),
                    )
                    return (deadline, -len(idxs))

                order = sorted(groups.items(), key=urgency)

            # publish the drain's predicted work horizon: submits racing
            # this drain see it as in-flight backlog (the queue itself
            # was swapped empty above)
            mb = self.config.max_batch
            now0 = time.perf_counter()
            horizon = 0.0
            for (family, bucket), idxs in order:
                if family == "empty":
                    continue
                if family == "scalar":
                    horizon += len(idxs) * self.predictor.scalar_s()
                else:
                    Bg = batch_size_bucket(min(len(idxs), mb), mb)
                    horizon += (-(-len(idxs) // mb)) * self.predictor.batch_s(
                        family, Bg, bucket)
            self._inflight_until = now0 + horizon

            # EDF group splitting (§17): when a tail ticket's budget
            # cannot survive its whole group, peel an urgent sub-batch
            # off at a smaller B-bucket — bounded by split_budget extra
            # dispatches per drain
            units: list[tuple[tuple, list[int]]] = []
            splits_left = self.config.split_budget
            t_acc = 0.0
            for (family, bucket), idxs in order:
                split = None
                if family not in ("empty", "scalar") and splits_left > 0:
                    split = self._split_urgent(pending, idxs, family,
                                               bucket, t_acc, now0)
                if split is not None:
                    urgent, rest = split
                    splits_left -= 1
                    self.metrics.inc("serve.admission.split")
                    with self._stats_lock:
                        if "admission" in self.stats:
                            self.stats["admission"]["splits"] += 1
                    units.append(((family, bucket), urgent))
                    units.append(((family, bucket), rest))
                else:
                    units.append(((family, bucket), idxs))
                if family == "scalar":
                    t_acc += len(idxs) * self.predictor.scalar_s()
                elif family != "empty":
                    Bg = batch_size_bucket(min(len(idxs), mb), mb)
                    t_acc += (-(-len(idxs) // mb)) * self.predictor.batch_s(
                        family, Bg, bucket)

            for (family, bucket), idxs in units:
                if family == "empty":
                    now = time.perf_counter()
                    for i in idxs:
                        self._resolve(
                            pending[i], plans[i], slots, i,
                            ExecResult(results=empty_results(), latency_s=0.0,
                                       bucket=0, batch_size=1, started_at=now,
                                       finished_at=now),
                            plan_s[i],
                        )
                    continue
                queries = [pending[i].lemma_ids for i in idxs]
                if family == "scalar":
                    execs = self.scalar.execute(index, queries,
                                                [None] * len(idxs),
                                                step_family=None, bucket=None)
                else:
                    sels = [self._selection_for(plans[i], family) for i in idxs]
                    shared = [plans[i].route != family for i in idxs]
                    # one payload per (family, bucket) group: all its
                    # plans were routed under the same cost-model state
                    execs = self.compiled.execute(index, queries, sels,
                                                  step_family=family,
                                                  bucket=bucket, shared=shared,
                                                  payload=plans[idxs[0]].payload)
                    if bucket in self.stats["bucket_hist"]:
                        mb = self.config.max_batch
                        with self._stats_lock:
                            self.stats["bucket_hist"][bucket] += (
                                -(-len(idxs) // mb)
                            )
                for i, ex in zip(idxs, execs):
                    self._resolve(pending[i], plans[i], slots, i, ex,
                                  plan_s[i])
        self._inflight_until = 0.0
        self.metrics.observe(
            "serve.drain.total",
            (time.perf_counter() - t_drain0) * 1e6,
        )
        self._finish_stats(plans)
        return slots

    def _drop_expired(self, pending: list) -> list:
        """Shed requests whose deadline has already passed before any
        batch work starts (§17, admission engines only): serving an
        expired request is a *guaranteed* miss that still costs a full
        batch slot, so it is resolved as shed here and its slot goes to
        traffic that can still meet its budget. This is the burst-onset
        backstop — the latch and the margin judge predictions at
        submit, but a flood arriving inside one drain window can outrun
        any decision made at its front. Returns the still-live tickets;
        expired ones resolve via their ticket (they are not in the
        drain's return list)."""
        now = time.perf_counter()
        live, expired = [], []
        for t in pending:
            if (t.deadline_s is not None
                    and t.arrival + t.deadline_s < now):
                expired.append(t)
            else:
                live.append(t)
        for t in expired:
            self.metrics.inc("serve.admission.expired")
            with self._stats_lock:
                self.stats["admission"]["expired"] += 1
            with self.tracer.span("admission.expired"):
                self._resolve_unserved(t, t.plan, STATUS_SHED)
        return live

    def _split_urgent(self, pending, idxs, family: str, bucket: int,
                      t_acc: float, now: float):
        """EDF group splitting (§17): does some deadline-carrying tail
        of this group miss its budget if served with the whole group,
        but survive a small urgent sub-batch at a cheaper B-bucket?

        Returns ``(urgent_idxs, rest_idxs)`` or None. ``t_acc`` is the
        predicted time already committed to earlier EDF groups this
        drain. The urgent sub-batch must be *strictly* cheaper than the
        full-group chunk — padding both to the same B-bucket, or
        splitting onto a cold shape (whose prediction carries the AOT
        compile penalty), makes splitting pure overhead and is refused
        here."""
        cfg = self.config
        mb = cfg.max_batch
        B_full = batch_size_bucket(min(len(idxs), mb), mb)
        chunk_s = self.predictor.batch_s(family, B_full, bucket,
                                         strict_warm=True)
        urgent = []
        for pos, i in enumerate(idxs):
            t = pending[i]
            if t.deadline_s is None:
                continue
            remaining = t.arrival + t.deadline_s - now
            # the chunk this request rides finishes after all earlier
            # chunks of the group
            finish = t_acc + (pos // mb + 1) * chunk_s
            if remaining < finish:
                urgent.append(i)
        if not urgent or len(urgent) >= len(idxs):
            return None
        urgent.sort(key=lambda i: pending[i].arrival + pending[i].deadline_s)
        urgent = urgent[:cfg.split_max_urgent]
        B_u = batch_size_bucket(min(len(urgent), mb), mb)
        if self.predictor.batch_s(family, B_u, bucket,
                                  strict_warm=True) >= chunk_s:
            return None
        urgent_set = set(urgent)
        rest = [i for i in idxs if i not in urgent_set]
        return urgent, rest

    @staticmethod
    def _selection_for(p: QueryPlan, family: str):
        """Packer-ready key selection: a qt34 plan riding the qt5 step
        becomes a zero-stop qt5 plan (anchor, others, (), counts)."""
        if p.route == _planner.ROUTE_QT34 and family == _planner.ROUTE_QT5:
            anchor, others, counts = p.selection
            return anchor, others, (), counts
        return p.selection

    def _resolve(self, ticket, p: QueryPlan, slots, i, ex: ExecResult,
                 plan_dt: float = 0.0) -> None:
        # deadline and queue wait are judged against *this request's
        # batch* (its ExecResult timestamps), not the whole group — in a
        # multi-chunk group, earlier chunks resolve earlier
        queue_wait = max(ex.started_at - ticket.arrival, 0.0)
        # the per-request phase breakdown (§15): queue + plan + the
        # batch phases. The batch phases tile [started_at, finished_at]
        # and queue tiles [arrival, started_at], so the values sum to
        # the end-to-end latency (plan overlaps the queue window but is
        # orders of magnitude smaller; tests pin agreement within 10%)
        phases = {"queue": queue_wait, "plan": plan_dt}
        phases.update(ex.phases if ex.phases else zero_phases())
        met = None
        blame = None
        e2e = ex.finished_at - ticket.arrival
        if ticket.deadline_s is not None:
            met = e2e <= ticket.deadline_s
            if not met:
                # name the phase that blew the budget: queue when
                # waiting alone exceeded it, else the slowest work phase
                if queue_wait > ticket.deadline_s:
                    blame = "queue"
                else:
                    blame = max(
                        (ph for ph in phases if ph != "queue"),
                        key=lambda ph: phases[ph],
                    )
            with self._stats_lock:
                dl = self.stats["deadlines"]
                dl["met" if met else "missed"] += 1
                if blame is not None:
                    dl["miss_blame"][blame] = (
                        dl["miss_blame"].get(blame, 0) + 1
                    )
        else:
            with self._stats_lock:
                self.stats["deadlines"]["unset"] += 1
        m = self.metrics
        for name, dur in phases.items():
            m.observe(f"serve.phase.{name}", dur * 1e6)
        m.observe("serve.request.e2e", e2e * 1e6)
        if blame is not None:
            m.inc(f"serve.deadline.miss_blame.{blame}")
        # §19 feedback loops: realized predicted-vs-actual completion
        # error for the adaptive reserve, and served result counts for
        # the recall-cost model that orders degrade candidates
        if (self.admission is not None and ticket.verdict is not None
                and ticket.verdict.admitted):
            self.admission.observe_completion(
                ticket.verdict.predicted_e2e_s, e2e)
        if self.recall_costs is not None and p.is_compiled:
            n_res = int(ex.results["doc"].size) if ex.results else 0
            if p.degraded:
                self.recall_costs.observe_degraded(p.step_family,
                                                   p.bucket, n_res)
            else:
                self.recall_costs.observe_full(p.step_family, n_res)
        executed = p if ex.payload in (None, p.payload) \
            else dataclasses.replace(p, payload=ex.payload)
        resp = SearchResponse(
            results=ex.results, latency_s=ex.latency_s, bucket=ex.bucket,
            batch_size=ex.batch_size, path=_route_to_path(p.route),
            plan=executed, deadline_met=met, queue_wait_s=queue_wait,
            phases=phases, started_at=ex.started_at,
            finished_at=ex.finished_at, deadline_blame=blame,
            status=STATUS_DEGRADED if p is not None and p.degraded
            else STATUS_OK,
        )
        ticket.response = resp
        slots[i] = resp

    def _finish_stats(self, plans: list[QueryPlan]) -> None:
        ex = self.compiled
        est_vs_measured = ex.est_vs_measured(_planner._streams)
        pack_stats = (self.pack_cache.stats
                      if self.pack_cache is not None else None)
        comp_stats = (self.compressed_cache.stats
                      if self.compressed_cache is not None else None)
        with self._stats_lock:
            st = self.stats
            st["requests"] += len(plans)
            routes = st["plans"]["routes"]
            for p in plans:
                routes[p.route] = routes.get(p.route, 0) + 1
                st["paths"][_route_to_path(p.route)] += 1
                if p.fallback_reason is not None:
                    fb = st["plans"]["fallbacks"]
                    fb[p.fallback_reason] = fb.get(p.fallback_reason, 0) + 1
            st["batches"] = ex.stats["batches"]
            st["compressed_batches"] = ex.stats["compressed_batches"]
            st["offset_fallbacks"] = ex.stats["offset_fallbacks"]
            st["plans"]["executables"] = ex.n_executables
            st["plans"]["shared_batches"] = ex.stats["shared_batches"]
            st["plans"]["est_vs_measured"] = est_vs_measured
            if self.payload_costs is not None:
                st["plans"]["payload_costs"] = self.payload_costs.table()
            if pack_stats is not None:
                st["pack_cache"] = pack_stats
            if comp_stats is not None:
                st["compressed_cache"] = comp_stats
            if self.admission is not None:
                st["admission"]["margin"] = self.admission.margin_stats()
            if self.recall_costs is not None:
                st["admission"]["recall"] = self.recall_costs.table()

    # -- observability (DESIGN.md §15) -------------------------------------
    def stats_snapshot(self) -> dict:
        """A deep, consistent copy of :attr:`stats`, with the cache
        stats re-read fresh. ``stats`` itself is mutated in place during
        :meth:`drain` — a concurrent reader iterating it can see
        half-updated counters (or hit a dict-size-changed error); this
        snapshot is taken under the same lock the mutators hold, so the
        counters in one snapshot are mutually consistent. Benchmarks and
        examples read this, never ``stats`` directly."""
        with self._stats_lock:
            snap = copy.deepcopy(self.stats)
        # cache stats properties already return fresh dicts under the
        # cache's own lock; re-read them so the snapshot is current even
        # between drains
        if self.pack_cache is not None:
            snap["pack_cache"] = self.pack_cache.stats
        if self.compressed_cache is not None:
            snap["compressed_cache"] = self.compressed_cache.stats
        if self.admission is not None:
            snap["admission"]["margin"] = self.admission.margin_stats()
        if self.recall_costs is not None:
            snap["admission"]["recall"] = self.recall_costs.table()
        return snap

    def metrics_snapshot(self, prefix: str = "") -> dict:
        """Plain-data snapshot of the metrics registry (counters,
        gauges, histogram percentiles) — ``prefix`` filters by dotted
        name (``"serve.phase."`` for the request phase breakdown)."""
        return self.metrics.snapshot(prefix)

    def trace_snapshot(self) -> dict:
        """The recorded span buffer as a Chrome JSON trace object —
        ``json.dump`` it and load the file in https://ui.perfetto.dev
        (or pass ``--trace-out`` to ``launch/serve.py`` /
        ``examples/serve_search.py``). One span tree per drain:
        ``drain`` → ``plan`` / ``group`` / per-batch ``batch`` →
        ``pack``/``compress``/``compile``/``dispatch``/``execute``/
        ``decode``."""
        return chrome_trace(self.tracer.snapshot())

    def write_trace(self, path: str) -> dict:
        """Write :meth:`trace_snapshot` to ``path``; returns the trace
        object (callers report event counts)."""
        return write_chrome_trace(path, self.tracer.snapshot())
