"""Executors: the device/scalar execution layer of the serving tier
(DESIGN.md §14), instrumented per phase (DESIGN.md §15).

Two implementations of one :class:`Executor` protocol sit below the
:class:`repro.serving.service.SearchService` facade:

* :class:`CompiledExecutor` owns the serve-step factories and the
  per-(step kind, B, L) **executable table** — every distinct compiled
  shape ever executed, the denominator of the response-time guarantee.
  It implements dispatch-aware batching (the ROADMAP item): a ``qt34``
  group whose plan fits the QT5 step's non-stop slots is packed with
  zero stop constraints and served on the ``qt5`` executable of the
  same (B, L) — ``qt5_join`` with zero stop constraints *is*
  ``qt34_join`` — so mixed traffic compiles one executable ladder
  where it previously compiled two.
* :class:`ScalarExecutor` wraps the scalar
  :class:`repro.core.search.ProximitySearchEngine` — the correctness
  backstop every ``scalar``-route plan of the dispatch matrix falls
  back to (routing affects latency, never results).

Observability contract (§15): both executors record into the service's
shared :class:`repro.obs.MetricsRegistry` and :class:`repro.obs.Tracer`.
Every batch emits a span tree (``batch`` → ``pack`` / ``compress`` /
``compile`` / ``dispatch`` / ``execute`` / ``decode``) and every
:class:`ExecResult` carries the same timings as a ``phases`` dict whose
values tile ``[started_at, finished_at]`` exactly — the service adds
queue/plan on top, which is how a response's phase breakdown sums to
its end-to-end latency. Compile time is split from run time by
first-call detection: the first execution of a (kind, B, L) triple
ahead-of-time lowers and compiles the step (timed as the ``compile``
phase, with the XLA ``cost_analysis()`` summary captured off the
compiled executable); subsequent calls hit the AOT table, so the
``serve.step.<family>.B<B>.L<L>`` histograms measure pure run time —
the measured-cost table ``explain(costs=True)`` and admission control
calibrate ``est_step_cost`` against.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Protocol

import jax
import numpy as np

from repro.core.jax_search import (
    assemble_qt1_compressed,
    assemble_qt2_compressed,
    assemble_qt34_compressed,
    assemble_qt5_compressed,
    batch_size_bucket,
    compress_qt1_batch,
    compress_qt2_batch,
    compress_qt34_batch,
    compress_qt5_batch,
    decode_results,
    make_qt1_serve_step,
    make_qt1_serve_step_compressed,
    make_wv_serve_step,
    pack_qt1_batch,
    pack_qt2_batch,
    pack_qt34_batch,
    pack_qt5_batch,
)
from repro.obs import MetricsRegistry, Tracer
from repro.serving.planner import (
    PAYLOAD_DELTA16,
    PAYLOAD_OFFSETS,
    PAYLOAD_RAW,
    delta16_aligned,
)

# the batch-level phases every ExecResult reports; the service prepends
# "queue" and "plan" (tests assert this exact vocabulary)
BATCH_PHASES = ("pack", "compress", "compile", "dispatch", "execute", "decode")


def zero_phases() -> dict:
    return {p: 0.0 for p in BATCH_PHASES}


@dataclass
class ExecResult:
    """Per-request execution record: the decoded results plus the
    executed shape — ``payload`` is the format actually served (a
    planner delta16 prediction downgrades to offsets when a key's
    in-block span overflows uint16), ``latency_s`` the wall-clock of
    the whole batch the request rode in, ``started_at``/``finished_at``
    the perf_counter timestamps of *that batch* (not the whole group:
    the service derives queue waits and deadline verdicts per batch),
    and ``phases`` the batch's per-phase durations in seconds —
    contiguous sub-intervals tiling [started_at, finished_at]."""

    results: dict
    latency_s: float
    bucket: int
    batch_size: int
    payload: str | None = None
    started_at: float = 0.0
    finished_at: float = 0.0
    phases: dict = field(default_factory=zero_phases)


class Executor(Protocol):
    """One (route, bucket) group of requests in, one ExecResult per
    request out, aligned with the inputs."""

    def execute(self, index, queries: list, selections: list, *,
                step_family: str | None, bucket: int | None,
                shared: list | None = None) -> list[ExecResult]: ...


# kind suffix -> planner payload name
_PAYLOAD_OF_KIND = {"base": PAYLOAD_RAW, "raw": PAYLOAD_RAW,
                    "delta": PAYLOAD_DELTA16, "offsets": PAYLOAD_OFFSETS}


def _payload_of_kind(kind: str) -> str:
    return _PAYLOAD_OF_KIND[kind.rsplit("_", 1)[-1] if "_" in kind else kind]


def xla_cost_summary(compiled) -> dict | None:
    """The interesting scalars of an XLA ``cost_analysis()`` — flops,
    bytes accessed, transcendentals — tolerant of the list-vs-dict
    return shape across jax versions. None when the backend does not
    implement cost analysis."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return None
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if not isinstance(ca, dict):
        return None
    out = {}
    for key in ("flops", "bytes accessed", "transcendentals",
                "optimal_seconds"):
        v = ca.get(key)
        if v is not None:
            out[key.replace(" ", "_")] = float(v)
    return out


class CompiledExecutor:
    """Packs, compresses and executes padded batches on the compiled
    per-(step kind, B-bucket, L-bucket) serve steps.

    ``executables`` maps every (kind, B, L) triple ever executed to its
    batch count — the engine-stats surface tests assert B-bucket
    sharing on; ``stats["shared_batches"]`` counts qt34 groups served
    on qt5 executables. ``compile_times`` / ``cost_summaries`` hold the
    first-call AOT compile wall-clock and XLA cost_analysis summary per
    triple; measured run times stream into the metrics registry as
    ``serve.step.<family>.B<B>.L<L>`` histograms (µs)."""

    def __init__(self, mesh, config, pack_cache=None, compressed_cache=None,
                 metrics: MetricsRegistry | None = None,
                 tracer: Tracer | None = None, costs=None):
        self.mesh = mesh
        self.config = config
        self.pack_cache = pack_cache
        self.compressed_cache = compressed_cache
        # optional PayloadCostModel (owned by the service): warm batch
        # times stream into it per (family, bucket, payload arm), and
        # the planner consults it for the group's payload choice
        self.costs = costs
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer(enabled=False)
        # compiled steps, one per (step family, payload format); jit
        # caches per (B, L) shape under each, and batch_size_bucket
        # bounds how many shapes each one ever sees
        self._steps: dict[str, object] = {}
        self.executables: dict[tuple, int] = {}
        # (kind, B, L) -> AOT-compiled executable (or the jit fallback
        # when lowering failed); built on first execution of the triple
        self._aot: dict[tuple, object] = {}
        self.compile_times: dict[tuple, float] = {}
        self.cost_summaries: dict[tuple, dict | None] = {}
        # (family, B, L) triples with measured run-time histograms
        self.measured_keys: set[tuple] = set()
        # delta-format eligibility on the cache-less compressed path is
        # static per (family, bucket) and goes sticky-False after a
        # uint16 span overflow so persistent-overflow corpora don't pay
        # a failed delta encoding per batch (with the compressed cache
        # the verdict is per key instead)
        self._delta_ok: dict[tuple, bool] = {}
        self.stats = {"batches": 0, "compressed_batches": 0,
                      "offset_fallbacks": 0, "shared_batches": 0,
                      "compiles": 0}

    @property
    def n_executables(self) -> int:
        return len(self.executables)

    def _step(self, kind: str, max_distance: int):
        step = self._steps.get(kind)
        if step is None:
            cfg = self.config
            if kind == "base":
                step = make_qt1_serve_step(self.mesh, top_k=cfg.top_k)
            elif kind in ("delta", "offsets"):
                step = make_qt1_serve_step_compressed(
                    self.mesh, top_k=cfg.top_k, delta_g=(kind == "delta")
                )
            else:  # "qt2_raw" ... "qt5_offsets"
                qtype, payload = kind.split("_", 1)
                step = make_wv_serve_step(
                    self.mesh, qtype, top_k=cfg.top_k, payload=payload,
                    max_distance=max_distance, r_max=cfg.r_max,
                    use_pallas=cfg.use_pallas,
                )
            self._steps[kind] = step
        return step

    def _family_fns(self, family: str):
        """(assemble_fn, pack_fn, compress_fn, kind prefix, K kwargs)
        for one step family — the only place the four families differ."""
        cfg = self.config
        if family == "qt1":
            return (assemble_qt1_compressed, pack_qt1_batch,
                    compress_qt1_batch, "", {"K": cfg.k_fst})
        if family == "qt2":
            return (assemble_qt2_compressed, pack_qt2_batch,
                    compress_qt2_batch, "qt2_", {"K": cfg.k_wv})
        if family == "qt34":
            return (assemble_qt34_compressed, pack_qt34_batch,
                    compress_qt34_batch, "qt34_", {"Kn": cfg.k_ord})
        return (assemble_qt5_compressed, pack_qt5_batch,
                compress_qt5_batch, "qt5_", {"Kn": cfg.k_ns, "Ks": cfg.k_st})

    def execute(self, index, queries, selections, *, step_family, bucket,
                shared=None, payload=None):
        """Serve one (step family, L-bucket) group: chunked to
        ``config.max_batch``, each chunk padded to the power-of-two
        batch ladder and executed on the (kind, B, L) executable.
        ``shared`` (aligned with ``queries``) flags requests riding a
        foreign step family — qt34 plans converted to zero-stop qt5
        plans by the caller; a batch containing any counts as shared.
        ``payload`` is the group's planner-chosen format: ``raw`` on a
        compressed engine forces the raw pack path (the cost model's
        raw arm); None keeps the config-static behavior."""
        cfg = self.config
        out: list[ExecResult] = []
        for lo in range(0, len(queries), cfg.max_batch):
            chunk_q = queries[lo:lo + cfg.max_batch]
            chunk_s = selections[lo:lo + cfg.max_batch]
            B_pad = batch_size_bucket(len(chunk_q), cfg.max_batch)
            pad = B_pad - len(chunk_q)
            with self.tracer.span("batch", family=step_family, bucket=bucket,
                                  B=B_pad, n=len(chunk_q)) as bsp:
                t0 = time.perf_counter()
                kind, stub, args, t_pack, t_comp = self._prepare(
                    index, step_family, bucket,
                    chunk_q + [[]] * pad, chunk_s + [None] * pad, t0,
                    payload=payload,
                )
                key = (kind, B_pad, bucket)
                fn, first = self._executable_for(key, kind,
                                                 index.max_distance, args)
                t_compile = time.perf_counter()
                with self.tracer.span("dispatch", kind=kind):
                    raw = self._call(key, fn, kind, index.max_distance, args)
                t_disp = time.perf_counter()
                with self.tracer.span("execute", kind=kind, compile=first):
                    raw = jax.block_until_ready(raw)
                t_exec = time.perf_counter()
                with self.tracer.span("decode"):
                    decoded = decode_results(stub, *raw)
                t1 = time.perf_counter()
                bsp.set(kind=kind, compile=first)
            phases = {
                "pack": t_pack - t0,
                "compress": t_comp - t_pack,
                "compile": t_compile - t_comp,
                "dispatch": t_disp - t_compile,
                "execute": t_exec - t_disp,
                "decode": t1 - t_exec,
            }
            self.stats["batches"] += 1
            if shared is not None and any(shared[lo:lo + cfg.max_batch]):
                self.stats["shared_batches"] += 1
            self.executables[key] = self.executables.get(key, 0) + 1
            if not first:
                # measured step cost = dispatch + device execute, run-only
                # (first calls on the jit fallback would fold compile in)
                self.metrics.observe(
                    f"serve.step.{step_family}.B{B_pad}.L{bucket}",
                    (t_exec - t_compile) * 1e6,
                )
                # whole warm batch wall-clock (host pack/compress/decode
                # included, compile excluded): what one more batch of
                # the shape actually costs the serving loop — the
                # admission predictor's primitive; the step metric alone
                # under-predicts it badly on host-bound small batches
                self.metrics.observe(
                    f"serve.batch.{step_family}.B{B_pad}.L{bucket}",
                    ((t1 - t0) - phases["compile"]) * 1e6,
                )
                self.measured_keys.add((step_family, B_pad, bucket))
                if self.costs is not None:
                    # payload arbitration sees the whole warm batch cost
                    # (pack/compress/decode included — host encode work
                    # counts against the arm that incurs it), per padded
                    # query; compile is excluded like the step metric
                    warm_s = (t1 - t0) - phases["compile"]
                    self.costs.observe(step_family, bucket,
                                       _payload_of_kind(kind),
                                       warm_s * 1e6 / B_pad)
            payload = _payload_of_kind(kind)
            out.extend(
                ExecResult(results=decoded[bi], latency_s=t1 - t0,
                           bucket=bucket, batch_size=len(chunk_q),
                           payload=payload, started_at=t0, finished_at=t1,
                           phases=dict(phases))
                for bi in range(len(chunk_q))
            )
        return out

    # -- compile-vs-run split ----------------------------------------------
    def _executable_for(self, key, kind, max_distance, args):
        """The executable for one (kind, B, L) triple. First call per
        triple AOT-lowers and compiles the step (the ``compile`` phase)
        and captures its XLA cost_analysis summary; later calls return
        the cached executable, so their step timings are pure run."""
        fn = self._aot.get(key)
        if fn is not None:
            return fn, False
        step = self._step(kind, max_distance)
        with self.tracer.span("compile", kind=kind, B=key[1], L=key[2]):
            t0 = time.perf_counter()
            try:
                compiled = step.lower(*args).compile()
                self.cost_summaries[key] = xla_cost_summary(compiled)
                fn = compiled
            except Exception:
                # lowering is best-effort: fall back to the jit-cached
                # step (compile then happens inside the first dispatch,
                # so the split degrades gracefully instead of failing)
                self.cost_summaries[key] = None
                fn = step
            dt = time.perf_counter() - t0
        self._aot[key] = fn
        self.compile_times[key] = dt
        self.stats["compiles"] += 1
        self.metrics.observe(
            f"serve.compile.{kind}.B{key[1]}.L{key[2]}", dt * 1e6)
        return fn, True

    def _call(self, key, fn, kind, max_distance, args):
        try:
            return fn(*args)
        except (TypeError, ValueError):
            if fn is self._steps.get(kind):
                raise
            # an AOT executable is stricter about input avals than jit;
            # if a batch ever disagrees, demote the triple to the jit
            # step permanently rather than failing the drain
            step = self._step(kind, max_distance)
            self._aot[key] = step
            return step(*args)

    # -- measured-cost surface ---------------------------------------------
    def measured_step_us(self, family: str, B: int, L: int) -> float | None:
        """Measured warm batch run time (p50 µs) for one (family, B, L)
        shape — the admission controller's prediction primitive
        (DESIGN.md §17). Falls back from the exact shape to the nearest
        measured shape of the family scaled by the slot ratio
        ``(B*L) / (B'*L')`` (step work is linear in both axes); None
        when the family has no measurement at all (the caller then uses
        the unit estimate)."""
        return self._nearest_p50("serve.step", family, B, L)

    def measured_batch_us(self, family: str, B: int, L: int) -> float | None:
        """Measured warm *whole-batch* wall-clock (p50 µs, host
        pack/compress/decode included, compile excluded) for one
        (family, B, L) shape — what one more batch of the shape costs
        the serving loop, and therefore what admission control and EDF
        splitting must predict with (the run-only step metric
        under-predicts host-bound small batches badly). Same
        nearest-shape fallback as :meth:`measured_step_us`."""
        return self._nearest_p50("serve.batch", family, B, L)

    def _nearest_p50(self, metric: str, family: str, B: int,
                     L: int) -> float | None:
        hist = self.metrics.get(f"{metric}.{family}.B{B}.L{L}")
        if hist is not None and hist.count:
            return hist.percentile(50)
        best = None
        for (fam, Bm, Lm) in self.measured_keys:
            if fam != family:
                continue
            h = self.metrics.get(f"{metric}.{fam}.B{Bm}.L{Lm}")
            if h is None or not h.count:
                continue
            # prefer the measured shape closest in slot count
            dist = abs(Bm * Lm - B * L)
            if best is None or dist < best[0]:
                best = (dist, h.percentile(50) * (B * L) / (Bm * Lm))
        return best[1] if best is not None else None

    def is_warm(self, family: str, B: int, L: int) -> bool:
        """Whether some executable of the family already exists at
        (B, L) — a batch routed to a cold shape pays the first-call AOT
        compile, which admission prediction must price in."""
        return any(kb == B and kl == L and _kind_family(kind) == family
                   for (kind, kb, kl) in self._aot)

    def family_warm(self, family: str, L: int) -> bool:
        """Whether the family has *any* warm B at this L-bucket. The
        admission predictor amortizes the compile penalty once this
        holds (a new B-bucket of an already-serving (family, L) pays
        one compile over the service lifetime; pricing it into every
        singleton admit cold-rejects all traffic a drain would happily
        batch onto the warm shapes — a self-sustaining reject spiral,
        since what is never admitted never warms)."""
        return any(kl == L and _kind_family(kind) == family
                   for (kind, _kb, kl) in self._aot)

    def compile_penalty_s(self) -> float:
        """Predicted first-call compile cost for a cold (kind, B, L)
        shape: the mean of the observed AOT compile times (0.0 before
        any compile has run — a cold service has nothing better, and
        the unit step estimate dominates its predictions anyway)."""
        if not self.compile_times:
            return 0.0
        return sum(self.compile_times.values()) / len(self.compile_times)

    def measured_scalar_us(self) -> float | None:
        """Measured per-request p50 of the scalar backstop engine."""
        hist = self.metrics.get("serve.step.scalar")
        if hist is not None and hist.count:
            return hist.percentile(50)
        return None

    def measured_cost(self, family: str, bucket: int) -> dict:
        """Measured run-time percentiles for every B-bucket of one
        (step_family, L-bucket) executable, plus its compile time and
        XLA cost summary — the calibration table for ``est_step_cost``
        (µs; empty until a second batch of the shape has run)."""
        out = {}
        for (fam, B, L) in sorted(self.measured_keys):
            if fam != family or L != bucket:
                continue
            hist = self.metrics.get(f"serve.step.{fam}.B{B}.L{L}")
            if hist is None or hist.count == 0:
                continue
            snap = hist.snapshot()
            entry = {"measured_p50_us": snap["p50"],
                     "measured_p95_us": snap["p95"],
                     "measured_p99_us": snap["p99"],
                     "count": snap["count"]}
            for (kind, kb, kl), dt in self.compile_times.items():
                if kb == B and kl == L and _kind_family(kind) == fam:
                    entry["compile_us"] = dt * 1e6
                    xla = self.cost_summaries.get((kind, kb, kl))
                    if xla:
                        entry["xla"] = xla
                    break
            out[f"B{B}"] = entry
        return out

    def est_vs_measured(self, streams_of) -> dict:
        """est_step_cost calibration: per measured (family, B, L), the
        planner's estimate (padded posting slots) against the measured
        run-time p50 — ``us_per_kslot`` is the live conversion factor
        admission control needs to turn an estimate into a time budget."""
        cfg = self.config
        out = {}
        for (fam, B, L) in sorted(self.measured_keys):
            hist = self.metrics.get(f"serve.step.{fam}.B{B}.L{L}")
            if hist is None or hist.count == 0:
                continue
            est = streams_of(fam, cfg) * L * cfg.doc_shards
            p50 = hist.percentile(50)
            out[f"{fam}/B{B}/L{L}"] = {
                "est_step_cost": est,
                "measured_p50_us": p50,
                "n": hist.count,
                "us_per_kslot": p50 / (est / 1000.0),
            }
        return out

    # -- batch preparation --------------------------------------------------
    def _prepare(self, index, family, bucket, queries, selections, t0,
                 payload=None):
        """Pack (and compress) one padded batch; returns
        ``(kind, decode stub, device args, t_pack_end, t_compress_end)``
        so the caller can tile the phase timeline without gaps.
        ``payload=PAYLOAD_RAW`` forces the raw pack path even on a
        compressed engine — the cost model's raw arm; the raw and
        compressed steps of a family are bit-identical in results, so
        the choice only moves time."""
        assemble_fn, pack_fn, compress_fn, prefix, kw = self._family_fns(family)
        cfg = self.config
        ccache = self.compressed_cache
        serve_compressed = cfg.compressed and payload != PAYLOAD_RAW
        if serve_compressed and ccache is not None:
            # the per-key compressed-row cache derives raw + compressed
            # rows in one pass, so pack and compress are one phase here
            # (attributed to pack; compress reads 0)
            with self.tracer.span("pack", family=family, cached=True):
                kind, args, stub = assemble_fn(
                    index, queries, L=bucket, doc_shards=cfg.doc_shards,
                    ccache=ccache, cache=self.pack_cache, plans=selections,
                    **kw,
                )
            self._count_compressed(kind)
            t_pack = time.perf_counter()
            return kind, stub, args, t_pack, t_pack
        if not serve_compressed:
            kind = "base" if family == "qt1" else f"{family}_raw"
            with self.tracer.span("pack", family=family):
                batch = pack_fn(
                    index, queries, L=bucket, doc_shards=cfg.doc_shards,
                    cache=self.pack_cache, plans=selections, **kw,
                )
                # the host->device transfer of the packed rows belongs
                # to pack, not to whatever phase is timed next
                args = batch.device_args()
            t_pack = time.perf_counter()
            return kind, batch, args, t_pack, t_pack
        with self.tracer.span("pack", family=family):
            batch = pack_fn(
                index, queries, L=bucket, doc_shards=cfg.doc_shards,
                cache=self.pack_cache, plans=selections, **kw,
            )
        t_pack = time.perf_counter()
        with self.tracer.span("compress", family=family):
            kind, args = self._compress_batch(bucket, batch, compress_fn,
                                              prefix)
        return kind, batch, args, t_pack, time.perf_counter()

    def _compress_batch(self, bucket, batch, compress_fn, prefix=""):
        """Cache-less compressed path: whole-batch re-encode with the
        per-(family, bucket) sticky delta verdict (the
        use_compressed_cache=False fallback, kept for benchmarking)."""
        ck = (prefix, bucket)
        ok = self._delta_ok.get(ck)
        if ok is None:
            ok = delta16_aligned(bucket, self.config)
            self._delta_ok[ck] = ok
        kind = "offsets"
        if ok:
            try:
                args = compress_fn(batch, delta_g=True)
                kind = "delta"
            except ValueError:  # in-block key span overflows uint16
                self._delta_ok[ck] = False
        if kind == "offsets":
            args = compress_fn(batch, delta_g=False)
        self._count_compressed(kind)
        return prefix + kind, args

    def _count_compressed(self, kind: str) -> None:
        self.stats["compressed_batches"] += 1
        if kind.endswith("offsets"):
            self.stats["offset_fallbacks"] += 1


def _kind_family(kind: str) -> str:
    """Step-kind -> step-family name ("base"/"delta"/"offsets" are the
    qt1 payload kinds; everything else is "<family>_<payload>")."""
    return kind.split("_", 1)[0] if "_" in kind else "qt1"


class ScalarExecutor:
    """The scalar correctness backstop: wraps a per-snapshot
    :class:`ProximitySearchEngine` behind the same Executor protocol —
    every dispatch-matrix shape the static-shape steps cannot express
    is served here, bit-identical to the reference the compiled paths
    are tested against. Responses carry the same timing surface as the
    compiled path (started_at/finished_at + a phase breakdown whose
    work all lands in ``execute``), so scalar-fallback traffic is
    first-class in deadline and phase accounting."""

    def __init__(self, config, metrics: MetricsRegistry | None = None,
                 tracer: Tracer | None = None):
        self.config = config
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer(enabled=False)
        self._engine = None  # rebuilt per snapshot on first use

    def _engine_for(self, index):
        from repro.core.search import ProximitySearchEngine

        if self._engine is None or self._engine.index is not index:
            self._engine = ProximitySearchEngine(
                index, top_k=self.config.top_k, equalize_mode="bulk"
            )
        return self._engine

    def execute(self, index, queries, selections, *, step_family=None,
                bucket=None, shared=None):
        eng = self._engine_for(index)
        out = []
        with self.tracer.span("batch", family="scalar", n=len(queries)):
            for q in queries:
                t0 = time.perf_counter()
                with self.tracer.span("execute", kind="scalar"):
                    res, _ = eng.search_ids(list(q))
                t1 = time.perf_counter()
                self.metrics.observe("serve.step.scalar", (t1 - t0) * 1e6)
                phases = zero_phases()
                phases["execute"] = t1 - t0
                out.append(ExecResult(
                    results={"doc": res.doc, "start": res.start,
                             "end": res.end, "score": res.score},
                    latency_s=t1 - t0, bucket=0, batch_size=1,
                    started_at=t0, finished_at=t1, phases=phases,
                ))
        return out


def empty_results() -> dict:
    """A zero-hit result set with freshly allocated arrays — callers
    may mutate their response in place, so empty responses must never
    share buffers (the old module-level ``_EMPTY_RESULT`` dict handed
    the same four arrays to every empty response)."""
    return {"doc": np.zeros(0, np.int64), "start": np.zeros(0, np.int64),
            "end": np.zeros(0, np.int64), "score": np.zeros(0, np.float32)}
