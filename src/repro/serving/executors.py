"""Executors: the device/scalar execution layer of the serving tier
(DESIGN.md §14).

Two implementations of one :class:`Executor` protocol sit below the
:class:`repro.serving.service.SearchService` facade:

* :class:`CompiledExecutor` owns the serve-step factories and the
  per-(step kind, B, L) **executable table** — every distinct compiled
  shape ever executed, the denominator of the response-time guarantee.
  It implements dispatch-aware batching (the ROADMAP item): a ``qt34``
  group whose plan fits the QT5 step's non-stop slots is packed with
  zero stop constraints and served on the ``qt5`` executable of the
  same (B, L) — ``qt5_join`` with zero stop constraints *is*
  ``qt34_join`` — so mixed traffic compiles one executable ladder
  where it previously compiled two.
* :class:`ScalarExecutor` wraps the scalar
  :class:`repro.core.search.ProximitySearchEngine` — the correctness
  backstop every ``scalar``-route plan of the dispatch matrix falls
  back to (routing affects latency, never results).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Protocol

import numpy as np

from repro.core.jax_search import (
    assemble_qt1_compressed,
    assemble_qt2_compressed,
    assemble_qt34_compressed,
    assemble_qt5_compressed,
    batch_size_bucket,
    compress_qt1_batch,
    compress_qt2_batch,
    compress_qt34_batch,
    compress_qt5_batch,
    decode_results,
    make_qt1_serve_step,
    make_qt1_serve_step_compressed,
    make_wv_serve_step,
    pack_qt1_batch,
    pack_qt2_batch,
    pack_qt34_batch,
    pack_qt5_batch,
)
from repro.serving.planner import (
    PAYLOAD_DELTA16,
    PAYLOAD_OFFSETS,
    PAYLOAD_RAW,
    delta16_aligned,
)


@dataclass
class ExecResult:
    """Per-request execution record: the decoded results plus the
    executed shape — ``payload`` is the format actually served (a
    planner delta16 prediction downgrades to offsets when a key's
    in-block span overflows uint16), ``latency_s`` the wall-clock of
    the whole batch the request rode in, ``started_at``/``finished_at``
    the perf_counter timestamps of *that batch* (not the whole group:
    the service derives queue waits and deadline verdicts per batch)."""

    results: dict
    latency_s: float
    bucket: int
    batch_size: int
    payload: str | None = None
    started_at: float = 0.0
    finished_at: float = 0.0


class Executor(Protocol):
    """One (route, bucket) group of requests in, one ExecResult per
    request out, aligned with the inputs."""

    def execute(self, index, queries: list, selections: list, *,
                step_family: str | None, bucket: int | None,
                shared: list | None = None) -> list[ExecResult]: ...


# kind suffix -> planner payload name
_PAYLOAD_OF_KIND = {"base": PAYLOAD_RAW, "raw": PAYLOAD_RAW,
                    "delta": PAYLOAD_DELTA16, "offsets": PAYLOAD_OFFSETS}


def _payload_of_kind(kind: str) -> str:
    return _PAYLOAD_OF_KIND[kind.rsplit("_", 1)[-1] if "_" in kind else kind]


class CompiledExecutor:
    """Packs, compresses and executes padded batches on the compiled
    per-(step kind, B-bucket, L-bucket) serve steps.

    ``executables`` maps every (kind, B, L) triple ever executed to its
    batch count — the engine-stats surface tests assert B-bucket
    sharing on; ``stats["shared_batches"]`` counts qt34 groups served
    on qt5 executables."""

    def __init__(self, mesh, config, pack_cache=None, compressed_cache=None):
        self.mesh = mesh
        self.config = config
        self.pack_cache = pack_cache
        self.compressed_cache = compressed_cache
        # compiled steps, one per (step family, payload format); jit
        # caches per (B, L) shape under each, and batch_size_bucket
        # bounds how many shapes each one ever sees
        self._steps: dict[str, object] = {}
        self.executables: dict[tuple, int] = {}
        # delta-format eligibility on the cache-less compressed path is
        # static per (family, bucket) and goes sticky-False after a
        # uint16 span overflow so persistent-overflow corpora don't pay
        # a failed delta encoding per batch (with the compressed cache
        # the verdict is per key instead)
        self._delta_ok: dict[tuple, bool] = {}
        self.stats = {"batches": 0, "compressed_batches": 0,
                      "offset_fallbacks": 0, "shared_batches": 0}

    @property
    def n_executables(self) -> int:
        return len(self.executables)

    def _step(self, kind: str, max_distance: int):
        step = self._steps.get(kind)
        if step is None:
            cfg = self.config
            if kind == "base":
                step = make_qt1_serve_step(self.mesh, top_k=cfg.top_k)
            elif kind in ("delta", "offsets"):
                step = make_qt1_serve_step_compressed(
                    self.mesh, top_k=cfg.top_k, delta_g=(kind == "delta")
                )
            else:  # "qt2_raw" ... "qt5_offsets"
                qtype, payload = kind.split("_", 1)
                step = make_wv_serve_step(
                    self.mesh, qtype, top_k=cfg.top_k, payload=payload,
                    max_distance=max_distance, r_max=cfg.r_max,
                )
            self._steps[kind] = step
        return step

    def _family_fns(self, family: str):
        """(assemble_fn, pack_fn, compress_fn, kind prefix, K kwargs)
        for one step family — the only place the four families differ."""
        cfg = self.config
        if family == "qt1":
            return (assemble_qt1_compressed, pack_qt1_batch,
                    compress_qt1_batch, "", {"K": cfg.k_fst})
        if family == "qt2":
            return (assemble_qt2_compressed, pack_qt2_batch,
                    compress_qt2_batch, "qt2_", {"K": cfg.k_wv})
        if family == "qt34":
            return (assemble_qt34_compressed, pack_qt34_batch,
                    compress_qt34_batch, "qt34_", {"Kn": cfg.k_ord})
        return (assemble_qt5_compressed, pack_qt5_batch,
                compress_qt5_batch, "qt5_", {"Kn": cfg.k_ns, "Ks": cfg.k_st})

    def execute(self, index, queries, selections, *, step_family, bucket,
                shared=None):
        """Serve one (step family, L-bucket) group: chunked to
        ``config.max_batch``, each chunk padded to the power-of-two
        batch ladder and executed on the (kind, B, L) executable.
        ``shared`` (aligned with ``queries``) flags requests riding a
        foreign step family — qt34 plans converted to zero-stop qt5
        plans by the caller; a batch containing any counts as shared."""
        cfg = self.config
        out: list[ExecResult] = []
        for lo in range(0, len(queries), cfg.max_batch):
            chunk_q = queries[lo:lo + cfg.max_batch]
            chunk_s = selections[lo:lo + cfg.max_batch]
            t0 = time.perf_counter()
            B_pad = batch_size_bucket(len(chunk_q), cfg.max_batch)
            pad = B_pad - len(chunk_q)
            kind, decoded = self._run(
                index, step_family, bucket,
                chunk_q + [[]] * pad, chunk_s + [None] * pad,
            )
            t1 = time.perf_counter()
            self.stats["batches"] += 1
            if shared is not None and any(shared[lo:lo + cfg.max_batch]):
                self.stats["shared_batches"] += 1
            self.executables[(kind, B_pad, bucket)] = (
                self.executables.get((kind, B_pad, bucket), 0) + 1
            )
            payload = _payload_of_kind(kind)
            out.extend(
                ExecResult(results=decoded[bi], latency_s=t1 - t0,
                           bucket=bucket, batch_size=len(chunk_q),
                           payload=payload, started_at=t0, finished_at=t1)
                for bi in range(len(chunk_q))
            )
        return out

    def _run(self, index, family, bucket, queries, selections):
        """Pack + execute one padded batch; returns (kind, decoded)."""
        assemble_fn, pack_fn, compress_fn, prefix, kw = self._family_fns(family)
        cfg = self.config
        ccache = self.compressed_cache
        d = index.max_distance
        if cfg.compressed and ccache is not None:
            kind, args, stub = assemble_fn(
                index, queries, L=bucket, doc_shards=cfg.doc_shards,
                ccache=ccache, cache=self.pack_cache, plans=selections, **kw,
            )
            self._count_compressed(kind)
            return kind, decode_results(stub, *self._step(kind, d)(*args))
        batch = pack_fn(
            index, queries, L=bucket, doc_shards=cfg.doc_shards,
            cache=self.pack_cache, plans=selections, **kw,
        )
        if not cfg.compressed:
            kind = "base" if family == "qt1" else f"{family}_raw"
            return kind, decode_results(batch, *self._step(kind, d)(*batch.device_args()))
        kind, args = self._compress_batch(bucket, batch, compress_fn, prefix)
        return kind, decode_results(batch, *self._step(kind, d)(*args))

    def _compress_batch(self, bucket, batch, compress_fn, prefix=""):
        """Cache-less compressed path: whole-batch re-encode with the
        per-(family, bucket) sticky delta verdict (the
        use_compressed_cache=False fallback, kept for benchmarking)."""
        ck = (prefix, bucket)
        ok = self._delta_ok.get(ck)
        if ok is None:
            ok = delta16_aligned(bucket, self.config)
            self._delta_ok[ck] = ok
        kind = "offsets"
        if ok:
            try:
                args = compress_fn(batch, delta_g=True)
                kind = "delta"
            except ValueError:  # in-block key span overflows uint16
                self._delta_ok[ck] = False
        if kind == "offsets":
            args = compress_fn(batch, delta_g=False)
        self._count_compressed(kind)
        return prefix + kind, args

    def _count_compressed(self, kind: str) -> None:
        self.stats["compressed_batches"] += 1
        if kind.endswith("offsets"):
            self.stats["offset_fallbacks"] += 1


class ScalarExecutor:
    """The scalar correctness backstop: wraps a per-snapshot
    :class:`ProximitySearchEngine` behind the same Executor protocol —
    every dispatch-matrix shape the static-shape steps cannot express
    is served here, bit-identical to the reference the compiled paths
    are tested against."""

    def __init__(self, config):
        self.config = config
        self._engine = None  # rebuilt per snapshot on first use

    def _engine_for(self, index):
        from repro.core.search import ProximitySearchEngine

        if self._engine is None or self._engine.index is not index:
            self._engine = ProximitySearchEngine(
                index, top_k=self.config.top_k, equalize_mode="bulk"
            )
        return self._engine

    def execute(self, index, queries, selections, *, step_family=None,
                bucket=None, shared=None):
        eng = self._engine_for(index)
        out = []
        for q in queries:
            t0 = time.perf_counter()
            res, _ = eng.search_ids(list(q))
            t1 = time.perf_counter()
            out.append(ExecResult(
                results={"doc": res.doc, "start": res.start, "end": res.end,
                         "score": res.score},
                latency_s=t1 - t0, bucket=0, batch_size=1,
                started_at=t0, finished_at=t1,
            ))
        return out


def empty_results() -> dict:
    """A zero-hit result set with freshly allocated arrays — callers
    may mutate their response in place, so empty responses must never
    share buffers (the old module-level ``_EMPTY_RESULT`` dict handed
    the same four arrays to every empty response)."""
    return {"doc": np.zeros(0, np.int64), "start": np.zeros(0, np.int64),
            "end": np.zeros(0, np.int64), "score": np.zeros(0, np.float32)}
