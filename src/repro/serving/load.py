"""Open-loop load harness for the deadline control loop (DESIGN.md §17).

A closed-loop driver (submit a batch, drain, repeat — what
``serve_bench`` measures) can never overload the service: its arrival
rate adapts to the service's own speed, so the deadline met-rate it
reports says nothing about behaviour at a fixed *offered* rate. This
module drives :class:`repro.serving.SearchService` **open-loop**:
arrivals follow a generated schedule (Poisson, or bursty MMPP-style
on/off) that does not slow down when the service falls behind, so queue
buildup, admission verdicts, shedding and EDF splitting are exercised
exactly as a deployment would exercise them.

No threads: the harness exploits ``submit(..., arrival=t)`` arrival
backdating. The replay loop submits every request whose scheduled
instant has passed and drains whatever is queued; when a drain overruns
the schedule, the requests that "arrived" during it are submitted with
their *scheduled* perf_counter stamps, so queue waits, deadline
verdicts and admission budgets all measure the open-loop reality rather
than the submit call's lateness.

Vocabulary:

* :func:`poisson_arrivals` / :func:`bursty_arrivals` — arrival-offset
  schedules (seconds from trace start, deterministic per seed);
* :func:`run_open_loop` — replay a schedule over a query mix (e.g.
  ``repro.data.corpus.sample_mixed_queries``) against one service;
* :func:`run_closed_loop` — the adaptive baseline / capacity probe:
  submit-drain lockstep, reporting achieved QPS;
* :class:`LoadReport` — offered vs achieved QPS, met/shed/reject rates
  and per-phase latency percentiles, as plain data for benches.
"""

from __future__ import annotations

import random
import time
from dataclasses import dataclass, field

from repro.serving.admission import (
    STATUS_DEGRADED,
    STATUS_OK,
    STATUS_REJECTED,
    STATUS_SHED,
)

# statuses that were actually served by a drain (carry real results)
SERVED_STATUSES = (STATUS_OK, STATUS_DEGRADED)


def poisson_arrivals(qps: float, duration_s: float,
                     seed: int = 0) -> list[float]:
    """Offsets (seconds from trace start) of a Poisson arrival process
    at rate ``qps``, truncated to ``duration_s``. Deterministic per
    seed; i.i.d. exponential gaps."""
    if qps <= 0:
        raise ValueError(f"qps must be positive (got {qps})")
    rng = random.Random(seed)
    out, t = [], 0.0
    while True:
        t += rng.expovariate(qps)
        if t >= duration_s:
            return out
        out.append(t)


def bursty_arrivals(qps: float, duration_s: float, seed: int = 0,
                    burst_factor: float = 3.0, mean_on_s: float = 0.25,
                    mean_off_s: float = 0.75) -> list[float]:
    """Offsets of a two-state Markov-modulated (on/off) Poisson process
    with time-averaged rate ``qps``: exponential dwell times
    (``mean_on_s`` / ``mean_off_s``), arrival rate
    ``burst_factor × qps`` while *on* and whatever residual rate keeps
    the long-run average at ``qps`` while *off* (clamped at zero). The
    mean offered load matches the Poisson schedule at the same ``qps``;
    the bursts are what exercise hysteresis and shedding.

    The default factor keeps the off state *non-silent* (qps/3 here):
    the overload latch smooths backlog over admission decisions, so a
    completely silent off phase gives the controller nothing to decay
    its EWMA on and a stale latch greets the next burst — a degenerate
    trace, not a controller property worth benchmarking. With the
    default dwell split, ``burst_factor >= 4`` is exactly the silent
    regime (``qps_off = (1 - factor·0.25) / 0.75 × qps``)."""
    if qps <= 0:
        raise ValueError(f"qps must be positive (got {qps})")
    if burst_factor < 1.0:
        raise ValueError(f"burst_factor must be >= 1 (got {burst_factor})")
    qps_on = qps * burst_factor
    # time-average: (qps_on*on + qps_off*off) / (on+off) == qps
    qps_off = max(
        0.0,
        (qps * (mean_on_s + mean_off_s) - qps_on * mean_on_s) / mean_off_s,
    )
    rng = random.Random(seed)
    out, t, on = [], 0.0, True
    while t < duration_s:
        dwell = rng.expovariate(1.0 / (mean_on_s if on else mean_off_s))
        end = min(t + dwell, duration_s)
        rate = qps_on if on else qps_off
        if rate > 0:
            tt = t
            while True:
                tt += rng.expovariate(rate)
                if tt >= end:
                    break
                out.append(tt)
        t, on = end, not on
    return out


def _pctl(values: list[float], q: float) -> float:
    """Nearest-rank percentile of a non-empty list (0 for empty)."""
    if not values:
        return 0.0
    vs = sorted(values)
    k = min(len(vs) - 1, max(0, int(round(q / 100.0 * (len(vs) - 1)))))
    return vs[k]


@dataclass
class LoadReport:
    """One load run as plain data (benches serialize this verbatim).

    ``met_rate`` is over *served* deadline-carrying requests (the SLO a
    controlled service advertises for what it accepts);
    ``met_rate_offered`` charges every shed/rejected request as a miss
    (the uncontrolled-comparable number — on a service without
    admission the two coincide). ``phase_us`` maps each serving phase
    to its {p50, p95} over served requests, in microseconds."""

    mode: str                      # "open" | "closed"
    process: str                   # "poisson" | "bursty" | "lockstep"
    offered_qps: float
    achieved_qps: float
    duration_s: float
    n_offered: int
    n_served: int
    n_ok: int
    n_degraded: int
    n_rejected: int
    n_shed: int
    met_rate: float
    met_rate_offered: float
    shed_rate: float
    reject_rate: float
    queue_wait_p50_us: float
    queue_wait_p95_us: float
    e2e_p50_us: float
    e2e_p95_us: float
    phase_us: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return dict(self.__dict__)


def build_report(tickets, *, mode: str, process: str, offered_qps: float,
                 duration_s: float) -> LoadReport:
    """Fold a run's resolved tickets into a :class:`LoadReport`. Every
    ticket must be resolved (the §17 contract: rejected/shed resolve at
    submit, the rest by the drains the runner issued)."""
    n = len(tickets)
    by_status = {STATUS_OK: 0, STATUS_DEGRADED: 0,
                 STATUS_REJECTED: 0, STATUS_SHED: 0}
    served_met = served_deadlined = 0
    offered_met = offered_deadlined = 0
    waits, e2es = [], []
    phases: dict[str, list[float]] = {}
    first = last = None
    for t in tickets:
        r = t.result()
        by_status[r.status] = by_status.get(r.status, 0) + 1
        if t.deadline_s is not None:
            offered_deadlined += 1
            if r.deadline_met:
                offered_met += 1
        if r.status in SERVED_STATUSES:
            if t.deadline_s is not None:
                served_deadlined += 1
                if r.deadline_met:
                    served_met += 1
            waits.append(r.queue_wait_s * 1e6)
            e2es.append(r.e2e_s * 1e6)
            for ph, dur in r.phases.items():
                phases.setdefault(ph, []).append(dur * 1e6)
            first = (t.arrival if first is None else min(first, t.arrival))
            last = (r.finished_at if last is None
                    else max(last, r.finished_at))
    n_served = by_status[STATUS_OK] + by_status[STATUS_DEGRADED]
    span = (last - first) if (first is not None and last is not None
                              and last > first) else duration_s
    return LoadReport(
        mode=mode, process=process, offered_qps=offered_qps,
        achieved_qps=(n_served / span if span > 0 else 0.0),
        duration_s=duration_s, n_offered=n, n_served=n_served,
        n_ok=by_status[STATUS_OK], n_degraded=by_status[STATUS_DEGRADED],
        n_rejected=by_status[STATUS_REJECTED], n_shed=by_status[STATUS_SHED],
        met_rate=(served_met / served_deadlined if served_deadlined else 1.0),
        met_rate_offered=(offered_met / offered_deadlined
                          if offered_deadlined else 1.0),
        shed_rate=(by_status[STATUS_SHED] / n if n else 0.0),
        reject_rate=(by_status[STATUS_REJECTED] / n if n else 0.0),
        queue_wait_p50_us=_pctl(waits, 50), queue_wait_p95_us=_pctl(waits, 95),
        e2e_p50_us=_pctl(e2es, 50), e2e_p95_us=_pctl(e2es, 95),
        phase_us={ph: {"p50": _pctl(vs, 50), "p95": _pctl(vs, 95)}
                  for ph, vs in sorted(phases.items())},
    )


def warm_service(service, queries) -> int:
    """Warm every (step family, B-bucket, L-bucket) executable the mix
    can route to: for each distinct compiled (family, bucket) group one
    representative query is served at every B of the batch ladder, so
    an open-loop run measures steady-state serving instead of
    first-call AOT compiles (a mid-trace compile stalls the drain for
    seconds and blows every deadline behind it — deployments warm
    shapes at startup for exactly this reason). Scalar/empty routes
    need no warming. Returns the number of executables compiled."""
    reps: dict[tuple, list] = {}
    for q in queries:
        p = service.explain(q)
        if p.is_compiled:
            reps.setdefault((p.step_family, p.bucket), q)
    mb = service.config.max_batch
    ladder, B = [], 1
    while B <= mb:
        ladder.append(B)
        B *= 2
    for q in reps.values():
        for B in ladder:
            for _ in range(B):
                service.submit(q)
            service.drain()
    return service.compiled.n_executables


def _deadline_for(deadline_s, i: int):
    """Per-request offered deadline: a float applies to every request, a
    sequence is cycled (mixed-SLO traffic), None disables."""
    if deadline_s is None or isinstance(deadline_s, (int, float)):
        return deadline_s
    return deadline_s[i % len(deadline_s)]


def run_open_loop(service, queries, arrivals, *, deadline_s=0.05,
                  process: str = "poisson", offered_qps: float | None = None,
                  idle_sleep_s: float = 0.0005) -> LoadReport:
    """Replay an arrival schedule open-loop against ``service``.

    ``queries`` (a list of lemma-id lists, cycled) is the query mix;
    ``arrivals`` the offset schedule (:func:`poisson_arrivals` /
    :func:`bursty_arrivals`). The loop submits every request whose
    scheduled instant has passed — backdated to that instant — then
    drains whatever queued; arrivals do **not** wait for the service.
    Returns the :class:`LoadReport`; every ticket is resolved on
    return (one final drain sweeps the stragglers)."""
    if not arrivals:
        raise ValueError("empty arrival schedule")
    if not queries:
        raise ValueError("empty query mix")
    duration = arrivals[-1]
    if offered_qps is None:
        offered_qps = len(arrivals) / duration if duration > 0 else 0.0
    tickets = []
    t0 = time.perf_counter()
    i, n = 0, len(arrivals)
    while i < n:
        now = time.perf_counter()
        due = False
        while i < n and t0 + arrivals[i] <= now:
            tickets.append(service.submit(
                queries[i % len(queries)],
                deadline_s=_deadline_for(deadline_s, i),
                arrival=t0 + arrivals[i],
            ))
            i += 1
            due = True
        if due and service._queue:
            service.drain()
        elif i < n:
            # ahead of schedule: yield until the next scheduled arrival
            time.sleep(min(idle_sleep_s,
                           max(0.0, t0 + arrivals[i] - time.perf_counter())))
    service.drain()
    return build_report(tickets, mode="open", process=process,
                        offered_qps=offered_qps, duration_s=duration)


def run_closed_loop(service, queries, n_requests: int, *, deadline_s=0.05,
                    batch: int = 1) -> LoadReport:
    """The adaptive baseline: submit ``batch`` requests, drain, repeat —
    arrival rate is whatever the service sustains, so queue buildup is
    impossible by construction. The report's ``achieved_qps`` is the
    service's capacity on this mix (load benches calibrate their
    open-loop offered rates against it)."""
    if batch < 1:
        raise ValueError(f"batch must be >= 1 (got {batch})")
    tickets = []
    t0 = time.perf_counter()
    i = 0
    while i < n_requests:
        for _ in range(min(batch, n_requests - i)):
            tickets.append(service.submit(
                queries[i % len(queries)],
                deadline_s=_deadline_for(deadline_s, i)))
            i += 1
        service.drain()
    duration = time.perf_counter() - t0
    qps = n_requests / duration if duration > 0 else 0.0
    return build_report(tickets, mode="closed", process="lockstep",
                        offered_qps=qps, duration_s=duration)
