"""Packed-posting serve cache (DESIGN.md §11).

The paper's premise is that *frequently occurring* words dominate the
query stream — which makes the serve path's host-side packing worst
exactly where traffic is hottest: every drain re-read and re-padded the
postings of the same few stop-word keys. ``PackedPostingCache`` memoizes
the fully padded, range-partitioned ``(g, lo, hi)`` device rows that
``pack_fst_key_rows`` derives for one (f,s,t) key at one (L, doc_shards)
bucket, so packing a batch degenerates to B*K row copies.

Invalidation rule: entries are valid only for the snapshot they were
packed against. The cache tracks a single current ``snapshot_token``
(``repro.index.segmented.snapshot_token``: a process-unique id minted per
``SegmentedView``, or ``id()`` of a static immutable ``ProximityIndex``);
the first lookup against a *different* snapshot clears everything — so
``SegmentedIndex.refresh()`` invalidates naturally, and a stale row can
never be served (the token is part of admission, not of the entry key).

Bounded by both an entry count and a byte budget (LRU eviction); hits,
misses, evictions, invalidations and resident bytes are surfaced via
``.stats`` and re-exported in ``SearchServingEngine.stats``.
"""

from __future__ import annotations

import threading
from collections import OrderedDict

import numpy as np

from repro.core.jax_search import pack_fst_key_rows
from repro.index.segmented import snapshot_token
from repro.kernels.common import SENTINEL


class PackedPostingCache:
    """LRU cache of padded (g, lo, hi, present) rows for one snapshot."""

    def __init__(self, max_entries: int = 4096, max_bytes: int = 256 << 20):
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self._entries: OrderedDict = OrderedDict()  # positive: ck -> (rows, nbytes)
        self._absent: OrderedDict = OrderedDict()  # negative: ck -> rows
        self._token = None
        self._token_ref = None  # keeps the token's index alive (id() reuse)
        self._bytes = 0
        self._sentinel_rows: dict = {}  # L -> shared all-SENTINEL row
        self._lock = threading.Lock()
        self._counts = {"hits": 0, "misses": 0, "evictions": 0, "invalidations": 0}

    # -- lookups ----------------------------------------------------------
    def get_rows(self, index, key, L: int, doc_shards: int = 1, stride: int | None = None):
        """Rows for `key` at bucket (L, doc_shards), packed against
        `index`'s current snapshot. Same contract as
        ``pack_fst_key_rows``: three (L,) int32 arrays (read-only — they
        are shared across batches, and alias one SENTINEL row when the
        key is absent) plus a present flag. `stride` (snapshot-constant)
        avoids an O(n_docs) re-derivation per miss when the caller
        already has it."""
        # pin the immutable snapshot FIRST: given a mutable SegmentedIndex,
        # token and row derivation must see the same view even if a
        # refresh() publishes a new one mid-derivation
        if hasattr(index, "snapshot"):
            index = index.snapshot()
        tok = snapshot_token(index)
        ck = (key, L, doc_shards)
        with self._lock:
            if tok != self._token:
                if self._entries or self._absent:
                    self._counts["invalidations"] += 1
                self._entries.clear()
                self._absent.clear()
                self._bytes = 0
                self._token = tok
                # pin the token's index: for static indexes the token is
                # id(), which must not be freed and reused while entries
                # keyed under it are resident
                self._token_ref = index
            ent = self._entries.get(ck)
            if ent is not None:
                self._entries.move_to_end(ck)
                self._counts["hits"] += 1
                return ent[0]
            neg = self._absent.get(ck)
            if neg is not None:
                self._absent.move_to_end(ck)
                self._counts["hits"] += 1
                return neg
            self._counts["misses"] += 1
        # derive outside the lock: merged segment reads can be slow and
        # must not serialize concurrent serving threads
        g, lo, hi, present = pack_fst_key_rows(index, key, L, doc_shards, stride)
        if not present:
            # negative entry: callers never read non-present rows, so all
            # three alias one shared per-L SENTINEL row (0 bytes) and live
            # in a separate LRU — a stream of distinct absent keys must
            # not evict genuinely hot positive rows
            rows = (self._shared_sentinel(L),) * 3 + (False,)
            with self._lock:
                if tok != self._token:
                    return rows  # a refresh raced the derivation: don't admit
                self._absent[ck] = rows
                while len(self._absent) > self.max_entries:
                    self._absent.popitem(last=False)
                    self._counts["evictions"] += 1
            return rows
        for a in (g, lo, hi):
            a.setflags(write=False)
        nbytes = g.nbytes + lo.nbytes + hi.nbytes
        rows = (g, lo, hi, present)
        with self._lock:
            if tok != self._token:
                return rows  # a refresh raced the derivation: don't admit
            if ck not in self._entries:
                self._entries[ck] = (rows, nbytes)
                self._bytes += nbytes
                while len(self._entries) > self.max_entries or (
                    self._bytes > self.max_bytes and len(self._entries) > 1
                ):
                    _, (_, nb) = self._entries.popitem(last=False)
                    self._bytes -= nb
                    self._counts["evictions"] += 1
        return rows

    def _shared_sentinel(self, L: int):
        row = self._sentinel_rows.get(L)
        if row is None:
            row = np.full(L, SENTINEL, np.int32)
            row.setflags(write=False)
            self._sentinel_rows[L] = row
        return row

    # -- introspection ----------------------------------------------------
    @property
    def stats(self) -> dict:
        with self._lock:
            c = dict(self._counts)
            c["entries"] = len(self._entries)
            c["negative_entries"] = len(self._absent)
            c["bytes"] = self._bytes
        total = c["hits"] + c["misses"]
        c["hit_rate"] = c["hits"] / total if total else 0.0
        return c

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._absent.clear()
            self._bytes = 0
            self._token = None
            self._token_ref = None
