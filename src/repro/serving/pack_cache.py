"""Packed-posting serve cache (DESIGN.md §11-§12).

The paper's premise is that *frequently occurring* words dominate the
query stream — which makes the serve path's host-side packing worst
exactly where traffic is hottest: every drain re-read and re-padded the
postings of the same few hot keys. ``PackedPostingCache`` memoizes the
fully padded, range-partitioned device rows that the per-key packers in
``core.jax_search`` derive for one key at one (L, doc_shards) bucket, so
packing a batch degenerates to B*K row copies.

Row kinds (one cache instance can hold any mix; entries are keyed by
``(kind, key, L, doc_shards)``):

* ``"fst"`` — (g, lo, hi) rows of one (f,s,t) key (QT1);
* ``"wv"``  — (lo, hi) interval rows of one (w,v) key (QT2);
* ``"ord"`` — the g row of one lemma's ordinary postings, shared by the
  QT3/QT4 ordinary-window path and the QT5 anchor/non-stop streams: a
  lemma hot on either path warms both (DESIGN.md §13);
* ``"nsw"`` — (cnt, ext) NSW aggregates of one (anchor, stop) pair (QT5);
* ``"fst_c" / "wv_c" / "ord_c" / "nsw_c"`` — the block-delta16-compressed
  form of the same rows (base, delta16, uint8 side channels, delta_ok).
  Compressed kinds derive from the base kind's rows — via ``source``
  (typically the engine's raw-row cache) so a warm raw cache makes
  compressed misses cheap.

Invalidation rule: entries are valid only for the snapshot they were
packed against. The cache tracks a single current ``snapshot_token``
(``repro.index.segmented.snapshot_token``); the first lookup against a
*different* snapshot invalidates — but across a **benign** transition,
entries whose key no *fresh* segment touches are *retained* instead of
dropped: the merged rows of an untouched key are bitwise identical
across such snapshots. Benign covers add-only refreshes (fresh = the
newly sealed segments), **pure background compactions** (a merge output
whose ``derived_from`` lineage lies inside the old segment set and whose
doc set is exactly its victims' minus the old tombstones contributes
*no* fresh segments — global doc ids are merge-stable, so rows are
bitwise unchanged; DESIGN.md §18), dead-segment drops, and live memtable
overlays (fresh = the overlay). Any other transition (new deletes,
stride growth, unprovable lineage) clears everything, so a stale row can
never be served.

Bounded by both an entry count and a byte budget (LRU eviction); hits,
misses, evictions, invalidations, retentions and resident bytes are
surfaced via ``.stats`` and re-exported in ``SearchServingEngine.stats``.
Given a :class:`repro.obs.MetricsRegistry` (``metrics=``, with a
``scope`` name prefix), the cache additionally streams hit/miss
counters, a resident-bytes gauge and a per-miss derivation-time
histogram into it (DESIGN.md §15) — the same registry the serving
phases land in, so a drain's pack phase can be decomposed into cache
hits vs row derivations.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict

import numpy as np

from repro.core.jax_search import (
    compress_fst_rows,
    compress_nsw_rows,
    compress_ord_rows,
    compress_wv_rows,
    pack_fst_key_rows,
    pack_nsw_key_rows,
    pack_ord_key_rows,
    pack_wv_key_rows,
    qt1_stride,
)
from repro.index.merge import isin_sorted
from repro.index.segmented import snapshot_token
from repro.kernels.common import SENTINEL

_DERIVERS = {
    "fst": pack_fst_key_rows,
    "wv": pack_wv_key_rows,
    "ord": pack_ord_key_rows,
    "nsw": pack_nsw_key_rows,
}
_COMPRESSORS = {
    "fst_c": compress_fst_rows,
    "wv_c": compress_wv_rows,
    "ord_c": compress_ord_rows,
    "nsw_c": compress_nsw_rows,
}


def _base_kind(kind: str) -> str:
    return kind[:-2] if kind.endswith("_c") else kind


def _key_in_segment(kind: str, key, seg_index) -> bool:
    """Whether a segment's index could contribute postings to this entry
    (the add-only retention test). NSW aggregates are keyed by the anchor
    lemma: new anchor postings change both the row length and the
    renumbering, while a segment without the anchor cannot add records."""
    base = _base_kind(kind)
    if base == "fst":
        store = seg_index.fst
    elif base == "wv":
        store = seg_index.wv
    elif base == "ord":
        store = seg_index.ordinary
    else:  # "nsw": key = (anchor, sid)
        store = seg_index.ordinary
        key = key[0]
    return store is not None and key in store


class PackedPostingCache:
    """LRU cache of padded per-key device rows for one snapshot."""

    def __init__(self, max_entries: int = 4096, max_bytes: int = 256 << 20,
                 source: "PackedPostingCache | None" = None,
                 metrics=None, scope: str = "cache"):
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self.source = source  # raw-row cache compressed kinds derive from
        self._metrics = metrics  # optional repro.obs.MetricsRegistry
        self._scope = scope
        self._entries: OrderedDict = OrderedDict()  # positive: ck -> (rows, nbytes)
        self._absent: OrderedDict = OrderedDict()  # negative: ck -> rows
        self._token = None
        self._token_ref = None  # keeps the token's index alive (id() reuse)
        self._bytes = 0
        self._sentinel_rows: dict = {}  # (kind, L) -> shared padding rows
        self._lock = threading.Lock()
        self._counts = {"hits": 0, "misses": 0, "evictions": 0,
                        "invalidations": 0, "retained": 0}

    # -- lookups ----------------------------------------------------------
    def get_rows(self, index, key, L: int, doc_shards: int = 1, stride: int | None = None):
        """Padded ``(g, lo, hi, present)`` device rows of one (f,s,t) key.

        The original QT1 entry point — shorthand for
        ``get(index, "fst", key, L, doc_shards, stride)``; see
        :meth:`get` for the lookup/invalidation contract. ``key`` is a
        ``(f, s, t)`` lemma-id triple; the three ``(L,)`` int32 rows are
        read-only and shared across batches, and ``present`` is False
        when the key does not exist in the snapshot (the rows are then a
        shared all-SENTINEL padding set)."""
        return self.get(index, "fst", key, L, doc_shards, stride)

    def get(self, index, kind: str, key, L: int, doc_shards: int = 1,
            stride: int | None = None):
        """Rows for `key` at bucket (L, doc_shards), packed against
        `index`'s current snapshot. The returned tuple matches the kind's
        packer/compressor contract and ends with a present flag; arrays
        are read-only (shared across batches; absent keys alias one
        padding row set per (kind, L)). `stride` (snapshot-constant)
        avoids an O(n_docs) re-derivation per miss when the caller
        already has it."""
        # pin the immutable snapshot FIRST: given a mutable SegmentedIndex,
        # token and row derivation must see the same view even if a
        # refresh() publishes a new one mid-derivation
        if hasattr(index, "snapshot"):
            index = index.snapshot()
        tok = snapshot_token(index)
        ck = (kind, key, L, doc_shards)
        with self._lock:
            if tok != self._token:
                if self._entries or self._absent:
                    self._counts["invalidations"] += 1
                    self._retain_or_clear(index)
                self._token = tok
                # pin the token's index: for static indexes the token is
                # id(), which must not be freed and reused while entries
                # keyed under it are resident
                self._token_ref = index
            ent = self._entries.get(ck)
            if ent is not None:
                self._entries.move_to_end(ck)
                self._counts["hits"] += 1
                if self._metrics is not None:
                    self._metrics.inc(f"{self._scope}.hits")
                return ent[0]
            neg = self._absent.get(ck)
            if neg is not None:
                self._absent.move_to_end(ck)
                self._counts["hits"] += 1
                if self._metrics is not None:
                    self._metrics.inc(f"{self._scope}.hits")
                return neg
            self._counts["misses"] += 1
        # derive outside the lock: merged segment reads can be slow and
        # must not serialize concurrent serving threads
        t_derive = time.perf_counter()
        rows = self._derive(index, kind, key, L, doc_shards, stride)
        if self._metrics is not None:
            self._metrics.inc(f"{self._scope}.misses")
            self._metrics.observe(f"{self._scope}.derive_us",
                                  (time.perf_counter() - t_derive) * 1e6)
            self._metrics.set(f"{self._scope}.bytes", self._bytes)
        if not rows[-1]:  # not present
            # negative entry: callers never read non-present rows, so they
            # alias one shared per-(kind, L) padding row set (0 bytes) and
            # live in a separate LRU — a stream of distinct absent keys
            # must not evict genuinely hot positive rows
            rows = self._shared_sentinel(kind, L)
            with self._lock:
                if tok != self._token:
                    return rows  # a refresh raced the derivation: don't admit
                self._absent[ck] = rows
                while len(self._absent) > self.max_entries:
                    self._absent.popitem(last=False)
                    self._counts["evictions"] += 1
            return rows
        nbytes = 0
        for a in rows[:-1]:
            if isinstance(a, np.ndarray):
                a.setflags(write=False)
                nbytes += a.nbytes
        with self._lock:
            if tok != self._token:
                return rows  # a refresh raced the derivation: don't admit
            if ck not in self._entries:
                self._entries[ck] = (rows, nbytes)
                self._bytes += nbytes
                while len(self._entries) > self.max_entries or (
                    self._bytes > self.max_bytes and len(self._entries) > 1
                ):
                    _, (_, nb) = self._entries.popitem(last=False)
                    self._bytes -= nb
                    self._counts["evictions"] += 1
        return rows

    def _derive(self, index, kind, key, L, doc_shards, stride):
        packer = _DERIVERS.get(kind)
        if packer is not None:
            return packer(index, key, L, doc_shards, stride)
        compressor = _COMPRESSORS[kind]
        src = self.source if self.source is not None else self
        raw = src.get(index, _base_kind(kind), key, L, doc_shards, stride)
        return compressor(raw)

    def _shared_sentinel(self, kind: str, L: int):
        rows = self._sentinel_rows.get((kind, L))
        if rows is None:
            pad = np.full(L, SENTINEL, np.int32)
            zero = np.zeros(L, np.int32)
            if kind in ("fst", "wv", "ord"):
                n = {"fst": 3, "wv": 2, "ord": 1}[kind]
                rows = (pad,) * n + (False,)
            elif kind == "nsw":
                rows = (zero, zero, False)
            else:  # compressed kinds: run the compressor on padding rows
                base = _base_kind(kind)
                raw = self._shared_sentinel(base, L)
                rows = _COMPRESSORS[kind](raw)
                rows = rows[:-1] + (False,)
            for a in rows[:-1]:
                if isinstance(a, np.ndarray):
                    a.setflags(write=False)
            self._sentinel_rows[(kind, L)] = rows
        return rows

    # -- invalidation / cross-snapshot retention --------------------------
    def _retain_or_clear(self, new_index) -> None:
        """Called under the lock when the snapshot token changes. When the
        transition is benign (add-only refresh and/or pure background
        compaction, DESIGN.md §18), keep entries whose key no *fresh*
        segment touches; otherwise clear everything."""
        fresh = self._fresh_segments(new_index)
        if fresh is None:
            self._entries.clear()
            self._absent.clear()
            self._bytes = 0
            return
        n_docs_changed = (
            self._token_ref.doc_lengths.size != new_index.doc_lengths.size
        )
        for store in (self._entries, self._absent):
            for ck in list(store.keys()):
                kind, key, L, doc_shards = ck
                # range-partition bounds depend on the total doc count
                stale = doc_shards > 1 and n_docs_changed
                stale = stale or any(
                    _key_in_segment(kind, key, seg.index) for seg in fresh
                )
                if stale:
                    ent = store.pop(ck)
                    if store is self._entries:
                        self._bytes -= ent[1]
                else:
                    self._counts["retained"] += 1

    def _fresh_segments(self, new_index):
        """Classify the snapshot transition: the list of segments that can
        make an entry stale (newly sealed segments + live memtable
        overlays), or None when the transition is not provably benign and
        the cache must clear.

        The merge-aware rules (DESIGN.md §18) rest on two invariants of
        ``repro.index``: global doc ids are stable across compactions, and
        ``merged_key_read`` applies tombstones at read time. A compaction
        output whose immediate lineage (``Segment.derived_from``) lies
        inside the old snapshot's segment set — and whose doc set equals
        exactly its victims' docs minus the *old* tombstones — therefore
        carries bitwise the same merged rows its victims did, so entries
        survive it untouched. Any new tombstone, a stride change, a merge
        that dropped docs the old snapshot still served, or a live old
        segment vanishing un-merged clears the cache."""
        old = self._token_ref
        if old is None or new_index is old:
            return None
        for view in (old, new_index):
            if not (hasattr(view, "segments") and hasattr(view, "tombstones")):
                return None
        old_t, new_t = old.tombstones, new_index.tombstones
        if np.setdiff1d(new_t, old_t).size:
            return None  # new deletes: keys over those docs went stale
        if qt1_stride(old) != qt1_stride(new_index):
            return None  # a longer doc moved every packed g value
        old_overlay = getattr(old, "mem_overlay", None)
        new_overlay = getattr(new_index, "mem_overlay", None)
        old_idents, old_by_id = set(), {}
        for s in old.segments:
            old_idents.add(id(s))
            if s is not old_overlay:
                old_by_id[s.segment_id] = s
        fresh, covered = [], set()
        for s in new_index.segments:
            if id(s) in old_idents:
                continue  # carried over unchanged (identity)
            if s is new_overlay or getattr(s, "is_live", False):
                fresh.append(s)  # overlay stales exactly the keys it holds
                continue
            dfrom = set(getattr(s, "derived_from", ()) or ())
            if dfrom and dfrom <= set(old_by_id):
                victims = [old_by_id[i] for i in dfrom]
                want = np.concatenate([v.doc_map for v in victims])
                want = np.sort(want[~isin_sorted(old_t, want)])
                if np.array_equal(want, s.doc_map):
                    covered |= dfrom  # pure compaction: rows bitwise equal
                    continue
                return None  # merge dropped docs the old snapshot served
            fresh.append(s)  # newly sealed (or unprovable lineage)
        new_idents = {id(s) for s in new_index.segments}
        for s in old.segments:
            if id(s) in new_idents or s.segment_id in covered or s is old_overlay:
                continue
            if not bool(np.all(isin_sorted(old_t, s.doc_map))):
                return None  # a live old segment vanished un-merged
        if old_overlay is not None and id(old_overlay) not in new_idents:
            # entries packed while an overlay was live may embed its
            # postings; they are stale exactly where the overlay had keys
            fresh.append(old_overlay)
        return fresh

    # -- introspection ----------------------------------------------------
    @property
    def stats(self) -> dict:
        with self._lock:
            c = dict(self._counts)
            c["entries"] = len(self._entries)
            c["negative_entries"] = len(self._absent)
            c["bytes"] = self._bytes
        total = c["hits"] + c["misses"]
        c["hit_rate"] = c["hits"] / total if total else 0.0
        return c

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._absent.clear()
            self._bytes = 0
            self._token = None
            self._token_ref = None
