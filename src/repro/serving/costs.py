"""Measured-cost models for the serving tier: cost-driven payload
arbitration for the planner (DESIGN.md §16) and the step-cost
predictor behind admission control and EDF group splitting
(:class:`StepCostPredictor`, DESIGN.md §17).

The static payload rule ("compressed engine => delta16 when the bucket
is block-aligned, else offsets") encodes a bytes-per-posting argument,
but what the response-time guarantee cares about is *measured* warm
batch time — and PR 6's calibration showed the compressed payload
winning on some routes (QT4) while losing on others (QT3) at the same
bucket. :class:`PayloadCostModel` closes that loop: per (step_family,
L-bucket) it keeps a warm per-query EWMA for each payload *arm* —
``raw`` vs the static rule's compressed format — explores both briefly,
then routes the group to the measured argmin, re-probing the losing arm
every ``probe_every`` winner observations so a probe window that landed
on cache-cold drains cannot pin a stale verdict.

Integration contract:

* ``choose(family, bucket, static_payload)`` is consulted by
  ``planner._payload`` only when the engine is compressed (raw engines
  have a single candidate). Exploration order is compressed-arm first:
  short-lived services behave exactly like the static rule (the
  existing compressed-serving tests pin that), and only sustained
  traffic pays the one-off raw probe.
* ``observe(family, bucket, payload, us_per_query)`` is fed by the
  executor from *warm* batches only (first-call compiles are excluded,
  as in the ``serve.step.*`` histograms), with the whole warm batch
  wall-clock — pack/compress/decode included — divided by the padded
  batch size, so host-side encode costs count against the arm that
  incurs them.
* ``generation`` increments whenever the *effective* choice for some
  (family, bucket) changes — exploration-phase transitions (compressed
  arm sampled -> raw probe window -> measured argmin) as well as later
  EWMA flips; the service keys its plan memo on it, so memoized plans
  can never pin a stale payload or starve the raw probe.

The model is intentionally tiny (dict + EWMA, no locking beyond the
GIL): it arbitrates between two arms whose measured gap on the routes
that matter is tens of percent, far beyond EWMA noise.

:class:`RecallCostModel` (DESIGN.md §19) applies the same
measure-don't-assume move to degraded admits: instead of ordering
degrade candidates largest-prefix-first (a proxy for least
degradation), it tracks the measured result-count ratio of each
degraded (family, bucket) against the family's full route and orders
candidates by retained recall — with the prefix fraction as the
unmeasured prior, so a cold model reproduces the old ordering.
"""

from __future__ import annotations

from repro.serving.planner import PAYLOAD_RAW

# observations of an arm before the other arm is explored / the argmin
# is trusted; EWMA weight of the newest observation; winner observations
# between re-probes of the losing arm
MIN_SAMPLES = 2
ALPHA = 0.4
PROBE_EVERY = 16

# recall-cost tracking (degraded admits, DESIGN.md §19): observations
# per (family, bucket) before a measured recall is trusted over the
# prefix-fraction prior; EWMA weight
RECALL_MIN_SAMPLES = 4
RECALL_ALPHA = 0.3


def _arm(payload: str) -> str:
    """delta16 and offsets are one arm: which of them serves is the
    packer's uint16-overflow verdict, not a planner choice."""
    return PAYLOAD_RAW if payload == PAYLOAD_RAW else "compressed"


class StepCostPredictor:
    """Predicted wall-clock batch cost per (step_family, B, L-bucket) —
    the admission controller's time model (DESIGN.md §17).

    Prediction order, per shape:

    1. the live measured ``serve.batch.*`` whole-batch p50 (host
       pack/compress/decode included) for the exact or nearest measured
       shape of the family, scaled by the slot ratio — step work is
       linear in B and L; the run-only ``serve.step.*`` p50 backs it
       up when only the step metric exists;
    2. the *unit estimate* when no measurement exists: the planner's
       ``est_step_cost`` slots converted at
       ``config.unit_us_per_kslot`` — deliberately crude, but it makes
       a cold controller monotone in the same shape variables the
       measured model is, so admission decisions degrade gracefully
       instead of being unavailable;

    plus the mean observed AOT compile time whenever the shape has no
    executable yet (a batch routed to a cold shape pays the first-call
    compile, and admission/splitting must not pretend it is free). Two
    warmth regimes:

    * default (admission, backlog, drain horizon): the penalty applies
      only while the whole (family, L-bucket) is cold at *every* B —
      once some B serves warm, a new B-bucket's one-off compile is
      amortized over the service lifetime. Pricing it into every
      admit would cold-reject all traffic whose exact B never ran,
      and what is never admitted never warms (a reject spiral);
    * ``strict_warm=True`` (EDF split decisions): the exact (B, L)
      shape must be warm — a mid-drain split onto a cold B pays the
      compile *inside* the very deadline it is trying to save, so the
      split planner must see the true first-call cost.

    ``headroom`` scales every prediction: measured p50s under-predict
    the tail the deadline verdict is judged on, so the controller plans
    against ``headroom ×`` the median."""

    def __init__(self, executor, config, streams_of):
        self.executor = executor
        self.config = config
        self.streams_of = streams_of
        # per-shape prediction memo: reading a measured p50 sorts the
        # histogram's sample window, which is far too expensive to do
        # per candidate per submit at serving rates — the admission
        # path would stall the very drains it schedules around.
        # invalidate() is called once per drain (the only place new
        # measurements land), so between drains predictions are O(1)
        self._memo: dict[tuple, float] = {}

    def invalidate(self) -> None:
        """Drop memoized predictions (new measurements just landed)."""
        self._memo.clear()

    def batch_s(self, family: str, B: int, bucket: int,
                strict_warm: bool = False) -> float:
        key = (family, B, bucket, strict_warm)
        cached = self._memo.get(key)
        if cached is not None:
            return cached
        cfg = self.config
        us = self.executor.measured_batch_us(family, B, bucket)
        if us is None:
            us = self.executor.measured_step_us(family, B, bucket)
        if us is None:
            slots = self.streams_of(family, cfg) * bucket * cfg.doc_shards
            us = cfg.unit_us_per_kslot * B * slots / 1000.0
        warm = (self.executor.is_warm(family, B, bucket) if strict_warm
                else self.executor.family_warm(family, bucket))
        if not warm:
            us += self.executor.compile_penalty_s() * 1e6
        out = us * cfg.admission_headroom / 1e6
        self._memo[key] = out
        return out

    def scalar_s(self) -> float:
        """Per-request cost of the scalar backstop engine."""
        cached = self._memo.get("scalar")
        if cached is not None:
            return cached
        us = self.executor.measured_scalar_us()
        if us is None:
            us = self.config.unit_scalar_us
        out = us * self.config.admission_headroom / 1e6
        self._memo["scalar"] = out
        return out


class RecallCostModel:
    """Measured recall cost of degraded buckets (DESIGN.md §19).

    A degraded admit serves a *truncated posting prefix*
    (``planner.degrade``): its results are a subset of the full
    route's, and how much of the result set a given bucket retains is
    an empirical property of the posting distribution — not of the
    prefix fraction alone (hot lemmas front-load their postings in
    low doc ids; a quarter-length prefix can retain most results).
    Before this model, the admission controller ordered degrade
    candidates largest-prefix-first as a proxy for least degradation;
    this model replaces the proxy with the measured result-count ratio:

    * ``observe_full(family, n)`` — result count of a full-route
      compiled response (the per-family denominator);
    * ``observe_degraded(family, bucket, n)`` — result count of a
      response served from the degraded bucket;
    * ``recall(family, bucket)`` — EWMA(degraded) / EWMA(full), or
      None until both sides have ``min_samples`` observations;
    * ``order(family, buckets, planned_bucket)`` — degrade candidates
      sorted by estimated retained recall, best first. Unmeasured
      buckets use the prefix fraction ``bucket / planned_bucket`` as
      the prior, so a cold model reproduces the old largest-first
      ordering exactly (the static behaviour stays the fallback)."""

    def __init__(self, min_samples: int = RECALL_MIN_SAMPLES,
                 alpha: float = RECALL_ALPHA):
        self.min_samples = min_samples
        self.alpha = alpha
        self._full: dict[str, float] = {}       # family -> EWMA count
        self._full_n: dict[str, int] = {}
        self._deg: dict[tuple, float] = {}      # (family, bucket) -> EWMA
        self._deg_n: dict[tuple, int] = {}

    def _ewma(self, table: dict, key, value: float) -> None:
        prev = table.get(key)
        table[key] = (value if prev is None
                      else prev + self.alpha * (value - prev))

    def observe_full(self, family: str, n_results: int) -> None:
        self._ewma(self._full, family, float(n_results))
        self._full_n[family] = self._full_n.get(family, 0) + 1

    def observe_degraded(self, family: str, bucket: int,
                         n_results: int) -> None:
        key = (family, bucket)
        self._ewma(self._deg, key, float(n_results))
        self._deg_n[key] = self._deg_n.get(key, 0) + 1

    def recall(self, family: str, bucket: int) -> float | None:
        """Measured retained-recall estimate, or None while either side
        is under-sampled (an ordering must not flap on one batch)."""
        key = (family, bucket)
        if (self._deg_n.get(key, 0) < self.min_samples
                or self._full_n.get(family, 0) < self.min_samples):
            return None
        full = self._full.get(family, 0.0)
        if full <= 0.0:
            return None
        return min(1.0, self._deg[key] / full)

    def order(self, family: str, buckets, planned_bucket: int) -> list:
        """Degrade candidates best-recall-first; measured recall where
        it exists, the prefix fraction as the prior elsewhere. Ties
        break to the larger bucket (the superset per request)."""
        def key(b):
            r = self.recall(family, b)
            if r is None:
                r = b / planned_bucket if planned_bucket > 0 else 0.0
            return (-r, -b)
        return sorted(buckets, key=key)

    def table(self) -> dict:
        """Plain-data snapshot for ``stats["admission"]["recall"]``."""
        out: dict = {}
        for (family, bucket), ew in sorted(self._deg.items()):
            out[f"{family}/L{bucket}"] = {
                "recall": self.recall(family, bucket),
                "degraded_ewma_results": ew,
                "n": self._deg_n[(family, bucket)],
            }
        for family, ew in sorted(self._full.items()):
            out[f"{family}/full"] = {
                "full_ewma_results": ew,
                "n": self._full_n[family],
            }
        return out


class PayloadCostModel:
    """Measured per-(step_family, L-bucket) payload arbitration."""

    def __init__(self, min_samples: int = MIN_SAMPLES, alpha: float = ALPHA,
                 probe_every: int = PROBE_EVERY):
        self.min_samples = min_samples
        self.alpha = alpha
        self.probe_every = probe_every
        self._stale: dict[tuple, int] = {}  # winner obs since loser sampled
        self._ewma: dict[tuple, float] = {}  # (family, bucket, arm) -> us
        self._count: dict[tuple, int] = {}
        self._chosen: dict[tuple, str] = {}  # (family, bucket) -> arm
        self._phases: dict[tuple, str] = {}  # (family, bucket) -> phase
        self.generation = 0

    def observe(self, family: str, bucket: int, payload: str,
                us_per_query: float) -> None:
        key = (family, bucket, _arm(payload))
        prev = self._ewma.get(key)
        self._ewma[key] = (us_per_query if prev is None
                           else prev + self.alpha * (us_per_query - prev))
        self._count[key] = self._count.get(key, 0) + 1
        gk = (family, bucket)
        winner = self._argmin(family, bucket)
        if winner is not None:
            self._stale[gk] = (self._stale.get(gk, 0) + 1
                               if _arm(payload) == winner else 0)
        now = self._phase(family, bucket)
        if self._phases.get(gk, "explore_compressed") != now:
            self._phases[gk] = now
            if now in ("compressed", PAYLOAD_RAW):
                self._chosen[gk] = now
            self.generation += 1

    def _phase(self, family: str, bucket: int) -> str:
        """The exploration state machine: sample the static compressed
        format first, then a raw probe window, then the measured argmin
        — re-probing the losing arm after every ``probe_every`` winner
        observations. Any transition is a change in what :meth:`choose`
        returns, so :meth:`observe` bumps ``generation`` on it — without
        that, a service's memoized plans would pin the compressed
        payload and the raw arm would never be sampled. The periodic
        re-probe matters for the same reason the probe itself does: a
        probe window that happened to land on cache-cold drains writes
        an inflated EWMA for the losing arm, and with one-shot probing
        that stale verdict would never be revisited (the winner keeps
        refreshing its EWMA, the loser never does)."""
        if self._count.get((family, bucket, "compressed"), 0) < self.min_samples:
            return "explore_compressed"
        if self._count.get((family, bucket, PAYLOAD_RAW), 0) < self.min_samples:
            return "explore_raw"
        winner = self._argmin(family, bucket)
        if self._stale.get((family, bucket), 0) >= self.probe_every:
            return ("probe_raw" if winner == "compressed"
                    else "probe_compressed")
        return winner

    def _argmin(self, family: str, bucket: int) -> str | None:
        """The measured-best arm, or None while either arm is still
        unexplored (choices must not flap on one-sided evidence)."""
        arms = []
        for arm in ("compressed", PAYLOAD_RAW):
            key = (family, bucket, arm)
            if self._count.get(key, 0) < self.min_samples:
                return None
            arms.append((self._ewma[key], arm))
        return min(arms)[1]

    def choose(self, family: str, bucket: int, static_payload: str) -> str:
        """The payload one compiled group should serve: the static
        compressed format while that arm explores, one raw probe window
        next, then the measured argmin."""
        phase = self._phase(family, bucket)
        if phase in ("explore_compressed", "probe_compressed", "compressed"):
            return static_payload
        return PAYLOAD_RAW  # explore_raw / probe_raw / raw

    def table(self) -> dict:
        """Plain-data snapshot for stats/bench reporting: per
        (family, bucket), each arm's EWMA + count and the current
        choice."""
        out: dict = {}
        for (family, bucket, arm), us in sorted(self._ewma.items()):
            entry = out.setdefault(f"{family}/L{bucket}", {})
            entry[arm] = {"ewma_us_per_query": us,
                          "n": self._count[(family, bucket, arm)]}
            chosen = self._chosen.get((family, bucket))
            if chosen is not None:
                entry["chosen"] = chosen
        return out
