"""Admission control: the decision layer that closes the deadline
control loop (DESIGN.md §17).

The paper's headline is a *response-time guarantee*, yet before this
layer the serving tier only measured deadline misses (PR 6) — it never
enforced budgets. :class:`AdmissionController` is consulted by
``SearchService.submit()`` on every deadline-carrying request: using
the planner's ``est_step_cost`` calibrated by the measured
``us_per_kslot`` (through :class:`repro.serving.costs.StepCostPredictor`,
with the unit estimate as the cold fallback) plus the current queue
backlog, it predicts the request's completion time and returns a
machine-readable :class:`AdmissionVerdict`:

* ``admit`` — predicted to meet its budget (or optimistically admitted
  during a transient burst, see hysteresis below);
* ``degrade`` — the planned route cannot meet the budget, but a
  cheaper bucket (a *truncated posting prefix*, ``planner.degrade``)
  can: served degraded instead of rejected outright;
* ``reject_infeasible`` — the budget cannot be met even by the
  cheapest route on an idle system: rejected fast, before any queueing
  or device work;
* ``shed_overload`` — feasible in isolation but the backlog makes it
  miss: load shedding. Sheds when the controller's overload latch is
  set, or — latched or not — when the predicted completion overshoots
  the budget beyond the ``optimism`` factor (a hopeless miss; admitting
  it only deepens the backlog for the feasible traffic behind it).

**Hysteresis.** Overload is a latched state with separate enter/exit
thresholds (``enter_s > exit_s``) on an EWMA-smoothed backlog (the
drain loop empties the queue every cycle, so the raw backlog sawtooths
through zero and would flap any latch keyed on it): the controller
sheds every predicted-miss request while latched, and a transient
burst that pushes the smoothed backlog above ``exit_s`` but not
``enter_s`` cannot flap it — *marginal* predicted misses are admitted
optimistically (EDF ordering and group splitting often still rescue
them) until the backlog demonstrably exceeds ``enter_s``, and shedding
continues until it falls back below ``exit_s``.

**Adaptive reserve (DESIGN.md §19).** The utilization margin the admit
test applies (``predicted <= margin × budget``) defaults to a
hand-swept constant, but the error it exists to absorb — work admitted
later landing ahead of this request — is measurable after the fact:
``observe_completion(predicted, actual)`` tracks realized
actual/predicted completion ratios over a recent window, and the
effective margin becomes ``1 / (q95(error) × safety)``, floored at the
static value (the cold fallback and the never-less-conservative
guarantee). A well-calibrated predictor thus admits more of the budget;
a badly-calibrated one falls back to the hand-swept reserve.

The controller itself is deliberately free of service state: it takes
the predicted costs and backlog as numbers and returns a verdict, so
its state machine is unit-testable without a running engine
(tests/test_admission.py drives it directly).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass

# -- adaptive reserve (DESIGN.md §19) ---------------------------------------
# window of realized actual/predicted completion ratios; samples needed
# before the adaptive margin is trusted; the error quantile the reserve
# is derived from; the safety multiplier on that quantile; and how many
# observations between quantile recomputes (a sort per observation would
# tax the resolve path for nothing — the window moves slowly)
MARGIN_WINDOW = 256
MARGIN_MIN_SAMPLES = 32
MARGIN_QUANTILE = 0.95
MARGIN_SAFETY = 1.25
MARGIN_REFRESH = 8


def _quantile(sorted_vals, q: float) -> float:
    """Nearest-rank quantile of a sorted non-empty sequence."""
    k = min(len(sorted_vals) - 1,
            max(0, int(round(q * (len(sorted_vals) - 1)))))
    return sorted_vals[k]

# -- verdicts (machine-readable, the §17 vocabulary) -----------------------
ADMIT = "admit"
DEGRADE = "degrade"
REJECT_INFEASIBLE = "reject_infeasible"
SHED_OVERLOAD = "shed_overload"

# -- response statuses ------------------------------------------------------
STATUS_OK = "ok"
STATUS_DEGRADED = "degraded"
STATUS_REJECTED = "rejected"
STATUS_SHED = "shed"

# -- deadline_blame extensions: a shed/rejected request's budget was not
# blown by a serving phase but by the controller's decision — the blame
# vocabulary names that explicitly (DESIGN.md §17)
BLAME_SHED = "shed"
BLAME_INFEASIBLE = "infeasible"

# -- admit sub-reasons ------------------------------------------------------
REASON_NO_BUDGET = "no_budget"        # deadline-less: nothing to enforce
REASON_PREDICTED_MET = "predicted_met"
REASON_OPTIMISTIC = "optimistic"      # predicted miss, but not overloaded


@dataclass(frozen=True)
class AdmissionVerdict:
    """One admission decision, machine-readable end to end.

    * ``decision`` — ``admit`` / ``degrade`` / ``reject_infeasible`` /
      ``shed_overload``;
    * ``predicted_e2e_s`` — backlog + predicted batch cost of the
      chosen route (for reject/shed: of the best candidate judged);
    * ``budget_s`` — the remaining budget the prediction was judged
      against (None for deadline-less admits);
    * ``backlog_s`` — the queue backlog estimate at decision time;
    * ``bucket`` — the chosen route's L-bucket; differs from the
      planned bucket exactly when ``decision == "degrade"``;
    * ``reason`` — admit sub-reason (``predicted_met`` vs
      ``optimistic``) or None."""

    decision: str
    predicted_e2e_s: float
    budget_s: float | None
    backlog_s: float
    bucket: int | None = None
    reason: str | None = None

    @property
    def admitted(self) -> bool:
        return self.decision in (ADMIT, DEGRADE)


class AdmissionController:
    """The §17 verdict state machine: feasibility + hysteresis.

    ``consider(candidates, backlog_s, budget_s)`` judges one request;
    ``candidates`` is a non-empty preference-ordered list of
    ``(bucket, predicted_batch_s)`` routes — the planned bucket first,
    then (when degradation is enabled) each smaller ladder bucket, so
    "first candidate that fits" is "least degradation". Scalar-route
    plans pass a single ``(None, predicted_s)`` candidate."""

    def __init__(self, enter_s: float, exit_s: float,
                 margin: float = 0.4, optimism: float = 1.2,
                 alpha: float = 0.3, adaptive_margin: bool = True):
        if exit_s > enter_s:
            raise ValueError(f"hysteresis requires exit_s <= enter_s "
                             f"(got exit={exit_s}, enter={enter_s})")
        if not 0.0 < margin <= 1.0:
            raise ValueError(f"margin must be in (0, 1] (got {margin})")
        self.enter_s = enter_s
        self.exit_s = exit_s
        # utilization margin: the admit test is predicted <= margin ×
        # budget, not the raw budget. The backlog estimate is taken at
        # decision time, but traffic admitted *later* still lands ahead
        # of this request (its batch group grows; earlier-deadline
        # groups grow) — an error that scales with the backlog itself,
        # so judging against the full budget systematically over-admits
        # under load. The margin is the reserve that absorbs it.
        #
        # With ``adaptive_margin`` the reserve is *derived* from the
        # realized error instead of pinned: `observe_completion()` feeds
        # actual/predicted completion ratios into a bounded window, and
        # the effective margin becomes 1 / (q95(error) × safety) — "if
        # predictions run at most q95× optimistic, admitting up to
        # 1/(q95·safety) of the budget still completes inside it".
        # The static value stays the floor (never *less* conservative
        # than the hand-swept reserve) and the cold fallback (below
        # MARGIN_MIN_SAMPLES observations).
        self.static_margin = margin
        self.adaptive_margin = adaptive_margin
        self._errors: deque = deque(maxlen=MARGIN_WINDOW)
        self._margin_eff = margin
        self._since_refresh = 0
        # optimistic-admit bound: a predicted miss is admitted (unlatched
        # state only) when predicted completion <= optimism × the
        # margined budget — marginal misses are often rescued by EDF
        # ordering and group splitting, hopeless ones never are, and
        # admitting them only deepens the backlog for the feasible
        # traffic behind them
        self.optimism = optimism
        # the latch judges a smoothed backlog: the drain loop empties
        # the queue every cycle, so the instantaneous backlog sawtooths
        # through zero at every drain boundary and would flap a latch
        # keyed on it directly no matter the thresholds
        self.alpha = alpha
        self.backlog_ewma = 0.0
        self.overloaded = False
        self.transitions = 0  # overload latch flips (flap observability)

    @property
    def margin(self) -> float:
        """The effective reserve the admit test uses right now: the
        static margin while cold (or with ``adaptive_margin=False``),
        the realized-error-derived value once enough completions have
        been observed."""
        return self._margin_eff

    def observe_completion(self, predicted_s: float,
                          actual_s: float) -> None:
        """Feed one realized outcome: the ``predicted_e2e_s`` of an
        admitted verdict vs the actual end-to-end completion of the
        request it admitted. The ratio actual/predicted is the
        controller's realized prediction error — the quantity the
        reserve exists to absorb — tracked over a bounded recent window
        so the margin follows the prevailing workload."""
        if predicted_s <= 1e-9 or actual_s < 0.0:
            return
        self._errors.append(actual_s / predicted_s)
        self._since_refresh += 1
        if self._since_refresh >= MARGIN_REFRESH:
            self._since_refresh = 0
            self._margin_eff = self._derive_margin()

    def _derive_margin(self) -> float:
        if (not self.adaptive_margin
                or len(self._errors) < MARGIN_MIN_SAMPLES):
            return self.static_margin
        q = _quantile(sorted(self._errors), MARGIN_QUANTILE)
        if q <= 0.0:
            return self.static_margin
        # admit up to 1/(q·safety) of the budget: even a q95-pessimal
        # prediction error, padded by the safety factor, still lands
        # the request inside the full budget. Floored at the static
        # reserve, capped at the raw budget.
        return min(1.0, max(self.static_margin,
                            1.0 / (q * MARGIN_SAFETY)))

    def margin_stats(self) -> dict:
        """The realized-error stat surfaced in ``stats["admission"]``:
        static vs effective margin plus the error window's quantiles."""
        errs = sorted(self._errors)
        return {
            "static": self.static_margin,
            "effective": self._margin_eff,
            "adaptive": int(self.adaptive_margin),
            "n_samples": len(errs),
            "error_p50": _quantile(errs, 0.5) if errs else None,
            "error_p95": _quantile(errs, MARGIN_QUANTILE) if errs else None,
        }

    def _update_overload(self, backlog_s: float) -> None:
        self.backlog_ewma += self.alpha * (backlog_s - self.backlog_ewma)
        if not self.overloaded and self.backlog_ewma > self.enter_s:
            self.overloaded = True
            self.transitions += 1
        elif self.overloaded and self.backlog_ewma < self.exit_s:
            self.overloaded = False
            self.transitions += 1

    def consider(self, candidates, backlog_s: float,
                 budget_s: float | None,
                 idle_cost_s: float | None = None) -> AdmissionVerdict:
        """Judge one request. ``budget_s`` is the *remaining* budget at
        decision time (deadline minus time already spent since
        arrival); None means no deadline. ``idle_cost_s`` is the cost
        of serving the request *alone* on an idle system (a B=1 batch
        of the cheapest route) — the infeasibility test: candidate
        costs are priced at the current crowd's batch size, so under
        load they overstate what an idle system would charge, and
        judging feasibility on them would mislabel overload sheds as
        infeasible rejects. Defaults to the cheapest candidate."""
        self._update_overload(backlog_s)
        planned_bucket, planned_s = candidates[0]
        if budget_s is None:
            return AdmissionVerdict(ADMIT, backlog_s + planned_s, None,
                                    backlog_s, bucket=planned_bucket,
                                    reason=REASON_NO_BUDGET)
        effective = self.margin * budget_s
        # least-degraded candidate predicted to complete within the
        # margined budget
        for bucket, cost_s in candidates:
            predicted = backlog_s + cost_s
            if predicted <= effective:
                decision = ADMIT if bucket == planned_bucket else DEGRADE
                return AdmissionVerdict(decision, predicted, budget_s,
                                        backlog_s, bucket=bucket,
                                        reason=REASON_PREDICTED_MET)
        # nothing fits under the current backlog: is the request
        # feasible on an idle system at all? (judged against the full
        # budget — infeasibility is a property of the request, not of
        # the reserve policy or the current crowd)
        if idle_cost_s is None:
            idle_cost_s = min(cost_s for _, cost_s in candidates)
        if idle_cost_s > budget_s:
            return AdmissionVerdict(REJECT_INFEASIBLE,
                                    backlog_s + candidates[-1][1],
                                    budget_s, backlog_s,
                                    bucket=planned_bucket)
        best = min(backlog_s + cost_s for _, cost_s in candidates)
        if self.overloaded or best > self.optimism * effective:
            return AdmissionVerdict(SHED_OVERLOAD, best,
                                    budget_s, backlog_s,
                                    bucket=planned_bucket)
        # transient-burst tolerance: a *marginal* predicted miss while
        # the latch is open — admit and let EDF ordering / group
        # splitting try to rescue it (hopeless misses shed above even
        # unlatched: admitting them only deepens the backlog)
        return AdmissionVerdict(ADMIT, backlog_s + planned_s, budget_s,
                                backlog_s, bucket=planned_bucket,
                                reason=REASON_OPTIMISTIC)
