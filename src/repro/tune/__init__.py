"""Parameter autotuner + scenario workload suite (DESIGN.md §19).

The paper's core experiment is search-speed dependence on MaxDistance,
and its follow-up (arXiv 2101.03327) is optimal-parameter selection for
these exact indexes. This package turns every serving knob that past
PRs swept by hand into a measured decision:

* :mod:`repro.tune.workloads` — named, seeded, replayable workload
  generators (Zipfian frequency draws, long-tail L skew, all-stop-word
  floods, configurable five-type mixes) with JSON record/replay and
  arrival-process attachment;
* :mod:`repro.tune.sweep` — successive halving over the joint
  (MaxDistance, ServeConfig) space: a StepCostPredictor-priced estimate
  rung prunes the grid before any device work, survivors are measured
  via ``warm_service`` + open-loop replay;
* :mod:`repro.tune.objective` — the scoring policy (warm p50/p95,
  deadline met-rate at a target budget, index-size penalty) with
  machine-readable per-config verdicts;
* :mod:`repro.tune.report` — the winning ServeConfig as a JSON artifact
  (``launch/serve.py --config``) plus the per-parameter sensitivity
  table. ``benchmarks/tune_bench.py`` drives the whole loop and lands
  ``tune/*`` rows in BENCH_serve.json.
"""

from repro.tune.objective import Objective  # noqa: F401
from repro.tune.report import (  # noqa: F401
    emit_serve_config,
    load_serve_config,
    sensitivity_table,
)
from repro.tune.sweep import (  # noqa: F401
    Candidate,
    SweepOutcome,
    estimate_workload_us,
    grid,
    index_bytes,
    measure_candidate,
    successive_halving,
    sweep,
)
from repro.tune.workloads import (  # noqa: F401
    WORKLOAD_GENERATORS,
    Workload,
    attach_arrivals,
    load_workload,
    longtail_workload,
    make_workload,
    mixed_workload,
    record_workload,
    stopword_flood,
    zipfian_workload,
)

__all__ = [
    "Candidate",
    "Objective",
    "SweepOutcome",
    "WORKLOAD_GENERATORS",
    "Workload",
    "attach_arrivals",
    "emit_serve_config",
    "estimate_workload_us",
    "grid",
    "index_bytes",
    "load_serve_config",
    "load_workload",
    "longtail_workload",
    "make_workload",
    "measure_candidate",
    "mixed_workload",
    "record_workload",
    "sensitivity_table",
    "stopword_flood",
    "successive_halving",
    "sweep",
    "zipfian_workload",
]
