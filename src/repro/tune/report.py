"""Sweep reporting: the winning ServeConfig as a loadable artifact,
plus the per-parameter sensitivity table (DESIGN.md §19).

The tuner's product is not a number, it is a *deployable config*:
:func:`emit_serve_config` writes ``{format, max_distance, serve_config,
meta}`` as JSON and ``launch/serve.py --config`` loads it back through
:func:`load_serve_config` (round-trip pinned by tests/test_tune.py).
``serve_config`` serializes through ``ServeConfig.to_json_dict`` /
``from_json_dict``, so unknown fields fail loudly instead of silently
reverting a knob to its default.

:func:`sensitivity_table` answers "which knob mattered": for every
sweep axis it groups the scored candidates by axis value and reports
the best score per value; the spread between the best and worst value
of one axis is that axis's leverage under this workload (an axis with
near-zero spread can be dropped from the next sweep).
"""

from __future__ import annotations

import json

from repro.serving import ServeConfig

SERVE_CONFIG_FORMAT = "repro.tune/serve_config.v1"


def emit_serve_config(path: str, max_distance: int, config: ServeConfig, *,
                      meta: dict | None = None) -> dict:
    """Write the winning (MaxDistance, ServeConfig) pair as the JSON
    artifact ``launch/serve.py --config`` consumes. Returns the
    payload (benches embed it in their report)."""
    payload = {
        "format": SERVE_CONFIG_FORMAT,
        "max_distance": int(max_distance),
        "serve_config": config.to_json_dict(),
        "meta": meta or {},
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
    return payload


def load_serve_config(path: str) -> tuple[int, ServeConfig, dict]:
    """Load an emitted config artifact: ``(max_distance, ServeConfig,
    meta)``. Rejects files that are not serve-config artifacts and
    configs with unknown fields (``ServeConfig.from_json_dict``)."""
    with open(path) as fh:
        payload = json.load(fh)
    fmt = payload.get("format")
    if fmt != SERVE_CONFIG_FORMAT:
        raise ValueError(f"{path}: not a tuned serve config "
                         f"(format={fmt!r}, want {SERVE_CONFIG_FORMAT!r})")
    cfg = ServeConfig.from_json_dict(payload["serve_config"])
    return int(payload["max_distance"]), cfg, payload.get("meta", {})


def sensitivity_table(scored) -> dict:
    """Per-axis sensitivity from scored candidates.

    ``scored`` is ``[(Candidate, score), ...]`` (typically the sweep's
    rung-0 history: full grid coverage). Returns, per axis (including
    ``max_distance``), the best score observed at each axis value plus
    the axis ``spread`` (worst best-per-value minus best best-per-value
    — how much picking this knob wrong costs when everything else is
    chosen well)."""
    best: dict[str, dict[str, float]] = {}
    counts: dict[str, dict[str, int]] = {}
    for cand, score in scored:
        axes = (("max_distance", cand.max_distance),) + tuple(
            cand.axis_values or cand.overrides)
        for axis, value in axes:
            label = str(value)
            b = best.setdefault(axis, {})
            prev = b.get(label)
            if prev is None or score < prev:
                b[label] = float(score)
            c = counts.setdefault(axis, {})
            c[label] = c.get(label, 0) + 1
    out: dict = {}
    for axis, by_value in sorted(best.items()):
        vals = sorted(by_value.items(), key=lambda kv: kv[1])
        out[axis] = {
            "values": {label: {"best_score": s,
                               "n": counts[axis][label]}
                       for label, s in vals},
            "best_value": vals[0][0],
            "spread": vals[-1][1] - vals[0][1],
        }
    return out
