"""Successive-halving sweep over the joint (MaxDistance, ServeConfig)
space (DESIGN.md §19).

The search space is the product of an index-build axis (MaxDistance —
one :func:`repro.core.index_builder.build_index` per value, shared by
every serve candidate) and serve-time axes (k_fst/k_wv/k_ns/k_st,
r_max, bucket ladder, share_buckets, payload policy, admit_margin...).
Measuring every cell is quadratically wasteful, so the sweep runs
successive halving:

* **rung 0 (estimate)** — every candidate is scored *without device
  work*: the pure planner routes the whole workload under the
  candidate config and :class:`repro.serving.costs.StepCostPredictor`
  prices each (family, B, L-bucket) group with its unit cost model
  (`PayloadCostModel` likewise starts in its static phase, so
  compressed candidates are priced by the same static rule the planner
  applies cold). Crude, but monotone in the shape variables that
  dominate — enough to prune the clearly-bad half;
* **measured rungs** — survivors get a real run each:
  :func:`repro.serving.load.warm_service` (so no AOT compile lands
  inside the measurement) then an open-loop replay of the workload's
  arrival schedule, with the measurement budget growing as the field
  halves.

:func:`successive_halving` is the generic engine (injectable score
functions — tests rig a cost table and assert the known-best candidate
is never dropped); :func:`sweep` wires it to real estimate/measure
stages and returns a :class:`SweepOutcome` the report layer consumes.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field

from repro.core.jax_search import batch_size_bucket
from repro.serving import SearchService, ServeConfig, warm_service
from repro.serving.load import run_closed_loop, run_open_loop
from repro.tune.objective import Objective


def _fmt_value(v) -> str:
    if isinstance(v, (tuple, list)):
        return "-".join(str(x) for x in v)
    return str(v)


@dataclass(frozen=True)
class Candidate:
    """One cell of the joint space: ``max_distance`` (index build) plus
    ``overrides`` applied to a base :class:`ServeConfig` (sorted
    (field, value) pairs — hashable, so candidates key dicts).
    ``axis_values`` preserves the sweep-axis labelling for the
    sensitivity table (one axis may set several config fields)."""

    max_distance: int
    overrides: tuple = ()
    axis_values: tuple = ()

    @property
    def config_id(self) -> str:
        parts = [f"d={self.max_distance}"]
        parts += [f"{k}={_fmt_value(v)}" for k, v in
                  (self.axis_values or self.overrides)]
        return "|".join(parts)

    def serve_config(self, base: ServeConfig | None = None) -> ServeConfig:
        kw = (base.to_json_dict() if base is not None
              else ServeConfig().to_json_dict())
        kw.update(dict(self.overrides))
        return ServeConfig.from_json_dict(kw)


def grid(max_distances, axes: dict) -> list[Candidate]:
    """The full cartesian product. ``axes`` maps an axis name to a list
    of values; a scalar/tuple value overrides the ServeConfig field of
    the axis's name, a dict value overrides several fields at once
    (e.g. ``{"k": [{"k_ns": 2, "k_st": 2}, ...]}``)."""
    names = sorted(axes)
    out = []
    for d in max_distances:
        for combo in itertools.product(*(axes[n] for n in names)):
            overrides: dict = {}
            labels = []
            for name, value in zip(names, combo):
                if isinstance(value, dict):
                    overrides.update(value)
                    labels.append((name, "+".join(
                        f"{k}{v}" for k, v in sorted(value.items()))))
                else:
                    overrides[name] = value
                    labels.append((name, value))
            out.append(Candidate(
                max_distance=int(d),
                overrides=tuple(sorted(overrides.items())),
                axis_values=tuple(labels),
            ))
    return out


def successive_halving(candidates, rungs, *, keep=None, eta: float = 2.0,
                       min_keep: int = 2) -> list[list[tuple]]:
    """Generic successive halving: ``rungs`` is a list of score
    functions (lower is better, one per rung, later rungs assumed more
    faithful and more expensive); after each non-final rung the top
    ``keep[i]`` candidates (default ``ceil(n / eta)``, floored at
    ``min_keep``) survive. Returns the per-rung history as
    ``[(candidate, score), ...]`` sorted best-first — the winner is
    ``history[-1][0][0]``.

    Scores are ranked with a stable sort, so a candidate that is best
    (or tied-best) at every rung is mathematically never dropped: the
    survivor cut keeps a prefix of the ranking and ``keep >= 1``
    always. Tests pin this on a rigged cost table."""
    if not candidates:
        raise ValueError("no candidates")
    if not rungs:
        raise ValueError("no rungs")
    survivors = list(candidates)
    history: list[list[tuple]] = []
    for i, score_fn in enumerate(rungs):
        scored = [(c, float(score_fn(c))) for c in survivors]
        scored.sort(key=lambda t: t[1])
        history.append(scored)
        if i < len(rungs) - 1:
            k = int(keep[i] if keep is not None and i < len(keep)
                    else math.ceil(len(scored) / eta))
            k = max(1, min(len(scored), max(min_keep, k)))
            survivors = [c for c, _ in scored[:k]]
    return history


# -- estimate stage ---------------------------------------------------------
def index_bytes(index) -> int:
    """Total bytes of an index's size report (the objective's size
    input): every ``*_bytes`` entry of ``ProximityIndex.size_report``."""
    rep = index.size_report()
    return int(sum(v for k, v in rep.items() if k.endswith("_bytes")))


def estimate_workload_us(service: SearchService, queries) -> float:
    """Predicted mean per-query cost of serving ``queries`` under the
    service's config, with **no device work**: every query is planned
    (pure planner), grouped per (family, L-bucket) exactly as one drain
    would, and priced by the service's :class:`StepCostPredictor` — on
    a cold service that is the unit model (``unit_us_per_kslot`` /
    ``unit_scalar_us``), the same estimates admission degrades to
    before measurements exist."""
    if not queries:
        raise ValueError("empty workload")
    mb = service.config.max_batch
    groups: dict[tuple, int] = {}
    n_scalar = 0
    for q in queries:
        p = service.explain(q)
        if p.is_compiled:
            key = (p.step_family, p.bucket)
            groups[key] = groups.get(key, 0) + 1
        elif p.route == "scalar":
            n_scalar += 1
    total_s = n_scalar * service.predictor.scalar_s()
    for (family, bucket), n in groups.items():
        B = batch_size_bucket(min(n, mb), mb)
        total_s += (-(-n // mb)) * service.predictor.batch_s(family, B, bucket)
    return total_s * 1e6 / len(queries)


def make_estimator(indexes: dict, mesh, base: ServeConfig, queries,
                   objective: Objective):
    """Rung-0 score function: ``candidate -> estimate_score`` (predicted
    mean per-query us + the index-size penalty). ``indexes`` maps each
    MaxDistance in the grid to its built index."""
    size = {d: index_bytes(idx) for d, idx in indexes.items()}

    def score(candidate: Candidate) -> float:
        svc = SearchService(indexes[candidate.max_distance], mesh,
                            candidate.serve_config(base))
        est = estimate_workload_us(svc, queries)
        return objective.estimate_score(est, size[candidate.max_distance])

    return score


# -- measured stage ---------------------------------------------------------
def measure_candidate(index, mesh, config: ServeConfig, workload, *,
                      deadline_s: float = 0.05, arrivals=None,
                      closed_n: int = 64) -> dict:
    """One measured evaluation: build the service, warm every (family,
    B, L) executable the workload routes to, then replay the arrival
    schedule open-loop (or, with no schedule, a closed-loop run of
    ``closed_n`` requests). Returns the plain measurement dict the
    objective scores."""
    svc = SearchService(index, mesh, config)
    warm_service(svc, workload.queries)
    arrivals = arrivals if arrivals is not None else workload.arrivals
    if arrivals:
        rep = run_open_loop(svc, workload.queries, arrivals,
                            deadline_s=deadline_s)
    else:
        rep = run_closed_loop(svc, workload.queries, closed_n,
                              deadline_s=deadline_s)
    return {
        "p50_us": rep.e2e_p50_us,
        "p95_us": rep.e2e_p95_us,
        "met_rate": rep.met_rate,
        "met_rate_offered": rep.met_rate_offered,
        "shed_rate": rep.shed_rate,
        "achieved_qps": rep.achieved_qps,
        "n_offered": rep.n_offered,
        "index_bytes": index_bytes(index),
        "executables": svc.compiled.n_executables,
    }


@dataclass
class SweepOutcome:
    """Everything the report layer needs: the per-rung history (as
    plain ``{config_id, score}`` records), the measured candidates'
    objective verdicts, and the winner."""

    winner: Candidate
    winner_verdict: dict
    history: list = field(default_factory=list)
    verdicts: list = field(default_factory=list)
    measurements: dict = field(default_factory=dict)
    n_candidates: int = 0


def sweep(indexes: dict, mesh, candidates, workload, *,
          base: ServeConfig | None = None,
          objective: Objective | None = None,
          rung_arrivals=None, keep=None) -> SweepOutcome:
    """Run the full halving sweep: one estimate rung over every
    candidate, then one measured rung per arrival schedule in
    ``rung_arrivals`` (later schedules should be longer — the
    escalating-budget half of successive halving). ``keep`` bounds the
    survivors after each rung (default: halve)."""
    base = base if base is not None else ServeConfig()
    objective = objective if objective is not None else Objective()
    rung_arrivals = rung_arrivals or [None]
    measurements: dict[str, dict] = {}
    verdicts: dict[str, dict] = {}

    def make_measured(arrivals):
        def score(candidate: Candidate) -> float:
            m = measure_candidate(
                indexes[candidate.max_distance], mesh,
                candidate.serve_config(base), workload,
                deadline_s=objective.deadline_s, arrivals=arrivals)
            measurements[candidate.config_id] = m
            v = objective.score(m, config_id=candidate.config_id)
            verdicts[candidate.config_id] = v
            return v["score"]

        return score

    rungs = [make_estimator(indexes, mesh, base, workload.queries,
                            objective)]
    rungs += [make_measured(a) for a in rung_arrivals]
    history = successive_halving(candidates, rungs, keep=keep)
    winner = history[-1][0][0]
    return SweepOutcome(
        winner=winner,
        winner_verdict=verdicts[winner.config_id],
        history=[[{"config_id": c.config_id, "score": s}
                  for c, s in rung] for rung in history],
        verdicts=[verdicts[cid] for cid in sorted(verdicts)],
        measurements=measurements,
        n_candidates=len(candidates),
    )
