"""Scoring for the parameter sweep (DESIGN.md §19).

A candidate (MaxDistance + ServeConfig) is judged on the three axes the
paper's guarantee actually trades between:

* warm per-query latency — open-loop e2e p50 (weight 1) and p95
  (weight ``w_p95``), in microseconds;
* the deadline guarantee — a penalty per unit of met-rate shortfall
  below ``target_met_rate`` at the target budget (charged on the
  *offered* met rate, so shedding is not a free way to hit the SLO);
* index size — MaxDistance grows the (w,v)/(f,s,t) indexes
  superlinearly (the paper's core trade-off), so bytes carry a small
  latency-equivalent price.

``score()`` folds a measurement dict into one number (lower is better)
and returns a machine-readable verdict with every component broken out;
``estimate_score()`` is the cheap pre-measurement stand-in the halving
sweep's first rung uses (predicted latency + the size penalty — no met
rate exists before a measured run).
"""

from __future__ import annotations

from dataclasses import dataclass

MIB = float(1 << 20)


@dataclass(frozen=True)
class Objective:
    """The sweep's scoring policy, frozen so one objective is shared by
    every rung of one sweep (scores are only comparable under the same
    weights)."""

    deadline_s: float = 0.05
    target_met_rate: float = 0.99
    w_p95: float = 0.25
    # 1.0 of met-rate shortfall == 100k us of latency: missing the SLO
    # by 1% costs 1ms-equivalent, so no latency win can buy its way out
    # of a collapsed guarantee
    miss_penalty_us: float = 100_000.0
    size_penalty_us_per_mib: float = 2.0

    def estimate_score(self, est_us_per_query: float,
                       index_bytes: int) -> float:
        """Rung-0 score from the StepCostPredictor-based estimate."""
        return (est_us_per_query
                + self.size_penalty_us_per_mib * index_bytes / MIB)

    def score(self, measurement: dict, config_id: str = "") -> dict:
        """Fold one measured run into a verdict dict.

        ``measurement`` is the sweep's measurement record: ``p50_us``,
        ``p95_us``, ``met_rate_offered`` (or ``met_rate``),
        ``index_bytes``. The verdict carries the total ``score`` plus
        each component, so a report can attribute *why* a config won."""
        p50 = float(measurement["p50_us"])
        p95 = float(measurement.get("p95_us", p50))
        met = float(measurement.get("met_rate_offered",
                                    measurement.get("met_rate", 1.0)))
        index_mib = float(measurement.get("index_bytes", 0)) / MIB
        latency_us = p50 + self.w_p95 * p95
        shortfall = max(0.0, self.target_met_rate - met)
        miss_us = self.miss_penalty_us * shortfall
        size_us = self.size_penalty_us_per_mib * index_mib
        return {
            "config_id": config_id,
            "score": latency_us + miss_us + size_us,
            "p50_us": p50,
            "p95_us": p95,
            "met_rate": met,
            "met_target_ok": met >= self.target_met_rate,
            "index_mib": index_mib,
            "components": {
                "latency_us": latency_us,
                "miss_penalty_us": miss_us,
                "size_penalty_us": size_us,
            },
            "target": {
                "deadline_ms": self.deadline_s * 1e3,
                "met_rate": self.target_met_rate,
            },
        }
