"""First-class workload model for the parameter autotuner (DESIGN.md §19).

Every serving knob in ``ServeConfig`` was historically swept against
*uniform* query sampling — exactly the traffic the paper calls
unrepresentative of high-frequency-word search (the head of the Zipf
curve is where the multi-component indexes earn their keep, and where
they are stressed). This module makes the workload a first-class,
reproducible object:

* :class:`Workload` — a named query stream (lemma-id lists), its
  generator provenance (``meta``), and an optional arrival schedule;
* named generators — :func:`zipfian_workload` (lemma draws weighted by
  the corpus frequency table), :func:`longtail_workload` (ordinary-tail
  draws with an occasional head lemma: a long-tailed posting-length
  L distribution), :func:`stopword_flood` (adversarial all-stop QT1
  floods from the hottest stop lemmas), :func:`mixed_workload`
  (five-type traffic with a configurable type mix over the
  co-occurrence samplers of :mod:`repro.data.corpus`);
* record/replay — :func:`record_workload` / :func:`load_workload`
  round-trip a workload through a JSON trace file bit-identically, so
  a sweep can be replayed against a new build;
* :func:`attach_arrivals` — attach a :mod:`repro.serving.load` arrival
  process (poisson / bursty) to any workload, making it directly
  consumable by ``run_open_loop``.

All generators are deterministic per seed and draw only lemma ids that
exist in the lexicon (id == FL frequency rank), so every query routes
through the real planner. Zipfian/long-tail/flood queries are
frequency-realistic but not co-occurrence-constrained (a query's lemmas
may never share a document); the mixed generator samples real
co-occurrence windows. For latency tuning that is the right trade:
step cost is shape-bound, not hit-bound.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field

import numpy as np

from repro.core.query import classify
from repro.data.corpus import sample_typed_queries

WORKLOAD_FORMAT = "repro.tune/workload.v1"

QT_KINDS = ("qt1", "qt2", "qt3", "qt4", "qt5")


@dataclass
class Workload:
    """One reproducible query stream: ``queries`` is a list of lemma-id
    lists (the ``submit()`` shape), ``meta`` records generator + seed +
    declared mix, ``arrivals`` an optional offset schedule (seconds from
    trace start) attached by :func:`attach_arrivals`."""

    name: str
    queries: list = field(default_factory=list)
    meta: dict = field(default_factory=dict)
    arrivals: list | None = None

    def __len__(self) -> int:
        return len(self.queries)

    def type_mix(self, lex) -> dict:
        """Measured QT-class histogram of the stream (fractions)."""
        if not self.queries:
            return {}
        counts: dict[str, int] = {}
        for q in self.queries:
            qt = f"qt{int(classify(q, lex))}"
            counts[qt] = counts.get(qt, 0) + 1
        n = len(self.queries)
        return {k: counts[k] / n for k in sorted(counts)}


def _lengths(rng, n_queries: int, min_len: int, max_len: int) -> np.ndarray:
    if not 1 <= min_len <= max_len:
        raise ValueError(f"need 1 <= min_len <= max_len "
                         f"(got {min_len}, {max_len})")
    return rng.integers(min_len, max_len + 1, size=n_queries)


def _weighted_query(rng, pool: np.ndarray, probs: np.ndarray | None,
                    L: int) -> list[int]:
    """One query: L distinct draws from ``pool`` (weighted by ``probs``
    when given), clamped to the pool size."""
    take = min(L, pool.size)
    q = rng.choice(pool, size=take, replace=False, p=probs)
    return [int(x) for x in q]


def zipfian_workload(table, lex, n_queries: int, *, min_len: int = 3,
                     max_len: int = 5, alpha: float = 1.0,
                     seed: int = 0) -> Workload:
    """Zipfian lemma draws over the *observed* corpus frequency table:
    each lemma is drawn with probability proportional to
    ``lex.counts ** alpha`` — the head-heavy traffic of real query logs
    (``alpha=1`` reproduces the collection's own frequency profile;
    higher alpha concentrates further on stop/frequent lemmas)."""
    rng = np.random.default_rng(seed)
    counts = np.asarray(lex.counts, dtype=np.float64)
    w = np.power(np.maximum(counts, 1.0), alpha)
    probs = w / w.sum()
    pool = np.arange(counts.size)
    queries = [
        _weighted_query(rng, pool, probs, int(L))
        for L in _lengths(rng, n_queries, min_len, max_len)
    ]
    wl = Workload("zipfian", queries,
                  {"generator": "zipfian", "seed": seed, "alpha": alpha,
                   "min_len": min_len, "max_len": max_len})
    wl.meta["type_mix"] = wl.type_mix(lex)
    return wl


def longtail_workload(table, lex, n_queries: int, *, min_len: int = 3,
                      max_len: int = 5, head_frac: float = 0.15,
                      seed: int = 0) -> Workload:
    """Long-tail L skew: queries draw uniformly from the *ordinary*
    lemma tail (tiny posting rows — the bulk of the vocabulary), and a
    ``head_frac`` fraction of queries swaps one lemma for a
    frequency-weighted head (stop/frequent) lemma whose posting row is
    orders of magnitude longer. The resulting posting-length (L)
    distribution is long-tailed: most queries fit the smallest ladder
    bucket, a heavy tail does not — the regime where ladder choice and
    degrade policy actually matter."""
    rng = np.random.default_rng(seed)
    counts = np.asarray(lex.counts, dtype=np.float64)
    head_hi = lex.sw_count + lex.fu_count
    tail = np.arange(head_hi, counts.size)
    if tail.size < max_len:
        raise ValueError(f"lexicon has only {tail.size} ordinary lemmas "
                         f"(< max_len={max_len})")
    head = np.arange(min(head_hi, counts.size))
    head_w = counts[head]
    head_p = head_w / head_w.sum() if head_w.sum() > 0 else None
    queries = []
    for L in _lengths(rng, n_queries, min_len, max_len):
        q = _weighted_query(rng, tail, None, int(L))
        if head.size and rng.random() < head_frac:
            q[0] = int(rng.choice(head, p=head_p))
        queries.append(q)
    wl = Workload("longtail", queries,
                  {"generator": "longtail", "seed": seed,
                   "head_frac": head_frac, "min_len": min_len,
                   "max_len": max_len})
    wl.meta["type_mix"] = wl.type_mix(lex)
    return wl


def stopword_flood(lex, n_queries: int, *, min_len: int = 3,
                   max_len: int = 5, hottest: int = 32,
                   seed: int = 0) -> Workload:
    """Adversarial all-stop-word flood: every query is QT1, drawn
    frequency-weighted from the ``hottest`` most frequent stop lemmas —
    the worst-case traffic the paper's (f,s,t) index exists for (the
    longest posting rows in the collection, hit on every request)."""
    rng = np.random.default_rng(seed)
    sw = int(lex.sw_count)
    if sw < min_len:
        raise ValueError(f"lexicon has only {sw} stop lemmas "
                         f"(< min_len={min_len})")
    pool = np.arange(min(hottest, sw))
    counts = np.asarray(lex.counts, dtype=np.float64)[pool]
    probs = counts / counts.sum() if counts.sum() > 0 else None
    queries = [
        _weighted_query(rng, pool, probs, int(L))
        for L in _lengths(rng, n_queries, min_len, max_len)
    ]
    wl = Workload("stopflood", queries,
                  {"generator": "stopflood", "seed": seed,
                   "hottest": int(pool.size), "min_len": min_len,
                   "max_len": max_len})
    wl.meta["type_mix"] = wl.type_mix(lex)
    return wl


def mixed_workload(table, lex, n_queries: int, *, mix: dict | None = None,
                   min_len: int = 3, max_len: int = 5, window: int = 9,
                   seed: int = 0) -> Workload:
    """Mixed five-type traffic with a configurable type mix: per-class
    counts follow ``mix`` (weights over qt1..qt5, default uniform),
    queries come from the real co-occurrence samplers
    (:func:`repro.data.corpus.sample_typed_queries`) and are interleaved
    round-robin proportionally to the mix."""
    weights = {k: 1.0 for k in QT_KINDS} if mix is None else dict(mix)
    bad = sorted(set(weights) - set(QT_KINDS))
    if bad:
        raise ValueError(f"unknown query types in mix: {bad}")
    total = sum(max(w, 0.0) for w in weights.values())
    if total <= 0:
        raise ValueError(f"mix has no positive weight: {mix}")
    # largest-remainder apportionment: per-type counts sum to n_queries
    # and match the declared mix as closely as integers allow
    kinds = [k for k in QT_KINDS if weights.get(k, 0.0) > 0]
    exact = {k: n_queries * weights[k] / total for k in kinds}
    counts = {k: int(exact[k]) for k in kinds}
    short = n_queries - sum(counts.values())
    for k in sorted(kinds, key=lambda k: exact[k] - counts[k],
                    reverse=True)[:short]:
        counts[k] += 1
    cols = {
        k: sample_typed_queries(table, lex, counts[k], k, min_len,
                                max_len, window, seed + i)
        for i, k in enumerate(kinds)
    }
    declared = {k: counts[k] for k in kinds}
    # proportional round-robin interleave (no sorted type blocks: a
    # block would serialize into one giant batch and misrepresent the
    # steady-state group mix)
    queries: list = []
    idx = {k: 0 for k in kinds}
    while len(queries) < sum(len(c) for c in cols.values()):
        for k in kinds:
            if idx[k] < len(cols[k]):
                queries.append(cols[k][idx[k]])
                idx[k] += 1
    wl = Workload("mixed", queries,
                  {"generator": "mixed", "seed": seed,
                   "mix": {k: weights[k] for k in kinds},
                   "declared_counts": declared, "min_len": min_len,
                   "max_len": max_len, "window": window})
    wl.meta["type_mix"] = wl.type_mix(lex)
    return wl


# name -> generator; stopflood takes no token table
WORKLOAD_GENERATORS = {
    "zipfian": zipfian_workload,
    "longtail": longtail_workload,
    "stopflood": stopword_flood,
    "mixed": mixed_workload,
}


def make_workload(name: str, table, lex, n_queries: int, *, seed: int = 0,
                  **kw) -> Workload:
    """Build one of the named workloads (the registry the sweep harness
    and benches iterate)."""
    gen = WORKLOAD_GENERATORS.get(name)
    if gen is None:
        raise ValueError(f"unknown workload {name!r} "
                         f"(have {sorted(WORKLOAD_GENERATORS)})")
    if name == "stopflood":
        return gen(lex, n_queries, seed=seed, **kw)
    return gen(table, lex, n_queries, seed=seed, **kw)


# -- record / replay --------------------------------------------------------
def record_workload(workload: Workload, path: str) -> dict:
    """Write a workload (queries, meta, arrivals) as a JSON trace file.
    The payload is pure ints/floats/strings, so
    ``load_workload(record_workload(w, p))`` round-trips bit-identically
    — a recorded sweep workload replays exactly."""
    payload = {
        "format": WORKLOAD_FORMAT,
        "name": workload.name,
        "meta": workload.meta,
        "queries": [[int(l) for l in q] for q in workload.queries],
        "arrivals": workload.arrivals,
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
    return payload


def load_workload(path: str) -> Workload:
    """Load a trace file written by :func:`record_workload`."""
    with open(path) as fh:
        payload = json.load(fh)
    fmt = payload.get("format")
    if fmt != WORKLOAD_FORMAT:
        raise ValueError(f"{path}: not a workload trace "
                         f"(format={fmt!r}, want {WORKLOAD_FORMAT!r})")
    return Workload(
        name=payload["name"],
        queries=[list(q) for q in payload["queries"]],
        meta=payload.get("meta", {}),
        arrivals=payload.get("arrivals"),
    )


def attach_arrivals(workload: Workload, process: str = "poisson", *,
                    qps: float, duration_s: float, seed: int = 0,
                    **kw) -> Workload:
    """A copy of ``workload`` with a :mod:`repro.serving.load` arrival
    schedule attached (``process`` is ``"poisson"`` or ``"bursty"``;
    extra kwargs reach the generator, e.g. ``burst_factor``). The
    schedule is recorded in ``meta`` and survives record/replay, so an
    open-loop run over a replayed trace offers the identical load."""
    from repro.serving.load import bursty_arrivals, poisson_arrivals

    gens = {"poisson": poisson_arrivals, "bursty": bursty_arrivals}
    gen = gens.get(process)
    if gen is None:
        raise ValueError(f"unknown arrival process {process!r} "
                         f"(have {sorted(gens)})")
    # plain floats, not an ndarray: the schedule must survive the JSON
    # record/replay round-trip bit-identically
    arrivals = [float(t) for t in gen(qps, duration_s, seed=seed, **kw)]
    meta = dict(workload.meta)
    meta["arrival_process"] = {"process": process, "qps": qps,
                               "duration_s": duration_s, "seed": seed, **kw}
    return dataclasses.replace(workload, meta=meta, arrivals=arrivals)
