"""Production mesh construction (defined as functions so importing this
module never touches jax device state)."""

from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x has no AxisType; meshes default to auto
    AxisType = None


def _mesh(shape: tuple, axes: tuple):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods of
    256 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Auto-typed mesh helper (tests / small runs)."""
    return _mesh(shape, axes)


def dp_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_size(mesh) -> int:
    return mesh.shape.get("model", 1)


def dp_size(mesh) -> int:
    s = 1
    for a in dp_axes_of(mesh):
        s *= mesh.shape[a]
    return s
