"""Training driver: real steps on the local mesh, supervised by the
fault-tolerance layer (checkpoint/restart, straggler detection), with
optional compressed-DP gradient sync.

Used by examples/train_lm.py and the integration tests; the same loop
drives the production mesh (the dry-run proves the step compiles there).

XLA flags for real TPU fleets (recorded here; harmless on CPU):
  --xla_tpu_enable_data_parallel_all_reduce_opt=true
  --xla_tpu_data_parallel_opt_different_sized_ops=true
  --xla_enable_async_collective_permute=true
  --xla_tpu_enable_async_collective_fusion=true   (compute/comm overlap)
"""

from __future__ import annotations

import argparse
import functools
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_arch
from repro.launch.mesh import dp_axes_of, make_mesh
from repro.launch.steps import build_step, materialize_inputs
from repro.train.fault_tolerance import (
    FailureInjector,
    StragglerDetector,
    TrainSupervisor,
)


def make_lm_batch_fn(vocab: int, batch: int, seq: int, seed: int = 0):
    """Deterministic per-step synthetic LM batches (replay-exact): a noisy
    integer AR(1) stream so the loss has learnable structure."""

    def batch_fn(step: int):
        rng = np.random.default_rng(seed * 1_000_003 + step)
        base = rng.integers(0, vocab, (batch, seq + 1))
        # make it compressible: repeat previous token with p=0.5
        rep = rng.random((batch, seq + 1)) < 0.5
        for t in range(1, seq + 1):
            base[:, t] = np.where(rep[:, t], base[:, t - 1], base[:, t])
        return {
            "tokens": jnp.asarray(base[:, :-1], jnp.int32),
            "targets": jnp.asarray(base[:, 1:], jnp.int32),
        }

    return batch_fn


def train_arch(
    arch_id: str,
    shape_name: str = "train_4k",
    steps: int = 50,
    ckpt_dir: str = "/tmp/repro_ckpt",
    ckpt_every: int = 10,
    mesh_shape: tuple = (1, 1),
    inject_failures: dict | None = None,
    reduced: bool = True,
    seed: int = 0,
):
    arch = get_arch(arch_id)
    if reduced:
        arch = arch.reduced()
    mesh = make_mesh(mesh_shape, ("data", "model"))
    built = build_step(arch, shape_name, mesh)
    args = materialize_inputs(arch, shape_name, built, seed=seed)
    params0, opt0 = args[0], args[1]
    cfg = arch.model_cfg
    dims = arch.shapes[shape_name].dims
    batch_fn = make_lm_batch_fn(cfg.vocab, dims["global_batch"], dims["seq_len"], seed)

    def step_fn(state, batch):
        params, opt = state
        params, opt, metrics = built.fn(params, opt, batch)
        return (params, opt), metrics

    sup = TrainSupervisor(
        step_fn=step_fn,
        batch_fn=batch_fn,
        init_state_fn=lambda: (params0, opt0),
        ckpt_dir=ckpt_dir,
        ckpt_every=ckpt_every,
        injector=FailureInjector(inject_failures or {}),
        straggler=StragglerDetector(),
    )
    report = sup.run(steps)
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--full", action="store_true", help="full (non-reduced) config")
    args = ap.parse_args()
    t0 = time.time()
    report = train_arch(
        args.arch, args.shape, steps=args.steps, ckpt_dir=args.ckpt_dir,
        reduced=not args.full,
    )
    print(
        f"steps={report.steps_run} restarts={report.restarts} "
        f"stragglers={report.straggler_events} "
        f"loss[0]={report.losses[0]:.4f} loss[-1]={report.losses[-1]:.4f} "
        f"wall={time.time()-t0:.1f}s"
    )


if __name__ == "__main__":
    main()
