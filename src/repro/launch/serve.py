"""Serving launcher: builds a proximity index and serves batched QT1
requests through the deadline-aware `SearchService` (thin CLI over
serving/service.py; examples/serve_search.py is the narrated
walkthrough).

  PYTHONPATH=src python -m repro.launch.serve --n-docs 3000 --requests 512 --deadline-ms 50

With ``--load-qps`` the launcher replays an open-loop Poisson trace
instead of one closed batch, and ``--admission`` turns on the §17
deadline control loop (admission verdicts, shedding, EDF splits):

  PYTHONPATH=src python -m repro.launch.serve --n-docs 3000 \
      --deadline-ms 50 --admission --load-qps 2000

``--config`` loads a tuned (MaxDistance, ServeConfig) artifact emitted
by the §19 autotuner (``benchmarks/run.py --only tune``); explicit
``--deadline-ms`` / ``--admission`` flags still overlay the loaded
config:

  PYTHONPATH=src python -m repro.launch.serve \
      --config results/tuned_serve_config.json
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import numpy as np

from repro.core.index_builder import build_index
from repro.data.corpus import generate_corpus, sample_stop_queries
from repro.launch.mesh import make_mesh
from repro.serving import SearchService, ServeConfig


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=3000)
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--max-distance", type=int, default=5)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--top-k", type=int, default=8)
    ap.add_argument("--config", default=None, metavar="PATH",
                    help="load a tuned (MaxDistance, ServeConfig) JSON "
                         "artifact (repro.tune.report); overrides "
                         "--max-distance/--max-batch/--top-k, while "
                         "explicit --deadline-ms/--admission still apply")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request budget; responses report deadline_met "
                         "(<= 0 disables deadlines)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the drain's span tree as Chrome JSON trace "
                         "format (load in https://ui.perfetto.dev)")
    ap.add_argument("--admission", action="store_true",
                    help="enable the §17 deadline control loop (admission "
                         "verdicts, load shedding, EDF splits); requires "
                         "--deadline-ms to have any effect")
    ap.add_argument("--load-qps", type=float, default=None, metavar="QPS",
                    help="replay an open-loop Poisson trace at QPS instead "
                         "of one closed batch (repro.serving.load); reports "
                         "met/shed/reject rates")
    ap.add_argument("--load-duration-s", type=float, default=2.0,
                    help="open-loop trace length (with --load-qps)")
    return ap


def resolve_config(args) -> tuple[int, ServeConfig]:
    """(max_distance, ServeConfig) from flags, or from a tuned artifact
    with explicit deadline/admission flags overlaid on top."""
    deadline_on = args.deadline_ms is not None and args.deadline_ms > 0
    if args.config is not None:
        from repro.tune.report import load_serve_config

        max_distance, cfg, meta = load_serve_config(args.config)
        overlay: dict = {}
        if args.deadline_ms is not None:
            overlay["default_deadline_s"] = (
                args.deadline_ms / 1e3 if deadline_on else None)
        if args.admission:
            overlay["admission"] = True
            if cfg.max_queue is None:
                overlay["max_queue"] = 4 * cfg.max_batch
        if overlay:
            cfg = dataclasses.replace(cfg, **overlay)
        origin = meta.get("workload", meta.get("bench", "sweep"))
        print(f"loaded tuned config from {args.config} "
              f"(max_distance={max_distance}, tuned on {origin!r})",
              file=sys.stderr)
        return max_distance, cfg
    cfg = ServeConfig(
        max_batch=args.max_batch, top_k=args.top_k,
        default_deadline_s=args.deadline_ms / 1e3 if deadline_on else None,
        admission=args.admission,
        max_queue=4 * args.max_batch if args.admission else None,
    )
    return args.max_distance, cfg


def main() -> None:
    args = build_parser().parse_args()

    table, lex = generate_corpus(args.n_docs, mean_doc_len=160, vocab_size=40_000, seed=1)
    max_distance, cfg = resolve_config(args)
    index = build_index(table, lex, max_distance=max_distance)
    mesh = make_mesh((1, 1), ("data", "model"))
    deadline_on = args.deadline_ms is not None and args.deadline_ms > 0
    service = SearchService(index, mesh, cfg)
    queries = sample_stop_queries(table, lex, args.requests, window=3, seed=2)

    if args.load_qps is not None:
        from repro.serving import poisson_arrivals, run_open_loop, warm_service

        warm_service(service, queries)
        arrivals = poisson_arrivals(args.load_qps, args.load_duration_s, seed=2)
        rep = run_open_loop(
            service, queries, arrivals,
            deadline_s=(args.deadline_ms / 1e3 if deadline_on
                        else cfg.default_deadline_s or 0.05),
            offered_qps=len(arrivals) / args.load_duration_s,
        )
        print(f"open loop: offered {rep.offered_qps:.0f} qps for "
              f"{args.load_duration_s:.1f}s -> served {rep.n_served}/"
              f"{rep.n_offered} (goodput {rep.achieved_qps:.0f} qps); "
              f"met={rep.met_rate:.3f} shed={rep.shed_rate:.3f} "
              f"reject={rep.reject_rate:.3f}")
        stats = service.stats_snapshot()
        if cfg.admission:
            print(f"admission: {stats['admission']}")
        if args.trace_out:
            trace = service.write_trace(args.trace_out)
            print(f"wrote {len(trace['traceEvents'])} trace events to "
                  f"{args.trace_out} (open in https://ui.perfetto.dev)")
        return

    for q in queries:
        service.submit(q)
    t0 = time.time()
    responses = service.drain()
    wall = time.time() - t0
    lat = np.array([r.latency_s for r in responses])
    stats = service.stats_snapshot()
    print(
        f"served {len(responses)} requests in {wall:.2f}s ({len(responses)/wall:.1f} qps); "
        f"batch p50={np.percentile(lat, 50)*1e3:.1f}ms p99={np.percentile(lat, 99)*1e3:.1f}ms; "
        f"buckets={stats['bucket_hist']}"
    )
    phase = service.metrics_snapshot("serve.phase.")
    breakdown = "  ".join(
        f"{name.rsplit('.', 1)[-1]}={h['p50']/1e3:.2f}ms"
        for name, h in phase.items() if h["count"]
    )
    print(f"phase p50: {breakdown}")
    if deadline_on:
        met = sum(1 for r in responses if r.deadline_met)
        print(f"deadline {args.deadline_ms:.0f}ms: met {met}/{len(responses)} "
              f"({met/len(responses):.1%}); miss blame: "
              f"{stats['deadlines']['miss_blame']}")
    if args.trace_out:
        trace = service.write_trace(args.trace_out)
        print(f"wrote {len(trace['traceEvents'])} trace events to "
              f"{args.trace_out} (open in https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
