"""Roofline analysis over the dry-run records (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell:
    compute    = corrected_HLO_flops / peak_flops          [s]
    memory     = corrected_HLO_bytes / HBM_bw              [s]
    collective = collective_bytes    / link_bw             [s]

All quantities are per device. Corrections:
  * LM cells: cost_analysis counts the layer scan body once, so
    corrected = full + (L-1) * layer_probe (flops & bytes);
  * collectives inside while bodies are multiplied by the trip count
    (hlo_analysis.CollectiveStats.total);
  * MODEL_FLOPS = 6*N*T (train), 2*N*T (prefill/serve fwd), with
    N_active for MoE — the brief's utilization yardstick.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def model_flops_per_device(rec: dict, archs) -> float:
    """Analytic useful flops per device for the cell."""
    arch = archs[rec["arch"]]
    n_dev = rec["n_devices"]
    kind = rec["kind"]
    meta = rec.get("meta", {})
    if arch.family == "lm":
        n_active = meta.get("active_params", meta.get("model_params", 0))
        tokens = meta.get("tokens", 0)
        mult = 6 if kind == "train" else 2
        return mult * n_active * tokens / n_dev
    if arch.family == "gnn":
        cfg = arch.model_cfg
        dims = arch.shapes[rec["shape"]].dims
        h = cfg.d_hidden
        E = dims["n_edges"] * dims.get("batch", 1)
        N = dims["n_nodes"] * dims.get("batch", 1)
        F = dims["d_feat"]
        fwd = cfg.n_layers * (
            2 * E * ((2 * h + 1) * h + h * h)  # edge MLP
            + 2 * E * (h * h + h)  # coord MLP
            + 2 * N * (2 * h * h + h * h)  # node MLP
        ) + 2 * N * F * h
        return 3 * fwd / n_dev  # train: fwd+bwd
    if arch.family == "recsys":
        cfg = arch.model_cfg
        dims = arch.shapes[rec["shape"]].dims
        B = dims.get("batch", 1)
        C = dims.get("n_candidates", 0)
        name = type(cfg).__name__
        if name == "SeqRecConfig":
            d = cfg.embed_dim
            blk = cfg.n_blocks * (4 * d * d + 3 * d * 4 * d)  # attn + glu mlp
            fwd = 2 * B * cfg.seq_len * blk
            if kind == "train":
                fwd += 2 * B * 256 * d  # sampled softmax
                return 3 * fwd / n_dev
            fwd += 2 * B * (C if C else 100) * d
            return fwd / n_dev
        if name == "DINConfig":
            d = 2 * cfg.embed_dim
            attn_p = 4 * d * cfg.attn_mlp[0] + cfg.attn_mlp[0] * cfg.attn_mlp[1] + cfg.attn_mlp[1]
            mlp_p = (3 * d + cfg.d_user) * cfg.mlp[0] + cfg.mlp[0] * cfg.mlp[1] + cfg.mlp[1]
            rows = C if kind == "retrieval" else B
            fwd = 2 * rows * (cfg.seq_len * attn_p + mlp_p)
            return (3 if kind == "train" else 1) * fwd / n_dev
        # TwoTower
        t1, t2, t3 = cfg.tower
        d = cfg.embed_dim
        tower_p = (d + cfg.d_user) * t1 + t1 * t2 + t2 * t3
        item_p = 2 * d * t1 + t1 * t2 + t2 * t3
        if kind == "retrieval":
            fwd = 2 * (item_p * C + tower_p) + 2 * C * t3
        elif kind == "train":
            fwd = 3 * (2 * B * (tower_p + item_p) + 2 * B * B * t3)
        else:
            fwd = 2 * B * (tower_p + item_p) + 2 * B * t3
        return fwd / n_dev
    # search: useful work = one compare + select per posting slot
    postings = rec.get("meta", {}).get("postings", 0)
    return 2 * postings / n_dev


def analyze(rec: dict, archs) -> dict:
    meta = rec.get("meta", {})
    L = meta.get("n_layers", 1)
    flops = rec["cost"]["flops"]
    bytes_ = rec["cost"]["bytes_accessed"]
    probe = rec.get("layer_probe")
    if probe and L > 1:
        flops = flops + (L - 1) * probe["flops"]
        bytes_ = bytes_ + (L - 1) * probe["bytes_accessed"]
    coll = rec.get("collectives", {})
    once = sum(coll.get("once_bytes", {}).values())
    in_loop = sum(coll.get("in_loop_bytes", {}).values())
    coll_bytes = once + in_loop * L
    t_comp = flops / PEAK_FLOPS
    t_mem = bytes_ / HBM_BW
    t_coll = coll_bytes / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec, archs)
    util = mf / flops if flops else 0.0
    bound = max(terms.values())
    roofline_frac = t_comp / bound if bound else 0.0
    suggestions = {
        "compute": "compute-bound: raise MFU (fuse smalls, widen microbatch)",
        "memory": "memory-bound: cut bytes (quantize KV/params, fuse, remat less)",
        "collective": "collective-bound: overlap comm/compute, reshard to shrink gathers",
    }
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "kind": rec["kind"],
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": flops,
        "useful_flops_ratio": util,
        "roofline_fraction": roofline_frac,
        "peak_gib": rec["memory"]["peak_per_device_gib"],
        "note": suggestions[dominant],
    }


def to_markdown(rows: list[dict]) -> str:
    hdr = (
        "| arch | shape | mesh | compute s | memory s | collective s | dominant | "
        "MODEL/HLO flops | roofline frac | peak GiB |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | **{r['dominant']}** | "
            f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.2f} | {r['peak_gib']:.1f} |\n"
        )
    return "".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun.jsonl")
    ap.add_argument("--out", default="results/roofline.md")
    ap.add_argument("--json-out", default="results/roofline.json")
    args = ap.parse_args()
    from repro.configs.registry import ARCHS

    rows = []
    seen = set()
    for line in Path(args.dryrun).read_text().splitlines():
        rec = json.loads(line)
        if "error" in rec:
            continue
        key = (rec["arch"], rec["shape"], rec["mesh"])
        if key in seen:
            continue
        seen.add(key)
        rows.append(analyze(rec, ARCHS))
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    md = to_markdown(rows)
    Path(args.out).write_text(md)
    Path(args.json_out).write_text(json.dumps(rows, indent=1))
    print(md)
    # hillclimb candidates
    worst = min(rows, key=lambda r: r["roofline_fraction"])
    most_coll = max(rows, key=lambda r: r["t_collective_s"])
    print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']} = {worst['roofline_fraction']:.3f}")
    print(f"most collective-bound:  {most_coll['arch']}/{most_coll['shape']} = {most_coll['t_collective_s']:.2e}s")


if __name__ == "__main__":
    main()
