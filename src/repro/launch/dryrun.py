import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes and record memory / cost / collective
analyses for the roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch all
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --arch qwen1.5-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --layer-probe ...   (per-layer costs for scan scaling)

Results are appended to --out (JSON), one record per cell.
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs.registry import ARCHS, get_arch
from repro.launch.hlo_analysis import collective_stats
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_lm_layer_probe, build_step


def run_cell(arch_id: str, shape_name: str, multi_pod: bool, layer_probe: bool = False) -> dict:
    arch = get_arch(arch_id)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
        "kind": arch.shapes[shape_name].kind,
    }
    t0 = time.time()
    built = build_step(arch, shape_name, mesh)
    lowered = built.lower()
    rec["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 1)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_per_device_gib": round(
            (ma.argument_size_in_bytes + ma.output_size_in_bytes
             + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3),
    }
    ca = compiled.cost_analysis() or {}
    rec["cost"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }
    txt = compiled.as_text()
    cs = collective_stats(txt)
    rec["collectives"] = {
        "once_bytes": {k: int(v) for k, v in cs.op_bytes.items()},
        "in_loop_bytes": {k: int(v) for k, v in cs.in_loop_bytes.items()},
        "n_ops": cs.count,
    }
    rec["meta"] = built.meta
    # per-layer probe: undoes scan's count-the-body-once in cost_analysis
    if arch.family == "lm":
        probe = build_lm_layer_probe(arch, arch.shapes[shape_name], mesh)
        pcomp = probe.lower().compile()
        pca = pcomp.cost_analysis() or {}
        rec["layer_probe"] = {
            "flops": float(pca.get("flops", 0.0)),
            "bytes_accessed": float(pca.get("bytes accessed", 0.0)),
        }
    print(
        f"[dryrun] {arch_id}/{shape_name} mesh={rec['mesh']} "
        f"compile={rec['compile_s']}s peak/dev={rec['memory']['peak_per_device_gib']} GiB "
        f"flops={rec['cost']['flops']:.3e} colls={cs.count}"
    )
    return rec


def iter_cells(arch_sel: str, shape_sel: str):
    if arch_sel == "all":
        arch_ids = [a for a in ARCHS]
    elif arch_sel == "assigned":
        from repro.configs.registry import ASSIGNED_ARCH_IDS

        arch_ids = list(ASSIGNED_ARCH_IDS)
    else:
        arch_ids = [arch_sel]
    for aid in arch_ids:
        arch = get_arch(aid)
        shapes = [shape_sel] if shape_sel != "all" else list(arch.shapes)
        for s in shapes:
            if s in arch.shapes:
                yield aid, s
        if shape_sel == "all":
            for s, why in arch.skips.items():
                print(f"[dryrun] SKIP {aid}/{s}: {why}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--keep-going", action="store_true", default=True)
    args = ap.parse_args()

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    # skip cells already recorded (restartable across invocations)
    done = set()
    if out.exists():
        for line in out.read_text().splitlines():
            try:
                r = json.loads(line)
                if "error" not in r:
                    done.add((r["arch"], r["shape"], r["mesh"]))
            except json.JSONDecodeError:
                pass
    failures = 0
    with out.open("a") as fh:
        for multi in meshes:
            mesh_name = "2x16x16" if multi else "16x16"
            for aid, s in iter_cells(args.arch, args.shape):
                if (aid, s, mesh_name) in done:
                    print(f"[dryrun] cached {aid}/{s} {mesh_name}")
                    continue
                try:
                    rec = run_cell(aid, s, multi)
                except Exception as e:  # record and continue
                    failures += 1
                    rec = {
                        "arch": aid, "shape": s, "mesh": mesh_name,
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                    print(f"[dryrun] FAIL {aid}/{s} {mesh_name}: {rec['error']}")
                fh.write(json.dumps(rec) + "\n")
                fh.flush()
    print(f"[dryrun] done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
