"""HLO-text analysis for the roofline: collective bytes + while-loop
awareness.

`compiled.cost_analysis()` counts a scanned loop body ONCE (verified
empirically on jax 0.8.2 / XLA CPU), so per-(arch,shape) totals are
reconstructed as: non-loop costs + trip_count * loop-body costs. Loop
bodies are identified per HLO computation (transitively from `while`
instructions) and the caller supplies the trip count (layer count).

Collective bytes = sum of result-shape sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops (sync or async
-start forms) — a per-device traffic proxy.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    op_bytes: dict = field(default_factory=dict)  # outside loops
    in_loop_bytes: dict = field(default_factory=dict)  # inside while bodies
    count: int = 0

    def total(self, loop_trip_count: int = 1) -> float:
        return sum(self.op_bytes.values()) + sum(self.in_loop_bytes.values()) * max(
            loop_trip_count, 1
        )


def split_computations(hlo_text: str) -> dict:
    """computation name -> list of instruction lines. Robust to headers
    containing '=' in comments/aliasing and to FileNames sections."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        if not line:
            continue
        if not line.startswith(" "):
            s = line.rstrip()
            if s.endswith("{") and "(" in s:
                head = s.split("(", 1)[0].strip()
                toks = head.split()
                name = toks[-1].lstrip("%") if toks else ""
                cur = name
                comps[cur] = []
            else:
                cur = None  # '}' / HloModule / FileNames / etc.
            continue
        if cur is not None:
            comps[cur].append(line.strip())
    return comps


_CALL_RE = re.compile(r"(?:body|condition|to_apply|calls)=\s*%?([\w\.\-]+)")
_WHILE_BODY_RE = re.compile(r"while\(.*body=\s*%?([\w\.\-]+)", re.DOTALL)


def _called_by_while(comps: dict) -> set:
    calls: dict[str, set] = {}
    while_roots: set = set()
    for name, lines in comps.items():
        cs = set()
        for ln in lines:
            for m in _CALL_RE.finditer(ln):
                cs.add(m.group(1))
            if " while(" in ln:
                m = re.search(r"body=\s*%?([\w\.\-]+)", ln)
                if m:
                    while_roots.add(m.group(1))
        calls[name] = cs
    seen = set()
    stack = list(while_roots)
    while stack:
        n = stack.pop()
        if n in seen:
            continue
        seen.add(n)
        stack.extend(calls.get(n, ()))
    return seen


def collective_stats(hlo_text: str) -> CollectiveStats:
    comps = split_computations(hlo_text)
    loop_comps = _called_by_while(comps)
    stats = CollectiveStats()
    for name, lines in comps.items():
        in_loop = name in loop_comps
        for ln in lines:
            if "=" not in ln:
                continue
            for kind in _COLLECTIVES:
                if f" {kind}(" in ln or f" {kind}-start(" in ln:
                    lhs = ln.split("=", 1)[1]
                    b = _shape_bytes(lhs.split("(", 1)[0])
                    d = stats.in_loop_bytes if in_loop else stats.op_bytes
                    d[kind] = d.get(kind, 0) + b
                    stats.count += 1
                    break
    return stats
