"""Step builders: (arch x shape x mesh) -> jitted step + abstract inputs.

This is the single entry point used by the multi-pod dry-run, the roofline
analysis, the smoke tests and the example drivers. For every cell it
returns a `BuiltStep` carrying the jitted function (with in/out shardings
attached), the ordered abstract arguments (ShapeDtypeStruct pytrees — no
allocation), and metadata for the roofline (model flops, layer count).

Sharding strategy (DESIGN.md §5):
* LM train: FSDP(data) x TP(model) params + DP(pod) replication;
  batch over (pod, data);
* LM serving: TP-only params (replicated over data); KV cache batch over
  (pod,data), kv-heads over model when divisible else cache-seq over model;
* MoE: experts over model (expert parallelism inside shard_map);
* GNN: edge-parallel over the full mesh, nodes replicated;
* recsys: tables row-sharded over model, batch over (pod,data);
  retrieval shards the candidate axis over the dp axes (candidate ids must
  not be sharded over `model` — the table-shard psum would mix rows);
* search: doc-sharded postings over model, queries over (pod,data).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field, replace
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec, ShapeSpec
from repro.launch.mesh import dp_axes_of, dp_size, tp_size
from repro.models import gnn, recsys, transformer
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state

F32 = jnp.float32
I32 = jnp.int32


@dataclass
class BuiltStep:
    fn: Any  # jitted
    args: tuple  # abstract (ShapeDtypeStruct) pytrees, positional
    meta: dict = field(default_factory=dict)

    def lower(self):
        return self.fn.lower(*self.args)


def _is_pspec(x):
    return isinstance(x, P)


def _shardings(mesh, pspecs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs, is_leaf=_is_pspec)


def fsdpify(pspecs, abstract_params, mesh, axis="data"):
    """Add FSDP sharding over `axis` to the first shardable free dimension
    of each parameter (skipping the scan/layer-stack dim)."""
    fs = mesh.shape.get(axis, 1)
    if fs == 1:
        return pspecs

    def per_leaf(path, spec, arr):
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        skip0 = "layers" in names or "blocks" in names
        parts = list(spec) + [None] * (arr.ndim - len(spec))
        for i in range(1 if skip0 else 0, arr.ndim):
            if parts[i] is None and arr.shape[i] % fs == 0 and arr.shape[i] >= fs:
                parts[i] = axis
                break
        return P(*parts)

    return jax.tree_util.tree_map_with_path(
        per_leaf, pspecs, abstract_params, is_leaf=_is_pspec
    )


# ==========================================================================
# LM family
# ==========================================================================
def pad_heads_cfg(cfg, tp: int):
    """§Perf hillclimb: round the head count up to a TP multiple (Qwen-32B:
    40 -> 48 on TP=16) so q/k/v/wo shard by head instead of triggering
    GSPMD's involuntary full rematerialization. Numerically equivalent
    when the pad-head projections are zero (wo rows zero the pad heads'
    contribution)."""
    if cfg.n_heads % tp == 0:
        return cfg
    pad_to = -(-cfg.n_heads // tp) * tp
    kv = cfg.n_kv if cfg.n_kv % tp == 0 or cfg.n_kv != cfg.n_heads else pad_to
    return replace(cfg, n_heads=pad_to, n_kv=kv, d_head=cfg.head_dim)


def build_lm_step(arch: ArchSpec, shape: ShapeSpec, mesh) -> BuiltStep:
    import os

    cfg = arch.model_cfg
    dp_ax = dp_axes_of(mesh)
    tp = tp_size(mesh)
    if os.environ.get("REPRO_PAD_HEADS", "0") == "1":
        cfg = pad_heads_cfg(cfg, tp)
    dims = shape.dims
    B, S = dims["global_batch"], dims["seq_len"]
    params_abs = jax.eval_shape(
        functools.partial(transformer.init_params, cfg), jax.random.key(0)
    )
    meta = {
        "model_params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "n_layers": cfg.n_layers,
        "tokens": B * S if shape.kind != "decode" else B,
    }

    if shape.kind == "train":
        opt_abs = jax.eval_shape(init_opt_state, params_abs)
        pspecs = transformer.param_pspecs(cfg, tp)
        pspecs = fsdpify(pspecs, params_abs, mesh)
        opt_pspecs = {"mu": pspecs, "nu": pspecs, "step": P()}
        batch_spec = {"tokens": P(dp_ax, None), "targets": P(dp_ax, None)}
        # reduced (smoke/example) configs train faster with a higher LR
        opt_cfg = AdamWConfig(lr=1e-3) if cfg.d_model <= 128 else AdamWConfig()
        # microbatching: bound the remat residual stack (L x Bmicro x S x D
        # bf16) per device. The budget is tunable because it trades
        # activation memory against FSDP re-gather traffic (params are
        # re-gathered once per microbatch per layer — §Perf hillclimb B):
        # a 2x larger stack budget halves the collective term.
        stack_gib = float(os.environ.get("REPRO_MICRO_STACK_GIB", "2"))
        dp = dp_size(mesh)
        b_local = max(B // dp, 1)
        stack_bytes = lambda bm: cfg.n_layers * bm * S * cfg.d_model * 2
        micro_local = b_local
        while micro_local > 1 and stack_bytes(micro_local) > stack_gib * 2**30:
            micro_local //= 2
        n_micro = b_local // micro_local
        meta["n_micro"] = n_micro

        import os

        bf16_gather = os.environ.get("REPRO_BF16_GATHER", "1") != "0"
        meta["bf16_gather"] = bf16_gather

        def step(params, opt_state, batch):
            loss, grads = transformer.lm_grads_microbatched(
                cfg, params, batch["tokens"], batch["targets"], n_micro, mesh, dp_ax,
                param_pspecs=pspecs, bf16_gather=bf16_gather,
            )
            new_p, new_s, gnorm = adamw_update(opt_cfg, params, grads, opt_state)
            return new_p, new_s, {"loss": loss, "grad_norm": gnorm}

        batch_abs = {
            "tokens": jax.ShapeDtypeStruct((B, S), I32),
            "targets": jax.ShapeDtypeStruct((B, S), I32),
        }
        fn = jax.jit(
            step,
            in_shardings=(
                _shardings(mesh, pspecs),
                _shardings(mesh, opt_pspecs),
                _shardings(mesh, batch_spec),
            ),
            out_shardings=(
                _shardings(mesh, pspecs),
                _shardings(mesh, opt_pspecs),
                _shardings(mesh, {"loss": P(), "grad_norm": P()}),
            ),
            donate_argnums=(0, 1),
        )
        return BuiltStep(fn, (params_abs, opt_abs, batch_abs), meta)

    # serving: bf16 params (production serving never keeps f32 masters)
    params_abs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params_abs,
    )
    serve_pspecs = transformer.param_pspecs(cfg, tp)
    if shape.kind == "prefill":
        def step(params, tokens):
            return transformer.prefill(cfg, params, tokens, mesh, dp_ax)

        cache_spec = transformer.cache_pspecs(cfg, tp, dp_ax, seq_len=S)
        fn = jax.jit(
            step,
            in_shardings=(
                _shardings(mesh, serve_pspecs),
                NamedSharding(mesh, P(dp_ax, None)),
            ),
            out_shardings=(
                NamedSharding(mesh, P(dp_ax, None)),
                _shardings(mesh, cache_spec),
            ),
        )
        tokens_abs = jax.ShapeDtypeStruct((B, S), I32)
        return BuiltStep(fn, (params_abs, tokens_abs), meta)

    # decode: one new token against an S-long KV cache.
    # REPRO_KV_INT8=1 switches to the quantized cache (§Perf hillclimb).
    import os

    kv_int8 = os.environ.get("REPRO_KV_INT8", "0") == "1"
    meta["kv_cache"] = "int8" if kv_int8 else "bf16"
    kshape = (cfg.n_layers, B, S, cfg.n_kv, cfg.head_dim)
    if kv_int8:
        cache_abs = {
            "k": jax.ShapeDtypeStruct(kshape, jnp.int8),
            "v": jax.ShapeDtypeStruct(kshape, jnp.int8),
            "k_scale": jax.ShapeDtypeStruct(kshape[:-1], jnp.float32),
            "v_scale": jax.ShapeDtypeStruct(kshape[:-1], jnp.float32),
        }
    else:
        cache_abs = {
            "k": jax.ShapeDtypeStruct(kshape, jnp.bfloat16),
            "v": jax.ShapeDtypeStruct(kshape, jnp.bfloat16),
        }
    cache_spec = transformer.cache_pspecs(cfg, tp, dp_ax, seq_len=S, quantized=kv_int8)

    def step(params, token, caches, position):
        return transformer.decode_step(cfg, params, token, caches, position, mesh, dp_ax)

    fn = jax.jit(
        step,
        in_shardings=(
            _shardings(mesh, serve_pspecs),
            NamedSharding(mesh, P(dp_ax, None)),
            _shardings(mesh, cache_spec),
            NamedSharding(mesh, P()),
        ),
        out_shardings=(
            NamedSharding(mesh, P(dp_ax, None)),
            _shardings(mesh, cache_spec),
        ),
        donate_argnums=(2,),
    )
    token_abs = jax.ShapeDtypeStruct((B, 1), I32)
    pos_abs = jax.ShapeDtypeStruct((), I32)
    return BuiltStep(fn, (params_abs, token_abs, cache_abs, pos_abs), meta)


def build_lm_layer_probe(arch: ArchSpec, shape: ShapeSpec, mesh) -> BuiltStep:
    """Single-transformer-layer microstep with the cell's sharding: its
    cost_analysis supplies the per-layer flops/bytes that the roofline
    multiplies by (L-1) to undo scan's count-the-body-once behaviour.
    Collectives are NOT taken from the probe (the full graph's while-body
    parse already scales them)."""
    cfg = arch.model_cfg
    dp_ax = dp_axes_of(mesh)
    tp = tp_size(mesh)
    dims = shape.dims
    B, S = dims["global_batch"], dims["seq_len"]
    block = transformer._block(cfg, mesh, dp_ax)
    layer_abs = jax.eval_shape(
        functools.partial(transformer._layer_init, cfg), jax.random.key(0)
    )
    layer_specs = transformer.param_pspecs(cfg, tp, stacked=False)["layers"]
    dt = jnp.dtype(cfg.dtype)
    x_spec = P(dp_ax, None, None)

    if shape.kind == "train":
        def probe(x, p_l):
            def f(args):
                y, _, aux = block(args[0], args[1])
                return jnp.sum(y.astype(jnp.float32)) + aux

            loss, grads = jax.value_and_grad(f)((x, p_l))
            return loss, grads

        x_abs = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
        fn = jax.jit(
            probe,
            in_shardings=(NamedSharding(mesh, x_spec), _shardings(mesh, layer_specs)),
        )
        return BuiltStep(fn, (x_abs, layer_abs), {"n_layers": 1})

    if shape.kind == "prefill":
        def probe(x, p_l):
            y, cache, _ = block(x, p_l)
            return y, cache

        x_abs = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
        fn = jax.jit(
            probe,
            in_shardings=(NamedSharding(mesh, x_spec), _shardings(mesh, layer_specs)),
        )
        return BuiltStep(fn, (x_abs, layer_abs), {"n_layers": 1})

    # decode
    cache_abs = {
        "k": jax.ShapeDtypeStruct((B, S, cfg.n_kv, cfg.head_dim), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((B, S, cfg.n_kv, cfg.head_dim), jnp.bfloat16),
    }
    full_spec = transformer.cache_pspecs(cfg, tp, dp_ax, seq_len=S)
    cache_spec = {k: P(*tuple(v)[1:]) for k, v in full_spec.items()}  # drop L dim

    def probe(x, p_l, cache_l, position):
        y, new_cache, _ = block(x, p_l, cache_l=cache_l, position=position)
        return y, new_cache

    x_abs = jax.ShapeDtypeStruct((B, 1, cfg.d_model), dt)
    fn = jax.jit(
        probe,
        in_shardings=(
            NamedSharding(mesh, x_spec),
            _shardings(mesh, layer_specs),
            _shardings(mesh, cache_spec),
            NamedSharding(mesh, P()),
        ),
    )
    return BuiltStep(fn, (x_abs, layer_abs, cache_abs, jax.ShapeDtypeStruct((), I32)), {"n_layers": 1})


# ==========================================================================
# GNN family (EGNN)
# ==========================================================================
def build_gnn_step(arch: ArchSpec, shape: ShapeSpec, mesh) -> BuiltStep:
    dims = shape.dims
    cfg = replace(arch.model_cfg, d_feat=dims["d_feat"])
    params_abs = jax.eval_shape(functools.partial(gnn.init_params, cfg), jax.random.key(0))
    opt_abs = jax.eval_shape(init_opt_state, params_abs)
    opt_cfg = AdamWConfig(lr=1e-3, weight_decay=0.0)
    dp_ax = dp_axes_of(mesh)
    all_axes = tuple(mesh.axis_names)
    meta = {
        "n_layers": cfg.n_layers,
        "model_params": sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params_abs)),
        "n_edges": dims["n_edges"] * dims.get("batch", 1),
    }

    p_spec = jax.tree.map(lambda _: P(), params_abs)
    opt_spec = {"mu": p_spec, "nu": p_spec, "step": P()}

    if dims.get("batched"):
        Bt, N, E = dims["batch"], dims["n_nodes"], dims["n_edges"]
        batch_abs = {
            "feats": jax.ShapeDtypeStruct((Bt, N, dims["d_feat"]), F32),
            "coords": jax.ShapeDtypeStruct((Bt, N, 3), F32),
            "src": jax.ShapeDtypeStruct((Bt, E), I32),
            "dst": jax.ShapeDtypeStruct((Bt, E), I32),
            "edge_mask": jax.ShapeDtypeStruct((Bt, E), F32),
            "node_mask": jax.ShapeDtypeStruct((Bt, N), F32),
            "targets": jax.ShapeDtypeStruct((Bt, N), F32),
        }
        batch_spec = {k: P(dp_ax, *([None] * (len(v.shape) - 1))) for k, v in batch_abs.items()}

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(lambda p: gnn.batched_loss(cfg, p, batch))(params)
            new_p, new_s, gnorm = adamw_update(opt_cfg, params, grads, opt_state)
            return new_p, new_s, {"loss": loss, "grad_norm": gnorm}

    else:
        N, E = dims["n_nodes"], dims["n_edges"]
        # pad the edge axis to a multiple of 512 so it shards over either
        # production mesh (256 or 512 devices); pad edges carry mask=0
        E = -(-E // 512) * 512
        batch_abs = {
            "feats": jax.ShapeDtypeStruct((N, dims["d_feat"]), F32),
            "coords": jax.ShapeDtypeStruct((N, 3), F32),
            "src": jax.ShapeDtypeStruct((E,), I32),
            "dst": jax.ShapeDtypeStruct((E,), I32),
            "edge_mask": jax.ShapeDtypeStruct((E,), F32),
            "node_mask": jax.ShapeDtypeStruct((N,), F32),
            "targets": jax.ShapeDtypeStruct((N,), F32),
        }
        e_spec = P(all_axes)
        batch_spec = {
            "feats": P(), "coords": P(), "src": e_spec, "dst": e_spec,
            "edge_mask": e_spec, "node_mask": P(), "targets": P(),
        }

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: gnn.loss_fn(cfg, p, batch, mesh=mesh, edge_axes=all_axes)
            )(params)
            new_p, new_s, gnorm = adamw_update(opt_cfg, params, grads, opt_state)
            return new_p, new_s, {"loss": loss, "grad_norm": gnorm}

    fn = jax.jit(
        step,
        in_shardings=(
            _shardings(mesh, p_spec),
            _shardings(mesh, opt_spec),
            _shardings(mesh, batch_spec),
        ),
        out_shardings=(
            _shardings(mesh, p_spec),
            _shardings(mesh, opt_spec),
            _shardings(mesh, {"loss": P(), "grad_norm": P()}),
        ),
        donate_argnums=(0, 1),
    )
    return BuiltStep(fn, (params_abs, opt_abs, batch_abs), meta)


# ==========================================================================
# RecSys family
# ==========================================================================
def _recsys_param_pspecs(params_abs, mesh):
    """Embedding tables row-sharded over model when divisible; towers
    replicated (tiny)."""
    tp = tp_size(mesh)

    def per_leaf(path, arr):
        names = [str(getattr(p, "key", "")) for p in path]
        if (
            any("emb" in n for n in names)
            and arr.ndim == 2
            and arr.shape[0] % tp == 0
            and arr.shape[0] >= 64 * tp
        ):
            return P("model", None)
        return P(*([None] * arr.ndim))

    return jax.tree_util.tree_map_with_path(per_leaf, params_abs)


def build_recsys_step(arch: ArchSpec, shape: ShapeSpec, mesh) -> BuiltStep:
    cfg = arch.model_cfg
    dp_ax = dp_axes_of(mesh)
    dims = shape.dims
    B = dims["batch"]
    kind = shape.kind
    arch_kind = (
        "seqrec" if isinstance(cfg, recsys.SeqRecConfig)
        else "din" if isinstance(cfg, recsys.DINConfig)
        else "twotower"
    )
    tp = tp_size(mesh)

    init = {
        "seqrec": functools.partial(recsys.seqrec_init, cfg),
        "din": functools.partial(recsys.din_init, cfg),
        "twotower": functools.partial(recsys.twotower_init, cfg),
    }[arch_kind]
    params_abs = jax.eval_shape(init, jax.random.key(0))
    p_spec = _recsys_param_pspecs(params_abs, mesh)
    # tables actually sharded? (smoke configs are too small to shard)
    table_sharded = any(
        s != P(*([None] * 2)) for s in jax.tree.leaves(p_spec, is_leaf=_is_pspec) if len(s) == 2
    ) and tp > 1
    use_mesh = mesh if table_sharded else None
    meta = {
        "n_layers": getattr(cfg, "n_blocks", 1),
        "model_params": sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params_abs)),
    }

    from repro.configs.recsys_archs import N_NEG

    if arch_kind == "seqrec":
        S = cfg.seq_len
        if kind == "train":
            batch_abs = {
                "hist": jax.ShapeDtypeStruct((B, S), I32),
                "target": jax.ShapeDtypeStruct((B,), I32),
                "negatives": jax.ShapeDtypeStruct((B, N_NEG), I32),
            }
        elif kind == "serve":
            batch_abs = {
                "hist": jax.ShapeDtypeStruct((B, S), I32),
                "candidates": jax.ShapeDtypeStruct((B, 100), I32),
            }
        else:
            C = dims["n_candidates"]
            batch_abs = {
                "hist": jax.ShapeDtypeStruct((1, S), I32),
                "candidates": jax.ShapeDtypeStruct((1, C), I32),
            }
    elif arch_kind == "din":
        S = cfg.seq_len
        if kind == "retrieval":
            C = dims["n_candidates"]
            batch_abs = {
                "hist_items": jax.ShapeDtypeStruct((1, S), I32),
                "hist_cates": jax.ShapeDtypeStruct((1, S), I32),
                "cand_items": jax.ShapeDtypeStruct((C,), I32),
                "cand_cates": jax.ShapeDtypeStruct((C,), I32),
                "user_feats": jax.ShapeDtypeStruct((1, cfg.d_user), F32),
            }
        else:
            batch_abs = {
                "hist_items": jax.ShapeDtypeStruct((B, S), I32),
                "hist_cates": jax.ShapeDtypeStruct((B, S), I32),
                "target_item": jax.ShapeDtypeStruct((B,), I32),
                "target_cate": jax.ShapeDtypeStruct((B,), I32),
                "user_feats": jax.ShapeDtypeStruct((B, cfg.d_user), F32),
            }
            if kind == "train":
                batch_abs["labels"] = jax.ShapeDtypeStruct((B,), F32)
    else:
        if kind == "retrieval":
            C = dims["n_candidates"]
            batch_abs = {
                "hist": jax.ShapeDtypeStruct((1, cfg.hist_len), I32),
                "user_feats": jax.ShapeDtypeStruct((1, cfg.d_user), F32),
                "cand_items": jax.ShapeDtypeStruct((C,), I32),
                "cand_cates": jax.ShapeDtypeStruct((C,), I32),
            }
        else:
            batch_abs = {
                "hist": jax.ShapeDtypeStruct((B, cfg.hist_len), I32),
                "user_feats": jax.ShapeDtypeStruct((B, cfg.d_user), F32),
                "item": jax.ShapeDtypeStruct((B,), I32),
                "cate": jax.ShapeDtypeStruct((B,), I32),
            }
            if kind == "train":
                batch_abs["log_q"] = jax.ShapeDtypeStruct((B,), F32)

    # batch sharding: candidate axes over dp only (see module docstring);
    # B=1 axes replicated; everything else over dp.
    cand_spec_1d = P(dp_ax) if (dims.get("n_candidates", 0) % max(dp_size(mesh), 1) == 0 and dp_size(mesh) > 1) else P()

    def batch_pspec(name, arr):
        if name == "candidates" and arr.shape[0] == 1:
            return P(None, dp_ax if (dp_size(mesh) > 1 and arr.shape[1] % dp_size(mesh) == 0) else None)
        if name.startswith("cand"):
            return cand_spec_1d
        if arr.shape[0] == 1 or dp_size(mesh) == 1 or arr.shape[0] % dp_size(mesh) != 0:
            return P(*([None] * arr.ndim))
        return P(dp_ax, *([None] * (arr.ndim - 1)))

    batch_spec = {k: batch_pspec(k, v) for k, v in batch_abs.items()}

    if kind == "train":
        opt_abs = jax.eval_shape(init_opt_state, params_abs)
        opt_spec = {"mu": p_spec, "nu": p_spec, "step": P()}
        opt_cfg = AdamWConfig(lr=1e-3, weight_decay=0.0)
        loss_fn0 = {
            "seqrec": lambda p, b: recsys.seqrec_loss(cfg, p, b, use_mesh, dp_ax),
            "din": lambda p, b: recsys.din_loss(cfg, p, b, use_mesh, dp_ax),
            "twotower": lambda p, b: recsys.twotower_loss(cfg, p, b, use_mesh, dp_ax),
        }[arch_kind]

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(lambda p: loss_fn0(p, batch))(params)
            new_p, new_s, gnorm = adamw_update(opt_cfg, params, grads, opt_state)
            return new_p, new_s, {"loss": loss, "grad_norm": gnorm}

        fn = jax.jit(
            step,
            in_shardings=(
                _shardings(mesh, p_spec),
                _shardings(mesh, opt_spec),
                _shardings(mesh, batch_spec),
            ),
            out_shardings=(
                _shardings(mesh, p_spec),
                _shardings(mesh, opt_spec),
                _shardings(mesh, {"loss": P(), "grad_norm": P()}),
            ),
            donate_argnums=(0, 1),
        )
        return BuiltStep(fn, (params_abs, opt_abs, batch_abs), meta)

    if kind == "serve":
        serve_fn0 = {
            "seqrec": lambda p, b: recsys.seqrec_score(cfg, p, b, use_mesh, dp_ax),
            "din": lambda p, b: recsys.din_forward(cfg, p, b, use_mesh, dp_ax),
            "twotower": lambda p, b: recsys.twotower_score(cfg, p, b, use_mesh, dp_ax),
        }[arch_kind]
        fn = jax.jit(
            serve_fn0,
            in_shardings=(_shardings(mesh, p_spec), _shardings(mesh, batch_spec)),
        )
        return BuiltStep(fn, (params_abs, batch_abs), meta)

    # retrieval (B=1): user side replicated; candidate axis over dp
    if arch_kind == "seqrec":
        retr = lambda p, b: recsys.seqrec_score(cfg, p, b, use_mesh, ())
    elif arch_kind == "din":
        retr = lambda p, b: recsys.din_retrieval(
            cfg, p, b, 100, use_mesh, (), cand_pspec=cand_spec_1d
        )
    else:
        retr = lambda p, b: recsys.twotower_retrieve(
            cfg, p, b, 100, use_mesh, (), cand_pspec=cand_spec_1d
        )
    fn = jax.jit(
        retr, in_shardings=(_shardings(mesh, p_spec), _shardings(mesh, batch_spec))
    )
    return BuiltStep(fn, (params_abs, batch_abs), meta)


# ==========================================================================
# search family (the paper's engine)
# ==========================================================================
def build_search_step(arch: ArchSpec, shape: ShapeSpec, mesh) -> BuiltStep:
    """REPRO_SEARCH_COMPRESSED: ''/unset = baseline (3x int32 streams);
    'offsets' = uint8 fragment offsets; 'delta' = offsets + block-delta
    uint16 keys (§Perf hillclimb iterations)."""
    import os

    from repro.core.jax_search import (
        make_qt1_serve_step,
        make_qt1_serve_step_compressed,
    )

    cfg = arch.model_cfg
    dims = shape.dims
    B, L, K = dims["batch"], dims["postings"], cfg.n_keys
    mode = os.environ.get("REPRO_SEARCH_COMPRESSED", "")
    meta = {"n_layers": 1, "model_params": 0, "postings": B * K * L, "search_mode": mode or "baseline"}
    if mode == "delta":
        fn = make_qt1_serve_step_compressed(mesh, top_k=cfg.top_k, delta_g=True)
        args = (
            jax.ShapeDtypeStruct((B, K, L // 64), I32),
            jax.ShapeDtypeStruct((B, K, L), jnp.uint16),
            jax.ShapeDtypeStruct((B, K, L), jnp.uint8),
            jax.ShapeDtypeStruct((B, K, L), jnp.uint8),
            jax.ShapeDtypeStruct((B,), F32),
            jax.ShapeDtypeStruct((B,), F32),
        )
        return BuiltStep(fn, args, meta)
    if mode == "offsets":
        fn = make_qt1_serve_step_compressed(mesh, top_k=cfg.top_k, delta_g=False)
        args = (
            jax.ShapeDtypeStruct((B, K, 1), I32),
            jax.ShapeDtypeStruct((B, K, L), I32),
            jax.ShapeDtypeStruct((B, K, L), jnp.uint8),
            jax.ShapeDtypeStruct((B, K, L), jnp.uint8),
            jax.ShapeDtypeStruct((B,), F32),
            jax.ShapeDtypeStruct((B,), F32),
        )
        return BuiltStep(fn, args, meta)
    fn = make_qt1_serve_step(mesh, top_k=cfg.top_k)
    args = (
        jax.ShapeDtypeStruct((B, K, L), I32),
        jax.ShapeDtypeStruct((B, K, L), I32),
        jax.ShapeDtypeStruct((B, K, L), I32),
        jax.ShapeDtypeStruct((B,), F32),
        jax.ShapeDtypeStruct((B,), F32),
    )
    return BuiltStep(fn, args, meta)


# ==========================================================================
# dispatch + concrete-input materialization (smoke tests / examples)
# ==========================================================================
def build_step(arch: ArchSpec, shape_name: str, mesh) -> BuiltStep:
    shape = arch.shapes[shape_name]
    builder = {
        "lm": build_lm_step,
        "gnn": build_gnn_step,
        "recsys": build_recsys_step,
        "search": build_search_step,
    }[arch.family]
    return builder(arch, shape, mesh)


def materialize_inputs(arch: ArchSpec, shape_name: str, built: BuiltStep, seed: int = 0):
    """Concrete inputs for running a built step on CPU: real param init +
    range-correct synthetic batch (smoke tests and example drivers)."""
    rng = np.random.default_rng(seed)
    cfg = arch.model_cfg
    shape = arch.shapes[shape_name]
    key = jax.random.key(seed)

    def synth_batch(abs_tree):
        def leaf(path, x):
            name = str(getattr(path[-1], "key", "")) if path else ""
            if np.issubdtype(np.dtype(x.dtype), np.integer):
                hi = 4
                if arch.family == "lm":
                    hi = cfg.vocab
                elif arch.family == "gnn":
                    hi = shape.dims["n_nodes"] if name in ("src", "dst") else 4
                elif arch.family == "recsys":
                    hi = getattr(cfg, "n_cates", 4) if "cate" in name else getattr(cfg, "n_items", 4)
                if x.shape == ():
                    return jnp.zeros((), x.dtype)
                return jnp.asarray(rng.integers(0, max(hi, 2), x.shape), x.dtype)
            if "mask" in name:
                return jnp.ones(x.shape, x.dtype)
            if name == "log_q":
                return jnp.zeros(x.shape, x.dtype)
            return jnp.asarray(rng.normal(0, 0.5, x.shape), x.dtype)

        return jax.tree_util.tree_map_with_path(leaf, abs_tree)

    if arch.family == "lm":
        params = transformer.init_params(cfg, key)
        if shape.kind == "train":
            return (params, init_opt_state(params), synth_batch(built.args[2]))
        params = jax.tree.map(
            lambda x: x.astype(jnp.bfloat16)
            if jnp.issubdtype(x.dtype, jnp.floating) else x,
            params,
        )
        return (params,) + tuple(synth_batch(a) for a in built.args[1:])
    if arch.family == "gnn":
        dims = shape.dims
        gcfg = replace(cfg, d_feat=dims["d_feat"])
        params = gnn.init_params(gcfg, key)
        opt = init_opt_state(params)
        return (params, opt, synth_batch(built.args[2]))
    if arch.family == "recsys":
        init = {
            recsys.SeqRecConfig: recsys.seqrec_init,
            recsys.DINConfig: recsys.din_init,
            recsys.TwoTowerConfig: recsys.twotower_init,
        }[type(cfg)]
        params = init(cfg, key)
        rest = built.args[1:]
        if shape.kind == "train":
            return (params, init_opt_state(params), synth_batch(rest[1]))
        return (params, synth_batch(rest[0]))
    # search: sorted posting arrays with sentinel padding
    from repro.kernels.common import SENTINEL

    B, K, L = built.args[0].shape
    g = np.full((B, K, L), SENTINEL, np.int32)
    lo = g.copy()
    hi = g.copy()
    for b in range(B):
        base = np.sort(rng.choice(L * 4, size=L // 2, replace=False)).astype(np.int32)
        for k in range(K):
            n = rng.integers(L // 4, L // 2)
            sub = np.sort(rng.choice(base, size=n, replace=False))
            g[b, k, :n] = sub
            lo[b, k, :n] = sub - rng.integers(0, 5, n).astype(np.int32)
            hi[b, k, :n] = sub + rng.integers(0, 5, n).astype(np.int32)
    idf = rng.uniform(1, 5, B).astype(np.float32)
    span = np.full(B, 3.0, np.float32)
    return tuple(map(jnp.asarray, (g, lo, hi, idf, span)))
