"""Gradient compression for the data-parallel all-reduce.

Two production-standard schemes, expressed as explicit shard_map
collectives so the comm-bytes reduction is real and dry-run auditable:

* int8 quantization with per-chunk scales (4x traffic cut vs f32): each
  rank quantizes its local gradient, ranks all-gather the int8 payloads +
  scales, dequantize-and-mean locally. Stochastic rounding keeps the
  estimator unbiased.
* top-k sparsification with error feedback (Deep Gradient Compression):
  only the k largest-magnitude entries are exchanged; the residual is
  carried in an error-feedback accumulator so nothing is lost, only
  delayed.

`compressed_dp_grads` wraps a per-rank gradient pytree; trainers opt in
via TrainLoopConfig.grad_compression in launch/train.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jnp.ndarray, key=None):
    """Per-tensor symmetric int8 with optional stochastic rounding."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    y = x / scale
    if key is not None:
        y = jnp.floor(y + jax.random.uniform(key, x.shape))
    else:
        y = jnp.round(y)
    q = jnp.clip(y, -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def int8_allreduce_mean(x: jnp.ndarray, axis_name: str, key=None) -> jnp.ndarray:
    """Mean over `axis_name` exchanging int8 instead of f32: quantize ->
    all-gather(int8 + scale) -> dequant + mean. Traffic ~ n/4 bytes."""
    q, scale = quantize_int8(x, key)
    qs = jax.lax.all_gather(q, axis_name)  # (R, ...) int8
    ss = jax.lax.all_gather(scale, axis_name)  # (R,)
    deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * x.ndim)
    return deq.mean(axis=0)


def topk_sparsify(x: jnp.ndarray, err: jnp.ndarray, k: int):
    """Error-feedback top-k: returns (values, indices, new_err)."""
    flat = x.reshape(-1) + err.reshape(-1)
    mag = jnp.abs(flat)
    vals, idx = jax.lax.top_k(mag, k)
    sel = jnp.take(flat, idx)
    new_flat = flat.at[idx].set(0.0)
    return sel, idx.astype(jnp.int32), new_flat.reshape(x.shape)


def topk_allreduce_mean(x: jnp.ndarray, err: jnp.ndarray, k: int, axis_name: str):
    """Exchange only top-k (value, index) pairs; residual goes to the
    error-feedback state. Traffic ~ 8k bytes vs 4n."""
    sel, idx, new_err = topk_sparsify(x, err, k)
    vals_all = jax.lax.all_gather(sel, axis_name)  # (R, k)
    idx_all = jax.lax.all_gather(idx, axis_name)
    r = vals_all.shape[0]
    dense = jnp.zeros(x.size, jnp.float32)
    dense = dense.at[idx_all.reshape(-1)].add(vals_all.reshape(-1))
    return (dense / r).reshape(x.shape), new_err


def _tree_compress_mean(grads, err, axis, scheme, topk_frac):
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_flatten(err)[0]
    out_g, out_e = [], []
    for gi, ei in zip(flat_g, flat_e):
        if scheme == "int8":
            out_g.append(int8_allreduce_mean(gi.astype(jnp.float32), axis))
            out_e.append(ei)
        elif scheme == "topk":
            k = max(1, int(gi.size * topk_frac))
            s, ne = topk_allreduce_mean(gi.astype(jnp.float32), ei, k, axis)
            out_g.append(s)
            out_e.append(ne)
        else:  # exact baseline
            out_g.append(jax.lax.pmean(gi.astype(jnp.float32), axis))
            out_e.append(ei)
    unf = functools.partial(jax.tree_util.tree_unflatten, treedef)
    return unf(out_g), unf(out_e)


def make_compressed_dp_train_step(loss_fn, opt_cfg, mesh, dp_axis="data",
                                  scheme="int8", topk_frac: float = 0.01):
    """Explicit-DP train step with compressed gradient synchronization.

    Under plain GSPMD the gradient all-reduce is implicit and cannot be
    compressed; this path makes it explicit: params replicated, batch
    sharded over dp_axis, each rank computes local grads, the mean is
    exchanged int8- or topk-compressed, and every rank applies the same
    update. Returns step(params, opt_state, err_state, batch) ->
    (params, opt_state, err_state, metrics).
    """
    from repro.kernels.common import shard_map_compat as shard_map
    from repro.train.optimizer import adamw_update

    def local_step(params, opt_state, err, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        loss = jax.lax.pmean(loss, dp_axis)
        grads, err = _tree_compress_mean(grads, err, dp_axis, scheme, topk_frac)
        new_p, new_s, gnorm = adamw_update(opt_cfg, params, grads, opt_state)
        return new_p, new_s, err, {"loss": loss, "grad_norm": gnorm}

    def specs_like(tree, spec):
        return jax.tree.map(lambda _: spec, tree)

    def step(params, opt_state, err, batch):
        p_spec = specs_like(params, P())
        o_spec = specs_like(opt_state, P())
        e_spec = specs_like(err, P())
        b_spec = jax.tree.map(
            lambda x: P(dp_axis, *([None] * (x.ndim - 1))), batch
        )
        return shard_map(
            local_step,
            mesh=mesh,
            in_specs=(p_spec, o_spec, e_spec, b_spec),
            out_specs=(p_spec, o_spec, e_spec, {"loss": P(), "grad_norm": P()}),
        )(params, opt_state, err, batch)

    return jax.jit(step)


def init_error_state(grads_abs):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), grads_abs)
