"""Fault-tolerant checkpointing.

Design (1000+-node posture):
* a checkpoint is a directory `step_<N>/` of one .npy file per pytree
  leaf plus a JSON manifest (tree structure, shapes, dtypes, partition
  specs, step metadata);
* writes go to `step_<N>.tmp/` and are atomically renamed after fsync —
  a crash mid-write can never corrupt the latest valid checkpoint;
* `AsyncCheckpointer` moves host transfer + serialization off the train
  loop (background thread; the step only blocks if the previous save is
  still in flight — standard async-checkpoint discipline);
* restore is *elastic*: leaves are saved unsharded (gathered) with their
  PartitionSpecs recorded, so a restart may use a different mesh shape /
  device count — arrays are re-sharded on load (`restore(..., mesh=...)`).
  On real multi-host fleets the same layout supports per-host shard files;
  here single-process save suffices and keeps restarts bit-exact;
* retention: keep the last `keep` checkpoints (garbage-collect older).
"""

from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

_SEP = "/"


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out, treedef


def save_checkpoint(ckpt_dir: str | Path, step: int, tree, meta: dict | None = None) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, _ = _flatten_with_names(tree)
    manifest = {"step": step, "meta": meta or {}, "leaves": []}
    for i, (name, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append(
            {"name": name, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit
    return final


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.iterdir():
        if d.is_dir() and d.name.startswith("step_") and not d.name.endswith(".tmp"):
            if (d / "manifest.json").exists():
                steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str | Path, example_tree, step: int | None = None,
                       mesh=None, pspecs=None):
    """Restore into the structure of example_tree. With mesh+pspecs the
    leaves are placed sharded (elastic: any mesh shape works)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = _flatten_with_names(example_tree)
    by_name = {rec["name"]: rec for rec in manifest["leaves"]}
    specs_flat = None
    if pspecs is not None:
        specs_list, _ = _flatten_with_names(pspecs)
        specs_flat = dict(specs_list)
    out = []
    for name, leaf in leaves:
        rec = by_name.get(name)
        if rec is None:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = np.load(d / rec["file"])
        if mesh is not None and specs_flat is not None and name in specs_flat:
            sharding = NamedSharding(mesh, specs_flat[name])
            out.append(jax.device_put(arr, sharding))
        else:
            out.append(jax.device_put(arr))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, manifest


def gc_checkpoints(ckpt_dir: str | Path, keep: int = 3) -> None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return
    steps = sorted(
        d for d in ckpt_dir.iterdir()
        if d.is_dir() and d.name.startswith("step_") and not d.name.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(d, ignore_errors=True)


class AsyncCheckpointer:
    """Overlaps checkpoint I/O with training (one save in flight)."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree, meta: dict | None = None) -> None:
        self.wait()  # at most one save in flight
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree, meta)
                gc_checkpoints(self.ckpt_dir, self.keep)
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
