"""Fault tolerance + straggler mitigation for the training loop.

At thousand-node scale the supervisor discipline is:
  * every step runs under a watchdog; failures (device loss, NaN blowup,
    preemption) abort the step, not the job;
  * on failure the runner re-initializes from the latest atomic
    checkpoint (possibly on a different device count — elastic restore)
    and replays the data stream to the restored step (deterministic,
    seed+step-keyed batches make replay exact);
  * per-step wall times feed a straggler watermark (P50 * tolerance);
    slow steps raise a StragglerEvent so the deployment layer can hedge
    (re-schedule the slow host's shard, refresh its data feed, or drop it
    from the mesh at the next elastic restart).

The container is single-host, so hardware failures are *injected*
(FailureInjector) and the mitigation logic is what's under test — the
same supervisor runs unchanged on a real fleet where `step_fn` raises on
collective timeouts.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.train.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint


class InjectedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Deterministic failure schedule: {step: kind}."""

    schedule: dict = field(default_factory=dict)
    fired: set = field(default_factory=set)

    def maybe_fail(self, step: int) -> None:
        kind = self.schedule.get(step)
        if kind and step not in self.fired:
            self.fired.add(step)
            raise InjectedFailure(f"injected {kind} at step {step}")


@dataclass
class StragglerEvent:
    step: int
    seconds: float
    watermark: float


class StragglerDetector:
    """Flags steps slower than tolerance x rolling median."""

    def __init__(self, window: int = 32, tolerance: float = 3.0, warmup: int = 5):
        self.times: list[float] = []
        self.window = window
        self.tolerance = tolerance
        self.warmup = warmup
        self.events: list[StragglerEvent] = []

    def observe(self, step: int, seconds: float) -> StragglerEvent | None:
        self.times.append(seconds)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) < self.warmup:
            return None
        watermark = float(np.median(self.times)) * self.tolerance
        if seconds > watermark:
            ev = StragglerEvent(step, seconds, watermark)
            self.events.append(ev)
            return ev
        return None


@dataclass
class SupervisorReport:
    steps_run: int = 0
    restarts: int = 0
    straggler_events: int = 0
    final_step: int = 0
    losses: list = field(default_factory=list)


class TrainSupervisor:
    """Run a training loop with checkpoint/restart + straggler detection.

    step_fn(state, batch) -> (state, metrics);
    batch_fn(step) -> batch   (deterministic per step — replay-exact);
    state is a pytree (params, opt, ...).
    """

    def __init__(
        self,
        step_fn,
        batch_fn,
        init_state_fn,
        ckpt_dir,
        ckpt_every: int = 20,
        max_restarts: int = 8,
        injector: FailureInjector | None = None,
        straggler: StragglerDetector | None = None,
        keep: int = 3,
    ):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.init_state_fn = init_state_fn
        self.ckpt = AsyncCheckpointer(ckpt_dir, keep=keep)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.injector = injector or FailureInjector()
        self.straggler = straggler or StragglerDetector()

    def _restore_or_init(self):
        last = latest_step(self.ckpt_dir)
        state = self.init_state_fn()
        if last is None:
            return state, 0
        state, manifest = restore_checkpoint(self.ckpt_dir, state)
        return state, manifest["step"] + 1

    def run(self, total_steps: int) -> SupervisorReport:
        report = SupervisorReport()
        restarts = 0
        while True:
            state, start = self._restore_or_init()
            try:
                for step in range(start, total_steps):
                    t0 = time.perf_counter()
                    self.injector.maybe_fail(step)
                    batch = self.batch_fn(step)
                    state, metrics = self.step_fn(state, batch)
                    dt = time.perf_counter() - t0
                    if self.straggler.observe(step, dt):
                        report.straggler_events += 1
                    report.steps_run += 1
                    report.final_step = step
                    if metrics is not None and "loss" in metrics:
                        loss = float(metrics["loss"])
                        if not np.isfinite(loss):
                            raise RuntimeError(f"non-finite loss at step {step}")
                        report.losses.append(loss)
                    if (step + 1) % self.ckpt_every == 0:
                        self.ckpt.save(step, state, {"time": time.time()})
                self.ckpt.wait()
                self.ckpt.save(total_steps - 1, state, {"final": True})
                self.ckpt.wait()
                report.restarts = restarts
                return report
            except (InjectedFailure, RuntimeError) as e:
                restarts += 1
                self.ckpt.wait()
                if restarts > self.max_restarts:
                    raise RuntimeError(f"exceeded max restarts: {e}") from e
                # loop re-enters: restore from latest checkpoint and replay
