"""AdamW optimizer (pure pytree; optimizer state inherits the parameter
PartitionSpecs, so the update is element-wise local — ZeRO-style sharding
falls out of the FSDP parameter specs for free)."""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_opt_state(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1 - cfg.b1**t
    bc2 = 1 - cfg.b2**t

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / bc1
        vhat = nu / bc2
        new_p = p - cfg.lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)
        return new_p.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "mu": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "nu": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, gnorm
