"""Shared neural-net layers: norms, rotary embeddings, GQA attention,
gated MLP. Pure-functional (params are plain dict pytrees); all layers are
GSPMD-friendly (no python-level device logic)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    return y * scale.astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    return y * scale.astype(x.dtype) + bias.astype(x.dtype)


def apply_norm(x, p, kind: str):
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def init_norm(d: int, kind: str):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


# ---------------- rotary ---------------------------------------------------
def rotary_angles(positions, d_rot: int, theta: float = 10_000.0):
    """positions: (...,) int -> cos/sin of shape (..., d_rot//2)."""
    inv = 1.0 / (theta ** (np.arange(0, d_rot, 2) / d_rot))
    ang = positions[..., None].astype(jnp.float32) * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x, cos, sin, rotary_pct: float = 1.0):
    """x: (B, S, H, Dh); cos/sin: (B, S, d_rot//2). Partial rotary (e.g.
    StableLM-2 applies RoPE to 25% of head dims) supported via rotary_pct."""
    dh = x.shape[-1]
    d_rot = int(dh * rotary_pct)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = xr[..., : d_rot // 2], xr[..., d_rot // 2 :]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return jnp.concatenate([out, xp], axis=-1)


# ---------------- attention -------------------------------------------------
def gqa_attention_naive(q, k, v, *, causal: bool, q_offset=0, kv_len_valid=None):
    """Reference/decode path: full (B,H,Sq,Skv) score matrix. Used when
    Sq == 1 (decode: scores are tiny and the KV cache may be sharded along
    Skv — a chunk scan over a sharded axis would force gathers) and as the
    numerics oracle for the chunked path."""
    B, Sq, Hq, Dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, Dh)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores = scores / np.sqrt(Dh)
    Skv = k.shape[1]
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        qpos = jnp.arange(Sq) + q_offset
        mask = kpos[None, :] <= qpos[:, None]
    if kv_len_valid is not None:
        mask = mask & (kpos[None, :] < kv_len_valid)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(B, Sq, Hq, Dh)


def gqa_attention_chunked(
    q, k, v, *, causal: bool, q_offset=0, kv_len_valid=None,
    q_block: int = 512, kv_block: int = 1024,
):
    """Flash-style memory-efficient attention in pure JAX: scan over KV
    blocks with an online-softmax (running max / normalizer / accumulator),
    outer scan over Q blocks, jax.checkpoint on the inner body so the
    backward pass re-materializes one (q_block, kv_block) tile at a time.
    Peak score memory: O(B*H*q_block*kv_block) instead of O(B*H*Sq*Skv).

    This is the TPU-shaped realization (VMEM-sized tiles, MXU-aligned
    blocks); on-device the same tiling maps to a Pallas kernel."""
    B, Sq, Hq, Dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    nq = -(-Sq // qb)
    nk = -(-Skv // kb)
    pad_q = nq * qb - Sq
    pad_k = nk * kb - Skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    kv_valid = Skv if kv_len_valid is None else kv_len_valid

    qg = q.reshape(B, nq, qb, Hkv, G, Dh).transpose(1, 0, 2, 3, 4, 5)  # (nq,B,qb,Hkv,G,Dh)
    kg = k.reshape(B, nk, kb, Hkv, Dh).transpose(1, 0, 2, 3, 4)  # (nk,B,kb,Hkv,Dh)
    vg = v.reshape(B, nk, kb, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / np.sqrt(Dh)

    def q_block_fn(_, qi_and_blk):
        qi, qblk = qi_and_blk
        qpos = q_offset + qi * qb + jnp.arange(qb)

        def kv_body(carry, kj_and_blocks):
            m, l, acc = carry
            kj, kblk, vblk = kj_and_blocks
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk).astype(jnp.float32) * scale
            kpos = kj * kb + jnp.arange(kb)
            mask = kpos[None, :] < kv_valid
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            else:
                mask = jnp.broadcast_to(mask, (qb, kb))
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(qblk.dtype), vblk).astype(jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, Hkv, G, qb), -1e30, jnp.float32),
            jnp.zeros((B, Hkv, G, qb), jnp.float32),
            jnp.zeros((B, Hkv, G, qb, Dh), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_body), init, (jnp.arange(nk), kg, vg)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B,Hkv,G,qb,Dh) -> (B,qb,Hq,Dh)
        return None, out.transpose(0, 3, 1, 2, 4).reshape(B, qb, Hq, Dh).astype(q.dtype)

    _, outs = jax.lax.scan(q_block_fn, None, (jnp.arange(nq), qg))  # (nq,B,qb,Hq,Dh)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * qb, Hq, Dh)
    return out[:, :Sq]


def gqa_attention(q, k, v, *, causal: bool, q_offset=0, kv_len_valid=None,
                  q_block: int = 512, kv_block: int = 1024):
    """Dispatch: chunked for long sequences, naive for decode/small."""
    Sq, Skv = q.shape[1], k.shape[1]
    if Sq == 1 or (Sq * Skv) <= q_block * kv_block:
        return gqa_attention_naive(
            q, k, v, causal=causal, q_offset=q_offset, kv_len_valid=kv_len_valid
        )
    return gqa_attention_chunked(
        q, k, v, causal=causal, q_offset=q_offset, kv_len_valid=kv_len_valid,
        q_block=q_block, kv_block=kv_block,
    )


def init_attention(key, d_model: int, n_heads: int, n_kv: int, d_head: int, qkv_bias: bool):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = 1.0 / np.sqrt(d_model)
    p = {
        "wq": jax.random.normal(k1, (d_model, n_heads * d_head), jnp.float32) * scale,
        "wk": jax.random.normal(k2, (d_model, n_kv * d_head), jnp.float32) * scale,
        "wv": jax.random.normal(k3, (d_model, n_kv * d_head), jnp.float32) * scale,
        "wo": jax.random.normal(k4, (n_heads * d_head, d_model), jnp.float32) * scale,
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * d_head,), jnp.float32)
        p["bk"] = jnp.zeros((n_kv * d_head,), jnp.float32)
        p["bv"] = jnp.zeros((n_kv * d_head,), jnp.float32)
    return p


def attention_block(
    p,
    x,
    *,
    n_heads: int,
    n_kv: int,
    d_head: int,
    rotary_pct: float,
    causal: bool = True,
    cache=None,
    position: jnp.ndarray | int = 0,
):
    """Returns (out, new_cache). cache: dict(k, v) of (B, Smax, Hkv, Dh) or
    None (full self-attention over x)."""
    B, S, _ = x.shape
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, n_heads, d_head)
    k = (x @ p["wk"].astype(dt)).reshape(B, S, n_kv, d_head)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, n_kv, d_head)
    if "bq" in p:
        q = q + p["bq"].astype(dt).reshape(n_heads, d_head)
        k = k + p["bk"].astype(dt).reshape(n_kv, d_head)
        v = v + p["bv"].astype(dt).reshape(n_kv, d_head)
    d_rot = int(d_head * rotary_pct)
    d_rot -= d_rot % 2
    pos = jnp.arange(S)[None, :] + position  # (1, S) broadcast over batch
    pos = jnp.broadcast_to(pos, (B, S))
    if d_rot:
        cos, sin = rotary_angles(pos, d_rot)
        q = apply_rotary(q, cos, sin, rotary_pct)
        k = apply_rotary(k, cos, sin, rotary_pct)
    if cache is None:
        out = gqa_attention(q, k, v, causal=causal)
        new_cache = {"k": k, "v": v}
    elif "k_scale" in cache:
        # int8-quantized KV cache (§Perf hillclimb: 4x HBM cut vs bf16):
        # per-(token, head) symmetric scales; dequant fuses into the
        # attention contraction on TPU.
        def quant(x):  # x: (B, S, Hkv, Dh)
            scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
            scale = jnp.maximum(scale, 1e-8)
            q8 = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
            return q8.astype(jnp.int8), scale

        k_q, k_s = quant(k)
        v_q, v_s = quant(v)
        ck = jax.lax.dynamic_update_slice(cache["k"], k_q, (0, position, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v_q, (0, position, 0, 0))
        cks = jax.lax.dynamic_update_slice(cache["k_scale"], k_s, (0, position, 0))
        cvs = jax.lax.dynamic_update_slice(cache["v_scale"], v_s, (0, position, 0))
        kf = ck.astype(dt) * cks[..., None].astype(dt)
        vf = cv.astype(dt) * cvs[..., None].astype(dt)
        out = gqa_attention(
            q, kf, vf, causal=True, q_offset=position, kv_len_valid=position + S
        )
        new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
    else:
        # decode: insert at `position`, attend over the cache
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, position, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, position, 0, 0))
        out = gqa_attention(
            q, ck, cv, causal=True, q_offset=position, kv_len_valid=position + S
        )
        new_cache = {"k": ck, "v": cv}
    out = out.reshape(B, S, n_heads * d_head) @ p["wo"].astype(dt)
    return out, new_cache


# ---------------- MLP --------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(d_ff)
    return {
        "w_gate": jax.random.normal(k1, (d_model, d_ff), jnp.float32) * s_in,
        "w_up": jax.random.normal(k2, (d_model, d_ff), jnp.float32) * s_in,
        "w_down": jax.random.normal(k3, (d_ff, d_model), jnp.float32) * s_out,
    }


def mlp_block(p, x):
    dt = x.dtype
    g = jax.nn.silu(x @ p["w_gate"].astype(dt))
    u = x @ p["w_up"].astype(dt)
    return (g * u) @ p["w_down"].astype(dt)


def init_dense(key, d_in: int, d_out: int, bias: bool = True):
    k1, _ = jax.random.split(key)
    p = {"w": jax.random.normal(k1, (d_in, d_out), jnp.float32) / np.sqrt(d_in)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y
