"""RecSys architectures: BERT4Rec, SASRec, DIN, Two-Tower retrieval.

Common substrate: huge row-sharded embedding tables (models/embedding.py),
small dense towers, sampled-softmax training (full-vocab softmax at
vocab ~10^6-10^7 and batch 65536 is neither feasible nor how these systems
train). Sequential models reuse the transformer attention blocks.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.embedding import (
    embedding_bag_sum,
    embedding_lookup,
    sharded_embedding_bag,
    sharded_embedding_lookup,
)
from repro.models.layers import (
    attention_block,
    dense,
    init_attention,
    init_dense,
    init_mlp,
    init_norm,
    apply_norm,
    mlp_block,
)


def _lookup(table, ids, mesh, dp_axes):
    if mesh is None or mesh.shape.get("model", 1) == 1 or table.shape[0] % mesh.shape["model"]:
        return embedding_lookup(table, ids)
    return sharded_embedding_lookup(table, ids, mesh, dp_axes=dp_axes)


# ==========================================================================
# Sequential recommenders (BERT4Rec / SASRec)
# ==========================================================================
@dataclass(frozen=True)
class SeqRecConfig:
    name: str
    n_items: int
    embed_dim: int
    n_blocks: int
    n_heads: int
    seq_len: int
    causal: bool  # SASRec: True; BERT4Rec: False (bidirectional)

    @property
    def head_dim(self) -> int:
        return self.embed_dim // self.n_heads


def seqrec_init(cfg: SeqRecConfig, key):
    ks = jax.random.split(key, 2 + 2 * cfg.n_blocks)
    blocks = []
    for i in range(cfg.n_blocks):
        blocks.append(
            {
                "ln1": init_norm(cfg.embed_dim, "layernorm"),
                "ln2": init_norm(cfg.embed_dim, "layernorm"),
                "attn": init_attention(
                    ks[2 + 2 * i], cfg.embed_dim, cfg.n_heads, cfg.n_heads, cfg.head_dim, True
                ),
                "mlp": init_mlp(ks[3 + 2 * i], cfg.embed_dim, 4 * cfg.embed_dim),
            }
        )
    blocks = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    return {
        "item_emb": jax.random.normal(ks[0], (cfg.n_items, cfg.embed_dim), jnp.float32) * 0.02,
        "pos_emb": jax.random.normal(ks[1], (cfg.seq_len, cfg.embed_dim), jnp.float32) * 0.02,
        "final_ln": init_norm(cfg.embed_dim, "layernorm"),
        "blocks": blocks,
    }


def seqrec_user_state(cfg: SeqRecConfig, params, hist, mesh=None, dp_axes=("data",)):
    """hist (B, S) item ids (-1 pad) -> user state (B, D)."""
    x = _lookup(params["item_emb"], hist, mesh, dp_axes)
    x = x + params["pos_emb"][None, : x.shape[1]].astype(x.dtype)

    def body(x, p_b):
        h, _ = attention_block(
            p_b["attn"],
            apply_norm(x, p_b["ln1"], "layernorm"),
            n_heads=cfg.n_heads,
            n_kv=cfg.n_heads,
            d_head=cfg.head_dim,
            rotary_pct=0.0,
            causal=cfg.causal,
        )
        x = x + h
        x = x + mlp_block(p_b["mlp"], apply_norm(x, p_b["ln2"], "layernorm"))
        return x, ()

    x, _ = jax.lax.scan(body, x, params["blocks"], unroll=True)  # <=2 blocks
    x = apply_norm(x, params["final_ln"], "layernorm")
    return x[:, -1]  # next-item state


def seqrec_loss(cfg: SeqRecConfig, params, batch, mesh=None, dp_axes=("data",)):
    """Sampled softmax: target at slot 0 vs provided negatives."""
    u = seqrec_user_state(cfg, params, batch["hist"], mesh, dp_axes)
    cand = jnp.concatenate([batch["target"][:, None], batch["negatives"]], axis=1)
    c = _lookup(params["item_emb"], cand, mesh, dp_axes)  # (B, 1+N, D)
    logits = jnp.einsum("bd,bnd->bn", u, c).astype(jnp.float32)
    return -jax.nn.log_softmax(logits, axis=-1)[:, 0].mean()


def seqrec_score(cfg: SeqRecConfig, params, batch, mesh=None, dp_axes=("data",)):
    """Score candidate items: hist (B,S) x candidates (B,C) -> (B,C)."""
    u = seqrec_user_state(cfg, params, batch["hist"], mesh, dp_axes)
    c = _lookup(params["item_emb"], batch["candidates"], mesh, dp_axes)
    return jnp.einsum("bd,bcd->bc", u, c)


# ==========================================================================
# DIN — Deep Interest Network (target attention CTR model)
# ==========================================================================
@dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    n_items: int = 10_000_000
    n_cates: int = 100_000
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: tuple = (80, 40)
    mlp: tuple = (200, 80)
    d_user: int = 16


def din_init(cfg: DINConfig, key):
    ks = jax.random.split(key, 8)
    d = cfg.embed_dim * 2  # item ++ cate
    attn_in = 4 * d
    p = {
        "item_emb": jax.random.normal(ks[0], (cfg.n_items, cfg.embed_dim), jnp.float32) * 0.02,
        "cate_emb": jax.random.normal(ks[1], (cfg.n_cates, cfg.embed_dim), jnp.float32) * 0.02,
        "attn1": init_dense(ks[2], attn_in, cfg.attn_mlp[0]),
        "attn2": init_dense(ks[3], cfg.attn_mlp[0], cfg.attn_mlp[1]),
        "attn3": init_dense(ks[4], cfg.attn_mlp[1], 1),
        "mlp1": init_dense(ks[5], 3 * d + cfg.d_user, cfg.mlp[0]),
        "mlp2": init_dense(ks[6], cfg.mlp[0], cfg.mlp[1]),
        "out": init_dense(ks[7], cfg.mlp[1], 1),
    }
    return p


def _din_emb(cfg, params, items, cates, mesh, dp_axes):
    ei = _lookup(params["item_emb"], items, mesh, dp_axes)
    ec = _lookup(params["cate_emb"], cates, mesh, dp_axes)
    return jnp.concatenate([ei, ec], axis=-1)


def din_forward(cfg: DINConfig, params, batch, mesh=None, dp_axes=("data",)):
    """batch: hist_items/cates (B,S), target_item/cate (B,), user_feats (B,d_user)."""
    eh = _din_emb(cfg, params, batch["hist_items"], batch["hist_cates"], mesh, dp_axes)
    et = _din_emb(cfg, params, batch["target_item"], batch["target_cate"], mesh, dp_axes)
    et_b = et[:, None, :]  # (B, 1, d)
    feats = jnp.concatenate(
        [eh, jnp.broadcast_to(et_b, eh.shape), eh - et_b, eh * et_b], axis=-1
    )
    a = jax.nn.silu(dense(params["attn1"], feats))
    a = jax.nn.silu(dense(params["attn2"], a))
    w = dense(params["attn3"], a)[..., 0]  # (B, S) target-attention weights
    w = jnp.where(batch["hist_items"] >= 0, w, -1e30)
    w = jax.nn.softmax(w.astype(jnp.float32), axis=-1).astype(eh.dtype)
    interest = jnp.einsum("bs,bsd->bd", w, eh)
    z = jnp.concatenate([interest, et, interest * et, batch["user_feats"].astype(et.dtype)], axis=-1)
    z = jax.nn.silu(dense(params["mlp1"], z))
    z = jax.nn.silu(dense(params["mlp2"], z))
    return dense(params["out"], z)[:, 0]  # logit (B,)


def din_loss(cfg: DINConfig, params, batch, mesh=None, dp_axes=("data",)):
    logit = din_forward(cfg, params, batch, mesh, dp_axes)
    y = batch["labels"].astype(jnp.float32)
    z = logit.astype(jnp.float32)
    # numerically stable BCE-with-logits
    return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))


# ==========================================================================
# Two-tower retrieval
# ==========================================================================
@dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    n_items: int = 10_000_000
    n_cates: int = 100_000
    embed_dim: int = 256
    tower: tuple = (1024, 512, 256)
    hist_len: int = 50
    d_user: int = 64


def twotower_init(cfg: TwoTowerConfig, key):
    ks = jax.random.split(key, 10)
    d = cfg.embed_dim

    def tower(k, d_in):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "l1": init_dense(k1, d_in, cfg.tower[0]),
            "l2": init_dense(k2, cfg.tower[0], cfg.tower[1]),
            "l3": init_dense(k3, cfg.tower[1], cfg.tower[2]),
        }

    return {
        "item_emb": jax.random.normal(ks[0], (cfg.n_items, d), jnp.float32) * 0.02,
        "cate_emb": jax.random.normal(ks[1], (cfg.n_cates, d), jnp.float32) * 0.02,
        "user_tower": tower(ks[2], d + cfg.d_user),
        "item_tower": tower(ks[3], 2 * d),
    }


def _tower(p, x):
    x = jax.nn.silu(dense(p["l1"], x))
    x = jax.nn.silu(dense(p["l2"], x))
    x = dense(p["l3"], x)
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-6)


def twotower_user(cfg, params, batch, mesh=None, dp_axes=("data",), bag_pspec=None):
    if mesh is None or mesh.shape.get("model", 1) == 1:
        hist = embedding_bag_sum(params["item_emb"], batch["hist"])
    else:
        hist = sharded_embedding_bag(
            params["item_emb"], batch["hist"], mesh, dp_axes=dp_axes, ids_pspec=bag_pspec
        )
    x = jnp.concatenate([hist, batch["user_feats"].astype(hist.dtype)], axis=-1)
    return _tower(params["user_tower"], x)


def twotower_item(cfg, params, item_ids, cate_ids, mesh=None, dp_axes=("data",)):
    ei = _lookup(params["item_emb"], item_ids, mesh, dp_axes)
    ec = _lookup(params["cate_emb"], cate_ids, mesh, dp_axes)
    return _tower(params["item_tower"], jnp.concatenate([ei, ec], axis=-1))


def twotower_loss(cfg: TwoTowerConfig, params, batch, mesh=None, dp_axes=("data",)):
    """In-batch sampled softmax with logQ correction (Yi et al., RecSys'19)."""
    u = twotower_user(cfg, params, batch, mesh, dp_axes)  # (B, D)
    v = twotower_item(cfg, params, batch["item"], batch["cate"], mesh, dp_axes)  # (B, D)
    logits = (u @ v.T).astype(jnp.float32) * 20.0  # temperature
    logits = logits - batch["log_q"][None, :]  # sampling correction
    labels = jnp.arange(u.shape[0])
    return -jnp.take_along_axis(
        jax.nn.log_softmax(logits, axis=-1), labels[:, None], axis=1
    ).mean()


def din_retrieval(cfg: DINConfig, params, batch, top_k: int = 100, mesh=None, dp_axes=("data",), cand_pspec=None):
    """Score one user against C candidates (retrieval_cand shape): the
    history embedding is computed once; the candidate axis is sharded."""
    from repro.models.embedding import sharded_embedding_lookup

    def lk(table, ids, pspec=None):
        if mesh is None or mesh.shape.get("model", 1) == 1 or table.shape[0] % mesh.shape["model"]:
            return embedding_lookup(table, ids)
        return sharded_embedding_lookup(table, ids, mesh, dp_axes=dp_axes, ids_pspec=pspec)

    eh = jnp.concatenate(
        [lk(params["item_emb"], batch["hist_items"], P(None, None)),
         lk(params["cate_emb"], batch["hist_cates"], P(None, None))], axis=-1
    )  # (1, S, d)
    et = jnp.concatenate(
        [lk(params["item_emb"], batch["cand_items"], cand_pspec),
         lk(params["cate_emb"], batch["cand_cates"], cand_pspec)], axis=-1
    )  # (C, d)
    C = et.shape[0]
    ehb = eh[0][None]  # (1, S, d)
    et_b = et[:, None, :]  # (C, 1, d)
    feats = jnp.concatenate(
        [jnp.broadcast_to(ehb, (C,) + eh.shape[1:]),
         jnp.broadcast_to(et_b, (C,) + eh.shape[1:]),
         ehb - et_b, ehb * et_b], axis=-1
    )
    a = jax.nn.silu(dense(params["attn1"], feats))
    a = jax.nn.silu(dense(params["attn2"], a))
    w = dense(params["attn3"], a)[..., 0]
    w = jnp.where(batch["hist_items"][0][None, :] >= 0, w, -1e30)
    w = jax.nn.softmax(w.astype(jnp.float32), axis=-1).astype(eh.dtype)
    interest = jnp.einsum("cs,csd->cd", w, jnp.broadcast_to(ehb, (C,) + eh.shape[1:]))
    uf = jnp.broadcast_to(batch["user_feats"].astype(et.dtype), (C, batch["user_feats"].shape[-1]))
    z = jnp.concatenate([interest, et, interest * et, uf], axis=-1)
    z = jax.nn.silu(dense(params["mlp1"], z))
    z = jax.nn.silu(dense(params["mlp2"], z))
    scores = dense(params["out"], z)[:, 0]
    return jax.lax.top_k(scores[None, :], top_k)


def twotower_score(cfg: TwoTowerConfig, params, batch, mesh=None, dp_axes=("data",)):
    """Pointwise (user, item) scoring for online serving."""
    u = twotower_user(cfg, params, batch, mesh, dp_axes)
    v = twotower_item(cfg, params, batch["item"], batch["cate"], mesh, dp_axes)
    return jnp.sum(u * v, axis=-1)


def twotower_retrieve(cfg: TwoTowerConfig, params, batch, top_k: int = 100,
                      mesh=None, dp_axes=("data",), cand_pspec=None):
    """One query against a large candidate set: candidate axis sharded
    across the whole mesh; item-tower compute is fully parallel; the final
    dot + top-k reduce is a (1, C) score vector."""
    from repro.models.embedding import sharded_embedding_lookup

    u = twotower_user(cfg, params, batch, mesh, dp_axes, bag_pspec=P(None, None))  # (1, D)
    if mesh is None or mesh.shape.get("model", 1) == 1:
        v = twotower_item(cfg, params, batch["cand_items"], batch["cand_cates"])
    else:
        ei = sharded_embedding_lookup(params["item_emb"], batch["cand_items"], mesh,
                                      dp_axes=dp_axes, ids_pspec=cand_pspec)
        ec = sharded_embedding_lookup(params["cate_emb"], batch["cand_cates"], mesh,
                                      dp_axes=dp_axes, ids_pspec=cand_pspec)
        v = _tower(params["item_tower"], jnp.concatenate([ei, ec], axis=-1))
    scores = jnp.einsum("qd,cd->qc", u, v)
    return jax.lax.top_k(scores, top_k)
