"""EGNN — E(n)-equivariant graph network (Satorras et al., arXiv:2102.09844).

Message passing is expressed as gather (edge endpoints) -> edge MLP ->
`jax.ops.segment_sum` scatter — the JAX-native sparse-aggregation pattern
(no SpMM formats needed). Distribution is *edge-parallel*: edge arrays are
sharded across the whole mesh, node states replicated; each shard computes
local partial aggregations and a psum over the edge axes combines them
(see DESIGN.md §5). Padding edges carry src=dst=0 and mask 0.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense, init_dense


@dataclass(frozen=True)
class EGNNConfig:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    d_feat: int = 16


def _mlp2_init(key, d_in, d_h, d_out):
    k1, k2 = jax.random.split(key)
    return {"l1": init_dense(k1, d_in, d_h), "l2": init_dense(k2, d_h, d_out)}


def _mlp2(p, x):
    return dense(p["l2"], jax.nn.silu(dense(p["l1"], x)))


def init_params(cfg: EGNNConfig, key):
    keys = jax.random.split(key, cfg.n_layers * 3 + 2)
    h = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        layers.append(
            {
                "edge_mlp": _mlp2_init(keys[3 * i], 2 * h + 1, h, h),
                "coord_mlp": _mlp2_init(keys[3 * i + 1], h, h, 1),
                "node_mlp": _mlp2_init(keys[3 * i + 2], 2 * h, h, h),
            }
        )
    # stack layers for scan
    layers = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {
        "embed": init_dense(keys[-2], cfg.d_feat, h),
        "layers": layers,
        "readout": init_dense(keys[-1], h, 1),
    }


def _egnn_layer(p_l, h, x, src, dst, edge_mask, n_nodes):
    """One EGNN layer on (possibly local) edge arrays; returns partial
    aggregations that must be summed across edge shards before the update."""
    hi, hj = h[src], h[dst]
    dx = x[src] - x[dst]
    d2 = jnp.sum(dx * dx, axis=-1, keepdims=True)
    m = _mlp2(p_l["edge_mlp"], jnp.concatenate([hi, hj, d2], axis=-1))
    m = m * edge_mask[:, None].astype(m.dtype)
    w = _mlp2(p_l["coord_mlp"], m)
    coord_agg = jax.ops.segment_sum(dx * w, src, num_segments=n_nodes)
    msg_agg = jax.ops.segment_sum(m, src, num_segments=n_nodes)
    deg = jax.ops.segment_sum(edge_mask.astype(h.dtype), src, num_segments=n_nodes)
    return msg_agg, coord_agg, deg


def forward(cfg: EGNNConfig, params, feats, coords, src, dst, edge_mask, mesh=None, edge_axes=None):
    """feats (N, F), coords (N, 3), src/dst (E,), edge_mask (E,).
    Returns (node embeddings (N, Dh), coords (N, 3), graph scalar)."""
    n_nodes = feats.shape[0]
    h = dense(params["embed"], feats)

    def apply_layer(carry, p_l):
        h, x = carry
        if mesh is not None:
            from repro.kernels.common import shard_map_compat as shard_map

            def body(p_loc, h_loc, x_loc, s_loc, d_loc, m_loc):
                out = _egnn_layer(p_loc, h_loc, x_loc, s_loc, d_loc, m_loc, n_nodes)
                return tuple(jax.lax.psum(o, edge_axes) for o in out)

            e_spec = P(edge_axes)
            msg_agg, coord_agg, deg = shard_map(
                body,
                mesh=mesh,
                in_specs=(P(), P(), P(), e_spec, e_spec, e_spec),
                out_specs=(P(), P(), P()),
            )(p_l, h, x, src, dst, edge_mask)
        else:
            msg_agg, coord_agg, deg = _egnn_layer(p_l, h, x, src, dst, edge_mask, n_nodes)
        denom = jnp.maximum(deg, 1.0)[:, None]
        x = x + coord_agg / denom  # E(n)-equivariant coordinate update
        h = h + _mlp2(p_l["node_mlp"], jnp.concatenate([h, msg_agg / denom], axis=-1))
        return (h, x), ()

    # unroll: few layers; keeps cost_analysis exact (no while-loop body)
    (h, coords), _ = jax.lax.scan(apply_layer, (h, coords), params["layers"], unroll=True)
    energy = dense(params["readout"], h).sum()
    return h, coords, energy


def loss_fn(cfg: EGNNConfig, params, batch, mesh=None, edge_axes=None):
    """Node-level regression (energy-style): MSE of per-node readout."""
    h, _, _ = forward(
        cfg, params, batch["feats"], batch["coords"], batch["src"], batch["dst"],
        batch["edge_mask"], mesh=mesh, edge_axes=edge_axes,
    )
    pred = dense(params["readout"], h)[:, 0]
    mask = batch["node_mask"].astype(pred.dtype)
    err = (pred - batch["targets"]) ** 2 * mask
    return err.sum() / jnp.maximum(mask.sum(), 1.0)


def batched_forward(cfg: EGNNConfig, params, batch):
    """vmap over a batch of small graphs (the `molecule` shape)."""
    def one(feats, coords, src, dst, edge_mask):
        return forward(cfg, params, feats, coords, src, dst, edge_mask)

    return jax.vmap(one)(
        batch["feats"], batch["coords"], batch["src"], batch["dst"], batch["edge_mask"]
    )


def batched_loss(cfg: EGNNConfig, params, batch):
    h, _, _ = batched_forward(cfg, params, batch)
    pred = dense(params["readout"], h)[..., 0]
    mask = batch["node_mask"].astype(pred.dtype)
    err = (pred - batch["targets"]) ** 2 * mask
    return err.sum() / jnp.maximum(mask.sum(), 1.0)
