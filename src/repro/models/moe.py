"""Mixture-of-Experts FFN with top-k routing and expert parallelism.

Production design (DESIGN.md §5): activations are replicated over the
`model` mesh axis (Megatron TP keeps them replicated between blocks), so
expert parallelism needs *no all-to-all*: each model rank owns E/TP
experts, gathers the tokens routed to them from its (data-shard-local,
model-replicated) activation block, runs the expert FFNs, scatters back a
partial output, and the per-rank partials are combined by the same psum
that dense TP needs anyway.

The capacity discipline is GShard-style dropping: per data shard,
C = ceil(T_local * top_k * capacity_factor / E); overflow tokens fall back
to the residual stream (standard). Gather/scatter indices are (E_local, C)
int32 — tiny — so no (T, E, C) dense dispatch tensor is ever materialized.

Expressed with shard_map so the collective schedule is explicit and
dry-run-auditable. On a (1,1) mesh this degrades to plain single-device
top-k MoE (used by the smoke tests and the numerics test vs a dense
reference).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25


def init_moe(key, d_model: int, cfg: MoEConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    E, F = cfg.n_experts, cfg.d_ff_expert
    s_in = 1.0 / np.sqrt(d_model)
    s_out = 1.0 / np.sqrt(F)
    return {
        "router": jax.random.normal(k1, (d_model, E), jnp.float32) * s_in,
        "w_gate": jax.random.normal(k2, (E, d_model, F), jnp.float32) * s_in,
        "w_up": jax.random.normal(k3, (E, d_model, F), jnp.float32) * s_in,
        "w_down": jax.random.normal(k4, (E, F, d_model), jnp.float32) * s_out,
    }


def _local_moe(p, x, *, cfg: MoEConfig, n_local_experts: int, expert_offset, capacity: int):
    """Token dispatch for the experts owned by this rank.

    x: (T, D) local tokens (replicated over model axis);
    p arrays already sliced to this rank's experts (E_l, ...).
    Returns (partial_y (T, D), aux load-balance loss term)."""
    T, D = x.shape
    E = cfg.n_experts
    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, cfg.top_k)  # (T, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # position_in_expert via cumulative one-hot counts (GShard)
    flat_e = gate_e.reshape(-1)  # (T*k,) expert ids, row-major by token
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = jnp.cumsum(oh, axis=0) * oh - 1  # (T*k, E), -1 where not routed
    pos = jnp.max(pos_in_e, axis=-1)  # (T*k,)
    keep = (pos >= 0) & (pos < capacity)

    # local expert slot for this rank: slot = (e - offset) * C + pos
    local_e = flat_e - expert_offset
    mine = keep & (local_e >= 0) & (local_e < n_local_experts)
    slot = jnp.where(mine, local_e * capacity + pos, n_local_experts * capacity)

    # scatter token rows into expert slots (one extra trash slot at the end)
    buf = jnp.zeros((n_local_experts * capacity + 1, D), x.dtype)
    tok_idx = jnp.repeat(jnp.arange(T), cfg.top_k)
    buf = buf.at[slot].add(x[tok_idx] * mine[:, None].astype(x.dtype))
    ex_in = buf[:-1].reshape(n_local_experts, capacity, D)

    # expert FFNs (E_l, C, D) @ (E_l, D, F)
    dt = x.dtype
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ex_in, p["w_gate"].astype(dt)))
    u = jnp.einsum("ecd,edf->ecf", ex_in, p["w_up"].astype(dt))
    ex_out = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"].astype(dt))

    # combine: gather back and weight by gate
    flat_out = ex_out.reshape(n_local_experts * capacity, D)
    flat_out = jnp.concatenate([flat_out, jnp.zeros((1, D), dt)], axis=0)
    contrib = flat_out[slot] * (gate_w.reshape(-1, 1).astype(dt))
    y = jnp.zeros((T, D), dt).at[tok_idx].add(contrib * mine[:, None].astype(dt))

    # Switch-style load-balance aux (computed on full routing, replicated)
    frac_tokens = jnp.mean(jax.nn.one_hot(gate_e[:, 0], E, dtype=jnp.float32), axis=0)
    mean_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * mean_probs)
    return y, aux


def moe_block(p, x, *, cfg: MoEConfig, mesh, dp_axes: tuple, tp_axis: str = "model"):
    """x: (B, S, D) sharded P(dp_axes, None, None). Returns (y, aux)."""
    B, S, D = x.shape
    tp = mesh.shape[tp_axis]
    dp = int(np.prod([mesh.shape[a] for a in dp_axes]))
    assert cfg.n_experts % tp == 0, (cfg.n_experts, tp)
    n_local = cfg.n_experts // tp
    t_local = (B // dp) * S
    capacity = int(np.ceil(t_local * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    capacity = max(capacity, 1)

    def body(p_l, x_l):
        bl, sl, _ = x_l.shape
        rank = jax.lax.axis_index(tp_axis)
        y, aux = _local_moe(
            {k: (v[0] if k != "router" else v) for k, v in p_l.items()},
            x_l.reshape(bl * sl, D),
            cfg=cfg,
            n_local_experts=n_local,
            expert_offset=rank * n_local,
            capacity=capacity,
        )
        y = jax.lax.psum(y, tp_axis)  # combine expert partials (TP-style)
        aux = jax.lax.pmean(aux, dp_axes)
        return y.reshape(bl, sl, D), aux

    # router replicated; experts sharded over tp. Keep a dummy leading dim
    # on expert weights so shard_map slices them per rank.
    p_in = {
        "router": p["router"],
        "w_gate": p["w_gate"].reshape(tp, n_local, D, cfg.d_ff_expert),
        "w_up": p["w_up"].reshape(tp, n_local, D, cfg.d_ff_expert),
        "w_down": p["w_down"].reshape(tp, n_local, cfg.d_ff_expert, D),
    }
    specs_in = {
        "router": P(),
        "w_gate": P(tp_axis),
        "w_up": P(tp_axis),
        "w_down": P(tp_axis),
    }
    from repro.kernels.common import shard_map_compat as shard_map

    y, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(specs_in, P(dp_axes, None, None)),
        out_specs=(P(dp_axes, None, None), P()),
    )(p_in, x)
    return y, aux


def moe_block_dense_ref(p, x, *, cfg: MoEConfig):
    """Oracle: dense per-expert compute + exact top-k combine (no capacity
    drops). Used by tests to validate the dispatch path numerically."""
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, cfg.top_k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
    dt = x.dtype
    g = jax.nn.silu(jnp.einsum("td,edf->tef", xf, p["w_gate"].astype(dt)))
    u = jnp.einsum("td,edf->tef", xf, p["w_up"].astype(dt))
    all_out = jnp.einsum("tef,efd->ted", g * u, p["w_down"].astype(dt))
    combine = jnp.zeros((T, cfg.n_experts), dt)
    for k in range(cfg.top_k):
        combine = combine.at[jnp.arange(T), gate_e[:, k]].add(gate_w[:, k].astype(dt))
    y = jnp.einsum("te,ted->td", combine, all_out)
    return y.reshape(B, S, D)
