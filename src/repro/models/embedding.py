"""Sharded embedding tables (recsys substrate).

JAX has no EmbeddingBag and GSPMD's handling of gathers from row-sharded
operands is opaque, so the model-parallel lookup is explicit shard_map:
tables are row-sharded (contiguous ranges) over the `model` axis; each
rank gathers the ids it owns and the partials are psum'd — the collective
is only (batch, dim), never the table. This is the standard production
embedding-parallel pattern (DLRM-style) adapted to the jax mesh.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def embedding_lookup(table, ids):
    """Unsharded reference: ids (...,) int32, -1 = padding -> zeros."""
    safe = jnp.maximum(ids, 0)
    out = jnp.take(table, safe, axis=0)
    return out * (ids >= 0)[..., None].astype(table.dtype)


def embedding_bag_sum(table, ids, weights=None):
    """Bag-reduce over the last id axis: ids (..., S) -> (..., D)."""
    rows = embedding_lookup(table, ids)
    if weights is not None:
        rows = rows * weights[..., None].astype(rows.dtype)
    return rows.sum(axis=-2)


def _local_lookup(table_l, ids, rank, rows_per_shard):
    local = ids - rank * rows_per_shard
    valid = (local >= 0) & (local < rows_per_shard) & (ids >= 0)
    safe = jnp.clip(local, 0, rows_per_shard - 1)
    out = jnp.take(table_l, safe, axis=0)
    return out * valid[..., None].astype(table_l.dtype)


def sharded_embedding_lookup(table, ids, mesh, tp_axis="model", dp_axes=("data",), ids_pspec=None):
    """table row-sharded over tp_axis; ids sharded over dp_axes (leading
    axis) unless an explicit ids_pspec is given (e.g. retrieval shards the
    *candidate* axis). Returns embeddings sharded like ids."""
    tp = mesh.shape[tp_axis]
    V = table.shape[0]
    assert V % tp == 0, (V, tp)
    rows_per_shard = V // tp

    def body(table_l, ids_l):
        rank = jax.lax.axis_index(tp_axis)
        out = _local_lookup(table_l, ids_l, rank, rows_per_shard)
        return jax.lax.psum(out, tp_axis)

    from repro.kernels.common import shard_map_compat as shard_map

    ndim_ids = ids.ndim
    if ids_pspec is None:
        ids_pspec = P(dp_axes, *([None] * (ndim_ids - 1)))
    out_spec = P(*(tuple(ids_pspec) + (None,) * (ndim_ids + 1 - len(tuple(ids_pspec)))))
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(tp_axis, None), ids_pspec),
        out_specs=out_spec,
    )(table, ids)


def sharded_embedding_bag(table, ids, mesh, weights=None, tp_axis="model", dp_axes=("data",), ids_pspec=None):
    """Bag-reduce lookup with the psum applied *after* the local bag sum —
    the collective stays (batch, D) regardless of bag size S.

    NOTE: ids must never be sharded over tp_axis (the psum over table
    shards would then mix different rows' partials)."""
    tp = mesh.shape[tp_axis]
    V = table.shape[0]
    assert V % tp == 0
    rows_per_shard = V // tp

    def body(table_l, ids_l, w_l):
        rank = jax.lax.axis_index(tp_axis)
        rows = _local_lookup(table_l, ids_l, rank, rows_per_shard)
        if w_l is not None:
            rows = rows * w_l[..., None].astype(rows.dtype)
        return jax.lax.psum(rows.sum(axis=-2), tp_axis)

    from repro.kernels.common import shard_map_compat as shard_map

    nd = ids.ndim
    ids_spec = ids_pspec if ids_pspec is not None else P(dp_axes, *([None] * (nd - 1)))
    sp = tuple(ids_spec)
    sp = sp + (None,) * (nd - len(sp))
    out_spec = P(*(sp[: nd - 1] + (None,)))  # bag axis reduced away, D replicated
    if weights is None:
        return shard_map(
            lambda t, i: body(t, i, None),
            mesh=mesh,
            in_specs=(P(tp_axis, None), ids_spec),
            out_specs=out_spec,
        )(table, ids)
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(tp_axis, None), ids_spec, ids_spec),
        out_specs=out_spec,
    )(table, ids, weights)
