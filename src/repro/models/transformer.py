"""Decoder-only transformer LM covering the assigned dense + MoE configs
(StableLM-2-1.6B, CodeQwen1.5-7B, Qwen1.5-32B, Phi-3.5-MoE, Granite-MoE).

* layers are scanned (compact HLO at any depth; remat-friendly);
* GQA with optional QKV bias (Qwen) and partial rotary (StableLM);
* MoE blocks via models/moe.py (expert-parallel over the TP axis);
* Megatron-style tensor parallelism expressed as parameter PartitionSpecs
  (param_pspecs) + logical activation constraints;
* three entry points per config: train_step loss fwd, prefill, decode_step.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.layers import (
    apply_norm,
    attention_block,
    init_attention,
    init_mlp,
    init_norm,
    mlp_block,
)
from repro.models.moe import MoEConfig, init_moe, moe_block, moe_block_dense_ref


@jax.custom_vjp
def _opt_barrier(xs):
    """optimization_barrier with an identity gradient — jax 0.4.x has no
    differentiation rule for the primitive; the barrier only pins HLO
    scheduling, so identity is the correct cotangent."""
    return jax.lax.optimization_barrier(xs)


def _opt_barrier_fwd(xs):
    return _opt_barrier(xs), None


def _opt_barrier_bwd(_, g):
    return (g,)


_opt_barrier.defvjp(_opt_barrier_fwd, _opt_barrier_bwd)


@dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int | None = None
    qkv_bias: bool = False
    norm: str = "rmsnorm"  # or "layernorm"
    rotary_pct: float = 1.0
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    remat: bool = True
    dtype: str = "bfloat16"

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Megatron-style vocab padding: embedding/lm-head tensors round the
        vocab up to a multiple of 256 so the vocab dim shards over any TP
        degree (e.g. Granite's 49155 would otherwise replicate the logits).
        Logical vocab stays cfg.vocab; pad logits are masked in the loss."""
        return -(-self.vocab // 256) * 256

    def param_count(self) -> int:
        D, H, Kv, Dh, F, V, L = (
            self.d_model, self.n_heads, self.n_kv, self.head_dim,
            self.d_ff, self.vocab, self.n_layers,
        )
        attn = D * H * Dh + 2 * D * Kv * Dh + H * Dh * D
        if self.qkv_bias:
            attn += H * Dh + 2 * Kv * Dh
        if self.moe is not None:
            E, Fe = self.moe.n_experts, self.moe.d_ff_expert
            ffn = D * E + E * (2 * D * Fe + Fe * D)
        else:
            ffn = 3 * D * F
        norms = 2 * D * (2 if self.norm == "layernorm" else 1)
        embed = V * D * (1 if self.tie_embeddings else 2)
        return L * (attn + ffn + norms) + embed + D

    def active_param_count(self) -> int:
        """For MoE: params touched per token (6*N_active*D flops rule)."""
        if self.moe is None:
            return self.param_count()
        D, L = self.d_model, self.n_layers
        E, Fe, k = self.moe.n_experts, self.moe.d_ff_expert, self.moe.top_k
        total = self.param_count()
        ffn_all = L * E * 3 * D * Fe
        ffn_active = L * k * 3 * D * Fe
        return total - ffn_all + ffn_active


def _layer_init(cfg: TransformerConfig, key):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": init_norm(cfg.d_model, cfg.norm),
        "ln2": init_norm(cfg.d_model, cfg.norm),
        "attn": init_attention(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim, cfg.qkv_bias
        ),
    }
    if cfg.moe is not None:
        p["moe"] = init_moe(k2, cfg.d_model, cfg.moe)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff)
    return p


def init_params(cfg: TransformerConfig, key):
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_init(cfg, k))(layer_keys)
    params = {
        "embed": jax.random.normal(ke, (cfg.vocab_padded, cfg.d_model), jnp.float32) * 0.02,
        "layers": layers,
        "final_norm": init_norm(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(kh, (cfg.d_model, cfg.vocab_padded), jnp.float32)
            / np.sqrt(cfg.d_model)
        )
    return params


# --------------------------------------------------------------------------
# sharding rules (Megatron TP over `model`; DP over pod+data)
# --------------------------------------------------------------------------
def param_pspecs(cfg: TransformerConfig, tp: int = 1, stacked: bool = True):
    """PartitionSpec pytree matching init_params. Head-dim projections are
    sharded over `model` when divisible, else replicated (GQA with few KV
    heads, or Qwen's 40 heads on TP=16 — see DESIGN.md)."""
    lead = (None,) if stacked else ()
    m = "model"

    def spec(*axes):
        return P(*(lead + axes))

    q_shard = m if (cfg.n_heads * cfg.head_dim) % tp == 0 else None
    kv_shard = m if (cfg.n_kv * cfg.head_dim) % tp == 0 else None
    ff_shard = m if cfg.d_ff % tp == 0 else None
    attn = {
        "wq": spec(None, q_shard),
        "wk": spec(None, kv_shard),
        "wv": spec(None, kv_shard),
        "wo": spec(q_shard, None),
    }
    if cfg.qkv_bias:
        attn["bq"] = spec(q_shard)
        attn["bk"] = spec(kv_shard)
        attn["bv"] = spec(kv_shard)
    norm_spec = {"scale": spec(None)}
    if cfg.norm == "layernorm":
        norm_spec["bias"] = spec(None)
    layer = {"ln1": dict(norm_spec), "ln2": dict(norm_spec), "attn": attn}
    if cfg.moe is not None:
        e_shard = m if cfg.moe.n_experts % tp == 0 else None
        layer["moe"] = {
            "router": spec(None, None),
            "w_gate": spec(e_shard, None, None),
            "w_up": spec(e_shard, None, None),
            "w_down": spec(e_shard, None, None),
        }
    else:
        layer["mlp"] = {
            "w_gate": spec(None, ff_shard),
            "w_up": spec(None, ff_shard),
            "w_down": spec(ff_shard, None),
        }
    out = {
        "embed": P(m if cfg.vocab_padded % tp == 0 else None, None),
        "layers": layer,
        "final_norm": {"scale": P(None)} if cfg.norm == "rmsnorm" else {"scale": P(None), "bias": P(None)},
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = P(None, m if cfg.vocab_padded % tp == 0 else None)
    return out


# --------------------------------------------------------------------------
# forward passes
# --------------------------------------------------------------------------
def _constrain(x, mesh, spec):
    """Activation sharding constraint (no-op off-mesh). Without these,
    GSPMD propagates FSDP *weight* shardings (data-axis on feature dims)
    into the activations and replicates the batch — observed as 256-batch
    per-device buffers in the qwen32b dry-run."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding

    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _block(cfg: TransformerConfig, mesh, dp_axes):
    act_spec = P(tuple(dp_axes), None, None)

    def block(x, p_l, cache_l=None, position=0):
        h, new_cache = attention_block(
            p_l["attn"],
            apply_norm(x, p_l["ln1"], cfg.norm),
            n_heads=cfg.n_heads,
            n_kv=cfg.n_kv,
            d_head=cfg.head_dim,
            rotary_pct=cfg.rotary_pct,
            cache=cache_l,
            position=position,
        )
        x = _constrain(x + h, mesh, act_spec)
        z = apply_norm(x, p_l["ln2"], cfg.norm)
        if cfg.moe is not None:
            if mesh is not None:
                y, aux = moe_block(p_l["moe"], z, cfg=cfg.moe, mesh=mesh, dp_axes=dp_axes)
            else:
                y, aux = moe_block_dense_ref(p_l["moe"], z, cfg=cfg.moe), jnp.float32(0)
        else:
            y, aux = mlp_block(p_l["mlp"], z), jnp.float32(0)
        return _constrain(x + y, mesh, act_spec), new_cache, aux

    return block


def forward(cfg: TransformerConfig, params, tokens, mesh=None, dp_axes=("data",)):
    """tokens (B, S) -> logits (B, S, V). Scan over layers."""
    dt = jnp.dtype(cfg.dtype)
    x = _constrain(params["embed"].astype(dt)[tokens], mesh, P(tuple(dp_axes), None, None))
    block = _block(cfg, mesh, dp_axes)

    def body(carry, p_l):
        x, aux = carry
        y, _, a = block(x, p_l)
        return (y, aux + a), ()

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0)), params["layers"])
    x = apply_norm(x, params["final_norm"], cfg.norm)
    head = params.get("lm_head", params["embed"].T)
    logits = x @ head.astype(dt)
    tp_ok = mesh is not None and cfg.vocab_padded % mesh.shape.get("model", 1) == 0
    logits = _constrain(logits, mesh if tp_ok else None, P(tuple(dp_axes), None, "model"))
    return logits, aux / cfg.n_layers


def lm_loss(cfg: TransformerConfig, params, tokens, targets, mesh=None, dp_axes=("data",)):
    logits, aux = forward(cfg, params, tokens, mesh, dp_axes)
    logits = logits.astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = (jnp.arange(cfg.vocab_padded) >= cfg.vocab) * -1e30
        logits = logits + pad_mask[None, None, :]
    # vocab-sharding friendly CE: logsumexp reduces the sharded V axis with
    # partial reductions; the target logit comes from a one-hot contraction
    # (also a sharded-V reduction) instead of a gather across shards.
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, cfg.vocab_padded, dtype=logits.dtype)
    tgt = jnp.einsum("bsv,bsv->bs", logits, onehot)
    loss = (lse - tgt).mean()
    if cfg.moe is not None:
        loss = loss + 0.01 * aux
    return loss


def lm_grads_microbatched(cfg: TransformerConfig, params, tokens, targets,
                          n_micro: int, mesh=None, dp_axes=("data",),
                          param_pspecs=None, bf16_gather: bool = True):
    """Gradient accumulation: scan over n_micro microbatches, accumulating
    f32 grads sharded like the params. Bounds the remat residual stack to
    one microbatch (L x B_micro x S x D) — the production answer to the
    40-80 GiB stacks a full-batch backward would need (see dry-run log).

    bf16_gather (§Perf hillclimb): cast f32 master params to bf16 *at
    their FSDP-sharded layout* (sharding constraint pins the convert
    before the gather) so every FSDP all-gather moves half the bytes. The
    dry-run showed 5.8 GiB of f32 all-gathers per layer-loop body without
    this."""
    B = tokens.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    tk = tokens.reshape(n_micro, B // n_micro, -1)
    tg = targets.reshape(n_micro, B // n_micro, -1)

    def cast_sharded(p):
        if not (bf16_gather and mesh is not None and param_pspecs is not None):
            return p
        from jax.sharding import NamedSharding

        def leaf(x, s):
            if jnp.issubdtype(x.dtype, jnp.floating) and x.dtype == jnp.float32:
                return jax.lax.with_sharding_constraint(
                    x.astype(jnp.bfloat16), NamedSharding(mesh, s)
                )
            return x

        flat_p, td = jax.tree_util.tree_flatten(p)
        flat_s = jax.tree_util.tree_flatten(
            param_pspecs, is_leaf=lambda x: isinstance(x, P)
        )[0]
        casted = [leaf(x, s) for x, s in zip(flat_p, flat_s)]
        # the barrier pins the convert *before* the FSDP all-gather —
        # without it XLA sinks the bf16 cast past the gather and moves f32
        casted = _opt_barrier(casted)
        return td.unflatten(casted)

    def loss_fn(p, t, y):
        return lm_loss(cfg, cast_sharded(p), t, y, mesh, dp_axes)

    def micro(carry, xs):
        g_acc, l_acc = carry
        t, y = xs
        l, g = jax.value_and_grad(loss_fn)(params, t, y)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32) / n_micro, g_acc, g)
        return (g_acc, l_acc + l / n_micro), ()

    g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    (grads, loss), _ = jax.lax.scan(micro, (g0, jnp.float32(0)), (tk, tg))
    return loss, grads


def prefill(cfg: TransformerConfig, params, tokens, mesh=None, dp_axes=("data",)):
    """tokens (B, S) -> (last-position logits (B, V), stacked KV cache)."""
    dt = jnp.dtype(cfg.dtype)
    x = _constrain(params["embed"].astype(dt)[tokens], mesh, P(tuple(dp_axes), None, None))
    block = _block(cfg, mesh, dp_axes)

    def body(x, p_l):
        y, cache, _ = block(x, p_l)
        return y, cache

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, caches = jax.lax.scan(body_fn, x, params["layers"])
    x = apply_norm(x[:, -1:], params["final_norm"], cfg.norm)
    head = params.get("lm_head", params["embed"].T)
    return (x @ head.astype(dt))[:, 0], caches


def decode_step(cfg: TransformerConfig, params, token, caches, position, mesh=None, dp_axes=("data",)):
    """token (B, 1) + caches (L-stacked k/v (L,B,Smax,Hkv,Dh)) + position
    scalar -> (logits (B, V), updated caches)."""
    dt = jnp.dtype(cfg.dtype)
    x = _constrain(params["embed"].astype(dt)[token], mesh, P(tuple(dp_axes), None, None))
    block = _block(cfg, mesh, dp_axes)

    def body(x, scanned):
        p_l, cache_l = scanned
        y, new_cache, _ = block(x, p_l, cache_l=cache_l, position=position)
        return y, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    x = apply_norm(x, params["final_norm"], cfg.norm)
    head = params.get("lm_head", params["embed"].T)
    return (x @ head.astype(dt))[:, 0], new_caches


def init_cache(cfg: TransformerConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               quantized: bool = False):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.head_dim)
    if quantized:
        sshape = shape[:-1]
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(sshape, jnp.float32),
            "v_scale": jnp.zeros(sshape, jnp.float32),
        }
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_pspecs(cfg: TransformerConfig, tp: int, dp_axes, seq_len: int | None = None,
                 quantized: bool = False):
    """KV-cache sharding: heads over model when divisible; otherwise shard
    the sequence dim over model (softmax over a sharded axis is handled by
    GSPMD partial reductions) — keeps e.g. Qwen-32B's 40-head cache from
    being replicated 16x."""
    if cfg.n_kv % tp == 0:
        s = P(None, dp_axes, None, "model", None)
    elif seq_len is not None and seq_len % tp == 0:
        s = P(None, dp_axes, "model", None, None)
    else:
        s = P(None, dp_axes, None, None, None)
    out = {"k": s, "v": s}
    if quantized:
        out["k_scale"] = P(*tuple(s)[:-1])
        out["v_scale"] = P(*tuple(s)[:-1])
    return out
