"""Tokenizer + morphological analyzer (paper §1.1).

The paper uses a dictionary morphology: for each word the analyzer yields a
list of lemmas (canonical forms) — possibly several, e.g. "tinged" ->
[ting, tinge], "are" -> [are, be], "mine" -> [mine, my]. Words absent from
the dictionary lemmatize to themselves.

We implement a compact English analyzer: an irregular-form dictionary plus
suffix rules that emit *all* plausible stems (the paper's multi-lemma
behaviour falls out naturally: stripping "-ed" from "tinged" yields both
"ting" and "tinge" because the e-restored variant is also emitted).
"""

from __future__ import annotations

import re

_TOKEN_RE = re.compile(r"[a-z]+")

# Irregular forms -> lemma list. Includes the paper's worked examples.
IRREGULAR: dict[str, list[str]] = {
    # be-forms; "are" is also a noun (unit of area) -> two lemmas, as in the paper
    "am": ["be"], "is": ["be"], "are": ["are", "be"], "was": ["be"],
    "were": ["be"], "been": ["be"], "being": ["be"],
    "has": ["have"], "had": ["have"], "having": ["have"],
    "does": ["do"], "did": ["do"], "done": ["do"], "doing": ["do"],
    "goes": ["go"], "went": ["go"], "gone": ["go"],
    "said": ["say"], "says": ["say"],
    "made": ["make"], "making": ["make"],
    "took": ["take"], "taken": ["take"], "taking": ["take"],
    "came": ["come"], "coming": ["come"],
    "saw": ["saw", "see"], "seen": ["see"], "seeing": ["see"],
    "knew": ["know"], "known": ["know"],
    "thought": ["think"], "got": ["get"], "gotten": ["get"],
    "gave": ["give"], "given": ["give"],
    "found": ["find"], "told": ["tell"], "felt": ["feel"],
    "left": ["left", "leave"], "kept": ["keep"], "held": ["hold"],
    "brought": ["bring"], "began": ["begin"], "begun": ["begin"],
    "wrote": ["write"], "written": ["write"],
    "stood": ["stand"], "heard": ["hear"], "met": ["meet"],
    "ran": ["run"], "running": ["run"], "sat": ["sit"], "spoke": ["speak"],
    "men": ["man"], "women": ["woman"], "children": ["child"],
    "feet": ["foot"], "teeth": ["tooth"], "mice": ["mouse"],
    "people": ["people", "person"], "lives": ["life", "live"],
    "mine": ["mine", "my"],  # paper example: FL 2482 / 264
    "her": ["her", "she"], "his": ["his", "he"], "them": ["they"],
    "me": ["i", "me"], "us": ["we", "us"], "him": ["he"],
    "better": ["better", "good"], "best": ["best", "good"],
    "worse": ["worse", "bad"], "worst": ["worst", "bad"],
    "an": ["a"], "this": ["this"], "these": ["this"], "those": ["that"],
    "cannot": ["can", "not"],
}

_VOWELS = set("aeiou")


def tokenize(text: str) -> list[str]:
    return _TOKEN_RE.findall(text.lower())


def _dedup(seq: list[str]) -> list[str]:
    out: list[str] = []
    for s in seq:
        if s and s not in out:
            out.append(s)
    return out


def lemmatize_word(word: str) -> list[str]:
    """Return the list of lemmas for a word (paper: possibly several)."""
    w = word.lower()
    if w in IRREGULAR:
        return list(IRREGULAR[w])
    cands: list[str] = []
    n = len(w)
    # plural / 3sg
    if w.endswith("ies") and n > 4:
        cands.append(w[:-3] + "y")
    elif w.endswith("sses") or w.endswith("shes") or w.endswith("ches") or w.endswith("xes") or w.endswith("zes"):
        cands.append(w[:-2])
    elif w.endswith("ss"):
        pass  # "glass", "press" are their own lemma
    elif w.endswith("s") and n > 3:
        cands.append(w[:-1])
    # past tense
    if w.endswith("ied") and n > 4:
        cands.append(w[:-3] + "y")
    elif w.endswith("ed") and n > 3:
        stem = w[:-2]
        cands.append(stem)           # "tinged" -> "ting"
        cands.append(stem + "e")     # "tinged" -> "tinge"
        if len(stem) > 2 and stem[-1] == stem[-2] and stem[-1] not in _VOWELS:
            cands.append(stem[:-1])  # "stopped" -> "stop"
    # gerund
    if w.endswith("ing") and n > 4:
        stem = w[:-3]
        cands.append(stem)
        cands.append(stem + "e")     # "tinging" -> "tinge"
        if len(stem) > 2 and stem[-1] == stem[-2] and stem[-1] not in _VOWELS:
            cands.append(stem[:-1])  # "sitting" -> "sit"
    cands = _dedup([c for c in cands if len(c) >= 2])
    return cands if cands else [w]


def lemmatize_text(text: str) -> list[list[str]]:
    """Tokenize + lemmatize; one lemma-alternative list per token position."""
    return [lemmatize_word(t) for t in tokenize(text)]
