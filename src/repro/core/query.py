"""Query parsing, sub-query expansion and QT1-QT5 typing (paper §1.2, §2.1).

Phase 1-2 of Table 1: lemmatization yields per-word lemma alternatives;
the sub-query list is the cartesian product over alternatives ("who are
you who" -> Q1 [who are you who], Q2 [who be you who]); each sub-query is
typed by the lemma classes it contains.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from enum import IntEnum

import numpy as np

from repro.core.lemmatizer import lemmatize_text
from repro.core.lexicon import Lexicon, LemmaType, UNKNOWN_FL


class QueryType(IntEnum):
    QT1 = 1  # all stop lemmas
    QT2 = 2  # all frequently used
    QT3 = 3  # all ordinary
    QT4 = 4  # frequently used + ordinary, no stop
    QT5 = 5  # stop lemmas plus frequently used and/or ordinary


@dataclass
class SubQuery:
    lemma_ids: list[int]
    qtype: QueryType

    def __len__(self) -> int:
        return len(self.lemma_ids)


def classify(lemma_ids: list[int], lexicon: Lexicon) -> QueryType:
    types = {lexicon.type_of_id(l) for l in lemma_ids}
    if types == {LemmaType.STOP}:
        return QueryType.QT1
    if types == {LemmaType.FREQUENT}:
        return QueryType.QT2
    if types == {LemmaType.ORDINARY}:
        return QueryType.QT3
    if LemmaType.STOP not in types:
        return QueryType.QT4
    return QueryType.QT5


def build_subqueries(
    text: str,
    lexicon: Lexicon,
    max_subqueries: int = 16,
) -> list[SubQuery]:
    """Phases 1-2 of the search algorithm (paper Table 1)."""
    alts_per_word = lemmatize_text(text)
    if not alts_per_word:
        return []
    id_alts: list[list[int]] = []
    for alts in alts_per_word:
        ids = [lexicon.fl(a) for a in alts]
        ids = [i for i in ids if i != UNKNOWN_FL] or [UNKNOWN_FL]
        id_alts.append(ids)
    subs = []
    for combo in itertools.islice(itertools.product(*id_alts), max_subqueries):
        subs.append(SubQuery(lemma_ids=list(combo), qtype=classify(list(combo), lexicon)))
    return subs


def subqueries_from_ids(lemma_ids: list[int], lexicon: Lexicon) -> list[SubQuery]:
    """For synthetic-corpus experiments where queries are lemma-id lists."""
    return [SubQuery(lemma_ids=list(lemma_ids), qtype=classify(list(lemma_ids), lexicon))]


def select_fst_keys(lemma_ids: list[int]) -> tuple[int, list[tuple[int, int, int]]]:
    """QT1 index selection (paper §2.2; rule fixed in DESIGN.md §9).

    Anchor f := the most frequent lemma (smallest FL-number). The remaining
    multiset is covered by (s,t) pairs such that each key's requirement
    matches the query's per-lemma multiplicities:

    * lemmas occurring twice+ are paired with themselves first — an (l,l)
      key demands two *distinct* occurrences of l near the anchor;
    * distinct leftovers are paired with each other (ascending FL);
    * a final odd leftover is paired with an already-covered lemma (which
      adds no spurious multiplicity requirement).

    Reproduces the paper's example: [who,are,you,who] -> anchor=you,
    keys (you,are,who), (you,who,who). Lemma multiplicities >= 3 are
    under-required by one (pair keys can demand at most 2) — same
    approximation level as the paper's index.
    """
    ids = sorted(lemma_ids)
    f = ids[0]
    rest = ids[1:]
    if not rest:
        rest = [f]
    mult: dict[int, int] = {}
    for l in rest:
        mult[l] = mult.get(l, 0) + 1
    pairs: list[tuple[int, int]] = []
    leftovers: list[int] = []
    for l in sorted(mult):
        m = mult[l]
        pairs.extend([(l, l)] * (m // 2))
        if m % 2 == 1:
            leftovers.append(l)
    for i in range(0, len(leftovers) - 1, 2):
        pairs.append((leftovers[i], leftovers[i + 1]))
    if len(leftovers) % 2 == 1:
        last = leftovers[-1]
        covered = [l for p in pairs for l in p if l != last]
        partner = covered[0] if covered else last
        a, b = (partner, last) if partner <= last else (last, partner)
        pairs.append((a, b))
    keys = []
    for s, t in pairs:
        key = (f, s, t)
        if key not in keys:
            keys.append(key)
    return f, keys


def qt1_plan(index, lemma_ids: list[int]) -> tuple[list[tuple[int, int, int]], int]:
    """The QT1 per-query decomposition consumed by the serving planner
    and the device packer (the ``qt5_plan`` precedent, completing the
    per-type plan family qt1/qt2/qt34/qt5). Returns (keys, longest):
    keys = the (f,s,t) cover of :func:`select_fst_keys`; longest = the
    largest live posting count among them (what the planner sizes the
    L-bucket by — absent keys count 0)."""
    _, keys = select_fst_keys(list(lemma_ids))
    fst = index.fst
    longest = 0
    for key in keys:
        if fst is not None and key in fst:
            longest = max(longest, fst.n_postings(key))
    return keys, longest


def qt2_plan(index, lemma_ids) -> tuple[list[tuple[int, int]], int]:
    """The QT2 per-query decomposition: :func:`select_wv_keys` ordered
    sparsest-first by live posting count — the CPU engine anchors its
    interval join on the smallest list, and its np.argsort tie-break is
    reproduced by sorting the same size array the same way (absent keys
    count 0: they sort first, and an all-padding anchor yields the CPU's
    any-key-absent empty result). Returns (ordered keys, longest posting
    count) — the second element is what the serving planner sizes the
    L-bucket by, so planner and packer share one derivation."""
    keys = select_wv_keys(list(lemma_ids))
    wv = index.wv
    sizes = np.array(
        [wv.n_postings(k) if wv is not None and k in wv else 0 for k in keys],
        np.int64,
    )
    order = np.argsort(sizes)
    return [keys[i] for i in order], int(sizes.max(initial=0))


def qt5_plan(index, lemma_ids: list[int]):
    """The QT5 decomposition shared by the CPU engine
    (``search.ProximitySearchEngine._qt5``), the device packer
    (``jax_search.pack_qt5_batch``) and the serving router — one copy so
    the compiled and scalar paths cannot drift. Returns (anchor, others,
    stops, counts): anchor = the rarest non-stop lemma (tie-break by
    id); others = [(lemma, multiplicity), ...] ordinary-window
    constraints — the anchor itself included when its multiplicity > 1 —
    ordered sparsest-first by live posting count (tie-break by id, the
    early-mask join order of DESIGN.md §16); stops = [(stop lemma,
    multiplicity), ...] NSW constraints sorted by id; counts = live
    posting counts of the non-stop lemmas. None for degenerate queries
    (no stop or no non-stop lemma)."""
    sw = index.lexicon.sw_count
    ids = list(lemma_ids)
    stop = [l for l in ids if l < sw]
    nonstop = [l for l in ids if l >= sw]
    if not nonstop or not stop:
        return None
    counts = {l: index.ordinary.n_postings(l) for l in set(nonstop)}
    anchor = min(sorted(set(nonstop)), key=lambda l: (counts[l], l))
    mult_ns: dict[int, int] = {}
    for l in nonstop:
        mult_ns[l] = mult_ns.get(l, 0) + 1
    # Sparsest-first join order (arXiv 2009.02684): rarer constraint rows
    # invalidate more anchor lanes earlier, so the fused join's early-mask
    # skips work for the denser keys. The join's AND/min/max accumulation
    # is order-independent, so CPU/device results are unchanged.
    cons = [l for l in mult_ns if l != anchor or mult_ns[l] > 1]
    others = [(l, mult_ns[l])
              for l in sorted(cons, key=lambda l: (counts[l], l))]
    mult_st: dict[int, int] = {}
    for l in stop:
        mult_st[l] = mult_st.get(l, 0) + 1
    return anchor, others, sorted(mult_st.items()), counts


def qt34_plan(index, lemma_ids: list[int]):
    """The QT3/QT4 ordinary-window decomposition shared by the CPU engine
    (``search.ProximitySearchEngine._ordinary_window``), the device packer
    (``jax_search.pack_qt34_batch``) and the serving router — one copy so
    the compiled and scalar paths cannot drift (the ``qt5_plan``
    precedent). Returns (anchor, others, counts): anchor = the most
    frequent lemma (smallest FL-number, the uniform anchor rule of
    DESIGN.md §9); others = [(lemma, multiplicity), ...] window
    constraints — the anchor itself included when its multiplicity > 1 —
    ordered sparsest-first by live posting count (tie-break by FL, the
    early-mask join order of DESIGN.md §16); counts = live ordinary
    posting counts per distinct lemma (what the serving router sizes the
    L-bucket by)."""
    ids = list(lemma_ids)
    mult: dict[int, int] = {}
    for l in ids:
        mult[l] = mult.get(l, 0) + 1
    uniq = sorted(mult)
    anchor = uniq[0]
    counts = {l: index.ordinary.n_postings(l) for l in uniq}
    # Sparsest-first join order — see qt5_plan; results are unchanged
    # because the join accumulation is order-independent.
    cons = [l for l in uniq if l != anchor or mult[l] > 1]
    others = [(l, mult[l]) for l in sorted(cons, key=lambda l: (counts[l], l))]
    return anchor, others, counts


def select_wv_keys(lemma_ids: list[int]) -> list[tuple[int, int]]:
    """QT2 pair covering: sort ascending by FL, pair consecutive lemmas;
    odd count pairs the leftover with the most frequent lemma."""
    ids = sorted(lemma_ids)
    keys = []
    for i in range(0, len(ids) - 1, 2):
        keys.append((ids[i], ids[i + 1]))
    if len(ids) % 2 == 1:
        a, b = ids[0], ids[-1]
        keys.append((a, b) if a <= b else (b, a))
    return keys
