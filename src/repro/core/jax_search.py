"""TPU-adapted batched proximity search (the paper's engine as a jitted,
shardable serve step).

Key re-design vs the CPU engine (DESIGN.md §3):
* postings live in dense, padded int32 device arrays; (doc, pos) pairs are
  packed as g = doc * stride + pos (documents are strided so windows can't
  cross them);
* a batch of B QT1 queries is evaluated at once; each query carries K
  three-component-key posting lists of bucketed length L (padding =
  SENTINEL). K and L are *static* — the compiled step is the response-time
  guarantee;
* Equalize == sorted intersection: key list 0 is the anchor stream; lists
  1..K-1 are joined via vectorized membership (searchsorted on CPU/GPU,
  the Pallas intersect kernel on TPU);
* the index is document-sharded over the `model` mesh axis (each shard
  holds a doc range of every posting list); queries are batch-sharded over
  `pod`/`data`. Per-shard top-k results are all-gathered (k entries per
  shard — tiny collective) and reduced to a global top-k.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.index_builder import ProximityIndex
from repro.core.query import qt2_plan, qt34_plan, qt5_plan, select_fst_keys
from repro.kernels.common import SENTINEL
from repro.kernels.nearest_r import window_join

from repro.kernels.common import shard_map_compat as _shard_map

NEG_INF = jnp.float32(-1e30)


# --------------------------------------------------------------------------
# batched single-device primitives
# --------------------------------------------------------------------------
def _membership(g0: jnp.ndarray, gk: jnp.ndarray):
    """Batched membership of g0 rows in gk rows: (B, L) int32 each."""

    def one(g0_row, gk_row):
        idx = jnp.searchsorted(gk_row, g0_row)
        idx_c = jnp.clip(idx, 0, gk_row.shape[0] - 1)
        found = (gk_row[idx_c] == g0_row) & (g0_row != SENTINEL)
        return found, idx_c

    return jax.vmap(one)(g0, gk)


def qt1_join(key_g: jnp.ndarray, key_lo: jnp.ndarray, key_hi: jnp.ndarray):
    """Join K key posting lists on the anchor stream (list 0).

    key_g/lo/hi: (B, K, L) int32. Returns (valid, lo, hi) each (B, L),
    aligned with the anchor list."""
    K = key_g.shape[1]
    g0 = key_g[:, 0]
    valid = g0 != SENTINEL
    lo = key_lo[:, 0]
    hi = key_hi[:, 0]
    for k in range(1, K):
        found, idx = _membership(g0, key_g[:, k])
        valid &= found
        lo_k = jnp.take_along_axis(key_lo[:, k], idx, axis=1)
        hi_k = jnp.take_along_axis(key_hi[:, k], idx, axis=1)
        lo = jnp.where(found, jnp.minimum(lo, lo_k), lo)
        hi = jnp.where(found, jnp.maximum(hi, hi_k), hi)
    return valid, lo, hi


def qt1_score(valid, lo, hi, idf_sum, span_adjust):
    span_excess = jnp.maximum((hi - lo) - span_adjust[:, None], 0)
    return jnp.where(valid, idf_sum[:, None] / (1.0 + span_excess.astype(jnp.float32)), NEG_INF)


def qt1_topk(score, g_anchor, lo, hi, k: int):
    top_s, top_i = jax.lax.top_k(score, k)
    take = lambda x: jnp.take_along_axis(x, top_i, axis=1)
    return top_s, take(g_anchor), take(lo), take(hi)


# --------------------------------------------------------------------------
# (w,v)-key / NSW joins (QT2 and QT5)
# --------------------------------------------------------------------------
BIG_DIST = jnp.int32(2**31 - 1)  # "no candidate" distance (> any max_sep)


def _nearest1(b_rows, centers, max_sep: int):
    """Batched nearest-value lookup: for each center, the closest value of
    the sorted row b within max_sep. (B, L) int32 each, SENTINEL-padded.
    Ties prefer the predecessor (the CPU engine's candidate column order
    [idx-1, idx] under a stable sort). Returns (matched, value, first_idx)
    where first_idx is the value's *first* occurrence in b — the CPU
    engine recovers the partner interval's end via searchsorted on starts,
    which lands on the first duplicate."""
    Lb = b_rows.shape[-1]

    def one(b_row, c_row):
        idx = jnp.searchsorted(b_row, c_row)
        prev = b_row[jnp.clip(idx - 1, 0, Lb - 1)]
        nxt = b_row[jnp.clip(idx, 0, Lb - 1)]
        d_prev = jnp.where((idx >= 1) & (prev != SENTINEL), c_row - prev, BIG_DIST)
        d_next = jnp.where((idx < Lb) & (nxt != SENTINEL), nxt - c_row, BIG_DIST)
        d_prev = jnp.where(d_prev <= max_sep, d_prev, BIG_DIST)
        d_next = jnp.where(d_next <= max_sep, d_next, BIG_DIST)
        take_prev = d_prev <= d_next
        matched = jnp.where(take_prev, d_prev, d_next) <= max_sep
        val = jnp.where(matched, jnp.where(take_prev, prev, nxt), c_row)
        first = jnp.clip(jnp.searchsorted(b_row, val), 0, Lb - 1)
        return matched, val, first

    return jax.vmap(one)(b_rows, centers)


def qt2_join(wv_lo, wv_hi, n_keys, max_sep: int):
    """Join K (w,v)-interval lists on the anchor list (list 0 — the host
    packers order lists sparsest-first, mirroring the CPU engine's anchor
    choice). wv_lo/wv_hi: (B, K, L) int32 sorted by lo, SENTINEL-padded;
    n_keys: (B,) int32 — lists k >= n_keys[b] are padding and do not
    constrain. For every anchor interval each other list must contribute
    an interval starting within max_sep (= 2*MaxDistance); the nearest
    such interval extends the fragment. Returns (valid, lo, hi) aligned
    with the anchor list."""
    K = wv_lo.shape[1]
    a_lo = wv_lo[:, 0]
    valid = a_lo != SENTINEL
    lo = a_lo
    hi = wv_hi[:, 0]
    for k in range(1, K):
        m, val, j = _nearest1(wv_lo[:, k], a_lo, max_sep)
        b_hi = jnp.take_along_axis(wv_hi[:, k], j, axis=1)
        active = (jnp.int32(k) < n_keys)[:, None]
        valid &= m | ~active
        upd = active & m
        lo = jnp.where(upd, jnp.minimum(lo, val), lo)
        hi = jnp.where(upd, jnp.maximum(hi, b_hi), hi)
    return valid, lo, hi


def _nearest_r_multi(b_rows, centers, max_sep: int, r, r_max: int):
    """Batched r-nearest membership (device twin of search.py's
    ``_nearest_r``): for each center, whether the sorted row b holds r
    distinct values within max_sep, plus the min/max of the r nearest.
    r: (B,) traced multiplicity (r == 0 rows are ignored by the caller).
    Candidate columns mirror the CPU order [idx-1, idx, idx-2, idx+1, …]
    and the sort is stable, so tie-breaking matches numpy's insertion
    sort at these widths (2*r_max <= 16)."""
    Lb = b_rows.shape[-1]
    jcol = np.arange(2 * r_max) // 2  # candidate ring index per column

    def one(b_row, c_row, r1):
        idx = jnp.searchsorted(b_row, c_row)
        cols = []
        for j in range(1, r_max + 1):
            cols.append(idx - j)
            cols.append(idx + (j - 1))
        ci = jnp.stack(cols, axis=1)
        ok = (ci >= 0) & (ci < Lb)
        cand = jnp.where(ok, b_row[jnp.clip(ci, 0, Lb - 1)], 0)
        ok &= cand != SENTINEL
        dist = jnp.abs(cand - c_row[:, None])
        ok &= dist <= max_sep
        ok &= jnp.asarray(jcol)[None, :] < r1
        dist = jnp.where(ok, dist, BIG_DIST)
        order = jnp.argsort(dist, axis=1)
        d_sorted = jnp.take_along_axis(dist, order, axis=1)
        c_sorted = jnp.take_along_axis(cand, order, axis=1)
        matched = jnp.take(d_sorted, jnp.clip(r1 - 1, 0, 2 * r_max - 1), axis=1) <= max_sep
        keep = (jnp.arange(2 * r_max)[None, :] < r1) & (d_sorted <= max_sep)
        chosen = jnp.where(keep, c_sorted, c_row[:, None])
        return matched, chosen.min(axis=1), chosen.max(axis=1)

    return jax.vmap(one)(b_rows, centers, r)


def qt34_join(a_g, ns_g, ns_r, max_sep: int, r_max: int,
              use_pallas: bool = False):
    """Ordinary-window join (QT3/QT4, DESIGN.md §13): the anchor lemma's
    ordinary posting row against the other lemmas' ordinary rows — for
    each anchor posting, every other row must hold r distinct positions
    within MaxDistance (r = the lemma's query multiplicity, traced per
    key, r <= static r_max); the r nearest extend the fragment. This is
    the device twin of ``search.ProximitySearchEngine._ordinary_window``
    and exactly the non-stop half of the QT5 join, which reuses it.
    Keys with r == 0 are padding and do not constrain. a_g: (B, L);
    ns_g: (B, Kn, L); ns_r: (B, Kn). Returns (valid, lo, hi) aligned
    with the anchor row.

    Delegates to ``kernels.nearest_r.window_join`` (DESIGN.md §16): the
    sort-free counting join over all keys at once by default, the
    Pallas fused kernel with ``use_pallas=True``. Both are bit-identical
    to the historical per-key argsort loop over ``_nearest_r_multi``
    (kept above as the documented device twin and test oracle)."""
    return window_join(a_g, ns_g, ns_r, max_sep=max_sep, r_max=r_max,
                       use_pallas=use_pallas)


def qt5_join(a_g, ns_g, ns_r, st_cnt, st_ext, st_r, max_sep: int, r_max: int,
             use_pallas: bool = False):
    """Join the QT5 anchor (rarest non-stop lemma) posting row against
    the other non-stop rows (the ordinary-window join of
    :func:`qt34_join`) and the per-(anchor, stop-lemma) NSW aggregate
    rows (neighbor count >= r plus nearest-offset fragment extension —
    no stop-lemma posting list is ever materialized, the paper's point).
    Keys with r == 0 are padding. a_g: (B, L); ns_g: (B, Kn, L);
    st_cnt/st_ext: (B, Ks, L) aligned with the anchor row. The stop
    constraints fold into the same fused ``window_join`` pass (Pallas:
    into the same kernel), preserving the qt34/qt5 step sharing."""
    return window_join(a_g, ns_g, ns_r, st_cnt, st_ext, st_r,
                       max_sep=max_sep, r_max=r_max, use_pallas=use_pallas)


# --------------------------------------------------------------------------
# sharded serve step
# --------------------------------------------------------------------------
def make_qt1_serve_step(mesh, top_k: int = 16, use_pallas: bool = False):
    """Build the jitted, mesh-sharded QT1 serve step.

    Sharding: batch over pod+data axes, posting length (doc ranges) over
    model. The all-gather moves only K' = top_k entries per shard."""
    has_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if has_pod else ("data",)

    def local_step(key_g, key_lo, key_hi, idf_sum, span_adjust):
        valid, lo, hi = qt1_join(key_g, key_lo, key_hi)
        score = qt1_score(valid, lo, hi, idf_sum, span_adjust)
        s, g, l, h = qt1_topk(score, key_g[:, 0], lo, hi, top_k)
        # gather per-shard top-k across the doc-sharded axis
        s_all = jax.lax.all_gather(s, "model", axis=1, tiled=True)
        g_all = jax.lax.all_gather(g, "model", axis=1, tiled=True)
        l_all = jax.lax.all_gather(l, "model", axis=1, tiled=True)
        h_all = jax.lax.all_gather(h, "model", axis=1, tiled=True)
        return qt1_topk(s_all, g_all, l_all, h_all, top_k)

    batch_spec = P(batch_axes, None, "model")
    vec_spec = P(batch_axes)
    out_spec = P(batch_axes, None)
    step = _shard_map(
        local_step,
        mesh,
        in_specs=(batch_spec, batch_spec, batch_spec, vec_spec, vec_spec),
        out_specs=(out_spec, out_spec, out_spec, out_spec),
    )
    in_shardings = (
        NamedSharding(mesh, batch_spec),
        NamedSharding(mesh, batch_spec),
        NamedSharding(mesh, batch_spec),
        NamedSharding(mesh, vec_spec),
        NamedSharding(mesh, vec_spec),
    )
    out_shardings = tuple(NamedSharding(mesh, out_spec) for _ in range(4))
    return jax.jit(step, in_shardings=in_shardings, out_shardings=out_shardings)


def make_qt1_serve_step_compressed(mesh, top_k: int = 16, delta_g: bool = True):
    """Beyond-paper §Perf optimization of the serve step: the posting
    payload is compressed in HBM and decompressed on the fly.

    * fragment bounds ride as uint8 offsets from the anchor (|off| <=
      MaxDistance, which must be <= 254 — 255 marks padding; checked at
      engine construction) instead of two int32 streams;
    * with delta_g, anchor keys are block-delta-coded: one int32 base per
      64-posting block + uint16 in-block deltas (doc strides bound the
      in-block range; blocks with wider span fall back via the packer).

    Bytes/posting: 12 -> 6 (offsets) -> 4 (offsets + delta16). The join is
    unchanged — reconstruction is elementwise and fuses into it.
    """
    has_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if has_pod else ("data",)
    BLK = 64

    def local_step(key_base, key_delta, key_lo_off, key_hi_off, idf_sum, span_adjust):
        if delta_g:
            # (B,K,nb) int32 base + (B,K,L) uint16 deltas -> int32 keys
            base = jnp.repeat(key_base, BLK, axis=2)
            key_g = base + key_delta.astype(jnp.int32)
        else:
            key_g = key_delta
        lo = key_g - key_lo_off.astype(jnp.int32)
        hi = key_g + key_hi_off.astype(jnp.int32)
        # SENTINEL-preservation: padding slots are marked by lo_off==255
        pad = key_lo_off == 255
        key_g = jnp.where(pad, SENTINEL, key_g)
        valid, lo, hi = qt1_join(key_g, lo, hi)
        score = qt1_score(valid, lo, hi, idf_sum, span_adjust)
        s, g, l, h = qt1_topk(score, key_g[:, 0], lo, hi, top_k)
        s_all = jax.lax.all_gather(s, "model", axis=1, tiled=True)
        g_all = jax.lax.all_gather(g, "model", axis=1, tiled=True)
        l_all = jax.lax.all_gather(l, "model", axis=1, tiled=True)
        h_all = jax.lax.all_gather(h, "model", axis=1, tiled=True)
        return qt1_topk(s_all, g_all, l_all, h_all, top_k)

    batch_spec = P(batch_axes, None, "model")
    # offsets-only: the dummy (B,K,1) base cannot shard its unit dim
    base_spec = batch_spec if delta_g else P(batch_axes, None, None)
    vec_spec = P(batch_axes)
    out_spec = P(batch_axes, None)
    step = _shard_map(
        local_step,
        mesh,
        in_specs=(base_spec, batch_spec, batch_spec, batch_spec, vec_spec, vec_spec),
        out_specs=(out_spec,) * 4,
    )
    shards = lambda spec: NamedSharding(mesh, spec)
    return jax.jit(
        step,
        in_shardings=(shards(base_spec), shards(batch_spec), shards(batch_spec),
                      shards(batch_spec), shards(vec_spec), shards(vec_spec)),
        out_shardings=(shards(out_spec),) * 4,
    )


def make_wv_serve_step(mesh, qtype: str, top_k: int = 16, payload: str = "raw",
                       max_distance: int = 5, r_max: int = 4,
                       use_pallas: bool = False):
    """Build the jitted, mesh-sharded QT2/QT3/QT4/QT5 serve step — the
    (w,v)-key / ordinary-window / NSW analogue of
    :func:`make_qt1_serve_step` (DESIGN.md §12-§13). One factory covers
    all non-QT1 query types (``"qt34"`` serves both QT3 and QT4: their
    evaluation is identical, only the lemma classes differ) and all
    three payload formats so the sharding/all-gather plumbing exists
    once:

    * ``payload="raw"``     — int32 rows as packed by pack_qt2_batch /
      pack_qt34_batch / pack_qt5_batch;
    * ``payload="delta"``   — block-delta16-coded anchor streams
      (4 B/posting class, like the QT1 compressed step);
    * ``payload="offsets"`` — int32 anchor streams + uint8 side channels
      (the fallback when a 64-posting block's span overflows uint16;
      for qt34 — whose payload is g rows only — it equals "raw" and
      exists so the engine's per-format step naming stays uniform).

    The joins are payload-independent: compressed payloads are
    reconstructed elementwise and fuse into them. ``use_pallas``
    (qt34/qt5 only) routes the window join through the fused Pallas
    nearest-r kernel — a TPU escape hatch; the default lax counting
    join is the fast path on CPU hosts (DESIGN.md §16)."""
    assert qtype in ("qt2", "qt34", "qt5")
    assert payload in ("raw", "delta", "offsets")
    has_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if has_pod else ("data",)

    def finish(score, g, lo, hi):
        s, g1, l1, h1 = qt1_topk(score, g, lo, hi, top_k)
        s_all = jax.lax.all_gather(s, "model", axis=1, tiled=True)
        g_all = jax.lax.all_gather(g1, "model", axis=1, tiled=True)
        l_all = jax.lax.all_gather(l1, "model", axis=1, tiled=True)
        h_all = jax.lax.all_gather(h1, "model", axis=1, tiled=True)
        return qt1_topk(s_all, g_all, l_all, h_all, top_k)

    row = P(batch_axes, None, "model")  # (B, K, L) posting rows
    arow = P(batch_axes, "model")       # (B, L) anchor rows
    vec = P(batch_axes)                 # (B,) per-query scalars
    kvec = P(batch_axes, None)          # (B, K) per-key scalars
    out = P(batch_axes, None)

    if qtype == "qt2":
        sep = 2 * max_distance

        def join_finish(wv_lo, wv_hi, n_keys, idf_sum, span_adjust):
            valid, lo, hi = qt2_join(wv_lo, wv_hi, n_keys, sep)
            score = qt1_score(valid, lo, hi, idf_sum, span_adjust)
            # the CPU engine derives the doc from lo, so lo doubles as g
            return finish(score, lo, lo, hi)

        if payload == "raw":
            local_step = join_finish
            in_specs = (row, row, vec, vec, vec)
        elif payload == "delta":
            def local_step(base, delta, width, n_keys, idf_sum, span_adjust):
                lo = jnp.repeat(base, BLK, axis=2) + delta.astype(jnp.int32)
                pad = width == 255
                hi = jnp.where(pad, SENTINEL, lo + width.astype(jnp.int32))
                lo = jnp.where(pad, SENTINEL, lo)
                return join_finish(lo, hi, n_keys, idf_sum, span_adjust)

            in_specs = (row, row, row, vec, vec, vec)
        else:  # offsets
            def local_step(lo, width, n_keys, idf_sum, span_adjust):
                pad = width == 255
                hi = jnp.where(pad, SENTINEL, lo + width.astype(jnp.int32))
                return join_finish(lo, hi, n_keys, idf_sum, span_adjust)

            in_specs = (row, row, vec, vec, vec)
    elif qtype == "qt34":
        sep = max_distance

        def join_finish(a_g, ns_g, ns_r, idf_sum, span_adjust):
            valid, lo, hi = qt34_join(a_g, ns_g, ns_r, sep, r_max,
                                      use_pallas=use_pallas)
            score = qt1_score(valid, lo, hi, idf_sum, span_adjust)
            return finish(score, lo, lo, hi)

        if payload in ("raw", "offsets"):
            local_step = join_finish
            in_specs = (arow, row, kvec, vec, vec)
        else:  # delta
            def local_step(a_base, a_delta, a_pad, ns_base, ns_delta, ns_pad,
                           ns_r, idf_sum, span_adjust):
                a_g = jnp.repeat(a_base, BLK, axis=1) + a_delta.astype(jnp.int32)
                a_g = jnp.where(a_pad == 1, SENTINEL, a_g)
                ns_g = jnp.repeat(ns_base, BLK, axis=2) + ns_delta.astype(jnp.int32)
                ns_g = jnp.where(ns_pad == 1, SENTINEL, ns_g)
                return join_finish(a_g, ns_g, ns_r, idf_sum, span_adjust)

            in_specs = (arow, arow, arow, row, row, row, kvec, vec, vec)
    else:
        sep = max_distance

        def join_finish(a_g, ns_g, ns_r, st_cnt, st_ext, st_r, idf_sum, span_adjust):
            valid, lo, hi = qt5_join(a_g, ns_g, ns_r, st_cnt, st_ext, st_r, sep,
                                     r_max, use_pallas=use_pallas)
            score = qt1_score(valid, lo, hi, idf_sum, span_adjust)
            return finish(score, lo, lo, hi)

        if payload == "raw":
            local_step = join_finish
            in_specs = (arow, row, kvec, row, row, kvec, vec, vec)
        elif payload == "delta":
            def local_step(a_base, a_delta, a_pad, ns_base, ns_delta, ns_pad,
                           ns_r, st_cnt, st_eneg, st_epos, st_r, idf_sum, span_adjust):
                a_g = jnp.repeat(a_base, BLK, axis=1) + a_delta.astype(jnp.int32)
                a_g = jnp.where(a_pad == 1, SENTINEL, a_g)
                ns_g = jnp.repeat(ns_base, BLK, axis=2) + ns_delta.astype(jnp.int32)
                ns_g = jnp.where(ns_pad == 1, SENTINEL, ns_g)
                cnt = st_cnt.astype(jnp.int32)
                ext = st_epos.astype(jnp.int32) - st_eneg.astype(jnp.int32)
                return join_finish(a_g, ns_g, ns_r, cnt, ext, st_r, idf_sum, span_adjust)

            in_specs = (arow, arow, arow, row, row, row, kvec, row, row, row,
                        kvec, vec, vec)
        else:  # offsets
            def local_step(a_g, ns_g, ns_r, st_cnt, st_eneg, st_epos, st_r,
                           idf_sum, span_adjust):
                cnt = st_cnt.astype(jnp.int32)
                ext = st_epos.astype(jnp.int32) - st_eneg.astype(jnp.int32)
                return join_finish(a_g, ns_g, ns_r, cnt, ext, st_r, idf_sum, span_adjust)

            in_specs = (arow, row, kvec, row, row, row, kvec, vec, vec)

    step = _shard_map(local_step, mesh, in_specs=in_specs, out_specs=(out,) * 4)
    shards = lambda spec: NamedSharding(mesh, spec)  # noqa: E731
    return jax.jit(
        step,
        in_shardings=tuple(shards(s) for s in in_specs),
        out_shardings=(shards(out),) * 4,
    )


# --------------------------------------------------------------------------
# compressed payload encoding
# --------------------------------------------------------------------------
BLK = 64  # delta-coding block: one int32 base per BLK postings


def _delta16_blocks(g):
    """Block-delta16 code an int64 key stream (…, L) with SENTINEL pads:
    one int32 base per 64-posting block + uint16 in-block deltas. The
    base is the min over *live* postings, not element 0: with doc_shards
    > 1 a block can straddle a shard-segment boundary and start with
    padding while holding live keys later — anchoring on the min keeps
    every delta non-negative (and minimal). Returns (base, delta, ok);
    ok False when any in-block span overflows uint16."""
    L = g.shape[-1]
    nb = L // BLK
    gb = g.reshape(g.shape[:-1] + (nb, BLK))
    is_pad = gb == np.int64(SENTINEL)
    live_min = np.where(is_pad, np.int64(SENTINEL), gb).min(axis=-1)
    base = np.where(live_min == np.int64(SENTINEL), 0, live_min)
    delta = np.where(is_pad, 0, gb - base[..., None])
    if delta.max(initial=0) >= 2**16:
        return None, None, False
    return (
        base.astype(np.int32),
        delta.reshape(g.shape[:-1] + (L,)).astype(np.uint16),
        True,
    )


def compress_qt1_batch(batch: "QT1Batch", delta_g: bool = True):
    """Pack a QT1Batch into the compressed device format (args for
    make_qt1_serve_step_compressed). Raises if a 64-posting block's key
    span exceeds uint16 (the serving packer then falls back to the
    offsets-only format for that bucket)."""
    g = batch.key_g.astype(np.int64)
    B, K, L = g.shape
    # pads are marked by lo_off == 255 in the compressed format
    lo_off = np.where(batch.key_lo == SENTINEL, 255,
                      np.clip(g - batch.key_lo, 0, 254))
    hi_off = np.where(batch.key_hi == SENTINEL, 0,
                      np.clip(batch.key_hi - g, 0, 254))
    if not delta_g:
        return (
            jnp.zeros((B, K, 1), jnp.int32),
            jnp.asarray(batch.key_g),
            jnp.asarray(lo_off.astype(np.uint8)),
            jnp.asarray(hi_off.astype(np.uint8)),
            jnp.asarray(batch.idf_sum),
            jnp.asarray(batch.span_adjust),
        )
    assert L % BLK == 0
    base, delta, ok = _delta16_blocks(g)
    if not ok:
        raise ValueError("in-block key span exceeds uint16; use offsets format")
    return (
        jnp.asarray(base),
        jnp.asarray(delta),
        jnp.asarray(lo_off.astype(np.uint8)),
        jnp.asarray(hi_off.astype(np.uint8)),
        jnp.asarray(batch.idf_sum),
        jnp.asarray(batch.span_adjust),
    )


# --------------------------------------------------------------------------
# host-side batch packing from a ProximityIndex
# --------------------------------------------------------------------------
@dataclass
class QT1Batch:
    key_g: np.ndarray  # (B, K, L) int32
    key_lo: np.ndarray
    key_hi: np.ndarray
    idf_sum: np.ndarray  # (B,) f32
    span_adjust: np.ndarray  # (B,) f32 == len(query) - 1
    stride: int

    def device_args(self):
        return (
            jnp.asarray(self.key_g),
            jnp.asarray(self.key_lo),
            jnp.asarray(self.key_hi),
            jnp.asarray(self.idf_sum),
            jnp.asarray(self.span_adjust),
        )


def qt1_stride(index) -> int:
    """Document stride of the g = doc * stride + pos packing. Derived only
    from the (immutable) index, so every batch packed against one snapshot
    agrees on it."""
    max_len = int(index.doc_lengths.max()) if index.doc_lengths is not None else 1
    return max_len + index.max_distance + 2


def batch_size_bucket(n: int, cap: int) -> int:
    """Round a batch size up to the next power of two, capped at `cap`.

    The serve step is jit-compiled per (B, K, L) shape; padding B to this
    small ladder means at most log2(cap)+1 compiles per L-bucket instead
    of one silent recompile for every batch size the queue happens to
    produce."""
    b = 1
    while b < n and b < cap:
        b <<= 1
    return min(b, cap)


def pack_fst_key_rows(
    index,
    key,
    L: int,
    doc_shards: int = 1,
    stride: int | None = None,
    out=None,
):
    """Derive the padded, range-partitioned device rows for one (f,s,t) key.

    Returns ``(g, lo, hi, present)``: three (L,) int32 rows plus whether
    the key exists in the index. Postings are range-partitioned into
    doc_shards contiguous doc ranges, each padded to L // doc_shards — so
    that sharding the L axis over the mesh's model axis puts aligned doc
    ranges on the same shard (the alignment invariant of the distributed
    join). Rows depend only on (snapshot, key, L, doc_shards): this is the
    unit the serving layer's PackedPostingCache memoizes (DESIGN.md §11).

    With ``out`` (three caller-provided (L,) views, already
    SENTINEL-filled — e.g. slices of the batch arrays) postings are
    written in place and no rows are allocated, keeping the uncached
    packing path copy-free."""
    if stride is None:
        stride = qt1_stride(index)
    assert L % doc_shards == 0
    Ls = L // doc_shards
    if out is None:
        g_row = np.full(L, SENTINEL, np.int32)
        lo_row = np.full(L, SENTINEL, np.int32)
        hi_row = np.full(L, SENTINEL, np.int32)
    else:
        g_row, lo_row, hi_row = out
    if index.fst is None or key not in index.fst:
        return g_row, lo_row, hi_row, False
    docs, pf, o1, o2 = index.read_fst(key)
    g = (docs * stride + pf).astype(np.int64)
    lo = pf + np.minimum(np.minimum(o1, o2), 0) + docs * stride
    hi = pf + np.maximum(np.maximum(o1, o2), 0) + docs * stride
    _fill_partitioned(docs, (g, lo, hi), index.doc_lengths.size, doc_shards,
                      Ls, (g_row, lo_row, hi_row))
    return g_row, lo_row, hi_row, True


def _fill_partitioned(docs, cols, n_docs, doc_shards, Ls, out_rows):
    """Scatter per-posting columns into range-partitioned row segments:
    shard s holds docs in [s*n/S, (s+1)*n/S), each segment padded to Ls
    entries (out_rows come pre-filled with the pad value). Shared by all
    per-key row packers so every payload kind obeys the same alignment
    invariant (aligned doc ranges land on the same model shard)."""
    lo_bound = 0
    for s in range(doc_shards):
        hi_bound = ((s + 1) * n_docs) // doc_shards
        m = (docs >= lo_bound) & (docs < hi_bound)
        seg = min(int(m.sum()), Ls)
        sl = slice(s * Ls, s * Ls + seg)
        for col, row in zip(cols, out_rows):
            row[sl] = col[m][:seg]
        lo_bound = hi_bound


def pack_wv_key_rows(
    index,
    key,
    L: int,
    doc_shards: int = 1,
    stride: int | None = None,
    out=None,
):
    """Padded, range-partitioned interval rows for one (w,v) key.

    Returns ``(lo, hi, present)``: two (L,) int32 rows sorted by lo (the
    CPU engine's QT2 item order — per-doc lo ranges never overlap, so the
    per-shard sort equals the global stable sort) plus whether the key
    exists. Rows depend only on (snapshot, key, L, doc_shards): the unit
    the serving row cache memoizes under kind "wv"."""
    if stride is None:
        stride = qt1_stride(index)
    assert L % doc_shards == 0
    Ls = L // doc_shards
    if out is None:
        lo_row = np.full(L, SENTINEL, np.int32)
        hi_row = np.full(L, SENTINEL, np.int32)
    else:
        lo_row, hi_row = out
    if index.wv is None or key not in index.wv:
        return lo_row, hi_row, False
    docs, pw, off = index.read_wv(key)
    ga = docs.astype(np.int64) * stride + pw
    gb = ga + off
    lo = np.minimum(ga, gb)
    hi = np.maximum(ga, gb)
    order = np.argsort(lo, kind="stable")
    _fill_partitioned(docs[order], (lo[order], hi[order]),
                      index.doc_lengths.size, doc_shards, Ls, (lo_row, hi_row))
    return lo_row, hi_row, True


def pack_ord_key_rows(
    index,
    lemma: int,
    L: int,
    doc_shards: int = 1,
    stride: int | None = None,
    out=None,
):
    """Padded, range-partitioned g row for one lemma's *ordinary* posting
    list (the QT5 anchor / other-non-stop streams). Returns
    ``(g, present)``; present is False when the lemma has no postings
    (the CPU engine's empty-read early-out)."""
    if stride is None:
        stride = qt1_stride(index)
    assert L % doc_shards == 0
    Ls = L // doc_shards
    g_row = np.full(L, SENTINEL, np.int32) if out is None else out[0]
    docs, pos = index.read_ordinary(lemma)
    if docs.size == 0:
        return g_row, False
    g = docs.astype(np.int64) * stride + pos
    _fill_partitioned(docs, (g,), index.doc_lengths.size, doc_shards, Ls, (g_row,))
    return g_row, True


def pack_nsw_key_rows(
    index,
    key,
    L: int,
    doc_shards: int = 1,
    stride: int | None = None,
    out=None,
):
    """NSW aggregate rows for one (anchor lemma, stop lemma) pair,
    aligned with the anchor's ordinary posting row (same order, padding
    and range partition — zeros at pads). key = (anchor, sid). Returns
    ``(cnt, ext, present)``: per-anchor-posting neighbor count within
    MaxDistance and the nearest neighbor offset (ties prefer the
    negative offset, mirroring the CPU engine's stable lexsort over the
    (row, fl, off)-ordered record stream)."""
    if stride is None:
        stride = qt1_stride(index)
    assert L % doc_shards == 0
    Ls = L // doc_shards
    anchor, sid = key
    if out is None:
        cnt_row = np.zeros(L, np.int32)
        ext_row = np.zeros(L, np.int32)
    else:
        cnt_row, ext_row = out
    a_docs, _ = index.read_ordinary(anchor)
    n = int(a_docs.size)
    if n == 0:
        return cnt_row, ext_row, False
    rows, fls, offs = index.nsw.read(anchor)
    keep = np.abs(offs) <= index.max_distance
    sel = keep & (fls == sid)
    r_rows = rows[sel]
    r_offs = offs[sel]
    cnt = np.bincount(r_rows, minlength=n).astype(np.int64)
    order = np.lexsort((np.abs(r_offs), r_rows))
    rr, ro = r_rows[order], r_offs[order]
    first = np.ones(rr.size, bool)
    first[1:] = rr[1:] != rr[:-1]
    ext = np.zeros(n, np.int64)
    ext[rr[first]] = ro[first]
    _fill_partitioned(a_docs, (cnt, ext), index.doc_lengths.size, doc_shards,
                      Ls, (cnt_row, ext_row))
    return cnt_row, ext_row, True


def pack_qt1_batch(
    index: ProximityIndex,
    queries: list[list[int]],
    L: int,
    K: int = 2,
    doc_shards: int = 1,
    cache=None,
    plans: list | None = None,
) -> QT1Batch:
    """Pack QT1 queries into fixed-shape device arrays.

    Per-key row derivation lives in :func:`pack_fst_key_rows`; with
    `cache` (a ``repro.serving.pack_cache.PackedPostingCache``) the rows
    of hot keys are served from memory instead of being re-derived from
    segment reads — packing becomes B*K row copies.

    An empty query is a batch-shape padding slot: its rows stay
    all-SENTINEL and its idf_sum is 0, so it scores NEG_INF everywhere
    and decodes to zero results.

    INVARIANT: doc_shards must equal the serving mesh's model-axis size.
    Each range-partitioned segment is sorted *locally*; the concatenated
    row is not globally sorted, so the searchsorted-based join is only
    correct when each model shard sees exactly one segment."""
    B = len(queries)
    lex = index.lexicon
    stride = qt1_stride(index)
    assert L % doc_shards == 0

    key_g = np.full((B, K, L), SENTINEL, np.int32)
    key_lo = np.full((B, K, L), SENTINEL, np.int32)
    key_hi = np.full((B, K, L), SENTINEL, np.int32)
    idf_sum = np.zeros(B, np.float32)
    span_adj = np.zeros(B, np.float32)

    for qi, q in enumerate(queries):
        if not q:
            continue  # padding slot
        keys = plans[qi] if plans is not None and plans[qi] is not None \
            else select_fst_keys(q)[1]
        keys = (keys + [keys[-1]] * K)[:K]  # pad by repeating (idempotent join)
        span_adj[qi] = len(q) - 1
        any_present = False
        for ki, key in enumerate(keys):
            if cache is not None:
                g_row, lo_row, hi_row, present = cache.get_rows(
                    index, key, L, doc_shards, stride
                )
                if present:
                    key_g[qi, ki] = g_row
                    key_lo[qi, ki] = lo_row
                    key_hi[qi, ki] = hi_row
            else:  # write postings straight into the batch arrays
                _, _, _, present = pack_fst_key_rows(
                    index, key, L, doc_shards, stride,
                    out=(key_g[qi, ki], key_lo[qi, ki], key_hi[qi, ki]),
                )
            any_present = any_present or present
        if any_present:
            idf_sum[qi] = sum(lex.idf(l) for l in q)
    return QT1Batch(key_g, key_lo, key_hi, idf_sum, span_adj, stride)


# --------------------------------------------------------------------------
# QT2/QT5 host-side batch packing
# --------------------------------------------------------------------------
@dataclass
class QT2Batch:
    wv_lo: np.ndarray  # (B, K, L) int32, sorted by lo, SENTINEL-padded
    wv_hi: np.ndarray
    n_keys: np.ndarray  # (B,) int32; lists k >= n_keys[b] are padding
    idf_sum: np.ndarray
    span_adjust: np.ndarray
    stride: int

    def device_args(self):
        return tuple(jnp.asarray(a) for a in (
            self.wv_lo, self.wv_hi, self.n_keys, self.idf_sum, self.span_adjust))


@dataclass
class QT5Batch:
    a_g: np.ndarray  # (B, L) anchor ordinary posting row
    ns_g: np.ndarray  # (B, Kn, L) other non-stop rows
    ns_r: np.ndarray  # (B, Kn) multiplicities (0 = padding)
    st_cnt: np.ndarray  # (B, Ks, L) NSW neighbor counts (anchor-aligned)
    st_ext: np.ndarray  # (B, Ks, L) nearest NSW offsets
    st_r: np.ndarray  # (B, Ks) stop multiplicities (0 = padding)
    idf_sum: np.ndarray
    span_adjust: np.ndarray
    stride: int

    def device_args(self):
        return tuple(jnp.asarray(a) for a in (
            self.a_g, self.ns_g, self.ns_r, self.st_cnt, self.st_ext,
            self.st_r, self.idf_sum, self.span_adjust))


@dataclass
class QT34Batch:
    a_g: np.ndarray  # (B, L) anchor ordinary posting row
    ns_g: np.ndarray  # (B, Kn, L) other ordinary rows
    ns_r: np.ndarray  # (B, Kn) multiplicities (0 = padding)
    idf_sum: np.ndarray
    span_adjust: np.ndarray
    stride: int

    def device_args(self):
        return tuple(jnp.asarray(a) for a in (
            self.a_g, self.ns_g, self.ns_r, self.idf_sum, self.span_adjust))


# the QT2 key ordering moved beside the other per-type plans in
# core/query.py (the serving planner consumes them uniformly); the old
# name stays importable for existing callers
ordered_wv_keys = qt2_plan


def pack_qt2_batch(
    index,
    queries: list[list[int]],
    L: int,
    K: int = 3,
    doc_shards: int = 1,
    cache=None,
    plans: list | None = None,
) -> QT2Batch:
    """Pack QT2 queries into fixed-shape (w,v)-interval device arrays.

    Per-key row derivation lives in :func:`pack_wv_key_rows`; with
    ``cache`` hot-key rows come from the serving row cache (kind "wv").
    Empty queries are batch-padding slots. Same alignment invariant as
    pack_qt1_batch: doc_shards must equal the mesh's model-axis size.

    doc_shards > 1 caveat: the CPU engine's 2*MaxDistance nearest-start
    window can (for d >= 3) reach across a document boundary — an
    artifact of g-space distance exceeding the inter-doc gap of d+3 —
    and therefore across a shard boundary, which the per-shard
    searchsorted join cannot see. Single-shard serving (the tested
    configuration) is exactly equivalent; sharded QT2 serving misses
    only those cross-document artifacts. QT1 (exact g equality) and QT5
    (window = d < inter-doc gap) have no such boundary cases."""
    B = len(queries)
    lex = index.lexicon
    stride = qt1_stride(index)
    assert L % doc_shards == 0
    wv_lo = np.full((B, K, L), SENTINEL, np.int32)
    wv_hi = np.full((B, K, L), SENTINEL, np.int32)
    n_keys = np.zeros(B, np.int32)
    idf_sum = np.zeros(B, np.float32)
    span_adj = np.zeros(B, np.float32)
    for qi, q in enumerate(queries):
        if not q:
            continue  # padding slot
        keys = (plans[qi] if plans is not None and plans[qi] is not None
                else ordered_wv_keys(index, q)[0])[:K]
        n_keys[qi] = len(keys)
        span_adj[qi] = len(q) - 1
        any_present = False
        for ki, key in enumerate(keys):
            if cache is not None:
                lo_row, hi_row, present = cache.get(index, "wv", key, L,
                                                    doc_shards, stride)
                if present:
                    wv_lo[qi, ki] = lo_row
                    wv_hi[qi, ki] = hi_row
            else:
                _, _, present = pack_wv_key_rows(
                    index, key, L, doc_shards, stride,
                    out=(wv_lo[qi, ki], wv_hi[qi, ki]),
                )
            any_present = any_present or present
        if any_present:
            idf_sum[qi] = sum(lex.idf(l) for l in q)
    return QT2Batch(wv_lo, wv_hi, n_keys, idf_sum, span_adj, stride)


def pack_qt5_batch(
    index,
    queries: list[list[int]],
    L: int,
    Kn: int = 3,
    Ks: int = 3,
    doc_shards: int = 1,
    cache=None,
    plans: list | None = None,
) -> QT5Batch:
    """Pack QT5 queries: anchor + other non-stop ordinary rows (kind
    "ord") and per-(anchor, stop-lemma) NSW aggregate rows (kind "nsw").
    The serving router guarantees the per-query constraint counts fit
    (Kn, Ks) and multiplicities fit the step's r_max; longer queries take
    the CPU fallback."""
    B = len(queries)
    lex = index.lexicon
    stride = qt1_stride(index)
    assert L % doc_shards == 0
    a_g = np.full((B, L), SENTINEL, np.int32)
    ns_g = np.full((B, Kn, L), SENTINEL, np.int32)
    ns_r = np.zeros((B, Kn), np.int32)
    st_cnt = np.zeros((B, Ks, L), np.int32)
    st_ext = np.zeros((B, Ks, L), np.int32)
    st_r = np.zeros((B, Ks), np.int32)
    idf_sum = np.zeros(B, np.float32)
    span_adj = np.zeros(B, np.float32)
    for qi, q in enumerate(queries):
        if not q:
            continue  # padding slot
        plan = (plans[qi] if plans is not None and plans[qi] is not None
                else qt5_plan(index, q))
        if plan is None:
            continue  # degenerate; the router sends these to the CPU
        anchor, others, stops, _ = plan
        span_adj[qi] = len(q) - 1
        if cache is not None:
            g_row, present = cache.get(index, "ord", anchor, L, doc_shards, stride)
            if present:
                a_g[qi] = g_row
        else:
            _, present = pack_ord_key_rows(index, anchor, L, doc_shards, stride,
                                           out=(a_g[qi],))
        for ki, (lemma, r) in enumerate(others[:Kn]):
            ns_r[qi, ki] = r
            if cache is not None:
                g_row, pres = cache.get(index, "ord", lemma, L, doc_shards, stride)
                if pres:
                    ns_g[qi, ki] = g_row
            else:
                pack_ord_key_rows(index, lemma, L, doc_shards, stride,
                                  out=(ns_g[qi, ki],))
        for ki, (sid, r) in enumerate(stops[:Ks]):
            st_r[qi, ki] = r
            if cache is not None:
                cnt_row, ext_row, pres = cache.get(index, "nsw", (anchor, sid),
                                                   L, doc_shards, stride)
                if pres:
                    st_cnt[qi, ki] = cnt_row
                    st_ext[qi, ki] = ext_row
            else:
                pack_nsw_key_rows(index, (anchor, sid), L, doc_shards, stride,
                                  out=(st_cnt[qi, ki], st_ext[qi, ki]))
        idf_sum[qi] = sum(lex.idf(l) for l in q)
    return QT5Batch(a_g, ns_g, ns_r, st_cnt, st_ext, st_r, idf_sum, span_adj, stride)


def pack_qt34_batch(
    index,
    queries: list[list[int]],
    L: int,
    Kn: int = 4,
    doc_shards: int = 1,
    cache=None,
    plans: list | None = None,
) -> QT34Batch:
    """Pack QT3/QT4 queries: anchor (most frequent lemma) + other
    ordinary rows, all kind "ord" — the same per-key rows the QT5
    packer's non-stop streams use, so a warm row cache is shared across
    both paths. The serving router guarantees the per-query constraint
    count fits Kn and multiplicities fit the step's r_max; anything else
    takes the CPU fallback. Same alignment invariant as pack_qt1_batch:
    doc_shards must equal the mesh's model-axis size."""
    B = len(queries)
    lex = index.lexicon
    stride = qt1_stride(index)
    assert L % doc_shards == 0
    a_g = np.full((B, L), SENTINEL, np.int32)
    ns_g = np.full((B, Kn, L), SENTINEL, np.int32)
    ns_r = np.zeros((B, Kn), np.int32)
    idf_sum = np.zeros(B, np.float32)
    span_adj = np.zeros(B, np.float32)
    for qi, q in enumerate(queries):
        if not q:
            continue  # padding slot
        plan = (plans[qi] if plans is not None and plans[qi] is not None
                else qt34_plan(index, q))
        anchor, others, _ = plan
        span_adj[qi] = len(q) - 1
        if cache is not None:
            g_row, present = cache.get(index, "ord", anchor, L, doc_shards, stride)
            if present:
                a_g[qi] = g_row
        else:
            _, present = pack_ord_key_rows(index, anchor, L, doc_shards, stride,
                                           out=(a_g[qi],))
        for ki, (lemma, r) in enumerate(others[:Kn]):
            ns_r[qi, ki] = r
            if lemma == anchor:
                # the anchor's own multiplicity constraint re-windows its row
                ns_g[qi, ki] = a_g[qi]
                continue
            if cache is not None:
                g_row, pres = cache.get(index, "ord", lemma, L, doc_shards, stride)
                if pres:
                    ns_g[qi, ki] = g_row
            else:
                pack_ord_key_rows(index, lemma, L, doc_shards, stride,
                                  out=(ns_g[qi, ki],))
        idf_sum[qi] = sum(lex.idf(l) for l in q)
    return QT34Batch(a_g, ns_g, ns_r, idf_sum, span_adj, stride)


def compress_qt2_batch(batch: QT2Batch, delta_g: bool = True):
    """QT2Batch -> compressed device args. Interval widths (hi - lo <=
    MaxDistance <= 254) ride as uint8 (255 marks padding); with delta_g
    the lo stream is block-delta16 coded. Raises on uint16 overflow (the
    engine then falls back to the offsets format)."""
    lo = batch.wv_lo.astype(np.int64)
    pad = lo == np.int64(SENTINEL)
    width = np.where(pad, 255,
                     np.clip(batch.wv_hi.astype(np.int64) - lo, 0, 254)).astype(np.uint8)
    tail = (jnp.asarray(width), jnp.asarray(batch.n_keys),
            jnp.asarray(batch.idf_sum), jnp.asarray(batch.span_adjust))
    if not delta_g:
        return (jnp.asarray(batch.wv_lo),) + tail
    assert lo.shape[-1] % BLK == 0
    base, delta, ok = _delta16_blocks(lo)
    if not ok:
        raise ValueError("in-block key span exceeds uint16; use offsets format")
    return (jnp.asarray(base), jnp.asarray(delta)) + tail


def compress_qt34_batch(batch: QT34Batch, delta_g: bool = True):
    """QT34Batch -> compressed device args: with delta_g the anchor and
    other ordinary streams are block-delta16 coded behind uint8 pad
    masks (4 B/posting class); without it the int32 rows ship as-is
    (the "offsets" format — QT3/QT4 has no uint8 side channels, so the
    fallback is simply uncompressed). Raises on uint16 overflow (the
    engine then falls back to the offsets format)."""
    tail = (jnp.asarray(batch.ns_r), jnp.asarray(batch.idf_sum),
            jnp.asarray(batch.span_adjust))
    if not delta_g:
        return (jnp.asarray(batch.a_g), jnp.asarray(batch.ns_g)) + tail
    a = batch.a_g.astype(np.int64)
    ns = batch.ns_g.astype(np.int64)
    assert a.shape[-1] % BLK == 0
    a_base, a_delta, ok_a = _delta16_blocks(a)
    ns_base, ns_delta, ok_n = _delta16_blocks(ns)
    if not (ok_a and ok_n):
        raise ValueError("in-block key span exceeds uint16; use offsets format")
    a_pad = (a == np.int64(SENTINEL)).astype(np.uint8)
    ns_pad = (ns == np.int64(SENTINEL)).astype(np.uint8)
    return (jnp.asarray(a_base), jnp.asarray(a_delta), jnp.asarray(a_pad),
            jnp.asarray(ns_base), jnp.asarray(ns_delta), jnp.asarray(ns_pad)) + tail


def compress_qt5_batch(batch: QT5Batch, delta_g: bool = True):
    """QT5Batch -> compressed device args: uint8 NSW counts (clipped at
    255 — multiplicities are far smaller) and split-sign uint8 nearest
    offsets (|ext| <= MaxDistance <= 254); with delta_g the anchor and
    non-stop streams are block-delta16 coded behind uint8 pad masks."""
    cnt8 = np.clip(batch.st_cnt, 0, 255).astype(np.uint8)
    eneg = np.clip(-np.minimum(batch.st_ext, 0), 0, 255).astype(np.uint8)
    epos = np.clip(np.maximum(batch.st_ext, 0), 0, 255).astype(np.uint8)
    tail = (jnp.asarray(batch.ns_r), jnp.asarray(cnt8), jnp.asarray(eneg),
            jnp.asarray(epos), jnp.asarray(batch.st_r),
            jnp.asarray(batch.idf_sum), jnp.asarray(batch.span_adjust))
    if not delta_g:
        return (jnp.asarray(batch.a_g), jnp.asarray(batch.ns_g)) + tail
    a = batch.a_g.astype(np.int64)
    ns = batch.ns_g.astype(np.int64)
    assert a.shape[-1] % BLK == 0
    a_base, a_delta, ok_a = _delta16_blocks(a)
    ns_base, ns_delta, ok_n = _delta16_blocks(ns)
    if not (ok_a and ok_n):
        raise ValueError("in-block key span exceeds uint16; use offsets format")
    a_pad = (a == np.int64(SENTINEL)).astype(np.uint8)
    ns_pad = (ns == np.int64(SENTINEL)).astype(np.uint8)
    return (jnp.asarray(a_base), jnp.asarray(a_delta), jnp.asarray(a_pad),
            jnp.asarray(ns_base), jnp.asarray(ns_delta), jnp.asarray(ns_pad)) + tail


# --------------------------------------------------------------------------
# per-key compressed rows (the compressed-row cache's unit, DESIGN.md §12)
# --------------------------------------------------------------------------
def compress_fst_rows(rows):
    """(g, lo, hi, present) -> (base, delta16, lo_off, hi_off, delta_ok,
    present). base/delta are None when the key's in-block span overflows
    uint16 — the batch assembler then falls back to the offsets format,
    which reuses lo_off/hi_off with the raw g row."""
    g, lo, hi, present = rows
    g64 = g.astype(np.int64)
    lo_off = np.where(lo == SENTINEL, 255, np.clip(g64 - lo, 0, 254)).astype(np.uint8)
    hi_off = np.where(hi == SENTINEL, 0, np.clip(hi - g64, 0, 254)).astype(np.uint8)
    if g64.shape[-1] % BLK:
        return (None, None, lo_off, hi_off, False, present)
    base, delta, ok = _delta16_blocks(g64)
    return (base, delta, lo_off, hi_off, ok, present)


def compress_wv_rows(rows):
    """(lo, hi, present) -> (base, delta16, width, delta_ok, present)."""
    lo, hi, present = rows
    lo64 = lo.astype(np.int64)
    pad = lo64 == np.int64(SENTINEL)
    width = np.where(pad, 255, np.clip(hi.astype(np.int64) - lo64, 0, 254)).astype(np.uint8)
    if lo64.shape[-1] % BLK:
        return (None, None, width, False, present)
    base, delta, ok = _delta16_blocks(lo64)
    return (base, delta, width, ok, present)


def compress_ord_rows(rows):
    """(g, present) -> (base, delta16, pad, delta_ok, present)."""
    g, present = rows
    g64 = g.astype(np.int64)
    pad = (g64 == np.int64(SENTINEL)).astype(np.uint8)
    if g64.shape[-1] % BLK:
        return (None, None, pad, False, present)
    base, delta, ok = _delta16_blocks(g64)
    return (base, delta, pad, ok, present)


def compress_nsw_rows(rows):
    """(cnt, ext, present) -> (cnt8, ext_neg, ext_pos, True, present)."""
    cnt, ext, present = rows
    cnt8 = np.clip(cnt, 0, 255).astype(np.uint8)
    eneg = np.clip(-np.minimum(ext, 0), 0, 255).astype(np.uint8)
    epos = np.clip(np.maximum(ext, 0), 0, 255).astype(np.uint8)
    return (cnt8, eneg, epos, True, present)


# --------------------------------------------------------------------------
# compressed batch assembly from per-key cached rows
# --------------------------------------------------------------------------
def assemble_qt1_compressed(index, queries, L, K=2, doc_shards=1,
                            ccache=None, cache=None, plans=None):
    """Build compressed QT1 device args from per-key *cached* compressed
    rows: warm drains become B*K row copies instead of an O(B·K·L) host
    re-encode. Returns (kind, args, batch_stub) with kind "delta" or
    "offsets" (chosen per batch: offsets when any key's in-block span
    overflows uint16 or the bucket is block/shard-misaligned)."""
    B = len(queries)
    stride = qt1_stride(index)
    lex = index.lexicon
    delta_fmt = L % (BLK * doc_shards) == 0
    lo_off = np.full((B, K, L), 255, np.uint8)
    hi_off = np.zeros((B, K, L), np.uint8)
    idf_sum = np.zeros(B, np.float32)
    span_adj = np.zeros(B, np.float32)
    ents: list = [None] * B
    for qi, q in enumerate(queries):
        if not q:
            continue
        keys = plans[qi] if plans is not None and plans[qi] is not None \
            else select_fst_keys(list(q))[1]
        keys = (keys + [keys[-1]] * K)[:K]
        span_adj[qi] = len(q) - 1
        row_ents = []
        any_present = False
        for ki, key in enumerate(keys):
            base, delta, lo_o, hi_o, ok, present = ccache.get(
                index, "fst_c", key, L, doc_shards, stride)
            delta_fmt &= ok
            if present:
                lo_off[qi, ki] = lo_o
                hi_off[qi, ki] = hi_o
                any_present = True
            row_ents.append((key, base, delta, present))
        if any_present:
            idf_sum[qi] = sum(lex.idf(l) for l in q)
        ents[qi] = row_ents
    stub = QT1Batch(None, None, None, idf_sum, span_adj, stride)
    tail = (jnp.asarray(lo_off), jnp.asarray(hi_off),
            jnp.asarray(idf_sum), jnp.asarray(span_adj))
    if delta_fmt:
        key_base = np.zeros((B, K, L // BLK), np.int32)
        key_delta = np.zeros((B, K, L), np.uint16)
        for qi, row_ents in enumerate(ents):
            if row_ents is None:
                continue
            for ki, (_, base, delta, present) in enumerate(row_ents):
                if present:
                    key_base[qi, ki] = base
                    key_delta[qi, ki] = delta
        return "delta", (jnp.asarray(key_base), jnp.asarray(key_delta)) + tail, stub
    key_g = np.full((B, K, L), SENTINEL, np.int32)
    for qi, row_ents in enumerate(ents):
        if row_ents is None:
            continue
        for ki, (key, _, _, present) in enumerate(row_ents):
            if not present:
                continue
            if cache is not None:
                g_row, _, _, pres = cache.get_rows(index, key, L, doc_shards, stride)
            else:
                g_row, _, _, pres = pack_fst_key_rows(index, key, L, doc_shards, stride)
            if pres:
                key_g[qi, ki] = g_row
    args = (jnp.zeros((B, K, 1), jnp.int32), jnp.asarray(key_g)) + tail
    return "offsets", args, stub


def assemble_qt2_compressed(index, queries, L, K=3, doc_shards=1,
                            ccache=None, cache=None, plans=None):
    """Compressed QT2 device args from per-key cached rows (kind "wv_c").
    Returns (kind, args, batch_stub), kind "qt2_delta" / "qt2_offsets"."""
    B = len(queries)
    stride = qt1_stride(index)
    lex = index.lexicon
    delta_fmt = L % (BLK * doc_shards) == 0
    width = np.full((B, K, L), 255, np.uint8)
    n_keys = np.zeros(B, np.int32)
    idf_sum = np.zeros(B, np.float32)
    span_adj = np.zeros(B, np.float32)
    ents: list = [None] * B
    for qi, q in enumerate(queries):
        if not q:
            continue
        keys = (plans[qi] if plans is not None and plans[qi] is not None
                else ordered_wv_keys(index, q)[0])[:K]
        n_keys[qi] = len(keys)
        span_adj[qi] = len(q) - 1
        row_ents = []
        any_present = False
        for ki, key in enumerate(keys):
            base, delta, w, ok, present = ccache.get(
                index, "wv_c", key, L, doc_shards, stride)
            delta_fmt &= ok
            if present:
                width[qi, ki] = w
                any_present = True
            row_ents.append((key, base, delta, present))
        if any_present:
            idf_sum[qi] = sum(lex.idf(l) for l in q)
        ents[qi] = row_ents
    stub = QT2Batch(None, None, n_keys, idf_sum, span_adj, stride)
    tail = (jnp.asarray(width), jnp.asarray(n_keys),
            jnp.asarray(idf_sum), jnp.asarray(span_adj))
    if delta_fmt:
        lo_base = np.zeros((B, K, L // BLK), np.int32)
        lo_delta = np.zeros((B, K, L), np.uint16)
        for qi, row_ents in enumerate(ents):
            if row_ents is None:
                continue
            for ki, (_, base, delta, present) in enumerate(row_ents):
                if present:
                    lo_base[qi, ki] = base
                    lo_delta[qi, ki] = delta
        return "qt2_delta", (jnp.asarray(lo_base), jnp.asarray(lo_delta)) + tail, stub
    wv_lo = np.full((B, K, L), SENTINEL, np.int32)
    for qi, row_ents in enumerate(ents):
        if row_ents is None:
            continue
        for ki, (key, _, _, present) in enumerate(row_ents):
            if not present:
                continue
            if cache is not None:
                lo_row, _, pres = cache.get(index, "wv", key, L, doc_shards, stride)
            else:
                lo_row, _, pres = pack_wv_key_rows(index, key, L, doc_shards, stride)
            if pres:
                wv_lo[qi, ki] = lo_row
    return "qt2_offsets", (jnp.asarray(wv_lo),) + tail, stub


def assemble_qt34_compressed(index, queries, L, Kn=4, doc_shards=1,
                             ccache=None, cache=None, plans=None):
    """Compressed QT3/QT4 device args from per-key cached rows (kind
    "ord_c" — shared with the QT5 anchor/non-stop streams, so a key hot
    on either path warms both). Returns (kind, args, batch_stub), kind
    "qt34_delta" / "qt34_offsets"."""
    B = len(queries)
    stride = qt1_stride(index)
    lex = index.lexicon
    delta_fmt = L % (BLK * doc_shards) == 0
    a_pad = np.ones((B, L), np.uint8)
    ns_pad = np.ones((B, Kn, L), np.uint8)
    ns_r = np.zeros((B, Kn), np.int32)
    idf_sum = np.zeros(B, np.float32)
    span_adj = np.zeros(B, np.float32)
    a_ents: list = [None] * B
    ns_ents: list = [None] * B
    for qi, q in enumerate(queries):
        if not q:
            continue
        plan = (plans[qi] if plans is not None and plans[qi] is not None
                else qt34_plan(index, q))
        anchor, others, _ = plan
        span_adj[qi] = len(q) - 1
        base, delta, pad, ok, present = ccache.get(
            index, "ord_c", anchor, L, doc_shards, stride)
        delta_fmt &= ok
        if present:
            a_pad[qi] = pad
        a_ents[qi] = (anchor, base, delta, present)
        row_ents = []
        for ki, (lemma, r) in enumerate(others[:Kn]):
            b2, d2, p2, ok2, pr2 = ccache.get(
                index, "ord_c", lemma, L, doc_shards, stride)
            delta_fmt &= ok2
            ns_r[qi, ki] = r
            if pr2:
                ns_pad[qi, ki] = p2
            row_ents.append((lemma, b2, d2, pr2))
        ns_ents[qi] = row_ents
        idf_sum[qi] = sum(lex.idf(l) for l in q)
    stub = QT34Batch(None, None, ns_r, idf_sum, span_adj, stride)
    tail = (jnp.asarray(ns_r), jnp.asarray(idf_sum), jnp.asarray(span_adj))
    if delta_fmt:
        nb = L // BLK
        a_base = np.zeros((B, nb), np.int32)
        a_delta = np.zeros((B, L), np.uint16)
        ns_base = np.zeros((B, Kn, nb), np.int32)
        ns_delta = np.zeros((B, Kn, L), np.uint16)
        for qi in range(B):
            if a_ents[qi] is not None and a_ents[qi][3]:
                a_base[qi] = a_ents[qi][1]
                a_delta[qi] = a_ents[qi][2]
            for ki, (_, b2, d2, pr2) in enumerate(ns_ents[qi] or ()):
                if pr2:
                    ns_base[qi, ki] = b2
                    ns_delta[qi, ki] = d2
        args = (jnp.asarray(a_base), jnp.asarray(a_delta), jnp.asarray(a_pad),
                jnp.asarray(ns_base), jnp.asarray(ns_delta),
                jnp.asarray(ns_pad)) + tail
        return "qt34_delta", args, stub

    def raw_row(lemma):
        if cache is not None:
            return cache.get(index, "ord", lemma, L, doc_shards, stride)
        return pack_ord_key_rows(index, lemma, L, doc_shards, stride)

    a_g = np.full((B, L), SENTINEL, np.int32)
    ns_g = np.full((B, Kn, L), SENTINEL, np.int32)
    for qi in range(B):
        if a_ents[qi] is not None and a_ents[qi][3]:
            g_row, pres = raw_row(a_ents[qi][0])
            if pres:
                a_g[qi] = g_row
        for ki, (lemma, _, _, pr2) in enumerate(ns_ents[qi] or ()):
            if pr2:
                g_row, pres = raw_row(lemma)
                if pres:
                    ns_g[qi, ki] = g_row
    return "qt34_offsets", (jnp.asarray(a_g), jnp.asarray(ns_g)) + tail, stub


def assemble_qt5_compressed(index, queries, L, Kn=3, Ks=3, doc_shards=1,
                            ccache=None, cache=None, plans=None):
    """Compressed QT5 device args from per-key cached rows (kinds "ord_c"
    for anchor/non-stop streams, "nsw_c" for the uint8 NSW aggregates).
    Returns (kind, args, batch_stub), kind "qt5_delta" / "qt5_offsets"."""
    B = len(queries)
    stride = qt1_stride(index)
    lex = index.lexicon
    delta_fmt = L % (BLK * doc_shards) == 0
    a_pad = np.ones((B, L), np.uint8)
    ns_pad = np.ones((B, Kn, L), np.uint8)
    ns_r = np.zeros((B, Kn), np.int32)
    st_r = np.zeros((B, Ks), np.int32)
    cnt8 = np.zeros((B, Ks, L), np.uint8)
    eneg = np.zeros((B, Ks, L), np.uint8)
    epos = np.zeros((B, Ks, L), np.uint8)
    idf_sum = np.zeros(B, np.float32)
    span_adj = np.zeros(B, np.float32)
    a_ents: list = [None] * B
    ns_ents: list = [None] * B
    for qi, q in enumerate(queries):
        if not q:
            continue
        plan = (plans[qi] if plans is not None and plans[qi] is not None
                else qt5_plan(index, q))
        if plan is None:
            continue  # degenerate; routed to the CPU by the engine
        anchor, others, stops, _ = plan
        span_adj[qi] = len(q) - 1
        base, delta, pad, ok, present = ccache.get(
            index, "ord_c", anchor, L, doc_shards, stride)
        delta_fmt &= ok
        if present:
            a_pad[qi] = pad
        a_ents[qi] = (anchor, base, delta, present)
        row_ents = []
        for ki, (lemma, r) in enumerate(others[:Kn]):
            b2, d2, p2, ok2, pr2 = ccache.get(
                index, "ord_c", lemma, L, doc_shards, stride)
            delta_fmt &= ok2
            ns_r[qi, ki] = r
            if pr2:
                ns_pad[qi, ki] = p2
            row_ents.append((lemma, b2, d2, pr2))
        ns_ents[qi] = row_ents
        for ki, (sid, r) in enumerate(stops[:Ks]):
            c8, en, ep, _, pr = ccache.get(
                index, "nsw_c", (anchor, sid), L, doc_shards, stride)
            st_r[qi, ki] = r
            if pr:
                cnt8[qi, ki] = c8
                eneg[qi, ki] = en
                epos[qi, ki] = ep
        idf_sum[qi] = sum(lex.idf(l) for l in q)
    stub = QT5Batch(None, None, ns_r, None, None, st_r, idf_sum, span_adj, stride)
    tail = (jnp.asarray(ns_r), jnp.asarray(cnt8), jnp.asarray(eneg),
            jnp.asarray(epos), jnp.asarray(st_r),
            jnp.asarray(idf_sum), jnp.asarray(span_adj))
    if delta_fmt:
        nb = L // BLK
        a_base = np.zeros((B, nb), np.int32)
        a_delta = np.zeros((B, L), np.uint16)
        ns_base = np.zeros((B, Kn, nb), np.int32)
        ns_delta = np.zeros((B, Kn, L), np.uint16)
        for qi in range(B):
            if a_ents[qi] is not None and a_ents[qi][3]:
                a_base[qi] = a_ents[qi][1]
                a_delta[qi] = a_ents[qi][2]
            for ki, (_, b2, d2, pr2) in enumerate(ns_ents[qi] or ()):
                if pr2:
                    ns_base[qi, ki] = b2
                    ns_delta[qi, ki] = d2
        args = (jnp.asarray(a_base), jnp.asarray(a_delta), jnp.asarray(a_pad),
                jnp.asarray(ns_base), jnp.asarray(ns_delta),
                jnp.asarray(ns_pad)) + tail
        return "qt5_delta", args, stub

    def raw_row(lemma):
        if cache is not None:
            return cache.get(index, "ord", lemma, L, doc_shards, stride)
        return pack_ord_key_rows(index, lemma, L, doc_shards, stride)

    a_g = np.full((B, L), SENTINEL, np.int32)
    ns_g = np.full((B, Kn, L), SENTINEL, np.int32)
    for qi in range(B):
        if a_ents[qi] is not None and a_ents[qi][3]:
            g_row, pres = raw_row(a_ents[qi][0])
            if pres:
                a_g[qi] = g_row
        for ki, (lemma, _, _, pr2) in enumerate(ns_ents[qi] or ()):
            if pr2:
                g_row, pres = raw_row(lemma)
                if pres:
                    ns_g[qi, ki] = g_row
    return "qt5_offsets", (jnp.asarray(a_g), jnp.asarray(ns_g)) + tail, stub


def decode_results(batch: QT1Batch, top_s, top_g, top_lo, top_hi):
    """Device top-k -> per-query (doc, start, end, score) numpy records.

    Vectorized: the four (B, k) result matrices are tiny (k = top_k), so
    they transfer wholesale in four copies and every filter/divmod runs
    in numpy — per-row device gathers would cost more in op dispatch
    than the masked rows' bytes (measured: ~0.7 ms per device
    ``__getitem__`` on CPU vs ~4 KB of extra transfer)."""
    s = np.asarray(top_s)
    valid = s > -1e29
    B = s.shape[0]
    z = np.zeros(0, np.int64)
    out = [
        {"doc": z, "start": z, "end": z, "score": np.zeros(0, s.dtype)}
        for _ in range(B)
    ]
    rows = np.flatnonzero(valid.any(axis=1))
    if rows.size == 0:
        return out
    g = np.asarray(top_g).astype(np.int64)[rows]
    lo = np.asarray(top_lo).astype(np.int64)[rows]
    hi = np.asarray(top_hi).astype(np.int64)[rows]
    vm = valid[rows]
    doc = g[vm] // batch.stride
    start = lo[vm] % batch.stride
    end = hi[vm] % batch.stride
    score = s[rows][vm]
    splits = np.cumsum(vm.sum(axis=1))[:-1]
    for qi, d, st, en, sc in zip(
        rows.tolist(),
        np.split(doc, splits),
        np.split(start, splits),
        np.split(end, splits),
        np.split(score, splits),
    ):
        out[qi] = {"doc": d, "start": st, "end": en, "score": sc}
    return out
