"""TPU-adapted batched proximity search (the paper's engine as a jitted,
shardable serve step).

Key re-design vs the CPU engine (DESIGN.md §3):
* postings live in dense, padded int32 device arrays; (doc, pos) pairs are
  packed as g = doc * stride + pos (documents are strided so windows can't
  cross them);
* a batch of B QT1 queries is evaluated at once; each query carries K
  three-component-key posting lists of bucketed length L (padding =
  SENTINEL). K and L are *static* — the compiled step is the response-time
  guarantee;
* Equalize == sorted intersection: key list 0 is the anchor stream; lists
  1..K-1 are joined via vectorized membership (searchsorted on CPU/GPU,
  the Pallas intersect kernel on TPU);
* the index is document-sharded over the `model` mesh axis (each shard
  holds a doc range of every posting list); queries are batch-sharded over
  `pod`/`data`. Per-shard top-k results are all-gathered (k entries per
  shard — tiny collective) and reduced to a global top-k.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.index_builder import ProximityIndex
from repro.core.query import select_fst_keys
from repro.kernels.common import SENTINEL

from repro.kernels.common import shard_map_compat as _shard_map

NEG_INF = jnp.float32(-1e30)


# --------------------------------------------------------------------------
# batched single-device primitives
# --------------------------------------------------------------------------
def _membership(g0: jnp.ndarray, gk: jnp.ndarray):
    """Batched membership of g0 rows in gk rows: (B, L) int32 each."""

    def one(g0_row, gk_row):
        idx = jnp.searchsorted(gk_row, g0_row)
        idx_c = jnp.clip(idx, 0, gk_row.shape[0] - 1)
        found = (gk_row[idx_c] == g0_row) & (g0_row != SENTINEL)
        return found, idx_c

    return jax.vmap(one)(g0, gk)


def qt1_join(key_g: jnp.ndarray, key_lo: jnp.ndarray, key_hi: jnp.ndarray):
    """Join K key posting lists on the anchor stream (list 0).

    key_g/lo/hi: (B, K, L) int32. Returns (valid, lo, hi) each (B, L),
    aligned with the anchor list."""
    K = key_g.shape[1]
    g0 = key_g[:, 0]
    valid = g0 != SENTINEL
    lo = key_lo[:, 0]
    hi = key_hi[:, 0]
    for k in range(1, K):
        found, idx = _membership(g0, key_g[:, k])
        valid &= found
        lo_k = jnp.take_along_axis(key_lo[:, k], idx, axis=1)
        hi_k = jnp.take_along_axis(key_hi[:, k], idx, axis=1)
        lo = jnp.where(found, jnp.minimum(lo, lo_k), lo)
        hi = jnp.where(found, jnp.maximum(hi, hi_k), hi)
    return valid, lo, hi


def qt1_score(valid, lo, hi, idf_sum, span_adjust):
    span_excess = jnp.maximum((hi - lo) - span_adjust[:, None], 0)
    return jnp.where(valid, idf_sum[:, None] / (1.0 + span_excess.astype(jnp.float32)), NEG_INF)


def qt1_topk(score, g_anchor, lo, hi, k: int):
    top_s, top_i = jax.lax.top_k(score, k)
    take = lambda x: jnp.take_along_axis(x, top_i, axis=1)
    return top_s, take(g_anchor), take(lo), take(hi)


# --------------------------------------------------------------------------
# sharded serve step
# --------------------------------------------------------------------------
def make_qt1_serve_step(mesh, top_k: int = 16, use_pallas: bool = False):
    """Build the jitted, mesh-sharded QT1 serve step.

    Sharding: batch over pod+data axes, posting length (doc ranges) over
    model. The all-gather moves only K' = top_k entries per shard."""
    has_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if has_pod else ("data",)

    def local_step(key_g, key_lo, key_hi, idf_sum, span_adjust):
        valid, lo, hi = qt1_join(key_g, key_lo, key_hi)
        score = qt1_score(valid, lo, hi, idf_sum, span_adjust)
        s, g, l, h = qt1_topk(score, key_g[:, 0], lo, hi, top_k)
        # gather per-shard top-k across the doc-sharded axis
        s_all = jax.lax.all_gather(s, "model", axis=1, tiled=True)
        g_all = jax.lax.all_gather(g, "model", axis=1, tiled=True)
        l_all = jax.lax.all_gather(l, "model", axis=1, tiled=True)
        h_all = jax.lax.all_gather(h, "model", axis=1, tiled=True)
        return qt1_topk(s_all, g_all, l_all, h_all, top_k)

    batch_spec = P(batch_axes, None, "model")
    vec_spec = P(batch_axes)
    out_spec = P(batch_axes, None)
    step = _shard_map(
        local_step,
        mesh,
        in_specs=(batch_spec, batch_spec, batch_spec, vec_spec, vec_spec),
        out_specs=(out_spec, out_spec, out_spec, out_spec),
    )
    in_shardings = (
        NamedSharding(mesh, batch_spec),
        NamedSharding(mesh, batch_spec),
        NamedSharding(mesh, batch_spec),
        NamedSharding(mesh, vec_spec),
        NamedSharding(mesh, vec_spec),
    )
    out_shardings = tuple(NamedSharding(mesh, out_spec) for _ in range(4))
    return jax.jit(step, in_shardings=in_shardings, out_shardings=out_shardings)


def make_qt1_serve_step_compressed(mesh, top_k: int = 16, delta_g: bool = True):
    """Beyond-paper §Perf optimization of the serve step: the posting
    payload is compressed in HBM and decompressed on the fly.

    * fragment bounds ride as uint8 offsets from the anchor (|off| <=
      MaxDistance, which must be <= 254 — 255 marks padding; checked at
      engine construction) instead of two int32 streams;
    * with delta_g, anchor keys are block-delta-coded: one int32 base per
      64-posting block + uint16 in-block deltas (doc strides bound the
      in-block range; blocks with wider span fall back via the packer).

    Bytes/posting: 12 -> 6 (offsets) -> 4 (offsets + delta16). The join is
    unchanged — reconstruction is elementwise and fuses into it.
    """
    has_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if has_pod else ("data",)
    BLK = 64

    def local_step(key_base, key_delta, key_lo_off, key_hi_off, idf_sum, span_adjust):
        if delta_g:
            # (B,K,nb) int32 base + (B,K,L) uint16 deltas -> int32 keys
            base = jnp.repeat(key_base, BLK, axis=2)
            key_g = base + key_delta.astype(jnp.int32)
        else:
            key_g = key_delta
        lo = key_g - key_lo_off.astype(jnp.int32)
        hi = key_g + key_hi_off.astype(jnp.int32)
        # SENTINEL-preservation: padding slots are marked by lo_off==255
        pad = key_lo_off == 255
        key_g = jnp.where(pad, SENTINEL, key_g)
        valid, lo, hi = qt1_join(key_g, lo, hi)
        score = qt1_score(valid, lo, hi, idf_sum, span_adjust)
        s, g, l, h = qt1_topk(score, key_g[:, 0], lo, hi, top_k)
        s_all = jax.lax.all_gather(s, "model", axis=1, tiled=True)
        g_all = jax.lax.all_gather(g, "model", axis=1, tiled=True)
        l_all = jax.lax.all_gather(l, "model", axis=1, tiled=True)
        h_all = jax.lax.all_gather(h, "model", axis=1, tiled=True)
        return qt1_topk(s_all, g_all, l_all, h_all, top_k)

    batch_spec = P(batch_axes, None, "model")
    # offsets-only: the dummy (B,K,1) base cannot shard its unit dim
    base_spec = batch_spec if delta_g else P(batch_axes, None, None)
    vec_spec = P(batch_axes)
    out_spec = P(batch_axes, None)
    step = _shard_map(
        local_step,
        mesh,
        in_specs=(base_spec, batch_spec, batch_spec, batch_spec, vec_spec, vec_spec),
        out_specs=(out_spec,) * 4,
    )
    shards = lambda spec: NamedSharding(mesh, spec)
    return jax.jit(
        step,
        in_shardings=(shards(base_spec), shards(batch_spec), shards(batch_spec),
                      shards(batch_spec), shards(vec_spec), shards(vec_spec)),
        out_shardings=(shards(out_spec),) * 4,
    )


def compress_qt1_batch(batch: "QT1Batch", delta_g: bool = True):
    """Pack a QT1Batch into the compressed device format (args for
    make_qt1_serve_step_compressed). Raises if a 64-posting block's key
    span exceeds uint16 (the serving packer then falls back to the
    offsets-only format for that bucket)."""
    BLK = 64
    g = batch.key_g.astype(np.int64)
    B, K, L = g.shape
    # pads are marked by lo_off == 255 in the compressed format
    lo_off = np.where(batch.key_lo == SENTINEL, 255,
                      np.clip(g - batch.key_lo, 0, 254))
    hi_off = np.where(batch.key_hi == SENTINEL, 0,
                      np.clip(batch.key_hi - g, 0, 254))
    if not delta_g:
        return (
            jnp.zeros((B, K, 1), jnp.int32),
            jnp.asarray(batch.key_g),
            jnp.asarray(lo_off.astype(np.uint8)),
            jnp.asarray(hi_off.astype(np.uint8)),
            jnp.asarray(batch.idf_sum),
            jnp.asarray(batch.span_adjust),
        )
    assert L % BLK == 0
    nb = L // BLK
    gb = g.reshape(B, K, nb, BLK)
    is_pad = gb == SENTINEL
    # per-block base = min over live postings, not element 0: with
    # doc_shards > 1 a block can straddle a shard-segment boundary and
    # *start* with padding while holding live keys later — anchoring on
    # the min keeps every delta non-negative (and minimal)
    live_min = np.where(is_pad, np.int64(SENTINEL), gb).min(axis=-1)
    base = np.where(live_min == np.int64(SENTINEL), 0, live_min)
    delta = np.where(is_pad, 0, gb - base[..., None])
    if delta.max(initial=0) >= 2**16:
        raise ValueError("in-block key span exceeds uint16; use offsets format")
    return (
        jnp.asarray(base.astype(np.int32)),
        jnp.asarray(delta.reshape(B, K, L).astype(np.uint16)),
        jnp.asarray(lo_off.astype(np.uint8)),
        jnp.asarray(hi_off.astype(np.uint8)),
        jnp.asarray(batch.idf_sum),
        jnp.asarray(batch.span_adjust),
    )


# --------------------------------------------------------------------------
# host-side batch packing from a ProximityIndex
# --------------------------------------------------------------------------
@dataclass
class QT1Batch:
    key_g: np.ndarray  # (B, K, L) int32
    key_lo: np.ndarray
    key_hi: np.ndarray
    idf_sum: np.ndarray  # (B,) f32
    span_adjust: np.ndarray  # (B,) f32 == len(query) - 1
    stride: int

    def device_args(self):
        return (
            jnp.asarray(self.key_g),
            jnp.asarray(self.key_lo),
            jnp.asarray(self.key_hi),
            jnp.asarray(self.idf_sum),
            jnp.asarray(self.span_adjust),
        )


def qt1_stride(index) -> int:
    """Document stride of the g = doc * stride + pos packing. Derived only
    from the (immutable) index, so every batch packed against one snapshot
    agrees on it."""
    max_len = int(index.doc_lengths.max()) if index.doc_lengths is not None else 1
    return max_len + index.max_distance + 2


def batch_size_bucket(n: int, cap: int) -> int:
    """Round a batch size up to the next power of two, capped at `cap`.

    The serve step is jit-compiled per (B, K, L) shape; padding B to this
    small ladder means at most log2(cap)+1 compiles per L-bucket instead
    of one silent recompile for every batch size the queue happens to
    produce."""
    b = 1
    while b < n and b < cap:
        b <<= 1
    return min(b, cap)


def pack_fst_key_rows(
    index,
    key,
    L: int,
    doc_shards: int = 1,
    stride: int | None = None,
    out=None,
):
    """Derive the padded, range-partitioned device rows for one (f,s,t) key.

    Returns ``(g, lo, hi, present)``: three (L,) int32 rows plus whether
    the key exists in the index. Postings are range-partitioned into
    doc_shards contiguous doc ranges, each padded to L // doc_shards — so
    that sharding the L axis over the mesh's model axis puts aligned doc
    ranges on the same shard (the alignment invariant of the distributed
    join). Rows depend only on (snapshot, key, L, doc_shards): this is the
    unit the serving layer's PackedPostingCache memoizes (DESIGN.md §11).

    With ``out`` (three caller-provided (L,) views, already
    SENTINEL-filled — e.g. slices of the batch arrays) postings are
    written in place and no rows are allocated, keeping the uncached
    packing path copy-free."""
    if stride is None:
        stride = qt1_stride(index)
    assert L % doc_shards == 0
    Ls = L // doc_shards
    if out is None:
        g_row = np.full(L, SENTINEL, np.int32)
        lo_row = np.full(L, SENTINEL, np.int32)
        hi_row = np.full(L, SENTINEL, np.int32)
    else:
        g_row, lo_row, hi_row = out
    if index.fst is None or key not in index.fst:
        return g_row, lo_row, hi_row, False
    docs, pf, o1, o2 = index.read_fst(key)
    g = (docs * stride + pf).astype(np.int64)
    lo = pf + np.minimum(np.minimum(o1, o2), 0) + docs * stride
    hi = pf + np.maximum(np.maximum(o1, o2), 0) + docs * stride
    n_docs = index.doc_lengths.size
    lo_bound = 0
    for s in range(doc_shards):
        hi_bound = ((s + 1) * n_docs) // doc_shards
        m = (docs >= lo_bound) & (docs < hi_bound)
        seg = min(int(m.sum()), Ls)
        sl = slice(s * Ls, s * Ls + seg)
        g_row[sl] = g[m][:seg]
        lo_row[sl] = lo[m][:seg]
        hi_row[sl] = hi[m][:seg]
        lo_bound = hi_bound
    return g_row, lo_row, hi_row, True


def pack_qt1_batch(
    index: ProximityIndex,
    queries: list[list[int]],
    L: int,
    K: int = 2,
    doc_shards: int = 1,
    cache=None,
) -> QT1Batch:
    """Pack QT1 queries into fixed-shape device arrays.

    Per-key row derivation lives in :func:`pack_fst_key_rows`; with
    `cache` (a ``repro.serving.pack_cache.PackedPostingCache``) the rows
    of hot keys are served from memory instead of being re-derived from
    segment reads — packing becomes B*K row copies.

    An empty query is a batch-shape padding slot: its rows stay
    all-SENTINEL and its idf_sum is 0, so it scores NEG_INF everywhere
    and decodes to zero results.

    INVARIANT: doc_shards must equal the serving mesh's model-axis size.
    Each range-partitioned segment is sorted *locally*; the concatenated
    row is not globally sorted, so the searchsorted-based join is only
    correct when each model shard sees exactly one segment."""
    B = len(queries)
    lex = index.lexicon
    stride = qt1_stride(index)
    assert L % doc_shards == 0

    key_g = np.full((B, K, L), SENTINEL, np.int32)
    key_lo = np.full((B, K, L), SENTINEL, np.int32)
    key_hi = np.full((B, K, L), SENTINEL, np.int32)
    idf_sum = np.zeros(B, np.float32)
    span_adj = np.zeros(B, np.float32)

    for qi, q in enumerate(queries):
        if not q:
            continue  # padding slot
        _, keys = select_fst_keys(q)
        keys = (keys + [keys[-1]] * K)[:K]  # pad by repeating (idempotent join)
        span_adj[qi] = len(q) - 1
        any_present = False
        for ki, key in enumerate(keys):
            if cache is not None:
                g_row, lo_row, hi_row, present = cache.get_rows(
                    index, key, L, doc_shards, stride
                )
                if present:
                    key_g[qi, ki] = g_row
                    key_lo[qi, ki] = lo_row
                    key_hi[qi, ki] = hi_row
            else:  # write postings straight into the batch arrays
                _, _, _, present = pack_fst_key_rows(
                    index, key, L, doc_shards, stride,
                    out=(key_g[qi, ki], key_lo[qi, ki], key_hi[qi, ki]),
                )
            any_present = any_present or present
        if any_present:
            idf_sum[qi] = sum(lex.idf(l) for l in q)
    return QT1Batch(key_g, key_lo, key_hi, idf_sum, span_adj, stride)


def decode_results(batch: QT1Batch, top_s, top_g, top_lo, top_hi):
    """Device top-k -> per-query (doc, start, end, score) numpy records.

    Vectorized: one host transfer of the (B, k) score matrix decides which
    rows matter; fully masked rows never cross device->host (the g/lo/hi
    gather is restricted to surviving rows), and the stride divmod runs
    once over all surviving entries instead of per query."""
    s = np.asarray(top_s)
    valid = s > -1e29
    B = s.shape[0]
    z = np.zeros(0, np.int64)
    out = [
        {"doc": z, "start": z, "end": z, "score": np.zeros(0, s.dtype)}
        for _ in range(B)
    ]
    rows = np.flatnonzero(valid.any(axis=1))
    if rows.size == 0:
        return out
    g = np.asarray(top_g[rows]).astype(np.int64)
    lo = np.asarray(top_lo[rows]).astype(np.int64)
    hi = np.asarray(top_hi[rows]).astype(np.int64)
    vm = valid[rows]
    doc = g[vm] // batch.stride
    start = lo[vm] % batch.stride
    end = hi[vm] % batch.stride
    score = s[rows][vm]
    splits = np.cumsum(vm.sum(axis=1))[:-1]
    for qi, d, st, en, sc in zip(
        rows.tolist(),
        np.split(doc, splits),
        np.split(start, splits),
        np.split(end, splits),
        np.split(score, splits),
    ):
        out[qi] = {"doc": d, "start": st, "end": en, "score": sc}
    return out
