"""Posting-list storage with byte-metered access.

Layout mirrors the paper:
  * ordinary index: per lemma, stream-1 = (doc,pos) postings; stream-2 =
    NSW records (separate so QT3/QT4 can *skip* them, paper §1.2/QT5);
  * (w,v) index: per two-component key, (doc, p_w, zz(p_v-p_w)) triples;
  * (f,s,t) index: per three-component key, (doc, p_f, zz(off_s), zz(off_t)).

All streams are delta+varbyte encoded. A `ByteMeter` counts every byte
decoded on behalf of a query — the paper's "data read size" metric.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.codecs import (
    delta_decode,
    delta_encode,
    varbyte_decode,
    varbyte_encode,
    zigzag_decode,
    zigzag_encode,
)


@dataclass
class ByteMeter:
    bytes_read: int = 0
    postings_read: int = 0

    def reset(self) -> None:
        self.bytes_read = 0
        self.postings_read = 0

    def add(self, nbytes: int, npostings: int) -> None:
        self.bytes_read += int(nbytes)
        self.postings_read += int(npostings)


def encode_postings(columns: list[np.ndarray], delta_col: int = 0) -> bytes:
    """Encode parallel posting columns. Column `delta_col` (doc ids) is
    delta-encoded; column delta_col+1 (positions) is delta-encoded within
    runs of equal doc id; remaining columns are stored verbatim (they are
    already zigzagged small offsets). Interleaved row-major like a real
    on-disk posting stream."""
    n = columns[0].size
    if n == 0:
        return b""
    docs = columns[delta_col].astype(np.int64)
    doc_gap = delta_encode(docs)
    enc_cols = []
    for ci, col in enumerate(columns):
        if ci == delta_col:
            enc_cols.append(doc_gap)
        elif ci == delta_col + 1:
            pos = col.astype(np.int64)
            pg = np.empty(n, np.int64)
            pg[0] = pos[0]
            same = docs[1:] == docs[:-1]
            pg[1:] = np.where(same, pos[1:] - pos[:-1], pos[1:])
            # position gaps can be negative only if input unsorted; zigzag to be safe
            enc_cols.append(zigzag_encode(pg))
        else:
            enc_cols.append(col.astype(np.uint64))
    inter = np.empty(n * len(columns), np.uint64)
    for ci, col in enumerate(enc_cols):
        inter[ci :: len(columns)] = col
    return varbyte_encode(inter)


def decode_postings(buf: bytes, n_columns: int) -> list[np.ndarray]:
    vals = varbyte_decode(buf)
    if vals.size == 0:
        return [np.zeros(0, np.int64) for _ in range(n_columns)]
    n = vals.size // n_columns
    cols = [vals[ci::n_columns] for ci in range(n_columns)]
    docs = delta_decode(cols[0])
    out = [docs]
    pg = zigzag_decode(cols[1])
    # positions: cumulative within doc runs -> reconstruct via segmented cumsum
    pos = np.empty(n, np.int64)
    pos[0] = pg[0]
    boundaries = np.empty(n, bool)
    boundaries[0] = True
    boundaries[1:] = docs[1:] != docs[:-1]
    # segmented cumsum: cumsum then subtract carry at boundaries
    cs = np.cumsum(pg)
    seg_start = np.nonzero(boundaries)[0]
    carry = np.zeros(n, np.int64)
    carry_vals = cs[seg_start] - pg[seg_start]
    carry[seg_start] = np.diff(np.concatenate([[0], carry_vals]))
    pos = cs - np.cumsum(carry)
    out.append(pos)
    for ci in range(2, n_columns):
        out.append(cols[ci].astype(np.int64))
    return out


@dataclass
class PostingStore:
    """Maps key -> encoded blob (+ posting count); metered decode access."""

    n_columns: int
    blobs: dict = field(default_factory=dict)
    counts: dict = field(default_factory=dict)
    _raw: dict = field(default_factory=dict, repr=False)  # lazily encoded

    def put_raw(self, key, columns: list[np.ndarray]) -> None:
        """Register raw columns; encoding happens lazily on first access."""
        self._raw[key] = columns
        self.counts[key] = int(columns[0].size)

    def _blob(self, key) -> bytes:
        b = self.blobs.get(key)
        if b is None:
            cols = self._raw.get(key)
            if cols is None:
                return b""
            b = encode_postings(cols)
            self.blobs[key] = b
        return b

    def __contains__(self, key) -> bool:
        return key in self.counts

    def keys(self):
        return self.counts.keys()

    def n_postings(self, key) -> int:
        return self.counts.get(key, 0)

    def read(self, key, meter: ByteMeter | None = None) -> list[np.ndarray]:
        """Metered decode of a full posting list (the paper reads posting
        lists sequentially from disk; Idx1 queries consume them fully)."""
        blob = self._blob(key)
        if meter is not None:
            meter.add(len(blob), self.counts.get(key, 0))
        return decode_postings(blob, self.n_columns)

    def columns(self, key) -> list[np.ndarray]:
        """Unmetered decoded columns, skipping the codec round-trip when the
        raw columns are still in memory (segment merges, not query serving:
        queries go through `read` so the ByteMeter sees every byte)."""
        cols = self._raw.get(key)
        if cols is not None:
            return [np.asarray(c).astype(np.int64) for c in cols]
        return decode_postings(self._blob(key), self.n_columns)

    def total_bytes(self) -> int:
        # force-encode everything (used by index-size reports, not queries)
        return sum(len(self._blob(k)) for k in self.counts)


@dataclass
class BlobStore:
    """Opaque per-key byte blobs (NSW record streams)."""

    blobs: dict = field(default_factory=dict)

    def put(self, key, blob: bytes) -> None:
        self.blobs[key] = blob

    def read(self, key, meter: ByteMeter | None = None) -> bytes:
        b = self.blobs.get(key, b"")
        if meter is not None:
            meter.add(len(b), 0)
        return b

    def total_bytes(self) -> int:
        return sum(len(b) for b in self.blobs.values())
