"""Posting-list storage with byte-metered access.

Layout mirrors the paper:
  * ordinary index: per lemma, stream-1 = (doc,pos) postings; stream-2 =
    NSW records (separate so QT3/QT4 can *skip* them, paper §1.2/QT5);
  * (w,v) index: per two-component key, (doc, p_w, zz(p_v-p_w)) triples;
  * (f,s,t) index: per three-component key, (doc, p_f, zz(off_s), zz(off_t)).

All streams are delta+varbyte encoded. A `ByteMeter` counts every byte
decoded on behalf of a query — the paper's "data read size" metric.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.codecs import (
    delta_decode,
    delta_encode,
    varbyte_decode,
    varbyte_encode,
    zigzag_decode,
    zigzag_encode,
)


@dataclass
class ByteMeter:
    bytes_read: int = 0
    postings_read: int = 0

    def reset(self) -> None:
        self.bytes_read = 0
        self.postings_read = 0

    def add(self, nbytes: int, npostings: int) -> None:
        self.bytes_read += int(nbytes)
        self.postings_read += int(npostings)


def encode_postings(columns: list[np.ndarray], delta_col: int = 0) -> bytes:
    """Encode parallel posting columns. Column `delta_col` (doc ids) is
    delta-encoded; column delta_col+1 (positions) is delta-encoded within
    runs of equal doc id; remaining columns are stored verbatim (they are
    already zigzagged small offsets). Interleaved row-major like a real
    on-disk posting stream."""
    n = columns[0].size
    if n == 0:
        return b""
    docs = columns[delta_col].astype(np.int64)
    doc_gap = delta_encode(docs)
    enc_cols = []
    for ci, col in enumerate(columns):
        if ci == delta_col:
            enc_cols.append(doc_gap)
        elif ci == delta_col + 1:
            pos = col.astype(np.int64)
            pg = np.empty(n, np.int64)
            pg[0] = pos[0]
            same = docs[1:] == docs[:-1]
            pg[1:] = np.where(same, pos[1:] - pos[:-1], pos[1:])
            # position gaps can be negative only if input unsorted; zigzag to be safe
            enc_cols.append(zigzag_encode(pg))
        else:
            enc_cols.append(col.astype(np.uint64))
    inter = np.empty(n * len(columns), np.uint64)
    for ci, col in enumerate(enc_cols):
        inter[ci :: len(columns)] = col
    return varbyte_encode(inter)


def decode_postings(buf: bytes, n_columns: int) -> list[np.ndarray]:
    vals = varbyte_decode(buf)
    if vals.size == 0:
        return [np.zeros(0, np.int64) for _ in range(n_columns)]
    n = vals.size // n_columns
    cols = [vals[ci::n_columns] for ci in range(n_columns)]
    docs = delta_decode(cols[0])
    out = [docs]
    pg = zigzag_decode(cols[1])
    # positions: cumulative within doc runs -> reconstruct via segmented cumsum
    pos = np.empty(n, np.int64)
    pos[0] = pg[0]
    boundaries = np.empty(n, bool)
    boundaries[0] = True
    boundaries[1:] = docs[1:] != docs[:-1]
    # segmented cumsum: cumsum then subtract carry at boundaries
    cs = np.cumsum(pg)
    seg_start = np.nonzero(boundaries)[0]
    carry = np.zeros(n, np.int64)
    carry_vals = cs[seg_start] - pg[seg_start]
    carry[seg_start] = np.diff(np.concatenate([[0], carry_vals]))
    pos = cs - np.cumsum(carry)
    out.append(pos)
    for ci in range(2, n_columns):
        out.append(cols[ci].astype(np.int64))
    return out


class PostingStore:
    """Maps key -> encoded blob (+ posting count); metered decode access.

    Two registration paths:

    * ``put_raw(key, cols)`` — per-key, dict-backed (loads, ad-hoc use);
    * ``put_bulk(keys_arr, starts, ends, cols)`` — the whole store at
      once over one shared column arena (the seal/merge build paths).
      Per-key reads binary-search an integer mixed-radix encoding of the
      key, and the public ``counts`` dict materializes lazily on first
      iteration, so registering 10^5 keys is O(K) numpy work with no
      per-key Python loop — the memtable-seal latency hot path
      (DESIGN.md §18).
    """

    def __init__(self, n_columns: int):
        self.n_columns = n_columns
        self.blobs: dict = {}  # key -> encoded blob (lazy cache)
        self._counts: dict = {}
        self._counts_full = True  # no bulk arena yet -> dict is authoritative
        self._raw: dict = {}  # key -> raw columns (lazily encoded)
        # (keys2d, starts, ends, cols, enc, strides_l, maxes_l, scalar)
        self._bulk = None

    def put_raw(self, key, columns: list[np.ndarray]) -> None:
        """Register raw columns; encoding happens lazily on first access."""
        self._raw[key] = columns
        self._counts[key] = int(columns[0].size)

    def put_bulk(self, keys_arr: np.ndarray, starts: np.ndarray,
                 ends: np.ndarray, columns: list[np.ndarray]) -> None:
        """Register every key of this store at once over one shared arena.

        ``keys_arr`` is ``(K,)`` (scalar keys) or ``(K, kdim)``, lexico-
        graphically sorted and unique; key ``i`` owns rows
        ``starts[i]:ends[i]`` of every column. Requires an empty store."""
        if self._counts or self._raw or self._bulk is not None:
            raise ValueError("put_bulk requires an empty store")
        keys2d = np.asarray(keys_arr, np.int64)
        scalar = keys2d.ndim == 1
        if scalar:
            keys2d = keys2d.reshape(-1, 1)
        starts = np.asarray(starts, np.int64)
        ends = np.asarray(ends, np.int64)
        kdim = keys2d.shape[1]
        maxes = (keys2d.max(axis=0) + 1) if keys2d.size else np.ones(kdim, np.int64)
        maxes_l = [int(m) for m in maxes]
        strides_l = [1] * kdim
        cap = 1
        for j in range(kdim - 2, -1, -1):
            strides_l[j] = strides_l[j + 1] * maxes_l[j + 1]
        for m in maxes_l:
            cap *= m
        if cap >= 2**62:  # encoding would overflow int64: rare, go per-key
            keys_l = (keys2d[:, 0].tolist() if scalar
                      else list(map(tuple, keys2d.tolist())))
            for k, s, e in zip(keys_l, starts.tolist(), ends.tolist()):
                self.put_raw(k, [c[s:e] for c in columns])
            return
        enc = keys2d @ np.asarray(strides_l, np.int64)
        self._bulk = (keys2d, starts, ends, columns, enc, strides_l, maxes_l, scalar)
        self._counts_full = False

    def _bulk_find(self, key) -> int:
        """Index of ``key`` in the bulk arena, or -1."""
        b = self._bulk
        if b is None:
            return -1
        enc, strides_l, maxes_l = b[4], b[5], b[6]
        comps = key if isinstance(key, tuple) else (key,)
        if len(comps) != len(strides_l):
            return -1
        e = 0
        for c, st, m in zip(comps, strides_l, maxes_l):
            c = int(c)
            if c < 0 or c >= m:
                return -1  # out-of-range component can't be stored
            e += c * st
        i = int(np.searchsorted(enc, e))
        if i < enc.size and int(enc[i]) == e:
            return i
        return -1

    @property
    def counts(self) -> dict:
        """key -> posting count. Materialized lazily from the bulk arena on
        first access; per-key lookups should prefer ``n_postings``/``in``,
        which never materialize."""
        if not self._counts_full:
            self._counts_full = True
            b = self._bulk
            keys2d, starts, ends = b[0], b[1], b[2]
            cnts = (ends - starts).tolist()
            ks = (keys2d[:, 0].tolist() if b[7]
                  else map(tuple, keys2d.tolist()))
            merged = dict(zip(ks, cnts))
            merged.update(self._counts)  # per-key overrides win
            self._counts = merged
        return self._counts

    def bulk_rows(self):
        """Zero-copy ``(keys2d, starts, ends, columns)`` over the whole
        store when it is backed by one contiguous bulk arena with no
        per-key overrides; ``None`` otherwise (per-key/decoded stores).
        Lets a segment merge gather all rows without a per-key loop."""
        b = self._bulk
        if b is None or self._raw:
            return None
        keys2d, starts, ends, cols = b[0], b[1], b[2], b[3]
        n = int(cols[0].shape[0])
        if not (starts.size and int(starts[0]) == 0 and int(ends[-1]) == n
                and np.array_equal(starts[1:], ends[:-1])):
            return None  # spans don't tile the arena; fall back to per-key
        return keys2d, starts, ends, cols

    def _raw_cols(self, key) -> list[np.ndarray] | None:
        """Raw (undecoded) columns for a key, cutting arena slices lazily."""
        cols = self._raw.get(key)
        if cols is not None:
            return cols
        i = self._bulk_find(key)
        if i < 0:
            return None
        b = self._bulk
        s, e = int(b[1][i]), int(b[2][i])
        return [c[s:e] for c in b[3]]

    def _blob(self, key) -> bytes:
        b = self.blobs.get(key)
        if b is None:
            cols = self._raw_cols(key)
            if cols is None:
                return b""
            b = encode_postings(cols)
            self.blobs[key] = b
        return b

    def __contains__(self, key) -> bool:
        return key in self._counts or self._bulk_find(key) >= 0

    def keys(self):
        return self.counts.keys()

    def n_keys(self) -> int:
        """Number of keys, without materializing the counts dict."""
        n = len(self._counts)
        if not self._counts_full:
            n += self._bulk[0].shape[0]
        return n

    def n_postings(self, key) -> int:
        c = self._counts.get(key)
        if c is not None:
            return c
        i = self._bulk_find(key)
        if i >= 0:
            b = self._bulk
            return int(b[2][i] - b[1][i])
        return 0

    def read(self, key, meter: ByteMeter | None = None) -> list[np.ndarray]:
        """Metered decode of a full posting list (the paper reads posting
        lists sequentially from disk; Idx1 queries consume them fully)."""
        blob = self._blob(key)
        if meter is not None:
            meter.add(len(blob), self.n_postings(key))
        return decode_postings(blob, self.n_columns)

    def columns(self, key) -> list[np.ndarray]:
        """Unmetered decoded columns, skipping the codec round-trip when the
        raw columns are still in memory (segment merges, not query serving:
        queries go through `read` so the ByteMeter sees every byte)."""
        cols = self._raw_cols(key)
        if cols is not None:
            return [np.asarray(c).astype(np.int64) for c in cols]
        return decode_postings(self._blob(key), self.n_columns)

    def total_bytes(self) -> int:
        # force-encode everything (used by index-size reports, not queries)
        return sum(len(self._blob(k)) for k in self.counts)


@dataclass
class BlobStore:
    """Opaque per-key byte blobs (NSW record streams)."""

    blobs: dict = field(default_factory=dict)

    def put(self, key, blob: bytes) -> None:
        self.blobs[key] = blob

    def read(self, key, meter: ByteMeter | None = None) -> bytes:
        b = self.blobs.get(key, b"")
        if meter is not None:
            meter.add(len(b), 0)
        return b

    def total_bytes(self) -> int:
        return sum(len(b) for b in self.blobs.values())
