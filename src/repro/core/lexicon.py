"""FL-list and lemma typing (paper §1.1).

All lemmas are sorted in decreasing order of their occurrence frequency in
the corpus — the *FL-list*. The rank of a lemma is its *FL-number*; we use
0-based ranks and make the integer lemma id coincide with the FL-number,
so typing a lemma is a single comparison:

    id <  sw_count                     -> stop lemma
    id <  sw_count + fu_count          -> frequently used lemma
    otherwise                          -> ordinary lemma

The paper uses SWCount=700, FUCount=2100.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from enum import IntEnum
from pathlib import Path

import numpy as np

UNKNOWN_FL = 2**31 - 1  # the paper's "~": a very large number

DEFAULT_SW_COUNT = 700
DEFAULT_FU_COUNT = 2100


class LemmaType(IntEnum):
    STOP = 0
    FREQUENT = 1
    ORDINARY = 2


@dataclass
class Lexicon:
    """FL-ordered lemma dictionary with corpus statistics."""

    lemmas: list[str]  # index == lemma id == 0-based FL-number
    counts: np.ndarray  # occurrences per lemma, non-increasing
    doc_freqs: np.ndarray  # number of documents containing the lemma
    n_docs: int
    sw_count: int = DEFAULT_SW_COUNT
    fu_count: int = DEFAULT_FU_COUNT
    _fl: dict[str, int] = field(default_factory=dict, repr=False)

    def __post_init__(self) -> None:
        if not self._fl:
            self._fl = {w: i for i, w in enumerate(self.lemmas)}

    # -- lookups ----------------------------------------------------------
    def fl(self, lemma: str) -> int:
        """0-based FL-number; UNKNOWN_FL ('~') for out-of-corpus lemmas."""
        return self._fl.get(lemma, UNKNOWN_FL)

    def lemma_of(self, lemma_id: int) -> str:
        return self.lemmas[lemma_id]

    @property
    def n_lemmas(self) -> int:
        return len(self.lemmas)

    # -- typing (paper §1.1) ----------------------------------------------
    def type_of_id(self, lemma_id: int) -> LemmaType:
        if lemma_id < self.sw_count:
            return LemmaType.STOP
        if lemma_id < self.sw_count + self.fu_count:
            return LemmaType.FREQUENT
        return LemmaType.ORDINARY

    def type_of(self, lemma: str) -> LemmaType:
        return self.type_of_id(self.fl(lemma))

    def is_stop_id(self, lemma_id) -> np.ndarray:
        return np.asarray(lemma_id) < self.sw_count

    def is_nonstop_id(self, lemma_id) -> np.ndarray:
        return np.asarray(lemma_id) >= self.sw_count

    # -- relevance support --------------------------------------------------
    def idf(self, lemma_id: int) -> float:
        if lemma_id >= len(self.lemmas):
            return float(np.log1p(self.n_docs))
        df = max(int(self.doc_freqs[lemma_id]), 1)
        return float(np.log1p(self.n_docs / df))

    # -- construction -------------------------------------------------------
    @classmethod
    def build(
        cls,
        doc_lemma_ids_or_strs,
        sw_count: int = DEFAULT_SW_COUNT,
        fu_count: int = DEFAULT_FU_COUNT,
    ) -> "Lexicon":
        """Build from an iterable of documents; each document is a list of
        lemma strings (or a list of per-token lemma-alternative lists)."""
        counts: dict[str, int] = {}
        dfs: dict[str, int] = {}
        n_docs = 0
        for doc in doc_lemma_ids_or_strs:
            n_docs += 1
            seen: set[str] = set()
            for tok in doc:
                alts = tok if isinstance(tok, (list, tuple)) else (tok,)
                for lem in alts:
                    counts[lem] = counts.get(lem, 0) + 1
                    if lem not in seen:
                        seen.add(lem)
                        dfs[lem] = dfs.get(lem, 0) + 1
        order = sorted(counts, key=lambda w: (-counts[w], w))
        return cls(
            lemmas=order,
            counts=np.array([counts[w] for w in order], np.int64),
            doc_freqs=np.array([dfs[w] for w in order], np.int64),
            n_docs=n_docs,
            sw_count=sw_count,
            fu_count=fu_count,
        )

    @classmethod
    def from_rank_counts(
        cls,
        counts: np.ndarray,
        doc_freqs: np.ndarray,
        n_docs: int,
        sw_count: int = DEFAULT_SW_COUNT,
        fu_count: int = DEFAULT_FU_COUNT,
        names: list[str] | None = None,
    ) -> "Lexicon":
        """For synthetic corpora where lemma id == frequency rank already."""
        if names is None:
            names = [f"w{i}" for i in range(len(counts))]
        return cls(
            lemmas=names,
            counts=np.asarray(counts, np.int64),
            doc_freqs=np.asarray(doc_freqs, np.int64),
            n_docs=n_docs,
            sw_count=sw_count,
            fu_count=fu_count,
        )

    # -- persistence --------------------------------------------------------
    def save(self, path: str | Path) -> None:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        payload = {
            "lemmas": self.lemmas,
            "counts": self.counts.tolist(),
            "doc_freqs": self.doc_freqs.tolist(),
            "n_docs": self.n_docs,
            "sw_count": self.sw_count,
            "fu_count": self.fu_count,
        }
        path.write_text(json.dumps(payload))

    @classmethod
    def load(cls, path: str | Path) -> "Lexicon":
        payload = json.loads(Path(path).read_text())
        return cls(
            lemmas=payload["lemmas"],
            counts=np.array(payload["counts"], np.int64),
            doc_freqs=np.array(payload["doc_freqs"], np.int64),
            n_docs=payload["n_docs"],
            sw_count=payload["sw_count"],
            fu_count=payload["fu_count"],
        )
