"""Posting iterators and the Equalize procedure (paper §2.2-2.3).

Three interchangeable implementations, all tested for agreement:

* ``equalize_basic`` — the linear-scan variant from [10]: find the min and
  max iterator by scanning, advance the min until all equal; O(n)/step;
* ``EqualizeState`` (two binary heaps) — the *paper's contribution*:
  O(log n)/step inner loop (§2.3.4);
* ``bulk_align_docs`` — the vectorized (numpy) equivalent used by the Idx1
  baseline (which must consume millions of postings per query; a per-
  posting Python loop would be unfair to the baseline) and as the stepping
  stone to the TPU engine in jax_search.py.
"""

from __future__ import annotations

import numpy as np

from repro.core.heaps import IteratorHeap

_EXHAUSTED = np.iinfo(np.int64).max


class PostingIterator:
    """Paper §2.2 iterator: IT.next(), IT.value == (ID, P) + payload.

    Reads a decoded posting list (docs/positions [+ payload columns]) from
    start to end; ``value_id`` is the current doc id, exhausted iterators
    report value_id == +inf so heap-based Equalize naturally terminates.
    """

    __slots__ = ("docs", "positions", "payload", "cursor", "min_index", "max_index", "label")

    def __init__(self, docs: np.ndarray, positions: np.ndarray, payload: tuple = (), label=None):
        self.docs = docs
        self.positions = positions
        self.payload = payload
        self.cursor = 0
        self.min_index = 0
        self.max_index = 0
        self.label = label

    @property
    def value_id(self) -> int:
        return int(self.docs[self.cursor]) if self.cursor < self.docs.size else _EXHAUSTED

    @property
    def exhausted(self) -> bool:
        return self.cursor >= self.docs.size

    def next(self) -> bool:
        self.cursor += 1
        return self.cursor < self.docs.size

    def skip_to_doc(self, doc: int) -> None:
        """Galloping skip: advance cursor to the first posting with id>=doc."""
        self.cursor += int(np.searchsorted(self.docs[self.cursor :], doc, side="left"))

    def doc_slice(self) -> tuple[int, slice]:
        """(current doc, slice of postings belonging to it); cursor unmoved."""
        doc = self.value_id
        end = self.cursor + int(
            np.searchsorted(self.docs[self.cursor :], doc, side="right")
        )
        return doc, slice(self.cursor, end)

    def advance_past_doc(self) -> bool:
        doc, sl = self.doc_slice()
        self.cursor = sl.stop
        return self.cursor < self.docs.size


def equalize_basic(iterators: list[PostingIterator]) -> int | None:
    """Linear-scan Equalize from [10]: returns the aligned doc id, or None
    if some iterator is exhausted."""
    while True:
        ids = [it.value_id for it in iterators]
        mx = max(ids)
        if mx == _EXHAUSTED:
            return None
        mn = min(ids)
        if mn == mx:
            return mn
        it = iterators[ids.index(mn)]
        it.skip_to_doc(mx)  # galloping variant of repeated next()
        if it.exhausted:
            return None


class EqualizeState:
    """Paper §2.3.4: Equalize with MinHeap + MaxHeap.

    Usage::
        st = EqualizeState(iterators)
        while (doc := st.equalize()) is not None:
            ... consume doc on all iterators ...
            st.advance_all_past_doc()
    """

    def __init__(self, iterators: list[PostingIterator]):
        self.iterators = iterators
        n = len(iterators)
        self.min_heap = IteratorHeap(n, "min")
        self.max_heap = IteratorHeap(n, "max")
        for it in iterators:
            self.min_heap.insert(it)
            self.max_heap.insert(it)

    def _update(self, it: PostingIterator) -> None:
        self.min_heap.update(it.min_index)
        self.max_heap.update(it.max_index)

    def equalize(self, gallop: bool = True) -> int | None:
        """Steps 1-7 of §2.3.4. With gallop=True the advance uses
        skip_to_doc(max) instead of repeated next() — same result, fewer
        iterations (a beyond-paper micro-optimization, measured in
        benchmarks/equalize_scaling.py)."""
        while True:
            lo_it = self.min_heap.get_min()
            hi_it = self.max_heap.get_min()
            if lo_it.value_id == hi_it.value_id:
                if lo_it.value_id == _EXHAUSTED:
                    return None
                return lo_it.value_id
            if gallop:
                lo_it.skip_to_doc(hi_it.value_id)
            else:
                lo_it.next()
            if lo_it.exhausted:
                return None
            self._update(lo_it)

    def advance_all_past_doc(self) -> None:
        """After a doc has been consumed, move every iterator past it."""
        doc = self.min_heap.get_min().value_id
        for it in self.iterators:
            if not it.exhausted and it.value_id == doc:
                it.advance_past_doc()
                self._update(it)


def bulk_align_docs(doc_arrays: list[np.ndarray]) -> np.ndarray:
    """Vectorized Equalize: doc ids present in *all* sorted arrays.

    Semantically identical to iterating Equalize over every aligned doc;
    runs at numpy speed. Used by the Idx1 baseline engine and mirrored by
    the Pallas intersection kernel on TPU."""
    if not doc_arrays:
        return np.zeros(0, np.int64)
    common = np.unique(doc_arrays[0])
    for arr in doc_arrays[1:]:
        if common.size == 0:
            break
        # intersect1d(assume_unique) after unique'ing the incoming side
        common = np.intersect1d(common, np.unique(arr), assume_unique=True)
    return common
