"""Binary heaps over posting iterators — paper §2.3.

Faithful to the paper: two heaps (MinHeap ordered by ascending Value.ID,
MaxHeap by descending Value.ID) hold *pointers* to the same iterator
objects; every iterator carries back-pointer fields `min_index` /
`max_index` that the heaps keep up to date inside Insert and Update
(paper §2.3.3), so that after `it.next()` both heaps can reposition the
iterator in O(log n) via `Update(it.min_index)` / `Update(it.max_index)`.

Arrays are 1-indexed as in the paper (H[i] <= H[2i], H[2i+1]).
"""

from __future__ import annotations


class IteratorHeap:
    """Paper §2.3.2-2.3.3. kind='min' orders by ascending doc id,
    kind='max' by descending doc id."""

    def __init__(self, max_count: int, kind: str = "min"):
        assert kind in ("min", "max")
        self.kind = kind
        self.index_attr = "min_index" if kind == "min" else "max_index"
        self.heap: list = [None] * (max_count + 1)  # 1-indexed
        self.count = 0

    # comparison: MinHeap: A < B iff A.ID < B.ID; MaxHeap: A < B iff A.ID > B.ID
    def _less(self, a, b) -> bool:
        if self.kind == "min":
            return a.value_id < b.value_id
        return a.value_id > b.value_id

    def _set(self, i: int, it) -> None:
        self.heap[i] = it
        setattr(it, self.index_attr, i)

    def insert(self, it) -> None:
        """Paper §2.3.3 steps 1-5 (sift-up maintaining the index field)."""
        self.count += 1
        self._set(self.count, it)
        i = self.count
        while i > 1 and self._less(self.heap[i], self.heap[i // 2]):
            t, q = self.heap[i], self.heap[i // 2]
            self._set(i // 2, t)
            self._set(i, q)
            i //= 2

    def get_min(self):
        """Top of the heap: min doc id for MinHeap, max for MaxHeap. O(1)."""
        return self.heap[1]

    def update(self, i: int) -> None:
        """Reposition element i after its iterator advanced. O(log n)."""
        # sift up
        while i > 1 and self._less(self.heap[i], self.heap[i // 2]):
            t, q = self.heap[i], self.heap[i // 2]
            self._set(i // 2, t)
            self._set(i, q)
            i //= 2
        # sift down
        while True:
            l, r = 2 * i, 2 * i + 1
            smallest = i
            if l <= self.count and self._less(self.heap[l], self.heap[smallest]):
                smallest = l
            if r <= self.count and self._less(self.heap[r], self.heap[smallest]):
                smallest = r
            if smallest == i:
                return
            t, q = self.heap[smallest], self.heap[i]
            self._set(i, t)
            self._set(smallest, q)
            i = smallest

    def check_invariant(self) -> bool:
        """Heap property + back-pointer consistency (used by property tests)."""
        for i in range(1, self.count + 1):
            it = self.heap[i]
            if getattr(it, self.index_attr) != i:
                return False
            for c in (2 * i, 2 * i + 1):
                if c <= self.count and self._less(self.heap[c], self.heap[i]):
                    return False
        return True
