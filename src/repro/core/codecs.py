"""Vectorized integer codecs used by the index storage layer.

The paper stores postings as compressed streams on disk and reports the
*data read size per query* (Figs. 7/9). We reproduce that metric with a
classic varbyte (VB) codec plus zigzag/delta transforms, implemented as
vectorized numpy (no per-value Python loops) so that the Idx1 baseline —
which decodes millions of postings per query — runs at C speed.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "varbyte_encode",
    "varbyte_decode",
    "zigzag_encode",
    "zigzag_decode",
    "delta_encode",
    "delta_decode",
]

_MAX_VB_BYTES = 10  # enough for uint64


def varbyte_encode(values: np.ndarray) -> bytes:
    """Encode an array of unsigned integers with MSB-continuation varbyte.

    Big-endian 7-bit groups; every byte except the last of a value has the
    high bit set. Fully vectorized.
    """
    v = np.ascontiguousarray(values, dtype=np.uint64)
    if v.size == 0:
        return b""
    nb = np.ones(v.size, np.int64)
    for k in range(1, _MAX_VB_BYTES):
        nb += (v >= np.uint64(1) << np.uint64(7 * k)).astype(np.int64)
    ends = np.cumsum(nb)
    total = int(ends[-1])
    starts = ends - nb
    owner = np.repeat(np.arange(v.size, dtype=np.int64), nb)
    offset_in = np.arange(total, dtype=np.int64) - starts[owner]
    shift = ((nb[owner] - 1 - offset_in) * 7).astype(np.uint64)
    byte = ((v[owner] >> shift) & np.uint64(0x7F)).astype(np.uint8)
    cont = (offset_in < nb[owner] - 1).astype(np.uint8) << 7
    return (byte | cont).tobytes()


def varbyte_decode(buf: bytes | np.ndarray) -> np.ndarray:
    """Decode a varbyte stream back to uint64 values. Vectorized by
    grouping values by their byte count (<= _MAX_VB_BYTES passes)."""
    b = np.frombuffer(buf, np.uint8) if not isinstance(buf, np.ndarray) else buf
    if b.size == 0:
        return np.zeros(0, np.uint64)
    is_last = (b & 0x80) == 0
    ends = np.nonzero(is_last)[0]
    n = ends.size
    starts = np.empty(n, np.int64)
    starts[0] = 0
    starts[1:] = ends[:-1] + 1
    nb = ends - starts + 1
    vals = np.zeros(n, np.uint64)
    payload = (b & 0x7F).astype(np.uint64)
    max_nb = int(nb.max())
    for k in range(1, max_nb + 1):
        sel = np.nonzero(nb == k)[0]
        if sel.size == 0:
            continue
        s = starts[sel]
        acc = np.zeros(sel.size, np.uint64)
        for j in range(k):
            acc = (acc << np.uint64(7)) | payload[s + j]
        vals[sel] = acc
    return vals


def zigzag_encode(values: np.ndarray) -> np.ndarray:
    """Map signed -> unsigned: 0,-1,1,-2,... -> 0,1,2,3,..."""
    v = np.asarray(values, dtype=np.int64)
    return ((v << 1) ^ (v >> 63)).astype(np.uint64)


def zigzag_decode(values: np.ndarray) -> np.ndarray:
    v = np.asarray(values, dtype=np.uint64)
    return ((v >> np.uint64(1)).astype(np.int64)) ^ -((v & np.uint64(1)).astype(np.int64))


def delta_encode(values: np.ndarray) -> np.ndarray:
    """First-order delta; first element kept absolute. Input must be
    non-decreasing for unsigned round-trip (use zigzag otherwise)."""
    v = np.asarray(values, dtype=np.int64)
    out = np.empty_like(v)
    if v.size == 0:
        return out.astype(np.uint64)
    out[0] = v[0]
    np.subtract(v[1:], v[:-1], out=out[1:])
    return out.astype(np.uint64)


def delta_decode(values: np.ndarray) -> np.ndarray:
    v = np.asarray(values, dtype=np.uint64).astype(np.int64)
    return np.cumsum(v)
