"""The search algorithm (paper §2) for all query types QT1-QT5, plus the
ordinary-inverted-file baseline engine (Idx1).

Result records are (ID, P, E, R) — document, fragment start/end, relevance
— exactly the paper's sub-query result shape (§2.1). Relevance
R = Σ_lemma idf(lemma) / (1 + span_excess) (the paper does not specify R;
ours is monotone in proximity, see DESIGN.md §9).

Match semantics (uniform across engines so they can be cross-validated):
a fragment matches a sub-query if there is an assignment of one position
per query lemma occurrence (distinct positions for repeated lemmas) such
that every assigned position lies within MaxDistance of the *anchor*
lemma's position. The anchor rule is the QT1 key-selection rule (most
frequent lemma = smallest FL-number), applied uniformly.

Engines:
* ``InvertedIndexEngine`` — Idx1: every lemma through its full ordinary
  posting list. In bulk (vectorized) mode, because a 2008-faithful
  per-posting loop would be unfairly slow to the baseline; this makes our
  reported speedups conservative.
* ``ProximitySearchEngine`` — Idx2..4: QT1 via (f,s,t), QT2 via (w,v),
  QT3/QT4 via ordinary (+ (w,v)) skipping NSW, QT5 via NSW records.
  QT1 supports equalize_mode "heap" (paper §2.3), "basic" ([10]) and
  "bulk" (vectorized; mirrors the TPU engine).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.equalize import EqualizeState, PostingIterator, equalize_basic
from repro.core.index_builder import ProximityIndex
from repro.core.lexicon import Lexicon, UNKNOWN_FL
from repro.core.postings import ByteMeter
from repro.core.query import (
    QueryType,
    SubQuery,
    build_subqueries,
    qt34_plan,
    qt5_plan,
    select_fst_keys,
    select_wv_keys,
)


@dataclass
class QueryStats:
    postings: int = 0
    bytes_read: int = 0
    seconds: float = 0.0
    n_results: int = 0


@dataclass
class Matches:
    """Columnar (ID, P, E, R) result records."""

    doc: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    start: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    end: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    score: np.ndarray = field(default_factory=lambda: np.zeros(0, np.float64))

    @property
    def size(self) -> int:
        return int(self.doc.size)

    @staticmethod
    def concat(parts: list["Matches"]) -> "Matches":
        parts = [p for p in parts if p.size]
        if not parts:
            return Matches()
        return Matches(
            np.concatenate([p.doc for p in parts]),
            np.concatenate([p.start for p in parts]),
            np.concatenate([p.end for p in parts]),
            np.concatenate([p.score for p in parts]),
        )

    def dedup_topk(self, k: int | None = None) -> "Matches":
        if self.size == 0:
            return self
        order = np.lexsort((-self.score, self.end, self.start, self.doc))
        d, s, e, sc = self.doc[order], self.start[order], self.end[order], self.score[order]
        first = np.ones(d.size, bool)
        first[1:] = (d[1:] != d[:-1]) | (s[1:] != s[:-1]) | (e[1:] != e[:-1])
        d, s, e, sc = d[first], s[first], e[first], sc[first]
        rank = np.argsort(-sc, kind="stable")
        if k is not None:
            rank = rank[:k]
        return Matches(d[rank], s[rank], e[rank], sc[rank])


def _span_scores(idf_sum: float, start: np.ndarray, end: np.ndarray, m: int) -> np.ndarray:
    excess = np.maximum((end - start) - (m - 1), 0)
    return idf_sum / (1.0 + excess)


def _nearest_r(
    g_sorted: np.ndarray, centers: np.ndarray, d: int, r: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """For each center, find the r nearest *distinct* values of g_sorted
    within distance d. Returns (matched, min_chosen, max_chosen).
    Vectorized: examines the 2r candidates adjacent to the insertion point.
    """
    n = centers.size
    if g_sorted.size == 0 or n == 0:
        z = np.zeros(n, np.int64)
        return np.zeros(n, bool), z, z
    idx = np.searchsorted(g_sorted, centers)
    cols = []
    for j in range(1, r + 1):
        cols.append(idx - j)  # predecessors
        cols.append(idx + (j - 1))  # successors
    cand_idx = np.stack(cols, axis=1)
    valid = (cand_idx >= 0) & (cand_idx < g_sorted.size)
    cand = np.where(valid, g_sorted[np.clip(cand_idx, 0, g_sorted.size - 1)], 0)
    dist = np.abs(cand - centers[:, None]).astype(np.float64)
    dist[~valid] = np.inf
    dist[dist > d] = np.inf
    order = np.argsort(dist, axis=1)[:, :r]
    rowi = np.arange(n)[:, None]
    chosen_dist = np.take_along_axis(dist, order, axis=1)
    matched = np.isfinite(chosen_dist[:, r - 1])
    chosen = np.take_along_axis(cand, order, axis=1)
    chosen = np.where(np.isfinite(chosen_dist), chosen, centers[:, None])
    return matched, chosen.min(axis=1), chosen.max(axis=1)


class _BaseEngine:
    def __init__(self, index: ProximityIndex, top_k: int = 100):
        self.index = index
        self.lex: Lexicon = index.lexicon
        self.top_k = top_k
        d = index.max_distance
        max_len = int(index.doc_lengths.max()) if index.doc_lengths is not None and index.doc_lengths.size else 1
        self.stride = np.int64(max_len + d + 2)

    def _g(self, docs: np.ndarray, pos: np.ndarray) -> np.ndarray:
        return docs.astype(np.int64) * self.stride + pos.astype(np.int64)

    def _split_g(self, g: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        return g // self.stride, g % self.stride

    def _multiplicities(self, lemma_ids: list[int]) -> dict[int, int]:
        mult: dict[int, int] = {}
        for l in lemma_ids:
            mult[l] = mult.get(l, 0) + 1
        return mult

    def _window_match(
        self,
        anchor_g: np.ndarray,
        others: list[tuple[np.ndarray, int]],
        d: int,
        idf_sum: float,
        m: int,
    ) -> Matches:
        """Vectorized matcher: anchor occurrences x (sorted g array, needed
        multiplicity) constraints. Used by Idx1, QT3, QT4 and parts of QT5."""
        if anchor_g.size == 0:
            return Matches()
        ok = np.ones(anchor_g.size, bool)
        lo = anchor_g.copy()
        hi = anchor_g.copy()
        for g_sorted, r in others:
            matched, mn, mx = _nearest_r(g_sorted, anchor_g, d, r)
            ok &= matched
            lo = np.minimum(lo, np.where(matched, mn, lo))
            hi = np.maximum(hi, np.where(matched, mx, hi))
        sel = np.nonzero(ok)[0]
        if sel.size == 0:
            return Matches()
        doc, start = self._split_g(lo[sel])
        doc2, end = self._split_g(hi[sel])
        score = _span_scores(idf_sum, start, end, m)
        return Matches(doc, start, end, score)


class InvertedIndexEngine(_BaseEngine):
    """Idx1 baseline: ordinary inverted file only, no NSW/(w,v)/(f,s,t)."""

    def search_sub(self, sub: SubQuery, meter: ByteMeter) -> Matches:
        ids = sub.lemma_ids
        if any(l == UNKNOWN_FL for l in ids):
            return Matches()
        mult = self._multiplicities(ids)
        uniq = sorted(mult)
        # read full posting lists (the baseline's cost — paper Fig. 6/7)
        lists = {}
        for l in uniq:
            docs, pos = self.index.read_ordinary(l, meter)
            if docs.size == 0:
                return Matches()
            lists[l] = self._g(docs, pos)
        anchor = uniq[0]  # most frequent lemma (smallest FL-number)
        anchor_g = lists[anchor]
        others = []
        a_r = mult[anchor] - 1
        if a_r > 0:
            others.append((anchor_g, a_r + 1))  # r+1 within d incl. itself
        for l in uniq:
            if l != anchor:
                others.append((lists[l], mult[l]))
        idf_sum = sum(self.lex.idf(l) for l in ids)
        m = self._window_match(anchor_g, others, self.index.max_distance, idf_sum, len(ids))
        return m

    def search_ids(self, lemma_ids: list[int]) -> tuple[Matches, QueryStats]:
        meter = ByteMeter()
        t0 = time.perf_counter()
        sub = SubQuery(lemma_ids=list(lemma_ids), qtype=QueryType.QT1)
        res = self.search_sub(sub, meter).dedup_topk(self.top_k)
        dt = time.perf_counter() - t0
        return res, QueryStats(meter.postings_read, meter.bytes_read, dt, res.size)


class ProximitySearchEngine(_BaseEngine):
    """The paper's engine over Idx2..Idx4 (ordinary+NSW, (w,v), (f,s,t))."""

    def __init__(self, index: ProximityIndex, top_k: int = 100, equalize_mode: str = "heap"):
        super().__init__(index, top_k)
        assert equalize_mode in ("heap", "basic", "bulk")
        self.equalize_mode = equalize_mode

    # ---------------- QT1: three-component keys -------------------------
    def _qt1(self, sub: SubQuery, meter: ByteMeter) -> Matches:
        ids = sub.lemma_ids
        if len(ids) < 3:
            # degenerate short queries: fall back to ordinary-index search
            return self._ordinary_window(ids, meter)
        if len(ids) > self.index.max_distance:
            # paper §4: queries longer than MaxDistance are split into parts
            parts = [ids[i : i + self.index.max_distance] for i in range(0, len(ids), self.index.max_distance)]
            return Matches.concat([self._qt1(SubQuery(p, QueryType.QT1), meter) for p in parts if len(p) >= 1])
        _, keys = select_fst_keys(ids)
        key_cols = []
        for key in keys:
            if self.index.fst is None or key not in self.index.fst:
                return Matches()
            docs, pf, o1, o2 = self.index.read_fst(key, meter)
            key_cols.append((docs, pf, o1, o2))
        idf_sum = sum(self.lex.idf(l) for l in ids)
        if self.equalize_mode == "bulk":
            return self._qt1_bulk(key_cols, idf_sum, len(ids))
        return self._qt1_iter(key_cols, idf_sum, len(ids))

    def _qt1_bulk(self, key_cols, idf_sum: float, m: int) -> Matches:
        """Vectorized join on (doc, P_f) across keys — mirrors the TPU path."""
        g0 = None
        lo = hi = None
        for docs, pf, o1, o2 in key_cols:
            g = self._g(docs, pf)
            klo = pf + np.minimum(np.minimum(o1, o2), 0)
            khi = pf + np.maximum(np.maximum(o1, o2), 0)
            if g0 is None:
                g0, lo, hi = g, klo, khi
            else:
                common, ia, ib = np.intersect1d(g0, g, return_indices=True)
                g0 = common
                lo = np.minimum(lo[ia], klo[ib])
                hi = np.maximum(hi[ia], khi[ib])
            if g0.size == 0:
                return Matches()
        doc = g0 // self.stride
        return Matches(doc, lo, hi, _span_scores(idf_sum, lo, hi, m))

    def _qt1_iter(self, key_cols, idf_sum: float, m: int) -> Matches:
        """Paper §2.2-2.3: iterators + Equalize (heap or basic), then per-
        document intersection on P_f."""
        iters = [
            PostingIterator(docs, pf, payload=(o1, o2))
            for docs, pf, o1, o2 in key_cols
        ]
        state = EqualizeState(iters) if self.equalize_mode == "heap" else None
        out: list[Matches] = []
        while True:
            if state is not None:
                doc = state.equalize()
            else:
                doc = equalize_basic(iters)
            if doc is None:
                break
            # in-document join on P_f
            pf0 = None
            lo = hi = None
            for it in iters:
                _, sl = it.doc_slice()
                pf = it.positions[sl]
                o1, o2 = it.payload[0][sl], it.payload[1][sl]
                klo = pf + np.minimum(np.minimum(o1, o2), 0)
                khi = pf + np.maximum(np.maximum(o1, o2), 0)
                if pf0 is None:
                    pf0, lo, hi = pf, klo, khi
                else:
                    common, ia, ib = np.intersect1d(pf0, pf, return_indices=True)
                    pf0 = common
                    lo = np.minimum(lo[ia], klo[ib])
                    hi = np.maximum(hi[ia], khi[ib])
            if pf0 is not None and pf0.size:
                docs_arr = np.full(pf0.size, doc, np.int64)
                out.append(
                    Matches(docs_arr, lo, hi, _span_scores(idf_sum, lo, hi, m))
                )
            if state is not None:
                state.advance_all_past_doc()
            else:
                for it in iters:
                    if not it.exhausted and it.value_id == doc:
                        it.advance_past_doc()
        return Matches.concat(out)

    # ---------------- QT2: two-component keys ----------------------------
    def _qt2(self, sub: SubQuery, meter: ByteMeter) -> Matches:
        ids = sub.lemma_ids
        keys = select_wv_keys(ids)
        d = self.index.max_distance
        pair_items = []  # (sorted start g, aligned end g)
        for key in keys:
            if self.index.wv is None or key not in self.index.wv:
                return Matches()
            docs, pw, off = self.index.read_wv(key, meter)
            ga = self._g(docs, pw)
            gb = ga + off
            lo = np.minimum(ga, gb)
            hi = np.maximum(ga, gb)
            order = np.argsort(lo, kind="stable")
            pair_items.append((lo[order], hi[order]))
        idf_sum = sum(self.lex.idf(l) for l in ids)
        return self._join_intervals(pair_items, d, idf_sum, len(ids))

    def _join_intervals(self, items, d: int, idf_sum: float, m: int) -> Matches:
        """Anchor on the sparsest interval list; for every anchor interval
        pick the nearest interval of each other list whose start is within
        2*MaxDistance; all chosen intervals merge into the fragment."""
        order = np.argsort([it[0].size for it in items])
        items = [items[i] for i in order]
        a_lo, a_hi = items[0]
        ok = np.ones(a_lo.size, bool)
        lo, hi = a_lo.copy(), a_hi.copy()
        for b_lo, b_hi in items[1:]:
            matched, mn, _ = _nearest_r(b_lo, a_lo, 2 * d, 1)
            # recover the matched interval's end via searchsorted on starts
            j = np.searchsorted(b_lo, mn)
            j = np.clip(j, 0, b_lo.size - 1)
            ok &= matched
            lo = np.minimum(lo, np.where(matched, mn, lo))
            hi = np.maximum(hi, np.where(matched, b_hi[j], hi))
        sel = np.nonzero(ok)[0]
        if sel.size == 0:
            return Matches()
        doc, start = self._split_g(lo[sel])
        _, end = self._split_g(hi[sel])
        return Matches(doc, start, end, _span_scores(idf_sum, start, end, m))

    # ---------------- QT3/QT4: ordinary index, NSW skipped ---------------
    def _ordinary_window(self, ids: list[int], meter: ByteMeter) -> Matches:
        """Ordinary-index window scan (QT3/QT4 and the short-QT1
        fallback): every lemma through its ordinary posting list,
        r-nearest-windowed around the anchor. Consumes the shared
        ``query.qt34_plan`` — the same decomposition the device packer
        (``jax_search.pack_qt34_batch``) and the serving router use — so
        the scalar and compiled paths cannot drift (DESIGN.md §13)."""
        anchor, other_plan, _ = qt34_plan(self.index, ids)
        a_docs, a_pos = self.index.read_ordinary(anchor, meter)
        if a_docs.size == 0:
            return Matches()
        anchor_g = self._g(a_docs, a_pos)
        others = []
        for l, r in other_plan:
            if l == anchor:
                others.append((anchor_g, r))
                continue
            docs, pos = self.index.read_ordinary(l, meter)
            if docs.size == 0:
                return Matches()
            others.append((self._g(docs, pos), r))
        idf_sum = sum(self.lex.idf(l) for l in ids)
        return self._window_match(
            anchor_g, others, self.index.max_distance, idf_sum, len(ids)
        )

    def _qt3(self, sub: SubQuery, meter: ByteMeter) -> Matches:
        return self._ordinary_window(sub.lemma_ids, meter)

    def _qt4(self, sub: SubQuery, meter: ByteMeter) -> Matches:
        return self._ordinary_window(sub.lemma_ids, meter)

    # ---------------- QT5: NSW records ------------------------------------
    def _qt5(self, sub: SubQuery, meter: ByteMeter) -> Matches:
        ids = sub.lemma_ids
        d = self.index.max_distance
        # anchor / constraint selection is shared with the compiled serve
        # path (query.qt5_plan) so the two engines cannot drift: anchor =
        # the rarest non-stop lemma (deterministic tie-break by id)
        plan = qt5_plan(self.index, ids)
        if plan is None:
            return Matches()
        anchor, other_plan, stops, _ = plan
        a_docs, a_pos = self.index.read_ordinary(anchor, meter)
        if a_docs.size == 0:
            return Matches()
        a_g = self._g(a_docs, a_pos)
        # other non-stop lemmas: ordinary window around the anchor
        others = []
        for l, r in other_plan:
            if l == anchor:
                others.append((a_g, r))
                continue
            docs, pos = self.index.read_ordinary(l, meter)
            if docs.size == 0:
                return Matches()
            others.append((self._g(docs, pos), r))
        ok = np.ones(a_g.size, bool)
        lo = a_g.copy()
        hi = a_g.copy()
        for g_sorted, r in others:
            matched, mn, mx = _nearest_r(g_sorted, a_g, d, r)
            ok &= matched
            lo = np.minimum(lo, np.where(matched, mn, lo))
            hi = np.maximum(hi, np.where(matched, mx, hi))
        # stop lemmas: resolved from the anchor's NSW records — the paper's
        # point: no stop-lemma posting list is ever read.
        rows, fls, offs = self.index.nsw.read(anchor, meter)
        keep = np.abs(offs) <= d
        rows, fls, offs = rows[keep], fls[keep], offs[keep]
        for sid, r in stops:
            sel = fls == sid
            r_rows = rows[sel]
            r_offs = offs[sel]
            cnt = np.bincount(r_rows, minlength=a_g.size)
            ok &= cnt >= r
            # fragment extension: nearest offsets per row
            order = np.lexsort((np.abs(r_offs), r_rows))
            rr, ro = r_rows[order], r_offs[order]
            first = np.ones(rr.size, bool)
            first[1:] = rr[1:] != rr[:-1]
            ext = np.zeros(a_g.size, np.int64)
            ext[rr[first]] = ro[first]
            lo = np.minimum(lo, a_g + np.minimum(ext, 0))
            hi = np.maximum(hi, a_g + np.maximum(ext, 0))
        sel = np.nonzero(ok)[0]
        if sel.size == 0:
            return Matches()
        doc, start = self._split_g(lo[sel])
        _, end = self._split_g(hi[sel])
        idf_sum = sum(self.lex.idf(l) for l in ids)
        return Matches(doc, start, end, _span_scores(idf_sum, start, end, len(ids)))

    # ---------------- dispatch -------------------------------------------
    def search_sub(self, sub: SubQuery, meter: ByteMeter) -> Matches:
        if any(l == UNKNOWN_FL for l in sub.lemma_ids):
            return Matches()
        if sub.qtype == QueryType.QT1:
            return self._qt1(sub, meter)
        if sub.qtype == QueryType.QT2:
            return self._qt2(sub, meter)
        if sub.qtype == QueryType.QT3:
            return self._qt3(sub, meter)
        if sub.qtype == QueryType.QT4:
            return self._qt4(sub, meter)
        return self._qt5(sub, meter)

    def search_ids(self, lemma_ids: list[int]) -> tuple[Matches, QueryStats]:
        from repro.core.query import classify

        meter = ByteMeter()
        t0 = time.perf_counter()
        sub = SubQuery(lemma_ids=list(lemma_ids), qtype=classify(list(lemma_ids), self.lex))
        res = self.search_sub(sub, meter).dedup_topk(self.top_k)
        dt = time.perf_counter() - t0
        return res, QueryStats(meter.postings_read, meter.bytes_read, dt, res.size)

    def search(self, text: str) -> tuple[Matches, QueryStats]:
        """Full pipeline of Table 1: lemmatize -> sub-queries -> evaluate ->
        combine, sorted by relevance."""
        meter = ByteMeter()
        t0 = time.perf_counter()
        subs = build_subqueries(text, self.lex)
        parts = [self.search_sub(s, meter) for s in subs]
        res = Matches.concat(parts).dedup_topk(self.top_k)
        dt = time.perf_counter() - t0
        return res, QueryStats(meter.postings_read, meter.bytes_read, dt, res.size)
