"""NSW (near stop words) records — paper §1.2 (QT5 machinery).

For every occurrence of a frequently-used or ordinary lemma at position P,
the ordinary index carries a second stream with an *NSW record*: an encoded
list of all stop lemmas occurring within MaxDistance of P, with their
offsets. QT5 queries resolve their stop lemmas from these records instead
of reading the (huge) stop-lemma posting lists.

Record format (varbyte):  [count, (fl_delta, zigzag(offset)) * count]
with neighbors sorted by (fl, offset); fl delta-encoded within the record.
"""

from __future__ import annotations

import numpy as np

from repro.core.codecs import varbyte_decode, varbyte_encode, zigzag_decode, zigzag_encode


def encode_nsw_stream(record_rows: np.ndarray, record_fls: np.ndarray, record_offs: np.ndarray, n_records: int) -> bytes:
    """Encode NSW records for one lemma's posting list.

    record_rows: (E,) posting ordinal each neighbor belongs to (sorted asc);
    record_fls / record_offs: stop-lemma FL numbers and signed offsets.
    """
    order = np.lexsort((record_offs, record_fls, record_rows))
    rows = record_rows[order]
    fls = record_fls[order].astype(np.int64)
    offs = record_offs[order].astype(np.int64)
    counts = np.bincount(rows, minlength=n_records).astype(np.int64)
    # delta-encode fl within each record
    fl_delta = fls.copy()
    if fls.size:
        first_of_record = np.zeros(fls.size, bool)
        starts = np.cumsum(np.concatenate([[0], counts[:-1]]))
        starts = starts[counts > 0]
        first_of_record[starts] = True
        fl_delta[1:] = np.where(first_of_record[1:], fls[1:], fls[1:] - fls[:-1])
    # interleave: counts then per-record payload — emit as single stream:
    # [c_0, payload_0..., c_1, payload_1, ...]
    total = n_records + 2 * fls.size
    out = np.empty(total, np.uint64)
    # compute write offsets
    rec_sizes = 1 + 2 * counts
    rec_starts = np.cumsum(np.concatenate([[0], rec_sizes[:-1]]))
    out[rec_starts] = counts.astype(np.uint64)
    if fls.size:
        payload_base = np.repeat(rec_starts + 1, counts)
        within = np.arange(fls.size) - np.repeat(np.cumsum(np.concatenate([[0], counts[:-1]])), counts)
        out[payload_base + 2 * within] = np.where(fl_delta >= 0, fl_delta, 0).astype(np.uint64)  # fl deltas are >=0 by sort
        out[payload_base + 2 * within + 1] = zigzag_encode(offs)
    return varbyte_encode(out)


def decode_nsw_stream(blob: bytes, n_records: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Decode -> (record_rows, fls, offsets), neighbors sorted by record."""
    vals = varbyte_decode(blob)
    if vals.size == 0:
        return (np.zeros(0, np.int64),) * 3
    rows_l, fls_l, offs_l = [], [], []
    i = 0
    rec = 0
    vals_i = vals.astype(np.int64)
    while rec < n_records and i < vals.size:
        c = int(vals_i[i])
        i += 1
        if c:
            payload = vals_i[i : i + 2 * c]
            fl = np.cumsum(payload[0::2])
            off = zigzag_decode(payload[1::2].astype(np.uint64))
            rows_l.append(np.full(c, rec, np.int64))
            fls_l.append(fl)
            offs_l.append(off)
            i += 2 * c
        rec += 1
    if not rows_l:
        return (np.zeros(0, np.int64),) * 3
    return np.concatenate(rows_l), np.concatenate(fls_l), np.concatenate(offs_l)


def build_nsw_neighbors(
    gpos_all_stop: np.ndarray,
    stop_lemma_ids: np.ndarray,
    anchor_gpos: np.ndarray,
    max_distance: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized neighbor collection.

    gpos_all_stop: sorted global positions of stop-lemma occurrences;
    stop_lemma_ids: their lemma ids (FL numbers);
    anchor_gpos: global positions of the non-stop postings (any order).

    Returns (anchor_row, fl, offset) triples. Global positions must embed
    document gaps > max_distance so windows never cross documents.
    """
    rows_l, fls_l, offs_l = [], [], []
    lo = np.searchsorted(gpos_all_stop, anchor_gpos - max_distance, side="left")
    hi = np.searchsorted(gpos_all_stop, anchor_gpos + max_distance, side="right")
    counts = hi - lo
    if counts.sum() == 0:
        return (np.zeros(0, np.int64),) * 3
    rows = np.repeat(np.arange(anchor_gpos.size, dtype=np.int64), counts)
    # vectorized segmented arange: take[k] = lo[row(k)] + (k - segment_start(k))
    seg_off = np.repeat(np.cumsum(counts) - counts, counts)
    take = np.repeat(lo, counts) + (np.arange(int(counts.sum()), dtype=np.int64) - seg_off)
    fls = stop_lemma_ids[take].astype(np.int64)
    offs = gpos_all_stop[take].astype(np.int64) - anchor_gpos[rows]
    keep = offs != 0
    return rows[keep], fls[keep], offs[keep]
