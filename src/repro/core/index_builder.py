"""Index construction (paper §1.2): ordinary index (+NSW streams),
two-component (w,v) index, three-component (f,s,t) index.

Everything is vectorized numpy; per-token Python loops are avoided so the
d=9 build over millions of tokens stays tractable (the paper notes index
creation cost rises with MaxDistance — the (f,s,t) index emits
C(2d,2) candidate pairs per stop-lemma occurrence).

Conventions
-----------
* lemma id == 0-based FL-number (see lexicon.py);
* *global positions* `g = doc_start[doc] + pos` with inter-document gaps
  > MaxDistance so proximity windows never straddle documents;
* (f,s,t) keys: s,t canonically ordered by FL-number (s <= t); a key with
  s == t requires two *distinct* occurrences ("who ... who" semantics);
* (f,s,t) postings: one per (key, doc, P_f), keeping the nearest-offset
  witness pair: (doc, P_f, zz(off_s), zz(off_t));
* (w,v) keys: both lemmas non-stop, at least one frequently-used,
  canonically ordered; postings (doc, P_w, zz(P_v - P_w)).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.lexicon import Lexicon
from repro.core.nsw import build_nsw_neighbors, decode_nsw_stream, encode_nsw_stream
from repro.core.postings import BlobStore, ByteMeter, PostingStore
from repro.data.corpus import TokenTable

_K_SLOTS = 2  # max lemma alternatives tracked per token position


@dataclass
class NSWStreams:
    """Per-lemma NSW record streams, lazily varbyte-encoded."""

    neighbor_rows: np.ndarray  # (E,) global posting ordinal (ordinary order)
    neighbor_fls: np.ndarray
    neighbor_offs: np.ndarray
    lemma_row_start: dict  # lemma -> (start_row, end_row) in ordinary order
    _blobs: dict = field(default_factory=dict, repr=False)

    def blob(self, lemma: int) -> bytes:
        b = self._blobs.get(lemma)
        if b is None:
            se = self.lemma_row_start.get(lemma)
            if se is None:
                return b""
            s, e = se
            lo = np.searchsorted(self.neighbor_rows, s, side="left")
            hi = np.searchsorted(self.neighbor_rows, e, side="left")
            b = encode_nsw_stream(
                self.neighbor_rows[lo:hi] - s,
                self.neighbor_fls[lo:hi],
                self.neighbor_offs[lo:hi],
                e - s,
            )
            self._blobs[lemma] = b
        return b

    def read(self, lemma: int, meter: ByteMeter | None = None):
        se = self.lemma_row_start.get(lemma)
        if se is None:
            return (np.zeros(0, np.int64),) * 3
        blob = self.blob(lemma)
        if meter is not None:
            meter.add(len(blob), 0)
        return decode_nsw_stream(blob, se[1] - se[0])

    def records(self, lemma: int):
        """Unencoded (rows, fls, offs) for one lemma, normalized to the
        decode order (row, fl, off) — the cheap path for segment merges."""
        se = self.lemma_row_start.get(lemma)
        if se is None:
            return (np.zeros(0, np.int64),) * 3
        s, e = se
        lo = np.searchsorted(self.neighbor_rows, s, side="left")
        hi = np.searchsorted(self.neighbor_rows, e, side="left")
        rows = self.neighbor_rows[lo:hi] - s
        fls = self.neighbor_fls[lo:hi]
        offs = self.neighbor_offs[lo:hi]
        order = np.lexsort((offs, fls, rows))
        return rows[order].astype(np.int64), fls[order].astype(np.int64), offs[order].astype(np.int64)


@dataclass
class ProximityIndex:
    """The paper's composite index (Idx2..Idx4); with the additional
    structures disabled it degrades to the ordinary inverted file (Idx1)."""

    lexicon: Lexicon
    max_distance: int
    ordinary: PostingStore  # lemma -> (doc, pos)
    nsw: NSWStreams | None
    wv: PostingStore | None  # (w,v) -> (doc, p_w, zz_off)
    fst: PostingStore | None  # (f,s,t) -> (doc, p_f, zz_off_s, zz_off_t)
    doc_lengths: np.ndarray | None = None

    @property
    def has_additional(self) -> bool:
        return self.fst is not None

    def read_ordinary(self, lemma: int, meter: ByteMeter | None = None):
        cols = self.ordinary.read(lemma, meter)
        return cols[0], cols[1]

    def read_wv(self, key, meter: ByteMeter | None = None):
        from repro.core.codecs import zigzag_decode

        cols = self.wv.read(key, meter)
        return cols[0], cols[1], zigzag_decode(cols[2].astype(np.uint64))

    def read_fst(self, key, meter: ByteMeter | None = None):
        from repro.core.codecs import zigzag_decode

        cols = self.fst.read(key, meter)
        return (
            cols[0],
            cols[1],
            zigzag_decode(cols[2].astype(np.uint64)),
            zigzag_decode(cols[3].astype(np.uint64)),
        )

    def size_report(self) -> dict:
        rep = {"ordinary_bytes": self.ordinary.total_bytes()}
        if self.wv is not None:
            rep["wv_bytes"] = self.wv.total_bytes()
            rep["wv_keys"] = len(self.wv.counts)
        if self.fst is not None:
            rep["fst_bytes"] = self.fst.total_bytes()
            rep["fst_keys"] = len(self.fst.counts)
        return rep


def _group_store(store: PostingStore, keys_sorted: np.ndarray, cols: list[np.ndarray], tuple_keys: bool) -> None:
    """Bulk-register per-key row spans. keys_sorted is (n, kdim) or (n,).

    Registration is O(n keys) dict work via ``PostingStore.put_bulk`` —
    per-key column slices are cut lazily on first read. This is the seal
    hot path: a memtable seal's latency is dominated by grouping the
    (w,v)/(f,s,t) row streams into ~10^5 keys (DESIGN.md §18)."""
    if keys_sorted.size == 0:
        return
    if keys_sorted.ndim == 1:
        change = np.nonzero(np.diff(keys_sorted))[0] + 1
    else:
        change = np.nonzero(np.any(np.diff(keys_sorted, axis=0) != 0, axis=1))[0] + 1
    starts = np.concatenate([[0], change])
    ends = np.concatenate([change, [keys_sorted.shape[0]]])
    store.put_bulk(keys_sorted[starts], starts, ends, cols)


def _global_positions(table: TokenTable, max_distance: int):
    gap = max_distance + 1
    starts = np.zeros(table.n_docs + 1, np.int64)
    np.cumsum(table.doc_lengths.astype(np.int64) + gap, out=starts[1:])
    g = starts[table.doc_ids] + table.positions.astype(np.int64) + gap  # margin at front
    return g, int(starts[-1] + gap)


def build_index(
    table: TokenTable,
    lexicon: Lexicon,
    max_distance: int = 5,
    build_wv: bool = True,
    build_fst: bool = True,
    build_nsw: bool = True,
) -> ProximityIndex:
    """Single-shot build == one sealed segment of the incremental path.

    The numeric construction lives in :func:`build_segment_index`; this
    canonical entry point routes through ``repro.index.MemSegment`` so the
    static build and the segmented/LSM build (repro.index) share one code
    path and cannot drift apart."""
    from repro.index.segment import MemSegment

    mem = MemSegment(
        lexicon,
        max_distance=max_distance,
        build_wv=build_wv,
        build_fst=build_fst,
        build_nsw=build_nsw,
    )
    mem.add_table(table)
    seg = mem.seal(segment_id=0)
    if seg is None:  # empty corpus: degenerate empty index
        return build_segment_index(table, lexicon, max_distance, build_wv, build_fst, build_nsw)
    return seg.index


def build_segment_index(
    table: TokenTable,
    lexicon: Lexicon,
    max_distance: int = 5,
    build_wv: bool = True,
    build_fst: bool = True,
    build_nsw: bool = True,
) -> ProximityIndex:
    """Build all four paper index structures for one corpus slice (a
    segment). Doc ids in `table` are segment-local."""
    t = table.sorted_copy()  # (doc, pos, lemma)
    sw = lexicon.sw_count
    fu_hi = lexicon.sw_count + lexicon.fu_count
    d = max_distance
    g, G = _global_positions(t, d)

    # ---- ordinary index: rows sorted by (lemma, doc, pos) ----------------
    ord_order = np.lexsort((t.positions, t.doc_ids, t.lemma_ids))
    o_lem = t.lemma_ids[ord_order]
    o_doc = t.doc_ids[ord_order].astype(np.int64)
    o_pos = t.positions[ord_order].astype(np.int64)
    ordinary = PostingStore(n_columns=2)
    _group_store(ordinary, o_lem, [o_doc, o_pos], tuple_keys=False)

    # ---- position -> lemma slots table ------------------------------------
    # (padded margins already guaranteed by the leading/ trailing gaps)
    pos_lem = np.full((G + d + 1, _K_SLOTS), -1, np.int32)
    # slot index: within-run ordinal of rows sharing (doc,pos); t is sorted
    same_as_prev = np.zeros(t.n_rows, bool)
    if t.n_rows > 1:
        same_as_prev[1:] = (t.doc_ids[1:] == t.doc_ids[:-1]) & (t.positions[1:] == t.positions[:-1])
    slot = np.zeros(t.n_rows, np.int64)
    run = 0
    # vectorized run ordinal: cumsum resetting at run starts
    cs = np.cumsum(same_as_prev.astype(np.int64))
    run_start_cs = np.where(~same_as_prev, cs, 0)
    np.maximum.accumulate(run_start_cs, out=run_start_cs)
    slot = cs - run_start_cs
    keep = slot < _K_SLOTS
    pos_lem[g[keep], slot[keep]] = t.lemma_ids[keep]

    stop_mask = t.lemma_ids < sw
    nsw_streams = None
    wv_store = None
    fst_store = None

    # ---- (f,s,t) three-component index ------------------------------------
    if build_fst:
        f_rows = np.nonzero(stop_mask)[0]
        gF = g[f_rows]
        f_lem = t.lemma_ids[f_rows].astype(np.int32)
        f_doc = t.doc_ids[f_rows].astype(np.int32)
        f_pos = t.positions[f_rows].astype(np.int32)
        offsets = [o for o in range(-d, d + 1) if o != 0]
        acc = {k: [] for k in ("f", "s", "t", "doc", "pos", "o1", "o2")}
        for i1 in range(len(offsets)):
            o1 = offsets[i1]
            s_slots = pos_lem[gF + o1]
            for i2 in range(i1 + 1, len(offsets)):
                o2 = offsets[i2]
                t_slots = pos_lem[gF + o2]
                for ks in range(_K_SLOTS):
                    s_c = s_slots[:, ks]
                    vs = (s_c >= 0) & (s_c < sw)
                    if not vs.any():
                        continue
                    for kt in range(_K_SLOTS):
                        t_c = t_slots[:, kt]
                        sel = np.nonzero(vs & (t_c >= 0) & (t_c < sw))[0]
                        if sel.size == 0:
                            continue
                        s_v, t_v = s_c[sel], t_c[sel]
                        swapmask = s_v > t_v
                        s_fin = np.where(swapmask, t_v, s_v)
                        t_fin = np.where(swapmask, s_v, t_v)
                        acc["f"].append(f_lem[sel])
                        acc["s"].append(s_fin)
                        acc["t"].append(t_fin)
                        acc["doc"].append(f_doc[sel])
                        acc["pos"].append(f_pos[sel])
                        acc["o1"].append(np.where(swapmask, np.int32(o2), np.int32(o1)))
                        acc["o2"].append(np.where(swapmask, np.int32(o1), np.int32(o2)))
        fst_store = PostingStore(n_columns=4)
        if acc["f"]:
            fa = np.concatenate(acc["f"])
            sa = np.concatenate(acc["s"])
            ta = np.concatenate(acc["t"])
            da = np.concatenate(acc["doc"])
            pa = np.concatenate(acc["pos"])
            o1a = np.concatenate(acc["o1"])
            o2a = np.concatenate(acc["o2"])
            cost = np.abs(o1a).astype(np.int32) + np.abs(o2a).astype(np.int32)
            order = np.lexsort((cost, pa, da, ta, sa, fa))
            fa, sa, ta, da, pa, o1a, o2a = (
                x[order] for x in (fa, sa, ta, da, pa, o1a, o2a)
            )
            # dedupe per (f,s,t,doc,pos): keep first (min cost)
            first = np.ones(fa.size, bool)
            first[1:] = (
                (fa[1:] != fa[:-1])
                | (sa[1:] != sa[:-1])
                | (ta[1:] != ta[:-1])
                | (da[1:] != da[:-1])
                | (pa[1:] != pa[:-1])
            )
            sel = np.nonzero(first)[0]
            from repro.core.codecs import zigzag_encode

            keys = np.stack([fa[sel], sa[sel], ta[sel]], axis=1)
            _group_store(
                fst_store,
                keys,
                [
                    da[sel].astype(np.int64),
                    pa[sel].astype(np.int64),
                    zigzag_encode(o1a[sel]),
                    zigzag_encode(o2a[sel]),
                ],
                tuple_keys=True,
            )

    # ---- (w,v) two-component index -----------------------------------------
    if build_wv:
        n_rows_idx = np.nonzero(~stop_mask)[0]
        gN = g[n_rows_idx]
        w_lem = t.lemma_ids[n_rows_idx].astype(np.int32)
        w_doc = t.doc_ids[n_rows_idx].astype(np.int32)
        w_pos = t.positions[n_rows_idx].astype(np.int32)
        acc2 = {k: [] for k in ("a", "b", "doc", "pos", "off")}
        for o in range(1, d + 1):
            v_slots = pos_lem[gN + o]
            for kv in range(_K_SLOTS):
                v_c = v_slots[:, kv]
                fu_ok = (w_lem < fu_hi) | (v_c < fu_hi)
                sel = np.nonzero((v_c >= sw) & fu_ok)[0]
                if sel.size == 0:
                    continue
                wv_, vv_ = w_lem[sel], v_c[sel]
                swapmask = wv_ > vv_
                a = np.where(swapmask, vv_, wv_)
                b = np.where(swapmask, wv_, vv_)
                p_a = np.where(swapmask, w_pos[sel] + o, w_pos[sel])
                off = np.where(swapmask, -o, o).astype(np.int32)
                acc2["a"].append(a)
                acc2["b"].append(b)
                acc2["doc"].append(w_doc[sel])
                acc2["pos"].append(p_a)
                acc2["off"].append(off)
        wv_store = PostingStore(n_columns=3)
        if acc2["a"]:
            aa = np.concatenate(acc2["a"])
            ba = np.concatenate(acc2["b"])
            da = np.concatenate(acc2["doc"])
            pa = np.concatenate(acc2["pos"])
            fa_off = np.concatenate(acc2["off"])
            order = np.lexsort((fa_off, pa, da, ba, aa))
            aa, ba, da, pa, fa_off = (x[order] for x in (aa, ba, da, pa, fa_off))
            first = np.ones(aa.size, bool)
            first[1:] = (
                (aa[1:] != aa[:-1])
                | (ba[1:] != ba[:-1])
                | (da[1:] != da[:-1])
                | (pa[1:] != pa[:-1])
                | (fa_off[1:] != fa_off[:-1])
            )
            sel = np.nonzero(first)[0]
            from repro.core.codecs import zigzag_encode

            keys = np.stack([aa[sel], ba[sel]], axis=1)
            _group_store(
                wv_store,
                keys,
                [da[sel].astype(np.int64), pa[sel].astype(np.int64), zigzag_encode(fa_off[sel])],
                tuple_keys=True,
            )

    # ---- NSW streams --------------------------------------------------------
    if build_nsw:
        stop_rows = np.nonzero(stop_mask)[0]
        g_stop = g[stop_rows]
        stop_order = np.argsort(g_stop, kind="stable")
        g_stop_sorted = g_stop[stop_order]
        stop_lem_sorted = t.lemma_ids[stop_rows][stop_order].astype(np.int64)
        nonstop_in_ord = np.nonzero(o_lem >= sw)[0]
        anchor_g = np.zeros(o_lem.size, np.int64)
        anchor_g = g[ord_order]
        rows, fls, offs = build_nsw_neighbors(
            g_stop_sorted, stop_lem_sorted, anchor_g[nonstop_in_ord], d
        )
        # map back to global ordinary row numbers
        rows = nonstop_in_ord[rows]
        order2 = np.argsort(rows, kind="stable")
        rows, fls, offs = rows[order2], fls[order2], offs[order2]
        # lemma -> row span in ordinary order
        lemma_row_start = {}
        if o_lem.size:
            change = np.nonzero(np.diff(o_lem))[0] + 1
            starts = np.concatenate([[0], change])
            ends = np.concatenate([change, [o_lem.size]])
            for s, e in zip(starts.tolist(), ends.tolist()):
                lem = int(o_lem[s])
                if lem >= sw:
                    lemma_row_start[lem] = (s, e)
        nsw_streams = NSWStreams(rows, fls, offs, lemma_row_start)

    return ProximityIndex(
        lexicon=lexicon,
        max_distance=d,
        ordinary=ordinary,
        nsw=nsw_streams,
        wv=wv_store,
        fst=fst_store,
        doc_lengths=t.doc_lengths,
    )
