"""Graph data pipeline: synthetic graph generators + the fanout neighbor
sampler required by the minibatch_lg shape (seeds=1024, fanout 15-10,
GraphSAGE-style layered sampling over a CSR adjacency)."""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class CSRGraph:
    indptr: np.ndarray  # (N+1,)
    indices: np.ndarray  # (E,)
    n_nodes: int

    @classmethod
    def random(cls, n_nodes: int, avg_degree: int, seed: int = 0) -> "CSRGraph":
        rng = np.random.default_rng(seed)
        # power-law-ish degrees (Zipf-bounded)
        deg = np.minimum(
            rng.zipf(1.6, n_nodes).astype(np.int64) + avg_degree // 2, 50 * avg_degree
        )
        deg = (deg * (avg_degree / max(deg.mean(), 1))).astype(np.int64)
        deg = np.maximum(deg, 1)
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.cumsum(deg, out=indptr[1:])
        indices = rng.integers(0, n_nodes, int(indptr[-1])).astype(np.int32)
        return cls(indptr, indices, n_nodes)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]


def sample_fanout_subgraph(
    graph: CSRGraph,
    seeds: np.ndarray,
    fanout: tuple,
    rng: np.random.Generator,
    pad_nodes: int,
    pad_edges: int,
):
    """Layered fanout sampling (GraphSAGE): hop h samples up to fanout[h]
    neighbors per frontier node. Returns a padded edge-list subgraph with
    relabelled node ids, masks marking real entries, and the seed mask.

    Vectorized: per hop, neighbor draws are a single gather of random
    offsets into the CSR index range of every frontier node."""
    node_ids = list(seeds.astype(np.int64))
    id_of = {int(v): i for i, v in enumerate(node_ids)}
    src_l, dst_l = [], []
    frontier = seeds.astype(np.int64)
    for f in fanout:
        if frontier.size == 0:
            break
        starts = graph.indptr[frontier]
        degs = graph.indptr[frontier + 1] - starts
        k = np.minimum(degs, f)
        total = int(k.sum())
        if total == 0:
            break
        owner = np.repeat(np.arange(frontier.size), k)
        # random offsets within each node's adjacency range
        u = rng.random(total)
        offs = (u * degs[owner]).astype(np.int64)
        nbrs = graph.indices[starts[owner] + offs].astype(np.int64)
        new_frontier = []
        for s_node, d_node in zip(frontier[owner].tolist(), nbrs.tolist()):
            if d_node not in id_of:
                id_of[d_node] = len(node_ids)
                node_ids.append(d_node)
                new_frontier.append(d_node)
            src_l.append(id_of[s_node])
            dst_l.append(id_of[d_node])
        frontier = np.array(new_frontier, np.int64)
    n_real = len(node_ids)
    e_real = len(src_l)
    if n_real > pad_nodes or e_real > pad_edges:
        raise ValueError(f"subgraph exceeds padding: {n_real}/{pad_nodes} nodes, {e_real}/{pad_edges} edges")
    src = np.zeros(pad_edges, np.int32)
    dst = np.zeros(pad_edges, np.int32)
    src[:e_real] = src_l
    dst[:e_real] = dst_l
    edge_mask = np.zeros(pad_edges, np.float32)
    edge_mask[:e_real] = 1.0
    node_mask = np.zeros(pad_nodes, np.float32)
    node_mask[:n_real] = 1.0
    nodes = np.zeros(pad_nodes, np.int64)
    nodes[:n_real] = node_ids
    seed_mask = np.zeros(pad_nodes, np.float32)
    seed_mask[: seeds.size] = 1.0  # seeds are the first node ids by construction
    return {
        "nodes": nodes,
        "src": src,
        "dst": dst,
        "edge_mask": edge_mask,
        "node_mask": node_mask,
        "seed_mask": seed_mask,
        "n_real_nodes": n_real,
        "n_real_edges": e_real,
    }


def minibatch_stream(
    graph: CSRGraph,
    feats: np.ndarray,
    targets: np.ndarray,
    batch_nodes: int,
    fanout: tuple,
    pad_nodes: int,
    pad_edges: int,
    seed: int = 0,
):
    """Infinite generator of sampled-training batches (minibatch_lg)."""
    rng = np.random.default_rng(seed)
    while True:
        seeds = rng.choice(graph.n_nodes, size=batch_nodes, replace=False)
        sub = sample_fanout_subgraph(graph, seeds, fanout, rng, pad_nodes, pad_edges)
        yield {
            "feats": feats[sub["nodes"]] * sub["node_mask"][:, None],
            "coords": rng.normal(size=(pad_nodes, 3)).astype(np.float32),
            "src": sub["src"],
            "dst": sub["dst"],
            "edge_mask": sub["edge_mask"],
            "node_mask": sub["node_mask"],
            "targets": targets[sub["nodes"]] * sub["node_mask"],
        }
