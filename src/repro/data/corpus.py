"""Synthetic Zipf corpus + token tables.

The paper's collection (71.5 GB, 195k documents of fiction/articles) is not
available offline, so experiments run on a synthetic corpus whose word
frequency distribution follows Zipf's law (paper §1, Fig. 1). Lemma ids are
frequency ranks *by construction*, which matches the Lexicon convention
(id == FL-number) and lets us plant the exact SWCount/FUCount regime.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.lexicon import Lexicon, DEFAULT_FU_COUNT, DEFAULT_SW_COUNT


@dataclass
class TokenTable:
    """Flat occurrence table: one row per (token position, lemma).

    Multi-lemma words contribute several rows with the same (doc, pos).
    Rows are sorted by (doc, pos, lemma).
    """

    doc_ids: np.ndarray  # int32 (T,)
    positions: np.ndarray  # int32 (T,) ordinal within document
    lemma_ids: np.ndarray  # int32 (T,)
    doc_lengths: np.ndarray  # int32 (n_docs,) in token positions

    @property
    def n_docs(self) -> int:
        return int(self.doc_lengths.size)

    @property
    def n_rows(self) -> int:
        return int(self.doc_ids.size)

    def sorted_copy(self) -> "TokenTable":
        order = np.lexsort((self.lemma_ids, self.positions, self.doc_ids))
        return TokenTable(
            self.doc_ids[order], self.positions[order], self.lemma_ids[order], self.doc_lengths
        )

    def to_doc_lists(self) -> list[list[int]]:
        """Per-document lemma-id lists in position order — the shape
        ``SegmentedIndex.add_document`` consumes. Single-lemma corpora
        only (multi-lemma positions would need the alternatives shape)."""
        order = np.lexsort((self.positions, self.doc_ids))
        docs, toks = self.doc_ids[order], self.lemma_ids[order]
        splits = np.searchsorted(docs, np.arange(1, self.n_docs))
        return [d.tolist() for d in np.split(toks, splits)]

    @classmethod
    def from_docs(cls, docs: list[np.ndarray]) -> "TokenTable":
        """docs: list of int lemma-id arrays (single lemma per position)."""
        lengths = np.array([len(d) for d in docs], np.int32)
        doc_ids = np.repeat(np.arange(len(docs), dtype=np.int32), lengths)
        positions = np.concatenate([np.arange(len(d), dtype=np.int32) for d in docs]) if docs else np.zeros(0, np.int32)
        lemma_ids = np.concatenate(docs).astype(np.int32) if docs else np.zeros(0, np.int32)
        return cls(doc_ids, positions, lemma_ids, lengths)

    @classmethod
    def from_lemmatized(cls, docs: list[list[list[int]]]) -> "TokenTable":
        """docs: per doc, per token position, a list of lemma ids."""
        d_l, p_l, l_l, lens = [], [], [], []
        for di, doc in enumerate(docs):
            lens.append(len(doc))
            for pi, alts in enumerate(doc):
                for lem in alts:
                    d_l.append(di)
                    p_l.append(pi)
                    l_l.append(lem)
        return cls(
            np.array(d_l, np.int32),
            np.array(p_l, np.int32),
            np.array(l_l, np.int32),
            np.array(lens, np.int32),
        )


def zipf_probs(vocab_size: int, alpha: float = 1.1, shift: float = 2.7) -> np.ndarray:
    """Zipf-Mandelbrot pmf over ranks 0..vocab_size-1."""
    ranks = np.arange(vocab_size, dtype=np.float64)
    w = 1.0 / np.power(ranks + shift, alpha)
    return w / w.sum()


def generate_corpus(
    n_docs: int = 2000,
    mean_doc_len: int = 200,
    vocab_size: int = 50_000,
    alpha: float = 1.1,
    seed: int = 0,
) -> tuple[TokenTable, Lexicon]:
    """Generate a Zipf corpus; returns (token table, lexicon).

    Lemma ids are re-ranked by *observed* frequency so the Lexicon id ==
    FL-number invariant holds exactly even at small corpus sizes.
    """
    rng = np.random.default_rng(seed)
    lengths = np.maximum(8, rng.poisson(mean_doc_len, n_docs)).astype(np.int64)
    total = int(lengths.sum())
    probs = zipf_probs(vocab_size, alpha)
    raw = rng.choice(vocab_size, size=total, p=probs).astype(np.int32)

    # re-rank by observed frequency (stable: ties broken by original id)
    counts = np.bincount(raw, minlength=vocab_size)
    order = np.lexsort((np.arange(vocab_size), -counts))  # observed rank -> raw id
    rank_of = np.empty(vocab_size, np.int32)
    rank_of[order] = np.arange(vocab_size, dtype=np.int32)
    tokens = rank_of[raw]

    doc_ids = np.repeat(np.arange(n_docs, dtype=np.int32), lengths)
    positions = np.concatenate([np.arange(n, dtype=np.int32) for n in lengths])
    table = TokenTable(doc_ids, positions, tokens, lengths.astype(np.int32))

    sorted_counts = counts[order]
    n_seen = int((sorted_counts > 0).sum())
    # doc freqs
    pair = doc_ids.astype(np.int64) * vocab_size + tokens
    uniq = np.unique(pair)
    dfs = np.bincount((uniq % vocab_size).astype(np.int64), minlength=vocab_size)
    lex = Lexicon.from_rank_counts(
        counts=sorted_counts[:n_seen],
        doc_freqs=dfs[:n_seen],
        n_docs=n_docs,
        sw_count=min(DEFAULT_SW_COUNT, n_seen // 3),
        fu_count=min(DEFAULT_FU_COUNT, n_seen // 3),
    )
    return table, lex


def sample_typed_queries(
    table: TokenTable,
    lex: Lexicon,
    n_queries: int,
    qtype: str = "qt1",
    min_len: int = 3,
    max_len: int = 5,
    window: int = 9,
    seed: int = 0,
) -> list[list[int]]:
    """Sample queries of one QT class from real co-occurrence windows
    (the query-log-derived shape of sample_stop_queries, generalized):

    * ``"qt1"`` — all stop lemmas;
    * ``"qt2"`` — all frequently used lemmas (the (w,v) serve path);
    * ``"qt3"`` — all ordinary lemmas (the ordinary-window serve path);
    * ``"qt4"`` — at least one frequently used and one ordinary lemma,
      no stop lemmas (the other ordinary-window query class);
    * ``"qt5"`` — at least one stop lemma plus non-stop lemmas (the NSW
      serve path)."""
    rng = np.random.default_rng(seed)
    sw = lex.sw_count
    fu_hi = sw + lex.fu_count
    preds = {
        "qt1": lambda l: l < sw,
        "qt2": lambda l: (l >= sw) & (l < fu_hi),
        "qt3": lambda l: l >= fu_hi,
    }
    # mixed classes draw one sub-pool from each side of the split:
    # qt4 = frequent + ordinary (seeded on frequent rows, the rarer
    # side of its split in a Zipf stream), qt5 = stop + non-stop
    split = {
        "qt4": (preds["qt2"], lambda l: l >= fu_hi),
        "qt5": (lambda l: l < sw, lambda l: l >= sw),
    }
    seed_pred = split[qtype][0] if qtype in split else preds[qtype]
    seed_rows = np.nonzero(seed_pred(table.lemma_ids))[0]
    queries: list[list[int]] = []
    guard = 0
    while len(queries) < n_queries and guard < n_queries * 200 and seed_rows.size:
        guard += 1
        r = int(rng.choice(seed_rows))
        d, p = int(table.doc_ids[r]), int(table.positions[r])
        m = (table.doc_ids == d) & (np.abs(table.positions - p) <= window)
        lems = table.lemma_ids[m]
        L = int(rng.integers(min_len, max_len + 1))
        if qtype in split:
            pa, pb = split[qtype]
            a = lems[pa(lems)]
            b = lems[pb(lems)]
            if a.size < 1 or b.size < 1:
                continue
            k_a = int(rng.integers(1, min(L - 1, a.size) + 1))
            k_b = min(L - k_a, int(b.size))
            q = [int(x) for x in rng.choice(a, size=k_a, replace=False)]
            q += [int(x) for x in rng.choice(b, size=k_b, replace=False)]
        else:
            pool = lems[preds[qtype](lems)]
            if pool.size < min_len:
                continue
            take = rng.choice(pool.size, size=min(L, pool.size), replace=False)
            q = [int(x) for x in pool[take]]
        if len(q) >= min_len:
            queries.append(q)
    return queries


def sample_mixed_queries(
    table: TokenTable,
    lex: Lexicon,
    n_queries: int,
    kinds: tuple = ("qt1", "qt2", "qt3", "qt4", "qt5"),
    min_len: int = 3,
    max_len: int = 5,
    window: int = 9,
    seed: int = 0,
) -> list[list[int]]:
    """Round-robin interleave of per-type samples across all five query
    classes — the mixed-traffic shape the serving engine's query-type
    dispatch is built for."""
    per = -(-n_queries // len(kinds))
    cols = [
        sample_typed_queries(table, lex, per, k, min_len, max_len, window, seed + i)
        for i, k in enumerate(kinds)
    ]
    out: list[list[int]] = []
    for i in range(per):
        for c in cols:
            if i < len(c):
                out.append(c[i])
    return out[:n_queries]


def sample_stop_queries(
    table: TokenTable,
    lex: Lexicon,
    n_queries: int,
    min_len: int = 3,
    max_len: int = 5,
    window: int = 9,
    seed: int = 0,
) -> list[list[int]]:
    """Sample QT1 queries (all stop lemmas) from real co-occurrence windows,
    mirroring the paper's query-log-derived set: queries of 3..5 frequently
    occurring words that do have proximate matches in the collection."""
    rng = np.random.default_rng(seed)
    stop_rows = np.nonzero(table.lemma_ids < lex.sw_count)[0]
    # order rows to allow windowed scans
    queries: list[list[int]] = []
    guard = 0
    while len(queries) < n_queries and guard < n_queries * 50:
        guard += 1
        r = int(rng.choice(stop_rows))
        d, p = int(table.doc_ids[r]), int(table.positions[r])
        m = (table.doc_ids == d) & (np.abs(table.positions - p) <= window)
        lems = table.lemma_ids[m]
        lems = lems[lems < lex.sw_count]
        if lems.size < min_len:
            continue
        L = int(rng.integers(min_len, max_len + 1))
        take = rng.choice(lems.size, size=min(L, lems.size), replace=False)
        q = [int(x) for x in lems[take]]
        if len(q) >= min_len:
            queries.append(q)
    return queries
