"""Jitted wrapper for the proximity window join kernel."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.common import SENTINEL, cdiv, pad_to_multiple
from repro.kernels.proximity.proximity import (
    DEFAULT_BLOCK_A,
    DEFAULT_BLOCK_B,
    proximity_pallas,
)
from repro.kernels.proximity.ref import proximity_join_ref


def plan_starts(a_padded, b_padded, d: int, block_a: int, block_b: int):
    a_mins = a_padded[::block_a]
    start_elem = jnp.searchsorted(b_padded, a_mins - d)
    return (start_elem // block_b).astype(jnp.int32)


def plan_k_tiles(
    a: np.ndarray, b: np.ndarray, d: int, block_a: int = DEFAULT_BLOCK_A, block_b: int = DEFAULT_BLOCK_B
) -> int:
    a, b = np.asarray(a), np.asarray(b)
    if a.size == 0 or b.size == 0:
        return 1
    k = 1
    for i in range(cdiv(a.size, block_a)):
        blk = a[i * block_a : (i + 1) * block_a]
        lo = int(np.searchsorted(b, blk[0] - d)) // block_b
        hi = int(np.searchsorted(b, blk[-1] + d, side="right"))
        hi_blk = max(lo, cdiv(max(hi, 1), block_b) - 1)
        k = max(k, hi_blk - lo + 1)
    return int(k)


def proximity_join(
    a: jnp.ndarray,
    b: jnp.ndarray,
    d: int,
    *,
    block_a: int = DEFAULT_BLOCK_A,
    block_b: int = DEFAULT_BLOCK_B,
    k_tiles: int | None = None,
    use_pallas: bool = True,
    interpret: bool | None = None,
):
    """For each a_i: (is there a b within d, min matched b, max matched b)."""
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    n = a.shape[0]
    if not use_pallas:
        return proximity_join_ref(a, b, d)
    a_p = pad_to_multiple(a, block_a, SENTINEL)
    b_p = pad_to_multiple(b, block_b, SENTINEL)
    if k_tiles is None:
        k_tiles = b_p.shape[0] // block_b
    starts = plan_starts(a_p, b_p, d, block_a, block_b)
    mask, lo, hi = proximity_pallas(
        a_p, b_p, starts, d=d, block_a=block_a, block_b=block_b,
        k_tiles=int(k_tiles), interpret=interpret,
    )
    mask, lo, hi = mask[:n], lo[:n], hi[:n]
    lo = jnp.where(mask, lo, a)
    hi = jnp.where(mask, hi, a)
    return mask, lo, hi
