"""Pallas TPU kernel: proximity window join (|a - b| <= MaxDistance).

Same blocked structure as the intersect kernel (scalar-prefetched B-window
per A-block), different predicate, three outputs: match mask, min and max
matched B-position per A element (fragment bounds [P, E] of the paper's
result records). The MaxDistance parameter of the paper is the kernel's
`d` — static, so each Idx_d index family compiles its own specialized
join, mirroring the paper's per-MaxDistance index files.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import SENTINEL, default_interpret

DEFAULT_BLOCK_A = 512
DEFAULT_BLOCK_B = 1024

_I32_MAX = 2**31 - 1
_I32_MIN = -(2**31)


def _kernel(starts_ref, a_ref, b_ref, mask_ref, lo_ref, hi_ref, *, d: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        mask_ref[...] = jnp.zeros_like(mask_ref)
        lo_ref[...] = jnp.full_like(lo_ref, _I32_MAX)
        hi_ref[...] = jnp.full_like(hi_ref, _I32_MIN)

    a = a_ref[...]
    b = b_ref[...]
    near = (jnp.abs(a[:, None] - b[None, :]) <= d) & (b[None, :] != SENTINEL)
    near = near & (a[:, None] != SENTINEL)
    hit = jnp.any(near, axis=1)
    b_lo = jnp.min(jnp.where(near, b[None, :], _I32_MAX), axis=1)
    b_hi = jnp.max(jnp.where(near, b[None, :], _I32_MIN), axis=1)
    mask_ref[...] = mask_ref[...] | hit
    lo_ref[...] = jnp.minimum(lo_ref[...], b_lo)
    hi_ref[...] = jnp.maximum(hi_ref[...], b_hi)


@functools.partial(
    jax.jit, static_argnames=("d", "block_a", "block_b", "k_tiles", "interpret")
)
def proximity_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    starts: jnp.ndarray,
    *,
    d: int,
    block_a: int = DEFAULT_BLOCK_A,
    block_b: int = DEFAULT_BLOCK_B,
    k_tiles: int = 1,
    interpret: bool | None = None,
):
    if interpret is None:
        interpret = default_interpret()
    na_blocks = a.shape[0] // block_a
    nb_blocks = b.shape[0] // block_b
    kernel = functools.partial(_kernel, d=d)
    mask, lo, hi = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(na_blocks, k_tiles),
            in_specs=[
                pl.BlockSpec((block_a,), lambda i, k, starts: (i,)),
                pl.BlockSpec(
                    (block_b,),
                    lambda i, k, starts: (jnp.minimum(starts[i] + k, nb_blocks - 1),),
                ),
            ],
            out_specs=[
                pl.BlockSpec((block_a,), lambda i, k, starts: (i,)),
                pl.BlockSpec((block_a,), lambda i, k, starts: (i,)),
                pl.BlockSpec((block_a,), lambda i, k, starts: (i,)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((a.shape[0],), jnp.bool_),
            jax.ShapeDtypeStruct((a.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((a.shape[0],), jnp.int32),
        ],
        interpret=interpret,
    )(starts, a, b)
    return mask, lo, hi
