"""Pure-jnp oracle for the proximity window join.

Given two sorted position arrays (packed global positions: doc * stride +
pos, padding = SENTINEL), for each a_i: does b contain a position within
MaxDistance? Returns (mask, nearest_lo, nearest_hi) where nearest_lo/hi
are the min/max matched b-positions used for fragment bounds [P, E].
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import SENTINEL


def proximity_join_ref(a: jnp.ndarray, b: jnp.ndarray, d: int):
    m = b.shape[0]
    lo_idx = jnp.searchsorted(b, a - d, side="left")
    hi_idx = jnp.searchsorted(b, a + d, side="right")
    cnt = hi_idx - lo_idx
    mask = (cnt > 0) & (a != SENTINEL)
    lo_c = jnp.clip(lo_idx, 0, m - 1)
    hi_c = jnp.clip(hi_idx - 1, 0, m - 1)
    b_lo = jnp.where(mask, b[lo_c], a)
    b_hi = jnp.where(mask, b[hi_c], a)
    return mask, b_lo, b_hi


def proximity_count_ref(a: jnp.ndarray, b: jnp.ndarray, d: int) -> jnp.ndarray:
    """Number of b-positions within distance d of each a (multiplicity
    support for repeated query lemmas)."""
    lo_idx = jnp.searchsorted(b, a - d, side="left")
    hi_idx = jnp.searchsorted(b, a + d, side="right")
    return jnp.where(a != SENTINEL, hi_idx - lo_idx, 0)
