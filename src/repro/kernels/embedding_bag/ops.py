"""Jitted wrapper for EmbeddingBag: padding + mean-combine + fallback."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import cdiv
from repro.kernels.embedding_bag.embedding_bag import (
    DEFAULT_BLOCK_B,
    DEFAULT_BLOCK_V,
    embedding_bag_pallas,
)
from repro.kernels.embedding_bag.ref import embedding_bag_ref


def embedding_bag(
    ids: jnp.ndarray,
    table: jnp.ndarray,
    weights: jnp.ndarray | None = None,
    combine: str = "sum",
    *,
    use_pallas: bool = False,
    block_b: int = DEFAULT_BLOCK_B,
    block_v: int = DEFAULT_BLOCK_V,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Bag-reduce embedding lookup. use_pallas routes through the MXU
    one-hot kernel (TPU hot path); default is the XLA gather reference,
    which is what large sharded tables use under GSPMD."""
    if not use_pallas:
        return embedding_bag_ref(ids, table, weights, combine)
    B, S = ids.shape
    V, D = table.shape
    w = jnp.ones_like(ids, jnp.float32) if weights is None else weights.astype(jnp.float32)
    pb = (-B) % block_b
    if pb:
        ids = jnp.pad(ids, ((0, pb), (0, 0)), constant_values=-1)
        w = jnp.pad(w, ((0, pb), (0, 0)))
    pv = (-V) % block_v
    if pv:
        table = jnp.pad(table, ((0, pv), (0, 0)))
    out = embedding_bag_pallas(
        ids.astype(jnp.int32), w, table, block_b=block_b, block_v=block_v, interpret=interpret
    )[:B]
    if combine == "mean":
        denom = jnp.maximum((ids[:B] >= 0).sum(axis=1, keepdims=True), 1)
        out = out / denom.astype(out.dtype)
    return out.astype(table.dtype)
