"""Pallas TPU kernel: EmbeddingBag as blocked one-hot matmul.

TPU has no efficient in-kernel random gather; the TPU-native realization
of a bag lookup routes through the MXU: for each (batch-block, vocab-
block) grid cell, build the masked one-hot matrix of the ids that fall in
the vocab block and contract it with the resident table tile:

    out[Bb, D] += onehot(ids[Bb, S] - v0)  @  table[Vb, D]
                  (Bb*S, Vb)                  (Vb, D)

The vocab axis is the innermost grid dimension so the f32 accumulator
tile stays in VMEM across the sweep. For sharded tables (model-parallel
rows), the wrapper runs this kernel per shard and psums.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import default_interpret

DEFAULT_BLOCK_B = 128
DEFAULT_BLOCK_V = 512


def _kernel(ids_ref, w_ref, table_ref, out_ref, *, block_v: int):
    v = pl.program_id(1)

    @pl.when(v == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]  # (Bb, S) int32
    w = w_ref[...]  # (Bb, S) f32
    table = table_ref[...]  # (Vb, D)
    v0 = v * block_v
    local = ids - v0  # (Bb, S)
    in_block = (local >= 0) & (local < block_v) & (ids >= 0)
    bb, s = ids.shape
    # one-hot on the MXU: (Bb*S, Vb) @ (Vb, D)
    local_flat = jnp.where(in_block, local, 0).reshape(bb * s)
    onehot = (
        local_flat[:, None] == jax.lax.iota(jnp.int32, block_v)[None, :]
    ).astype(table.dtype)
    onehot = onehot * (in_block.reshape(bb * s, 1)).astype(table.dtype)
    onehot = onehot * w.reshape(bb * s, 1).astype(table.dtype)
    contrib = jnp.dot(onehot, table, preferred_element_type=jnp.float32)
    out_ref[...] += contrib.reshape(bb, s, -1).sum(axis=1).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_v", "interpret")
)
def embedding_bag_pallas(
    ids: jnp.ndarray,  # (B, S) int32 (padded rows: -1)
    weights: jnp.ndarray,  # (B, S) f32
    table: jnp.ndarray,  # (V, D); V % block_v == 0
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_v: int = DEFAULT_BLOCK_V,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = default_interpret()
    B, S = ids.shape
    V, D = table.shape
    grid = (B // block_b, V // block_v)
    kernel = functools.partial(_kernel, block_v=block_v)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, S), lambda b, v: (b, 0)),
            pl.BlockSpec((block_b, S), lambda b, v: (b, 0)),
            pl.BlockSpec((block_v, D), lambda b, v: (v, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, D), lambda b, v: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )(ids, weights, table)
