"""Pure-jnp oracle for EmbeddingBag (recsys hot path).

JAX has no native EmbeddingBag; the reference composes jnp.take with a
masked sum (equivalently segment_sum over the bag axis). ids == -1 are
padding and contribute nothing.
"""

from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(
    ids: jnp.ndarray,  # (B, S) int32, -1 = padding
    table: jnp.ndarray,  # (V, D)
    weights: jnp.ndarray | None = None,  # (B, S) or None
    combine: str = "sum",
) -> jnp.ndarray:
    mask = (ids >= 0).astype(table.dtype)  # (B, S)
    safe = jnp.maximum(ids, 0)
    rows = jnp.take(table, safe, axis=0)  # (B, S, D)
    w = mask if weights is None else mask * weights.astype(table.dtype)
    out = jnp.einsum("bs,bsd->bd", w, rows)
    if combine == "mean":
        denom = jnp.maximum(mask.sum(axis=1, keepdims=True), 1.0)
        out = out / denom
    return out
