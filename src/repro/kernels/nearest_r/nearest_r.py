"""Pallas TPU kernel: fused batched r-nearest window-membership join.

One blocked pass over all Kn non-stop rows replaces the per-key
searchsorted + argsort loop of the serve join. Structure:

* grid (B, n_l, Kn, k_tiles): the (valid, lo, hi) output block for an
  anchor tile stays resident in VMEM across the whole inner (key,
  b-tile) sweep — keys fold into it one after another, so the qt5
  stop-row constraints can seed it once and the qt34/qt5 executable
  sharing is preserved.
* δ-presence bitmask scratch: instead of gathering and sorting the
  2·r_max nearest candidates, each b-tile OR-accumulates "some b value
  sits at signed distance δ from this anchor" masks (δ ∈ 1..max_sep
  for predecessors, 0..max_sep for successors) via one broadcast
  compare per δ, the same VPU shape as the proximity kernel. At the
  last b-tile the p-th nearest distance is recovered by counting —
  valid because real posting values are strictly increasing per row,
  so distance sets are duplicate-free.
* early-mask join ordering (arXiv 2009.02684): callers order keys
  sparsest-first; a b-tile whose anchor block is already fully
  invalidated (or whose key is inactive) is skipped with pl.when, so
  later, denser keys touch fewer live lanes.
* scalar-prefetched window starts: like the intersect kernel, each
  (anchor-tile, key) only walks b-tiles from searchsorted(block min −
  max_sep) onwards.

Tie-breaking matches ``search._nearest_r`` bit-for-bit: at equal
distance, pred_p precedes succ_q iff p <= q (CPU candidate-column
order [idx-1, idx, idx-2, idx+1, ...] under a stable sort).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import SENTINEL, default_interpret, pad_to_multiple

DEFAULT_BLOCK_L = 256
DEFAULT_BLOCK_K = 512

BIG_DIST = 2**30  # plain int: Pallas kernels cannot capture device constants


def _kernel(starts_ref, nsr_ref, str_ref, a_ref, ns_ref, *rest,
            max_sep: int, r_max: int, n_stops: int, block_l: int):
    if n_stops:
        st_cnt_ref, st_ext_ref, valid_ref, lo_ref, hi_ref, pred_ref, succ_ref = rest
    else:
        st_cnt_ref = st_ext_ref = None
        valid_ref, lo_ref, hi_ref, pred_ref, succ_ref = rest

    b = pl.program_id(0)
    key = pl.program_id(2)
    k = pl.program_id(3)
    a = a_ref[0, :]

    @pl.when((key == 0) & (k == 0))
    def _init():
        # Seed outputs from the anchor; fold the elementwise stop-row
        # constraints here so one kernel serves qt34 (n_stops=0) and qt5.
        v = a != SENTINEL
        lo = a
        hi = a
        for s in range(n_stops):
            rs = str_ref[b, s]
            act = rs > 0
            v &= (st_cnt_ref[0, s, :] >= rs) | jnp.logical_not(act)
            ext = jnp.where(act, st_ext_ref[0, s, :], 0)
            lo = jnp.minimum(lo, a + jnp.minimum(ext, 0))
            hi = jnp.maximum(hi, a + jnp.maximum(ext, 0))
        valid_ref[0, :] = v
        lo_ref[0, :] = lo
        hi_ref[0, :] = hi

    @pl.when(k == 0)
    def _reset():
        pred_ref[...] = jnp.zeros_like(pred_ref)
        succ_ref[...] = jnp.zeros_like(succ_ref)

    r1 = nsr_ref[b, key]
    live = (r1 > 0) & jnp.any(valid_ref[0, :])

    @pl.when(live)
    def _accumulate():
        w = ns_ref[0, 0, :]
        ok = (a != SENTINEL)[:, None] & (w != SENTINEL)[None, :]
        diff = a[:, None] - w[None, :]
        for dlt in range(1, max_sep + 1):
            hit = jnp.any(ok & (diff == dlt), axis=1).astype(jnp.int32)
            pred_ref[dlt - 1, :] = pred_ref[dlt - 1, :] | hit
        for dlt in range(0, max_sep + 1):
            hit = jnp.any(ok & (diff == -dlt), axis=1).astype(jnp.int32)
            succ_ref[dlt, :] = succ_ref[dlt, :] | hit

    @pl.when(k == pl.num_programs(3) - 1)
    def _finalize():
        act = r1 > 0
        pred = pred_ref[...]
        succ = succ_ref[...]
        # p-th / q-th smallest present distance per side by counting.
        dp, ds = [], []
        for p in range(1, r_max + 1):
            run = jnp.zeros((block_l,), jnp.int32)
            lt = jnp.zeros((block_l,), jnp.int32)
            for dlt in range(1, max_sep + 1):
                run = run + pred[dlt - 1]
                lt = lt + (run < p).astype(jnp.int32)
            d = 1 + lt
            dp.append(jnp.where((d <= max_sep) & (p <= r1), d, BIG_DIST))
        for q in range(1, r_max + 1):
            run = jnp.zeros((block_l,), jnp.int32)
            lt = jnp.zeros((block_l,), jnp.int32)
            for dlt in range(0, max_sep + 1):
                run = run + succ[dlt]
                lt = lt + (run < q).astype(jnp.int32)
            d = lt
            ds.append(jnp.where((d <= max_sep) & (q <= r1), d, BIG_DIST))
        cnt = sum((d != BIG_DIST).astype(jnp.int32) for d in dp + ds)
        m = cnt >= r1
        # pred_p kept iff p + #{succs strictly before it} <= r; ties at
        # equal distance resolve pred_p before succ_q iff p <= q.
        mn_d = jnp.zeros((block_l,), jnp.int32)
        mx_d = jnp.zeros((block_l,), jnp.int32)
        for p in range(1, r_max + 1):
            s_before = sum(
                ((ds[q - 1] < dp[p - 1])
                 | ((ds[q - 1] == dp[p - 1]) & (q < p))).astype(jnp.int32)
                for q in range(1, r_max + 1)
            )
            keep = (dp[p - 1] != BIG_DIST) & (p + s_before <= r1)
            mn_d = jnp.maximum(mn_d, jnp.where(keep, dp[p - 1], 0))
        for q in range(1, r_max + 1):
            p_before = sum(
                ((dp[p - 1] < ds[q - 1])
                 | ((dp[p - 1] == ds[q - 1]) & (p <= q))).astype(jnp.int32)
                for p in range(1, r_max + 1)
            )
            keep = (ds[q - 1] != BIG_DIST) & (q + p_before <= r1)
            mx_d = jnp.maximum(mx_d, jnp.where(keep, ds[q - 1], 0))
        upd = act & m
        valid_ref[0, :] = valid_ref[0, :] & (m | jnp.logical_not(act))
        lo = lo_ref[0, :]
        hi = hi_ref[0, :]
        lo_ref[0, :] = jnp.where(upd, jnp.minimum(lo, a - mn_d), lo)
        hi_ref[0, :] = jnp.where(upd, jnp.maximum(hi, a + mx_d), hi)


@functools.partial(
    jax.jit,
    static_argnames=("max_sep", "r_max", "interpret", "block_l", "block_k",
                     "k_tiles"),
)
def window_join_pallas(a_g, ns_g, ns_r, st_cnt=None, st_ext=None, st_r=None, *,
                       max_sep: int, r_max: int, interpret: bool | None = None,
                       block_l: int = DEFAULT_BLOCK_L,
                       block_k: int = DEFAULT_BLOCK_K, k_tiles=None):
    if interpret is None:
        interpret = default_interpret()
    B, Kn, L = ns_g.shape
    if Kn == 0:
        raise ValueError("window_join_pallas needs at least one non-stop row")
    a_p = pad_to_multiple(a_g, block_l, SENTINEL)
    ns_p = pad_to_multiple(ns_g, block_k, SENTINEL)
    La = a_p.shape[-1]
    n_l = La // block_l
    nk = ns_p.shape[-1] // block_k
    if k_tiles is None:
        k_tiles = nk
    k_tiles = max(1, min(k_tiles, nk))
    n_stops = 0 if st_cnt is None else st_cnt.shape[1]

    # Scalar-prefetched b-tile windows: rows are sorted, so the first
    # tile that can matter for an anchor tile starts at the insertion
    # point of (tile minimum - max_sep).
    tile_min = a_p[:, ::block_l] - max_sep  # (B, n_l)
    starts = jax.vmap(  # (B, n_l, Kn)
        lambda rows, t: jax.vmap(lambda row: jnp.searchsorted(row, t))(rows).T
    )(ns_p, tile_min)
    starts = jnp.minimum(starts // block_k, nk - 1).astype(jnp.int32)

    in_specs = [
        pl.BlockSpec((1, block_l), lambda b, i, key, k, *refs: (b, i)),
        pl.BlockSpec(
            (1, 1, block_k),
            lambda b, i, key, k, starts, nsr, str_: (
                b, key, jnp.minimum(starts[b, i, key] + k, nk - 1)),
        ),
    ]
    operands = [a_p, ns_p]
    if n_stops:
        st_spec = pl.BlockSpec((1, n_stops, block_l),
                               lambda b, i, key, k, *refs: (b, 0, i))
        in_specs += [st_spec, st_spec]
        operands += [pad_to_multiple(st_cnt, block_l, 0),
                     pad_to_multiple(st_ext, block_l, 0)]
    st_r_arr = (jnp.zeros((B, 1), jnp.int32) if st_r is None
                else st_r.astype(jnp.int32))

    out_spec = pl.BlockSpec((1, block_l), lambda b, i, key, k, *refs: (b, i))
    kernel = functools.partial(_kernel, max_sep=max_sep, r_max=r_max,
                               n_stops=n_stops, block_l=block_l)
    valid, lo, hi = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, n_l, Kn, k_tiles),
            in_specs=in_specs,
            out_specs=[out_spec, out_spec, out_spec],
            scratch_shapes=[
                pltpu.VMEM((max_sep, block_l), jnp.int32),
                pltpu.VMEM((max_sep + 1, block_l), jnp.int32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, La), jnp.bool_),
            jax.ShapeDtypeStruct((B, La), jnp.int32),
            jax.ShapeDtypeStruct((B, La), jnp.int32),
        ],
        interpret=interpret,
    )(starts, ns_r.astype(jnp.int32), st_r_arr, *operands)
    return valid[:, :L], lo[:, :L], hi[:, :L]
