"""Pure-jnp oracle for the fused ordinary-window + NSW join.

``window_join_ref`` is the pre-kernel serve path verbatim: one
argsort-based r-nearest membership pass per non-stop key (the device
twin of ``search._nearest_r``), folded with the elementwise stop-row
constraints of ``qt5_join``. It is the lax *baseline* the nearest-r
kernel rows in ``benchmarks/kernel_bench.py`` compare against, and the
tie-breaking oracle the property tests pin the kernel to: candidate
columns in CPU order [idx-1, idx, idx-2, idx+1, ...], stable sort, so
ties at equal distance resolve pred_p before succ_q iff p <= q.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import SENTINEL

BIG_DIST = jnp.int32(2**30)


def nearest_r_ref(b_rows, centers, max_sep: int, r, r_max: int):
    """Batched r-nearest membership, argsort formulation: for each
    center, whether sorted row b holds r distinct values within
    ``max_sep``, plus the min/max of the r nearest (center included —
    the join folds via min/max against bounds that already bracket the
    center, so this equals the CPU contract wherever it is consumed).
    b_rows/centers: (N, L); r: (N,) traced multiplicity."""
    Lb = b_rows.shape[-1]
    jcol = np.arange(2 * r_max) // 2  # candidate ring index per column

    def one(b_row, c_row, r1):
        idx = jnp.searchsorted(b_row, c_row)
        cols = []
        for j in range(1, r_max + 1):
            cols.append(idx - j)
            cols.append(idx + (j - 1))
        ci = jnp.stack(cols, axis=1)
        ok = (ci >= 0) & (ci < Lb)
        cand = jnp.where(ok, b_row[jnp.clip(ci, 0, Lb - 1)], 0)
        ok &= cand != SENTINEL
        dist = jnp.abs(cand - c_row[:, None])
        ok &= dist <= max_sep
        ok &= jnp.asarray(jcol)[None, :] < r1
        dist = jnp.where(ok, dist, BIG_DIST)
        order = jnp.argsort(dist, axis=1)
        d_sorted = jnp.take_along_axis(dist, order, axis=1)
        c_sorted = jnp.take_along_axis(cand, order, axis=1)
        r_col = jnp.clip(r1 - 1, 0, 2 * r_max - 1)
        matched = jnp.take(d_sorted, r_col, axis=1) <= max_sep
        keep = (jnp.arange(2 * r_max)[None, :] < r1) & (d_sorted <= max_sep)
        chosen = jnp.where(keep, c_sorted, c_row[:, None])
        return matched, chosen.min(axis=1), chosen.max(axis=1)

    return jax.vmap(one)(b_rows, centers, r)


def window_join_ref(a_g, ns_g, ns_r, st_cnt=None, st_ext=None, st_r=None, *,
                    max_sep: int, r_max: int):
    """Fused-join oracle: per-key argsort r-nearest loop + stop fold.

    a_g: (B, L) sorted anchor rows; ns_g: (B, Kn, L) sorted non-stop
    rows; ns_r: (B, Kn) multiplicities (0 = padding key). Optional
    st_cnt/st_ext: (B, Ks, L) NSW aggregates aligned with the anchor,
    st_r: (B, Ks). Returns (valid, lo, hi) aligned with the anchor."""
    valid = a_g != SENTINEL
    lo = a_g
    hi = a_g
    for k in range(ns_g.shape[1]):
        r = ns_r[:, k]
        m, mn, mx = nearest_r_ref(ns_g[:, k], a_g, max_sep, r, r_max)
        active = (r > 0)[:, None]
        valid &= m | ~active
        upd = active & m
        lo = jnp.where(upd, jnp.minimum(lo, mn), lo)
        hi = jnp.where(upd, jnp.maximum(hi, mx), hi)
    if st_cnt is not None:
        for k in range(st_cnt.shape[1]):
            r = st_r[:, k][:, None]
            active = r > 0
            valid &= (st_cnt[:, k] >= r) | ~active
            ext = jnp.where(active, st_ext[:, k], 0)
            lo = jnp.minimum(lo, a_g + jnp.minimum(ext, 0))
            hi = jnp.maximum(hi, a_g + jnp.maximum(ext, 0))
    return valid, lo, hi
