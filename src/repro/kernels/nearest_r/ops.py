"""Dispatch wrapper for the fused nearest-r window join.

Two production paths behind one signature:

- ``use_pallas=False`` (default, and the serve default on CPU hosts):
  a sort-free *counting* formulation. One ``searchsorted`` per
  flattened (query, key) row, then the p-th nearest predecessor /
  q-th nearest successor distances are ranked by counting comparisons
  across the 2·r_max candidate lanes instead of materialising and
  sorting a (B, L, 2·r_max) distance tensor per key. This is the ~9×
  win over the argsort join on CPU and the baseline the kernel rows in
  ``benchmarks/kernel_bench.py`` quantify.
- ``use_pallas=True``: the Pallas TPU kernel in ``nearest_r.py`` —
  one blocked pass over all Kn rows with δ-presence bitmask scratch,
  sparsest-first key order exploited via early-masked blocks
  (interpret mode on CPU; see DESIGN.md §16).

Both reproduce ``ref.window_join_ref`` (and therefore the CPU engine's
``search._nearest_r``) bit-for-bit on valid lanes, including stable
tie-breaking at equal distances: pred_p wins over succ_q iff p <= q,
the column order [idx-1, idx, idx-2, idx+1, ...] of the CPU oracle.

Preconditions shared with the rest of the serve path: rows are sorted
ascending, strictly increasing on real values, SENTINEL-padded; ns_r
multiplicities are <= r_max.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import SENTINEL, cdiv

BIG_DIST = jnp.int32(2**30)


def _nearest_r_counting(b_rows, centers, max_sep: int, r, r_max: int):
    """Sort-free device twin of ``search._nearest_r``.

    b_rows (N, L) sorted asc (SENTINEL pad), centers (N, L), r (N,).
    Returns (matched, mn, mx) with mn/mx = min/max over (r nearest
    values + center) — identical to the argsort formulation at the
    join level, where lo/hi already bracket the center.
    """
    Lb = b_rows.shape[-1]

    def one(b_row, c_row, r1):
        idx = jnp.searchsorted(b_row, c_row)
        dp, ds = [], []
        for j in range(1, r_max + 1):
            ip = idx - j
            vp = b_row[jnp.clip(ip, 0, Lb - 1)]
            okp = (ip >= 0) & (vp != SENTINEL) & (jnp.int32(j) <= r1)
            d = c_row - vp
            dp.append(jnp.where(okp & (d <= max_sep), d, BIG_DIST))
            iq = idx + (j - 1)
            vq = b_row[jnp.clip(iq, 0, Lb - 1)]
            okq = (iq < Lb) & (vq != SENTINEL) & (jnp.int32(j) <= r1)
            d = vq - c_row
            ds.append(jnp.where(okq & (d <= max_sep), d, BIG_DIST))
        cnt = sum((d != BIG_DIST).astype(jnp.int32) for d in dp + ds)
        matched = cnt >= r1
        # pred_p is kept iff p + #{succs strictly before it} <= r;
        # tie at equal distance: pred_p before succ_q iff p <= q.
        mn_d = jnp.zeros_like(c_row)
        mx_d = jnp.zeros_like(c_row)
        for p in range(1, r_max + 1):
            s_before = sum(
                ((ds[q - 1] < dp[p - 1])
                 | ((ds[q - 1] == dp[p - 1]) & (q < p))).astype(jnp.int32)
                for q in range(1, r_max + 1)
            )
            keep = (dp[p - 1] != BIG_DIST) & (p + s_before <= r1)
            mn_d = jnp.maximum(mn_d, jnp.where(keep, dp[p - 1], 0))
        for q in range(1, r_max + 1):
            p_before = sum(
                ((dp[p - 1] < ds[q - 1])
                 | ((dp[p - 1] == ds[q - 1]) & (p <= q))).astype(jnp.int32)
                for p in range(1, r_max + 1)
            )
            keep = (ds[q - 1] != BIG_DIST) & (q + p_before <= r1)
            mx_d = jnp.maximum(mx_d, jnp.where(keep, ds[q - 1], 0))
        return matched, c_row - mn_d, c_row + mx_d

    return jax.vmap(one)(b_rows, centers, r)


def _fold_stops(valid, lo, hi, a_g, st_cnt, st_ext, st_r):
    """Elementwise NSW stop-row constraints of ``qt5_join``."""
    for k in range(st_cnt.shape[1]):
        r = st_r[:, k][:, None]
        active = r > 0
        valid &= (st_cnt[:, k] >= r) | ~active
        ext = jnp.where(active, st_ext[:, k], 0)
        lo = jnp.minimum(lo, a_g + jnp.minimum(ext, 0))
        hi = jnp.maximum(hi, a_g + jnp.maximum(ext, 0))
    return valid, lo, hi


def window_join(a_g, ns_g, ns_r, st_cnt=None, st_ext=None, st_r=None, *,
                max_sep: int, r_max: int, use_pallas: bool = False,
                interpret=None, block_l: int = 256, block_k: int = 512,
                k_tiles=None):
    """Fused ordinary-window + NSW join over all keys at once.

    a_g: (B, L) anchor rows; ns_g: (B, Kn, L) non-stop rows; ns_r:
    (B, Kn) multiplicities (0 = inactive key). Optional stop aggregates
    st_cnt/st_ext (B, Ks, L) + st_r (B, Ks). Returns (valid, lo, hi)
    aligned with the anchor, SENTINEL lanes invalid.
    """
    if use_pallas:
        from repro.kernels.nearest_r.nearest_r import window_join_pallas
        return window_join_pallas(
            a_g, ns_g, ns_r, st_cnt, st_ext, st_r,
            max_sep=max_sep, r_max=r_max, interpret=interpret,
            block_l=block_l, block_k=block_k, k_tiles=k_tiles)

    B, Kn, L = ns_g.shape
    valid = a_g != SENTINEL
    lo = a_g
    hi = a_g
    if Kn:
        b_flat = ns_g.reshape(B * Kn, L)
        c_flat = jnp.broadcast_to(a_g[:, None, :], (B, Kn, L)).reshape(B * Kn, L)
        r_flat = ns_r.reshape(B * Kn)
        m, mn, mx = _nearest_r_counting(b_flat, c_flat, max_sep, r_flat, r_max)
        m = m.reshape(B, Kn, L)
        mn = mn.reshape(B, Kn, L)
        mx = mx.reshape(B, Kn, L)
        active = (ns_r > 0)[:, :, None]
        valid &= jnp.all(m | ~active, axis=1)
        upd = active & m
        lo = jnp.minimum(lo, jnp.where(upd, mn, lo[:, None, :]).min(axis=1))
        hi = jnp.maximum(hi, jnp.where(upd, mx, hi[:, None, :]).max(axis=1))
    if st_cnt is not None:
        valid, lo, hi = _fold_stops(valid, lo, hi, a_g, st_cnt, st_ext, st_r)
    return valid, lo, hi


def plan_k_tiles(a_g, ns_g, max_sep: int, block_l: int, block_k: int) -> int:
    """Host-side exact bound on b-tiles any (anchor-block, key) pair
    needs so every candidate within ``max_sep`` of a block's anchors is
    visited. Concrete inputs only; the kernel defaults to the safe
    full-row bound when this is not supplied."""
    import numpy as np

    a = np.asarray(a_g)
    ns = np.asarray(ns_g)
    B, Kn, L = ns.shape
    n_l = cdiv(L, block_l)
    nk = cdiv(L, block_k)
    worst = 1
    for b in range(B):
        for i in range(n_l):
            blk = a[b, i * block_l:(i + 1) * block_l]
            blk = blk[blk != SENTINEL]
            if blk.size == 0:
                continue
            for key in range(Kn):
                row = ns[b, key]
                s = np.searchsorted(row, blk.min() - max_sep) // block_k
                e = np.searchsorted(row, blk.max() + max_sep, "right")
                e = min(nk - 1, e // block_k)
                worst = max(worst, int(e - s) + 1)
    return worst
