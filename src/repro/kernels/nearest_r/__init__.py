"""Batched r-nearest window-membership join (QT3/QT4/QT5 hot loop)."""

from repro.kernels.nearest_r.ops import window_join, plan_k_tiles  # noqa: F401
