"""Pure-jnp oracle for sorted posting-list intersection.

Semantics: for each element of sorted array `a`, is it present in sorted
array `b`? (Padding slots hold SENTINEL and never match.) This is the
vectorized Equalize (paper §2.3): aligning posting iterators on document
ids == computing membership of one sorted doc-id list in another.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import SENTINEL


def intersect_mask_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a: (n,) sorted int32; b: (m,) sorted int32 -> bool (n,) membership."""
    idx = jnp.searchsorted(b, a)
    idx_c = jnp.clip(idx, 0, b.shape[0] - 1)
    found = (idx < b.shape[0]) & (b[idx_c] == a) & (a != SENTINEL)
    return found


def intersect_idx_ref(a: jnp.ndarray, b: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Also return, per element of a, the index in b of the match (-1 if none)."""
    idx = jnp.searchsorted(b, a)
    idx_c = jnp.clip(idx, 0, b.shape[0] - 1)
    found = (idx < b.shape[0]) & (b[idx_c] == a) & (a != SENTINEL)
    return found, jnp.where(found, idx_c, -1)
