"""Pallas TPU kernel: blocked sorted-list intersection with scalar-
prefetched dynamic B-window placement.

TPU adaptation of the paper's Equalize (§2.3): instead of a binary heap
advancing one iterator at a time, list A is tiled into VMEM blocks; for
each A-block the host precomputes (via searchsorted on block boundaries)
which aligned block of B its value range can possibly overlap. The grid is
(num_a_blocks, k_tiles): step (i, k) compares A-tile i against B-tile
(start[i] + k) with a broadcast equality over the VPU — a (BA, BB) int32
compare, well within VMEM at the default 512x1024 tile.

k_tiles bounds the per-block B-span and therefore the *compiled latency*
of the search step — the kernel-level realization of the paper's
"response time guarantee" (see DESIGN.md §3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import SENTINEL, cdiv, default_interpret, pad_to_multiple

DEFAULT_BLOCK_A = 512
DEFAULT_BLOCK_B = 1024


def _kernel(starts_ref, a_ref, b_ref, mask_ref, idx_ref, *, block_b: int, nb_blocks: int):
    i = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        mask_ref[...] = jnp.zeros_like(mask_ref)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    a = a_ref[...]  # (BA,)
    b = b_ref[...]  # (BB,)
    eq = a[:, None] == b[None, :]  # (BA, BB) — VPU broadcast compare
    hit = jnp.any(eq, axis=1) & (a != SENTINEL)
    # global b-index of the first match within this tile
    col = jnp.argmax(eq, axis=1).astype(jnp.int32)
    b_block = jnp.minimum(starts_ref[i] + k, nb_blocks - 1)
    gidx = b_block * block_b + col
    newly = hit & (idx_ref[...] < 0)
    mask_ref[...] = mask_ref[...] | hit
    idx_ref[...] = jnp.where(newly, gidx, idx_ref[...])


DELTA_BLK = 64  # postings per delta-coding block
PAD_DELTA = 2**16 - 1  # uint16 marker for padding slots


def _kernel_compressed(
    starts_ref, a_base_ref, a_delta_ref, b_base_ref, b_delta_ref, mask_ref,
    *, nb_blocks: int
):
    """In-kernel decompression (§Perf hillclimb C, TPU completion): posting
    streams arrive as int32 per-64 block bases + uint16 in-block deltas and
    are decoded in VMEM between the DMA and the compare — the decoded int32
    form never round-trips through HBM (the XLA-level decompression did,
    which kept bytes_accessed flat; see EXPERIMENTS.md §Perf C)."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        mask_ref[...] = jnp.zeros_like(mask_ref)

    a_delta = a_delta_ref[...]  # (BA,) uint16
    a = jnp.repeat(a_base_ref[...], DELTA_BLK) + a_delta.astype(jnp.int32)
    a_pad = a_delta == PAD_DELTA
    b_delta = b_delta_ref[...]
    b = jnp.repeat(b_base_ref[...], DELTA_BLK) + b_delta.astype(jnp.int32)
    b_ok = (b_delta != PAD_DELTA)[None, :]
    eq = (a[:, None] == b[None, :]) & b_ok
    hit = jnp.any(eq, axis=1) & ~a_pad
    mask_ref[...] = mask_ref[...] | hit


@functools.partial(
    jax.jit, static_argnames=("block_a", "block_b", "k_tiles", "interpret")
)
def intersect_pallas_compressed(
    a_base: jnp.ndarray,
    a_delta: jnp.ndarray,
    b_base: jnp.ndarray,
    b_delta: jnp.ndarray,
    starts: jnp.ndarray,
    *,
    block_a: int = DEFAULT_BLOCK_A,
    block_b: int = DEFAULT_BLOCK_B,
    k_tiles: int = 1,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Compressed-stream variant: 2B deltas + 4B/64 bases ≈ 2.06 B/posting
    streamed from HBM vs 4 B/posting for raw int32."""
    if interpret is None:
        interpret = default_interpret()
    na_blocks = a_delta.shape[0] // block_a
    nb_blocks = b_delta.shape[0] // block_b
    kernel = functools.partial(_kernel_compressed, nb_blocks=nb_blocks)
    (mask,) = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(na_blocks, k_tiles),
            in_specs=[
                pl.BlockSpec((block_a // DELTA_BLK,), lambda i, k, starts: (i,)),
                pl.BlockSpec((block_a,), lambda i, k, starts: (i,)),
                pl.BlockSpec(
                    (block_b // DELTA_BLK,),
                    lambda i, k, starts: (jnp.minimum(starts[i] + k, nb_blocks - 1),),
                ),
                pl.BlockSpec(
                    (block_b,),
                    lambda i, k, starts: (jnp.minimum(starts[i] + k, nb_blocks - 1),),
                ),
            ],
            out_specs=[pl.BlockSpec((block_a,), lambda i, k, starts: (i,))],
        ),
        out_shape=[jax.ShapeDtypeStruct((a_delta.shape[0],), jnp.bool_)],
        interpret=interpret,
    )(starts, a_base, a_delta, b_base, b_delta)
    return mask


@functools.partial(
    jax.jit, static_argnames=("block_a", "block_b", "k_tiles", "interpret")
)
def intersect_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    starts: jnp.ndarray,
    *,
    block_a: int = DEFAULT_BLOCK_A,
    block_b: int = DEFAULT_BLOCK_B,
    k_tiles: int = 1,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """a, b: sorted int32, already padded to multiples of the block sizes
    with SENTINEL; starts: (num_a_blocks,) int32 — first B-block index each
    A-block may overlap. Returns (mask, idx) per element of a."""
    if interpret is None:
        interpret = default_interpret()
    na_blocks = a.shape[0] // block_a
    nb_blocks = b.shape[0] // block_b
    grid = (na_blocks, k_tiles)
    kernel = functools.partial(_kernel, block_b=block_b, nb_blocks=nb_blocks)
    mask, idx = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_a,), lambda i, k, starts: (i,)),
                pl.BlockSpec(
                    (block_b,),
                    lambda i, k, starts: (jnp.minimum(starts[i] + k, nb_blocks - 1),),
                ),
            ],
            out_specs=[
                pl.BlockSpec((block_a,), lambda i, k, starts: (i,)),
                pl.BlockSpec((block_a,), lambda i, k, starts: (i,)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((a.shape[0],), jnp.bool_),
            jax.ShapeDtypeStruct((a.shape[0],), jnp.int32),
        ],
        interpret=interpret,
    )(starts, a, b)
    return mask, idx
