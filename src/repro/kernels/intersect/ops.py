"""Jitted public wrapper around the intersection kernel.

Handles padding, host-side window planning (searchsorted on A-block
boundaries), and the k_tiles static bound. `plan_k_tiles` computes the
exact bound for concrete inputs; serving systems pick a bucket-level bound
offline (the response-time guarantee).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import SENTINEL, cdiv, pad_to_multiple
from repro.kernels.intersect.intersect import (
    DEFAULT_BLOCK_A,
    DEFAULT_BLOCK_B,
    intersect_pallas,
)
from repro.kernels.intersect.ref import intersect_idx_ref


def plan_starts(a_padded: jnp.ndarray, b_padded: jnp.ndarray, block_a: int, block_b: int):
    """Aligned B-block start per A-block (traceable; runs outside the kernel)."""
    a_mins = a_padded[::block_a]
    start_elem = jnp.searchsorted(b_padded, a_mins)
    return (start_elem // block_b).astype(jnp.int32)


def plan_k_tiles(a: np.ndarray, b: np.ndarray, block_a: int = DEFAULT_BLOCK_A, block_b: int = DEFAULT_BLOCK_B) -> int:
    """Exact static bound on B-blocks any A-block can span (host-side,
    concrete arrays): max over blocks of ceil span. Never < 1."""
    a = np.asarray(a)
    b = np.asarray(b)
    if a.size == 0 or b.size == 0:
        return 1
    na = cdiv(a.size, block_a)
    k = 1
    for i in range(na):
        blk = a[i * block_a : (i + 1) * block_a]
        lo = int(np.searchsorted(b, blk[0])) // block_b
        hi = int(np.searchsorted(b, blk[-1], side="right"))
        hi_blk = max(lo, cdiv(max(hi, 1), block_b) - 1)
        k = max(k, hi_blk - lo + 1)
    return int(k)


def pack_delta_stream(x: np.ndarray, total_len: int):
    """Host/offline packing: sorted int32 postings -> (base int32 per 64,
    delta uint16, padded to total_len). Raises if an in-block span exceeds
    uint16 (the index builder then splits the block)."""
    from repro.kernels.intersect.intersect import DELTA_BLK, PAD_DELTA

    x = np.asarray(x, np.int64)
    assert total_len % DELTA_BLK == 0
    nb = total_len // DELTA_BLK
    padded = np.full(total_len, 0, np.int64)
    padded[: x.size] = x
    blocks = padded.reshape(nb, DELTA_BLK)
    base = blocks[:, 0].copy()
    # blocks fully in padding get base of the last real value
    if x.size:
        last_real_block = (x.size - 1) // DELTA_BLK
        base[last_real_block + 1 :] = 0
    delta = blocks - base[:, None]
    if x.size and delta[: last_real_block + 1].max() >= PAD_DELTA:
        raise ValueError("in-block span exceeds uint16")
    delta = np.clip(delta, 0, PAD_DELTA).astype(np.uint16)
    flat = delta.reshape(-1)
    flat[x.size :] = PAD_DELTA  # pad marker
    return base.astype(np.int32), flat


def intersect_sorted_compressed(
    a: np.ndarray,
    b: np.ndarray,
    *,
    block_a: int = DEFAULT_BLOCK_A,
    block_b: int = DEFAULT_BLOCK_B,
    k_tiles: int | None = None,
    interpret: bool | None = None,
):
    """Same contract as intersect_sorted (mask only) but the posting
    streams cross HBM as base+delta (2.06 B/posting)."""
    from repro.kernels.intersect.intersect import intersect_pallas_compressed

    a = np.asarray(a, np.int64)
    b = np.asarray(b, np.int64)
    la = cdiv(max(a.size, 1), block_a) * block_a
    lb = cdiv(max(b.size, 1), block_b) * block_b
    a_base, a_delta = pack_delta_stream(a, la)
    b_base, b_delta = pack_delta_stream(b, lb)
    a_mins = a_base[:: block_a // 64]
    start_elem = np.searchsorted(b, a_mins)
    starts = (start_elem // block_b).astype(np.int32)
    if k_tiles is None:
        k_tiles = lb // block_b
    mask = intersect_pallas_compressed(
        jnp.asarray(a_base), jnp.asarray(a_delta), jnp.asarray(b_base),
        jnp.asarray(b_delta), jnp.asarray(starts),
        block_a=block_a, block_b=block_b, k_tiles=int(k_tiles), interpret=interpret,
    )
    return mask[: a.size]


def intersect_sorted(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_a: int = DEFAULT_BLOCK_A,
    block_b: int = DEFAULT_BLOCK_B,
    k_tiles: int | None = None,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Membership of each element of sorted `a` in sorted `b`.

    Returns (mask, idx) of length len(a): idx is the matching position in
    the *padded* b (valid wherever mask). With use_pallas=False, the
    searchsorted oracle runs instead (same contract)."""
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    n = a.shape[0]
    if not use_pallas:
        mask, idx = intersect_idx_ref(a, b)
        return mask, idx
    a_p = pad_to_multiple(a, block_a, SENTINEL)
    b_p = pad_to_multiple(b, block_b, SENTINEL)
    if k_tiles is None:
        k_tiles = b_p.shape[0] // block_b  # safe full scan
    starts = plan_starts(a_p, b_p, block_a, block_b)
    mask, idx = intersect_pallas(
        a_p, b_p, starts, block_a=block_a, block_b=block_b, k_tiles=int(k_tiles), interpret=interpret
    )
    return mask[:n], idx[:n]
