"""Shared helpers for the Pallas TPU kernels.

All kernels follow the same contract:
* written for TPU (pl.pallas_call + BlockSpec VMEM tiling, MXU/VPU-aligned
  tile shapes, scalar-prefetched dynamic block index maps);
* validated on CPU with interpret=True against the pure-jnp oracles in
  each kernel's ref.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Sentinel for padded posting slots: larger than any real doc id / packed
# (doc, pos) key, still valid int32.
SENTINEL = np.int32(2**31 - 1)


def default_interpret() -> bool:
    """Pallas interpret mode: True unless running on a real TPU."""
    return jax.default_backend() != "tpu"


def pad_to_multiple(x: jnp.ndarray, multiple: int, fill) -> jnp.ndarray:
    n = x.shape[-1]
    rem = (-n) % multiple
    if rem == 0:
        return x
    pad_width = [(0, 0)] * (x.ndim - 1) + [(0, rem)]
    return jnp.pad(x, pad_width, constant_values=fill)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def shard_map_compat(f, mesh, in_specs, out_specs):
    """Version-portable shard_map with replication checking disabled (the
    static checker cannot see through top_k / psum-reduced outputs).

    Covers three API generations: top-level `jax.shard_map` with
    `check_vma` (>= 0.5), top-level with the older `check_rep` spelling,
    and `jax.experimental.shard_map` (0.4.x)."""
    try:
        from jax import shard_map as sm
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm

        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
    except TypeError:  # promoted to top level but pre-rename
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)
