"""Shared helpers for the Pallas TPU kernels.

All kernels follow the same contract:
* written for TPU (pl.pallas_call + BlockSpec VMEM tiling, MXU/VPU-aligned
  tile shapes, scalar-prefetched dynamic block index maps);
* validated on CPU with interpret=True against the pure-jnp oracles in
  each kernel's ref.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Sentinel for padded posting slots: larger than any real doc id / packed
# (doc, pos) key, still valid int32.
SENTINEL = np.int32(2**31 - 1)


def default_interpret() -> bool:
    """Pallas interpret mode: True unless running on a real TPU."""
    return jax.default_backend() != "tpu"


def pad_to_multiple(x: jnp.ndarray, multiple: int, fill) -> jnp.ndarray:
    n = x.shape[-1]
    rem = (-n) % multiple
    if rem == 0:
        return x
    pad_width = [(0, 0)] * (x.ndim - 1) + [(0, rem)]
    return jnp.pad(x, pad_width, constant_values=fill)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)
