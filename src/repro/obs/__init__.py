"""Observability tier: metrics + trace spans for the serving stack
(DESIGN.md §15).

The paper's product is a *response-time guarantee*; this package is how
the reproduction observes whether — and *where* — a budget is spent:

* :mod:`repro.obs.metrics` — :class:`MetricsRegistry` of counters,
  gauges and streaming histograms (p50/p95/p99 over a bounded sample
  ring). Every ``SearchService`` owns one; the service, both executors
  and both packed-row caches record into it (``serve.phase.*``,
  ``serve.step.*``, ``serve.compile.*``, ``cache.*``).
* :mod:`repro.obs.trace` — :class:`Tracer` of nested per-drain /
  per-batch spans, exported as Chrome JSON trace format via
  :func:`chrome_trace` / :func:`write_chrome_trace` — loadable in
  https://ui.perfetto.dev as one span tree per drained batch
  (``SearchService.trace_snapshot()`` / ``write_trace()``,
  ``launch/serve.py --trace-out``).

The package is dependency-free (numpy only) and serving-agnostic: the
instruments know nothing about query types, so the index build path or
the LM batcher can adopt the same registry later.
"""

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry  # noqa: F401
from repro.obs.trace import Span, Tracer, chrome_trace, write_chrome_trace  # noqa: F401

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "Tracer",
    "chrome_trace",
    "write_chrome_trace",
]
