"""Per-request / per-batch trace spans with Chrome-trace (Perfetto)
export (DESIGN.md §15).

A :class:`Tracer` records *complete spans*: named intervals with a
start timestamp, a duration, a thread id, a depth, and free-form args.
Spans nest via a per-thread stack — ``with tracer.span("drain"):``
makes every span opened inside it a child — so one
``SearchService.drain()`` produces one span tree per drained batch:

    drain
    ├─ plan
    ├─ group
    └─ batch(qt1, B=16, L=1024)
       ├─ pack
       ├─ compress
       ├─ dispatch
       ├─ execute          (args: compile=True on the first (kind,B,L))
       └─ decode

Timestamps come from ``time.perf_counter()`` rebased to the tracer's
creation (so they are small, strictly monotonic per thread, and share
one epoch across threads). :func:`chrome_trace` renders the buffer as
Chrome JSON trace format — ``{"traceEvents": [{"ph": "X", ...}]}`` with
microsecond ``ts``/``dur`` — which https://ui.perfetto.dev and
``chrome://tracing`` both load directly; nesting is expressed by time
containment per track, which is exactly the invariant the span stack
enforces (tests/test_obs.py pins it).

The buffer is a bounded ring (default 8192 completed spans, oldest
evicted first) so a long-lived service cannot grow without bound;
``enabled=False`` turns ``span()`` into a no-op context manager whose
overhead is one attribute read.
"""

from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from collections import deque
from dataclasses import dataclass, field

__all__ = ["Span", "Tracer", "chrome_trace", "write_chrome_trace"]


@dataclass
class Span:
    """One completed interval. ``ts``/``dur`` are seconds relative to
    the tracer's epoch; ``tid`` the recording thread's ident; ``depth``
    the nesting level at record time (0 = root); ``args`` free-form
    metadata rendered into the Chrome trace ``args`` field."""

    name: str
    cat: str
    ts: float
    dur: float
    tid: int
    depth: int = 0
    args: dict = field(default_factory=dict)

    @property
    def end(self) -> float:
        return self.ts + self.dur


class _NullSpan:
    """The disabled-tracer span handle: accepts arg updates, keeps
    nothing."""

    __slots__ = ()

    def set(self, **kw) -> None:
        pass


_NULL_SPAN = _NullSpan()


class _LiveSpan:
    """Handle yielded by :meth:`Tracer.span` while the span is open —
    lets the body attach args discovered mid-span (e.g. the payload
    kind a compressed pack settled on)."""

    __slots__ = ("args",)

    def __init__(self, args: dict):
        self.args = args

    def set(self, **kw) -> None:
        self.args.update(kw)


class Tracer:
    """Bounded recorder of nested spans; thread-safe, one per service."""

    def __init__(self, capacity: int = 8192, enabled: bool = True):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.enabled = enabled
        self.epoch = time.perf_counter()
        self._spans: deque[Span] = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._local = threading.local()
        self._dropped = 0

    # -- recording ---------------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    @contextmanager
    def span(self, name: str, cat: str = "serve", **args):
        """Record ``name`` over the ``with`` body. Exceptions propagate;
        the span is still recorded (with ``error=True``) so a trace of
        a failing drain shows where it died."""
        if not self.enabled:
            yield _NULL_SPAN
            return
        stack = self._stack()
        depth = len(stack)
        live = _LiveSpan(dict(args))
        stack.append(name)
        t0 = time.perf_counter()
        try:
            yield live
        except BaseException:
            live.args["error"] = True
            raise
        finally:
            t1 = time.perf_counter()
            stack.pop()
            sp = Span(name=name, cat=cat, ts=t0 - self.epoch, dur=t1 - t0,
                      tid=threading.get_ident(), depth=depth, args=live.args)
            with self._lock:
                if len(self._spans) == self.capacity:
                    self._dropped += 1
                self._spans.append(sp)

    # -- introspection -----------------------------------------------------
    def snapshot(self) -> list[Span]:
        """Completed spans, oldest first, ordered by start timestamp
        (record order is *end* order — a parent records after its
        children — so export re-sorts by ``ts``)."""
        with self._lock:
            spans = list(self._spans)
        return sorted(spans, key=lambda s: (s.ts, -s.dur))

    @property
    def dropped(self) -> int:
        return self._dropped

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._dropped = 0


def chrome_trace(spans: list[Span], process_name: str = "repro.serving") -> dict:
    """Render completed spans as a Chrome JSON trace object.

    Complete events (``ph: "X"``) with integer-microsecond ``ts`` and
    ``dur``, one track per recording thread; Perfetto nests events on a
    track by time containment. Metadata events name the process and
    threads so the UI shows something better than bare ids."""
    events = []
    tids = []
    for sp in sorted(spans, key=lambda s: (s.ts, -s.dur)):
        if sp.tid not in tids:
            tids.append(sp.tid)
        events.append({
            "name": sp.name, "cat": sp.cat, "ph": "X",
            "ts": round(sp.ts * 1e6, 3), "dur": round(sp.dur * 1e6, 3),
            "pid": 0, "tid": tids.index(sp.tid),
            "args": {k: _jsonable(v) for k, v in sp.args.items()},
        })
    meta = [{
        "name": "process_name", "ph": "M", "pid": 0, "tid": 0,
        "args": {"name": process_name},
    }]
    meta += [{
        "name": "thread_name", "ph": "M", "pid": 0, "tid": i,
        "args": {"name": f"serve-thread-{i}"},
    } for i in range(len(tids))]
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


def write_chrome_trace(path: str, spans: list[Span], **kw) -> dict:
    """Write :func:`chrome_trace` JSON to ``path``; returns the object
    (callers report event counts)."""
    obj = chrome_trace(spans, **kw)
    with open(path, "w") as fh:
        json.dump(obj, fh)
    return obj
