"""Low-overhead metrics registry: counters, gauges, streaming
histograms (DESIGN.md §15).

One :class:`MetricsRegistry` instance lives on each
``repro.serving.service.SearchService`` and is shared by every layer of
the serving tier: the service records per-phase request latencies, the
executors record per-(step_family, B, L) measured step costs and
compile times, and the packed-posting caches record hit/miss counts and
derivation timings. Names are dotted strings (``serve.phase.pack``,
``serve.step.qt1.B16.L1024``); the registry is the single
source the phase rows of BENCH_serve.json, ``stats_snapshot()`` and
``explain(costs=True)`` all read from.

Design constraints (the overhead budget of §15):

* ``observe()``/``inc()`` on the hot path are a dict lookup plus a few
  float ops under a per-instrument lock — no allocation after the
  first observation of a name;
* histograms keep a bounded sample ring (default 4096); percentiles
  are computed only at snapshot time (numpy quantile over the resident
  samples), never on the record path;
* ``snapshot()`` returns plain dicts/floats only — safe to json-dump,
  deep-copy free of live references, and consistent per instrument
  (each instrument is snapshotted under its own lock).
"""

from __future__ import annotations

import threading

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


class Counter:
    """Monotonic counter. ``inc`` is the only mutator."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value

    def snapshot(self):
        return self._value


class Gauge:
    """Last-write-wins scalar (queue depth, resident cache bytes)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self):
        return self._value


class Histogram:
    """Streaming histogram: exact count/sum/min/max plus a bounded
    sample ring for percentiles.

    The ring keeps the *last* ``capacity`` observations (overwrite in
    arrival order), so percentiles reflect recent behaviour — the right
    bias for serving telemetry, where an old compile-time outlier must
    not dominate p99 forever. While fewer than ``capacity`` samples
    have been observed the percentiles are exact (tests pin them
    against ``np.quantile`` directly)."""

    __slots__ = ("name", "capacity", "_ring", "_n_seen", "_sum", "_min",
                 "_max", "_lock")

    def __init__(self, name: str, capacity: int = 4096):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.name = name
        self.capacity = capacity
        self._ring = np.empty(capacity, np.float64)
        self._n_seen = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._ring[self._n_seen % self.capacity] = v
            self._n_seen += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        return self._n_seen

    def _samples(self) -> np.ndarray:
        n = min(self._n_seen, self.capacity)
        return self._ring[:n]

    def percentile(self, q: float) -> float:
        """q in [0, 100], linear interpolation — bit-identical to
        ``np.percentile`` over the resident samples."""
        with self._lock:
            s = self._samples()
            if s.size == 0:
                return float("nan")
            return float(np.percentile(s, q))

    def snapshot(self) -> dict:
        with self._lock:
            s = self._samples().copy()
            n, total = self._n_seen, self._sum
        if s.size == 0:
            return {"count": 0, "sum": 0.0, "mean": 0.0, "min": 0.0,
                    "max": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
        p50, p95, p99 = (float(x) for x in np.percentile(s, (50, 95, 99)))
        return {
            "count": n, "sum": total, "mean": total / n,
            "min": self._min, "max": self._max,
            "p50": p50, "p95": p95, "p99": p99,
        }


class MetricsRegistry:
    """Get-or-create registry of named instruments.

    A name is permanently bound to the first instrument kind created
    under it (creating ``counter("x")`` then ``histogram("x")``
    raises): mixed-type metrics are always a bug, and catching it at
    the registration site beats a corrupt snapshot later."""

    def __init__(self, histogram_capacity: int = 4096):
        self.histogram_capacity = histogram_capacity
        self._instruments: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, **kw):
        inst = self._instruments.get(name)
        if inst is None:
            with self._lock:
                inst = self._instruments.get(name)
                if inst is None:
                    inst = cls(name, **kw)
                    self._instruments[name] = inst
        if not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} is a {type(inst).__name__}, "
                f"not a {cls.__name__}"
            )
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, capacity: int | None = None) -> Histogram:
        cap = capacity if capacity is not None else self.histogram_capacity
        return self._get(name, Histogram, capacity=cap)

    # -- hot-path shorthands ----------------------------------------------
    def inc(self, name: str, n: int = 1) -> None:
        self.counter(name).inc(n)

    def set(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v)

    # -- introspection -----------------------------------------------------
    def names(self, prefix: str = "") -> list[str]:
        with self._lock:
            return sorted(n for n in self._instruments if n.startswith(prefix))

    def get(self, name: str):
        return self._instruments.get(name)

    def snapshot(self, prefix: str = "") -> dict:
        """``{name: value-or-histogram-dict}`` for every instrument under
        ``prefix``. Plain data only — json-dumpable, no live references;
        per-instrument consistency (each snapshotted under its lock)."""
        with self._lock:
            items = [(n, i) for n, i in self._instruments.items()
                     if n.startswith(prefix)]
        return {n: inst.snapshot() for n, inst in sorted(items)}
