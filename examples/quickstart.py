"""Quickstart: build a proximity index over real text and run the paper's
worked example query "who are you who" end to end (Table 1 pipeline:
lemmatization -> sub-queries -> (f,s,t) evaluation -> combined ranking).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.index_builder import build_index
from repro.core.lemmatizer import lemmatize_text
from repro.core.lexicon import Lexicon
from repro.core.search import ProximitySearchEngine
from repro.data.corpus import TokenTable

DOCS = [
    "All was fresh around them, familiar and yet new, tinged with the beauty",
    "Who are you who said the stranger in the pale morning light",
    "The Who are an English rock band and Who are You is one of their songs",
    "You said that you are the one who was around these familiar places",
    "It was fresh and new, and the beauty of it was plain to all of them",
    "Are you the one? said the man. Who are you? You know who we are.",
] * 5  # small corpus; repetition stabilizes the FL-list


def main() -> None:
    lemmatized = [lemmatize_text(t) for t in DOCS]
    lexicon = Lexicon.build(lemmatized, sw_count=10, fu_count=8)
    print(f"lexicon: {lexicon.n_lemmas} lemmas over {lexicon.n_docs} docs")
    print("top of the FL-list:", lexicon.lemmas[:10])

    docs_ids = [[[lexicon.fl(a) for a in alts] for alts in doc] for doc in lemmatized]
    table = TokenTable.from_lemmatized(docs_ids)
    index = build_index(table, lexicon, max_distance=5)
    print("index:", index.size_report())

    engine = ProximitySearchEngine(index, top_k=10)
    for query in ("who are you who", "fresh and new", "the beauty of the morning"):
        results, stats = engine.search(query)
        print(f"\nquery: {query!r}  ({stats.seconds*1000:.2f} ms, "
              f"{stats.postings} postings, {stats.bytes_read} bytes)")
        for i in range(min(results.size, 3)):
            doc = int(results.doc[i]) % len(set(DOCS))
            print(f"  doc={int(results.doc[i])} [{int(results.start[i])},"
                  f"{int(results.end[i])}] score={float(results.score[i]):.3f}")
            print(f"    text: {DOCS[int(results.doc[i])][:70]}...")


if __name__ == "__main__":
    main()
