"""Full search stack: the paper's proximity index as the *retrieval*
stage, a two-tower model as the candidate scorer, and SASRec as the
sequential re-ranker — the production composition where this paper's
contribution lives (retrieval layer of a search/recommendation system).

Run:  PYTHONPATH=src python examples/search_pipeline.py
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.core.index_builder import build_index
from repro.core.search import ProximitySearchEngine
from repro.data.corpus import generate_corpus, sample_stop_queries
from repro.models import recsys


def main() -> None:
    # stage 1 — retrieval: the paper's proximity index over "documents"
    table, lex = generate_corpus(n_docs=1200, mean_doc_len=150, vocab_size=30_000, seed=4)
    index = build_index(table, lex, max_distance=5)
    retriever = ProximitySearchEngine(index, top_k=50, equalize_mode="bulk")
    queries = sample_stop_queries(table, lex, 8, window=3, seed=5)

    # stage 2 — ranker: SASRec (reduced) scores retrieved docs as "items"
    sas = get_arch("sasrec").reduced().model_cfg
    params = recsys.seqrec_init(sas, jax.random.key(0))
    rng = np.random.default_rng(0)

    t0 = time.time()
    for qi, q in enumerate(queries):
        cands, stats = retriever.search_ids(q)
        if cands.size == 0:
            print(f"q{qi}: no proximity matches")
            continue
        # treat doc ids (mod item vocab) as items; a user history drives
        # personalization of the retrieved set
        doc_items = np.unique(cands.doc.astype(np.int64) % sas.n_items)[:32]
        hist = rng.integers(0, sas.n_items, (1, sas.seq_len)).astype(np.int32)
        batch = {
            "hist": jnp.asarray(hist),
            "candidates": jnp.asarray(doc_items[None, :].astype(np.int32)),
        }
        scores = recsys.seqrec_score(sas, params, batch)
        order = np.argsort(-np.asarray(scores[0]))
        print(
            f"q{qi}: {cands.size} proximity hits ({stats.postings} postings read) "
            f"-> reranked top3 items: {doc_items[order[:3]].tolist()}"
        )
    print(f"pipeline wall: {time.time()-t0:.2f}s")


if __name__ == "__main__":
    main()
