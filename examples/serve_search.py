"""End-to-end driver (the paper's kind is serving): build a proximity
index over a synthetic Zipf collection, then serve batched requests
through the deadline-aware ``SearchService`` — the response-time
guarantee of the paper realized as compiled per-bucket steps, with the
per-query routing decision (`QueryPlan`) and the deadline verdict
surfaced on every response (DESIGN.md §14).

Run:  PYTHONPATH=src python examples/serve_search.py [--n-docs 3000] [--requests 256]
"""

import argparse
import time

import numpy as np

from repro.core.index_builder import build_index
from repro.data.corpus import generate_corpus, sample_mixed_queries, sample_stop_queries
from repro.launch.mesh import make_mesh
from repro.serving import SearchService, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=3000)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--max-distance", type=int, default=5)
    ap.add_argument("--compressed", action="store_true",
                    help="serve the delta-coded posting payload (DESIGN.md §11-§12)")
    ap.add_argument("--mixed", action="store_true",
                    help="mixed QT1-QT5 traffic through the query-type "
                         "dispatch (DESIGN.md §13) instead of all-stop-word "
                         "QT1 queries")
    ap.add_argument("--deadline-ms", type=float, default=50.0,
                    help="per-request budget (<= 0 disables deadlines)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the serving span trees as Chrome JSON "
                         "trace format (load in https://ui.perfetto.dev)")
    args = ap.parse_args()

    t0 = time.time()
    table, lex = generate_corpus(args.n_docs, mean_doc_len=160, vocab_size=40_000, seed=1)
    print(f"corpus: {table.n_rows} tokens, {table.n_docs} docs  ({time.time()-t0:.1f}s)")
    t0 = time.time()
    index = build_index(table, lex, max_distance=args.max_distance)
    print(f"index built (MaxDistance={args.max_distance}) in {time.time()-t0:.1f}s: "
          f"{len(index.fst.counts)} (f,s,t) keys, {len(index.wv.counts)} (w,v) keys")

    mesh = make_mesh((1, 1), ("data", "model"))
    deadline_s = args.deadline_ms / 1e3 if args.deadline_ms > 0 else None
    service = SearchService(index, mesh, ServeConfig(
        max_batch=64, top_k=8, compressed=args.compressed,
        default_deadline_s=deadline_s,
    ))

    if args.mixed:
        queries = sample_mixed_queries(table, lex, args.requests, window=3, seed=2)
    else:
        queries = sample_stop_queries(table, lex, args.requests, window=3, seed=2)

    # the planner answers routing questions without executing anything
    plan = service.explain(queries[0])
    print(f"\nexplain(first query): route={plan.route} step={plan.step_family} "
          f"L-bucket={plan.bucket} payload={plan.payload} "
          f"est_step_cost={plan.est_step_cost}")

    for round_name in ("cold", "warm"):  # warm: packed rows come from cache
        tickets = [service.submit(q) for q in queries]
        t0 = time.time()
        responses = service.drain()
        wall = time.time() - t0
        assert all(t.done for t in tickets)
        lat = np.array([r.latency_s for r in responses])
        n_hits = sum(1 for r in responses if r.results["doc"].size > 0)
        print(f"\n[{round_name}] served {len(responses)} requests in {wall:.2f}s "
              f"({len(responses)/wall:.1f} qps)")
        print(f"batch latency p50={np.percentile(lat,50)*1000:.1f}ms "
              f"p99={np.percentile(lat,99)*1000:.1f}ms")
        print(f"requests with hits: {n_hits}/{len(responses)}")
        # every response carries its §15 phase breakdown — where this
        # round's budget actually went, per request
        phase_ms = {
            ph: np.percentile([r.phases[ph] for r in responses], 50) * 1e3
            for ph in responses[0].phases
        }
        print("phase p50: " + "  ".join(
            f"{ph}={ms:.2f}ms" for ph, ms in phase_ms.items()))
        if deadline_s is not None:
            met = sum(1 for r in responses if r.deadline_met)
            waits = np.array([r.queue_wait_s for r in responses])
            blames = [r.deadline_blame for r in responses if r.deadline_blame]
            blame_note = (f"; misses blame "
                          f"{ {b: blames.count(b) for b in set(blames)} }"
                          if blames else "")
            print(f"deadline {args.deadline_ms:.0f}ms met: {met}/{len(responses)} "
                  f"({met/len(responses):.1%}); queue wait "
                  f"p50={np.percentile(waits,50)*1e3:.1f}ms{blame_note}")
    # stats_snapshot(): a deep, consistent copy — never read .stats
    # directly while another thread might be draining
    st = service.stats_snapshot()
    print(f"\nbucket histogram: {st['bucket_hist']}")
    print(f"batches: {st['batches']}  paths: {st['paths']}")
    print(f"plan routes: {st['plans']['routes']}  fallbacks: {st['plans']['fallbacks']}")
    print(f"compiled executables: {st['plans']['executables']} "
          f"(qt34-on-qt5 shared batches: {st['plans']['shared_batches']})")
    print(f"pack cache: {st['pack_cache']}")
    if args.compressed:
        print(f"compressed batches: {st['compressed_batches']} "
              f"(offsets fallbacks: {st['offset_fallbacks']})")
        print(f"compressed-row cache: {st['compressed_cache']}")
    # est_step_cost calibration (§15): measured µs per 1k estimated slots
    for key, row in sorted(st["plans"]["est_vs_measured"].items()):
        print(f"measured {key}: est={row['est_step_cost']} slots, "
              f"p50={row['measured_p50_us']:.0f}us "
              f"({row['us_per_kslot']:.1f} us/kslot, n={row['n']})")
    if args.trace_out:
        trace = service.write_trace(args.trace_out)
        print(f"wrote {len(trace['traceEvents'])} trace events to "
              f"{args.trace_out} (open in https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
