"""Train a small LM for a few hundred steps under the fault-tolerance
supervisor (checkpoint/restart + straggler detection), with an injected
mid-run failure to demonstrate exact recovery.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import shutil
import time

from repro.launch.train import train_arch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    t0 = time.time()
    report = train_arch(
        args.arch,
        "train_4k",
        steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=25,
        inject_failures={args.steps // 2: "simulated node loss"},
        reduced=True,  # reduced config: same architecture family, CPU-sized
    )
    print(
        f"\nsteps={report.steps_run} (restarts={report.restarts}, "
        f"stragglers={report.straggler_events})"
    )
    print(f"loss: {report.losses[0]:.4f} -> {report.losses[-1]:.4f} "
          f"({time.time()-t0:.1f}s)")
    assert report.losses[-1] < report.losses[0], "loss should improve"
    assert report.restarts == 1, "the injected failure should cause one restart"
    print("OK: loss improved across an injected failure + restart")


if __name__ == "__main__":
    main()
