"""Segmented incremental indexing end-to-end: stream documents into a
SegmentedIndex, delete some, watch size-tiered compaction fold segments
together, and serve QT1 queries from immutable snapshots through both the
CPU engine and the bucketed compiled JAX serve step — the live-refresh
loop a production deployment runs.

Run:  PYTHONPATH=src python examples/incremental_index.py
"""

import numpy as np

from repro.core.search import ProximitySearchEngine
from repro.data.corpus import generate_corpus, sample_stop_queries
from repro.index import SegmentedIndex
from repro.launch.mesh import make_mesh
from repro.serving import SearchService, ServeConfig


def main() -> None:
    table, lex = generate_corpus(n_docs=600, mean_doc_len=120, vocab_size=8000, seed=4)
    stream = table.to_doc_lists()
    queries = sample_stop_queries(table, lex, 8, window=3, seed=5)

    idx = SegmentedIndex(lex, max_distance=5, memtable_docs=64, tier_fanout=4)
    mesh = make_mesh((1, 1), ("data", "model"))
    serving = SearchService(idx, mesh, ServeConfig(buckets=(1024, 4096, 16384), top_k=8))

    rng = np.random.default_rng(0)
    alive: list[int] = []
    for round_no, lo in enumerate(range(0, len(stream), 150)):
        for doc in stream[lo : lo + 150]:
            alive.append(idx.add_document(doc))
        for _ in range(15):  # churn: delete 10% of this round's adds
            alive.remove(victim := int(rng.choice(alive)))
            idx.delete_document(victim)
        view = idx.refresh()
        serving.refresh()
        rep = view.size_report()
        print(
            f"round {round_no}: live_docs={rep['live_docs']} "
            f"segments={rep['n_segments']} tombstones={rep['tombstones']} "
            f"merges_so_far={idx.stats['merges']}"
        )
        engine = ProximitySearchEngine(view, top_k=8)
        q = queries[round_no % len(queries)]
        res, stats = engine.search_ids(q)
        serving.submit(q)
        (resp,) = serving.drain()
        hot = max(resp.phases, key=resp.phases.get)  # §15 phase breakdown
        print(
            f"  QT1 {q}: cpu {res.size} hits in {stats.seconds * 1e3:.2f} ms "
            f"({stats.bytes_read} B read), jax bucket={resp.bucket} "
            f"{resp.results['doc'].size} hits in {resp.latency_s * 1e3:.1f} ms "
            f"(dominant phase: {hot}={resp.phases[hot] * 1e3:.1f} ms)"
        )

    idx.compact(force=True)
    view = idx.refresh()
    print(
        f"after major compaction: segments={view.size_report()['n_segments']} "
        f"live_docs={view.size_report()['live_docs']}"
    )


if __name__ == "__main__":
    main()
