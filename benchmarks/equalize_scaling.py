"""Paper §2.3: the optimized two-heap Equalize vs the basic linear-scan
variant from [10] — per-step cost O(log n) vs O(n) in the number of
iterators — plus the vectorized bulk mode."""

from __future__ import annotations

import time

import numpy as np

from repro.core.equalize import EqualizeState, PostingIterator, bulk_align_docs, equalize_basic


def _make_lists(n_iters: int, n_postings: int, universe: int, seed: int):
    rng = np.random.default_rng(seed)
    return [
        np.unique(rng.integers(0, universe, n_postings).astype(np.int64))
        for _ in range(n_iters)
    ]


def _drain_heap(lists, gallop=True):
    iters = [PostingIterator(d, np.zeros_like(d)) for d in lists]
    st = EqualizeState(iters)
    n = 0
    while st.equalize(gallop=gallop) is not None:
        n += 1
        st.advance_all_past_doc()
    return n


def _drain_basic(lists):
    iters = [PostingIterator(d, np.zeros_like(d)) for d in lists]
    n = 0
    while (doc := equalize_basic(iters)) is not None:
        n += 1
        for it in iters:
            if not it.exhausted and it.value_id == doc:
                it.advance_past_doc()
    return n


def _drain_basic_nogallop(lists):
    """The [10] baseline as literally described: linear min/max scan and
    one IT.Next() per Equalize pass."""
    iters = [PostingIterator(d, np.zeros_like(d)) for d in lists]
    n = 0
    while True:
        ids = [it.value_id for it in iters]
        mx = max(ids)
        if mx == np.iinfo(np.int64).max:
            return n
        mn = min(ids)
        if mn == mx:
            n += 1
            for it in iters:
                if not it.exhausted and it.value_id == mn:
                    it.advance_past_doc()
            continue
        it = iters[ids.index(mn)]
        if not it.next():
            return n


def run(n_postings: int = 20_000, universe: int = 60_000, reps: int = 1):
    rows = []
    for n_iters in (2, 4, 8, 16, 32):
        lists = _make_lists(n_iters, n_postings, universe, n_iters)
        for name, fn in (
            ("heap", lambda: _drain_heap(lists)),
            ("heap_nogallop", lambda: _drain_heap(lists, gallop=False)),
            ("basic", lambda: _drain_basic(lists)),
            ("basic_nogallop", lambda: _drain_basic_nogallop(lists)),
            ("bulk", lambda: bulk_align_docs(lists).size),
        ):
            t0 = time.perf_counter()
            for _ in range(reps):
                fn()
            dt = (time.perf_counter() - t0) / reps
            rows.append((f"equalize/{name}_n{n_iters}", dt * 1e6, f"postings={n_postings}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
