"""Compiled QT1 serve-step throughput (single host device): the compiled
per-bucket latency IS the response-time guarantee (DESIGN.md §3)."""

from __future__ import annotations

import time

import numpy as np
import jax

from repro.core.index_builder import build_index
from repro.core.jax_search import make_qt1_serve_step, pack_qt1_batch
from repro.data.corpus import generate_corpus, sample_stop_queries
from repro.launch.mesh import make_mesh


def run():
    rows = []
    table, lex = generate_corpus(n_docs=1500, mean_doc_len=150, vocab_size=20_000, seed=3)
    idx = build_index(table, lex, max_distance=5)
    queries = sample_stop_queries(table, lex, 64, window=3, seed=5)
    mesh = make_mesh((1, 1), ("data", "model"))
    step = make_qt1_serve_step(mesh, top_k=16)
    for B, L in ((16, 4096), (64, 4096), (64, 16384)):
        qs = (queries * ((B // len(queries)) + 1))[:B]
        batch = pack_qt1_batch(idx, qs, L=L, K=2)
        args = batch.device_args()
        out = step(*args)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        reps = 10
        for _ in range(reps):
            out = step(*args)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / reps
        rows.append((
            f"serve/qt1_B{B}_L{L}", dt * 1e6,
            f"queries_per_s={B / dt:.1f};postings_per_s={B * 2 * L / dt:.3e}",
        ))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
