"""Serve-path benchmarks: compiled QT1-QT5 step latency per bucket
(the response-time guarantee, DESIGN.md §3/§12-§13) plus the host hot
path around it (DESIGN.md §11) — packed-posting-cache cold vs warm
packing, engine drains uncompressed vs warm-cache vs compressed
(re-encode-per-drain vs per-key compressed-row cache), per-type
cold/warm drains for every dispatch route, five-type mixed drains
through the query-type dispatch, the per-route plan statistics of the
planner layer (DESIGN.md §14), the cost-driven payload arbitration
report per typed route (``serve/payload_choice_*`` rows + the
``payload_choice`` report: measured arms, the chosen payload and the
warm ratio vs the raw engine, DESIGN.md §16), the per-phase latency
breakdown of the mixed stream (``serve/phase.*`` rows from the §15 metrics registry,
with the phase-sum-vs-e2e tiling check), and the deadline met-rate
curve of warm drains at 10/50/100 ms budgets through
``SearchService.submit(deadline_s=...)`` with per-miss phase blame
(``serve/deadline_miss_phase``). The met rate *under sustained offered
load* — the enforced guarantee, admission control on — is
benchmarks/load_bench.py's job (DESIGN.md §17).

``run()`` returns ``(rows, report)``: CSV rows for the harness and a
nested dict that ``benchmarks/run.py --json`` writes to BENCH_serve.json
so the perf trajectory is tracked across PRs.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.index_builder import build_index
from repro.core.jax_search import make_qt1_serve_step, pack_qt1_batch
from repro.data.corpus import (
    generate_corpus,
    sample_mixed_queries,
    sample_stop_queries,
    sample_typed_queries,
)
from repro.launch.mesh import make_mesh
from repro.serving import SearchService, ServeConfig
from repro.serving.pack_cache import PackedPostingCache


def _measure_drains(variants, queries, rounds: int) -> dict:
    """Median per-drain latency per variant, measured *interleaved*: one
    drain of each engine per round, so slow system drift over the
    measurement window is shared by all variants instead of being
    attributed to whichever ran last (the median additionally discards
    GC/scheduler outliers, which on a small CPU box can exceed the
    host-side effect under measurement). One unmeasured warmup drain
    each (jit compile + cache fill are reported separately)."""
    for _, eng in variants:
        for q in queries:
            eng.submit(q)
        eng.drain()
    samples = {name: [] for name, _ in variants}
    for _ in range(rounds):
        for name, eng in variants:
            for q in queries:
                eng.submit(q)
            t0 = time.perf_counter()
            eng.drain()
            samples[name].append(time.perf_counter() - t0)
    return {name: float(np.median(t)) * 1e6 for name, t in samples.items()}


def run(smoke: bool = False):
    rows = []
    rep: dict = {"step": {}, "pack": {}, "drain": {}}
    if smoke:
        n_docs, vocab, n_q, reps, rounds = 300, 4000, 16, 3, 3
        shapes = ((16, 1024),)
        eng_L, eng_B = 1024, 16
    else:
        n_docs, vocab, n_q, reps, rounds = 1500, 20_000, 64, 10, 8
        shapes = ((16, 4096), (64, 4096), (64, 16384))
        eng_L, eng_B = 4096, 64
    table, lex = generate_corpus(
        n_docs=n_docs, mean_doc_len=150, vocab_size=vocab, seed=3
    )
    idx = build_index(table, lex, max_distance=5)
    queries = sample_stop_queries(table, lex, n_q, window=3, seed=5)
    mesh = make_mesh((1, 1), ("data", "model"))

    # -- compiled step latency per (B, L) bucket ---------------------------
    step = make_qt1_serve_step(mesh, top_k=16)
    for B, L in shapes:
        qs = (queries * ((B // len(queries)) + 1))[:B]
        batch = pack_qt1_batch(idx, qs, L=L, K=2)
        args = batch.device_args()
        out = step(*args)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(reps):
            out = step(*args)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / reps
        rep["step"][f"B{B}_L{L}_us"] = dt * 1e6
        rows.append((
            f"serve/qt1_B{B}_L{L}", dt * 1e6,
            f"queries_per_s={B / dt:.1f};postings_per_s={B * 2 * L / dt:.3e}",
        ))

    # -- host packing: per-drain re-derivation vs warm cache row gathers ---
    # (interleaved for the same drift-sharing reason as _measure_drains)
    qs = (queries * ((eng_B // len(queries)) + 1))[:eng_B]
    cache = PackedPostingCache()
    pack_qt1_batch(idx, qs, L=eng_L, K=2, cache=cache)  # warm it
    cold = warm = 0.0
    for _ in range(reps):
        t0 = time.perf_counter()
        pack_qt1_batch(idx, qs, L=eng_L, K=2)
        cold += time.perf_counter() - t0
        t0 = time.perf_counter()
        pack_qt1_batch(idx, qs, L=eng_L, K=2, cache=cache)
        warm += time.perf_counter() - t0
    cold /= reps
    warm /= reps
    rep["pack"] = {
        "cold_us": cold * 1e6,
        "warm_us": warm * 1e6,
        "speedup": cold / warm,
        "cache": cache.stats,
    }
    rows.append((f"serve/pack_cold_B{eng_B}_L{eng_L}", cold * 1e6, ""))
    rows.append((
        f"serve/pack_warm_B{eng_B}_L{eng_L}", warm * 1e6,
        f"speedup_vs_cold={cold / warm:.2f};hit_rate={cache.stats['hit_rate']:.3f}",
    ))

    # -- engine drains: seed path vs warm cache vs compressed --------------
    # "compressed" is PR 2's re-encode-per-drain path (delta encoding runs
    # on every batch even at 100% pack-cache hit rate); "compressed_cached"
    # adds the per-key compressed-row cache (DESIGN.md §12)
    mk = lambda **kw: SearchService(  # noqa: E731
        idx, mesh, ServeConfig(buckets=(eng_L,), max_batch=eng_B, top_k=16, **kw)
    )
    variants = (
        ("uncached", mk(use_pack_cache=False)),
        ("cached", mk()),
        ("compressed", mk(compressed=True, use_compressed_cache=False)),
        ("compressed_cached", mk(compressed=True)),
    )
    lat = _measure_drains(variants, qs, rounds)
    for name, eng in variants:
        us = lat[name]
        st = eng.stats_snapshot()
        d = rep["drain"][name] = {"us": us, "per_query_us": us / eng_B}
        derived = f"per_query_us={us / eng_B:.1f}"
        if eng.pack_cache is not None:
            d["cache_hit_rate"] = st["pack_cache"]["hit_rate"]
            derived += f";cache_hit_rate={d['cache_hit_rate']:.3f}"
        if eng.config.compressed:
            d["offset_fallbacks"] = st["offset_fallbacks"]
            derived += f";offset_fallbacks={d['offset_fallbacks']}"
        if eng.compressed_cache is not None:
            d["compressed_cache_hit_rate"] = st["compressed_cache"]["hit_rate"]
            derived += f";ccache_hit_rate={d['compressed_cache_hit_rate']:.3f}"
        rows.append((f"serve/drain_{name}_B{eng_B}_L{eng_L}", us, derived))
    rep["drain"]["warm_vs_uncached_speedup"] = (
        rep["drain"]["uncached"]["us"] / rep["drain"]["cached"]["us"]
    )
    rep["drain"]["compressed_cache_speedup_offsets_regime"] = (
        rep["drain"]["compressed"]["us"] / rep["drain"]["compressed_cached"]["us"]
    )

    # -- compressed-row cache in the delta16 regime ------------------------
    # The quick corpus's g-range overflows uint16, so its compressed
    # drains above exercise the *offsets fallback* (cheap re-encode). The
    # headline format is delta16 — its per-drain re-encode is the costly
    # one the compressed-row cache eliminates — so the acceptance metric
    # is measured on a corpus whose g-range fits uint16 blocks. Shapes
    # (B, K, L) are identical: bucket padding makes step and encode cost
    # shape-bound, not corpus-bound.
    if smoke:
        didx, dqs = idx, qs  # the smoke corpus is already delta-friendly
    else:
        dtable, dlex = generate_corpus(
            n_docs=300, mean_doc_len=150, vocab_size=20_000, seed=3
        )
        didx = build_index(dtable, dlex, max_distance=5)
        dq = sample_stop_queries(dtable, dlex, n_q, window=3, seed=5)
        dqs = (dq * ((eng_B // len(dq)) + 1))[:eng_B]
    mkd = lambda **kw: SearchService(  # noqa: E731
        didx, mesh, ServeConfig(buckets=(eng_L,), max_batch=eng_B, top_k=16, **kw)
    )
    dvariants = (
        ("compressed_reencode", mkd(compressed=True, use_compressed_cache=False)),
        ("compressed_cached", mkd(compressed=True)),
    )
    dlat = _measure_drains(dvariants, dqs, rounds)
    rep["drain"]["delta_regime"] = {
        name: {"us": dlat[name], "per_query_us": dlat[name] / eng_B}
        for name, _ in dvariants
    }
    rep["drain"]["delta_regime"]["offset_fallbacks"] = (
        dvariants[1][1].stats_snapshot()["offset_fallbacks"]
    )
    rep["drain"]["compressed_cache_speedup"] = (
        dlat["compressed_reencode"] / dlat["compressed_cached"]
    )
    for name, _ in dvariants:
        rows.append((
            f"serve/drain_delta_{name}_B{eng_B}_L{eng_L}", dlat[name],
            f"per_query_us={dlat[name] / eng_B:.1f}",
        ))

    # -- typed + mixed drains through the query-type dispatch --------------
    typed = {
        "qt2": sample_typed_queries(table, lex, n_q, "qt2", window=3, seed=6),
        "qt3": sample_typed_queries(table, lex, n_q, "qt3", window=3, seed=9),
        "qt4": sample_typed_queries(table, lex, n_q, "qt4", window=3, seed=10),
        "qt5": sample_typed_queries(table, lex, n_q, "qt5", window=3, seed=7),
    }
    rep["drain_typed"] = {}
    rep["payload_choice"] = {}
    for tname, tqs in typed.items():
        tqs = (tqs * ((eng_B // max(len(tqs), 1)) + 1))[:eng_B] if tqs else tqs
        if not tqs:
            continue
        tcosts = None
        for cname, eng in (("", mk()), ("_compressed", mk(compressed=True))):
            for q in tqs:  # jit + cache warmup
                eng.submit(q)
            eng.drain()
            if cname == "_compressed":
                # Converge the §16 payload arbitration before measuring:
                # four unmeasured drains sample each arm once cache-warm
                # and once cache-cold (explore compressed x2, raw probe
                # x2), so the measured rounds below run on the argmin
                # choice rather than mid-exploration.
                for i in range(4):
                    if i % 2:
                        for c in (eng.pack_cache, eng.compressed_cache):
                            if c is not None:
                                c.clear()
                    for q in tqs:
                        eng.submit(q)
                    eng.drain()
            lats = {"cold": 0.0, "warm": 0.0}
            for _ in range(rounds):  # cold = jit-warm, cache-cold
                for c in (eng.pack_cache, eng.compressed_cache):
                    if c is not None:
                        c.clear()
                for phase in ("cold", "warm"):
                    for q in tqs:
                        eng.submit(q)
                    t0 = time.perf_counter()
                    eng.drain()
                    lats[phase] += time.perf_counter() - t0
            lats = {k: v / rounds * 1e6 for k, v in lats.items()}
            rep["drain_typed"][f"{tname}{cname}"] = lats
            for phase, us in lats.items():
                rows.append((
                    f"serve/drain_{tname}{cname}_{phase}_B{len(tqs)}_L{eng_L}",
                    us, f"per_query_us={us / len(tqs):.1f}",
                ))
            if cname == "_compressed":
                tcosts = eng.stats_snapshot()["plans"].get("payload_costs", {})
        # -- payload arbitration report (DESIGN.md §16): the compressed
        # engine's measured arms + choice, and its warm drain relative to
        # the raw engine's (acceptance: the cost-driven engine is never
        # >5% slower warm than the single-payload alternative)
        raw_lat = rep["drain_typed"].get(tname)
        arb_lat = rep["drain_typed"].get(f"{tname}_compressed")
        if raw_lat and arb_lat:
            ratio = arb_lat["warm"] / max(raw_lat["warm"], 1e-9)
            # acceptance: per measured route the chosen arm's EWMA is
            # within 5% of the alternative's (argmin guarantees <= 1.0
            # once converged; >1.05 means the model is serving a loser)
            arb_ok = all(
                v[v["chosen"]]["ewma_us_per_query"] <= 1.05 * v[
                    "raw" if v["chosen"] != "raw" else "compressed"
                ]["ewma_us_per_query"]
                for v in (tcosts or {}).values() if "chosen" in v
            )
            rep["payload_choice"][tname] = {
                "warm_raw_engine_us": raw_lat["warm"],
                "warm_compressed_engine_us": arb_lat["warm"],
                "warm_ratio_vs_raw_engine": ratio,
                "chosen_within_5pct_of_alt": arb_ok,
                "costs": tcosts,
            }
            chosen = ";".join(
                f"{route}={v['chosen']}" for route, v in sorted((tcosts or {}).items())
                if "chosen" in v
            )
            rows.append((
                f"serve/payload_choice_{tname}", arb_lat["warm"] / len(tqs),
                f"warm_ratio_vs_raw_engine={ratio:.3f};"
                f"chosen_within_5pct_of_alt={int(arb_ok)};"
                + (chosen or "chosen=exploring"),
            ))

    mixed = sample_mixed_queries(table, lex, eng_B, window=3, seed=8)
    mvariants = (
        ("mixed_uncached", mk(use_pack_cache=False)),
        ("mixed_cached", mk()),
        ("mixed_compressed_reencode", mk(compressed=True, use_compressed_cache=False)),
        ("mixed_compressed_cached", mk(compressed=True)),
    )
    mlat = _measure_drains(mvariants, mixed, rounds)
    rep["drain_mixed"] = {}
    for name, eng in mvariants:
        us = mlat[name]
        d = rep["drain_mixed"][name] = {"us": us, "per_query_us": us / len(mixed)}
        derived = f"per_query_us={us / len(mixed):.1f}"
        d["paths"] = eng.stats_snapshot()["paths"]
        rows.append((f"serve/drain_{name}_B{len(mixed)}_L{eng_L}", us, derived))
    rep["drain_mixed"]["compressed_cache_speedup"] = (
        rep["drain_mixed"]["mixed_compressed_reencode"]["us"]
        / rep["drain_mixed"]["mixed_compressed_cached"]["us"]
    )

    # -- phase-latency breakdown over the mixed stream (DESIGN.md §15) -----
    # Every SearchResponse carries a per-phase latency dict whose entries
    # tile [arrival, finished_at]; the registry accumulates the same
    # numbers as serve.phase.* histograms across every drain above. One
    # more captured warm drain checks the tiling invariant end to end:
    # per-request phase sums must agree with the e2e drain latency (the
    # acceptance bound is 10%; only the per-request plan timing overlaps
    # the queue window, and it is microseconds).
    meng = mvariants[1][1]  # mixed_cached: warm rows, all five types
    for q in mixed:
        meng.submit(q)
    presponses = meng.drain()
    psums = np.array([sum(r.phases.values()) for r in presponses])
    e2e = np.array([r.e2e_s for r in presponses])
    phase_err = float(np.max(np.abs(psums - e2e) / np.maximum(e2e, 1e-12)))
    phase_hists = meng.metrics_snapshot("serve.phase.")
    rep["phases"] = {
        "per_request_sum_vs_e2e_max_rel_err": phase_err,
        **{
            name.rsplit(".", 1)[-1]: {
                "p50_us": h["p50"], "p95_us": h["p95"], "count": h["count"],
            }
            for name, h in phase_hists.items()
        },
    }
    for name, h in sorted(phase_hists.items()):
        rows.append((
            f"serve/phase.{name.rsplit('.', 1)[-1]}", h["p50"],
            f"p95_us={h['p95']:.1f};count={h['count']};"
            f"sum_vs_e2e_max_rel_err={phase_err:.4f}",
        ))

    # -- planner layer: per-route plan stats + deadline_met_rate -----------
    # (DESIGN.md §14) The mixed cached engine exercised every dispatch
    # route; its plan stats record the route split, the compiled
    # executable count and how many qt34 batches rode qt5 executables
    # (dispatch-aware batching). The deadline drain re-submits the mixed
    # stream with a 50 ms budget on the warm engine — the met rate is
    # the response-time guarantee as a single observable number, and each
    # miss names the phase that blew the budget (§15 blame attribution).
    mstats = meng.stats_snapshot()
    rep["plans"] = {
        "routes": mstats["plans"]["routes"],
        "fallbacks": mstats["plans"]["fallbacks"],
        "executables": mstats["plans"]["executables"],
        "shared_batches": mstats["plans"]["shared_batches"],
        "est_vs_measured": mstats["plans"]["est_vs_measured"],
    }
    # One warm drain per budget (10/50/100 ms): the met-rate curve over
    # budgets separates "the budget is tight for this hardware" (10 ms)
    # from "the serving loop is broken" (100 ms) — a single point cannot.
    # rep["deadline"] keeps the 50 ms summary as its top-level fields
    # (the tracked headline) with the full curve under "budgets".
    rep["deadline"] = {"budgets": {}}
    total_missed = 0
    blame_all: dict = {}
    for budget_s in (0.010, 0.050, 0.100):
        blame_before = meng.stats_snapshot()["deadlines"]["miss_blame"]
        tickets = [meng.submit(q, deadline_s=budget_s) for q in mixed]
        meng.drain()
        met = sum(1 for t in tickets if t.response.deadline_met)
        met_rate = met / max(len(tickets), 1)
        waits = [t.response.queue_wait_s for t in tickets]
        blame_after = meng.stats_snapshot()["deadlines"]["miss_blame"]
        miss_blame = {
            k: v - blame_before.get(k, 0)
            for k, v in blame_after.items() if v > blame_before.get(k, 0)
        }
        total_missed += len(tickets) - met
        for k, v in miss_blame.items():
            blame_all[k] = blame_all.get(k, 0) + v
        entry = {
            "budget_ms": budget_s * 1e3,
            "met_rate": met_rate,
            "n": len(tickets),
            "queue_wait_p50_us": float(np.percentile(waits, 50)) * 1e6,
            "miss_blame": miss_blame,
        }
        ms = round(budget_s * 1e3)
        rep["deadline"]["budgets"][f"{ms}ms"] = entry
        if ms == 50:
            rep["deadline"].update(entry)
        rows.append((
            f"serve/deadline_met_rate_{ms}ms", met_rate,
            f"met={met}/{len(tickets)};routes={len(rep['plans']['routes'])};"
            f"executables={rep['plans']['executables']};"
            f"shared_batches={rep['plans']['shared_batches']}",
        ))
    rows.append((
        "serve/deadline_miss_phase", float(total_missed),
        ";".join(f"blame_{k}={v}" for k, v in sorted(blame_all.items()))
        or "blame_none=0",
    ))
    return rows, rep


if __name__ == "__main__":
    for name, us, derived in run()[0]:
        print(f"{name},{us:.1f},{derived}")
