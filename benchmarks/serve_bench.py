"""Serve-path benchmarks: compiled QT1 step latency per bucket (the
response-time guarantee, DESIGN.md §3) plus the host hot path around it
(DESIGN.md §11) — packed-posting-cache cold vs warm packing, and engine
drains uncompressed vs warm-cache vs compressed.

``run()`` returns ``(rows, report)``: CSV rows for the harness and a
nested dict that ``benchmarks/run.py --json`` writes to BENCH_serve.json
so the perf trajectory is tracked across PRs.
"""

from __future__ import annotations

import time

import jax

from repro.core.index_builder import build_index
from repro.core.jax_search import make_qt1_serve_step, pack_qt1_batch
from repro.data.corpus import generate_corpus, sample_stop_queries
from repro.launch.mesh import make_mesh
from repro.serving.engine import SearchServingEngine
from repro.serving.pack_cache import PackedPostingCache


def _measure_drains(variants, queries, rounds: int) -> dict:
    """Mean per-drain latency per variant, measured *interleaved*: one
    drain of each engine per round, so slow system drift over the
    measurement window is shared by all variants instead of being
    attributed to whichever ran last. One unmeasured warmup drain each
    (jit compile + cache fill are reported separately)."""
    for _, eng in variants:
        for q in queries:
            eng.submit(q)
        eng.drain()
    totals = {name: 0.0 for name, _ in variants}
    for _ in range(rounds):
        for name, eng in variants:
            for q in queries:
                eng.submit(q)
            t0 = time.perf_counter()
            eng.drain()
            totals[name] += time.perf_counter() - t0
    return {name: t / rounds * 1e6 for name, t in totals.items()}


def run(smoke: bool = False):
    rows = []
    rep: dict = {"step": {}, "pack": {}, "drain": {}}
    if smoke:
        n_docs, vocab, n_q, reps, rounds = 300, 4000, 16, 3, 3
        shapes = ((16, 1024),)
        eng_L, eng_B = 1024, 16
    else:
        n_docs, vocab, n_q, reps, rounds = 1500, 20_000, 64, 10, 8
        shapes = ((16, 4096), (64, 4096), (64, 16384))
        eng_L, eng_B = 4096, 64
    table, lex = generate_corpus(
        n_docs=n_docs, mean_doc_len=150, vocab_size=vocab, seed=3
    )
    idx = build_index(table, lex, max_distance=5)
    queries = sample_stop_queries(table, lex, n_q, window=3, seed=5)
    mesh = make_mesh((1, 1), ("data", "model"))

    # -- compiled step latency per (B, L) bucket ---------------------------
    step = make_qt1_serve_step(mesh, top_k=16)
    for B, L in shapes:
        qs = (queries * ((B // len(queries)) + 1))[:B]
        batch = pack_qt1_batch(idx, qs, L=L, K=2)
        args = batch.device_args()
        out = step(*args)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(reps):
            out = step(*args)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / reps
        rep["step"][f"B{B}_L{L}_us"] = dt * 1e6
        rows.append((
            f"serve/qt1_B{B}_L{L}", dt * 1e6,
            f"queries_per_s={B / dt:.1f};postings_per_s={B * 2 * L / dt:.3e}",
        ))

    # -- host packing: per-drain re-derivation vs warm cache row gathers ---
    # (interleaved for the same drift-sharing reason as _measure_drains)
    qs = (queries * ((eng_B // len(queries)) + 1))[:eng_B]
    cache = PackedPostingCache()
    pack_qt1_batch(idx, qs, L=eng_L, K=2, cache=cache)  # warm it
    cold = warm = 0.0
    for _ in range(reps):
        t0 = time.perf_counter()
        pack_qt1_batch(idx, qs, L=eng_L, K=2)
        cold += time.perf_counter() - t0
        t0 = time.perf_counter()
        pack_qt1_batch(idx, qs, L=eng_L, K=2, cache=cache)
        warm += time.perf_counter() - t0
    cold /= reps
    warm /= reps
    rep["pack"] = {
        "cold_us": cold * 1e6,
        "warm_us": warm * 1e6,
        "speedup": cold / warm,
        "cache": cache.stats,
    }
    rows.append((f"serve/pack_cold_B{eng_B}_L{eng_L}", cold * 1e6, ""))
    rows.append((
        f"serve/pack_warm_B{eng_B}_L{eng_L}", warm * 1e6,
        f"speedup_vs_cold={cold / warm:.2f};hit_rate={cache.stats['hit_rate']:.3f}",
    ))

    # -- engine drains: seed path vs warm cache vs compressed --------------
    mk = lambda **kw: SearchServingEngine(  # noqa: E731
        idx, mesh, buckets=(eng_L,), max_batch=eng_B, top_k=16, **kw
    )
    variants = (
        ("uncached", mk(use_pack_cache=False)),
        ("cached", mk()),
        ("compressed", mk(compressed=True)),
    )
    lat = _measure_drains(variants, qs, rounds)
    for name, eng in variants:
        us = lat[name]
        d = rep["drain"][name] = {"us": us, "per_query_us": us / eng_B}
        derived = f"per_query_us={us / eng_B:.1f}"
        if eng.pack_cache is not None:
            d["cache_hit_rate"] = eng.pack_cache.stats["hit_rate"]
            derived += f";cache_hit_rate={d['cache_hit_rate']:.3f}"
        if eng.compressed:
            d["offset_fallbacks"] = eng.stats["offset_fallbacks"]
            derived += f";offset_fallbacks={d['offset_fallbacks']}"
        rows.append((f"serve/drain_{name}_B{eng_B}_L{eng_L}", us, derived))
    rep["drain"]["warm_vs_uncached_speedup"] = (
        rep["drain"]["uncached"]["us"] / rep["drain"]["cached"]["us"]
    )
    return rows, rep


if __name__ == "__main__":
    for name, us, derived in run()[0]:
        print(f"{name},{us:.1f},{derived}")
