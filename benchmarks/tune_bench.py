"""Autotuner bench: successive-halving sweep of the joint
(MaxDistance, ServeConfig) space against realistic traffic, emitting
the best config as a deployable artifact (DESIGN.md §19).

The sweep tunes on the **mixed** five-type workload (the closest to
real traffic), then the winner is cross-evaluated against the default
ServeConfig on all four named workloads (zipfian / longtail /
stopflood / mixed) with warm closed-loop p50 — the headline rows:

* ``tune/sweep_candidates`` — size of the searched space (>= 2
  MaxDistance values x >= 8 serve configs, the CI floor);
* ``tune/best_score`` / ``tune/best_warm_p50_us`` — the winner's
  objective score and its measured warm p50 on the mixed workload;
* ``tune/p50@<workload>`` — the winner's warm p50 per workload, with
  the default config's p50 and the tuned/default ratio in ``derived``
  (``check_serve_regression.py`` guards ratio <= 1.10 in quick mode).

Measured p50s on a shared CI box are noisy, so the sweep carries the
default config as an explicit *incumbent* candidate and falls back to
it when the tuned winner loses to the default on two or more of the
four eval workloads (``winner_source = "incumbent_fallback"``) — the
emitted artifact is then simply the default, never a regression.

The winning (MaxDistance, ServeConfig) pair is written to
``results/tuned_serve_config.json`` (``launch/serve.py --config``
loads it) and the tuning workload trace to
``results/tune_workload_mixed.json`` (replayable via
``repro.tune.load_workload``). ``run()`` returns ``(rows, report)``
like every bench; the report lands in BENCH_serve.json under
``"tune"``.
"""

from __future__ import annotations

import dataclasses
import os

from repro.core.index_builder import build_index
from repro.data.corpus import generate_corpus
from repro.launch.mesh import make_mesh
from repro.serving import (
    SearchService,
    ServeConfig,
    poisson_arrivals,
    run_closed_loop,
    warm_service,
)
from repro.tune import (
    Candidate,
    Objective,
    emit_serve_config,
    grid,
    make_workload,
    measure_candidate,
    record_workload,
    sensitivity_table,
    sweep,
)
from repro.tune.sweep import make_estimator

DEADLINE_S = 0.05
MAX_DISTANCES = (3, 5)
DEFAULT_D = 5
WORKLOADS = ("zipfian", "longtail", "stopflood", "mixed")

# serve-time axes of the sweep (x MAX_DISTANCES = the searched space);
# a dict value sets several ServeConfig fields under one axis label
AXES = {
    "buckets": [(256, 1024, 4096, 16384, 65536),
                (1024, 4096, 16384, 65536)],
    "r_max": [2, 4],
    "share_buckets": [True, False],
    "admit_margin": [0.4, 0.7],
}

INCUMBENT = Candidate(max_distance=DEFAULT_D, overrides=(),
                      axis_values=(("config", "default"),))


def _axis_labels(axes: dict) -> dict:
    out = {}
    for name, values in axes.items():
        labels = []
        for v in values:
            if isinstance(v, dict):
                labels.append("+".join(f"{k}{x}" for k, x in sorted(v.items())))
            elif isinstance(v, (tuple, list)):
                labels.append("-".join(str(x) for x in v))
            else:
                labels.append(str(v))
        out[name] = labels
    return out


def run(smoke: bool = False):
    if smoke:
        n_docs, vocab, n_q = 300, 4000, 24
        eng_B = 8
        durations, keep, closed_n = (0.5, 1.0), (6, 3), 48
    else:
        n_docs, vocab, n_q = 800, 12_000, 32
        eng_B = 16
        durations, keep, closed_n = (0.75, 1.5), (8, 4), 96
    table, lex = generate_corpus(
        n_docs=n_docs, mean_doc_len=150, vocab_size=vocab, seed=3
    )
    indexes = {d: build_index(table, lex, max_distance=d)
               for d in MAX_DISTANCES}
    mesh = make_mesh((1, 1), ("data", "model"))
    base = ServeConfig(max_batch=eng_B, top_k=8, admission=True,
                       max_queue=4 * eng_B)
    objective = Objective(deadline_s=DEADLINE_S)

    workloads = {name: make_workload(name, table, lex, n_q, seed=21 + i)
                 for i, name in enumerate(WORKLOADS)}
    tune_wl = workloads["mixed"]

    # -- capacity probe (uncontrolled, warmed, closed loop): the sweep's
    # offered rate is a fixed fraction of this box's ceiling, so the
    # open-loop rungs are machine-independent
    probe_cfg = dataclasses.replace(base, admission=False, max_queue=None)
    probe = SearchService(indexes[DEFAULT_D], mesh, probe_cfg)
    warm_service(probe, tune_wl.queries)
    cap = run_closed_loop(probe, tune_wl.queries, 4 * n_q,
                          deadline_s=DEADLINE_S, batch=8 * eng_B)
    capacity_qps = cap.achieved_qps
    offered = 0.6 * capacity_qps

    candidates = grid(MAX_DISTANCES, AXES) + [INCUMBENT]
    rung_arrivals = [poisson_arrivals(offered, durations[0], seed=11),
                     poisson_arrivals(offered, durations[1], seed=12)]
    outcome = sweep(indexes, mesh, candidates, tune_wl, base=base,
                    objective=objective, rung_arrivals=rung_arrivals,
                    keep=keep)

    # sensitivity from a fresh estimate pass (pure planner — no device
    # work), so every candidate contributes to every axis
    estimator = make_estimator(indexes, mesh, base, tune_wl.queries,
                               objective)
    sens = sensitivity_table([(c, estimator(c)) for c in candidates])

    # -- cross-eval: tuned winner vs default config, warm closed-loop
    # p50 on every named workload
    def eval_p50(candidate: Candidate) -> dict:
        out = {}
        for name, wl in workloads.items():
            out[name] = measure_candidate(
                indexes[candidate.max_distance], mesh,
                candidate.serve_config(base), wl,
                deadline_s=DEADLINE_S, closed_n=closed_n)
        return out

    default_eval = eval_p50(INCUMBENT)
    winner, winner_source = outcome.winner, "sweep"
    if winner.config_id == INCUMBENT.config_id:
        tuned_eval, winner_source = default_eval, "incumbent"
    else:
        tuned_eval = eval_p50(winner)
        losses = sum(1 for n in WORKLOADS
                     if tuned_eval[n]["p50_us"] > default_eval[n]["p50_us"])
        if losses >= 2:
            # the measured winner does not generalize off the tuning
            # workload — ship the incumbent instead of a regression
            winner, winner_source = INCUMBENT, "incumbent_fallback"
            tuned_eval = default_eval

    winner_cfg = winner.serve_config(base)
    os.makedirs("results", exist_ok=True)
    artifact = emit_serve_config(
        "results/tuned_serve_config.json", winner.max_distance, winner_cfg,
        meta={"workload": "mixed", "config_id": winner.config_id,
              "source": winner_source, "mode": "smoke" if smoke else "quick",
              "sweep_best_score": outcome.winner_verdict["score"],
              "deadline_ms": DEADLINE_S * 1e3})
    record_workload(tune_wl, "results/tune_workload_mixed.json")

    rows = [(
        "tune/sweep_candidates", float(len(candidates)),
        f"max_distances={len(MAX_DISTANCES)};"
        f"serve_configs={len(candidates) // len(MAX_DISTANCES)};"
        f"rungs={1 + len(rung_arrivals)};keep={'-'.join(map(str, keep))}",
    ), (
        "tune/best_score", outcome.winner_verdict["score"],
        f"config={outcome.winner.config_id};source={winner_source};"
        f"met_rate={outcome.winner_verdict['met_rate']:.3f}",
    ), (
        "tune/best_warm_p50_us", tuned_eval["mixed"]["p50_us"],
        f"config={winner.config_id};workload=mixed;n={closed_n}",
    )]
    eval_rep = {}
    for name in WORKLOADS:
        t, d0 = tuned_eval[name]["p50_us"], default_eval[name]["p50_us"]
        ratio = t / d0 if d0 > 0 else 1.0
        eval_rep[name] = {"tuned": tuned_eval[name],
                          "default": default_eval[name], "ratio": ratio}
        rows.append((
            f"tune/p50@{name}", t,
            f"default_p50_us={d0:.1f};ratio={ratio:.3f};"
            f"config={winner.config_id}",
        ))

    rep = {
        "deadline_ms": DEADLINE_S * 1e3,
        "capacity_qps": capacity_qps,
        "offered_qps": offered,
        "space": {
            "max_distances": list(MAX_DISTANCES),
            "axes": _axis_labels(AXES),
            "n_candidates": len(candidates),
            "n_serve_configs": len(candidates) // len(MAX_DISTANCES),
        },
        "workloads": {name: wl.meta for name, wl in workloads.items()},
        "winner": {
            "config_id": winner.config_id,
            "max_distance": winner.max_distance,
            "source": winner_source,
            "serve_config": winner_cfg.to_json_dict(),
            "verdict": outcome.winner_verdict,
        },
        "history": outcome.history,
        "verdicts": outcome.verdicts,
        "sensitivity": sens,
        "eval": eval_rep,
        "artifact": "results/tuned_serve_config.json",
        "workload_trace": "results/tune_workload_mixed.json",
    }
    return rows, rep


if __name__ == "__main__":
    for name, val, derived in run(smoke=True)[0]:
        print(f"{name},{val:.1f},{derived}")
