"""Benchmark harness — one section per paper table/figure plus kernel and
serving micro-benches. Prints ``name,us_per_call,derived`` CSV.

Sections:
  search/*    — the paper's Idx1 vs Idx2/3/4 experiment (Figs. 6-9);
  equalize/*  — §2.3 heap vs basic Equalize scaling;
  kernel/*    — posting-intersection / proximity / embedding-bag ops;
  serve/*     — compiled QT1 serve-step latency per bucket;
  churn/*     — segmented-index throughput + latency under add/delete/
                merge churn (repro.index).

Quick mode (default) uses a reduced corpus; --full matches the corpus
scale used in EXPERIMENTS.md.
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="EXPERIMENTS.md-scale corpus")
    ap.add_argument("--only", default=None, help="comma-separated section filter")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    rows: list[tuple] = []

    def want(section: str) -> bool:
        return only is None or section in only

    if want("search"):
        from benchmarks import paper_experiments

        if args.full:
            rep = paper_experiments.run()
        else:
            rep = paper_experiments.run(n_docs=1200, mean_doc_len=140, n_queries=150,
                                        out_json="results/paper_experiments_quick.json")
        rows += paper_experiments.rows(rep)

    if want("equalize"):
        from benchmarks import equalize_scaling

        rows += equalize_scaling.run()

    if want("kernel"):
        from benchmarks import kernel_bench

        rows += kernel_bench.run()

    if want("serve"):
        from benchmarks import serve_bench

        rows += serve_bench.run()

    if want("churn"):
        from benchmarks import churn_bench

        if args.full:
            rep = churn_bench.run()
        else:
            rep = churn_bench.run(n_docs=400, chunk=40)
        rows += churn_bench.rows(rep)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
