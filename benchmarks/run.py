"""Benchmark harness — one section per paper table/figure plus kernel and
serving micro-benches. Prints ``name,us_per_call,derived`` CSV.

Sections:
  search/*    — the paper's Idx1 vs Idx2/3/4 experiment (Figs. 6-9);
  equalize/*  — §2.3 heap vs basic Equalize scaling;
  kernel/*    — posting-intersection / proximity / embedding-bag ops;
  serve/*     — compiled QT1 serve-step latency per bucket, packed-posting
                cache cold/warm packing, engine drains uncached/cached/
                compressed, and closed-loop deadline met-rates;
  load        — open-loop load (rows under serve/): controlled
                (admission on, §17) vs uncontrolled deadline met-rates
                at sustained/overload/bursty offered rates
                (benchmarks/load_bench.py);
  churn/*     — segmented-index throughput + latency under add/delete/
                merge churn (repro.index) with background compaction and
                live-memtable serving (§18), incl. serve-cache hit rate,
                refresh p95, and ingest docs/sec;
  tune/*      — §19 parameter autotuner: successive-halving sweep of
                the joint (MaxDistance, ServeConfig) space on the mixed
                workload, winner cross-evaluated vs the default config
                on zipfian/longtail/stopflood/mixed traffic and emitted
                to results/tuned_serve_config.json
                (benchmarks/tune_bench.py).

Quick mode (default) uses a reduced corpus; --full matches the corpus
scale used in EXPERIMENTS.md; --smoke is the tiny-corpus CI invocation.
``--json [PATH]`` writes the serve + churn reports (cache hit rates,
cold/warm drain latencies) to PATH (default BENCH_serve.json) so the
perf trajectory is tracked across PRs.
"""

from __future__ import annotations

import argparse
import json
import platform


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="EXPERIMENTS.md-scale corpus")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny corpus, few reps (CI smoke)")
    ap.add_argument("--only", default=None, help="comma-separated section filter")
    ap.add_argument("--json", nargs="?", const="BENCH_serve.json", default=None,
                    metavar="PATH",
                    help="write serve+churn reports as JSON (default %(const)s)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    rows: list[tuple] = []
    reports: dict = {}

    def want(section: str) -> bool:
        return only is None or section in only

    if want("search"):
        from benchmarks import paper_experiments

        if args.full:
            rep = paper_experiments.run()
        else:
            rep = paper_experiments.run(n_docs=1200, mean_doc_len=140, n_queries=150,
                                        out_json="results/paper_experiments_quick.json")
        rows += paper_experiments.rows(rep)

    if want("equalize"):
        from benchmarks import equalize_scaling

        rows += equalize_scaling.run()

    if want("kernel"):
        from benchmarks import kernel_bench

        rows += kernel_bench.run(smoke=args.smoke)

    if want("serve"):
        from benchmarks import serve_bench

        serve_rows, serve_rep = serve_bench.run(smoke=args.smoke)
        rows += serve_rows
        reports["serve"] = serve_rep

    if want("load"):
        from benchmarks import load_bench

        load_rows, load_rep = load_bench.run(smoke=args.smoke)
        rows += load_rows
        reports["load"] = load_rep

    if want("churn"):
        from benchmarks import churn_bench

        # background + live-memtable serving is the §18 default: refresh
        # seals and schedules, merges run on the CompactionExecutor
        if args.full:
            rep = churn_bench.run(serve=True, background=True, serve_memtable=True)
        elif args.smoke:
            rep = churn_bench.run(n_docs=150, chunk=40, memtable_docs=24, serve=True,
                                  background=True, serve_memtable=True)
        else:
            rep = churn_bench.run(n_docs=400, chunk=40, serve=True,
                                  background=True, serve_memtable=True)
        rows += churn_bench.rows(rep)
        reports["churn"] = rep

    if want("tune"):
        from benchmarks import tune_bench

        tune_rows, tune_rep = tune_bench.run(smoke=args.smoke)
        rows += tune_rows
        reports["tune"] = tune_rep

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if args.json:
        payload = {
            "python": platform.python_version(),
            "mode": "full" if args.full else ("smoke" if args.smoke else "quick"),
            "rows": [
                {"name": n, "us_per_call": us, "derived": d} for n, us, d in rows
            ],
            "reports": reports,
        }
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
