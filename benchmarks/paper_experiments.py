"""The paper's experiments (§3): Idx1 (ordinary inverted file) vs
Idx2/3/4 (additional indexes, MaxDistance = 5/7/9) on QT1 queries.

Reproduces the three headline tables/figures:
  * Fig. 6/8 — average query execution time;
  * Fig. 7/9 — average data read size per query;
  * postings processed per query.

The collection is synthetic Zipf (the paper's 71.5 GB fiction collection
is not available offline); the reproduction targets are the *ratios*
Idx1/IdxN and their dependence on MaxDistance (paper: 94.7x/69.4x/45.9x
time, 88x/55.9x/31.1x bytes, 193M vs 0.765M/1.251M/1.841M postings).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core.index_builder import build_index
from repro.core.search import InvertedIndexEngine, ProximitySearchEngine
from repro.data.corpus import generate_corpus, sample_stop_queries

DEFAULTS = dict(n_docs=6000, mean_doc_len=170, vocab_size=60_000, n_queries=975)


def run(n_docs=None, mean_doc_len=None, vocab_size=None, n_queries=None,
        distances=(5, 7, 9), seed=7, out_json="results/paper_experiments.json",
        equalize_mode="heap") -> dict:
    p = dict(DEFAULTS)
    for k, v in dict(n_docs=n_docs, mean_doc_len=mean_doc_len,
                     vocab_size=vocab_size, n_queries=n_queries).items():
        if v is not None:
            p[k] = v
    t0 = time.time()
    table, lex = generate_corpus(p["n_docs"], p["mean_doc_len"], p["vocab_size"], seed=seed)
    queries = sample_stop_queries(table, lex, p["n_queries"], window=3, seed=seed + 1)
    rep: dict = {
        "params": p,
        "corpus_tokens": int(table.n_rows),
        "sw_count": lex.sw_count,
        "fu_count": lex.fu_count,
        "n_queries": len(queries),
        "indexes": {},
    }

    def sweep(engine, label):
        t_sum = b_sum = p_sum = r_sum = 0.0
        for q in queries:
            res, stats = engine.search_ids(q)
            t_sum += stats.seconds
            b_sum += stats.bytes_read
            p_sum += stats.postings
            r_sum += res.size
        n = len(queries)
        return {
            "avg_time_s": t_sum / n,
            "avg_bytes": b_sum / n,
            "avg_postings": p_sum / n,
            "avg_results": r_sum / n,
            "total_time_s": t_sum,
        }

    # Idx1: ordinary inverted file (vectorized baseline — conservative for
    # us: a faithful 2008 per-posting loop would be far slower)
    t_build = time.time()
    idx1 = build_index(table, lex, max_distance=5, build_wv=False,
                       build_fst=False, build_nsw=False)
    rep["indexes"]["Idx1"] = {
        "build_s": time.time() - t_build,
        "max_distance": None,
        **sweep(InvertedIndexEngine(idx1, top_k=100), "Idx1"),
    }

    for i, d in enumerate(distances):
        t_build = time.time()
        idx = build_index(table, lex, max_distance=d)
        label = f"Idx{i + 2}"
        rep["indexes"][label] = {
            "build_s": time.time() - t_build,
            "max_distance": d,
            **sweep(ProximitySearchEngine(idx, top_k=100, equalize_mode=equalize_mode), label),
        }
        # bulk mode: vectorized engine, apples-to-apples with the
        # vectorized Idx1 baseline (paper-faithful heap mode carries
        # per-posting Python overhead the 2008 C++ engine didn't)
        bulk = sweep(ProximitySearchEngine(idx, top_k=100, equalize_mode="bulk"), label)
        rep["indexes"][label]["bulk_avg_time_s"] = bulk["avg_time_s"]
        del idx

    base = rep["indexes"]["Idx1"]
    for label, r in rep["indexes"].items():
        if label == "Idx1":
            continue
        r["time_speedup_vs_idx1"] = base["avg_time_s"] / max(r["avg_time_s"], 1e-12)
        if "bulk_avg_time_s" in r:
            r["bulk_time_speedup_vs_idx1"] = base["avg_time_s"] / max(r["bulk_avg_time_s"], 1e-12)
        r["bytes_reduction_vs_idx1"] = base["avg_bytes"] / max(r["avg_bytes"], 1e-9)
        r["postings_reduction_vs_idx1"] = base["avg_postings"] / max(r["avg_postings"], 1e-9)
    rep["wall_s"] = time.time() - t0
    if out_json:
        Path(out_json).parent.mkdir(parents=True, exist_ok=True)
        Path(out_json).write_text(json.dumps(rep, indent=1))
    return rep


def rows(rep: dict) -> list[tuple]:
    """CSV rows for benchmarks.run: name, us_per_call, derived."""
    out = []
    base = rep["indexes"]["Idx1"]
    out.append(("search/Idx1_avg_query", base["avg_time_s"] * 1e6,
                f"postings={base['avg_postings']:.0f};bytes={base['avg_bytes']:.0f}"))
    for label, r in rep["indexes"].items():
        if label == "Idx1":
            continue
        out.append((
            f"search/{label}_d{r['max_distance']}_avg_query",
            r["avg_time_s"] * 1e6,
            f"speedup={r['time_speedup_vs_idx1']:.1f}x;bytes_red={r['bytes_reduction_vs_idx1']:.1f}x;"
            f"postings_red={r['postings_reduction_vs_idx1']:.1f}x",
        ))
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int)
    ap.add_argument("--mean-doc-len", type=int)
    ap.add_argument("--n-queries", type=int)
    ap.add_argument("--out", default="results/paper_experiments.json")
    a = ap.parse_args()
    rep = run(n_docs=a.n_docs, mean_doc_len=a.mean_doc_len, n_queries=a.n_queries, out_json=a.out)
    for label, r in rep["indexes"].items():
        extra = ""
        if "time_speedup_vs_idx1" in r:
            extra = (f"  [{r['time_speedup_vs_idx1']:.1f}x faster, "
                     f"{r['bytes_reduction_vs_idx1']:.1f}x fewer bytes, "
                     f"{r['postings_reduction_vs_idx1']:.1f}x fewer postings]")
        print(
            f"{label}(d={r['max_distance']}): {r['avg_time_s']*1000:.2f} ms/query, "
            f"{r['avg_bytes']/1e6:.3f} MB/query, {r['avg_postings']:.0f} postings/query{extra}"
        )
