"""Bench-regression guard for the QT3-QT5 warm serve path (DESIGN.md §16).

Compares a freshly measured BENCH json against the committed
``BENCH_serve.json`` on the warm per-query medians of the guarded
routes (``serve/drain_qt{3,4,5}_warm_*`` rows) and fails when any fresh
number exceeds ``committed * tolerance``.

The default tolerance is deliberately loose (2.5x): the committed
numbers come from a different host than the CI runner, so the guard is
calibrated to catch *step-gap* regressions — e.g. the fused window join
silently falling back to the ~30x-slower per-key argsort path — not
single-digit-percent noise. Both files must carry the same ``mode``
(quick vs smoke vs full); on a mode mismatch the guard skips rather
than compare different corpus scales.

Usage:
    python benchmarks/check_serve_regression.py \
        --fresh BENCH_fresh.json --committed BENCH_serve.json [--tolerance 2.5]
"""

from __future__ import annotations

import argparse
import json
import sys

GUARDED_ROUTES = ("qt3", "qt4", "qt5")
DEFAULT_TOLERANCE = 2.5


def warm_per_query_us(payload: dict, route: str) -> float | None:
    """The per_query_us of the plain-engine warm drain row for a route."""
    prefix = f"serve/drain_{route}_warm_"
    for row in payload["rows"]:
        if row["name"].startswith(prefix):
            for part in row["derived"].split(";"):
                if part.startswith("per_query_us="):
                    return float(part.split("=", 1)[1])
    return None


def check(fresh: dict, committed: dict, tolerance: float) -> list[str]:
    if fresh.get("mode") != committed.get("mode"):
        print(f"benchmark modes differ (fresh={fresh.get('mode')!r}, "
              f"committed={committed.get('mode')!r}); guard skipped")
        return []
    failures = []
    for route in GUARDED_ROUTES:
        f = warm_per_query_us(fresh, route)
        c = warm_per_query_us(committed, route)
        if f is None or c is None:
            failures.append(f"{route}: warm drain row missing "
                            f"(fresh={f}, committed={c})")
            continue
        ratio = f / c
        ok = ratio <= tolerance
        print(f"{route}: warm per_query_us fresh={f:.1f} committed={c:.1f} "
              f"ratio={ratio:.2f} tolerance={tolerance:.2f} "
              f"[{'OK' if ok else 'REGRESSION'}]")
        if not ok:
            failures.append(f"{route}: {f:.1f}us > {tolerance:.2f}x "
                            f"committed {c:.1f}us")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True, help="freshly measured BENCH json")
    ap.add_argument("--committed", required=True, help="committed BENCH_serve.json")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE)
    args = ap.parse_args(argv)
    with open(args.fresh) as fh:
        fresh = json.load(fh)
    with open(args.committed) as fh:
        committed = json.load(fh)
    failures = check(fresh, committed, args.tolerance)
    if failures:
        print("serve bench regression:", *failures, sep="\n  ")
        return 1
    print("serve bench regression guard OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
