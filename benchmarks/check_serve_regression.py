"""Bench-regression guard for the QT3-QT5 warm serve path (DESIGN.md §16).

Compares a freshly measured BENCH json against the committed
``BENCH_serve.json`` on the warm per-query medians of the guarded
routes (``serve/drain_qt{3,4,5}_warm_*`` rows) and fails when any fresh
number exceeds ``committed * tolerance``.

The default tolerance is deliberately loose (2.5x): the committed
numbers come from a different host than the CI runner, so the guard is
calibrated to catch *step-gap* regressions — e.g. the fused window join
silently falling back to the ~30x-slower per-key argsort path — not
single-digit-percent noise. Both files must carry the same ``mode``
(quick vs smoke vs full); on a mode mismatch the guard skips rather
than compare different corpus scales.

It additionally enforces the §17 response-time SLO as an *absolute*
floor: any ``serve/deadline_met_rate_controlled@<rate>`` row
(benchmarks/load_bench.py) in a quick-mode file must be >= 0.95 — met
rates are host-independent because the offered rate scales with the
measured capacity of the box, so this check runs even when the two
files' modes differ.

And the §18 write-path SLO as an *absolute* ceiling: the
``churn/refresh_p95`` row (benchmarks/churn_bench.py) in a quick-mode
file must stay under 100 ms — with background compaction a refresh is
an O(memtable) seal-and-schedule, so a p95 anywhere near the ceiling
means merges have crept back onto the write path.

And the §19 autotuner guarantee: every quick-mode ``tune/p50@<workload>``
row (benchmarks/tune_bench.py) carries the default ServeConfig's warm
p50 in its derived column (``default_p50_us=``), and the tuned winner
must never be worse than that default by more than 10% on any workload
— both numbers come from the *same* run on the *same* host, so unlike
the cross-host latency ratios this check is tight. (The tuner's
incumbent fallback makes this structurally satisfiable: when the swept
winner does not generalize, the emitted config *is* the default.)

Usage:
    python benchmarks/check_serve_regression.py \
        --fresh BENCH_fresh.json --committed BENCH_serve.json [--tolerance 2.5]
"""

from __future__ import annotations

import argparse
import json
import sys

GUARDED_ROUTES = ("qt3", "qt4", "qt5")
DEFAULT_TOLERANCE = 2.5
# the §17 response-time SLO: every controlled open-loop met-rate row
# (serve/deadline_met_rate_controlled@<rate>, benchmarks/load_bench.py)
# must hold this floor in quick mode — unlike the warm-latency ratios
# this is an *absolute* check (a met rate is host-independent: the
# offered rate scales with the measured capacity of the box)
CONTROLLED_ROW_PREFIX = "serve/deadline_met_rate_controlled@"
MET_RATE_FLOOR = 0.95
# the §18 write-path SLO: with background compaction, refresh() is an
# O(memtable) seal-and-schedule — its quick-mode p95 (us_per_call of the
# churn/refresh_p95 row, benchmarks/churn_bench.py) must stay under
# 100 ms, an absolute ceiling loose enough to be host-independent
REFRESH_ROW = "churn/refresh_p95"
REFRESH_P95_CEILING_US = 100_000.0
# the §19 autotuner guarantee: a tuned config must never ship worse
# than the default it was searched against — tuned p50 vs the same-run
# default p50 (the default_p50_us= field of a tune/p50@<workload> row,
# benchmarks/tune_bench.py) within 10%, quick mode only
TUNE_ROW_PREFIX = "tune/p50@"
TUNE_P50_TOLERANCE = 1.10


def controlled_met_rates(payload: dict) -> list[tuple[str, float]]:
    """All controlled open-loop met-rate rows of a BENCH json."""
    return [(row["name"], float(row["us_per_call"]))
            for row in payload["rows"]
            if row["name"].startswith(CONTROLLED_ROW_PREFIX)]


def check_met_rate_slo(payload: dict, label: str) -> list[str]:
    """Absolute SLO check on whichever file carries load-bench rows.

    Skips silently when the payload has none (e.g. a fresh run with
    ``--only serve``) or is not quick mode — smoke corpora are too small
    for the met-rate to be meaningful as a hard floor."""
    if payload.get("mode") != "quick":
        return []
    failures = []
    for name, met in controlled_met_rates(payload):
        ok = met >= MET_RATE_FLOOR
        print(f"{label} {name}: met_rate={met:.3f} "
              f"floor={MET_RATE_FLOOR:.2f} [{'OK' if ok else 'VIOLATION'}]")
        if not ok:
            failures.append(f"{label} {name}: controlled met rate "
                            f"{met:.3f} < {MET_RATE_FLOOR:.2f}")
    return failures


def check_refresh_slo(payload: dict, label: str) -> list[str]:
    """Absolute §18 refresh-latency ceiling on quick-mode churn rows.

    Skips silently when the payload carries no ``churn/refresh_p95`` row
    (e.g. ``--only serve``) or is not quick mode."""
    if payload.get("mode") != "quick":
        return []
    failures = []
    for row in payload["rows"]:
        if row["name"] != REFRESH_ROW:
            continue
        p95 = float(row["us_per_call"])
        ok = p95 <= REFRESH_P95_CEILING_US
        print(f"{label} {REFRESH_ROW}: p95={p95 / 1e3:.1f}ms "
              f"ceiling={REFRESH_P95_CEILING_US / 1e3:.0f}ms "
              f"[{'OK' if ok else 'VIOLATION'}]")
        if not ok:
            failures.append(f"{label} {REFRESH_ROW}: refresh p95 "
                            f"{p95 / 1e3:.1f}ms > "
                            f"{REFRESH_P95_CEILING_US / 1e3:.0f}ms ceiling")
    return failures


def check_tune_slo(payload: dict, label: str) -> list[str]:
    """Tuned-vs-default p50 guard on quick-mode autotuner rows.

    Each ``tune/p50@<workload>`` row is self-contained (its derived
    column carries the same-run default p50), so the check applies to
    the fresh and committed files independently and skips silently when
    a payload carries no tune rows (e.g. ``--only serve``)."""
    if payload.get("mode") != "quick":
        return []
    failures = []
    for row in payload["rows"]:
        if not row["name"].startswith(TUNE_ROW_PREFIX):
            continue
        tuned = float(row["us_per_call"])
        default = None
        for part in row["derived"].split(";"):
            if part.startswith("default_p50_us="):
                default = float(part.split("=", 1)[1])
        if default is None or default <= 0.0:
            failures.append(f"{label} {row['name']}: no default_p50_us "
                            f"in derived ({row['derived']!r})")
            continue
        ratio = tuned / default
        ok = ratio <= TUNE_P50_TOLERANCE
        print(f"{label} {row['name']}: tuned={tuned:.1f}us "
              f"default={default:.1f}us ratio={ratio:.3f} "
              f"tolerance={TUNE_P50_TOLERANCE:.2f} "
              f"[{'OK' if ok else 'VIOLATION'}]")
        if not ok:
            failures.append(f"{label} {row['name']}: tuned p50 "
                            f"{tuned:.1f}us > {TUNE_P50_TOLERANCE:.2f}x "
                            f"default {default:.1f}us")
    return failures


def warm_per_query_us(payload: dict, route: str) -> float | None:
    """The per_query_us of the plain-engine warm drain row for a route."""
    prefix = f"serve/drain_{route}_warm_"
    for row in payload["rows"]:
        if row["name"].startswith(prefix):
            for part in row["derived"].split(";"):
                if part.startswith("per_query_us="):
                    return float(part.split("=", 1)[1])
    return None


def check(fresh: dict, committed: dict, tolerance: float) -> list[str]:
    # the absolute met-rate SLO does not need mode-matched files: it
    # judges each file on its own
    failures = (check_met_rate_slo(fresh, "fresh")
                + check_met_rate_slo(committed, "committed")
                + check_refresh_slo(fresh, "fresh")
                + check_refresh_slo(committed, "committed")
                + check_tune_slo(fresh, "fresh")
                + check_tune_slo(committed, "committed"))
    if fresh.get("mode") != committed.get("mode"):
        print(f"benchmark modes differ (fresh={fresh.get('mode')!r}, "
              f"committed={committed.get('mode')!r}); guard skipped")
        return failures
    for route in GUARDED_ROUTES:
        f = warm_per_query_us(fresh, route)
        c = warm_per_query_us(committed, route)
        if f is None or c is None:
            failures.append(f"{route}: warm drain row missing "
                            f"(fresh={f}, committed={c})")
            continue
        ratio = f / c
        ok = ratio <= tolerance
        print(f"{route}: warm per_query_us fresh={f:.1f} committed={c:.1f} "
              f"ratio={ratio:.2f} tolerance={tolerance:.2f} "
              f"[{'OK' if ok else 'REGRESSION'}]")
        if not ok:
            failures.append(f"{route}: {f:.1f}us > {tolerance:.2f}x "
                            f"committed {c:.1f}us")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True, help="freshly measured BENCH json")
    ap.add_argument("--committed", required=True, help="committed BENCH_serve.json")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE)
    args = ap.parse_args(argv)
    with open(args.fresh) as fh:
        fresh = json.load(fh)
    with open(args.committed) as fh:
        committed = json.load(fh)
    failures = check(fresh, committed, args.tolerance)
    if failures:
        print("serve bench regression:", *failures, sep="\n  ")
        return 1
    print("serve bench regression guard OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
