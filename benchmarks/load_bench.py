"""Open-loop load benchmark: the response-time guarantee under a fixed
offered rate (DESIGN.md §17).

serve_bench's deadline section measures met rate on a closed loop — the
driver waits for every drain, so the service can never fall behind and
the number says nothing about overload. This bench drives the same
mixed query stream **open-loop** (arrivals do not adapt to the
service, ``repro.serving.load``) against two engines on the *same
arrival trace*:

* ``uncontrolled`` — admission off: every request is queued and served,
  deadline misses are merely measured (the pre-§17 behaviour);
* ``controlled`` — ``ServeConfig(admission=True, max_queue=...)``: the
  §17 control loop fast-rejects infeasible budgets, sheds
  predicted-miss traffic under overload, degrades over-budget plans
  and EDF-splits urgent tails.

Offered rates are machine-independent: a closed-loop probe measures the
box's capacity on the warmed mix, and the open-loop traces offer a
fraction/multiple of it (sustained ~0.9x, overload 1.5x, plus a bursty
MMPP trace at the sustained mean). The headline acceptance row is
``serve/deadline_met_rate_controlled@1.5x`` — the controlled engine
holds the met-rate SLO (>= 0.99 among served requests) at an offered
rate where the uncontrolled engine collapses, with its shed/reject
rates reported alongside (shedding is the *mechanism* of the
guarantee, never hidden).

``run()`` returns ``(rows, report)`` like every bench; the report lands
in BENCH_serve.json under ``"load"``.
"""

from __future__ import annotations

from repro.core.index_builder import build_index
from repro.data.corpus import generate_corpus, sample_mixed_queries
from repro.launch.mesh import make_mesh
from repro.serving import (
    SearchService,
    ServeConfig,
    bursty_arrivals,
    poisson_arrivals,
    run_closed_loop,
    run_open_loop,
    warm_service,
)

DEADLINE_S = 0.05


def _mk(idx, mesh, eng_L, eng_B, **kw) -> SearchService:
    return SearchService(
        idx, mesh,
        ServeConfig(buckets=(eng_L // 4, eng_L), max_batch=eng_B, top_k=16,
                    **kw),
    )


def run(smoke: bool = False):
    rows = []
    if smoke:
        n_docs, vocab, n_q = 300, 4000, 16
        eng_L, eng_B = 1024, 16
        duration_s, probe_n = 1.0, 192
    else:
        n_docs, vocab, n_q = 1500, 20_000, 48
        eng_L, eng_B = 4096, 32
        duration_s, probe_n = 2.0, 512
    table, lex = generate_corpus(
        n_docs=n_docs, mean_doc_len=150, vocab_size=vocab, seed=3
    )
    idx = build_index(table, lex, max_distance=5)
    mesh = make_mesh((1, 1), ("data", "model"))
    queries = sample_mixed_queries(table, lex, n_q, window=3, seed=8)

    # -- capacity probe: closed loop on a warmed uncontrolled engine ---
    # (batch >> max_batch amortizes the per-drain overhead, so this is
    # the throughput ceiling open-loop traffic is offered against —
    # making the offered rates machine-independent)
    probe = _mk(idx, mesh, eng_L, eng_B)
    warm_service(probe, queries)
    cap = run_closed_loop(probe, queries, probe_n, deadline_s=DEADLINE_S,
                          batch=8 * eng_B)
    capacity_qps = cap.achieved_qps
    rep: dict = {
        "deadline_ms": DEADLINE_S * 1e3,
        "capacity_qps": capacity_qps,
        "closed_loop": cap.as_dict(),
        "traces": {},
    }
    rows.append((
        "serve/load_capacity_qps", capacity_qps,
        f"closed_loop_met={cap.met_rate:.3f};n={cap.n_offered}",
    ))

    # -- open-loop traces: controlled vs uncontrolled on the SAME trace
    traces = (
        ("poisson", "0.9x", poisson_arrivals(0.9 * capacity_qps, duration_s,
                                             seed=7)),
        ("poisson", "1.5x", poisson_arrivals(1.5 * capacity_qps, duration_s,
                                             seed=7)),
        ("bursty", "0.9x-bursty", bursty_arrivals(0.9 * capacity_qps,
                                                  duration_s, seed=7)),
    )
    for process, rate, arrivals in traces:
        # time-average over the trace window (an MMPP trace may end in
        # an off-phase, so arrivals[-1] would overstate the rate)
        offered = len(arrivals) / duration_s
        trace_rep: dict = {"offered_qps": offered, "n": len(arrivals)}
        for variant, eng in (
            ("uncontrolled", _mk(idx, mesh, eng_L, eng_B)),
            ("controlled", _mk(idx, mesh, eng_L, eng_B, admission=True,
                               max_queue=4 * eng_B)),
        ):
            warm_service(eng, queries)
            lrep = run_open_loop(eng, queries, arrivals,
                                 deadline_s=DEADLINE_S, process=process,
                                 offered_qps=offered)
            trace_rep[variant] = lrep.as_dict()
            if variant == "controlled":
                st = eng.stats_snapshot()
                trace_rep["admission"] = st["admission"]
            rows.append((
                f"serve/deadline_met_rate_{variant}@{rate}",
                lrep.met_rate,
                f"process={process};offered_qps={offered:.0f};"
                f"served={lrep.n_served}/{lrep.n_offered};"
                f"shed_rate={lrep.shed_rate:.3f};"
                f"reject_rate={lrep.reject_rate:.3f};"
                f"goodput_qps={lrep.achieved_qps:.0f};"
                f"met_rate_offered={lrep.met_rate_offered:.3f}",
            ))
        rep["traces"][f"poisson@{rate}" if process == "poisson"
                      else rate] = trace_rep

    # headline: the guarantee holds where the uncontrolled engine fails
    over = rep["traces"]["poisson@1.5x"]
    rep["controlled_met_rate_at_overload"] = over["controlled"]["met_rate"]
    rep["uncontrolled_met_rate_at_overload"] = over["uncontrolled"]["met_rate"]
    return rows, rep


if __name__ == "__main__":
    for name, val, derived in run()[0]:
        print(f"{name},{val:.3f},{derived}")
