"""Indexing throughput and query latency under add/delete/merge churn.

The paper's guarantee is defined over a static index; this bench measures
what the segmented subsystem (repro.index) preserves of it while the
corpus mutates: a writer streams document adds + tombstone deletes through
``SegmentedIndex`` (memtable seals and size-tiered merges run inline),
while queries are answered from immutable snapshots — optionally from a
concurrent reader thread, which is safe precisely because snapshots are
immutable.

Reported: indexing docs/sec (including seal+merge time), refresh latency,
and QT1 query latency p50/p95 sampled *during* churn, for both the CPU
``ProximitySearchEngine`` and (with --serve) the bucketed compiled JAX
serve path behind the refresh() protocol.

With ``--background`` (DESIGN.md §18) merges run on the rate-limited
``CompactionExecutor`` instead of inline in ``refresh()``: the writer's
``refresh(wait=False)`` seals the memtable and *schedules* merges, so
refresh latency is O(memtable) and ingest throughput no longer pays for
compaction on the write path. ``--serve-memtable`` additionally serves
the unsealed memtable live (``live_view()``) so adds are visible before
any refresh. The quiesce (final ``refresh(wait=True)``) is reported
separately as ``quiesce_s``.

Run directly (``python benchmarks/churn_bench.py``) or via
``benchmarks/run.py --only churn``.
"""

from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from repro.core.search import ProximitySearchEngine
from repro.data.corpus import generate_corpus, sample_stop_queries
from repro.index import SegmentedIndex


def _pct(xs, p):
    return float(np.percentile(np.asarray(xs), p)) if xs else float("nan")


def run(
    n_docs: int = 1200,
    mean_doc_len: int = 120,
    vocab_size: int = 8000,
    chunk: int = 60,
    delete_frac: float = 0.15,
    queries_per_round: int = 12,
    memtable_docs: int = 48,
    tier_fanout: int = 4,
    threads: bool = False,
    serve: bool = False,
    serve_compressed: bool = False,
    background: bool = False,
    serve_memtable: bool = False,
    seed: int = 3,
):
    table, lex = generate_corpus(
        n_docs=n_docs, mean_doc_len=mean_doc_len, vocab_size=vocab_size, seed=seed
    )
    docs = table.to_doc_lists()
    queries = sample_stop_queries(table, lex, 64, window=3, seed=seed + 1)
    rng = np.random.default_rng(seed + 2)

    seg = SegmentedIndex(
        lex, max_distance=5, memtable_docs=memtable_docs, tier_fanout=tier_fanout,
        background=background,
    )
    q_lat: list[float] = []
    refresh_lat: list[float] = []
    stop_flag = {"stop": False}

    def query_round(view, n):
        eng = ProximitySearchEngine(view, top_k=16)
        for _ in range(n):
            q = queries[int(rng.integers(0, len(queries)))]
            t0 = time.perf_counter()
            eng.search_ids(q)
            q_lat.append(time.perf_counter() - t0)

    reader = None
    if threads:

        def loop():
            while not stop_flag["stop"]:
                query_round(seg.snapshot(), 4)

        reader = threading.Thread(target=loop, daemon=True)

    serve_cold: list[float] = []
    serve_warm: list[float] = []
    serve_engine = None
    if serve or serve_compressed:
        from repro.launch.mesh import make_mesh
        from repro.serving import SearchService, ServeConfig

        mesh = make_mesh((1, 1), ("data", "model"))
        serve_engine = SearchService(seg, mesh, ServeConfig(
            buckets=(1024, 4096, 16384), max_batch=16, top_k=16,
            compressed=serve_compressed, serve_memtable=serve_memtable,
        ))

    alive: list[int] = []
    t_index = 0.0
    t_start = time.perf_counter()
    first = True
    for lo in range(0, len(docs), chunk):
        t0 = time.perf_counter()
        for d in docs[lo : lo + chunk]:
            alive.append(seg.add_document(d))
        n_del = int(len(alive) * delete_frac * chunk / max(len(docs), 1))
        for _ in range(min(n_del, max(len(alive) - 8, 0))):
            victim = alive.pop(int(rng.integers(0, len(alive))))
            seg.delete_document(victim)
        tr0 = time.perf_counter()
        # background: seal-and-schedule only (O(memtable)); foreground:
        # inline compaction to fixpoint as before
        view = seg.refresh(wait=False) if background else seg.refresh()
        refresh_lat.append(time.perf_counter() - tr0)
        t_index += time.perf_counter() - t0
        if first and reader is not None:
            reader.start()
            first = False
        if not threads:
            query_round(view, queries_per_round)
        if serve_engine is not None:
            serve_engine.refresh()
            # three drains per round: an unmeasured warmup drain absorbs
            # any one-time jit compile of a newly seen (B-bucket,
            # L-bucket) shape, then the pack cache is cleared — stop-word
            # queries share hot keys by design, so only an explicit clear
            # makes the first measured drain genuinely cache-cold (the
            # second is warm)
            qs = [queries[int(rng.integers(0, len(queries)))] for _ in range(4)]
            for q in qs:
                serve_engine.submit(q)
            serve_engine.drain()
            if serve_engine.pack_cache is not None:
                serve_engine.pack_cache.clear()
            for lat in (serve_cold, serve_warm):
                for q in qs:
                    serve_engine.submit(q)
                ts = time.perf_counter()
                serve_engine.drain()
                lat.append((time.perf_counter() - ts) / 4)
    stop_flag["stop"] = True
    if reader is not None:
        reader.join(timeout=10)
    quiesce_s = 0.0
    if background:
        tq = time.perf_counter()
        seg.refresh(wait=True)  # drain in-flight merges before reporting
        quiesce_s = time.perf_counter() - tq
    wall = time.perf_counter() - t_start

    rep = {
        "docs_indexed": len(docs),
        "docs_deleted": seg.stats["docs_deleted"],
        "seals": seg.stats["seals"],
        "merges": seg.stats["merges"],
        "final_segments": seg.n_segments,
        "docs_per_s": len(docs) / t_index,
        "wall_s": wall,
        "refresh_p50_ms": _pct(refresh_lat, 50) * 1e3,
        "refresh_p95_ms": _pct(refresh_lat, 95) * 1e3,
        "query_p50_ms": _pct(q_lat, 50) * 1e3,
        "query_p95_ms": _pct(q_lat, 95) * 1e3,
        "queries_during_churn": len(q_lat),
        "background": int(background),
        "serve_memtable": int(serve_memtable),
        "quiesce_s": quiesce_s,
    }
    if serve_engine is not None:
        rep["serve_cold_p50_ms"] = _pct(serve_cold, 50) * 1e3
        rep["serve_warm_p50_ms"] = _pct(serve_warm, 50) * 1e3
        rep["serve_p95_ms"] = _pct(serve_cold + serve_warm, 95) * 1e3
        rep["serve_compressed"] = int(serve_compressed)
        if serve_engine.pack_cache is not None:
            cs = serve_engine.stats_snapshot()["pack_cache"]
            rep["serve_cache_hit_rate"] = cs["hit_rate"]
            rep["serve_cache_hits"] = cs["hits"]
            rep["serve_cache_misses"] = cs["misses"]
            rep["serve_cache_invalidations"] = cs["invalidations"]
    seg.close()
    return rep


def rows(rep: dict) -> list[tuple]:
    derived = ";".join(
        f"{k}={rep[k]:.2f}" if isinstance(rep[k], float) else f"{k}={rep[k]}"
        for k in sorted(rep)
        if k not in ("query_p50_ms",)
    )
    mode = "bg" if rep.get("background") else "fg"
    tag = f"mode={mode};docs={rep['docs_indexed']}"
    return [
        ("churn/qt1_under_churn", rep["query_p50_ms"] * 1e3, derived),
        # us_per_call column carries the refresh p95 in microseconds —
        # the §18 write-path SLO guarded by check_serve_regression.py
        ("churn/refresh_p95", rep["refresh_p95_ms"] * 1e3,
         f"{tag};refresh_p50_ms={rep['refresh_p50_ms']:.2f}"),
        # value column is docs/sec here (not microseconds), same
        # convention as the load-bench met-rate rows
        ("churn/ingest_docs_per_s", rep["docs_per_s"],
         f"{tag};quiesce_s={rep['quiesce_s']:.2f};merges={rep['merges']}"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--docs", type=int, default=1200)
    ap.add_argument("--doc-len", type=int, default=120)
    ap.add_argument("--vocab", type=int, default=8000)
    ap.add_argument("--chunk", type=int, default=60)
    ap.add_argument("--delete-frac", type=float, default=0.15)
    ap.add_argument("--memtable-docs", type=int, default=48)
    ap.add_argument("--tier-fanout", type=int, default=4)
    ap.add_argument("--threads", action="store_true",
                    help="query from a concurrent reader thread")
    ap.add_argument("--serve", action="store_true",
                    help="also drive the compiled JAX serve path")
    ap.add_argument("--serve-compressed", action="store_true",
                    help="serve via the compressed posting payload")
    ap.add_argument("--background", action="store_true",
                    help="merge on the background CompactionExecutor (§18)")
    ap.add_argument("--serve-memtable", action="store_true",
                    help="serve the unsealed memtable live (live_view())")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-corpus CI invocation (overrides size args)")
    args = ap.parse_args()
    if args.smoke:
        args.docs, args.chunk, args.memtable_docs = 150, 40, 24
    rep = run(
        n_docs=args.docs,
        mean_doc_len=args.doc_len,
        vocab_size=args.vocab,
        chunk=args.chunk,
        delete_frac=args.delete_frac,
        memtable_docs=args.memtable_docs,
        tier_fanout=args.tier_fanout,
        threads=args.threads,
        serve=args.serve,
        serve_compressed=args.serve_compressed,
        background=args.background,
        serve_memtable=args.serve_memtable,
    )
    for k in sorted(rep):
        v = rep[k]
        print(f"{k}: {v:.3f}" if isinstance(v, float) else f"{k}: {v}")


if __name__ == "__main__":
    main()
