"""Bench-coverage gate: a generated (or committed) BENCH_serve.json
must keep one row / report entry per subsystem the serving stack has
grown — dispatch routes, planner layer, phase observability, nearest-r
kernels, payload choice, the §17 load control loop, the §18 ingest
tier, and the §19 autotuner.

This replaces the inline python heredoc the CI workflow used to carry
(and that tests/test_docs.py partially duplicated): every check lives
here once, grouped by section name matching ``benchmarks/run.py
--only`` sections. Checkers return failure-message lists instead of
raising, so one run reports *every* hole. Pure stdlib, so the lint job
(no jax) can import it.

  python benchmarks/check_bench_coverage.py --json BENCH_smoke.json \
      --sections serve,kernel,load,churn,tune
"""

from __future__ import annotations

import argparse
import json
import sys


def _names(payload) -> set:
    return {r["name"] for r in payload["rows"]}


def _rows(payload) -> dict:
    return {r["name"]: r for r in payload["rows"]}


def check_serve(payload) -> list[str]:
    """Dispatch routes + §14 planner layer + §15 phases + §16 payload
    choice + multi-budget deadline rows (serve_bench)."""
    f: list[str] = []
    rep = payload.get("reports", {}).get("serve")
    if rep is None:
        return ["serve: no reports.serve section"]
    names = _names(payload)
    rows = _rows(payload)

    def need(cond, msg):
        if not cond:
            f.append(f"serve: {msg}")

    need("compressed_cache_speedup" in rep.get("drain", {}),
         "drain report lacks compressed_cache_speedup")
    need("compressed_cache_speedup" in rep.get("drain_mixed", {}),
         "drain_mixed report lacks compressed_cache_speedup")
    for want in ("drain_qt2_", "drain_qt3_", "drain_qt4_", "drain_qt5_",
                 "drain_mixed_", "deadline_met_rate"):
        need(any(want in n for n in names), f"no row matching {want!r}")
    typed = rep.get("drain_typed", {})
    for key in ("qt3", "qt4", "qt3_compressed", "qt4_compressed"):
        need({"cold", "warm"} <= typed.get(key, {}).keys(),
             f"drain_typed[{key!r}] lacks cold/warm")
    # §14 planner layer: deadline_met_rate + per-route plan stats
    need({"budget_ms", "met_rate", "n"} <= rep.get("deadline", {}).keys(),
         "deadline report lacks budget_ms/met_rate/n")
    plans = rep.get("plans", {})
    for route in ("qt1", "qt2", "qt34", "qt5", "scalar"):
        need(route in plans.get("routes", {}), f"no plan route {route!r}")
    need("executables" in plans and "shared_batches" in plans,
         "plans report lacks executables/shared_batches")
    # §15 observability: per-phase p50/p95 rows + deadline blame
    phases = rep.get("phases", {})
    for ph in ("queue", "plan", "pack", "compress", "execute", "decode"):
        row = rows.get(f"serve/phase.{ph}")
        need(row is not None and "p95_us=" in row["derived"],
             f"no serve/phase.{ph} row with p95_us")
        stats = phases.get(ph, {})
        need(stats.get("p95_us", -1.0) >= stats.get("p50_us", 0.0) >= 0.0,
             f"phase {ph!r} p50/p95 missing or inverted")
    need(phases.get("per_request_sum_vs_e2e_max_rel_err", 1.0) < 0.10,
         "phase tiling error >= 10%")
    need("serve/deadline_miss_phase" in names, "no deadline_miss_phase row")
    need("miss_blame" in rep.get("deadline", {}), "no miss_blame attribution")
    need(plans.get("est_vs_measured"), "est_vs_measured table empty")
    # §16 cost-driven payload report
    for want in ("serve/payload_choice_qt3", "serve/payload_choice_qt4",
                 "serve/payload_choice_qt5"):
        need(any(n.startswith(want) for n in names), f"no row {want!r}")
    pc = rep.get("payload_choice", {})
    for route in ("qt3", "qt4", "qt5"):
        entry = pc.get(route, {})
        need(entry.get("warm_ratio_vs_raw_engine", 0.0) > 0.0,
             f"payload_choice[{route!r}] lacks warm ratio")
        need(entry.get("chosen_within_5pct_of_alt"),
             f"payload_choice[{route!r}] chosen payload not within 5% of alt")
    # §17 multi-budget closed-loop rows
    for ms in (10, 50, 100):
        need(f"serve/deadline_met_rate_{ms}ms" in names,
             f"no deadline_met_rate_{ms}ms row")
        need(f"{ms}ms" in rep.get("deadline", {}).get("budgets", {}),
             f"no {ms}ms budget in deadline report")
    return f


def check_kernel(payload) -> list[str]:
    """§16 nearest-r kernel rows incl. the Pallas interpret spot-check
    (kernel_bench)."""
    f: list[str] = []
    names = _names(payload)
    for want in ("kernel/nearest_r_ref_", "kernel/nearest_r_count_",
                 "kernel/nearest_r_pallas_interp_"):
        if not any(n.startswith(want) for n in names):
            f.append(f"kernel: no row matching {want!r}")
    pallas = [r for r in payload["rows"]
              if r["name"].startswith("kernel/nearest_r_pallas_interp_")]
    if pallas and "bit_identical_to_ref=1" not in pallas[0]["derived"]:
        f.append("kernel: pallas interpret row not bit-identical to ref")
    return f


def check_load(payload) -> list[str]:
    """§17 open-loop control loop: capacity probe + controlled vs
    uncontrolled met-rates on a shared trace (load_bench)."""
    f: list[str] = []
    lrep = payload.get("reports", {}).get("load")
    if lrep is None:
        return ["load: no reports.load section"]
    names = _names(payload)
    rows = _rows(payload)
    if not lrep.get("capacity_qps", 0.0) > 0.0:
        f.append("load: capacity_qps not positive")
    for want in ("serve/load_capacity_qps",
                 "serve/deadline_met_rate_controlled@1.5x",
                 "serve/deadline_met_rate_uncontrolled@1.5x",
                 "serve/deadline_met_rate_controlled@0.9x-bursty"):
        if want not in names:
            f.append(f"load: no row {want!r}")
    ctl = rows.get("serve/deadline_met_rate_controlled@1.5x")
    if ctl is not None:
        for key in ("shed_rate=", "reject_rate=", "goodput_qps="):
            if key not in ctl["derived"]:
                f.append(f"load: controlled@1.5x row lacks {key!r}")
    over = lrep.get("traces", {}).get("poisson@1.5x", {})
    ctl_met = over.get("controlled", {}).get("met_rate")
    unc_met = over.get("uncontrolled", {}).get("met_rate")
    if ctl_met is None or unc_met is None:
        f.append("load: overload trace lacks controlled/uncontrolled reports")
    elif ctl_met < unc_met:
        f.append(f"load: controlled met_rate {ctl_met:.3f} < "
                 f"uncontrolled {unc_met:.3f} at overload")
    if "admission" not in over:
        f.append("load: overload trace lacks admission stats")
    return f


def check_churn(payload) -> list[str]:
    """§18 ingest tier: churn ran with background compaction +
    live-memtable serving and at least one off-path merge
    (churn_bench)."""
    f: list[str] = []
    crep = payload.get("reports", {}).get("churn")
    if crep is None:
        return ["churn: no reports.churn section"]
    names = _names(payload)
    if not (crep.get("background") == 1 and crep.get("serve_memtable") == 1):
        f.append("churn: not run with background compaction + live memtable")
    if not crep.get("merges", 0) >= 1:
        f.append("churn: no merge ran off-path")
    for want in ("churn/qt1_under_churn", "churn/refresh_p95",
                 "churn/ingest_docs_per_s"):
        if want not in names:
            f.append(f"churn: no row {want!r}")
    return f


TUNE_WORKLOADS = ("zipfian", "longtail", "stopflood", "mixed")


def check_tune(payload) -> list[str]:
    """§19 autotuner: the sweep searched >= 2 MaxDistance values x >= 8
    serve configs, emitted a winner (config + verdict + sensitivity),
    and cross-evaluated it vs the default on every named workload
    (tune_bench)."""
    f: list[str] = []
    trep = payload.get("reports", {}).get("tune")
    if trep is None:
        return ["tune: no reports.tune section"]
    rows = _rows(payload)
    for want in ("tune/sweep_candidates", "tune/best_score",
                 "tune/best_warm_p50_us"):
        if want not in rows:
            f.append(f"tune: no row {want!r}")
    for name in TUNE_WORKLOADS:
        row = rows.get(f"tune/p50@{name}")
        if row is None:
            f.append(f"tune: no row tune/p50@{name}")
            continue
        for key in ("default_p50_us=", "ratio="):
            if key not in row["derived"]:
                f.append(f"tune: p50@{name} row lacks {key!r}")
    space = trep.get("space", {})
    if len(space.get("max_distances", [])) < 2:
        f.append(f"tune: swept < 2 MaxDistance values ({space})")
    if space.get("n_serve_configs", 0) < 8:
        f.append(f"tune: swept < 8 serve configs ({space})")
    winner = trep.get("winner", {})
    for key in ("config_id", "serve_config", "source", "verdict"):
        if key not in winner:
            f.append(f"tune: winner report lacks {key!r}")
    if not trep.get("verdicts"):
        f.append("tune: no per-config objective verdicts")
    if not trep.get("sensitivity"):
        f.append("tune: no sensitivity table")
    if not trep.get("history"):
        f.append("tune: no halving history")
    missing = [w for w in TUNE_WORKLOADS
               if w not in trep.get("workloads", {})]
    if missing:
        f.append(f"tune: workload meta missing {missing}")
    return f


SECTIONS = {
    "serve": check_serve,
    "kernel": check_kernel,
    "load": check_load,
    "churn": check_churn,
    "tune": check_tune,
}


def check_payload(payload, sections) -> list[str]:
    """All failure messages from the named section checkers (empty ==
    the payload passes)."""
    failures: list[str] = []
    for name in sections:
        checker = SECTIONS.get(name)
        if checker is None:
            failures.append(f"unknown section {name!r} "
                            f"(have {sorted(SECTIONS)})")
            continue
        failures += checker(payload)
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_smoke.json", metavar="PATH")
    ap.add_argument("--sections", default=",".join(sorted(SECTIONS)),
                    help="comma-separated section subset (default: all)")
    args = ap.parse_args(argv)
    with open(args.json) as fh:
        payload = json.load(fh)
    sections = [s for s in args.sections.split(",") if s]
    failures = check_payload(payload, sections)
    if failures:
        for msg in failures:
            print(f"FAIL {msg}")
        return 1
    print(f"bench coverage OK: {len(_names(payload))} rows, "
          f"sections {','.join(sections)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
