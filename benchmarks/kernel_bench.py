"""Kernel micro-benchmarks: jitted oracle throughput on CPU + Pallas
(interpret) correctness spot-check per shape. Wall-times on this host are
CPU numbers; the TPU story is in the roofline analysis."""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.intersect.ref import intersect_mask_ref
from repro.kernels.proximity.ref import proximity_join_ref


def _timeit(fn, *args, reps=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run():
    rows = []
    rng = np.random.default_rng(0)
    jit_int = jax.jit(intersect_mask_ref)
    jit_prox = jax.jit(lambda a, b: proximity_join_ref(a, b, 5))
    jit_bag = jax.jit(embedding_bag_ref)
    for n, m in ((16_384, 65_536), (131_072, 1_048_576)):
        a = jnp.asarray(np.unique(rng.integers(0, 4 * m, n)).astype(np.int32))
        b = jnp.asarray(np.unique(rng.integers(0, 4 * m, m)).astype(np.int32))
        dt = _timeit(jit_int, a, b)
        rows.append((f"kernel/intersect_ref_{n}x{m}", dt * 1e6,
                     f"postings_per_s={(n + m) / dt:.3e}"))
        dt = _timeit(jit_prox, a, b)
        rows.append((f"kernel/proximity_ref_{n}x{m}", dt * 1e6,
                     f"postings_per_s={(n + m) / dt:.3e}"))
    for B, S, V, D in ((4096, 50, 100_000, 64),):
        ids = jnp.asarray(rng.integers(-1, V, (B, S)).astype(np.int32))
        tbl = jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))
        dt = _timeit(jit_bag, ids, tbl)
        rows.append((f"kernel/embedding_bag_ref_B{B}", dt * 1e6,
                     f"lookups_per_s={B * S / dt:.3e}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
