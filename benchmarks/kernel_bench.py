"""Kernel micro-benchmarks: jitted oracle throughput on CPU + Pallas
(interpret) correctness spot-check per shape. Wall-times on this host are
CPU numbers; the TPU story is in the roofline analysis."""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.intersect.ref import intersect_mask_ref
from repro.kernels.nearest_r import window_join
from repro.kernels.nearest_r.ref import window_join_ref
from repro.kernels.proximity.ref import proximity_join_ref


def _timeit(fn, *args, reps=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _sorted_rows(rng, shape, max_step=3):
    """Strictly increasing int32 rows — the posting-row precondition of
    the nearest-r join."""
    return np.cumsum(rng.integers(1, max_step + 1, shape), axis=-1).astype(np.int32)


def _nearest_r_rows(rng, smoke):
    """Fused window-join rows: argsort baseline vs sort-free counting
    path at a serve-representative shape, plus the Pallas kernel in
    interpret mode at a tiny shape (a correctness spot-check on CPU; the
    compiled-TPU story is DESIGN.md §16)."""
    rows = []
    max_sep, r_max = 5, 4
    shapes = ((8, 256, 2),) if smoke else ((64, 4096, 3),)
    jit_ref = jax.jit(lambda a, n, r: window_join_ref(a, n, r, max_sep=max_sep, r_max=r_max))
    jit_cnt = jax.jit(lambda a, n, r: window_join(a, n, r, max_sep=max_sep, r_max=r_max))
    for B, L, K in shapes:
        a = jnp.asarray(_sorted_rows(rng, (B, L)))
        ns = jnp.asarray(_sorted_rows(rng, (B, K, L)))
        ns_r = jnp.asarray(rng.integers(1, r_max + 1, (B, K)).astype(np.int32))
        reps = 20 if smoke else 5
        dt_ref = _timeit(jit_ref, a, ns, ns_r, reps=reps)
        rows.append((f"kernel/nearest_r_ref_B{B}xL{L}K{K}", dt_ref * 1e6,
                     f"anchors_per_s={B * L / dt_ref:.3e}"))
        dt = _timeit(jit_cnt, a, ns, ns_r, reps=reps)
        rows.append((f"kernel/nearest_r_count_B{B}xL{L}K{K}", dt * 1e6,
                     f"speedup_vs_ref={dt_ref / dt:.2f}x"))
    # Pallas interpret: tiny shape, verified bit-identical on valid lanes
    B, L, K = 2, 64, 2
    a = jnp.asarray(_sorted_rows(rng, (B, L)))
    ns = jnp.asarray(_sorted_rows(rng, (B, K, L)))
    ns_r = jnp.asarray(rng.integers(1, r_max + 1, (B, K)).astype(np.int32))
    pallas = lambda a, n, r: window_join(  # noqa: E731
        a, n, r, max_sep=max_sep, r_max=r_max,
        use_pallas=True, interpret=True, block_l=32, block_k=32)
    v, lo, hi = (np.asarray(x) for x in pallas(a, ns, ns_r))
    wv, wlo, whi = (np.asarray(x) for x in jit_ref(a, ns, ns_r))
    ok = int(np.array_equal(v, wv) and np.array_equal(lo[wv], wlo[wv])
             and np.array_equal(hi[wv], whi[wv]))
    dt = _timeit(pallas, a, ns, ns_r, reps=3)
    rows.append((f"kernel/nearest_r_pallas_interp_B{B}xL{L}K{K}", dt * 1e6,
                 f"bit_identical_to_ref={ok}"))
    return rows


def run(smoke: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    jit_int = jax.jit(intersect_mask_ref)
    jit_prox = jax.jit(lambda a, b: proximity_join_ref(a, b, 5))
    jit_bag = jax.jit(embedding_bag_ref)
    shapes = ((4_096, 16_384),) if smoke else ((16_384, 65_536), (131_072, 1_048_576))
    for n, m in shapes:
        a = jnp.asarray(np.unique(rng.integers(0, 4 * m, n)).astype(np.int32))
        b = jnp.asarray(np.unique(rng.integers(0, 4 * m, m)).astype(np.int32))
        dt = _timeit(jit_int, a, b)
        rows.append((f"kernel/intersect_ref_{n}x{m}", dt * 1e6,
                     f"postings_per_s={(n + m) / dt:.3e}"))
        dt = _timeit(jit_prox, a, b)
        rows.append((f"kernel/proximity_ref_{n}x{m}", dt * 1e6,
                     f"postings_per_s={(n + m) / dt:.3e}"))
    for B, S, V, D in ((256, 20, 10_000, 32),) if smoke else ((4096, 50, 100_000, 64),):
        ids = jnp.asarray(rng.integers(-1, V, (B, S)).astype(np.int32))
        tbl = jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))
        dt = _timeit(jit_bag, ids, tbl)
        rows.append((f"kernel/embedding_bag_ref_B{B}", dt * 1e6,
                     f"lookups_per_s={B * S / dt:.3e}"))
    rows += _nearest_r_rows(rng, smoke)
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
