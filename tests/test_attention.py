"""Chunked (flash-style) attention vs the naive oracle."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.layers import gqa_attention_chunked, gqa_attention_naive


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32) * 0.5


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,Sq,Skv,Hq,Hkv,Dh", [
    (2, 128, 128, 4, 2, 16),
    (1, 96, 200, 4, 4, 8),    # non-multiple of block sizes
    (2, 64, 64, 8, 1, 16),    # MQA
])
def test_chunked_matches_naive(causal, B, Sq, Skv, Hq, Hkv, Dh):
    ks = jax.random.split(jax.random.key(B * Sq + Hq), 3)
    q = _rand(ks[0], (B, Sq, Hq, Dh))
    k = _rand(ks[1], (B, Skv, Hkv, Dh))
    v = _rand(ks[2], (B, Skv, Hkv, Dh))
    off = Skv - Sq if causal else 0
    naive = gqa_attention_naive(q, k, v, causal=causal, q_offset=off)
    chunk = gqa_attention_chunked(q, k, v, causal=causal, q_offset=off,
                                  q_block=32, kv_block=48)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(naive), rtol=2e-5, atol=2e-5)


def test_chunked_kv_len_valid():
    ks = jax.random.split(jax.random.key(0), 3)
    B, S, H, D = 1, 64, 2, 8
    q = _rand(ks[0], (B, S, H, D))
    k = _rand(ks[1], (B, S, H, D))
    v = _rand(ks[2], (B, S, H, D))
    naive = gqa_attention_naive(q, k, v, causal=False, kv_len_valid=37)
    chunk = gqa_attention_chunked(q, k, v, causal=False, kv_len_valid=37,
                                  q_block=16, kv_block=16)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(naive), rtol=2e-5, atol=2e-5)


def test_chunked_gradients_match():
    ks = jax.random.split(jax.random.key(7), 3)
    B, S, H, D = 1, 80, 2, 8
    q = _rand(ks[0], (B, S, H, D))
    k = _rand(ks[1], (B, S, H, D))
    v = _rand(ks[2], (B, S, H, D))

    def loss_naive(q, k, v):
        return gqa_attention_naive(q, k, v, causal=True).sum()

    def loss_chunk(q, k, v):
        return gqa_attention_chunked(q, k, v, causal=True, q_block=16, kv_block=32).sum()

    g1 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_chunk, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=5e-5, atol=5e-5)
