"""Planner-layer coverage (DESIGN.md §14): for every row of the §13
dispatch matrix — each query type on its compiled route and each
scalar-fallback shape — ``explain()`` must return the expected
route/payload/``fallback_reason``, and the executed ``response.plan``
must agree with the pre-computed plan. Plus the dispatch-aware
batching acceptance: ``CompiledExecutor`` demonstrably shares B-bucket
executables across the qt34 and qt5 paths (via engine stats)."""

import dataclasses

import pytest

from repro.core.index_builder import build_index
from repro.core.lexicon import UNKNOWN_FL
from repro.core.query import QueryType, classify, qt34_plan
from repro.core.search import ProximitySearchEngine
from repro.data.corpus import generate_corpus, sample_typed_queries
from repro.launch.mesh import make_mesh
from repro.serving import QueryPlan, SearchService, ServeConfig
from repro.serving import planner

D = 5
BUCKETS = (256, 1024)


@pytest.fixture(scope="module")
def world():
    table, lex = generate_corpus(n_docs=80, mean_doc_len=70, vocab_size=500, seed=11)
    lex.sw_count = 14
    lex.fu_count = 30
    idx = build_index(table, lex, max_distance=D)
    mesh = make_mesh((1, 1), ("data", "model"))
    queries = {
        k: sample_typed_queries(table, lex, 10, k, window=D, seed=3)
        for k in ("qt1", "qt2", "qt3", "qt4", "qt5")
    }
    return table, lex, idx, mesh, queries


def _service(idx, mesh, **over):
    over = {"buckets": BUCKETS, "max_batch": 8, "top_k": 256, **over}
    return SearchService(idx, mesh, ServeConfig(**over))


def _cpu_set(idx, q):
    res, _ = ProximitySearchEngine(idx, top_k=100_000,
                                   equalize_mode="bulk").search_ids(q)
    return set(zip(res.doc.tolist(), res.start.tolist(), res.end.tolist()))


def _resp_set(r):
    return set(zip(r.results["doc"].tolist(), r.results["start"].tolist(),
                   r.results["end"].tolist()))


# -- compiled rows of the matrix: QT1-QT5 x route x payload ----------------
@pytest.mark.parametrize("kind,route,family,qtype", [
    ("qt1", "qt1", "qt1", QueryType.QT1),
    ("qt2", "qt2", "qt2", QueryType.QT2),
    ("qt3", "qt34", "qt5", QueryType.QT3),   # share_buckets default: on
    ("qt4", "qt34", "qt5", QueryType.QT4),
    ("qt5", "qt5", "qt5", QueryType.QT5),
])
@pytest.mark.parametrize("compressed", [False, True])
def test_compiled_matrix_rows(world, kind, route, family, qtype, compressed):
    table, lex, idx, mesh, queries = world
    svc = _service(idx, mesh, compressed=compressed)
    qs = [q for q in queries[kind]
          if svc.explain(q).route == route][:6]
    assert qs, f"no {kind} queries plan onto route {route}"
    for q in qs:
        p = svc.explain(q)
        assert p.qtype == qtype
        assert p.route == route
        assert p.step_family in (family, route)
        assert p.bucket in BUCKETS
        assert p.fallback_reason is None
        assert p.is_compiled
        # predicted payload: raw uncompressed; delta16 when the bucket
        # is block-aligned (both BUCKETS are)
        assert p.payload == ("delta16" if compressed else "raw")
        assert p.est_step_cost is not None and p.est_step_cost > 0
    tickets = [svc.submit(q) for q in qs]
    responses = svc.drain()
    for q, t, r in zip(qs, tickets, responses):
        assert t.response is r
        pre = svc.explain(q)
        # the executed plan agrees with the pre-computed one (payload
        # may downgrade delta16 -> offsets on uint16 overflow; not on
        # this corpus)
        assert r.plan.route == pre.route == r.path
        assert r.plan.step_family == pre.step_family
        assert r.plan.bucket == pre.bucket == r.bucket
        assert r.plan.payload == pre.payload
        assert _resp_set(r) == _cpu_set(idx, q)


# -- scalar-fallback rows of the matrix ------------------------------------
def _fallback_cases(idx, lex, queries):
    """(case name, query, expected qtype, expected reason, config
    overrides) — one entry per CPU-fallback condition of the DESIGN.md
    §13 matrix that is reachable through ``classify``."""
    from repro.core.query import qt1_plan, qt2_plan

    sw, fu = lex.sw_count, lex.fu_count
    stop0 = int(queries["qt1"][0][0])
    ord0 = int(queries["qt3"][0][0])
    # ladder overflow needs a posting row longer than the tiny bucket
    q1_long = next(q for q in queries["qt1"] if qt1_plan(idx, q)[1] > 16)
    # (w,v) keys are sparse on this corpus: a 2-slot ladder overflows
    q2_long = next(q for q in queries["qt2"] if qt2_plan(idx, q)[1] > 2)
    q4_long = next(q for q in queries["qt4"]
                   if max(qt34_plan(idx, q)[2].values()) > 16)
    return [
        ("unknown_lemma", [stop0, UNKNOWN_FL], None,
         planner.FB_UNKNOWN_LEMMA, {}),
        ("qt1_short", [stop0, stop0 + 1], QueryType.QT1,
         planner.FB_QUERY_TOO_SHORT, {}),
        ("qt1_long", [0, 1, 2, 3, 4, 5, 0], QueryType.QT1,
         planner.FB_QUERY_TOO_LONG, {}),
        ("qt1_keys", queries["qt1"][0], QueryType.QT1,
         planner.FB_TOO_MANY_FST_KEYS, {"k_fst": 0}),
        ("qt1_ladder", q1_long, QueryType.QT1,
         planner.FB_ROW_EXCEEDS_LADDER, {"buckets": (16,)}),
        ("qt2_sharded", queries["qt2"][0], QueryType.QT2,
         planner.FB_SHARDED_QT2, {"doc_shards": 2}),
        ("qt2_keys", list(range(sw, sw + 8)), QueryType.QT2,
         planner.FB_TOO_MANY_WV_KEYS, {}),
        ("qt2_ladder", q2_long, QueryType.QT2,
         planner.FB_ROW_EXCEEDS_LADDER, {"buckets": (2,)}),
        ("qt34_constraints", [int(l) for l in range(sw + fu, sw + fu + 6)],
         QueryType.QT3, planner.FB_TOO_MANY_ORD_CONSTRAINTS, {}),
        ("qt34_rmax", [ord0] * 6, QueryType.QT3,
         planner.FB_MULTIPLICITY_OVER_R_MAX, {}),
        ("qt34_ladder", q4_long, QueryType.QT4,
         planner.FB_ROW_EXCEEDS_LADDER, {"buckets": (16,)}),
        # 5 non-stop lemmas: the rarest anchors, leaving 4 others > k_ns
        ("qt5_ns_constraints", [stop0] + [int(l) for l in
                                          range(sw + fu, sw + fu + 5)],
         QueryType.QT5, planner.FB_TOO_MANY_NS_CONSTRAINTS, {}),
        ("qt5_stop_constraints", [0, 1, 2, 3, ord0], QueryType.QT5,
         planner.FB_TOO_MANY_STOP_CONSTRAINTS, {}),
        ("qt5_rmax", [stop0] + [ord0] * 5, QueryType.QT5,
         planner.FB_MULTIPLICITY_OVER_R_MAX, {}),
        ("qt5_stop_overflow", [stop0] * 255 + [ord0], QueryType.QT5,
         planner.FB_STOP_MULTIPLICITY_OVERFLOW, {}),
        # a query lemma lives in the unsealed-memtable overlay (§18):
        # compiled caches would churn per add, so the row goes scalar
        ("live_memtable", queries["qt1"][0], QueryType.QT1,
         planner.FB_LIVE_MEMTABLE, {"_live_overlay": True}),
    ]


def _live_seg(table, lex, q):
    """A segmented index whose sealed tier is the module corpus and whose
    unsealed memtable holds one extra doc containing the query lemmas."""
    from repro.index import SegmentedIndex

    seg = SegmentedIndex(lex, max_distance=D, memtable_docs=1000)
    for d in table.to_doc_lists():
        seg.add_document(d)
    seg.refresh()
    seg.add_document(list(q) * 2)  # stays in the memtable: overlay-only
    return seg


def test_scalar_fallback_rows(world):
    table, lex, idx, mesh, queries = world
    for name, q, qtype, reason, over in _fallback_cases(idx, lex, queries):
        ref = idx
        if over.pop("_live_overlay", False):
            seg = _live_seg(table, lex, q)
            svc = _service(seg, mesh, serve_memtable=True, **over)
            svc.refresh()  # pulls live_view(): overlay becomes visible
            ref = seg.live_view()
        else:
            svc = _service(idx, mesh, **over)
        p = svc.explain(q)
        assert p.route == planner.ROUTE_SCALAR, (name, p)
        assert p.qtype == qtype, name
        assert p.fallback_reason == reason, (name, p.fallback_reason)
        assert p.bucket is None and p.payload is None
        assert p.est_step_cost is None  # no compiled-shape bound — the point
        if over.get("doc_shards", 1) > 1:
            continue  # plan-only: a 1-device mesh cannot execute 2 shards
        t = svc.submit(q)
        (r,) = svc.drain()
        assert r.path == "cpu" and r.plan == p, name
        assert t.response is r
        assert _resp_set(r) == _cpu_set(ref, q), name
    # empty requests are their own (inline) dispatch row
    svc = _service(idx, mesh)
    assert svc.explain([]) == QueryPlan(qtype=None, route=planner.ROUTE_EMPTY)
    svc.submit([])
    (r,) = svc.drain()
    assert r.path == "empty" and r.results["doc"].size == 0


def test_every_matrix_reason_is_covered(world):
    """The fallback-case table above must cover every reachable reason
    constant the planner can emit — a new matrix row without a test row
    fails here."""
    table, lex, idx, mesh, queries = world
    covered = {reason for _, _, _, reason, _ in _fallback_cases(idx, lex, queries)}
    all_reasons = {v for k, v in vars(planner).items() if k.startswith("FB_")}
    # no-store reasons need an index built without the structure;
    # degenerate QT5 is unreachable through classify (defensive)
    reachable = all_reasons - {
        planner.FB_NO_FST_INDEX, planner.FB_NO_WV_INDEX,
        planner.FB_NO_ORDINARY_INDEX, planner.FB_NO_NSW_INDEX,
        planner.FB_DEGENERATE_QT5,
    }
    assert covered == reachable, covered ^ reachable


def test_missing_store_fallbacks(world):
    """Idx1-style indexes (additional structures disabled) route every
    affected type to the scalar engine with the matching reason."""
    table, lex, idx, mesh, queries = world
    cfg = ServeConfig(buckets=BUCKETS)
    for field, q, reason in [
        ("fst", queries["qt1"][0], planner.FB_NO_FST_INDEX),
        ("wv", queries["qt2"][0], planner.FB_NO_WV_INDEX),
        ("nsw", queries["qt5"][0], planner.FB_NO_NSW_INDEX),
        # the ordinary guard protects qt34_plan/qt5_plan, which would
        # otherwise dereference index.ordinary.n_postings and crash
        ("ordinary", queries["qt3"][0], planner.FB_NO_ORDINARY_INDEX),
        ("ordinary", queries["qt5"][0], planner.FB_NO_ORDINARY_INDEX),
    ]:
        bare = dataclasses.replace(idx, **{field: None})
        p = planner.plan(q, bare, cfg)
        assert p.route == planner.ROUTE_SCALAR
        assert p.fallback_reason == reason, field


def test_plan_is_pure_and_memoized(world):
    table, lex, idx, mesh, queries = world
    cfg = ServeConfig(buckets=BUCKETS)
    q = queries["qt3"][0]
    assert planner.plan(q, idx, cfg) == planner.plan(list(q), idx, cfg)
    svc = _service(idx, mesh)
    assert svc.explain(q) is svc.explain(q)  # memoized per snapshot


# -- dispatch-aware batching (the acceptance criterion) --------------------
def test_qt34_shares_qt5_executables(world):
    """With share_buckets (default), qt34 groups whose plans fit the
    QT5 step's non-stop slots ride the qt5 executable of the same
    (B, L): the executable table gains no qt34 kind at all, the stats
    count shared batches — and results still match the CPU reference
    bit-for-bit (qt5_join with zero stop constraints is qt34_join)."""
    table, lex, idx, mesh, queries = world
    qs = [q for q in queries["qt3"] + queries["qt4"] + queries["qt5"]
          if len(qt34_plan(idx, q)[1]) <= 3 or classify(q, lex) == QueryType.QT5]
    shared = _service(idx, mesh)
    solo = _service(idx, mesh, share_buckets=False)
    for q in qs:
        shared.submit(q)
        solo.submit(q)
    got_shared = [_resp_set(r) for r in shared.drain()]
    got_solo = [_resp_set(r) for r in solo.drain()]
    assert got_shared == got_solo == [_cpu_set(idx, q) for q in qs]
    # shared engine: qt34 traffic executed, yet only qt5 executables exist
    assert shared.stats["paths"]["qt34"] > 0 and shared.stats["paths"]["qt5"] > 0
    kinds_shared = {k for (k, B, L) in shared.compiled.executables}
    assert any(k.startswith("qt5_") for k in kinds_shared)
    assert not any(k.startswith("qt34_") for k in kinds_shared)
    assert shared.stats["plans"]["shared_batches"] > 0
    # control: without sharing the qt34 path compiles its own executables
    kinds_solo = {k for (k, B, L) in solo.compiled.executables}
    assert any(k.startswith("qt34_") for k in kinds_solo)
    assert solo.stats["plans"]["shared_batches"] == 0
    assert shared.compiled.n_executables < solo.compiled.n_executables


def test_qt34_and_qt5_batch_together(world):
    """Sharing is batching, not just executable reuse: qt34 and qt5
    requests at the same (B, L) land in one padded batch."""
    table, lex, idx, mesh, queries = world
    svc = _service(idx, mesh)
    qs = [q for q in queries["qt3"][:4] + queries["qt5"][:4]
          if svc.explain(q).step_family == "qt5"
          and svc.explain(q).bucket == BUCKETS[0]]
    assert len({svc.explain(q).route for q in qs}) == 2, "need both routes"
    for q in qs:
        svc.submit(q)
    responses = svc.drain()
    assert svc.stats["batches"] == 1  # one fused batch served everything
    assert {r.path for r in responses} == {"qt34", "qt5"}
    for q, r in zip(qs, responses):
        assert _resp_set(r) == _cpu_set(idx, q)


def test_deadline_and_queue_wait_reporting(world):
    table, lex, idx, mesh, queries = world
    svc = _service(idx, mesh)
    generous = svc.submit(queries["qt1"][0], deadline_s=60.0)
    hopeless = svc.submit(queries["qt1"][1], deadline_s=-1.0)
    unset = svc.submit(queries["qt1"][2])
    svc.drain()
    assert generous.response.deadline_met is True
    assert hopeless.response.deadline_met is False
    assert unset.response.deadline_met is None
    assert all(t.response.queue_wait_s >= 0.0
               for t in (generous, hopeless, unset))
    assert svc.stats["deadlines"] == {
        "met": 1, "missed": 1, "unset": 1,
        # §15 phase attribution: a -1s budget is blown before the batch
        # even starts, so the miss is blamed on the queue
        "miss_blame": {"queue": 1},
    }
