"""HLO-text collective parser + roofline composition."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.hlo_analysis import CollectiveStats, collective_stats, split_computations
from repro.launch.mesh import make_mesh


def test_collective_stats_on_real_hlo():
    mesh = make_mesh((1, 1), ("data", "model"))

    # synthetic HLO exercising the parser without multi-device compile
    hlo = """HloModule test, is_scheduled=true

%region_body (p: (s32[], f32[8,4])) -> (s32[], f32[8,4]) {
  %ar = f32[8,4]{1,0} all-reduce(%x), channel_id=1, to_apply=%add
}

ENTRY %main (a: f32[16,8]) -> f32[16,8] {
  %ag = f32[16,8]{1,0} all-gather(%a), channel_id=2, dimensions={0}
  %w = (s32[], f32[8,4]) while(%init), condition=%cond, body=%region_body
}
"""
    comps = split_computations(hlo)
    assert "region_body" in comps and "main" in comps
    cs = collective_stats(hlo)
    assert cs.op_bytes.get("all-gather") == 16 * 8 * 4
    assert cs.in_loop_bytes.get("all-reduce") == 8 * 4 * 4
    # trip-count scaling: loop body collectives multiply
    assert cs.total(10) == 16 * 8 * 4 + 10 * 8 * 4 * 4


def test_collective_stats_real_compile():
    """End-to-end on an actually partitioned module (1x1 mesh -> no
    collectives; the parse must return zero, not crash)."""
    mesh = make_mesh((1, 1), ("data", "model"))
    f = jax.jit(
        lambda x: (x @ x.T).sum(),
        in_shardings=NamedSharding(mesh, P("data", "model")),
    )
    comp = f.lower(jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    cs = collective_stats(comp.as_text())
    assert cs.count == 0


def test_roofline_analyze_composition():
    from repro.configs.registry import ARCHS
    from repro.launch.roofline import analyze

    rec = {
        "arch": "stablelm-1.6b",
        "shape": "train_4k",
        "mesh": "16x16",
        "n_devices": 256,
        "kind": "train",
        "cost": {"flops": 1e12, "bytes_accessed": 1e11},
        "memory": {"peak_per_device_gib": 5.0},
        "collectives": {"once_bytes": {"all-gather": int(1e9)},
                        "in_loop_bytes": {"all-reduce": int(1e8)}},
        "meta": {"n_layers": 24, "model_params": 1.64e9, "active_params": 1.64e9,
                 "tokens": 4096 * 256},
        "layer_probe": {"flops": 5e11, "bytes_accessed": 4e10},
    }
    row = analyze(rec, ARCHS)
    # corrected flops = full + (L-1)*probe
    assert abs(row["hlo_flops_per_dev"] - (1e12 + 23 * 5e11)) < 1e6
    # collective bytes = once + in_loop * L
    want_coll = (1e9 + 24 * 1e8) / 50e9
    assert abs(row["t_collective_s"] - want_coll) < 1e-9
    assert row["dominant"] in ("compute", "memory", "collective")
    assert 0 < row["useful_flops_ratio"] < 5
