"""Background compaction + live memtable search (DESIGN.md §18).

The load-bearing property of the real-time ingest tier: a
``SegmentedIndex`` running merges *off-thread* — with live memtable
overlays serving unsealed documents — must answer QT1-QT5 bit-identically
(full (ID, P, E, R) records, modulo the global->compact doc-id remap) to
a fresh ``build_index`` over the logical corpus, at *every* observable
point: before any refresh (live view), mid-merge (pinned snapshots),
after swap-in, after faults, and after crash-recovery reopen.

The differential harness replays randomized add/delete/refresh/search
interleavings against the fresh-rebuild oracle; the fault-injection hook
of :class:`repro.index.CompactionExecutor` stalls or kills merges at
chosen stages to expose torn snapshots, lost tombstones and resurrection
bugs, and the crash-recovery tests kill a simulated merge between
segment write and manifest swap.
"""

import json
import threading
import time

import numpy as np
import pytest

from repro.core.index_builder import build_index
from repro.core.search import ProximitySearchEngine
from repro.data.corpus import TokenTable, generate_corpus
from repro.index import (
    CompactionExecutor,
    SegmentedIndex,
    leveled_plan,
    size_tiered_plan,
    write_json_atomic,
)
from repro.obs import MetricsRegistry

D = 5


@pytest.fixture(scope="module")
def corpus():
    table, lex = generate_corpus(n_docs=120, mean_doc_len=60, vocab_size=400, seed=3)
    lex.sw_count = 12
    lex.fu_count = 25
    return table.to_doc_lists(), lex


def _sample_query(ftable, lex, want, seed):
    rng = np.random.default_rng(seed)
    sw, fu = lex.sw_count, lex.fu_count
    for _ in range(3000):
        r = int(rng.integers(0, ftable.n_rows))
        d0, p0 = int(ftable.doc_ids[r]), int(ftable.positions[r])
        m = (ftable.doc_ids == d0) & (np.abs(ftable.positions - p0) <= D)
        lems = np.unique(ftable.lemma_ids[m])
        stop = lems[lems < sw]
        freq = lems[(lems >= sw) & (lems < sw + fu)]
        ordi = lems[lems >= sw + fu]
        if want == "qt1" and stop.size >= 3:
            return sorted(rng.choice(stop, 3, replace=False).tolist())
        if want == "qt2" and freq.size >= 2:
            return sorted(rng.choice(freq, 2, replace=False).tolist())
        if want == "qt3" and ordi.size >= 2:
            return sorted(rng.choice(ordi, 2, replace=False).tolist())
        if want == "qt4" and freq.size >= 1 and ordi.size >= 1:
            return sorted([int(rng.choice(freq)), int(rng.choice(ordi))])
        if want == "qt5" and stop.size >= 1 and freq.size + ordi.size >= 2:
            ns = np.concatenate([freq, ordi])
            return sorted(rng.choice(ns, 2, replace=False).tolist() + [int(rng.choice(stop))])
    return None


def _records(matches, remap=None):
    docs = matches.doc.tolist()
    if remap is not None:
        docs = [remap[int(x)] for x in docs]
    return sorted(
        zip(docs, matches.start.tolist(), matches.end.tolist(),
            np.round(matches.score, 9).tolist())
    )


def _assert_oracle_equiv(view, docs, lex, seed=0, min_qts=3):
    """Full differential check of one view against a fresh rebuild of its
    logical corpus: one sampled query per QT, full records bit-identical."""
    live = view.live_doc_ids()
    if live.size == 0:
        return
    ftable = TokenTable.from_docs([np.array(docs[int(g)], np.int32) for g in live])
    ref = build_index(ftable, lex, max_distance=D)
    remap = {int(g): i for i, g in enumerate(live.tolist())}
    e_view = ProximitySearchEngine(view, top_k=100_000)
    e_ref = ProximitySearchEngine(ref, top_k=100_000)
    tested = 0
    for i, want in enumerate(("qt1", "qt2", "qt3", "qt4", "qt5")):
        q = _sample_query(ftable, lex, want, seed=seed * 71 + i)
        if q is None:
            continue
        r_ref, _ = e_ref.search_ids(q)
        r_view, _ = e_view.search_ids(q)
        assert _records(r_ref) == _records(r_view, remap), (want, q)
        tested += 1
    assert tested >= min_qts


# -- differential interleaving replay ---------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_interleaving_replay_oracle(corpus, seed):
    """Randomized add/delete/refresh/search interleavings: the live view
    (sealed segments + memtable overlay + background merges in flight)
    must match the fresh-rebuild oracle at every search step."""
    docs, lex = corpus
    rng = np.random.default_rng(100 + seed)
    seg = SegmentedIndex(
        lex, max_distance=D, memtable_docs=8, tier_fanout=3, background=True
    )
    alive, nxt, checks = [], 0, 0
    try:
        for step in range(70):
            op = ["add", "add", "add", "add", "delete", "refresh", "search"][
                int(rng.integers(7))
            ]
            if op == "add" and nxt < len(docs):
                gid = seg.add_document(docs[nxt])
                assert gid == nxt  # gids are assigned sequentially
                alive.append(gid)
                nxt += 1
            elif op == "delete" and alive:
                seg.delete_document(alive.pop(int(rng.integers(len(alive)))))
            elif op == "refresh":
                seg.refresh(wait=bool(rng.integers(2)))
            elif op == "search":
                _assert_oracle_equiv(seg.live_view(), docs, lex, seed=seed * 13 + step,
                                     min_qts=0)
                checks += 1
        seg.refresh(wait=True)
        _assert_oracle_equiv(seg.snapshot(), docs, lex, seed=seed)
        assert checks >= 3
        assert seg.stats["merges"] >= 1  # the replay actually compacted
    finally:
        seg.close()


# -- live memtable visibility ------------------------------------------------
def test_live_view_sees_unsealed_adds(corpus):
    docs, lex = corpus
    seg = SegmentedIndex(lex, max_distance=D, memtable_docs=50, tier_fanout=3)
    for d in docs[:30]:
        seg.add_document(d)
    seg.refresh()
    for d in docs[30:40]:
        seg.add_document(d)  # memtable only, no refresh
    snap = seg.snapshot()
    live = seg.live_view()
    assert set(snap.live_doc_ids().tolist()) == set(range(30))
    assert set(live.live_doc_ids().tolist()) == set(range(40))
    assert live.mem_overlay is not None and live.mem_overlay.is_live
    _assert_oracle_equiv(live, docs, lex, seed=7)


def test_live_view_sees_unrefreshed_deletes(corpus):
    docs, lex = corpus
    seg = SegmentedIndex(lex, max_distance=D, memtable_docs=50, tier_fanout=3)
    for d in docs[:30]:
        seg.add_document(d)
    seg.refresh()
    for d in docs[30:36]:
        seg.add_document(d)
    seg.delete_document(5)   # sealed doc
    seg.delete_document(33)  # memtable doc
    live = seg.live_view()
    assert set(live.live_doc_ids().tolist()) == set(range(36)) - {5, 33}
    _assert_oracle_equiv(live, docs, lex, seed=8)


def test_live_view_memoized_until_mutation(corpus):
    docs, lex = corpus
    seg = SegmentedIndex(lex, max_distance=D, memtable_docs=50)
    for d in docs[:10]:
        seg.add_document(d)
    v1 = seg.live_view()
    assert seg.live_view() is v1  # no mutation: same frozen overlay
    seg.add_document(docs[10])
    v2 = seg.live_view()
    assert v2 is not v1
    seg.delete_document(0)
    assert seg.live_view() is not v2


# -- mid-merge consistency / fault injection ---------------------------------
def _stalled_world(docs, lex, n_docs=40, stall_stage="before_swap"):
    """A background index with one merge stalled at ``stall_stage`` until
    the returned ``hold`` event is set; ``entered`` is set when the merge
    reaches the stage."""
    hold, entered = threading.Event(), threading.Event()

    def hook(stage, job):
        if stage == stall_stage:
            entered.set()
            assert hold.wait(30)

    ex = CompactionExecutor(fault_hook=hook)
    seg = SegmentedIndex(
        lex, max_distance=D, memtable_docs=8, tier_fanout=3,
        background=True, executor=ex,
    )
    for d in docs[:n_docs]:
        seg.add_document(d)
    seg.refresh(wait=False)  # seals + schedules the stalled merge
    assert entered.wait(30)
    return seg, ex, hold, entered


def test_mid_merge_snapshot_stays_consistent(corpus):
    """A snapshot pinned while a merge is mid-flight serves bit-identical
    results; after swap-in the new snapshot does too."""
    docs, lex = corpus
    seg, ex, hold, _ = _stalled_world(docs, lex)
    try:
        pinned = seg.snapshot()
        _assert_oracle_equiv(pinned, docs, lex, seed=21)  # mid-merge
        hold.set()
        assert ex.wait_idle(30)
        assert seg.stats["merges"] >= 1
        post = seg.snapshot()
        assert post is not pinned  # swap-in republished atomically
        _assert_oracle_equiv(post, docs, lex, seed=22)
        _assert_oracle_equiv(pinned, docs, lex, seed=23)  # old pin still valid
    finally:
        hold.set()
        ex.close()


def test_late_tombstone_survives_merge(corpus):
    """A delete arriving while its doc's segment is being merged must not
    be purged by the swap-in (the capture predates it) — the doc stays
    masked, never resurrected."""
    docs, lex = corpus
    seg, ex, hold, _ = _stalled_world(docs, lex)
    try:
        seg.delete_document(0)  # doc 0 is inside the merging tier
        hold.set()
        assert ex.wait_idle(30)
        view = seg.refresh(wait=True)
        assert 0 not in set(view.live_doc_ids().tolist())
        assert 0 in set(view.tombstones.tolist())  # survived, not purged
        _assert_oracle_equiv(view, docs, lex, seed=31)
    finally:
        hold.set()
        ex.close()


def test_refresh_seal_only_is_nonblocking(corpus):
    """refresh(wait=False) must return in O(memtable) time while a merge
    is still in flight — the inline-merge stall this PR removes."""
    docs, lex = corpus
    seg, ex, hold, entered = _stalled_world(docs, lex, stall_stage="before_merge")
    try:
        assert entered.is_set() and ex.pending() >= 1
        for d in docs[40:44]:
            seg.add_document(d)
        t0 = time.perf_counter()
        view = seg.refresh(wait=False)  # merge still stalled: must not block
        elapsed = time.perf_counter() - t0
        assert elapsed < 5.0  # seal of a 4-doc memtable; nowhere near a merge stall
        assert ex.pending() >= 1  # the stalled merge is still in flight
        assert set(view.live_doc_ids().tolist()) >= set(range(44))
    finally:
        hold.set()
        ex.close()


def test_foreground_seal_only_refresh_skips_compaction(corpus):
    docs, lex = corpus
    seg = SegmentedIndex(lex, max_distance=D, memtable_docs=8, tier_fanout=3)
    for d in docs[:40]:
        seg.add_document(d)
    assert seg.stats["merges"] >= 1  # auto-seal compacts inline
    merges0 = seg.stats["merges"]
    for d in docs[40:44]:  # stay under memtable_docs: no auto-seal
        seg.add_document(d)
    n0 = seg.n_segments
    seg.refresh(wait=False)
    assert seg.stats["merges"] == merges0  # seal-only: no merge ran
    assert seg.n_segments >= n0
    seg.refresh(wait=True)
    _assert_oracle_equiv(seg.snapshot(), docs, lex, seed=41)


def test_superseded_merge_discarded(corpus):
    """A background merge whose victims were rewritten underneath it
    (forced major compaction won the race) is discarded at validation —
    no duplicate documents, state stays equivalent."""
    docs, lex = corpus
    hold, entered = threading.Event(), threading.Event()

    def hook(stage, job):
        if stage == "before_merge":
            entered.set()
            assert hold.wait(30)

    ex = CompactionExecutor(fault_hook=hook)
    seg = SegmentedIndex(
        lex, max_distance=D, memtable_docs=100, tier_fanout=3,
        background=True, executor=ex,
    )
    try:
        # seal manually (no auto-seal scheduling) so we hold the job handle
        for i, d in enumerate(docs[:40], 1):
            seg.add_document(d)
            if i % 8 == 0:
                with seg._lock:
                    seg._seal_only()
        jobs = ex.schedule(seg)
        assert jobs
        assert entered.wait(30)
        seg.compact(force=True)  # inline major compaction rewrites the victims
        hold.set()
        assert jobs[0].result(timeout=30) == "superseded"
        assert ex.stats["superseded"] >= 1
        view = seg.refresh(wait=True)
        assert sorted(view.live_doc_ids().tolist()) == list(range(40))  # no dupes
        _assert_oracle_equiv(view, docs, lex, seed=51)
    finally:
        hold.set()
        ex.close()


def test_overlapping_plan_skipped_and_cancel_honored(corpus):
    docs, lex = corpus
    seg, ex, hold, _ = _stalled_world(docs, lex, stall_stage="before_merge")
    try:
        sched0 = ex.stats["scheduled"]
        assert ex.schedule(seg) == []  # victims overlap the in-flight job
        assert ex.stats["scheduled"] == sched0
        # a cooperatively cancelled queued job resolves without merging
        for d in docs[40:60]:
            seg.add_document(d)
        with seg._lock:
            seg._seal_only()
        queued = ex.schedule(seg)
        for j in queued:
            j.cancel()
        hold.set()
        for j in queued:
            assert j.result(timeout=30) == "cancelled"
        assert ex.wait_idle(30)
    finally:
        hold.set()
        ex.close()


def test_compaction_metrics_and_spans(corpus):
    docs, lex = corpus
    from repro.obs import Tracer

    metrics, tracer = MetricsRegistry(), Tracer()
    ex = CompactionExecutor(metrics=metrics, tracer=tracer)
    seg = SegmentedIndex(
        lex, max_distance=D, memtable_docs=8, tier_fanout=3,
        background=True, executor=ex,
    )
    try:
        for d in docs[:40]:
            seg.add_document(d)
        seg.refresh(wait=True)
        snap = metrics.snapshot("compaction")
        assert snap["compaction.scheduled"] >= 1
        assert snap["compaction.started"] >= 1
        assert snap["compaction.merged"] >= 1
        assert snap["compaction.merge_ms"]["count"] >= 1
        assert ex.stats["merged"] == seg.stats["merges"]
    finally:
        ex.close()


# -- leveled policy ----------------------------------------------------------
def test_leveled_plan_merges_multi_run_tiers():
    class FakeSeg:
        def __init__(self, n):
            self.n_postings = n

    segs = [FakeSeg(10), FakeSeg(12), FakeSeg(300), FakeSeg(11), FakeSeg(4000)]
    # fanout=4 tiers: ~[1, 1, 4, 1, 5] -> tier 1 holds three runs
    assert size_tiered_plan(segs, fanout=4) == []  # tiering needs 4 per tier
    lv = leveled_plan(segs, fanout=4)
    assert lv == [[0, 1, 3]]  # leveled merges any tier holding >= 2 runs


def test_leveled_policy_end_to_end(corpus):
    docs, lex = corpus
    seg = SegmentedIndex(
        lex, max_distance=D, memtable_docs=8, tier_fanout=4,
        background=True, policy="leveled",
    )
    try:
        for d in docs[:60]:
            seg.add_document(d)
        for g in (2, 11, 25):
            seg.delete_document(g)
        view = seg.refresh(wait=True)
        # steady state: at most one run per tier
        tiers = {}
        for s in view.segments:
            t = int(np.log(max(s.n_postings, 1)) / np.log(4))
            tiers[t] = tiers.get(t, 0) + 1
        assert all(v == 1 for v in tiers.values()), tiers
        _assert_oracle_equiv(view, docs, lex, seed=61)
    finally:
        seg.close()


def test_unknown_policy_rejected(corpus):
    _, lex = corpus
    with pytest.raises(ValueError):
        SegmentedIndex(lex, policy="mystery")


# -- crash recovery ----------------------------------------------------------
def test_crash_recovery_ignores_orphan_merge_output(tmp_path, corpus):
    """Simulated crash between merge-segment write and manifest swap: the
    reopened index serves exactly the pre-merge state; orphaned segment
    dirs (complete or partial) are not counted as live."""
    docs, lex = corpus
    seg = SegmentedIndex(lex, max_distance=D, memtable_docs=8, tier_fanout=3)
    for d in docs[:30]:
        seg.add_document(d)
    seg.refresh()
    seg.save(tmp_path)
    manifest0 = json.loads((tmp_path / "manifest.json").read_text())

    # the "merge" wrote its output segment dir completely...
    from repro.index import merge_segments

    merged = merge_segments(
        seg._segments, np.zeros(0, np.int64), lex, D, segment_id=9999
    )
    merged.save(tmp_path / "seg_009999")
    # ...and another crashed mid-npz (no meta.json yet: recognizably partial)
    partial = tmp_path / "seg_009998"
    partial.mkdir()
    np.savez(partial / "segment.npz", half=np.zeros(3))
    # ...and the manifest swap died leaving a torn tmp behind
    (tmp_path / "manifest.json.tmp").write_text('{"segments": ["seg_009999"')

    out = SegmentedIndex.load(tmp_path)
    assert [s.segment_id for s in out._segments] == [
        int(name[4:]) for name in manifest0["segments"]
    ]
    assert not any(s.segment_id in (9998, 9999) for s in out._segments)
    view = out.refresh()
    assert sorted(view.live_doc_ids().tolist()) == list(range(30))
    _assert_oracle_equiv(view, docs, lex, seed=71)


def test_write_json_atomic_swaps_cleanly(tmp_path):
    target = tmp_path / "m.json"
    write_json_atomic(target, {"v": 1})
    assert json.loads(target.read_text()) == {"v": 1}
    write_json_atomic(target, {"v": 2})
    assert json.loads(target.read_text()) == {"v": 2}
    assert not (tmp_path / "m.json.tmp").exists()  # no tmp residue


def test_background_roundtrip_preserves_lineage(tmp_path, corpus):
    """Save/load through background churn: merge outputs carry their
    ``derived_from`` lineage across the round-trip and the reloaded index
    is oracle-equivalent."""
    docs, lex = corpus
    seg = SegmentedIndex(
        lex, max_distance=D, memtable_docs=8, tier_fanout=3, background=True
    )
    try:
        for d in docs[:50]:
            seg.add_document(d)
        for g in (1, 20):
            seg.delete_document(g)
        seg.refresh(wait=True)
        assert seg.stats["merges"] >= 1
        assert any(s.derived_from for s in seg._segments)
        seg.save(tmp_path)
    finally:
        seg.close()
    out = SegmentedIndex.load(tmp_path)
    assert any(s.derived_from for s in out._segments)
    lineage = {s.segment_id: s.derived_from for s in seg._segments}
    assert {s.segment_id: s.derived_from for s in out._segments} == lineage
    _assert_oracle_equiv(out.refresh(), docs, lex, seed=81)
