"""Property tests for the fused nearest-r window join: the lax counting
path and the Pallas kernel (interpret mode) vs the argsort oracle
``window_join_ref`` and the CPU engine's ``search._nearest_r`` replayed
at the join level. Comparison is on (valid, lo[valid], hi[valid]) — the
contract every consumer reads — because the impls differ only on lanes
the join masks out (center inclusion in mn/mx, matched at r=0).

Randomized cases run under hypothesis when it is installed (shrinking,
fresh examples); otherwise the same generators sweep a fixed seed grid
via parametrize so the coverage does not silently vanish."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import search
from repro.kernels.common import SENTINEL
from repro.kernels.nearest_r import plan_k_tiles, window_join
from repro.kernels.nearest_r.ref import window_join_ref

R_MAX = 4

try:
    from hypothesis import given, settings, strategies as st

    def property_cases(max_examples, **bounds):
        def deco(fn):
            strat = {k: st.integers(lo, hi) for k, (lo, hi) in bounds.items()}
            return settings(max_examples=max_examples, deadline=None)(
                given(**strat)(fn))
        return deco
except ModuleNotFoundError:
    def property_cases(max_examples, **bounds):
        def deco(fn):
            rng = np.random.default_rng(0)
            rows = [tuple(int(rng.integers(lo, hi + 1))
                          for lo, hi in bounds.values())
                    for _ in range(max_examples)]
            return pytest.mark.parametrize(",".join(bounds), rows)(fn)
        return deco


def _rows(rng, b, kn, l, stride, p_empty=0.15):
    """Strictly increasing SENTINEL-padded rows. Small ``stride`` makes
    equal pred/succ distances common — the tie-breaking cases."""
    out = np.full((b, kn, l), SENTINEL, np.int32)
    for i in range(b):
        for k in range(kn):
            if rng.random() < p_empty:
                continue
            n = int(rng.integers(1, l + 1))
            out[i, k, :n] = np.cumsum(rng.integers(1, stride + 1, n))
    return out


def _np3(out):
    return tuple(np.asarray(x) for x in out)


def _assert_same(got, want):
    gv, gl, gh = _np3(got)
    wv, wl, wh = _np3(want)
    np.testing.assert_array_equal(gv, wv)
    np.testing.assert_array_equal(gl[wv], wl[wv])
    np.testing.assert_array_equal(gh[wv], wh[wv])


def _cpu_join(a, ns, ns_r, st_cnt=None, st_ext=None, st_r=None, *, max_sep):
    """The CPU engine verbatim: ``search._nearest_r`` per key folded with
    ``_window_match``'s accumulation, then the elementwise stop fold —
    run on the unpadded rows, scattered back to the padded layout."""
    b, kn, l = ns.shape
    valid = np.zeros((b, l), bool)
    lo = a.astype(np.int64).copy()
    hi = a.astype(np.int64).copy()
    for i in range(b):
        real = a[i] != SENTINEL
        centers = a[i][real].astype(np.int64)
        ok = np.ones(centers.size, bool)
        lo_i = centers.copy()
        hi_i = centers.copy()
        for k in range(kn):
            r = int(ns_r[i, k])
            if r == 0:
                continue
            row = ns[i, k]
            g = row[row != SENTINEL].astype(np.int64)
            m, mn, mx = search._nearest_r(g, centers, max_sep, r)
            ok &= m
            lo_i = np.minimum(lo_i, np.where(m, mn, lo_i))
            hi_i = np.maximum(hi_i, np.where(m, mx, hi_i))
        valid[i, real] = ok
        lo[i, real] = lo_i
        hi[i, real] = hi_i
    if st_cnt is not None:
        a64 = a.astype(np.int64)
        for k in range(st_cnt.shape[1]):
            r = st_r[:, k][:, None]
            active = r > 0
            valid &= (st_cnt[:, k] >= r) | ~active
            ext = np.where(active, st_ext[:, k], 0)
            lo = np.minimum(lo, a64 + np.minimum(ext, 0))
            hi = np.maximum(hi, a64 + np.maximum(ext, 0))
    return valid, lo, hi


def _stops(rng, b, ks, l, max_sep):
    st_cnt = rng.integers(0, 4, (b, ks, l)).astype(np.int32)
    st_ext = rng.integers(-max_sep, max_sep + 1, (b, ks, l)).astype(np.int32)
    st_r = rng.integers(0, 3, (b, ks)).astype(np.int32)
    return st_cnt, st_ext, st_r


# ---------------- lax counting path vs oracle vs CPU ------------------------
@property_cases(40, seed=(0, 2**31 - 1), b=(1, 3), kn=(1, 3), l=(4, 48),
                stride=(1, 5), max_sep=(1, 8))
def test_counting_vs_ref_vs_cpu(seed, b, kn, l, stride, max_sep):
    rng = np.random.default_rng(seed)
    a = _rows(rng, b, 1, l, stride)[:, 0]
    ns = _rows(rng, b, kn, l, stride)
    ns_r = rng.integers(0, R_MAX + 1, (b, kn)).astype(np.int32)
    args = (jnp.asarray(a), jnp.asarray(ns), jnp.asarray(ns_r))
    got = window_join(*args, max_sep=max_sep, r_max=R_MAX)
    ref = window_join_ref(*args, max_sep=max_sep, r_max=R_MAX)
    cpu = _cpu_join(a, ns, ns_r, max_sep=max_sep)
    _assert_same(got, ref)
    _assert_same(got, cpu)


@property_cases(25, seed=(0, 2**31 - 1), stride=(1, 4))
def test_counting_qt5_stop_fold(seed, stride):
    rng = np.random.default_rng(seed)
    b, kn, ks, l, max_sep = 2, 2, 2, 32, 5
    a = _rows(rng, b, 1, l, stride)[:, 0]
    ns = _rows(rng, b, kn, l, stride)
    ns_r = rng.integers(0, R_MAX + 1, (b, kn)).astype(np.int32)
    st_cnt, st_ext, st_r = _stops(rng, b, ks, l, max_sep)
    args = (jnp.asarray(a), jnp.asarray(ns), jnp.asarray(ns_r),
            jnp.asarray(st_cnt), jnp.asarray(st_ext), jnp.asarray(st_r))
    got = window_join(*args, max_sep=max_sep, r_max=R_MAX)
    ref = window_join_ref(*args, max_sep=max_sep, r_max=R_MAX)
    cpu = _cpu_join(a, ns, ns_r, st_cnt, st_ext, st_r, max_sep=max_sep)
    _assert_same(got, ref)
    _assert_same(got, cpu)


# ---------------- Pallas kernel (interpret) vs oracle -----------------------
@property_cases(10, seed=(0, 2**31 - 1), stride=(1, 4))
def test_pallas_vs_ref(seed, stride):
    # Fixed shape/statics: one trace across examples (interpret is slow).
    rng = np.random.default_rng(seed)
    b, kn, l, max_sep = 2, 2, 48, 4
    a = _rows(rng, b, 1, l, stride, p_empty=0.0)[:, 0]
    ns = _rows(rng, b, kn, l, stride)
    ns_r = rng.integers(0, R_MAX + 1, (b, kn)).astype(np.int32)
    args = (jnp.asarray(a), jnp.asarray(ns), jnp.asarray(ns_r))
    got = window_join(*args, max_sep=max_sep, r_max=R_MAX,
                      use_pallas=True, interpret=True, block_l=16, block_k=16)
    ref = window_join_ref(*args, max_sep=max_sep, r_max=R_MAX)
    _assert_same(got, ref)


@property_cases(6, seed=(0, 2**31 - 1))
def test_pallas_qt5_stop_fold(seed):
    rng = np.random.default_rng(seed)
    b, kn, ks, l, max_sep = 2, 2, 2, 32, 4
    a = _rows(rng, b, 1, l, 3, p_empty=0.0)[:, 0]
    ns = _rows(rng, b, kn, l, 3)
    ns_r = rng.integers(0, R_MAX + 1, (b, kn)).astype(np.int32)
    st_cnt, st_ext, st_r = _stops(rng, b, ks, l, max_sep)
    args = (jnp.asarray(a), jnp.asarray(ns), jnp.asarray(ns_r),
            jnp.asarray(st_cnt), jnp.asarray(st_ext), jnp.asarray(st_r))
    got = window_join(*args, max_sep=max_sep, r_max=R_MAX,
                      use_pallas=True, interpret=True, block_l=16, block_k=16)
    ref = window_join_ref(*args, max_sep=max_sep, r_max=R_MAX)
    cpu = _cpu_join(a, ns, ns_r, st_cnt, st_ext, st_r, max_sep=max_sep)
    _assert_same(got, ref)
    _assert_same(got, cpu)


def test_pallas_block_boundary_straddle():
    """Candidates of one anchor block live in two different key b-tiles:
    anchors sit right at block_k boundaries of a dense key row, so the
    r nearest predecessors land in tile t and the successors in t+1.
    Exercised both with the safe full-row k_tiles bound and with the
    exact ``plan_k_tiles`` bound."""
    l, block, max_sep = 32, 8, 6
    ns = np.arange(2, 2 + 2 * l, 2, dtype=np.int32)[None, None, :]  # 2,4,..,64
    # anchors at the values just past each 8-value tile edge (16, 32, 48)
    a = np.full((1, l), SENTINEL, np.int32)
    a[0, :6] = [15, 17, 31, 33, 47, 49]
    ns_r = np.full((1, 1), 3, np.int32)
    args = (jnp.asarray(a), jnp.asarray(ns), jnp.asarray(ns_r))
    ref = window_join_ref(*args, max_sep=max_sep, r_max=R_MAX)
    for kt in (None, plan_k_tiles(a, ns, max_sep, block, block)):
        got = window_join(*args, max_sep=max_sep, r_max=R_MAX,
                          use_pallas=True, interpret=True,
                          block_l=block, block_k=block, k_tiles=kt)
        _assert_same(got, ref)
    # every anchor has >=3 even neighbours within 6 on both sides
    valid = np.asarray(ref[0])
    assert valid[0, :6].all() and not valid[0, 6:].any()


# ---------------- deterministic tie-breaking + degenerate cases -------------
def test_tie_pred_before_succ():
    """At equal distance the CPU column order [idx-1, idx, idx-2, ...]
    keeps pred_p before succ_q iff p <= q; pin one hand-computed case on
    all three implementations."""
    a = np.array([[100, SENTINEL]], np.int32)
    ns = np.array([[[98, 102]]], np.int32)  # pred and succ both at dist 2
    for r, want_lo, want_hi in ((1, 98, 100), (2, 98, 102)):
        ns_r = np.array([[r]], np.int32)
        args = (jnp.asarray(a), jnp.asarray(ns), jnp.asarray(ns_r))
        for impl in (
            lambda: window_join(*args, max_sep=5, r_max=R_MAX),
            lambda: window_join_ref(*args, max_sep=5, r_max=R_MAX),
            lambda: window_join(*args, max_sep=5, r_max=R_MAX,
                                use_pallas=True, interpret=True,
                                block_l=8, block_k=8),
        ):
            valid, lo, hi = _np3(impl())
            assert valid[0, 0] and not valid[0, 1]
            assert lo[0, 0] == want_lo and hi[0, 0] == want_hi
    # and the CPU oracle agrees on the r=1 tie
    m, mn, mx = search._nearest_r(np.array([98, 102], np.int64),
                                  np.array([100], np.int64), 5, 1)
    assert m[0] and mn[0] == 98 and mx[0] == 98


def test_inactive_and_empty_keys():
    a = np.array([[10, 20, SENTINEL, SENTINEL]], np.int32)
    empty = np.full((1, 1, 4), SENTINEL, np.int32)
    # r=0: key is padding -> anchors valid with degenerate [a, a] windows
    v, lo, hi = _np3(window_join(jnp.asarray(a), jnp.asarray(empty),
                                 jnp.asarray(np.zeros((1, 1), np.int32)),
                                 max_sep=3, r_max=R_MAX))
    assert list(v[0]) == [True, True, False, False]
    np.testing.assert_array_equal(lo[0, :2], [10, 20])
    np.testing.assert_array_equal(hi[0, :2], [10, 20])
    # r>0 against an empty row -> nothing matches, same as the CPU engine
    v, _, _ = _np3(window_join(jnp.asarray(a), jnp.asarray(empty),
                               jnp.asarray(np.ones((1, 1), np.int32)),
                               max_sep=3, r_max=R_MAX))
    assert not v.any()
    cpu_v, _, _ = _cpu_join(a, empty, np.ones((1, 1), np.int32), max_sep=3)
    assert not cpu_v.any()
