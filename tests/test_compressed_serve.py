"""§Perf hillclimb C: compressed posting payloads must return identical
results to the baseline serve step."""

import numpy as np
import pytest
import jax

from repro.core.index_builder import build_index
from repro.core.jax_search import (
    compress_qt1_batch,
    decode_results,
    make_qt1_serve_step,
    make_qt1_serve_step_compressed,
    pack_qt1_batch,
)
from repro.data.corpus import generate_corpus, sample_stop_queries
from repro.launch.mesh import make_mesh


@pytest.fixture(scope="module")
def world():
    table, lex = generate_corpus(n_docs=80, mean_doc_len=70, vocab_size=500, seed=11)
    lex.sw_count = 14
    lex.fu_count = 30
    idx = build_index(table, lex, max_distance=5)
    queries = sample_stop_queries(table, lex, 12, window=5, seed=4)
    batch = pack_qt1_batch(idx, queries, L=2048, K=2)
    mesh = make_mesh((1, 1), ("data", "model"))
    base_step = make_qt1_serve_step(mesh, top_k=256)
    base = decode_results(batch, *base_step(*batch.device_args()))
    return mesh, batch, base


@pytest.mark.parametrize("delta_g", [False, True])
def test_compressed_matches_baseline(world, delta_g):
    mesh, batch, base = world
    step = make_qt1_serve_step_compressed(mesh, top_k=256, delta_g=delta_g)
    args = compress_qt1_batch(batch, delta_g=delta_g)
    got = decode_results(batch, *step(*args))
    for qi in range(len(base)):
        b = set(zip(base[qi]["doc"].tolist(), base[qi]["start"].tolist(), base[qi]["end"].tolist()))
        g = set(zip(got[qi]["doc"].tolist(), got[qi]["start"].tolist(), got[qi]["end"].tolist()))
        assert b == g, (qi, b ^ g)


def test_compressed_bytes_reduction(world):
    mesh, batch, _ = world
    base_bytes = sum(np.asarray(a).nbytes for a in batch.device_args())
    for delta_g, expect_ratio in ((False, 1.8), (True, 2.5)):
        args = compress_qt1_batch(batch, delta_g=delta_g)
        comp_bytes = sum(np.asarray(a).nbytes for a in args)
        assert base_bytes / comp_bytes > expect_ratio, (delta_g, base_bytes, comp_bytes)
