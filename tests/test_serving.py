"""Serving runtime: bucketed search serving + LM continuous batching."""

import numpy as np
import pytest
import jax

from repro.core.index_builder import build_index
from repro.core.search import ProximitySearchEngine
from repro.data.corpus import generate_corpus, sample_stop_queries
from repro.launch.mesh import make_mesh
from repro.serving.engine import LMContinuousBatcher, SearchServingEngine


@pytest.fixture(scope="module")
def world():
    table, lex = generate_corpus(n_docs=200, mean_doc_len=80, vocab_size=2000, seed=9)
    lex.sw_count = 25
    lex.fu_count = 50
    idx = build_index(table, lex, max_distance=5)
    return table, lex, idx


def test_search_serving_matches_engine(world):
    table, lex, idx = world
    mesh = make_mesh((1, 1), ("data", "model"))
    eng = SearchServingEngine(idx, mesh, buckets=(256, 1024, 4096), max_batch=8, top_k=16)
    queries = sample_stop_queries(table, lex, 12, window=3, seed=1)
    for q in queries:
        eng.submit(q)
    responses = eng.drain()
    assert len(responses) == len(queries)
    ref = ProximitySearchEngine(idx, top_k=16, equalize_mode="bulk")
    # responses come back in per-bucket batches; match by re-submitting one
    eng2 = SearchServingEngine(idx, mesh, buckets=(256, 1024, 4096), max_batch=1, top_k=16)
    for q in queries[:4]:
        eng2.submit(q)
        (resp,) = eng2.drain()
        want, _ = ref.search_ids(q)
        got = set(zip(resp.results["doc"].tolist(), resp.results["start"].tolist()))
        expected = set(zip(want.doc.tolist()[:16], want.start.tolist()[:16]))
        # top-k sets agree (scores are equal -> order may differ at the tail)
        assert got <= set(zip(want.doc.tolist(), want.start.tolist()))
        if expected:
            assert got, f"no results for {q}"


def test_search_serving_stats(world):
    table, lex, idx = world
    mesh = make_mesh((1, 1), ("data", "model"))
    eng = SearchServingEngine(idx, mesh, buckets=(256, 4096), max_batch=4, top_k=8)
    queries = sample_stop_queries(table, lex, 10, window=3, seed=2)
    for q in queries:
        eng.submit(q)
    eng.drain()
    assert eng.stats["requests"] == 10
    assert eng.stats["batches"] >= 3  # max_batch=4 forces several batches


def test_lm_continuous_batching():
    from repro.configs.registry import get_arch
    from repro.models import transformer

    cfg = get_arch("stablelm-1.6b").reduced().model_cfg
    params = transformer.init_params(cfg, jax.random.key(0))
    batcher = LMContinuousBatcher(cfg, params, batch_slots=4, max_len=24, eos_id=-1)
    rids = [batcher.submit([1, 2, 3]) for _ in range(6)]  # 6 requests, 4 slots
    finished = {}
    for _ in range(80):
        finished.update(batcher.step())
        if len(finished) == 6:
            break
    assert len(finished) == 6, f"only {len(finished)} finished"
    for rid in rids:
        assert rid in finished
        assert 1 <= len(finished[rid]) <= 24
