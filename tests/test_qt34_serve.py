"""Compiled QT3/QT4 ordinary-window serve path (DESIGN.md §13): the
device join must match the CPU reference engine exactly — over static
and segmented (post-compaction) indexes, across all three payload
formats, through the per-key compressed-row cache, and the dispatch
matrix's fallback conditions must route (only) inexpressible shapes to
the scalar engine."""

import numpy as np
import pytest

from repro.core.index_builder import build_index
from repro.core.jax_search import (
    compress_qt34_batch,
    decode_results,
    make_wv_serve_step,
    pack_qt34_batch,
)
from repro.core.query import QueryType, classify, qt34_plan
from repro.core.search import ProximitySearchEngine
from repro.data.corpus import generate_corpus, sample_mixed_queries, sample_typed_queries
from repro.index import SegmentedIndex
from repro.launch.mesh import make_mesh
from repro.serving.engine import SearchServingEngine

D = 5
L = 512


@pytest.fixture(scope="module")
def world():
    table, lex = generate_corpus(n_docs=80, mean_doc_len=70, vocab_size=500, seed=11)
    lex.sw_count = 14
    lex.fu_count = 30
    idx = build_index(table, lex, max_distance=D)
    mesh = make_mesh((1, 1), ("data", "model"))
    queries = {
        k: sample_typed_queries(table, lex, 10, k, window=D, seed=3)
        for k in ("qt1", "qt2", "qt3", "qt4", "qt5")
    }
    return table, lex, idx, mesh, queries


def _cpu_sets(idx, qs):
    eng = ProximitySearchEngine(idx, top_k=100_000, equalize_mode="bulk")
    out = []
    for q in qs:
        res, _ = eng.search_ids(q)
        out.append(set(zip(res.doc.tolist(), res.start.tolist(), res.end.tolist())))
    return out


def _resp_set(r):
    return set(zip(r.results["doc"].tolist(), r.results["start"].tolist(),
                   r.results["end"].tolist()))


@pytest.mark.parametrize("kind", ["qt3", "qt4"])
@pytest.mark.parametrize("payload", ["raw", "delta", "offsets"])
def test_device_qt34_matches_reference(world, kind, payload):
    table, lex, idx, mesh, queries = world
    qs = queries[kind]
    want_type = QueryType.QT3 if kind == "qt3" else QueryType.QT4
    assert all(classify(q, lex) == want_type for q in qs)
    batch = pack_qt34_batch(idx, qs, L=L, Kn=4)
    step = make_wv_serve_step(mesh, "qt34", top_k=256, payload=payload,
                              max_distance=D, r_max=4)
    args = (compress_qt34_batch(batch, delta_g=True) if payload == "delta"
            else batch.device_args())
    decoded = decode_results(batch, *step(*args))
    got = [
        set(zip(decoded[i]["doc"].tolist(), decoded[i]["start"].tolist(),
                decoded[i]["end"].tolist()))
        for i in range(len(qs))
    ]
    for qi, (g, w) in enumerate(zip(got, _cpu_sets(idx, qs))):
        assert g == w, (kind, payload, qi, qs[qi], sorted(g ^ w)[:5])


def test_qt34_no_longer_counts_as_cpu(world):
    """The dispatch-matrix regression of this layer: expressible QT3 and
    QT4 queries must route to the compiled "qt34" path — a reappearing
    `cpu` count here means the serve tier lost its last-query-class
    coverage (the exact tail the paper's guarantee is about)."""
    table, lex, idx, mesh, queries = world
    qs = queries["qt3"][:8] + queries["qt4"][:8]
    eng = SearchServingEngine(idx, mesh, buckets=(256, 1024), max_batch=8, top_k=256)
    for q in qs:
        eng.submit(q)
    resp = eng.drain()
    assert [r.path for r in resp] == ["qt34"] * len(qs)
    assert eng.stats["paths"]["qt34"] == len(qs)
    assert eng.stats["paths"]["cpu"] == 0


def test_five_type_mixed_drain_submission_order(world):
    """One drain over all five query classes: responses stay in
    submission order (the slot i response answers the slot i request),
    every compiled path is exercised, and each response matches the CPU
    reference."""
    table, lex, idx, mesh, queries = world
    mixed = [q for k in ("qt1", "qt2", "qt3", "qt4", "qt5") for q in queries[k][:5]]
    # interleave so grouped serving must scatter results back by slot
    order = np.argsort(np.arange(len(mixed)) % 5, kind="stable")
    mixed = [mixed[i] for i in order]
    eng = SearchServingEngine(idx, mesh, buckets=(256, 1024), max_batch=8, top_k=256)
    for q in mixed:
        eng.submit(q)
    resp = eng.drain()
    assert len(resp) == len(mixed)
    want = _cpu_sets(idx, mixed)
    for q, r, w in zip(mixed, resp, want):
        assert _resp_set(r) == w, (q, r.path, sorted(_resp_set(r) ^ w)[:5])
    paths = eng.stats["paths"]
    assert paths["qt1"] >= 5 and paths["qt2"] == 5 and paths["qt5"] == 5
    assert paths["qt34"] == 10  # both QT3 and QT4 slices
    assert paths["cpu"] == 0


@pytest.mark.parametrize("use_ccache", [True, False])
def test_qt34_compressed_matches_uncompressed(world, use_ccache):
    table, lex, idx, mesh, queries = world
    qs = queries["qt3"][:6] + queries["qt4"][:6]
    base = SearchServingEngine(idx, mesh, buckets=(256, 1024), max_batch=8, top_k=256)
    comp = SearchServingEngine(idx, mesh, buckets=(256, 1024), max_batch=8,
                               top_k=256, compressed=True,
                               use_compressed_cache=use_ccache)
    for round_ in range(2):  # second round serves from the row caches
        for q in qs:
            base.submit(q)
            comp.submit(q)
        got_b = [_resp_set(r) for r in base.drain()]
        got_c = [_resp_set(r) for r in comp.drain()]
        assert got_b == got_c, round_
    assert comp.stats["compressed_batches"] > 0
    if use_ccache:
        st = comp.stats["compressed_cache"]
        assert st["hits"] > 0 and st["misses"] > 0


def test_qt34_fallback_conditions(world):
    """Only inexpressible shapes take the scalar engine: more distinct
    lemmas than k_ord, a multiplicity beyond r_max, or a posting list
    longer than the largest L-bucket — and they still match it, because
    they *are* it."""
    table, lex, idx, mesh, queries = world
    fu_hi = lex.sw_count + lex.fu_count
    many = [int(l) for l in range(fu_hi, fu_hi + 6)]  # 5 others > k_ord=4
    heavy = [int(queries["qt3"][0][0])] * 6  # multiplicity 6 > r_max=4
    assert classify(many, lex) == QueryType.QT3
    assert classify(heavy, lex) == QueryType.QT3
    eng = SearchServingEngine(idx, mesh, buckets=(256, 1024), max_batch=8, top_k=256)
    for q in (many, heavy):
        eng.submit(q)
    resp = eng.drain()
    want = _cpu_sets(idx, [many, heavy])
    for r, w in zip(resp, want):
        assert r.path == "cpu" and _resp_set(r) == w
    # a QT4 anchored on a frequently-used lemma whose ordinary posting
    # list exceeds every bucket is likewise inexpressible
    tiny = SearchServingEngine(idx, mesh, buckets=(16,), max_batch=8, top_k=256)
    q4 = queries["qt4"][0]
    assert max(qt34_plan(idx, q4)[2].values()) > 16
    tiny.submit(q4)
    (r,) = tiny.drain()
    assert r.path == "cpu"
    assert _resp_set(r) == _cpu_sets(idx, [q4])[0]


def test_qt34_repeated_lemma_multiplicities(world):
    """A duplicated lemma adds an r-nearest constraint (r > 1) on its
    own row — including the anchor re-windowing its own posting row."""
    table, lex, idx, mesh, queries = world
    qs = []
    for q in queries["qt3"] + queries["qt4"]:
        plan_anchor = qt34_plan(idx, q)[0]
        qs.append(q + [plan_anchor])  # duplicate the anchor
        qs.append(q + [int(q[-1])])  # duplicate a non-anchor lemma
    qs = [q for q in qs if classify(q, lex) in (QueryType.QT3, QueryType.QT4)][:12]
    # k_ord=6: a duplicated anchor on a 5-distinct-lemma query carries 5
    # window constraints, one past the default K — keep it on-device here
    eng = SearchServingEngine(idx, mesh, buckets=(256, 1024), max_batch=8,
                              top_k=256, k_ord=6)
    for q in qs:
        eng.submit(q)
    resp = eng.drain()
    want = _cpu_sets(idx, qs)
    for q, r, w in zip(qs, resp, want):
        assert _resp_set(r) == w, (q, r.path, sorted(_resp_set(r) ^ w)[:5])
    assert eng.stats["paths"]["cpu"] == 0


def test_qt34_segmented_post_compaction(world):
    """QT3/QT4 dispatch over a segmented snapshot that went through
    deletes and a forced major compaction must match a CPU engine over
    the same snapshot — uncompressed and compressed."""
    table, lex, idx, mesh, queries = world
    seg = SegmentedIndex(lex, max_distance=D, memtable_docs=16)
    for d in table.to_doc_lists():
        seg.add_document(d)
    seg.refresh()
    seg.delete_document(7)
    seg.delete_document(23)
    seg.compact(force=True)
    view = seg.refresh()
    qs = queries["qt3"][:6] + queries["qt4"][:6]
    eng = SearchServingEngine(seg, mesh, buckets=(256, 1024), max_batch=8, top_k=256)
    comp = SearchServingEngine(seg, mesh, buckets=(256, 1024), max_batch=8,
                               top_k=256, compressed=True)
    for q in qs:
        eng.submit(q)
        comp.submit(q)
    got = [_resp_set(r) for r in eng.drain()]
    got_c = [_resp_set(r) for r in comp.drain()]
    want = _cpu_sets(view, qs)
    assert got == want
    assert got_c == want
    served = {doc for s in got for doc, _, _ in s}
    assert 7 not in served and 23 not in served
