"""Equalize: heap (§2.3), basic ([10]) and bulk (vectorized) must agree."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.equalize import (
    EqualizeState,
    PostingIterator,
    bulk_align_docs,
    equalize_basic,
)


def _mk_iters(doc_lists):
    return [
        PostingIterator(np.array(sorted(ds), np.int64), np.zeros(len(ds), np.int64))
        for ds in doc_lists
    ]


def _drain_heap(doc_lists):
    iters = _mk_iters(doc_lists)
    st_ = EqualizeState(iters)
    out = []
    while (doc := st_.equalize()) is not None:
        out.append(doc)
        st_.advance_all_past_doc()
    return out


def _drain_basic(doc_lists):
    iters = _mk_iters(doc_lists)
    out = []
    while (doc := equalize_basic(iters)) is not None:
        out.append(doc)
        for it in iters:
            if not it.exhausted and it.value_id == doc:
                it.advance_past_doc()
    return out


doc_list_strategy = st.lists(
    st.lists(st.integers(0, 60), min_size=1, max_size=80), min_size=1, max_size=6
)


@given(doc_list_strategy)
@settings(max_examples=150, deadline=None)
def test_equalize_modes_agree(doc_lists):
    expected = sorted(set.intersection(*[set(ds) for ds in doc_lists]))
    assert _drain_heap(doc_lists) == expected
    assert _drain_basic(doc_lists) == expected
    bulk = bulk_align_docs([np.array(sorted(ds), np.int64) for ds in doc_lists])
    assert bulk.tolist() == expected


@given(doc_list_strategy)
@settings(max_examples=50, deadline=None)
def test_equalize_no_gallop_agrees(doc_lists):
    """The paper's literal step-3 (IT.Next, no galloping) must agree too."""
    iters = _mk_iters(doc_lists)
    st_ = EqualizeState(iters)
    out = []
    while (doc := st_.equalize(gallop=False)) is not None:
        out.append(doc)
        st_.advance_all_past_doc()
    expected = sorted(set.intersection(*[set(ds) for ds in doc_lists]))
    assert out == expected


def test_duplicate_docs_within_list():
    # multiple postings per document (common in position lists)
    doc_lists = [[1, 1, 2, 5, 5, 9], [1, 5, 5, 5], [0, 1, 5, 9, 9]]
    assert _drain_heap(doc_lists) == [1, 5]
    assert _drain_basic(doc_lists) == [1, 5]
