"""Neighbor sampler (minibatch_lg substrate) + EGNN training integration."""

import numpy as np
import pytest

from repro.data.graph_data import CSRGraph, minibatch_stream, sample_fanout_subgraph


@pytest.fixture(scope="module")
def graph():
    return CSRGraph.random(n_nodes=2000, avg_degree=12, seed=1)


def test_sampled_edges_exist_in_graph(graph):
    rng = np.random.default_rng(0)
    seeds = rng.choice(graph.n_nodes, 16, replace=False)
    sub = sample_fanout_subgraph(graph, seeds, (5, 3), rng, pad_nodes=512, pad_edges=512)
    n_e = sub["n_real_edges"]
    assert n_e > 0
    nodes = sub["nodes"]
    for i in range(n_e):
        s_global = nodes[sub["src"][i]]
        d_global = nodes[sub["dst"][i]]
        assert d_global in graph.neighbors(int(s_global)), (s_global, d_global)


def test_fanout_bounds(graph):
    rng = np.random.default_rng(1)
    seeds = rng.choice(graph.n_nodes, 8, replace=False)
    f = (4, 2)
    sub = sample_fanout_subgraph(graph, seeds, f, rng, pad_nodes=512, pad_edges=512)
    # hop-1 edges <= seeds*4; hop-2 <= (seeds*4)*2
    assert sub["n_real_edges"] <= 8 * 4 + 8 * 4 * 2
    assert sub["n_real_nodes"] <= 8 + 8 * 4 + 8 * 4 * 2


def test_seeds_come_first(graph):
    rng = np.random.default_rng(2)
    seeds = rng.choice(graph.n_nodes, 8, replace=False)
    sub = sample_fanout_subgraph(graph, seeds, (3,), rng, pad_nodes=128, pad_edges=128)
    np.testing.assert_array_equal(sub["nodes"][:8], seeds)


def test_minibatch_stream_feeds_egnn_training(graph):
    """Sampled batches drive a real EGNN train step (the minibatch_lg
    pipeline end to end)."""
    import jax.numpy as jnp
    import jax

    from repro.models import gnn
    from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state

    cfg = gnn.EGNNConfig(n_layers=2, d_hidden=16, d_feat=12)
    params = gnn.init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params)
    opt_cfg = AdamWConfig(lr=1e-3, weight_decay=0.0)
    feats = np.random.default_rng(0).normal(size=(graph.n_nodes, 12)).astype(np.float32)
    targets = feats.sum(axis=1)
    stream = minibatch_stream(graph, feats, targets, batch_nodes=16, fanout=(4, 3),
                              pad_nodes=512, pad_edges=512, seed=3)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(lambda p: gnn.loss_fn(cfg, p, batch))(params)
        p2, o2, _ = adamw_update(opt_cfg, params, grads, opt)
        return p2, o2, loss

    losses = []
    for _ in range(12):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
