"""§Perf hillclimb A: int8 KV cache numerics vs the bf16 cache."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.models import transformer


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("stablelm-1.6b").reduced().model_cfg
    params = transformer.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    B, S = 4, 48
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, 12)), jnp.int32)
    return cfg, params, prompt, B, S


def _decode_teacher_forced(cfg, params, tokens, B, S, quantized):
    """Feed a fixed token stream (no sampling feedback) and collect the
    per-step logits — isolates the cache-quantization error from greedy-
    decoding divergence."""
    cache = transformer.init_cache(cfg, B, S, quantized=quantized)
    logits_seq = []
    for pos in range(tokens.shape[1]):
        logits, cache = transformer.decode_step(
            cfg, params, tokens[:, pos : pos + 1], cache, pos
        )
        logits_seq.append(logits)
    return jnp.stack(logits_seq, axis=1)


def test_int8_cache_matches_bf16(setup):
    cfg, params, prompt, B, S = setup
    rng = np.random.default_rng(1)
    stream = jnp.concatenate(
        [prompt, jnp.asarray(rng.integers(0, cfg.vocab, (B, 12)), jnp.int32)], axis=1
    )
    ref = _decode_teacher_forced(cfg, params, stream, B, S, quantized=False)
    q = _decode_teacher_forced(cfg, params, stream, B, S, quantized=True)
    ref_f = np.asarray(ref, np.float32)
    q_f = np.asarray(q, np.float32)
    cos = (ref_f * q_f).sum() / (np.linalg.norm(ref_f) * np.linalg.norm(q_f))
    assert cos > 0.995, cos
    agreement = (ref_f.argmax(-1) == q_f.argmax(-1)).mean()
    assert agreement >= 0.9, agreement


def test_int8_cache_size_is_quarter(setup):
    cfg, params, prompt, B, S = setup
    c16 = transformer.init_cache(cfg, B, S, quantized=False)
    c8 = transformer.init_cache(cfg, B, S, quantized=True)
    b16 = sum(np.asarray(x).nbytes for x in jax.tree.leaves(c16))
    b8 = sum(np.asarray(x).nbytes for x in jax.tree.leaves(c8))
    # int8 + f32 per-head scales: ratio = (1 + 4/head_dim) / 2; the smoke
    # config's head_dim=16 gives 0.625, production head_dim=128 gives 0.52
    assert b8 < b16 * 0.7, (b8, b16)
